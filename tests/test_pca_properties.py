"""Hypothesis property tests on the paper's PCA invariants.

Separated from test_pca.py so the optional ``hypothesis`` dependency can
never break tier-1 collection: importorskip skips this module cleanly when
the package is absent (it ships in the ``dev`` extra).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import fit_pca, inverse_transform, transform, transform_query


@settings(max_examples=20, deadline=None)
@given(n=st.integers(20, 200), d=st.integers(4, 48),
       seed=st.integers(0, 1000))
def test_property_eigenvalues_nonneg_sum_to_trace(n, d, seed):
    rng = np.random.default_rng(seed)
    D = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    s = fit_pca(D)
    ev = np.asarray(s.eigenvalues, np.float64)
    assert (ev >= -1e-3).all()
    trace = float(np.trace(np.asarray(D, np.float64).T @ np.asarray(D, np.float64)))
    assert np.isclose(ev.sum(), trace, rtol=2e-3)


@settings(max_examples=20, deadline=None)
@given(d=st.integers(6, 40), m_frac=st.floats(0.2, 0.9),
       seed=st.integers(0, 1000))
def test_property_projection_norm_never_increases(d, m_frac, seed):
    """||W_mᵀ x|| <= ||x||: orthogonal projection is a contraction."""
    rng = np.random.default_rng(seed)
    D = jnp.asarray(rng.standard_normal((100, d)), jnp.float32)
    s = fit_pca(D)
    m = max(1, int(d * m_frac))
    X = jnp.asarray(rng.standard_normal((17, d)), jnp.float32)
    T = transform(X, s, m)
    assert (np.linalg.norm(np.asarray(T), axis=1)
            <= np.linalg.norm(np.asarray(X), axis=1) + 1e-3).all()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), m=st.integers(1, 16))
def test_property_truncation_error_monotone(seed, m):
    """Reconstruction error is non-increasing in m (Eckart–Young)."""
    rng = np.random.default_rng(seed)
    D = jnp.asarray(rng.standard_normal((80, 16)), jnp.float32)
    s = fit_pca(D)

    def err(mm):
        T = transform(D, s, mm)
        rec = inverse_transform(T, s)
        return float(jnp.linalg.norm(rec - D))

    if m < 16:
        assert err(m) >= err(m + 1) - 1e-3


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_query_doc_symmetry(seed):
    """Scores via transformed docs+queries == scores in truncated space either way."""
    rng = np.random.default_rng(seed)
    D = jnp.asarray(rng.standard_normal((60, 24)), jnp.float32)
    q = jnp.asarray(rng.standard_normal((24,)), jnp.float32)
    s = fit_pca(D)
    m = 12
    s1 = transform(D, s, m) @ transform_query(q, s, m)
    W = s.components[:, :m]
    s2 = (D @ W) @ (W.T @ q)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3,
                               atol=1e-4)
