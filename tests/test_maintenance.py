"""Index maintenance: incremental adds + drift-triggered refit policy."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.maintenance import IndexUpdater, captured_energy
from repro.core.pruning import StaticPruner
from repro.data.synthetic import make_corpus


def _corpus(seed=0, n=2000, domain_seed=None):
    D, _ = make_corpus("tasb", n_docs=n, d=96, seed=seed,
                       domain_seed=domain_seed)
    return jnp.asarray(D)


def test_add_documents_searchable():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    n0 = up.index.n
    new = _corpus(seed=0, n=200, domain_seed=1)[:100]
    up.add_documents(new)
    assert up.index.n == n0 + 100
    # a newly added doc retrieves itself
    _, ids = up.search(new[3][None, :], k=5)
    assert n0 + 3 in np.asarray(ids)[0].tolist()


def test_add_documents_int8_path():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5, quantize_int8=True)
    up.add_documents(_corpus(seed=0, n=120, domain_seed=2)[:50])
    assert up.index.base.vectors.dtype == jnp.int8
    assert up.index.deltas[0].vectors.dtype == jnp.int8
    assert up.index.deltas[0].scale is not None     # its OWN scale
    s, ids = up.search(D[:2], k=5)
    assert np.isfinite(np.asarray(s)).all()


def test_drift_low_in_domain_high_out_of_domain():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    in_dom = _corpus(seed=0, n=500, domain_seed=3)  # same encoder basis
    assert up.drift_score(in_dom) > 0.85
    # totally different basis (different encoder seed => rotated space)
    ood, _ = make_corpus("tasb", n_docs=500, d=96, seed=99)
    assert up.drift_score(jnp.asarray(ood)) < up.drift_score(in_dom)


def test_refit_restores_energy():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    shifted, _ = make_corpus("tasb", n_docs=2000, d=96, seed=99)
    shifted = jnp.asarray(shifted)
    before = up.drift_score(shifted)
    up.refit(shifted)
    after = up.drift_score(shifted)
    assert after > before
    assert abs(up.drift_score(shifted) - 1.0) < 0.05


def test_drift_score_without_fit_energy():
    """Directly-constructed updater (dataclass default fit_energy=None)
    used to raise TypeError in drift_score; the reference energy is now
    derived lazily from the eigenvalues — and matches the corpus-measured
    one on the fit corpus itself."""
    D = _corpus()
    pruner = StaticPruner(cutoff=0.5).fit(D)
    up = IndexUpdater(pruner=pruner, index=pruner.build_index(D))
    assert up.fit_energy is None
    score = up.drift_score(D[:500])        # must not raise
    assert 0.5 < score < 1.5
    # lazy reference == measured reference (uncentered Gram identity)
    measured = captured_energy(D, pruner)
    assert abs(up._reference_energy() - measured) < 2e-3


def test_drift_reference_centered_fit():
    """The lazy reference must also be exact for center=True fits, where
    captured_energy's uncentered ratio picks up the mean's energy."""
    D = _corpus() + 3.0                    # nonzero mean: centering matters
    pruner = StaticPruner(cutoff=0.5, center=True).fit(D)
    up = IndexUpdater(pruner=pruner, index=pruner.build_index(D))
    measured = captured_energy(D, pruner)
    assert abs(up._reference_energy() - measured) < 2e-3
    assert abs(up.drift_score(D) - 1.0) < 5e-3


def test_ood_append_scale_policy_trips_refit():
    """The frozen-scale regression, inverted: an out-of-distribution append
    used to clip silently under the base's scale. Per-delta scales now
    widen instead (clip_fraction is structurally zero), and the policy
    signal is the scale DIVERGENCE between delta and base."""
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5, quantize_int8=True)
    # in-distribution append: delta scale stays near the base's
    in_dom = _corpus(seed=0, n=200, domain_seed=4)[:100]
    up.add_documents(in_dom)
    assert up.clip_fraction == 0.0
    assert up.scale_divergence() < 4.0
    assert not up.needs_refit(in_dom)
    # OOD magnitudes: same subspace (drift blind), 50x the dynamic range —
    # nothing clips, but the delta's widened scale flags the divergence
    up.add_documents(50.0 * in_dom)
    assert up.clip_fraction == 0.0
    assert up.scale_divergence() > 4.0
    # drift_score can't see it (same subspace, energy ratio unchanged)...
    assert up.drift_score(50.0 * in_dom) > 0.9
    # ...but the scale policy trips the refit
    assert up.needs_refit(50.0 * in_dom)


def test_clip_fraction_zero_on_float_index():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    up.add_documents(1e6 * _corpus(seed=0, n=120, domain_seed=5)[:40])
    assert up.clip_fraction == 0.0
    assert up.scale_divergence() == 1.0             # unquantised: no scales


def test_delta_fraction_trips_refit():
    """Compaction pressure: once the deltas hold most of the corpus, the
    policy asks for a compaction even with zero drift."""
    D = _corpus(n=400)
    up = IndexUpdater.build(D, cutoff=0.5)
    in_dom = _corpus(seed=0, n=900, domain_seed=7)[400:]
    up.add_documents(in_dom)
    assert up.delta_fraction > 0.5
    # threshold=0 disables the drift leg: delta_fraction alone must trip
    assert up.needs_refit(in_dom[:100], threshold=0.0)
    up.compact()
    assert up.delta_fraction == 0.0
    assert not up.needs_refit(in_dom[:100], threshold=0.0)


def test_refit_resets_segments_and_telemetry():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5, quantize_int8=True)
    up.add_documents(50.0 * _corpus(seed=0, n=120, domain_seed=6)[:40])
    assert up.scale_divergence() > 1.0
    assert len(up.index.deltas) == 1
    up.refit(D)
    assert up.scale_divergence() == 1.0
    assert len(up.index.deltas) == 0
    assert up.appended_rows == 0


def test_captured_energy_bounds():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    e = captured_energy(D, up.pruner)
    assert 0.0 < e <= 1.0


# ---------------------------------------------------------------------------
# Lock-discipline regressions (crop of `python -m repro.analysis` findings:
# telemetry read index/pruner without the updater lock, _reference_energy
# wrote its cache bare and did D2H transfers under the lock)
# ---------------------------------------------------------------------------


def test_telemetry_safe_under_concurrent_appends():
    """delta_fraction/scale_divergence/drift_score/search snapshot
    (index, pruner) under the lock: hammering them while another thread
    appends must never raise (previously they could observe a half-swapped
    segment set)."""
    import threading

    D = _corpus(n=600)
    up = IndexUpdater.build(D, cutoff=0.5, quantize_int8=True,
                            delta_capacity=64)
    probe = _corpus(seed=2, n=64, domain_seed=3)
    errs = []
    done = threading.Event()

    def appender():
        try:
            for i in range(30):
                up.add_documents(_corpus(seed=i + 10, n=40,
                                         domain_seed=4)[:37])
        finally:
            done.set()

    th = threading.Thread(target=appender)
    th.start()
    try:
        while not done.is_set():
            try:
                assert 0.0 <= up.delta_fraction <= 1.0
                assert up.scale_divergence() >= 1.0
                assert up.drift_score(probe) > 0.0
                up.needs_refit(probe)
                up.search(probe[:2], k=3)
            except BaseException as e:  # noqa: BLE001 — must fail the test
                errs.append(e)
                break
    finally:
        th.join(timeout=60.0)
    assert not errs
    assert up.appended_rows == 30 * 37
    assert abs(up.delta_fraction - 30 * 37 / up.index.n) < 1e-9


def test_reference_energy_cached_once_and_refit_coherent():
    """The lazy fit_energy fill happens outside the lock but commits under
    it, and a refit that swaps the pruner mid-derivation must not be
    clobbered by the stale value."""
    D = _corpus(n=400)
    pruner = StaticPruner(cutoff=0.5).fit(D)
    up = IndexUpdater(pruner=pruner, index=pruner.build_index(D))
    assert up.fit_energy is None
    ref = up._reference_energy()
    assert up.fit_energy == ref                  # cached under the lock
    assert ref == up._reference_energy()         # stable on re-read
    D2 = _corpus(seed=9, n=400, domain_seed=7)
    up.refit(D2)
    assert up.fit_energy is not None and up.fit_energy != ref
    assert abs(up.drift_score(D2) - captured_energy(D2, up.pruner)
               / up.fit_energy) < 1e-9


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_background_compaction_failure_surfaces_in_health(monkeypatch):
    """A compact_async thread that dies must be RECORDED, not swallowed:
    health() flips to not-ok and carries the error, so a fleet health
    probe can see the dead maintenance thread. (The background re-raise is
    part of the loud-death contract — the thread warning is expected.)"""
    D = _corpus(n=400)
    up = IndexUpdater.build(D, cutoff=0.5)
    up.add_documents(_corpus(seed=3, n=80, domain_seed=4)[:40])
    assert up.health()["ok"]

    def boom(**kw):
        raise RuntimeError("disk full mid-compaction")

    monkeypatch.setattr(up, "compact", boom)
    th = up.compact_async()
    th.join(timeout=60.0)
    health = up.health()
    assert not health["ok"]
    assert health["background_errors"][0]["op"] == "compact"
    assert "disk full" in health["background_errors"][0]["error"]
    # serving-path reads still work: the failure is visible, not fatal
    _, ids = up.search(D[:2], k=3)
    assert np.asarray(ids).shape == (2, 3)
