"""Index maintenance: incremental adds + drift-triggered refit policy."""
import numpy as np
import jax.numpy as jnp

from repro.core.maintenance import IndexUpdater, captured_energy
from repro.data.synthetic import make_corpus, make_ood_corpus


def _corpus(seed=0, n=2000, domain_seed=None):
    D, _ = make_corpus("tasb", n_docs=n, d=96, seed=seed,
                       domain_seed=domain_seed)
    return jnp.asarray(D)


def test_add_documents_searchable():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    n0 = up.index.n
    new = _corpus(seed=0, n=200, domain_seed=1)[:100]
    up.add_documents(new)
    assert up.index.n == n0 + 100
    # a newly added doc retrieves itself
    _, ids = up.search(new[3][None, :], k=5)
    assert n0 + 3 in np.asarray(ids)[0].tolist()


def test_add_documents_int8_path():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5, quantize_int8=True)
    up.add_documents(_corpus(seed=0, n=120, domain_seed=2)[:50])
    assert up.index.vectors.dtype == jnp.int8
    s, ids = up.search(D[:2], k=5)
    assert np.isfinite(np.asarray(s)).all()


def test_drift_low_in_domain_high_out_of_domain():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    in_dom = _corpus(seed=0, n=500, domain_seed=3)  # same encoder basis
    assert up.drift_score(in_dom) > 0.85
    # totally different basis (different encoder seed => rotated space)
    ood, _ = make_corpus("tasb", n_docs=500, d=96, seed=99)
    assert up.drift_score(jnp.asarray(ood)) < up.drift_score(in_dom)


def test_refit_restores_energy():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    shifted, _ = make_corpus("tasb", n_docs=2000, d=96, seed=99)
    shifted = jnp.asarray(shifted)
    before = up.drift_score(shifted)
    up.refit(shifted)
    after = up.drift_score(shifted)
    assert after > before
    assert abs(up.drift_score(shifted) - 1.0) < 0.05


def test_captured_energy_bounds():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    e = captured_energy(D, up.pruner)
    assert 0.0 < e <= 1.0
