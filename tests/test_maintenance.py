"""Index maintenance: incremental adds + drift-triggered refit policy."""
import numpy as np
import jax.numpy as jnp

from repro.core.maintenance import IndexUpdater, captured_energy
from repro.core.pruning import StaticPruner
from repro.data.synthetic import make_corpus, make_ood_corpus


def _corpus(seed=0, n=2000, domain_seed=None):
    D, _ = make_corpus("tasb", n_docs=n, d=96, seed=seed,
                       domain_seed=domain_seed)
    return jnp.asarray(D)


def test_add_documents_searchable():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    n0 = up.index.n
    new = _corpus(seed=0, n=200, domain_seed=1)[:100]
    up.add_documents(new)
    assert up.index.n == n0 + 100
    # a newly added doc retrieves itself
    _, ids = up.search(new[3][None, :], k=5)
    assert n0 + 3 in np.asarray(ids)[0].tolist()


def test_add_documents_int8_path():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5, quantize_int8=True)
    up.add_documents(_corpus(seed=0, n=120, domain_seed=2)[:50])
    assert up.index.vectors.dtype == jnp.int8
    s, ids = up.search(D[:2], k=5)
    assert np.isfinite(np.asarray(s)).all()


def test_drift_low_in_domain_high_out_of_domain():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    in_dom = _corpus(seed=0, n=500, domain_seed=3)  # same encoder basis
    assert up.drift_score(in_dom) > 0.85
    # totally different basis (different encoder seed => rotated space)
    ood, _ = make_corpus("tasb", n_docs=500, d=96, seed=99)
    assert up.drift_score(jnp.asarray(ood)) < up.drift_score(in_dom)


def test_refit_restores_energy():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    shifted, _ = make_corpus("tasb", n_docs=2000, d=96, seed=99)
    shifted = jnp.asarray(shifted)
    before = up.drift_score(shifted)
    up.refit(shifted)
    after = up.drift_score(shifted)
    assert after > before
    assert abs(up.drift_score(shifted) - 1.0) < 0.05


def test_drift_score_without_fit_energy():
    """Directly-constructed updater (dataclass default fit_energy=None)
    used to raise TypeError in drift_score; the reference energy is now
    derived lazily from the eigenvalues — and matches the corpus-measured
    one on the fit corpus itself."""
    D = _corpus()
    pruner = StaticPruner(cutoff=0.5).fit(D)
    up = IndexUpdater(pruner=pruner, index=pruner.build_index(D))
    assert up.fit_energy is None
    score = up.drift_score(D[:500])        # must not raise
    assert 0.5 < score < 1.5
    # lazy reference == measured reference (uncentered Gram identity)
    measured = captured_energy(D, pruner)
    assert abs(up._reference_energy() - measured) < 2e-3


def test_drift_reference_centered_fit():
    """The lazy reference must also be exact for center=True fits, where
    captured_energy's uncentered ratio picks up the mean's energy."""
    D = _corpus() + 3.0                    # nonzero mean: centering matters
    pruner = StaticPruner(cutoff=0.5, center=True).fit(D)
    up = IndexUpdater(pruner=pruner, index=pruner.build_index(D))
    measured = captured_energy(D, pruner)
    assert abs(up._reference_energy() - measured) < 2e-3
    assert abs(up.drift_score(D) - 1.0) < 5e-3


def test_add_documents_clip_fraction_ood():
    """Regression: an out-of-distribution append under the frozen int8
    scale used to clip silently. The clip fraction must be tracked,
    exposed, and trip needs_refit even when drift alone would not."""
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5, quantize_int8=True)
    # in-distribution append: essentially no clipping
    in_dom = _corpus(seed=0, n=200, domain_seed=4)[:100]
    frac_in = up.add_documents(in_dom)
    assert frac_in < 0.01
    assert up.clip_fraction < 0.01
    assert not up.needs_refit(in_dom)
    # OOD magnitudes: same subspace (drift blind), 50x the dynamic range
    frac_ood = up.add_documents(50.0 * in_dom)
    assert frac_ood > 0.5
    assert up.clip_fraction > 0.01
    # drift_score can't see it (same subspace, energy ratio unchanged)...
    assert up.drift_score(50.0 * in_dom) > 0.9
    # ...but the clip policy trips the refit
    assert up.needs_refit(50.0 * in_dom)


def test_clip_fraction_zero_on_float_index():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    frac = up.add_documents(1e6 * _corpus(seed=0, n=120, domain_seed=5)[:40])
    assert frac == 0.0 and up.clip_fraction == 0.0


def test_refit_resets_clip_telemetry():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5, quantize_int8=True)
    up.add_documents(50.0 * _corpus(seed=0, n=120, domain_seed=6)[:40])
    assert up.clip_fraction > 0.0
    up.refit(D)
    assert up.clip_fraction == 0.0


def test_captured_energy_bounds():
    D = _corpus()
    up = IndexUpdater.build(D, cutoff=0.5)
    e = captured_energy(D, up.pruner)
    assert 0.0 < e <= 1.0
