"""LM transformer: attention modes, MoE routing, decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as M
from repro.models.layers import blocked_attention, dense_attention
from repro.models.transformer import (
    TransformerConfig,
    _unembed,
    decode_step,
    decode_step_sliding,
    forward_hidden,
    forward_train,
    init_lm,
    prefill,
)

CFG = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                        d_ff=128, vocab=256, compute_dtype="float32",
                        remat=False)


@pytest.fixture(scope="module")
def lm():
    return init_lm(jax.random.PRNGKey(0), CFG)


def test_train_loss_and_grads_finite(lm):
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 256)
    labels = jnp.roll(toks, -1, 1)
    loss, grads = jax.value_and_grad(forward_train)(lm, toks, labels, CFG)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


def test_masked_labels_ignored(lm):
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 256)
    labels = jnp.roll(toks, -1, 1)
    l1 = forward_train(lm, toks, labels, CFG)
    labels_masked = labels.at[:, -4:].set(-1)
    l2 = forward_train(lm, toks, labels_masked, CFG)
    assert float(l1) != pytest.approx(float(l2))


def test_decode_matches_full_forward(lm):
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 10), 0, 256)
    h, _ = forward_hidden(lm, toks, CFG)
    full = _unembed(lm, h, CFG)
    _, cache = prefill(lm, toks[:, :9], CFG, cache_len=10)
    lg, _ = decode_step(lm, cache, toks[:, 9], jnp.int32(9), CFG)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, 9]),
                               rtol=1e-4, atol=1e-4)


def test_causality(lm):
    """Future tokens must not affect current logits."""
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 12), 0, 256)
    h1, _ = forward_hidden(lm, toks, CFG)
    toks2 = toks.at[:, -1].set((toks[:, -1] + 7) % 256)
    h2, _ = forward_hidden(lm, toks2, CFG)
    np.testing.assert_allclose(np.asarray(h1[:, :-1]), np.asarray(h2[:, :-1]),
                               atol=1e-5)


def test_sliding_window_restricts_context():
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab=128, sliding_window=4,
                            compute_dtype="float32", remat=False)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, 128)
    h1, _ = forward_hidden(p, toks, cfg)
    # changing token 0 must not affect position 10 (outside window 4)
    toks2 = toks.at[:, 0].set((toks[:, 0] + 3) % 128)
    h2, _ = forward_hidden(p, toks2, cfg)
    np.testing.assert_allclose(np.asarray(h1[:, 10:]), np.asarray(h2[:, 10:]),
                               atol=1e-5)


def test_sliding_decode_rolling_buffer_matches_static():
    cfg = TransformerConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab=128, sliding_window=8,
                            compute_dtype="float32", remat=False)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    S = 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S + 1), 0, 128)
    h, _ = forward_hidden(p, toks, cfg)
    want = _unembed(p, h, cfg)[:, S]
    # roll tokens through the W-slot rolling buffer
    W = cfg.sliding_window
    kv = (jnp.zeros((2, 1, W, 2, 16)), jnp.zeros((2, 1, W, 2, 16)))
    for pos in range(S + 1):
        lg, kv = decode_step_sliding(p, kv, toks[:, pos], jnp.int32(pos), cfg)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(want), rtol=1e-3,
                               atol=1e-3)


def test_moe_forward_and_aux():
    cfg = TransformerConfig(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab=128, n_experts=4, top_k=2,
                            compute_dtype="float32", remat=False,
                            moe_group_size=32)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128)
    loss = forward_train(p, toks, jnp.roll(toks, -1, 1), cfg)
    assert np.isfinite(float(loss))


def test_moe_top1_vs_topk_capacity():
    key = jax.random.PRNGKey(0)
    p = M.init_moe(key, 16, 32, 4)
    x = jax.random.normal(key, (2, 64, 16))
    y1, aux1 = M.apply_moe(p, x, n_experts=4, top_k=1, group_size=32,
                           compute_dtype=jnp.float32)
    y2, aux2 = M.apply_moe(p, x, n_experts=4, top_k=2, group_size=32,
                           compute_dtype=jnp.float32)
    assert y1.shape == x.shape and y2.shape == x.shape
    assert np.isfinite(np.asarray(y1)).all() and np.isfinite(np.asarray(y2)).all()
    assert float(aux1) > 0 and float(aux2) > 0


def test_moe_capacity_drops_renormalise():
    """With a tiny capacity factor most tokens overflow; output stays finite
    and dropped tokens contribute zero (not NaN)."""
    key = jax.random.PRNGKey(0)
    p = M.init_moe(key, 16, 32, 4)
    x = jax.random.normal(key, (1, 64, 16))
    y, _ = M.apply_moe(p, x, n_experts=4, top_k=2, capacity_factor=0.1,
                       group_size=64, compute_dtype=jnp.float32)
    assert np.isfinite(np.asarray(y)).all()


def test_dense_residual_arctic_style():
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=64, vocab=128, n_experts=4, top_k=2,
                            dense_residual=True, residual_d_ff=48,
                            compute_dtype="float32", remat=False,
                            moe_group_size=32)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    assert "mlp" in jax.tree_util.tree_map(lambda x: x, p["layers"]).keys() \
        or "mlp" in p["layers"]
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, 128)
    h, _ = forward_hidden(p, toks, cfg)
    assert np.isfinite(np.asarray(h)).all()


def test_param_count_formula_matches_actual():
    p = init_lm(jax.random.PRNGKey(0), CFG)
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p))
    assert abs(actual - CFG.param_count()) / actual < 0.02


def test_blocked_attention_gqa_parity():
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (2, 64, 8, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 16))
    pos = jnp.arange(64)
    a = dense_attention(q, k, v, pos, pos, "causal")
    b = blocked_attention(q, k, v, pos, pos, "causal", q_chunk=16, k_chunk=24)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=2e-5)
