"""PCA table compression (beyond-paper recsys integration)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table_compress import compress_tables, compressed_table_bytes
from repro.models.recsys import RecsysConfig, init_recsys, item_embedding


def _structured_tables(seed=0):
    """Tables with low-rank structure (as trained embeddings have)."""
    rng = np.random.default_rng(seed)
    F = rng.standard_normal((32, 8))
    out = []
    for v in (200, 100):
        Z = rng.standard_normal((v, 8))
        out.append(jnp.asarray(Z @ F.T + 0.05 * rng.standard_normal((v, 32)),
                               jnp.float32))
    return out


def test_compress_tables_shapes_and_ratio():
    tables = _structured_tables()
    pruned, pruner = compress_tables(tables, cutoff=0.5)
    assert pruned[0].shape == (200, 16)
    assert pruned[1].shape == (100, 16)
    stats = compressed_table_bytes(tables, cutoff=0.5)
    assert abs(stats["ratio"] - 0.5) < 0.01


def test_compressed_dot_products_preserved():
    """Low-effective-rank tables: dots survive 50% column pruning."""
    tables = _structured_tables()
    pruned, pruner = compress_tables(tables, cutoff=0.5)
    q = tables[0][0]
    full = np.asarray(tables[1] @ q)
    approx = np.asarray(pruned[1] @ pruner.transform_queries(q))
    # ranking agreement on top-10
    top_full = set(np.argsort(-full)[:10].tolist())
    top_apx = set(np.argsort(-approx)[:10].tolist())
    assert len(top_full & top_apx) >= 8


def test_two_tower_item_table_compression_end_to_end():
    cfg = RecsysConfig(kind="two_tower", embed_dim=32, tower_mlp=(64, 32),
                       user_vocab=256, item_vocab=512)
    params = init_recsys(jax.random.PRNGKey(0), cfg)
    items = item_embedding(params, jnp.arange(cfg.item_vocab))   # (512, 32)
    pruned, pruner = compress_tables([items], cutoff=0.5)
    u = item_embedding(params, jnp.arange(5))                    # stand-in queries
    full_rank = np.argsort(-np.asarray(u @ items.T), axis=1)[:, :10]
    apx_scores = np.asarray(pruner.transform_queries(u) @ pruned[0].T)
    apx_rank = np.argsort(-apx_scores, axis=1)[:, :10]
    overlap = np.mean([len(set(full_rank[i]) & set(apx_rank[i])) / 10
                       for i in range(5)])
    assert overlap >= 0.6
