"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs. The FULL configs are exercised only
via the AOT dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.registry import get_smoke_cfg

KEY = jax.random.PRNGKey(0)

LM_ARCHS = ["mixtral-8x7b", "arctic-480b", "qwen2-1.5b", "phi3-medium-14b",
            "smollm-135m"]
CTR_ARCHS = ["dlrm-mlperf", "autoint", "deepfm"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    from repro.models.transformer import forward_train, init_lm
    cfg = get_smoke_cfg(arch)
    params = init_lm(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    labels = jnp.roll(toks, -1, 1)
    loss, grads = jax.value_and_grad(forward_train)(params, toks, labels, cfg)
    assert np.isfinite(float(loss)) and float(loss) > 0
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_prefill_decode(arch):
    from repro.models.transformer import decode_step, init_lm, prefill
    cfg = get_smoke_cfg(arch)
    params = init_lm(KEY, cfg)
    toks = jax.random.randint(KEY, (2, 8), 0, cfg.vocab)
    logits, cache = prefill(params, toks, cfg, cache_len=12)
    assert logits.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    lg, cache2 = decode_step(params, cache, toks[:, 0], jnp.int32(8), cfg)
    assert lg.shape == (2, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all()
    assert cache2[0].shape == cache[0].shape     # static cache


def test_mixtral_smoke_sliding_decode():
    from repro.models.transformer import decode_step_sliding, init_lm
    cfg = get_smoke_cfg("mixtral-8x7b")
    params = init_lm(KEY, cfg)
    W = cfg.sliding_window
    kv = (jnp.zeros((cfg.n_layers, 1, W, cfg.n_kv_heads, cfg.hd)),
          jnp.zeros((cfg.n_layers, 1, W, cfg.n_kv_heads, cfg.hd)))
    lg, kv2 = decode_step_sliding(params, kv, jnp.array([3]), jnp.int32(100), cfg)
    assert lg.shape == (1, cfg.vocab)
    assert np.isfinite(np.asarray(lg)).all()
    assert kv2[0].shape == kv[0].shape           # rolling buffer stays fixed


def test_graphcast_smoke():
    from repro.models.gnn import forward, init_gnn, mse_loss
    cfg = get_smoke_cfg("graphcast")
    params = init_gnn(KEY, cfg)
    rng = np.random.default_rng(0)
    nodes = jnp.asarray(rng.standard_normal((30, cfg.d_in)), jnp.float32)
    edges = jnp.asarray(rng.standard_normal((90, cfg.d_edge_in)), jnp.float32)
    ei = jnp.asarray(rng.integers(0, 30, (2, 90)), jnp.int32)
    out = forward(params, nodes, edges, ei, cfg)
    assert out.shape == (30, cfg.d_out)
    assert np.isfinite(np.asarray(out)).all()
    batch = dict(nodes=nodes, edges=edges, edge_index=ei,
                 targets=jnp.zeros((30, cfg.d_out)))
    g = jax.grad(mse_loss)(params, batch, cfg)
    assert all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(g))


@pytest.mark.parametrize("arch", CTR_ARCHS)
def test_ctr_smoke_train_step(arch):
    from repro.models.recsys import bce_loss, init_recsys
    cfg = get_smoke_cfg(arch)
    params = init_recsys(KEY, cfg)
    rng = np.random.default_rng(0)
    batch = {"sparse": jnp.asarray(
        np.stack([rng.integers(0, v, 32) for v in cfg.vocab_sizes], 1),
        jnp.int32),
        "label": jnp.asarray(rng.random(32) < 0.3, jnp.float32)}
    if cfg.kind == "dlrm":
        batch["dense"] = jnp.asarray(rng.standard_normal((32, cfg.n_dense)),
                                     jnp.float32)
    loss, grads = jax.value_and_grad(bce_loss)(params, batch, cfg)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


def test_two_tower_smoke_train_and_retrieve():
    from repro.models.recsys import (init_recsys, item_embedding,
                                     score_candidates, two_tower_loss)
    cfg = get_smoke_cfg("two-tower-retrieval")
    params = init_recsys(KEY, cfg)
    rng = np.random.default_rng(0)
    batch = {"user_ids": jnp.asarray(rng.integers(0, cfg.user_vocab, 16), jnp.int32),
             "item_ids": jnp.asarray(rng.integers(0, cfg.item_vocab, 16), jnp.int32),
             "item_logq": jnp.zeros(16)}
    loss, grads = jax.value_and_grad(two_tower_loss)(params, batch, cfg)
    assert np.isfinite(float(loss))
    items = item_embedding(params, jnp.arange(cfg.item_vocab))
    s, ids = score_candidates(params, batch["user_ids"][:2], items, k=5)
    assert s.shape == (2, 5) and np.isfinite(np.asarray(s)).all()


def test_biencoder_smoke():
    from repro.models.biencoder import contrastive_loss, encode, init_biencoder
    cfg = get_smoke_cfg("biencoder-msmarco")
    params = init_biencoder(KEY, cfg)
    toks = jax.random.randint(KEY, (4, 12), 0, cfg.vocab)
    emb = encode(params, toks, jnp.ones_like(toks), cfg)
    assert emb.shape == (4, cfg.embed_dim)
    assert np.isfinite(np.asarray(emb)).all()


def test_registry_lists_all_assigned_archs():
    assert len(registry.ARCHS) == 10
    assert len(list(registry.cells())) == 40


def test_skip_reasons_recorded():
    skipped = [(s.arch_id, c.name) for s, c in registry.cells()
               if c.skip_reason]
    # exactly the 4 pure-full-attention LMs skip long_500k
    assert sorted(skipped) == [("arctic-480b", "long_500k"),
                               ("phi3-medium-14b", "long_500k"),
                               ("qwen2-1.5b", "long_500k"),
                               ("smollm-135m", "long_500k")]
