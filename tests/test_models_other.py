"""GNN, recsys, bi-encoder model behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import recsys as R
from repro.models.biencoder import (
    BiEncoderConfig,
    contrastive_loss,
    encode,
    init_biencoder,
    shard_contrastive_loss,
)
from repro.models.gnn import GNNConfig, forward as gnn_fwd, init_gnn, mse_loss
from repro.par import compat

KEY = jax.random.PRNGKey(0)


# -- GNN ----------------------------------------------------------------------

GCFG = GNNConfig(n_layers=2, d_hidden=16, d_in=8, d_edge_in=4, d_out=8,
                 compute_dtype="float32", remat=False)


def _graph(n=40, e=160, seed=0):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.standard_normal((n, 8)), jnp.float32),
            jnp.asarray(rng.standard_normal((e, 4)), jnp.float32),
            jnp.asarray(rng.integers(0, n, (2, e)), jnp.int32))


def test_gnn_shapes_and_finite():
    p = init_gnn(KEY, GCFG)
    nodes, edges, ei = _graph()
    out = gnn_fwd(p, nodes, edges, ei, GCFG)
    assert out.shape == (40, 8)
    assert np.isfinite(np.asarray(out)).all()


def test_gnn_message_locality():
    """An isolated node's output depends only on its own features."""
    p = init_gnn(KEY, GCFG)
    nodes, edges, ei = _graph()
    ei = jnp.where(ei == 0, 1, ei)   # disconnect node 0
    out1 = gnn_fwd(p, nodes, edges, ei, GCFG)
    nodes2 = nodes.at[5].set(nodes[5] + 1.0)   # perturb some other node
    out2 = gnn_fwd(p, nodes2, edges, ei, GCFG)
    np.testing.assert_allclose(np.asarray(out1[0]), np.asarray(out2[0]),
                               atol=1e-5)


def test_gnn_edge_mask_zeroes_padding():
    p = init_gnn(KEY, GCFG)
    nodes, edges, ei = _graph(e=100)
    # pad 60 fake edges pointing at node 3, then mask them
    pad_ei = jnp.concatenate([ei, jnp.full((2, 60), 3, jnp.int32)], axis=1)
    pad_edges = jnp.concatenate([edges, jnp.ones((60, 4))], axis=0)
    mask = jnp.concatenate([jnp.ones(100), jnp.zeros(60)])
    out_masked = gnn_fwd(p, nodes, pad_edges, pad_ei, GCFG, edge_mask=mask)
    out_ref = gnn_fwd(p, nodes, edges, ei, GCFG,
                      edge_mask=jnp.ones(100))
    np.testing.assert_allclose(np.asarray(out_masked), np.asarray(out_ref),
                               rtol=1e-4, atol=1e-4)


def test_gnn_aggregators():
    for agg in ("sum", "mean", "max"):
        cfg = GNNConfig(n_layers=1, d_hidden=8, d_in=4, d_edge_in=4, d_out=4,
                        aggregator=agg, compute_dtype="float32", remat=False)
        p = init_gnn(KEY, cfg)
        rng = np.random.default_rng(0)
        out = gnn_fwd(p, jnp.asarray(rng.standard_normal((10, 4)), jnp.float32),
                      jnp.asarray(rng.standard_normal((30, 4)), jnp.float32),
                      jnp.asarray(rng.integers(0, 10, (2, 30)), jnp.int32), cfg)
        assert np.isfinite(np.asarray(out)).all()


def test_gnn_grads_flow():
    p = init_gnn(KEY, GCFG)
    nodes, edges, ei = _graph()
    batch = dict(nodes=nodes, edges=edges, edge_index=ei,
                 targets=jnp.zeros((40, 8)))
    g = jax.grad(mse_loss)(p, batch, GCFG)
    norms = [float(jnp.abs(x).max()) for x in jax.tree.leaves(g)]
    assert all(np.isfinite(norms)) and max(norms) > 0


# -- RecSys -------------------------------------------------------------------

def test_embedding_bag_single_and_multi_hot():
    table = jnp.arange(20, dtype=jnp.float32).reshape(10, 2)
    single = R.embedding_bag(table, jnp.array([0, 3]))
    np.testing.assert_allclose(np.asarray(single), [[0, 1], [6, 7]])
    multi = R.embedding_bag(table, jnp.array([[0, 2], [4, 4]]), combiner="sum")
    np.testing.assert_allclose(np.asarray(multi), [[4, 6], [16, 18]])
    mean = R.embedding_bag(table, jnp.array([[0, 2]]), combiner="mean")
    np.testing.assert_allclose(np.asarray(mean), [[2, 3]])


def test_sharded_embedding_bag_matches_plain():
    mesh = jax.make_mesh((1,), ("model",))
    table = jnp.asarray(np.random.default_rng(0).standard_normal((64, 8)),
                        jnp.float32)
    idx = jnp.asarray([3, 9, 63, 0], jnp.int32)
    from jax.sharding import PartitionSpec as P

    fn = compat.shard_map(
        lambda t, i: R.sharded_embedding_bag(t, i, axis="model", vocab=64),
        mesh=mesh, in_specs=(P("model", None), P()), out_specs=P(),
        check_vma=False)
    got = fn(table, idx)
    want = R.embedding_bag(table, idx)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_fm_identity():
    """FM trick equals explicit pairwise sum."""
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.standard_normal((3, 5, 4)), jnp.float32)
    got = R.fm_interaction(v)
    want = np.zeros(3)
    vn = np.asarray(v)
    for i in range(5):
        for j in range(i + 1, 5):
            want += (vn[:, i] * vn[:, j]).sum(-1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4)


def test_dot_interaction_shape():
    v = jnp.ones((2, 4, 8))
    out = R.dot_interaction(v)
    assert out.shape == (2, 6)   # 4 choose 2


def test_ctr_models_train_and_descend():
    rng = np.random.default_rng(0)
    for kind, cfg in [
        ("dlrm", R.RecsysConfig(kind="dlrm", vocab_sizes=(64, 32), embed_dim=8,
                                n_dense=4, bot_mlp=(16, 8), top_mlp=(16, 1))),
        ("deepfm", R.RecsysConfig(kind="deepfm", vocab_sizes=(64, 32, 16),
                                  embed_dim=6, deep_mlp=(16, 16))),
        ("autoint", R.RecsysConfig(kind="autoint", vocab_sizes=(64, 32, 16),
                                   embed_dim=8, n_attn_layers=2, n_heads=2,
                                   d_attn=4)),
    ]:
        p = R.init_recsys(KEY, cfg)
        batch = {"sparse": jnp.asarray(rng.integers(0, 16, (64, cfg.n_sparse)),
                                       jnp.int32),
                 "label": jnp.asarray(rng.random(64) < 0.3, jnp.float32)}
        if kind == "dlrm":
            batch["dense"] = jnp.asarray(rng.standard_normal((64, 4)), jnp.float32)
        loss0 = float(R.bce_loss(p, batch, cfg))
        # a few SGD steps must reduce loss on a fixed batch
        for _ in range(20):
            g = jax.grad(R.bce_loss)(p, batch, cfg)
            p = jax.tree.map(lambda w, gw: w - 0.1 * gw, p, g)
        loss1 = float(R.bce_loss(p, batch, cfg))
        assert loss1 < loss0, kind


def test_two_tower_retrieval_end_to_end():
    cfg = R.RecsysConfig(kind="two_tower", embed_dim=16, tower_mlp=(32, 16),
                         user_vocab=128, item_vocab=256)
    p = R.init_recsys(KEY, cfg)
    items = R.item_embedding(p, jnp.arange(256))
    assert items.shape == (256, 16)
    s, ids = R.score_candidates(p, jnp.array([5, 9]), items, k=20)
    assert s.shape == (2, 20)
    # scores sorted, ids valid
    assert (np.diff(np.asarray(s), axis=1) <= 1e-6).all()
    assert (np.asarray(ids) >= 0).all() and (np.asarray(ids) < 256).all()


def test_ctr_retrieval_scores_broadcast():
    cfg = R.RecsysConfig(kind="deepfm", vocab_sizes=(64, 32, 16, 16),
                         embed_dim=6, deep_mlp=(16,))
    p = R.init_recsys(KEY, cfg)
    fu, fi = R.ctr_user_item_split(cfg)
    user = {"sparse": jnp.zeros((1, fu), jnp.int32)}
    cand = jnp.asarray(np.random.default_rng(0).integers(0, 16, (100, fi)),
                       jnp.int32)
    scores = R.ctr_retrieval_scores(p, user, cand, cfg)
    assert scores.shape == (100,)
    assert np.isfinite(np.asarray(scores)).all()


# -- BiEncoder ---------------------------------------------------------------

BCFG = BiEncoderConfig(n_layers=2, d_model=32, n_heads=2, d_ff=64, vocab=128,
                       embed_dim=32, max_len=32, compute_dtype="float32",
                       remat=False)


def test_encode_normalised_and_mask_sensitive():
    p = init_biencoder(KEY, BCFG)
    toks = jax.random.randint(KEY, (4, 16), 0, 128)
    mask = jnp.ones((4, 16), jnp.int32)
    emb = encode(p, toks, mask, BCFG)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(emb), axis=1), 1.0,
                               rtol=1e-4)
    mask2 = mask.at[:, 8:].set(0)
    emb2 = encode(p, toks, mask2, BCFG)
    assert float(jnp.abs(emb - emb2).max()) > 1e-4


def test_contrastive_training_descends():
    from repro.data.tokens import pair_batch
    p = init_biencoder(KEY, BCFG)
    b = {k: jnp.asarray(v) for k, v in
         pair_batch(0, 0, batch=16, seq_len=12, vocab=128).items()}
    l0 = float(contrastive_loss(p, b, BCFG))
    for _ in range(10):
        g = jax.grad(contrastive_loss)(p, b, BCFG)
        p = jax.tree.map(lambda w, gw: w - 0.05 * gw, p, g)
    l1 = float(contrastive_loss(p, b, BCFG))
    assert l1 < l0


@pytest.mark.parametrize("ndev", [1, 2])
def test_shard_contrastive_loss_matches_replicated(ndev):
    from repro.data.tokens import pair_batch
    if jax.device_count() < ndev:
        pytest.skip(f"needs {ndev} devices")
    mesh = jax.make_mesh((ndev,), ("data",))
    p = init_biencoder(KEY, BCFG)
    b = {k: jnp.asarray(v) for k, v in
         pair_batch(0, 0, batch=8, seq_len=12, vocab=128).items()}
    # rank-heterogeneous batch: per-example weights ride along untouched by
    # the loss, pinning the rank-aware in_specs
    b["weight"] = jnp.ones((8,), jnp.float32)
    got = shard_contrastive_loss(p, b, BCFG, mesh, axis="data")
    want = contrastive_loss(p, {k: b[k] for k in b if k != "weight"}, BCFG)
    np.testing.assert_allclose(float(got), float(want), rtol=1e-4, atol=1e-5)
