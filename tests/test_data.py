"""Data pipelines: determinism, prefetch, graph sampling, corpus structure."""
import numpy as np

from repro.data.graph import CSRGraph, NeighborSampler, batched_molecules, random_graph
from repro.data.recsys import ctr_batch, two_tower_batch
from repro.data.synthetic import ENCODER_PROFILES, make_corpus, make_dataset
from repro.data.tokens import Prefetcher, token_batch


def test_token_batch_deterministic():
    a = token_batch(7, 42, batch=4, seq_len=16, vocab=100)
    b = token_batch(7, 42, batch=4, seq_len=16, vocab=100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = token_batch(7, 43, batch=4, seq_len=16, vocab=100)
    assert (a["tokens"] != c["tokens"]).any()


def test_token_labels_are_shifted():
    b = token_batch(0, 0, batch=2, seq_len=8, vocab=50)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_prefetcher_orders_steps():
    pf = Prefetcher(lambda t: {"t": t}, start_step=5, depth=2)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]


def test_ctr_batch_bounds():
    b = ctr_batch(0, 0, batch=128, vocab_sizes=(100, 50, 10), n_dense=5)
    assert b["sparse"].shape == (128, 3)
    assert (b["sparse"] >= 0).all()
    assert (b["sparse"].max(0) < np.array([100, 50, 10])).all()
    assert b["dense"].shape == (128, 5)


def test_two_tower_batch_logq():
    b = two_tower_batch(0, 0, batch=64, user_vocab=1000, item_vocab=500)
    assert np.isfinite(b["item_logq"]).all()
    assert (b["item_ids"] < 500).all()


def test_csr_and_sampler_shapes():
    ei = random_graph(200, avg_degree=8, seed=0)
    g = CSRGraph.from_edge_index(ei, 200)
    assert g.indptr[-1] == ei.shape[1]
    s = NeighborSampler(g, fanouts=(3, 2), batch_nodes=16, seed=0)
    sub = s.sample()
    assert sub["node_ids"].shape == (s.max_nodes,)
    assert sub["edge_index"].shape == (2, s.max_edges)
    assert sub["seed_mask"].sum() == 16
    # sampled edges reference only in-subgraph local ids
    n_real = int(sub["node_mask"].sum())
    assert sub["edge_index"].max() < max(n_real, 1)


def test_sampler_handles_isolated_nodes():
    ei = np.array([[0, 1], [1, 0]], dtype=np.int32)   # nodes 2.. isolated
    g = CSRGraph.from_edge_index(ei, 50)
    s = NeighborSampler(g, fanouts=(2,), batch_nodes=8, seed=1)
    sub = s.sample()
    assert np.isfinite(sub["node_mask"]).all()


def test_molecule_batch_block_diagonal():
    b = batched_molecules(batch=4, n_nodes=5, n_edges=7, d_feat=3, d_edge=2)
    assert b["nodes"].shape == (20, 3)
    assert b["edge_index"].shape == (2, 28)
    # graph g's edges stay within its node block
    for gidx in range(4):
        seg = b["edge_index"][:, gidx * 7:(gidx + 1) * 7]
        assert (seg >= gidx * 5).all() and (seg < (gidx + 1) * 5).all()


def test_corpus_spectra_ordered_by_profile():
    """Effective rank: ance < tasb < contriever (the paper's robustness order)."""
    ranks = {}
    for enc in ENCODER_PROFILES:
        D, _ = make_corpus(enc, n_docs=2000, d=64, seed=0)
        s = np.linalg.svd(D, compute_uv=False)
        p = s**2 / (s**2).sum()
        ranks[enc] = float(np.exp(-(p * np.log(p + 1e-12)).sum()))
    assert ranks["ance"] < ranks["tasb"] < ranks["contriever"]


def test_dataset_has_queries_and_graded_qrels():
    ds = make_dataset("tasb", n_docs=500, d=32, query_sets=("dl19", "devsmall"))
    assert ds.queries["dl19"].shape[0] == 43
    grades = {g for q in ds.qrels["dl19"].values() for g in q.values()}
    assert 3 in grades          # graded judgments
    grades_dev = {g for q in ds.qrels["devsmall"].values() for g in q.values()}
    assert grades_dev == {1}    # binary shallow judgments
