"""Replicated serving fleet: routing, admission control, failover,
health-gated rollouts, auto-compaction, and the fault-injection harness.

The corpus is unit-norm with self-retrieval queries (query i IS row i),
so every successful reply's top-1 id is exactly checkable — "misrouted"
and "wrong answer" are measured, never inferred.
"""
import shutil
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pruning import StaticPruner
from repro.core.store import IndexStore, save_index
from repro.launch.serve import TimedOut, _drive_open
from repro.serving.fleet import (AutoCompactPolicy, FaultEvent, FaultPlan,
                                 HealthPolicy, ReplicaSet, Shed,
                                 corrupt_artifact)

N, D_DIM = 384, 64


def _unit_corpus(n=N, d=D_DIM, seed=0):
    rng = np.random.default_rng(seed)
    D = rng.standard_normal((n, d)).astype(np.float32)
    return D / np.linalg.norm(D, axis=1, keepdims=True)


@pytest.fixture(scope="module")
def artifact(tmp_path_factory):
    """One committed store for the whole module; destructive tests copy."""
    D = _unit_corpus()
    pruner = StaticPruner(cutoff=0.5).fit(jnp.asarray(D))
    path = str(tmp_path_factory.mktemp("fleet") / "v1")
    save_index(path, pruner.build_index(jnp.asarray(D)), pruner=pruner)
    return path, D


@pytest.fixture
def make_fleet(artifact):
    """Factory for fleets over the shared artifact; closes them all."""
    path, D = artifact
    fleets = []

    def build(**kw):
        kw.setdefault("replicas", 3)
        kw.setdefault("probe_queries", D[:8])
        kw.setdefault("max_batch", 16)
        fleet = ReplicaSet(path, **kw)
        fleets.append(fleet)
        return fleet, D

    yield build
    for fleet in fleets:
        fleet.close()


def _assert_self_topk(fleet, D, qid):
    _, ids = fleet.query(D[qid])
    assert int(np.asarray(ids)[0]) == qid


# -- routing + accounting ---------------------------------------------------

def test_fleet_completes_all_and_balances(make_fleet):
    fleet, D = make_fleet()
    fleet.query(D[0])                           # warm the dispatch path
    # slow every replica so in-flight counts accumulate: a burst of
    # submits must then spread by least-in-flight, deterministically
    for rep in fleet.replicas:
        rep.faultable.state.inject(("slow", 0.05))
    qids = np.random.default_rng(1).integers(0, N, size=60)
    replies = [fleet.submit(D[q]) for q in qids]
    outs = [r.get(timeout=30.0) for r in replies]
    for q, out in zip(qids, outs):
        assert isinstance(out, tuple), f"reply failed: {out!r}"
        assert int(np.asarray(out[1])[0]) == q
    stats = fleet.stats()
    assert stats["accepted"] == 60 + 1          # +1 warmup query
    assert stats["lost_accepted"] == 0
    assert stats["shed"] == 0
    # replica worker logs prove the load actually spread
    served = [rep.server.worker_stats()["batches"] for rep in fleet.replicas]
    assert all(b > 0 for b in served), served
    for rep in fleet.replicas:
        rep.faultable.state.clear()


def test_admission_control_sheds_explicitly(make_fleet):
    fleet, D = make_fleet(max_outstanding=4, replica_timeout=5.0)
    for rep in fleet.replicas:
        rep.faultable.state.inject(("slow", 0.2))
    replies = [fleet.submit(D[i % N]) for i in range(40)]
    outs = [r.get(timeout=30.0) for r in replies]
    shed = [o for o in outs if isinstance(o, Shed)]
    ok = [o for o in outs if isinstance(o, tuple)]
    assert len(shed) + len(ok) == 40            # every submit got a reply
    assert shed, "a 10x overload over 4 slots must shed"
    stats = fleet.stats()
    assert stats["shed"] == len(shed)
    assert stats["accepted"] == len(ok)
    assert stats["lost_accepted"] == 0
    for rep in fleet.replicas:
        rep.faultable.state.clear()


def test_router_query_raises_shed(make_fleet):
    fleet, D = make_fleet(max_outstanding=1)
    fleet.replicas[0].faultable.state.inject(("slow", 0.3))
    fleet.submit(D[0])                          # occupies the only slot
    with pytest.raises(Shed):
        fleet.query(D[1])
    for rep in fleet.replicas:
        rep.faultable.state.clear()


# -- failover ---------------------------------------------------------------

def test_kill_fails_over_without_losing_replies(make_fleet):
    fleet, D = make_fleet()
    fleet.query(D[0])                           # warm the dispatch path
    fleet.replicas[0].faultable.state.inject("crash")
    qids = np.random.default_rng(2).integers(0, N, size=48)
    outs = [fleet.submit(D[q], deadline=10.0) for q in qids]
    for q, reply in zip(qids, outs):
        out = reply.get(timeout=30.0)
        assert isinstance(out, tuple), f"reply failed: {out!r}"
        assert int(np.asarray(out[1])[0]) == q
    stats = fleet.stats()
    assert stats["lost_accepted"] == 0
    assert "r0" in stats["down"]
    assert stats["marked_down"] >= 1


def test_restart_rejoins_and_serves(make_fleet):
    fleet, D = make_fleet()
    fleet.query(D[0])
    # slow the siblings so a burst actually reaches r1 (a zero-load tie
    # always routes to r0), then crash r1 and let failover mark it down
    for rep in (fleet.replicas[0], fleet.replicas[2]):
        rep.faultable.state.inject(("slow", 0.05))
    fleet.replicas[1].faultable.state.inject("crash")
    qids = np.random.default_rng(8).integers(0, N, size=12)
    outs = [fleet.submit(D[q], deadline=10.0) for q in qids]
    for q, reply in zip(qids, outs):
        out = reply.get(timeout=30.0)
        assert isinstance(out, tuple), f"reply failed: {out!r}"
        assert int(np.asarray(out[1])[0]) == q
    for rep in (fleet.replicas[0], fleet.replicas[2]):
        rep.faultable.state.clear()
    assert fleet.router.states()["r1"] == "down"
    fleet.restart("r1")
    assert fleet.router.states() == {"r0": "up", "r1": "up", "r2": "up"}
    health = fleet.health()
    assert health["ok"]
    assert {"kind": "restart", "replica": "r1"} in health["events"]
    _assert_self_topk(fleet, D, 7)


def test_hung_replica_fails_over_via_deadline(make_fleet):
    fleet, D = make_fleet(replica_timeout=0.5)
    fleet.query(D[0])
    fleet.replicas[2].faultable.state.inject("hang")
    t0 = time.perf_counter()
    qids = np.random.default_rng(3).integers(0, N, size=24)
    outs = [fleet.submit(D[q], deadline=10.0) for q in qids]
    got = [r.get(timeout=30.0) for r in outs]
    assert all(isinstance(o, tuple) for o in got)
    assert time.perf_counter() - t0 < 20.0
    stats = fleet.stats()
    assert stats["lost_accepted"] == 0
    fleet.replicas[2].faultable.state.clear()


def test_fault_plan_kill_restart_mid_drive(make_fleet):
    fleet, D = make_fleet()
    qids = np.random.default_rng(4).integers(0, N, size=240)
    plan = FaultPlan([FaultEvent(0.4, "kill", "r1"),
                      FaultEvent(1.0, "restart", "r1")])
    plan.start(fleet)
    res = _drive_open(fleet, D[qids], rate=150.0, collect=True,
                      tolerate_errors=True, deadline=2.0)
    stats = fleet.stats()
    assert stats["lost_accepted"] == 0
    misrouted = sum(1 for i, out in enumerate(res["results"])
                    if isinstance(out, tuple)
                    and int(np.asarray(out[1])[0]) != qids[i])
    assert misrouted == 0
    assert res["n_ok"] >= 0.8 * res["n"]
    assert fleet.health()["ok"]                 # r1 restarted and rejoined


# -- rolling rollout --------------------------------------------------------

def _build_artifact(path, D):
    pruner = StaticPruner(cutoff=0.5).fit(jnp.asarray(D))
    save_index(path, pruner.build_index(jnp.asarray(D)), pruner=pruner)
    return path


def test_rollout_good_commits_fleet_wide(make_fleet, tmp_path):
    fleet, D = make_fleet()
    v2 = _build_artifact(str(tmp_path / "v2"), D)
    result = fleet.rollout(v2)
    assert result["ok"] and not result["rolled_back"]
    assert len(result["per_replica"]) == len(fleet.replicas)
    assert all(p["recall"] == 1.0 for p in result["per_replica"])
    assert fleet.version == v2
    assert fleet.router.states() == {"r0": "up", "r1": "up", "r2": "up"}
    _assert_self_topk(fleet, D, 11)


def test_rollout_regression_rolls_back_with_zero_misrouted(make_fleet,
                                                           tmp_path):
    fleet, D = make_fleet()
    v1 = fleet.version
    # same rows, shuffled order: every id the bad index returns is wrong
    perm = np.random.default_rng(5).permutation(N)
    bad = _build_artifact(str(tmp_path / "vbad"), D[perm])
    qids = np.random.default_rng(6).integers(0, N, size=160)
    import threading
    result = {}
    roller = threading.Thread(
        target=lambda: result.update(fleet.rollout(bad)), daemon=True)
    roller.start()
    res = _drive_open(fleet, D[qids], rate=120.0, collect=True,
                      tolerate_errors=True, deadline=2.0)
    roller.join(timeout=60.0)
    assert result["rolled_back"] and not result["ok"]
    # the health gate must have caught it on the FIRST replica probed —
    # live traffic never reached the regressing index
    assert len(result["per_replica"]) == 1
    misrouted = sum(1 for i, out in enumerate(res["results"])
                    if isinstance(out, tuple)
                    and int(np.asarray(out[1])[0]) != qids[i])
    assert misrouted == 0
    assert fleet.stats()["lost_accepted"] == 0
    assert fleet.version == v1
    assert fleet.router.states() == {"r0": "up", "r1": "up", "r2": "up"}


def test_rollout_rejects_corrupt_artifact_and_keeps_serving(make_fleet,
                                                            tmp_path):
    fleet, D = make_fleet()
    v1 = fleet.version
    bad = _build_artifact(str(tmp_path / "vtorn"), D)
    corrupt_artifact(bad)                       # torn blob: open() must fail
    result = fleet.rollout(bad)
    assert not result["ok"] and not result["rolled_back"]
    assert "rejected" in result["reason"]
    assert fleet.version == v1
    assert not result["per_replica"]            # no replica was touched
    _assert_self_topk(fleet, D, 3)


def test_rollout_rejects_partial_commit_and_keeps_serving(make_fleet,
                                                          tmp_path):
    """Crash mid-rollout publication: an artifact whose manifest never
    landed (the blob-then-manifest-swap was interrupted) must be rejected
    by open() and the fleet keeps serving the previous version."""
    fleet, D = make_fleet()
    v1 = fleet.version
    partial = str(tmp_path / "vpartial")
    _build_artifact(partial, D)
    (tmp_path / "vpartial" / "manifest.json").unlink()
    result = fleet.rollout(partial)
    assert not result["ok"] and not result["rolled_back"]
    assert fleet.version == v1
    _assert_self_topk(fleet, D, 9)


def test_rollout_probes_catch_crashing_replica(make_fleet, tmp_path):
    """A fault during the probe window (not a bad artifact) also rolls
    back: the gate checks the replica actually answers, not just ids."""
    fleet, D = make_fleet(health_policy=HealthPolicy(probes=4,
                                                     timeout_s=2.0))
    v2 = _build_artifact(str(tmp_path / "v2"), D)
    # crash the LAST replica: reference answers still come from a healthy
    # one, and the gate must catch the crash on r2's own probe
    fleet.replicas[2].faultable.state.inject("crash")
    result = fleet.rollout(v2)
    assert result["rolled_back"] and not result["ok"]
    assert not result["per_replica"][-1]["ok"]
    fleet.replicas[2].faultable.state.clear()
    fleet.restart("r2")
    assert fleet.health()["ok"]


# -- maintenance: appends, auto-compaction, health --------------------------

def test_append_visible_on_every_replica(make_fleet):
    fleet, D = make_fleet()
    extra = _unit_corpus(n=32, d=D_DIM, seed=99)
    n0 = fleet.index.n
    fleet.append(extra)
    assert fleet.index.n == n0 + 32
    q = extra[5]
    for rep in fleet.replicas:
        _, ids = rep.server.query(q, timeout=10.0)
        assert int(np.asarray(ids)[0]) == n0 + 5


def test_autocompact_controller_triggers_and_serves(make_fleet):
    fleet, D = make_fleet(
        autocompact=AutoCompactPolicy(max_delta_fraction=0.10,
                                      interval_s=0.1))
    fleet.append(_unit_corpus(n=96, d=D_DIM, seed=7))   # 96/480 = 20%
    deadline = time.monotonic() + 60.0
    while time.monotonic() < deadline and fleet.updater.compactions == 0:
        time.sleep(0.05)
    assert fleet.updater.compactions == 1
    assert len(fleet.index.deltas) == 0
    kinds = [e["kind"] for e in fleet.events]
    assert "autocompact" in kinds
    _assert_self_topk(fleet, D, 21)
    # durably compacted too: a cold reload of the store sees no deltas
    cold = IndexStore.open(fleet.store.path)
    assert all(s.kind == "base" for s in cold.segments())


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_fleet_health_surfaces_background_compaction_death(make_fleet,
                                                           monkeypatch):
    """Satellite: a dead compact_async thread must degrade fleet health,
    not vanish (the updater records it, the fleet reads it). The re-raise
    in the background thread is part of the contract (loud death), hence
    the filtered warning."""
    fleet, D = make_fleet()

    def boom(**kw):
        raise RuntimeError("simulated compaction death")

    monkeypatch.setattr(fleet.updater, "compact", boom)
    th = fleet.updater.compact_async()
    th.join(timeout=30.0)
    health = fleet.health()
    assert not health["ok"]
    assert not health["maintenance"]["ok"]
    errs = health["maintenance"]["background_errors"]
    assert errs and "simulated compaction death" in errs[0]["error"]
    # serving itself is unaffected — health is degraded, not the traffic
    _assert_self_topk(fleet, D, 2)


# -- deadlines through the router -------------------------------------------

def test_router_deadline_times_out_hung_fleet(make_fleet):
    fleet, D = make_fleet(replica_timeout=10.0, max_retries=0)
    fleet.query(D[0])
    for rep in fleet.replicas:
        rep.faultable.state.inject("hang")
    t0 = time.perf_counter()
    out = fleet.submit(D[1], deadline=0.5).get(timeout=30.0)
    assert isinstance(out, TimedOut)
    assert time.perf_counter() - t0 < 10.0
    assert fleet.stats()["lost_accepted"] == 0
    for rep in fleet.replicas:
        rep.faultable.state.clear()


def test_corrupt_artifact_helper_removes_a_live_blob(artifact, tmp_path):
    path, D = artifact
    cp = str(tmp_path / "copy")
    shutil.copytree(path, cp)
    removed = corrupt_artifact(cp)
    assert removed.endswith(".npy")
    with pytest.raises(Exception):
        IndexStore.open(cp)
