"""Checkpoint manager: atomic commit, async, retention, elastic restore."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"layers": {"w": jnp.asarray(rng.standard_normal((16, 8)), jnp.float32),
                       "b": jnp.asarray(rng.standard_normal((8,)), jnp.float32)},
            "step_scale": jnp.float32(2.5)}


def test_roundtrip(tmp_path):
    t = _tree()
    path = str(tmp_path / "ck")
    save_pytree(path, t)
    t2 = load_pytree(path, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_save_fsyncs_every_blob_and_parent_dir(tmp_path, monkeypatch):
    """The commit protocol's durability claim: every .npy blob is fsynced
    before the manifest, and the parent directory is fsynced after the
    rename — not just the manifest (the old behaviour)."""
    import repro.checkpoint.manager as mgr
    synced_files: list[str] = []
    synced_dirs: list[str] = []
    real_file, real_dir = mgr.fsync_file, mgr.fsync_dir
    monkeypatch.setattr(mgr, "fsync_file",
                        lambda p: (synced_files.append(p), real_file(p)))
    monkeypatch.setattr(mgr, "fsync_dir",
                        lambda p: (synced_dirs.append(p), real_dir(p)))
    t = _tree()
    path = str(tmp_path / "ck")
    mgr.save_pytree(path, t)
    n_leaves = len(jax.tree.leaves(t))
    assert len([f for f in synced_files if f.endswith(".npy")]) == n_leaves
    # parent of the committed dir fsynced after the rename
    assert str(tmp_path) in [os.path.normpath(d) for d in synced_dirs]


def test_atomic_no_partial_dirs(tmp_path):
    t = _tree()
    path = str(tmp_path / "ck")
    save_pytree(path, t)
    assert not os.path.exists(path + ".tmp")
    assert os.path.exists(os.path.join(path, "manifest.json"))


def test_manager_save_restore_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=2)
    t = _tree()
    for step in (10, 20, 30):
        mgr.save(step, t, async_=False)
    assert mgr.all_steps() == [20, 30]
    restored, step = mgr.restore(t)
    assert step == 30


def test_manager_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_n=3)
    t = _tree()
    mgr.save(5, t, async_=True)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_restore_with_mesh_and_specs(tmp_path):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    t = _tree()
    specs = {"layers": {"w": P("model", None), "b": P()}, "step_scale": P()}
    path = str(tmp_path / "ck")
    save_pytree(path, t, spec_tree=specs)
    t2 = load_pytree(path, t, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(t["layers"]["w"]),
                                  np.asarray(t2["layers"]["w"]))
    assert isinstance(t2["layers"]["w"].sharding, jax.sharding.NamedSharding)


def test_elastic_restore_drops_nonfitting_specs(tmp_path):
    """A checkpoint written with 'model'-sharded dim restores onto a mesh
    where that dim no longer divides: spec degrades to replication."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    t = {"w": jnp.ones((3, 4))}   # dim0=3 won't divide a model axis of 2
    path = str(tmp_path / "ck")
    save_pytree(path, t, spec_tree={"w": P("model", None)})
    t2 = load_pytree(path, t, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(t2["w"]), np.ones((3, 4)))


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree())


def test_train_resume_cycle(tmp_path):
    """Full driver: train N steps, kill, resume, verify identical data replay."""
    from repro.launch.train import train
    d = str(tmp_path / "ck")
    out1 = train("smollm-135m", steps=6, smoke=True, ckpt_dir=d, ckpt_every=3,
                 resume="none", seed=0, shape=None, log_every=0)
    out2 = train("smollm-135m", steps=3, smoke=True, ckpt_dir=d, ckpt_every=3,
                 resume="auto", seed=0, shape=None, log_every=0)
    # resumed run continues from step 6 and stays finite
    assert out2["steps_run"] == 3
    assert np.isfinite(out2["final_loss"])
