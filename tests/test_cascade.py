"""Cascade retrieval: oracle parity, monotonicity, multi-resolution store.

Covers the acceptance surface of the two-stage cascade:
  * with N·k >= n the cascade is BIT-identical (scores AND ids) to the
    full-m exact ``search_projected`` — dense and segmented, f32 and int8
    full resolution, jnp and pallas backends;
  * recall@10 against the full-m oracle is non-decreasing in the
    shortlist depth N (a superset shortlist rescored exactly can only
    keep or add true top-k members) and reaches 1.0 at N·k >= n;
  * a stored coarse resolution round-trips bit-identically and corrupted
    multi-resolution manifests are rejected loudly (row mismatch,
    non-nested m, duplicate m, missing blobs);
  * ``CascadeIndex`` validates row alignment and nesting, and a
    segmented cascade grows BOTH resolutions in lockstep with zero
    steady-state recompiles.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CascadeIndex,
    DenseIndex,
    IndexStore,
    IndexStoreError,
    StaticPruner,
    save_index,
)

RNG = np.random.default_rng(17)


def _fixture(n=500, d=64, nq=5, seed=3):
    from repro.data.synthetic import make_corpus
    D, _ = make_corpus("tasb", n_docs=n, d=d, seed=seed)
    pruner = StaticPruner(cutoff=0.5).fit(jnp.asarray(D))
    pruned = pruner.prune_index(jnp.asarray(D))
    W, mean = pruner.projection()
    Q = jnp.asarray(RNG.standard_normal((nq, d)), jnp.float32)
    return pruned, W, mean, Q


def _full_nf(n, k):
    """n_factor making the shortlist cover the corpus: N·k >= n."""
    return -(-n // k)


# ---------------------------------------------------------------------------
# oracle parity: shortlist covering the corpus == full-m exact search
# ---------------------------------------------------------------------------


# interpret-mode pallas unrolls nk extraction passes per strip, so its
# parity configs run on a deliberately tiny corpus (same code path, same
# geometry family — just tractable off-TPU)
@pytest.mark.parametrize("quant", [False, True])
@pytest.mark.parametrize("backend,n", [("jnp", 500), ("pallas", 64)])
def test_cascade_bitwise_oracle_parity_dense(quant, backend, n):
    """Acceptance: N·k >= n makes the cascade bit-identical — scores AND
    ids — to the single-resolution full-m search, because the exact
    rescore sees every row and shares the oracle's dot shape family."""
    k = 8
    pruned, W, mean, Q = _fixture(n=n)
    cas = CascadeIndex.build(pruned, m_coarse=max(2, pruned.shape[1] // 2),
                             n_factor=_full_nf(n, k), quantize_int8=quant,
                             backend=backend)
    oracle = DenseIndex.build(pruned, quantize_int8=quant, backend=backend)
    s0, i0 = oracle.search_projected(Q, W, k=k, mean=mean)
    s1, i1 = cas.search_projected(Q, W, k=k, mean=mean)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


@pytest.mark.parametrize("quant", [False, True])
def test_cascade_bitwise_oracle_parity_segmented(quant):
    """Segmented cascade (base + live deltas in both resolutions) against
    the segmented full-m search on the same segment set."""
    k, n = 8, 400
    pruned, W, mean, Q = _fixture(n=n)
    extra = RNG.standard_normal((90, pruned.shape[1])).astype(np.float32)
    cas = CascadeIndex.build(pruned, m_coarse=max(2, pruned.shape[1] // 2),
                             n_factor=_full_nf(n + 90, k),
                             quantize_int8=quant
                             ).segmented(delta_capacity=64)
    cas = cas.append(extra)
    assert cas.n == n + 90 and cas.coarse.n == cas.full.n
    s0, i0 = cas.full.search_projected(Q, W, k=k, mean=mean)
    s1, i1 = cas.search_projected(Q, W, k=k, mean=mean)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_cascade_recall_monotone_in_shortlist_depth():
    """recall@10 vs the full-m oracle is non-decreasing in N: a deeper
    shortlist is a superset, and an exact rescore over a superset can
    displace a true top-k member only with another true top-k member."""
    k, n = 10, 1200
    pruned, W, mean, Q = _fixture(n=n, nq=8)
    oracle = DenseIndex.build(pruned)
    _, i0 = oracle.search_projected(Q, W, k=k, mean=mean)
    i0 = np.asarray(i0)
    recalls = []
    for nf in (1, 2, 4, 8, 16, _full_nf(n, k)):
        cas = CascadeIndex.from_index(oracle, m_coarse=pruned.shape[1] // 4,
                                      n_factor=nf)
        _, ids = cas.search_projected(Q, W, k=k, mean=mean)
        ids = np.asarray(ids)
        recalls.append(np.mean([
            len(set(i0[q]) & set(ids[q])) / k for q in range(len(i0))]))
    assert all(b >= a for a, b in zip(recalls, recalls[1:])), recalls
    assert recalls[-1] == 1.0


# ---------------------------------------------------------------------------
# construction-time validation
# ---------------------------------------------------------------------------


def test_cascade_rejects_row_count_mismatch():
    pruned, _, _, _ = _fixture(n=120)
    full = DenseIndex.build(pruned)
    coarse = DenseIndex.build(pruned[:100, :4], quantize_int8=True)
    with pytest.raises(ValueError, match="disagree on row count"):
        CascadeIndex(coarse=coarse, full=full)


def test_cascade_rejects_non_nested_m():
    pruned, _, _, _ = _fixture(n=120)
    full = DenseIndex.build(pruned)
    with pytest.raises(ValueError, match="does not nest"):
        CascadeIndex(coarse=DenseIndex.build(pruned), full=full)
    with pytest.raises(ValueError, match="n_factor"):
        CascadeIndex.build(pruned, m_coarse=4, n_factor=0)


def test_cascade_append_requires_segmented_resolutions():
    pruned, _, _, _ = _fixture(n=120)
    cas = CascadeIndex.build(pruned, m_coarse=4)
    with pytest.raises(TypeError, match="segmented"):
        cas.append(np.zeros((3, pruned.shape[1]), np.float32))


def test_cascade_append_zero_steady_state_recompiles():
    """Fixed-shape appends + searches after warmup must not grow any jit
    cache — nk is fixed and every per-segment dispatch takes live count
    and offset as traced operands."""
    from repro.core.index import segment_jit_cache_size
    k, n = 5, 300
    pruned, W, mean, Q = _fixture(n=n)
    cas = CascadeIndex.build(pruned, m_coarse=pruned.shape[1] // 2,
                             n_factor=2, quantize_int8=True
                             ).segmented(delta_capacity=128)
    block = RNG.standard_normal((8, pruned.shape[1])).astype(np.float32)
    cas = cas.append(block)            # opens both deltas, widest scale
    cas = cas.append(0.5 * block)      # non-widening extend compiles once
    cas.search_projected(Q, W, k=k, mean=mean)
    before = segment_jit_cache_size()
    for frac in (0.4, 0.3, 0.2):       # shrinking rows: never re-widen
        cas = cas.append(frac * block)
        cas.search_projected(Q, W, k=k, mean=mean)
    assert segment_jit_cache_size() == before
    assert cas.coarse.n == cas.full.n == n + 5 * 8


# ---------------------------------------------------------------------------
# multi-resolution store: round trips + corruption rejection
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quant", [False, True])
def test_cascade_store_roundtrip_dense(tmp_path, quant):
    """save_index(CascadeIndex) persists the coarse view as a manifest
    resolution; the load must search bit-identically."""
    k, n = 8, 300
    pruned, W, mean, Q = _fixture(n=n)
    cas = CascadeIndex.build(pruned, m_coarse=pruned.shape[1] // 2,
                             n_factor=3, quantize_int8=quant)
    store = save_index(str(tmp_path / "st"), cas)
    loaded = CascadeIndex.load(store, m_coarse=cas.m_coarse, n_factor=3)
    assert (loaded.n, loaded.m_coarse) == (cas.n, cas.m_coarse)
    s0, i0 = cas.search_projected(Q, W, k=k, mean=mean)
    s1, i1 = loaded.search_projected(Q, W, k=k, mean=mean)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_cascade_store_roundtrip_segmented(tmp_path):
    """A grown cascade persists through the store: the stored resolution
    covers the base rows, coarse deltas rehydrate from their PERSISTED
    segments (exact quantised bytes — no requantisation on load), and
    the pair stays row-aligned."""
    k, n = 8, 300
    pruned, W, mean, Q = _fixture(n=n)
    cas = CascadeIndex.build(pruned, m_coarse=pruned.shape[1] // 2,
                             n_factor=_full_nf(n + 40, k),
                             quantize_int8=True).segmented(delta_capacity=64)
    cas = cas.append(RNG.standard_normal((40, pruned.shape[1]))
                     .astype(np.float32))
    store = save_index(str(tmp_path / "st"), cas)
    loaded = CascadeIndex.load(store, m_coarse=cas.m_coarse,
                               n_factor=cas.n_factor, segmented=True,
                               delta_capacity=64)
    assert loaded.n == cas.n and loaded.coarse.n == loaded.full.n
    # at covering depth the shortlist spans every row, so ids/scores
    # match the full-resolution search exactly
    s0, i0 = loaded.full.search_projected(Q, W, k=k, mean=mean)
    s1, i1 = loaded.search_projected(Q, W, k=k, mean=mean)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def _cascade_store(tmp_path, n=200):
    pruned, W, mean, Q = _fixture(n=n)
    cas = CascadeIndex.build(pruned, m_coarse=pruned.shape[1] // 2,
                             n_factor=2, quantize_int8=True)
    return save_index(str(tmp_path / "st"), cas), pruned


def test_store_rejects_resolution_row_mismatch(tmp_path):
    store, pruned = _cascade_store(tmp_path)
    with pytest.raises(IndexStoreError, match="rows"):
        store.add_resolution(np.zeros((5, 3), np.float32))
    mpath = os.path.join(store.path, "manifest.json")
    with open(mpath) as f:
        m = json.load(f)
    m["resolutions"][0]["chunks"][0]["rows"] -= 1
    with open(mpath, "w") as f:
        json.dump(m, f)
    with pytest.raises(IndexStoreError, match="shape|same corpus"):
        IndexStore.open(store.path)


def test_store_rejects_non_nested_resolution_m(tmp_path):
    store, pruned = _cascade_store(tmp_path)
    n, m = pruned.shape
    with pytest.raises(IndexStoreError, match="nest"):
        store.add_resolution(np.zeros((n, m), np.float32))
    mpath = os.path.join(store.path, "manifest.json")
    with open(mpath) as f:
        man = json.load(f)
    man["resolutions"][0]["m"] = man["dim"] + 4
    with open(mpath, "w") as f:
        json.dump(man, f)
    with pytest.raises(IndexStoreError, match="does not nest"):
        IndexStore.open(store.path)


def test_store_rejects_duplicate_resolution_m(tmp_path):
    store, pruned = _cascade_store(tmp_path)
    mc = int(store.manifest["resolutions"][0]["m"])
    with pytest.raises(IndexStoreError, match="already present"):
        store.add_resolution(
            np.asarray(pruned[:, :mc], np.float32))


def test_store_rejects_missing_resolution_blob(tmp_path):
    store, _ = _cascade_store(tmp_path)
    entry = store.manifest["resolutions"][0]
    os.remove(os.path.join(store.path, entry["chunks"][0]["file"]))
    with pytest.raises(IndexStoreError, match="missing chunk"):
        IndexStore.open(store.path)


def test_store_rejects_missing_resolution_scale(tmp_path):
    store, _ = _cascade_store(tmp_path)
    entry = store.manifest["resolutions"][0]
    assert entry["scale_file"] is not None   # int8 coarse ships its scale
    os.remove(os.path.join(store.path, entry["scale_file"]))
    with pytest.raises(IndexStoreError, match="scale"):
        IndexStore.open(store.path)


def test_cascade_load_requires_matching_resolution(tmp_path):
    pruned, W, mean, Q = _fixture(n=150)
    plain = save_index(str(tmp_path / "plain"),
                       DenseIndex.build(pruned))
    with pytest.raises(IndexStoreError, match="no coarse resolutions"):
        CascadeIndex.load(plain)
    store, _ = _cascade_store(tmp_path)
    with pytest.raises(IndexStoreError, match="no m="):
        CascadeIndex.load(store, m_coarse=3)


def test_cascade_store_persists_coarse_deltas_bit_parity(tmp_path):
    """Satellite regression: a segmented cascade's coarse deltas persist
    in the store as exact quantised bytes + per-delta scales, and a
    segmented load rehydrates them BIT-identically — no requantisation
    from the full deltas on the load path."""
    k, n = 8, 300
    pruned, W, mean, Q = _fixture(n=n)
    cas = CascadeIndex.build(pruned, m_coarse=pruned.shape[1] // 2,
                             n_factor=_full_nf(n + 48, k),
                             quantize_int8=True).segmented(delta_capacity=64)
    for seed in (1, 2):
        cas = cas.append(np.random.default_rng(seed)
                         .standard_normal((24, pruned.shape[1]))
                         .astype(np.float32))
    store = save_index(str(tmp_path / "st"), cas)
    name = store.manifest["resolutions"][0]["name"]
    dviews = store.resolution_deltas(name)
    assert dviews, "segmented save must persist the coarse delta segments"
    assert [v.n for v in dviews] == [d.n_real for d in cas.coarse.deltas]
    loaded = CascadeIndex.load(store, m_coarse=cas.m_coarse,
                               n_factor=cas.n_factor, segmented=True,
                               delta_capacity=64)
    for mem, got in zip(cas.coarse.deltas, loaded.coarse.deltas):
        np.testing.assert_array_equal(
            np.asarray(mem.vectors[:mem.n_real]),
            np.asarray(got.vectors[:got.n_real]))
        assert (mem.scale is None) == (got.scale is None)
        if mem.scale is not None:
            np.testing.assert_array_equal(np.asarray(mem.scale),
                                          np.asarray(got.scale))
    s0, i0 = cas.search_projected(Q, W, k=k, mean=mean)
    s1, i1 = loaded.search_projected(Q, W, k=k, mean=mean)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_store_rejects_misaligned_resolution_deltas(tmp_path):
    """Coarse delta rows must mirror the main delta segments one-for-one
    — otherwise the two views would describe different docs."""
    pruned, W, mean, Q = _fixture(n=200)
    cas = CascadeIndex.build(pruned, m_coarse=pruned.shape[1] // 2,
                             n_factor=2,
                             quantize_int8=True).segmented(delta_capacity=64)
    cas = cas.append(RNG.standard_normal((16, pruned.shape[1]))
                     .astype(np.float32))
    store = save_index(str(tmp_path / "full-only"), cas.full)
    mc = cas.m_coarse
    base = np.asarray(cas.coarse.base.vectors[:cas.coarse.base.n])
    scale = np.asarray(cas.coarse.base.scale)
    with pytest.raises(IndexStoreError, match="mirror"):
        store.add_resolution(base, scale=scale, deltas=[
            {"rows": np.zeros((3, mc), np.int8), "scale": None,
             "capacity": 64}])
    with pytest.raises(IndexStoreError, match="no resolution"):
        store.resolution_deltas("m999")
