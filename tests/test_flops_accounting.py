"""Trip-count-aware cost accounting (launch/flops.py)."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.flops import hlo_collectives, jaxpr_cost


def test_dot_flops_exact():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 16), jnp.float32)
    c = jaxpr_cost(f, a, b)
    assert c["flops"] == pytest.approx(2 * 64 * 32 * 16, rel=0.01)


def test_scan_multiplies_body():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=8)
        return c
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    c = jaxpr_cost(f, x, w)
    base = 2 * 32 * 32 * 32
    assert c["flops"] >= 8 * base           # 8 trips counted
    assert c["flops"] < 8 * base * 1.5      # no runaway double counting


def test_grad_includes_backward():
    def loss(w, x):
        return jnp.sum((x @ w) ** 2)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 32), jnp.float32)
    f = jaxpr_cost(loss, w, x)["flops"]
    g = jaxpr_cost(jax.grad(loss), w, x)["flops"]
    assert g > 2 * f   # bwd ≈ 2x fwd for a matmul


def test_remat_recompute_counted():
    def loss(w, x):
        def blk(x):
            return jnp.tanh(x @ w)
        return jnp.sum(jax.checkpoint(blk)(x))
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    plain = jaxpr_cost(jax.grad(lambda w, x: jnp.sum(jnp.tanh(x @ w))), w, x)
    remat = jaxpr_cost(jax.grad(loss), w, x)
    assert remat["flops"] > plain["flops"]   # recompute shows up


def test_hlo_collectives_while_multiplication():
    # no collectives in this program regardless of device count; just verify
    # the parser returns a well-formed structure on a compiled while-loop
    def f(x):
        def body(c, _):
            return jnp.tanh(c) * 2, None
        c, _ = jax.lax.scan(body, x, None, length=5)
        return c
    compiled = jax.jit(f).lower(jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
    out = hlo_collectives(compiled.as_text())
    assert "total_bytes" in out and out["total_bytes"] == 0


def test_cell_flops_within_factor_of_model_estimate():
    """smollm train: jaxpr flops within ~2-5x of 6ND (remat+attention extra)."""
    import json, glob, os
    arts = glob.glob("experiments/dryrun/smollm-135m__train_4k__pod.json")
    if not arts:
        pytest.skip("dry-run artifact not present")
    r = json.load(open(arts[0]))
    if r.get("status") != "ok":
        pytest.skip("cell not ok")
    ratio = r["accounting"]["global_flops"] / r["meta"]["model_flops"]
    assert 1.0 < ratio < 6.0
