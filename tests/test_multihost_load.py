"""Multi-host load contract for ``ShardedDenseIndex.load``.

A pod-scale load must read ONLY the shards this process addresses
(``addressable_devices_indices_map``): 1/num_hosts of the store per host,
never a full-index host copy. Single-process CI can still pin the
contract: every locally-addressable row is read exactly once, shard
windows partition the padded row space, and a sharding that claims only a
SUBSET of devices (what one process of a multi-host job sees) yields read
ranges confined to that subset's rows.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DenseIndex, ShardedDenseIndex, StaticPruner
from repro.core.index import _addressable_shard_ranges
from repro.core.store import save_index

RNG = np.random.default_rng(11)


def _mesh(ndev=4):
    if jax.device_count() < ndev:
        pytest.skip(f"needs {ndev} devices, have {jax.device_count()}")
    return jax.make_mesh((ndev,), ("data",))


def _store(tmp_path, n=103, d=32, quant=True):
    D = jnp.asarray(RNG.standard_normal((n, d)).astype(np.float32))
    pruner = StaticPruner(cutoff=0.5).fit(D)
    index = pruner.build_index(D, quantize_int8=quant)
    return save_index(str(tmp_path / "st"), index, pruner=pruner), D, pruner


class _CountingStore:
    """Delegating wrapper that records every read_rows window."""

    def __init__(self, store):
        self._store = store
        self.reads: list[tuple[int, int]] = []

    def read_rows(self, lo, hi):
        self.reads.append((int(lo), int(hi)))
        return self._store.read_rows(lo, hi)

    def __getattr__(self, name):
        return getattr(self._store, name)


def test_load_reads_each_local_row_exactly_once(tmp_path):
    mesh = _mesh()
    store, D, pruner = _store(tmp_path)          # 103 rows: padding shard
    counting = _CountingStore(store)
    sidx = ShardedDenseIndex.load(counting, mesh)

    ndev = jax.device_count()
    assert len(counting.reads) == ndev           # one read per local shard
    covered = np.zeros(store.n, dtype=int)
    for lo, hi in counting.reads:
        covered[lo:hi] += 1
    assert (covered == 1).all()                  # each row exactly once

    # and the loaded index answers identically to the unsharded load
    dense = DenseIndex.load(store)
    W, mean = pruner.projection()
    q = jnp.asarray(RNG.standard_normal((3, D.shape[1]))
                    .astype(np.float32))
    s_sh, i_sh = sidx.search_projected(q, W, k=5, mean=mean)
    s_dn, i_dn = dense.search_projected(q, W, k=5, mean=mean)
    np.testing.assert_array_equal(np.asarray(i_sh), np.asarray(i_dn))
    np.testing.assert_allclose(np.asarray(s_sh), np.asarray(s_dn),
                               rtol=1e-5, atol=1e-5)


def test_shard_ranges_partition_padded_rows():
    mesh = _mesh()
    from jax.sharding import NamedSharding, PartitionSpec as P
    sharding = NamedSharding(mesh, P(("data",), None))
    n, ndev = 103, jax.device_count()
    n_padded = n + (-n) % ndev
    ranges = _addressable_shard_ranges(sharding, (n_padded, 8), n)
    windows = sorted((start, stop) for _, start, stop, _, _ in ranges)
    assert windows[0][0] == 0 and windows[-1][1] == n_padded
    for (_, a_stop), (b_start, _) in zip(windows, windows[1:]):
        assert a_stop == b_start                 # contiguous, disjoint
    for _, start, stop, lo, hi in ranges:
        assert start <= lo <= hi <= stop         # clamp stays in-window
        assert hi <= n                           # never reads padding rows


class _SubsetSharding:
    """What one process of a multi-host job observes: the global map has
    every shard, the addressable map only this host's slice."""

    def __init__(self, sharding, shape, keep):
        self._all = sorted(
            sharding.addressable_devices_indices_map(shape).items(),
            key=lambda kv: kv[1][0].start or 0)
        self._keep = keep

    def addressable_devices_indices_map(self, shape):
        return dict(self._all[:self._keep])


def test_subset_addressable_reads_only_local_rows():
    mesh = _mesh()
    from jax.sharding import NamedSharding, PartitionSpec as P
    sharding = NamedSharding(mesh, P(("data",), None))
    n, ndev = 100, jax.device_count()
    n_padded = n + (-n) % ndev
    per = n_padded // ndev
    keep = ndev // 2                             # "this host" owns half
    fake = _SubsetSharding(sharding, (n_padded, 8), keep)
    ranges = _addressable_shard_ranges(fake, (n_padded, 8), n)
    assert len(ranges) == keep
    rows = sorted((lo, hi) for _, _, _, lo, hi in ranges)
    # the union of local reads is exactly the first half's rows — the
    # other host's rows are never touched
    assert rows[0][0] == 0
    assert max(hi for _, hi in rows) <= keep * per
