"""StaticPruner end-to-end behaviour incl. the paper's RQ claims in miniature."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DenseIndex, StaticPruner
from repro.core.metrics import evaluate_run, mean_metrics
from repro.data.synthetic import make_dataset, make_ood_corpus


@pytest.fixture(scope="module")
def ds():
    return make_dataset("tasb", n_docs=4000, d=128, seed=0, query_sets=("dl19",))


def _ndcg(D, Q, qrels, pruner=None):
    if pruner is not None:
        D = pruner.prune_index(D)
        Q = pruner.transform_queries(Q)
    _, ids = DenseIndex.build(D).search(Q, k=50)
    run = {i: list(map(int, np.asarray(ids)[i])) for i in range(Q.shape[0])}
    return mean_metrics(evaluate_run(run, qrels))["nDCG@10"]


def test_config_validation():
    with pytest.raises(ValueError):
        StaticPruner()
    with pytest.raises(ValueError):
        StaticPruner(cutoff=0.5, m=10)
    with pytest.raises(RuntimeError):
        StaticPruner(cutoff=0.5).kept_dims


def test_rq1_pruning_50pct_small_loss(ds):
    D = jnp.asarray(ds.docs)
    Q = jnp.asarray(ds.queries["dl19"])
    base = _ndcg(D, Q, ds.qrels["dl19"])
    pr = StaticPruner(cutoff=0.5).fit(D)
    pruned = _ndcg(D, Q, ds.qrels["dl19"], pr)
    assert pr.kept_dims == 64
    assert pruned > base * 0.9   # paper: <=5% loss at 50% on TAS-B-like

def test_rq2_out_of_domain_transfer(ds):
    D = jnp.asarray(ds.docs)
    Q = jnp.asarray(ds.queries["dl19"])
    ood = jnp.asarray(make_ood_corpus("tasb", n_docs=4000, d=128))
    pr = StaticPruner(cutoff=0.5).fit(ood)          # fit on DIFFERENT corpus
    pruned = _ndcg(D, Q, ds.qrels["dl19"], pr)
    base = _ndcg(D, Q, ds.qrels["dl19"])
    assert pruned > base * 0.85


def test_rq3_fit_sample_count_insensitive(ds):
    D = jnp.asarray(ds.docs)
    Q = jnp.asarray(ds.queries["dl19"])
    n_small = _ndcg(D, Q, ds.qrels["dl19"],
                    StaticPruner(cutoff=0.5).fit(D[:500]))
    n_large = _ndcg(D, Q, ds.qrels["dl19"],
                    StaticPruner(cutoff=0.5).fit(D))
    assert abs(n_small - n_large) < 0.05


def test_streaming_fit_equivalent(ds):
    D = jnp.asarray(ds.docs)
    p1 = StaticPruner(cutoff=0.5).fit(D)
    p2 = StaticPruner(cutoff=0.5).fit_streaming(
        [np.asarray(D[i:i + 1000]) for i in range(0, D.shape[0], 1000)])
    i1 = p1.prune_index(D[:100])
    i2 = p2.prune_index(D[:100])
    # eigenvectors can flip sign; compare magnitudes of projections
    np.testing.assert_allclose(np.abs(np.asarray(i1)), np.abs(np.asarray(i2)),
                               rtol=1e-2, atol=1e-3)


def test_save_load_roundtrip(tmp_path, ds):
    D = jnp.asarray(ds.docs)
    pr = StaticPruner(cutoff=0.25).fit(D)
    path = str(tmp_path / "pruner.npz")
    pr.save(path)
    pr2 = StaticPruner.load(path, cutoff=0.25)
    np.testing.assert_allclose(np.asarray(pr.prune_index(D[:50])),
                               np.asarray(pr2.prune_index(D[:50])),
                               rtol=1e-5)


def test_build_index_variants(ds):
    D = jnp.asarray(ds.docs)
    pr = StaticPruner(m=32).fit(D)
    idx = pr.build_index(D)
    assert idx.dim == 32
    idx8 = pr.build_index(D, quantize_int8=True)
    assert idx8.vectors.dtype == jnp.int8
    q = pr.transform_queries(jnp.asarray(ds.queries["dl19"]))
    s, ids = idx8.search(q, k=10)
    assert np.isfinite(np.asarray(s)).all()


def test_block_rows_invariance(ds):
    D = jnp.asarray(ds.docs)
    pr = StaticPruner(cutoff=0.5).fit(D)
    a = pr.prune_index(D, block_rows=999)
    b = pr.prune_index(D, block_rows=10**6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)
