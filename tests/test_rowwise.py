"""Rowwise-AdaGrad embedding optimizer (repro.optim.rowwise)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.rowwise import combine_duplicate_rows, rowwise_adagrad_update


def test_combine_duplicate_rows_exact():
    idx = jnp.array([3, 1, 3, 7, 1, 1], jnp.int32)
    g = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    ids, gc, valid = combine_duplicate_rows(idx, g)
    got = {}
    for i in range(6):
        if bool(valid[i]):
            got[int(ids[i])] = np.asarray(gc[i])
    np.testing.assert_allclose(got[1], np.asarray(g[1] + g[4] + g[5]))
    np.testing.assert_allclose(got[3], np.asarray(g[0] + g[2]))
    np.testing.assert_allclose(got[7], np.asarray(g[3]))
    assert int(valid.sum()) == 3


def test_rowwise_update_touches_only_indexed_rows():
    table = jnp.ones((10, 4))
    acc = jnp.zeros((10,))
    idx = jnp.array([2, 5], jnp.int32)
    g = jnp.ones((2, 4))
    nt, na = rowwise_adagrad_update(table, acc, idx, g, jnp.float32(0.1))
    changed = np.where(np.abs(np.asarray(nt) - 1.0).sum(-1) > 0)[0]
    assert set(changed.tolist()) == {2, 5}
    assert np.asarray(na)[[2, 5]].min() > 0
    assert np.asarray(na)[[0, 1, 3, 4, 6, 7, 8, 9]].max() == 0


def test_rowwise_descends_on_embedding_regression():
    rng = np.random.default_rng(0)
    V, E, B = 50, 8, 32
    table = jnp.asarray(rng.standard_normal((V, E)) * 0.1, jnp.float32)
    target = jnp.asarray(rng.standard_normal((V, E)), jnp.float32)
    acc = jnp.zeros((V,))

    def loss(rows, tgt_rows):
        return jnp.mean((rows - tgt_rows) ** 2)

    losses = []
    for _step in range(60):
        idx = jnp.asarray(rng.integers(0, V, B), jnp.int32)
        rows = table[idx]
        l, g = jax.value_and_grad(loss)(rows, target[idx])
        table, acc = rowwise_adagrad_update(table, acc, idx, g,
                                            jnp.float32(0.05))
        losses.append(float(l))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.5


def test_rowwise_duplicates_equal_single_combined_step():
    """A batch with duplicate ids must equal one combined-gradient step."""
    table = jnp.ones((6, 3))
    acc = jnp.zeros((6,))
    gdup = jnp.array([[1., 1, 1], [2, 2, 2]])
    t1, a1 = rowwise_adagrad_update(table, acc, jnp.array([4, 4]), gdup,
                                    jnp.float32(0.1))
    t2, a2 = rowwise_adagrad_update(table, acc, jnp.array([4, 0]),
                                    jnp.array([[3., 3, 3], [0, 0, 0]]),
                                    jnp.float32(0.1))
    np.testing.assert_allclose(np.asarray(t1[4]), np.asarray(t2[4]), rtol=1e-5)
