"""PCA core unit tests. Hypothesis property tests live in
test_pca_properties.py behind ``pytest.importorskip`` — a missing optional
package must never kill tier-1 collection."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    cutoff_from_m,
    fit_pca,
    fit_pca_streaming,
    gram,
    load_pca,
    m_for_variance,
    m_from_cutoff,
    save_pca,
    transform,
)

RNG = np.random.default_rng(0)


def _corpus(n=500, d=32, rank=8, noise=0.05, seed=0):
    rng = np.random.default_rng(seed)
    Z = rng.standard_normal((n, rank))
    F = np.linalg.qr(rng.standard_normal((d, rank)))[0]
    return jnp.asarray((Z @ F.T + noise * rng.standard_normal((n, d))),
                       dtype=jnp.float32)


# -- unit ---------------------------------------------------------------------

def test_gram_matches_naive():
    D = _corpus()
    np.testing.assert_allclose(np.asarray(gram(D, block_rows=128)),
                               np.asarray(D).T @ np.asarray(D),
                               rtol=1e-4, atol=1e-3)


def test_eigh_descending_and_orthonormal():
    state = fit_pca(_corpus())
    ev = np.asarray(state.eigenvalues)
    assert (np.diff(ev) <= 1e-4).all()
    W = np.asarray(state.components)
    np.testing.assert_allclose(W.T @ W, np.eye(W.shape[0]), atol=1e-4)


def test_full_rotation_preserves_scores():
    """Key paper identity: (DW)(Wᵀq) == Dq exactly when m = d."""
    D = _corpus()
    Q = jnp.asarray(RNG.standard_normal((7, D.shape[1])), jnp.float32)
    state = fit_pca(D)
    T = transform(D, state)
    Qt = transform(Q, state)
    np.testing.assert_allclose(np.asarray(T @ Qt.T), np.asarray(D @ Q.T),
                               rtol=1e-3, atol=1e-4)


def test_streaming_matches_batch():
    D = _corpus(n=600)
    s1 = fit_pca(D)
    s2 = fit_pca_streaming([D[:200], D[200:350], D[350:]])
    np.testing.assert_allclose(np.asarray(s1.eigenvalues),
                               np.asarray(s2.eigenvalues), rtol=1e-3, atol=1e-4)
    # eigenvectors match up to sign
    dots = np.abs(np.sum(np.asarray(s1.components) * np.asarray(s2.components),
                         axis=0))
    assert (dots[:8] > 0.99).all()   # top components (well-separated)


def test_low_rank_corpus_truncation_is_lossless():
    D = _corpus(rank=8, noise=0.0)
    state = fit_pca(D)
    T8 = transform(D, state, m=8)
    rec = T8 @ state.components[:, :8].T
    np.testing.assert_allclose(np.asarray(rec), np.asarray(D), atol=1e-3)


def test_centered_variant():
    D = _corpus() + 5.0   # large mean offset
    s = fit_pca(D, center=True)
    assert np.abs(np.asarray(s.mean)).mean() > 1.0
    T = transform(D, s)
    # centred projection has ~zero mean
    assert abs(float(T.mean())) < 0.1


def test_cutoff_math():
    assert m_from_cutoff(768, 0.5) == 384
    assert m_from_cutoff(768, 0.25) == 576
    assert m_from_cutoff(768, 0.75) == 192
    assert cutoff_from_m(768, 384) == pytest.approx(0.5)
    with pytest.raises(ValueError):
        m_from_cutoff(768, 1.0)


def test_m_for_variance():
    D = _corpus(rank=8, noise=0.0)
    s = fit_pca(D)
    assert m_for_variance(s, 0.999) <= 9


def test_m_for_variance_full_target_in_range():
    """target=1.0 regression: fp32 cumsum tops out just below 1.0, where an
    unclamped searchsorted+1 would return d+1 — out of range for W[:, :m]."""
    s = fit_pca(_corpus())
    d = s.d
    m = m_for_variance(s, 1.0)
    assert 1 <= m <= d
    # the clamped m must still index a valid transform
    assert transform(_corpus(), s, m).shape[1] == m


def test_save_load_roundtrip(tmp_path):
    s = fit_pca(_corpus())
    p = str(tmp_path / "pca.npz")
    save_pca(p, s)
    s2 = load_pca(p)
    np.testing.assert_array_equal(np.asarray(s.components),
                                  np.asarray(s2.components))
    assert s2.centered == s.centered
