"""On-disk index artifact store: round-trips, streaming build, durability.

Covers the acceptance surface of the artifact subsystem:
  * save -> load parity (scores AND ids) against the in-memory build path,
    dense and sharded, fp32 and int8, on 1- and 4-device meshes, with row
    counts not divisible by the device count;
  * the streaming build path's peak host memory stays O(block_rows · d) —
    the full corpus array never materialises (tracemalloc-verified);
  * corrupted / partially-written directories are rejected loudly;
  * ``IndexUpdater`` appends persist: append -> reload preserves n and
    search results.
"""
import json
import os
import tracemalloc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DenseIndex,
    IndexStore,
    IndexStoreError,
    ShardedDenseIndex,
    StaticPruner,
    save_index,
)
from repro.core.maintenance import IndexUpdater
from repro.core.store import IndexStoreWriter

RNG = np.random.default_rng(11)


def _corpus(n=1003, d=64):
    from repro.data.synthetic import make_corpus
    D, _ = make_corpus("tasb", n_docs=n, d=d, seed=3)
    return jnp.asarray(D)


def _queries(d=64, nq=6):
    return jnp.asarray(RNG.standard_normal((nq, d)), jnp.float32)


def _mesh(ndev):
    if jax.device_count() < ndev:
        pytest.skip(f"needs {ndev} devices, have {jax.device_count()}")
    return jax.make_mesh((ndev,), ("data",))


def _batches(D, rows=200):
    D = np.asarray(D)

    def gen():
        for i in range(0, len(D), rows):
            yield D[i:i + rows]
    return gen


# ---------------------------------------------------------------------------
# round trips: saved artifact == served index, all dtypes / layouts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quantize", [False, True])
def test_saved_index_serves_identical_topk_dense(tmp_path, quantize):
    """Acceptance: load path returns identical scores and ids to the
    in-memory build it was saved from (fp32 and int8)."""
    D, Q = _corpus(), _queries()
    pruner = StaticPruner(cutoff=0.5).fit(D)
    idx = pruner.build_index(D, quantize_int8=quantize)
    store = save_index(str(tmp_path / "st"), idx, pruner=pruner)

    loaded = DenseIndex.load(store)
    qh = store.load_pruner().transform_queries(Q)
    s0, i0 = idx.search(pruner.transform_queries(Q), k=10)
    s1, i1 = loaded.search(qh, k=10)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    # the stored bytes are the served bytes
    disk = np.concatenate([np.array(c) for c in store.iter_chunks()])
    np.testing.assert_array_equal(disk, np.asarray(idx.vectors))


@pytest.mark.parametrize("ndev", [1, 4])
@pytest.mark.parametrize("quantize", [False, True])
def test_sharded_load_matches_dense_uneven_rows(tmp_path, ndev, quantize):
    """1003 % 4 != 0: load-time device padding must never surface."""
    mesh = _mesh(ndev)
    D, Q = _corpus(1003, 32), _queries(32)
    pruner = StaticPruner(cutoff=0.5).fit(D)
    idx = pruner.build_index(D, quantize_int8=quantize)
    store = save_index(str(tmp_path / "st"), idx, pruner=pruner)

    sidx = ShardedDenseIndex.load(store, mesh)
    assert sidx.n == store.n == 1003
    qh = pruner.transform_queries(Q)
    s0, i0 = idx.search(qh, k=10)
    s1, i1 = sidx.search(qh, k=10)
    assert int(np.asarray(i1).max()) < 1003
    assert (np.asarray(i0) == np.asarray(i1)).all()
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-5, atol=1e-5)


def test_sharded_load_shard_entirely_padding(tmp_path):
    """n=5 on a 4-device mesh: the last shard is 100% device padding —
    the load must synthesise it rather than crash on an out-of-range
    read, and search must still match the dense oracle."""
    mesh = _mesh(4)
    D = jnp.asarray(RNG.standard_normal((5, 8)), jnp.float32)
    Q = _queries(8, nq=3)
    store = save_index(str(tmp_path / "st"), DenseIndex.build(D))
    sidx = ShardedDenseIndex.load(store, mesh)
    assert sidx.n == 5
    s0, i0 = DenseIndex.build(D).search(Q, k=3)
    s1, i1 = sidx.search(Q, k=3)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-5, atol=1e-5)


def test_store_replacement_at_same_path(tmp_path):
    """Re-committing to an existing path (IndexUpdater.refit) swaps via
    rename-aside — the new store wins and no .tmp/.old residue is left."""
    D1 = _corpus(300, 16)
    D2 = _corpus(421, 16)
    path = str(tmp_path / "st")
    save_index(path, DenseIndex.build(D1))
    # a leftover .old from a previous crashed replacement must not block
    os.makedirs(path + ".old", exist_ok=True)
    save_index(path, DenseIndex.build(D2))
    st = IndexStore.open(path)
    assert st.n == 421
    assert not os.path.exists(path + ".tmp")
    assert not os.path.exists(path + ".old")


def test_bf16_round_trip(tmp_path):
    """bf16 has no native .npy encoding — stored as uint16 views, loaded
    back as logical bf16, bit-identical."""
    D, Q = _corpus(500, 32), _queries(32)
    idx = DenseIndex.build(D, dtype=jnp.bfloat16)
    store = save_index(str(tmp_path / "st"), idx)
    assert store.manifest["dtype"] == "bfloat16"
    loaded = DenseIndex.load(store)
    assert loaded.vectors.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(loaded.vectors).view(np.uint16),
        np.asarray(idx.vectors).view(np.uint16))
    s0, i0 = idx.search(Q, k=10)
    s1, i1 = loaded.search(Q, k=10)
    assert (np.asarray(i0) == np.asarray(i1)).all()


def test_multi_chunk_read_rows(tmp_path):
    """read_rows assembles across chunk boundaries without touching
    chunks outside the range."""
    writer = IndexStore.create(str(tmp_path / "st"))
    parts = [RNG.standard_normal((r, 8)).astype(np.float32)
             for r in (10, 7, 13)]
    for p in parts:
        writer.append(p)
    store = writer.commit()
    full = np.concatenate(parts)
    np.testing.assert_array_equal(store.read_rows(5, 25), full[5:25])
    np.testing.assert_array_equal(store.read_rows(0, 30), full)
    with pytest.raises(ValueError):
        store.read_rows(0, 31)


# ---------------------------------------------------------------------------
# streaming build: memory stays O(block), multi-pass contract enforced
# ---------------------------------------------------------------------------


def test_streaming_build_matches_in_memory(tmp_path):
    D, Q = _corpus(), _queries()
    st = StaticPruner(cutoff=0.5).build_index_to(
        str(tmp_path / "st"), _batches(D))
    assert st.n == D.shape[0]
    assert st.meta["kept_dims"] == st.dim
    mem = StaticPruner(cutoff=0.5).fit(D)
    qh = mem.transform_queries(Q)
    _, i0 = mem.build_index(D).search(qh, k=10)
    _, i1 = DenseIndex.load(st).search(
        st.load_pruner().transform_queries(Q), k=10)
    assert (np.asarray(i0) == np.asarray(i1)).all()


def test_streaming_build_int8_matches_in_memory(tmp_path):
    D, Q = _corpus(), _queries()
    st = StaticPruner(cutoff=0.5).build_index_to(
        str(tmp_path / "st"), _batches(D), quantize_int8=True)
    assert st.dtype == np.int8
    assert st.scale() is not None
    mem = StaticPruner(cutoff=0.5).fit(D)
    qh = mem.transform_queries(Q)
    _, i0 = mem.build_index(D, quantize_int8=True).search(qh, k=10)
    _, i1 = DenseIndex.load(st).search(qh, k=10)
    assert (np.asarray(i0) == np.asarray(i1)).all()


def test_streaming_build_peak_memory_is_o_block(tmp_path):
    """Build a 30000x128 (~15 MiB fp32) index from 1000-row batches that
    are generated on the fly — host peak must stay a small multiple of one
    block (~0.5 MiB), nowhere near the full corpus."""
    n, d, rows = 30000, 128, 1000
    full_bytes = n * d * 4

    def gen():
        rng = np.random.default_rng(0)    # fresh per pass: identical blocks
        for _ in range(n // rows):
            yield rng.standard_normal((rows, d)).astype(np.float32)

    tracemalloc.start()
    tracemalloc.reset_peak()
    st = StaticPruner(cutoff=0.5).build_index_to(str(tmp_path / "st"), gen)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert st.n == n
    assert peak < full_bytes / 4, \
        f"peak host memory {peak} bytes is not O(block) vs corpus {full_bytes}"


def test_streaming_int8_build_two_corpus_passes(tmp_path):
    """The int8 build's absmax piggybacks on the write pass (projected
    blocks spill to disk while the scale accumulates), so when the scale
    stabilises in the first block the corpus is read exactly twice: once
    for the Gram fit, once to project+write. Counted via generator
    restarts. (A corpus whose absmax keeps growing pays one extra bounded
    re-read pass for the stale blocks — see the spill test below.)"""
    D = np.asarray(_corpus(900, 48))
    # first block dominates the dynamic range per-dim: the provisional
    # scale equals the final scale from block 0, so no block goes stale
    blocks = [3.0 * D[:300], np.asarray(D[300:600]), np.asarray(D[600:])]
    blocks = [np.asarray(b, np.float32) for b in blocks]
    calls = {"n": 0}

    def gen():
        calls["n"] += 1
        yield from blocks

    st = StaticPruner(cutoff=0.5).build_index_to(
        str(tmp_path / "st"), gen, quantize_int8=True)
    assert calls["n"] == 2, f"expected 2 corpus passes, got {calls['n']}"
    assert st.n == 900 and st.dtype == np.int8
    assert st.meta["requant_blocks"] == 0

    # an already-fitted pruner needs only the write pass
    pre = StaticPruner(cutoff=0.5)
    pre.fit_streaming(blocks)
    calls["n"] = 0
    st2 = pre.build_index_to(str(tmp_path / "st2"), gen, quantize_int8=True)
    assert calls["n"] == 1
    # identical artifact either way: same scale, same quantised rows
    np.testing.assert_array_equal(st.scale(), st2.scale())
    np.testing.assert_array_equal(st.read_rows(0, 900), st2.read_rows(0, 900))


def test_streaming_int8_spill_is_int8_and_bit_identical(tmp_path):
    """The spill is int8 (4x fewer bytes than the old f32 spill), blocks
    whose provisional scale went stale are re-projected in one bounded
    re-read pass, and the committed artifact is BIT-IDENTICAL to
    quantising exact f32 projections under the final corpus-wide scale."""
    from repro.core import pca as _pca
    D = np.asarray(_corpus(900, 48))
    blocks = [np.asarray(D[i:i + 300], np.float32) for i in range(0, 900, 300)]
    calls = {"n": 0}

    def gen():
        calls["n"] += 1
        yield from blocks

    st = StaticPruner(cutoff=0.5).build_index_to(
        str(tmp_path / "st"), gen, quantize_int8=True)
    # generic corpus: absmax keeps growing -> fit + write + bounded re-read
    assert calls["n"] <= 3
    m = st.meta["kept_dims"]
    assert st.meta["spill_dtype"] == "int8"
    assert st.meta["spill_bytes"] == 900 * m          # int8: one byte/value
    assert 0 <= st.meta["requant_blocks"] <= len(blocks)

    # oracle: exact f32 projections quantised under the final scale
    pre = StaticPruner(cutoff=0.5)
    pre.fit_streaming(blocks)
    proj = np.concatenate([
        np.asarray(_pca.transform(jnp.asarray(b), pre.state, m), np.float32)
        for b in blocks])
    scale = (np.maximum(np.abs(proj).max(axis=0), 1e-12) / 127.0) \
        .astype(np.float32)
    want = np.clip(np.round(proj / scale[None, :]), -127, 127).astype(np.int8)
    np.testing.assert_array_equal(st.scale(), scale)
    np.testing.assert_array_equal(st.read_rows(0, 900), want)


def test_streaming_int8_build_peak_memory_is_o_block(tmp_path):
    """The absmax fusion spills projected blocks to disk — host peak must
    stay O(block) for the int8 path too, not grow to the corpus."""
    n, d, rows = 30000, 128, 1000
    full_bytes = n * d * 4

    def gen():
        rng = np.random.default_rng(0)    # fresh per pass: identical blocks
        for _ in range(n // rows):
            yield rng.standard_normal((rows, d)).astype(np.float32)

    tracemalloc.start()
    tracemalloc.reset_peak()
    st = StaticPruner(cutoff=0.5).build_index_to(str(tmp_path / "st"), gen,
                                                 quantize_int8=True)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert st.n == n and st.dtype == np.int8
    assert peak < full_bytes / 4, \
        f"peak host memory {peak} bytes is not O(block) vs corpus {full_bytes}"


def test_streaming_build_rejects_one_shot_generator(tmp_path):
    D = _corpus(400, 16)
    gen = iter([np.asarray(D[:200]), np.asarray(D[200:])])
    with pytest.raises(TypeError, match="multiple passes"):
        StaticPruner(cutoff=0.5).build_index_to(str(tmp_path / "st"), gen)


def test_writer_rejects_mismatched_chunks(tmp_path):
    w = IndexStoreWriter(str(tmp_path / "st"))
    w.append(np.zeros((4, 8), np.float32))
    with pytest.raises(ValueError, match="chunk mismatch"):
        w.append(np.zeros((4, 9), np.float32))
    with pytest.raises(ValueError, match="chunk mismatch"):
        w.append(np.zeros((4, 8), np.int8))
    w.abort()


# ---------------------------------------------------------------------------
# durability: partial writes and corruption rejected loudly
# ---------------------------------------------------------------------------


def test_uncommitted_tmp_dir_rejected(tmp_path):
    """A crash mid-build leaves only <dir>.tmp — open() must refuse both
    the missing final dir and the tmp dir itself."""
    w = IndexStoreWriter(str(tmp_path / "st"))
    w.append(np.zeros((4, 8), np.float32))
    # no commit: simulate the crash
    with pytest.raises(IndexStoreError, match="not a committed"):
        IndexStore.open(str(tmp_path / "st"))
    assert not os.path.exists(str(tmp_path / "st"))
    assert os.path.exists(str(tmp_path / "st.tmp"))


def test_missing_chunk_rejected(tmp_path):
    D = _corpus(300, 16)
    st = save_index(str(tmp_path / "st"), DenseIndex.build(D))
    os.remove(os.path.join(st.path, st.manifest["chunks"][0]["file"]))
    with pytest.raises(IndexStoreError, match="missing chunk"):
        IndexStore.open(st.path)


def test_wrong_shape_chunk_rejected(tmp_path):
    D = _corpus(300, 16)
    st = save_index(str(tmp_path / "st"), DenseIndex.build(D))
    f = os.path.join(st.path, st.manifest["chunks"][0]["file"])
    np.save(f, np.zeros((7, 16), np.float32))
    with pytest.raises(IndexStoreError, match="shape"):
        IndexStore.open(st.path)


def test_row_count_mismatch_rejected(tmp_path):
    D = _corpus(300, 16)
    st = save_index(str(tmp_path / "st"), DenseIndex.build(D))
    mpath = os.path.join(st.path, "manifest.json")
    with open(mpath) as f:
        m = json.load(f)
    m["n"] = 9999
    with open(mpath, "w") as f:
        json.dump(m, f)
    with pytest.raises(IndexStoreError, match="manifest n"):
        IndexStore.open(st.path)


def test_unsupported_version_rejected(tmp_path):
    D = _corpus(300, 16)
    st = save_index(str(tmp_path / "st"), DenseIndex.build(D))
    mpath = os.path.join(st.path, "manifest.json")
    with open(mpath) as f:
        m = json.load(f)
    m["format_version"] = 99
    with open(mpath, "w") as f:
        json.dump(m, f)
    with pytest.raises(IndexStoreError, match="format_version"):
        IndexStore.open(st.path)


# ---------------------------------------------------------------------------
# incremental growth through the store
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quantize", [False, True])
def test_updater_append_persists_across_reload(tmp_path, quantize):
    D, Q = _corpus(800, 48), _queries(48)
    up = IndexUpdater.build(D, cutoff=0.5, quantize_int8=quantize,
                            store_path=str(tmp_path / "st"))
    new = _corpus(900, 48)[800:870]
    up.add_documents(new)
    assert up.index.n == 870

    # reload from disk: same n, identical search results
    up2 = IndexUpdater.from_store(str(tmp_path / "st"))
    assert up2.index.n == 870
    s0, i0 = up.search(Q, k=10)
    s1, i1 = up2.search(Q, k=10)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1),
                               rtol=1e-5, atol=1e-5)
    # and a freshly appended doc is findable after reload
    _, ids = up2.search(new[3][None, :], k=5)
    assert 803 in np.asarray(ids)[0].tolist()


def test_updater_append_sharded_reload(tmp_path):
    """Append on the dense updater, reload the grown artifact sharded."""
    mesh = _mesh(4)
    D, Q = _corpus(801, 32), _queries(32)
    up = IndexUpdater.build(D, cutoff=0.5, store_path=str(tmp_path / "st"))
    up.add_documents(_corpus(900, 32)[801:850])
    sidx = ShardedDenseIndex.load(str(tmp_path / "st"), mesh)
    assert sidx.n == 850
    qh = up.pruner.transform_queries(Q)
    _, i0 = up.index.search(qh, k=10)
    _, i1 = sidx.search(qh, k=10)
    assert (np.asarray(i0) == np.asarray(i1)).all()


# ---------------------------------------------------------------------------
# serve-path parity: the restart really serves what the build served
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sharded", [False, True])
@pytest.mark.parametrize("quantize", [False, True])
def test_served_topk_identical_after_reload(tmp_path, sharded, quantize):
    """The serve.py restart path end to end: build+save, then serve from
    the artifact through the same RetrievalServer — identical scores and
    ids per query, dense and sharded, fp32 and int8."""
    from repro.launch.serve import RetrievalServer
    if sharded and jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    D, Q = _corpus(1003, 32), np.asarray(_queries(32, nq=8))
    pruner = StaticPruner(cutoff=0.5).fit(D)
    idx = pruner.build_index(D, quantize_int8=quantize)
    store = save_index(str(tmp_path / "st"), idx, pruner=pruner)

    if sharded:
        mesh = _mesh(4)
        served = ShardedDenseIndex.load(store, mesh)
    else:
        served = DenseIndex.load(store)
    s_build = RetrievalServer(idx, pruner, k=10, max_batch=4)
    s_load = RetrievalServer(served, store.load_pruner(), k=10, max_batch=4)
    try:
        for q in Q:
            sb, ib = s_build.query(q)
            sl, il = s_load.query(q)
            np.testing.assert_array_equal(ib, il)
            np.testing.assert_allclose(sb, sl, rtol=1e-5, atol=1e-5)
    finally:
        s_build.close()
        s_load.close()


def test_append_crash_window_leaves_valid_store(tmp_path):
    """An orphan chunk blob without a manifest swap (crash between the two
    append steps) must not invalidate the store."""
    D = _corpus(300, 16)
    st = save_index(str(tmp_path / "st"), DenseIndex.build(D))
    np.save(os.path.join(st.path, "vectors_999999.npy"),
            np.zeros((5, 16), np.float32))
    re = IndexStore.open(st.path)   # orphan blob ignored
    assert re.n == 300


def test_truncated_chunk_rejected(tmp_path):
    """A torn write (crash mid-rollout/copy: npy header intact, payload
    short) must be rejected by open() as an IndexStoreError diagnosis,
    not surface as a raw mmap failure."""
    D = _corpus(300, 16)
    st = save_index(str(tmp_path / "st"), DenseIndex.build(D))
    f = os.path.join(st.path, st.manifest["chunks"][0]["file"])
    with open(f, "r+b") as fh:
        fh.truncate(os.path.getsize(f) // 2)
    with pytest.raises(IndexStoreError, match="truncated"):
        IndexStore.open(st.path)
