"""Optimizers, schedules, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.optim import (
    AdamWConfig,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    compressed_psum,
    constant_lr,
    error_feedback_step,
    warmup_cosine,
)
from repro.optim.adamw import opt_state_specs, zero1_specs
from repro.optim.grad_compress import init_residual
from repro.par import compat


def _quadratic_problem():
    target = jnp.asarray(np.random.default_rng(0).standard_normal((8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8)), "b": jnp.zeros((8,))}
    def loss(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["b"] ** 2)
    return params, loss, target


def test_adamw_converges():
    params, loss, target = _quadratic_problem()
    state = adamw_init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = adamw_update(g, state, params, jnp.float32(0.05),
                                     AdamWConfig(weight_decay=0.0))
    assert float(loss(params)) < 0.05


def test_adamw_grad_clip():
    params, loss, _ = _quadratic_problem()
    state = adamw_init(params)
    g = jax.tree.map(lambda x: jnp.full_like(x, 1e6), params)  # exploding
    p2, _ = adamw_update(g, state, params, jnp.float32(0.1))
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(p2))


def test_adafactor_converges_and_is_factored():
    params, loss, _ = _quadratic_problem()
    state = adafactor_init(params)
    assert set(state["v"]["w"].keys()) == {"vr", "vc"}
    assert set(state["v"]["b"].keys()) == {"v"}
    assert state["v"]["w"]["vr"].shape == (8,)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, state = adafactor_update(g, state, params, jnp.float32(0.1))
    assert float(loss(params)) < 1.0


def test_schedules():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, abs=1e-3)
    assert float(lr(100)) == pytest.approx(0.1, abs=1e-2)
    assert float(constant_lr(0.3)(99)) == pytest.approx(0.3)


def test_zero1_specs_extend_unsharded_dim():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {"w": jax.ShapeDtypeStruct((16, 8), jnp.float32)}
    base = {"w": P("model", None)}
    z = zero1_specs(base, params, mesh)
    assert z["w"] == P("model", "data")


def test_zero1_specs_skip_when_dp_consumed():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {"t": jax.ShapeDtypeStruct((32, 8), jnp.float32)}
    base = {"t": P(("data", "model"), None)}   # FSDP rows already use dp
    z = zero1_specs(base, params, mesh)
    assert z["t"] == P(("data", "model"), None)


def test_opt_state_specs_structure():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {"w": jax.ShapeDtypeStruct((16, 8), jnp.float32)}
    base = {"w": P(None, "model")}
    specs = opt_state_specs(base, params, mesh)
    assert set(specs.keys()) == {"mu", "nu", "step"}
    assert specs["step"] == P()


# -- gradient compression ------------------------------------------------------

def test_compressed_psum_single_device():
    mesh = jax.make_mesh((1,), ("data",))
    g = jnp.asarray(np.random.default_rng(0).standard_normal((64, 64)),
                    jnp.float32)
    fn = compat.shard_map(lambda x: compressed_psum(x, "data"), mesh=mesh,
                          in_specs=(P(),), out_specs=P(), check_vma=False)
    out = fn(g)
    rel = float(jnp.linalg.norm(out - g) / jnp.linalg.norm(g))
    assert rel < 0.01   # int8 quantisation error only


def test_error_feedback_accumulates_residual():
    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jnp.full((32,), 1e-4, jnp.float32)}   # tiny: quantises to 0
    residual = init_residual(grads)

    def step(g, r):
        return error_feedback_step(g, r, "data")

    fn = compat.shard_map(step, mesh=mesh, in_specs=(P(), P()),
                          out_specs=(P(), P()), check_vma=False)
    total = jnp.zeros((32,))
    g, r = grads, residual
    for _ in range(40):
        out, r = fn(g, r)
        total = total + out["w"]
    # over many steps the mean sent gradient ≈ the true gradient (unbiased)
    assert float(jnp.abs(total / 40 - 1e-4).max()) < 3e-5


def test_compression_ratio():
    from repro.optim.grad_compress import compress_int8
    g = jnp.asarray(np.random.default_rng(0).standard_normal(1000), jnp.float32)
    q = compress_int8(g, jnp.float32(0.03))
    assert q.dtype == jnp.int8
    assert q.nbytes * 4 == g.nbytes
