"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


# ---------------------------------------------------------------------------
# gram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d", [(64, 16), (257, 64), (1000, 96), (1024, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_matches_oracle(n, d, dtype):
    D = _rand((n, d), dtype)
    got = ops.gram(D, block_rows=128, interpret=True)
    want = ref.gram_ref(D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 2e-3,
                               atol=1e-2)


def test_gram_block_size_invariance():
    D = _rand((500, 32), jnp.float32)
    a = ops.gram(D, block_rows=64, interpret=True)
    b = ops.gram(D, block_rows=500, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-3)


def test_gram_psd():
    D = _rand((300, 24), jnp.float32)
    G = np.asarray(ops.gram(D, interpret=True))
    evals = np.linalg.eigvalsh(G)
    assert evals.min() > -1e-3
    np.testing.assert_allclose(G, G.T, atol=1e-5)


# ---------------------------------------------------------------------------
# topk_score
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,B,k,bn", [
    (128, 16, 1, 5, 64),
    (1000, 64, 8, 10, 256),
    (555, 48, 4, 13, 128),     # non-divisible block
    (2048, 128, 16, 100, 512), # k large
])
def test_topk_matches_oracle(n, m, B, k, bn):
    D = _rand((n, m), jnp.float32)
    Q = _rand((B, m), jnp.float32)
    s1, i1 = ops.topk_score(D, Q, k=k, block_n=bn, interpret=True)
    s2, i2 = ref.topk_score_ref(D, Q, k=k)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)
    # discrete-boundary check: sets must match even if tie order differs
    for b in range(B):
        assert set(np.asarray(i1)[b].tolist()) == set(np.asarray(i2)[b].tolist())


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_topk_dtypes(dtype):
    D = _rand((400, 32), dtype)
    Q = _rand((4, 32), jnp.float32)
    s1, i1 = ops.topk_score(D, Q, k=10, block_n=128, interpret=True)
    s2, i2 = ref.topk_score_ref(D, Q, k=10)
    assert (np.asarray(i1) == np.asarray(i2)).mean() > 0.95


def test_topk_k_exceeding_block():
    # k larger than one block's rows: merge must span blocks correctly.
    D = _rand((96, 8), jnp.float32)
    Q = _rand((2, 8), jnp.float32)
    s1, i1 = ops.topk_score(D, Q, k=40, block_n=32, interpret=True)
    s2, i2 = ref.topk_score_ref(D, Q, k=40)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)
    assert (np.asarray(i1) == np.asarray(i2)).all()


def test_topk_sorted_descending():
    D = _rand((300, 16), jnp.float32)
    Q = _rand((3, 16), jnp.float32)
    s, _ = ops.topk_score(D, Q, k=20, block_n=64, interpret=True)
    s = np.asarray(s)
    assert (np.diff(s, axis=-1) <= 1e-6).all()


def test_topk_duplicate_scores_tiebreak():
    # identical rows => tied scores; ids must be the smallest ones (top_k semantics)
    row = RNG.standard_normal(16).astype(np.float32)
    D = jnp.asarray(np.tile(row, (64, 1)))
    Q = jnp.asarray(row[None, :])
    _, ids = ops.topk_score(D, Q, k=8, block_n=16, interpret=True)
    assert set(np.asarray(ids)[0].tolist()) == set(range(8))


def _quantized(D):
    from repro.core.quantization import quantize_int8_per_dim
    return quantize_int8_per_dim(D)


@pytest.mark.parametrize("B", [1, 8, 64])
@pytest.mark.parametrize("dtype", ["f32", "bf16", "int8"])
def test_topk_parity_scan_topk(dtype, B):
    """Kernel vs the jnp oracle on every index dtype, with block_b=16 so
    B=64 crosses the batch-tile boundary (and B=1 exercises tile padding)."""
    from repro.core.index import _scan_topk
    D = _rand((1000, 64), jnp.float32)
    Q = _rand((B, 64), jnp.float32)
    if dtype == "int8":
        D, scale = _quantized(D)
        Q = Q * scale[None, :]
    elif dtype == "bf16":
        D = D.astype(jnp.bfloat16)
    s1, i1 = ops.topk_score(D, Q, k=10, block_n=256, block_b=16,
                            interpret=True)
    s2, i2 = _scan_topk(D, Q, 10, block=256)
    assert (np.asarray(i1) == np.asarray(i2)).all()
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


def test_topk_batch_not_multiple_of_tile():
    from repro.core.index import _scan_topk
    D = _rand((500, 32), jnp.float32)
    Q = _rand((10, 32), jnp.float32)
    s1, i1 = ops.topk_score(D, Q, k=7, block_n=128, block_b=8, interpret=True)
    s2, i2 = _scan_topk(D, Q, 7, block=128)
    assert (np.asarray(i1) == np.asarray(i2)).all()


def test_topk_int8_streams_native():
    """The array handed to pallas_call must keep the index dtype — an int8
    corpus streams as int8, with no fp32 shadow copy at any size."""
    D, scale = _quantized(_rand((300, 32), jnp.float32))
    Q = _rand((4, 32), jnp.float32) * scale[None, :]

    def find_pallas_eqn(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                return eqn
        for sub in jax.core.subjaxprs(jaxpr):
            got = find_pallas_eqn(sub)
            if got is not None:
                return got
        return None

    jaxpr = jax.make_jaxpr(
        lambda d, q: ops.topk_score(d, q, k=5, interpret=True))(D, Q)
    eqn = find_pallas_eqn(jaxpr.jaxpr)
    assert eqn is not None
    in_dtypes = {str(v.aval.dtype) for v in eqn.invars}
    assert "int8" in in_dtypes
    # and no fp32 operand the size of the corpus anywhere in the trace
    corpus_elems = D.shape[0] * D.shape[1]
    for v in eqn.invars:
        if str(v.aval.dtype) == "float32":
            assert np.prod(v.aval.shape) < corpus_elems


def test_topk_all_tied_across_strips():
    """Every score identical over multiple strips: min-id tie-break must
    match jax.lax.top_k first-occurrence order exactly."""
    row = RNG.standard_normal(16).astype(np.float32)
    D = jnp.asarray(np.tile(row, (300, 1)))
    Q = jnp.asarray(np.stack([row, 2 * row]))
    s, ids = ops.topk_score(D, Q, k=9, block_n=64, interpret=True)
    _, want = ref.topk_score_ref(D, Q, k=9)
    assert (np.asarray(ids) == np.asarray(want)).all()
    assert (np.asarray(ids) == np.arange(9)[None, :]).all()


def test_topk_k_equals_n():
    D = _rand((96, 16), jnp.float32)
    Q = _rand((3, 16), jnp.float32)
    s1, i1 = ops.topk_score(D, Q, k=96, block_n=32, interpret=True)
    s2, i2 = ref.topk_score_ref(D, Q, k=96)
    assert (np.asarray(i1) == np.asarray(i2)).all()
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_topk_strip_entirely_padding():
    """n_valid cuts the corpus mid-array: the second strip is 100% masked
    (its max is -inf) and must be skipped without corrupting the running
    list; no id >= n_valid may surface."""
    from repro.core.index import _scan_topk
    D = _rand((128, 16), jnp.float32)
    Q = _rand((4, 16), jnp.float32)
    s1, i1 = ops.topk_score(D, Q, k=5, block_n=64, n_valid=64, interpret=True)
    s2, i2 = _scan_topk(D[:64], Q, 5, block=64)
    assert int(np.asarray(i1).max()) < 64
    assert (np.asarray(i1) == np.asarray(i2)).all()
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)


def test_topk_every_strip_skippable():
    """n_valid=0 masks every strip to -inf: the merge never runs and the
    finish step must still write the init state (-inf scores, -1 ids)."""
    D = _rand((256, 16), jnp.float32)
    Q = _rand((3, 16), jnp.float32)
    s, ids = ops.topk_score(D, Q, k=4, block_n=64, n_valid=0, interpret=True)
    assert (np.asarray(ids) == -1).all()
    assert np.isneginf(np.asarray(s)).all()


def test_topk_block_skip_guard_parity():
    """Top-k concentrated in the first strip: every later strip fails the
    guard (strip max < kth best) yet the result must equal the oracle —
    including when a later strip ties the kth best exactly (ascending id
    order means the tie loses anyway)."""
    base = RNG.standard_normal((256, 16)).astype(np.float32)
    base[:8] *= 100.0          # first strip dominates
    base[200] = base[7]        # exact tie with a kept row, larger id
    D = jnp.asarray(base)
    Q = jnp.asarray(base[:4] + 0.01 * RNG.standard_normal((4, 16))
                    .astype(np.float32))
    s1, i1 = ops.topk_score(D, Q, k=8, block_n=64, interpret=True)
    s2, i2 = ref.topk_score_ref(D, Q, k=8)
    assert (np.asarray(i1) == np.asarray(i2)).all()
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


def test_topk_rescore_nonascending_ids_tiebreak():
    """Regression (ROADMAP follow-up (a)): rescore mode with a deliberately
    NON-ascending shortlist. A tied score in a later strip carries a
    SMALLER doc id; the old skip-on-equality guard never merged that strip,
    surfacing the larger id and breaking the min-id tie-break. The guard
    now merges on equality whenever row_ids is present."""
    m = 16
    D = np.zeros((16, m), np.float32)
    D[:, 0] = np.linspace(0.5, 2.0, 16)   # background, all < 5
    D[3, 0] = 5.0     # strip 1 (rows 0-7): tied max, LARGER id
    D[11, 0] = 5.0    # strip 2 (rows 8-15): tied max, SMALLER id
    row_ids = np.asarray([20, 21, 22, 10, 24, 25, 26, 27,
                          28, 29, 30, 7, 32, 33, 34, 35], np.int32)
    Q = np.zeros((1, m), np.float32)
    Q[0, 0] = 1.0
    s, ids = ops.topk_score(jnp.asarray(D), jnp.asarray(Q), k=1, block_n=8,
                            interpret=True, row_ids=jnp.asarray(row_ids))
    assert float(np.asarray(s)[0, 0]) == 5.0
    assert int(np.asarray(ids)[0, 0]) == 7    # min id among the tied max


def test_topk_rescore_ascending_ids_unchanged():
    """The guard change must be invisible for the ascending shortlists the
    cascade actually emits: rescore-mode results still match the oracle."""
    D = _rand((200, 32), jnp.float32)
    Q = _rand((3, 32), jnp.float32)
    ids = jnp.arange(200, dtype=jnp.int32) + 1000       # ascending, offset
    s1, i1 = ops.topk_score(D, Q, k=7, block_n=64, interpret=True,
                            row_ids=ids)
    s2, i2 = ref.topk_score_ref(D, Q, k=7)
    assert (np.asarray(i1) == np.asarray(i2) + 1000).all()
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# pca_project (+ quant epilogue)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,m", [(100, 32, 8), (513, 96, 48), (1024, 128, 64)])
def test_project_matches_oracle(n, d, m):
    D = _rand((n, d), jnp.float32)
    W = _rand((d, m), jnp.float32)
    got = ops.pca_project(D, W, block_rows=128, interpret=True)
    want = ref.pca_project_ref(D, W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_project_quant_matches_oracle():
    D = _rand((500, 64), jnp.float32)
    W = _rand((64, 32), jnp.float32)
    t = np.asarray(ref.pca_project_ref(D, W))
    scale = jnp.asarray(np.abs(t).max(0) / 127.0)
    got = np.asarray(ops.pca_project_quant(D, W, scale, block_rows=128, interpret=True))
    want = np.asarray(ref.pca_project_quant_ref(D, W, scale))
    # rounding boundaries may flip +-1 ulp of int8 on a tiny fraction
    assert (got == want).mean() > 0.999
    assert np.abs(got.astype(np.int32) - want.astype(np.int32)).max() <= 1
    assert got.dtype == np.int8


def test_project_quant_roundtrip_error_bounded():
    D = _rand((400, 48), jnp.float32)
    W = np.linalg.qr(RNG.standard_normal((48, 48)))[0].astype(np.float32)
    W = jnp.asarray(W[:, :24])
    t = np.asarray(ref.pca_project_ref(D, W))
    scale = jnp.asarray(np.abs(t).max(0) / 127.0)
    q = np.asarray(ops.pca_project_quant(D, W, scale, interpret=True))
    rec = q.astype(np.float32) * np.asarray(scale)[None, :]
    rel = np.linalg.norm(rec - t) / np.linalg.norm(t)
    assert rel < 0.01  # int8 symmetric ~ <1% Frobenius error
