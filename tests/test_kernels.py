"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


# ---------------------------------------------------------------------------
# gram
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d", [(64, 16), (257, 64), (1000, 96), (1024, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_matches_oracle(n, d, dtype):
    D = _rand((n, d), dtype)
    got = ops.gram(D, block_rows=128, interpret=True)
    want = ref.gram_ref(D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 2e-3,
                               atol=1e-2)


def test_gram_block_size_invariance():
    D = _rand((500, 32), jnp.float32)
    a = ops.gram(D, block_rows=64, interpret=True)
    b = ops.gram(D, block_rows=500, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-3)


def test_gram_psd():
    D = _rand((300, 24), jnp.float32)
    G = np.asarray(ops.gram(D, interpret=True))
    evals = np.linalg.eigvalsh(G)
    assert evals.min() > -1e-3
    np.testing.assert_allclose(G, G.T, atol=1e-5)


# ---------------------------------------------------------------------------
# topk_score
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,B,k,bn", [
    (128, 16, 1, 5, 64),
    (1000, 64, 8, 10, 256),
    (555, 48, 4, 13, 128),     # non-divisible block
    (2048, 128, 16, 100, 512), # k large
])
def test_topk_matches_oracle(n, m, B, k, bn):
    D = _rand((n, m), jnp.float32)
    Q = _rand((B, m), jnp.float32)
    s1, i1 = ops.topk_score(D, Q, k=k, block_n=bn, interpret=True)
    s2, i2 = ref.topk_score_ref(D, Q, k=k)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)
    # discrete-boundary check: sets must match even if tie order differs
    for b in range(B):
        assert set(np.asarray(i1)[b].tolist()) == set(np.asarray(i2)[b].tolist())


@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_topk_dtypes(dtype):
    D = _rand((400, 32), dtype)
    Q = _rand((4, 32), jnp.float32)
    s1, i1 = ops.topk_score(D, Q, k=10, block_n=128, interpret=True)
    s2, i2 = ref.topk_score_ref(D, Q, k=10)
    assert (np.asarray(i1) == np.asarray(i2)).mean() > 0.95


def test_topk_k_exceeding_block():
    # k larger than one block's rows: merge must span blocks correctly.
    D = _rand((96, 8), jnp.float32)
    Q = _rand((2, 8), jnp.float32)
    s1, i1 = ops.topk_score(D, Q, k=40, block_n=32, interpret=True)
    s2, i2 = ref.topk_score_ref(D, Q, k=40)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4, atol=1e-4)
    assert (np.asarray(i1) == np.asarray(i2)).all()


def test_topk_sorted_descending():
    D = _rand((300, 16), jnp.float32)
    Q = _rand((3, 16), jnp.float32)
    s, _ = ops.topk_score(D, Q, k=20, block_n=64, interpret=True)
    s = np.asarray(s)
    assert (np.diff(s, axis=-1) <= 1e-6).all()


def test_topk_duplicate_scores_tiebreak():
    # identical rows => tied scores; ids must be the smallest ones (top_k semantics)
    row = RNG.standard_normal(16).astype(np.float32)
    D = jnp.asarray(np.tile(row, (64, 1)))
    Q = jnp.asarray(row[None, :])
    _, ids = ops.topk_score(D, Q, k=8, block_n=16, interpret=True)
    assert set(np.asarray(ids)[0].tolist()) == set(range(8))


# ---------------------------------------------------------------------------
# pca_project (+ quant epilogue)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,m", [(100, 32, 8), (513, 96, 48), (1024, 128, 64)])
def test_project_matches_oracle(n, d, m):
    D = _rand((n, d), jnp.float32)
    W = _rand((d, m), jnp.float32)
    got = ops.pca_project(D, W, block_rows=128, interpret=True)
    want = ref.pca_project_ref(D, W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_project_quant_matches_oracle():
    D = _rand((500, 64), jnp.float32)
    W = _rand((64, 32), jnp.float32)
    t = np.asarray(ref.pca_project_ref(D, W))
    scale = jnp.asarray(np.abs(t).max(0) / 127.0)
    got = np.asarray(ops.pca_project_quant(D, W, scale, block_rows=128, interpret=True))
    want = np.asarray(ref.pca_project_quant_ref(D, W, scale))
    # rounding boundaries may flip +-1 ulp of int8 on a tiny fraction
    assert (got == want).mean() > 0.999
    assert np.abs(got.astype(np.int32) - want.astype(np.int32)).max() <= 1
    assert got.dtype == np.int8


def test_project_quant_roundtrip_error_bounded():
    D = _rand((400, 48), jnp.float32)
    W = np.linalg.qr(RNG.standard_normal((48, 48)))[0].astype(np.float32)
    W = jnp.asarray(W[:, :24])
    t = np.asarray(ref.pca_project_ref(D, W))
    scale = jnp.asarray(np.abs(t).max(0) / 127.0)
    q = np.asarray(ops.pca_project_quant(D, W, scale, interpret=True))
    rec = q.astype(np.float32) * np.asarray(scale)[None, :]
    rel = np.linalg.norm(rec - t) / np.linalg.norm(t)
    assert rel < 0.01  # int8 symmetric ~ <1% Frobenius error
