"""Pipelined serving: reply/request integrity under concurrency, drain-on-
close, sync/pipelined bit-identity, and the no-busy-wait batching queue.

The server's correctness contract is scheduling-independent: whatever the
batch composition, in-flight depth, or arrival order, every reply must
carry exactly the submitting query's (scores, ids), and closing the server
must flush — never drop — accepted work.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DenseIndex, StaticPruner
from repro.launch.serve import BatchingQueue, RetrievalServer, _drive_open

RNG = np.random.default_rng(7)


def _unit_corpus(n=96, d=64):
    """Rows ~unit-norm and well separated: query = row i retrieves id i."""
    D = RNG.standard_normal((n, d)).astype(np.float32)
    D /= np.linalg.norm(D, axis=1, keepdims=True)
    return D


@pytest.fixture(scope="module")
def served():
    D = _unit_corpus()
    pruner = StaticPruner(cutoff=0.25).fit(jnp.asarray(D))
    index = DenseIndex.build(pruner.prune_index(jnp.asarray(D)))
    return D, pruner, index


# ---------------------------------------------------------------------------
# BatchingQueue
# ---------------------------------------------------------------------------


def test_batching_queue_coalesces_backlog():
    bq = BatchingQueue(max_batch=4, deadline_ms=50.0)
    replies = [bq.submit(np.full((3,), float(i), np.float32))
               for i in range(6)]
    vecs, reps = bq.next_batch(timeout=1.0)
    assert vecs.shape == (4, 3)               # capped at max_batch
    assert reps == replies[:4]                # FIFO order preserved
    vecs, reps = bq.next_batch(timeout=1.0)   # remainder flushes at deadline
    assert vecs.shape == (2, 3)
    assert (vecs[:, 0] == [4.0, 5.0]).all()


def test_batching_queue_deadline_flushes_partial():
    bq = BatchingQueue(max_batch=32, deadline_ms=5.0)
    bq.submit(np.zeros((2,), np.float32))
    t0 = time.perf_counter()
    item = bq.next_batch(timeout=1.0)
    took = time.perf_counter() - t0
    assert item is not None and item[0].shape == (1, 2)
    assert took < 0.5                         # deadline, not the full timeout


def test_batching_queue_want_full_holds_then_kick_releases():
    bq = BatchingQueue(max_batch=8, deadline_ms=1.0)
    busy = threading.Event()
    busy.set()
    bq.submit(np.zeros((2,), np.float32))
    got = []

    def collect():
        got.append(bq.next_batch(timeout=5.0, want_full=busy.is_set))

    th = threading.Thread(target=collect)
    th.start()
    time.sleep(0.15)
    assert not got                            # held: device "busy", not full
    busy.clear()
    bq.kick()                                 # device idle -> partial flushes
    th.join(timeout=5.0)
    assert got and got[0][0].shape == (1, 2)


def test_idle_server_burns_no_cpu(served):
    """Blocking condition-variable waits: an idle server must not spin.
    The old queue slept in 200 µs increments while collecting and woke
    every 0.5 s at idle; process CPU over an idle window must stay a small
    fraction of wall time."""
    D, pruner, index = served
    server = RetrievalServer(index, pruner, k=5, max_batch=8)
    try:
        server.query(D[0])                    # warm: compile outside window
        wall = 0.6
        c0 = time.process_time()
        time.sleep(wall)
        cpu = time.process_time() - c0
        assert cpu < 0.5 * wall, f"idle server used {cpu:.3f}s CPU in {wall}s"
    finally:
        server.close()


# ---------------------------------------------------------------------------
# RetrievalServer pipeline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth", [1, 3])
def test_replies_map_to_requests_under_concurrent_pressure(served, depth):
    """Many clients, shuffled arrival, batches interleaving in flight:
    reply r must answer query r (self-retrieval: query == doc row)."""
    D, pruner, index = served
    server = RetrievalServer(index, pruner, k=1, max_batch=8,
                             pipeline_depth=depth)
    n = len(D)
    order = RNG.permutation(np.arange(n).repeat(3))     # 288 requests
    hits = np.zeros(len(order), dtype=bool)

    def client(slot, doc_id):
        _, ids = server.query(D[doc_id], timeout=30.0)
        hits[slot] = (ids[0] == doc_id)

    try:
        threads = [threading.Thread(target=client, args=(s, int(i)))
                   for s, i in enumerate(order)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60.0)
        assert hits.all(), f"{(~hits).sum()} replies answered the wrong query"
    finally:
        server.close()


def test_close_drains_inflight_without_dropping(served):
    D, pruner, index = served
    server = RetrievalServer(index, pruner, k=1, max_batch=8,
                             pipeline_depth=3)
    server.query(D[0])                        # compile before the burst
    replies = [server.submit(D[i % len(D)]) for i in range(100)]
    server.close()                            # must flush, not drop
    for i, r in enumerate(replies):
        _, ids = r.get(timeout=5.0)
        assert ids[0] == i % len(D)


def test_sync_and_pipelined_results_bit_identical(served):
    """Same queries through depth=1 and depth=3 servers (arbitrary batch
    compositions): every (scores, ids) reply must agree bit-exactly —
    scheduling may change throughput, never results."""
    D, pruner, index = served
    Q = np.repeat(D, 2, axis=0)
    outs = []
    for depth in (1, 3):
        server = RetrievalServer(index, pruner, k=5, max_batch=8,
                                 pipeline_depth=depth)
        try:
            res = _drive_open(server, Q, rate=4000.0, collect=True)
        finally:
            server.close()
        outs.append(res["results"])
    for (s0, i0), (s1, i1) in zip(*outs):
        assert (np.asarray(i0) == np.asarray(i1)).all()
        assert (np.asarray(s0) == np.asarray(s1)).all()


def test_open_loop_driver_reports(served):
    D, pruner, index = served
    server = RetrievalServer(index, pruner, k=3, max_batch=8)
    try:
        res = _drive_open(server, D[:48], rate=2000.0)
    finally:
        server.close()
    assert res["n"] == 48
    assert res["achieved_qps"] > 0
    assert res["p50_ms"] <= res["p95_ms"] <= res["p99_ms"]
    stats = server.worker_stats()
    assert stats["batches"] >= 1
    assert 0 < stats["occupancy"] <= 1.0


def test_bucketed_batches_map_and_results(served):
    """Batch-shape bucketing: partial batches pad to the next bucket in
    {8, 16, ..., max_batch}, and the returned ids match the pad-to-max
    server exactly (pad rows are inert; only the compiled shape differs)."""
    D, pruner, index = served
    server = RetrievalServer(index, pruner, k=5, max_batch=32,
                             pipeline_depth=1, bucket_batches=True)
    ref = RetrievalServer(index, pruner, k=5, max_batch=32,
                          pipeline_depth=1, bucket_batches=False)
    try:
        assert server._buckets == (8, 16, 32)
        assert [server._bucket_for(b) for b in (1, 8, 9, 16, 17, 32)] \
            == [8, 8, 16, 16, 32, 32]
        server.warmup()                        # compiles every bucket shape
        for i in range(24):
            _, ids_b = server.query(D[i])
            _, ids_r = ref.query(D[i])
            assert (np.asarray(ids_b) == np.asarray(ids_r)).all()
    finally:
        server.close()
        ref.close()


def test_pipeline_overlaps_batches_in_flight(served):
    """Under a saturating open-loop burst the stager must run ahead of the
    completer: with depth 3 the worker log shows batches whose dispatch
    happened before the previous batch finished."""
    D, pruner, index = served
    server = RetrievalServer(index, pruner, k=1, max_batch=4,
                             pipeline_depth=3)
    try:
        _drive_open(server, np.repeat(D, 2, axis=0), rate=1e5)
        log = sorted(server.batch_log, key=lambda b: b[1])
        overlapped = sum(1 for a, b in zip(log, log[1:]) if b[1] < a[2])
        assert len(log) >= 2
        assert overlapped > 0, "no batch was staged while another ran"
    finally:
        server.close()


# ---------------------------------------------------------------------------
# Lock-discipline regressions (crop of `python -m repro.analysis` findings:
# unguarded index/_proj snapshots, unlocked batch_log, bare _items read)
# ---------------------------------------------------------------------------


def test_reset_stats_clears_worker_log(served):
    D, pruner, index = served
    server = RetrievalServer(index, pruner, k=1, max_batch=4)
    try:
        server.query(D[0])
        assert server.worker_stats()["batches"] >= 1
        server.reset_stats()
        assert server.worker_stats()["batches"] == 0
    finally:
        server.close()


def test_worker_stats_safe_while_completer_appends(served):
    """worker_stats() snapshots batch_log under its lock: polling it from
    another thread mid-drive must never raise or observe a torn log row
    (the completer appends concurrently)."""
    D, pruner, index = served
    server = RetrievalServer(index, pruner, k=1, max_batch=4,
                             pipeline_depth=3)
    errs, counts = [], []
    stop = threading.Event()

    def poll():
        try:
            while not stop.is_set():
                s = server.worker_stats()
                assert s["batches"] >= 0 and s["mean_batch"] >= 0.0
                counts.append(s["batches"])
        except BaseException as e:  # noqa: BLE001 — must fail the test
            errs.append(e)

    th = threading.Thread(target=poll)
    th.start()
    try:
        _drive_open(server, np.repeat(D, 2, axis=0), rate=1e5)
    finally:
        stop.set()
        th.join(timeout=10.0)
        server.close()
    assert not errs
    assert counts == sorted(counts)    # log only grows between resets


def test_submit_validation_tracks_index_swap(served):
    """submit() reads (index, projection) as ONE locked snapshot: after a
    swap that drops the pruner, validation must follow the new state."""
    D, pruner, index = served
    d_raw = D.shape[1]
    m = index.dim
    assert m < d_raw
    server = RetrievalServer(index, pruner, k=1, max_batch=4)
    try:
        server.query(D[0])                       # raw-dim queries accepted
        server.swap_index(index, pruner=None)    # now serves projected dim
        with pytest.raises(ValueError, match=str(m)):
            server.submit(D[0])
        scores, ids = server.query(np.zeros((m,), np.float32))
        assert ids.shape == (1,)
    finally:
        server.close()


def test_batching_queue_empty_tracks_submit_and_drain():
    bq = BatchingQueue(max_batch=4)
    assert bq.empty()
    bq.submit(np.zeros((2,), np.float32))
    assert not bq.empty()
    assert len(bq.drain()) == 1
    assert bq.empty()


# ---------------------------------------------------------------------------
# deadlines: completer-side expiry of overdue queued work
# ---------------------------------------------------------------------------


def test_reply_resolve_first_writer_wins():
    """A reply racing its own expiry must deliver exactly one payload:
    later writers are no-ops and the completion stamp is the winner's."""
    from repro.launch.serve import Reply, TimedOut
    r = Reply(deadline=None)
    assert r.resolve(("scores", "ids"), 1.5)
    assert not r.resolve(TimedOut("late expiry"), 9.9)
    assert r.get(timeout=1.0) == ("scores", "ids")
    assert r.completed_at == 1.5
    assert r.empty()                  # exactly one payload ever posted


def test_deadline_noop_on_fast_path(served):
    """A generous deadline must not perturb a healthy request."""
    D, pruner, index = served
    server = RetrievalServer(index, pruner, k=1, max_batch=4)
    try:
        scores, ids = server.query(D[3], deadline=30.0)
        assert int(np.asarray(ids)[0]) == 3
    finally:
        server.close()


def test_deadline_expires_overdue_work_behind_hung_dispatch(served):
    """A hung dispatch must NOT park deadline-carrying clients forever:
    the completer sweep resolves them with an explicit TimedOut while the
    batch is still stuck, and the server recovers once the hang clears."""
    from repro.launch.serve import TimedOut
    from repro.serving.fleet import FaultableIndex

    D, pruner, index = served
    faultable = FaultableIndex(index)
    server = RetrievalServer(faultable, pruner, k=1, max_batch=4)
    try:
        server.query(D[0])                       # warm/compile first
        faultable.state.inject("hang")
        t0 = time.perf_counter()
        reply = server.submit(D[1], deadline=0.3)
        out = reply.get(timeout=30.0)
        took = time.perf_counter() - t0
        assert isinstance(out, TimedOut)
        assert took < 5.0, f"expiry took {took:.1f}s for a 0.3s deadline"
        # un-hang: the stuck batch completes, its late result is a no-op
        # (first-writer-wins), and fresh queries serve normally again
        faultable.state.clear()
        scores, ids = server.query(D[2], timeout=30.0)
        assert int(np.asarray(ids)[0]) == 2
        assert server.error is None
    finally:
        faultable.state.clear()
        server.close()


def test_deadline_expired_before_batch_never_wastes_dispatch(served):
    """Already-expired work must resolve TimedOut without requiring the
    worker to execute it (queued behind a hang, deadline long past)."""
    from repro.launch.serve import TimedOut
    from repro.serving.fleet import FaultableIndex

    D, pruner, index = served
    faultable = FaultableIndex(index)
    server = RetrievalServer(faultable, pruner, k=1, max_batch=2)
    try:
        server.query(D[0])
        faultable.state.inject("hang")
        server.submit(D[1])                      # wedges the worker
        replies = [server.submit(D[i], deadline=0.2) for i in (2, 3, 4)]
        outs = [r.get(timeout=30.0) for r in replies]
        assert all(isinstance(o, TimedOut) for o in outs)
    finally:
        faultable.state.clear()
        server.close()
