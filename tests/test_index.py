"""DenseIndex / ShardedDenseIndex / int8 quantisation."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DenseIndex, ShardedDenseIndex
from repro.core.quantization import dequantize_int8, quantization_error, quantize_int8_per_dim

RNG = np.random.default_rng(7)


def _data(n=2000, d=64):
    D = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
    Q = jnp.asarray(RNG.standard_normal((9, d)), jnp.float32)
    return D, Q


def test_exact_search_matches_bruteforce():
    D, Q = _data()
    idx = DenseIndex.build(D)
    s, ids = idx.search(Q, k=10, block=300)
    brute = np.asarray(Q) @ np.asarray(D).T
    want_ids = np.argsort(-brute, axis=1)[:, :10]
    assert (np.asarray(ids) == want_ids).all()
    np.testing.assert_allclose(np.asarray(s),
                               np.take_along_axis(brute, want_ids, 1),
                               rtol=1e-4, atol=1e-4)


def test_block_size_invariance():
    D, Q = _data(777)
    a = DenseIndex.build(D).search(Q, k=7, block=100)
    b = DenseIndex.build(D).search(Q, k=7, block=7777)
    assert (np.asarray(a[1]) == np.asarray(b[1])).all()


def test_pallas_backend_matches_jnp():
    D, Q = _data(500, 32)
    a = DenseIndex.build(D, backend="jnp").search(Q, k=10)
    b = DenseIndex.build(D, backend="pallas").search(Q, k=10)
    for x in range(Q.shape[0]):
        assert set(np.asarray(a[1])[x].tolist()) == set(np.asarray(b[1])[x].tolist())


def test_pallas_backend_honours_block():
    """``block`` used to be silently dropped on the pallas backend — a
    non-default block must reach the kernel and preserve exact results."""
    D, Q = _data(700, 32)
    idx = DenseIndex.build(D, backend="pallas")
    s_def, i_def = idx.search(Q, k=10)
    s_blk, i_blk = idx.search(Q, k=10, block=256)   # non-default block_n
    assert (np.asarray(i_def) == np.asarray(i_blk)).all()
    np.testing.assert_allclose(np.asarray(s_def), np.asarray(s_blk),
                               rtol=1e-5, atol=1e-5)
    # and both match the jnp oracle at another non-default block
    _, want = DenseIndex.build(D).search(Q, k=10, block=130)
    assert (np.asarray(i_blk) == np.asarray(want)).all()


def test_int8_index_recall():
    D, Q = _data(3000, 64)
    full = DenseIndex.build(D)
    q8 = DenseIndex.build(D, quantize_int8=True)
    assert q8.nbytes < full.nbytes / 3.5
    _, ids_f = full.search(Q, k=10)
    _, ids_q = q8.search(Q, k=10)
    # int8 keeps high top-10 overlap
    overlap = np.mean([len(set(np.asarray(ids_f)[i]) & set(np.asarray(ids_q)[i])) / 10
                       for i in range(Q.shape[0])])
    assert overlap > 0.8


def test_quantization_roundtrip_error_small():
    D, _ = _data(1000, 32)
    assert float(quantization_error(D)) < 0.01
    q, s = quantize_int8_per_dim(D)
    assert q.dtype == jnp.int8
    rec = dequantize_int8(q, s)
    assert float(jnp.abs(rec - D).max()) < float(jnp.abs(D).max()) * 0.02


def test_sharded_index_single_device_mesh():
    # 1-device mesh exercises the shard_map merge path end to end
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    D, Q = _data(1024, 32)
    sidx = ShardedDenseIndex.build(D, mesh)
    s, ids = sidx.search(Q, k=10)
    _, want = DenseIndex.build(D).search(Q, k=10)
    assert (np.asarray(ids) == np.asarray(want)).all()


def test_sharded_index_pads_uneven_rows():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    D, Q = _data(1000, 16)   # 1000 rows, any padding must not surface
    sidx = ShardedDenseIndex.build(D, mesh)
    s, ids = sidx.search(Q, k=5)
    assert int(ids.max()) < 1000
