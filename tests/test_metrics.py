"""IR metric correctness against hand-computed values."""
import numpy as np
import pytest

from repro.core.metrics import (
    average_precision,
    dcg,
    evaluate_run,
    mean_metrics,
    mrr_at_k,
    ndcg_at_k,
    recall_at_k,
    wilcoxon_significant,
)


def test_dcg_hand_computed():
    # grades [3, 2, 0]: (2^3-1)/log2(2) + (2^2-1)/log2(3) + 0
    g = np.array([3.0, 2.0, 0.0])
    want = 7.0 / 1.0 + 3.0 / np.log2(3.0)
    assert dcg(g) == pytest.approx(want)


def test_ndcg_perfect_ranking_is_one():
    qrel = {1: 3, 2: 2, 3: 1}
    assert ndcg_at_k([1, 2, 3], qrel, k=10) == pytest.approx(1.0)


def test_ndcg_worst_ranking_below_one():
    qrel = {1: 3, 2: 2, 3: 1, 7: 0}
    assert ndcg_at_k([7, 3, 2, 1], qrel, k=10) < 1.0


def test_average_precision_hand_computed():
    # relevant docs: 1, 3; ranking [1, 2, 3] -> (1/1 + 2/3)/2
    qrel = {1: 1, 3: 1}
    assert average_precision([1, 2, 3], qrel) == pytest.approx((1 + 2 / 3) / 2)


def test_mrr():
    qrel = {5: 1}
    assert mrr_at_k([9, 8, 5], qrel, k=10) == pytest.approx(1 / 3)
    assert mrr_at_k([9, 8, 7], qrel, k=3) == 0.0


def test_recall():
    qrel = {1: 1, 2: 1, 3: 1, 4: 1}
    assert recall_at_k([1, 2, 9, 9, 9], qrel, k=5) == pytest.approx(0.5)


def test_evaluate_run_missing_query_scores_zero():
    qrels = {0: {1: 1}, 1: {2: 1}}
    run = {0: [1]}
    pq = evaluate_run(run, qrels)
    assert pq["nDCG@10"][0] == pytest.approx(1.0)
    assert pq["nDCG@10"][1] == 0.0
    m = mean_metrics(pq)
    assert m["nDCG@10"] == pytest.approx(0.5)


def test_wilcoxon_identical_not_significant():
    a = np.array([0.5, 0.6, 0.7, 0.4] * 5)
    sig, p = wilcoxon_significant(a, a.copy())
    assert not sig and p == 1.0


def test_wilcoxon_detects_consistent_drop():
    rng = np.random.default_rng(0)
    a = rng.uniform(0.4, 0.9, 50)
    b = a - 0.05 + rng.normal(0, 0.005, 50)
    sig, p = wilcoxon_significant(a, b)
    assert sig and p < 0.01


def test_wilcoxon_noise_not_significant():
    rng = np.random.default_rng(1)
    a = rng.uniform(0.4, 0.9, 30)
    b = a + rng.normal(0, 0.01, 30)  # symmetric noise
    sig, p = wilcoxon_significant(a, b)
    assert p > 0.01 or not sig
