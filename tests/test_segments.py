"""Segmented live index: parity, mixed scales, swap-under-load, layout.

The acceptance surface of the segment architecture:
  * ``SegmentedIndex.search`` is BIT-IDENTICAL to a monolithic index built
    from the concatenated corpus when every segment shares one scale —
    dense and sharded base, f32 and int8, jnp and pallas backends;
  * with mixed per-segment scales, ids/ordering exactly match an f32
    oracle over the per-segment dequantised vectors;
  * appends never clip (per-delta scales widen) and never recompile in
    steady state (fixed-capacity dispatch, jit-cache-size pinned);
  * a pre-segment artifact opens as a single base segment (backward
    compat) and a segmented artifact round-trips losslessly;
  * ``RetrievalServer.swap_index`` under live append+query load drops no
    reply and never serves from a half-swapped segment set.
"""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DeltaSegment,
    DenseIndex,
    IndexStore,
    IndexStoreError,
    SegmentedIndex,
    ShardedDenseIndex,
    StaticPruner,
    save_index,
)
from repro.core.index import segment_jit_cache_sizes
from repro.core.maintenance import IndexUpdater
from repro.core.quantization import quantize_int8_per_dim

RNG = np.random.default_rng(17)


def _corpus(n=1003, d=48, seed=3, domain_seed=None):
    from repro.data.synthetic import make_corpus
    D, _ = make_corpus("tasb", n_docs=n, d=d, seed=seed,
                       domain_seed=domain_seed)
    return np.asarray(D, np.float32)


def _queries(d=48, nq=7):
    return jnp.asarray(RNG.standard_normal((nq, d)), jnp.float32)


def _mesh(ndev):
    if jax.device_count() < ndev:
        pytest.skip(f"needs {ndev} devices, have {jax.device_count()}")
    return jax.make_mesh((ndev,), ("data",))


def _shared_scale_segmented(D, splits, *, quantize, backend="jnp",
                            mesh=None, capacity=256):
    """Segment a corpus at ``splits`` with ONE shared scale (the parity
    construction: same quantised bytes as the monolithic index)."""
    if quantize:
        q8, scale = quantize_int8_per_dim(jnp.asarray(D))
        stored = np.asarray(q8)
        raw = stored.astype(np.float32) * np.asarray(scale)[None, :]
    else:
        stored, scale = np.asarray(D, np.float32), None
        raw = stored
    lo = splits[0]
    if mesh is not None:
        base = ShardedDenseIndex(
            vectors=jax.device_put(
                jnp.asarray(_pad_rows(stored[:lo], mesh)),
                jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec(("data",), None))),
            mesh=mesh, scale=scale, backend=backend, n_real=lo)
    else:
        base = DenseIndex(vectors=jnp.asarray(stored[:lo]), scale=scale,
                          backend=backend)
    deltas = []
    bounds = list(splits) + [len(D)]
    for a, b in zip(bounds, bounds[1:]):
        seg = np.zeros((capacity, D.shape[1]), stored.dtype)
        seg[:b - a] = stored[a:b]
        deltas.append(DeltaSegment(vectors=jnp.asarray(seg), n_real=b - a,
                                   scale=scale, raw=raw[a:b]))
    return SegmentedIndex(base=base, deltas=tuple(deltas),
                          delta_capacity=capacity)


def _pad_rows(v, mesh):
    ndev = int(np.prod(mesh.devices.shape))
    pad = (-v.shape[0]) % ndev
    return np.concatenate([v, np.zeros((pad, v.shape[1]), v.dtype)]) \
        if pad else v


# ---------------------------------------------------------------------------
# parity: segmented == monolithic, bit for bit, when scales agree
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("quantize", [False, True])
def test_dense_parity_bit_identical(backend, quantize):
    D = _corpus(703, 32)
    Q = _queries(32)
    seg = _shared_scale_segmented(D, (500, 650), quantize=quantize,
                                  backend=backend)
    if quantize:
        mono = DenseIndex(vectors=jnp.asarray(
            np.concatenate([np.asarray(seg.base.vectors)]
                           + [np.asarray(d.vectors[:d.n_real])
                              for d in seg.deltas])),
            scale=seg.base.scale, backend=backend)
    else:
        mono = DenseIndex.build(jnp.asarray(D), backend=backend)
    s0, i0 = mono.search(Q, k=10)
    s1, i1 = seg.search(Q, k=10)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


@pytest.mark.parametrize("ndev", [1, 4])
@pytest.mark.parametrize("quantize", [False, True])
def test_sharded_base_parity_bit_identical(ndev, quantize):
    """Sharded base + dense deltas vs a fully-sharded monolithic index —
    uneven rows, so device padding and delta padding coexist."""
    mesh = _mesh(ndev)
    D = _corpus(1003, 32)
    Q = _queries(32)
    seg = _shared_scale_segmented(D, (801, 950), quantize=quantize, mesh=mesh)
    if quantize:
        q8, scale = quantize_int8_per_dim(jnp.asarray(D))
        mono = ShardedDenseIndex(
            vectors=jax.device_put(
                jnp.asarray(_pad_rows(np.asarray(q8), mesh)),
                jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec(("data",), None))),
            mesh=mesh, scale=scale, n_real=D.shape[0])
    else:
        mono = ShardedDenseIndex.build(jnp.asarray(D), mesh)
    s0, i0 = mono.search(Q, k=10)
    s1, i1 = seg.search(Q, k=10)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_search_projected_parity_bit_identical():
    """Raw-query path: shared projection + per-segment fold must equal the
    monolithic fused search_projected dispatch bit-for-bit."""
    D = _corpus(703, 32)
    pruner = StaticPruner(cutoff=0.5).fit(jnp.asarray(D))
    Dh = np.asarray(pruner.prune_index(jnp.asarray(D)), np.float32)
    Q = _queries(32)
    W, mean = pruner.projection()
    seg = _shared_scale_segmented(Dh, (500, 650), quantize=True)
    mono = DenseIndex(vectors=jnp.asarray(np.concatenate(
        [np.asarray(seg.base.vectors)]
        + [np.asarray(d.vectors[:d.n_real]) for d in seg.deltas])),
        scale=seg.base.scale)
    s0, i0 = mono.search_projected(Q, W, k=10, mean=mean)
    s1, i1 = seg.search_projected(Q, W, k=10, mean=mean)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_mixed_scale_ids_match_f32_oracle():
    """Per-segment scales (an OOD append widened the delta's): ids and
    ordering must exactly match exact f32 search over the per-segment
    DEQUANTISED vectors — strict correctness, not best-effort."""
    D = _corpus(600, 32)
    base8, base_scale = quantize_int8_per_dim(jnp.asarray(D))
    base = DenseIndex(vectors=base8, scale=base_scale)
    seg = SegmentedIndex.from_index(base, delta_capacity=128)
    ood = np.concatenate([_corpus(80, 32, seed=9) * 12.0,
                          _corpus(40, 32, seed=11)])
    seg = seg.append(ood)
    assert len(seg.deltas) == 1
    assert not np.array_equal(np.asarray(seg.deltas[0].scale),
                              np.asarray(base_scale))
    # oracle: dequantise every segment with ITS scale, exact f32 search
    dq = [np.asarray(base8, np.float32) * np.asarray(base_scale)[None, :]]
    for d in seg.deltas:
        dq.append(np.asarray(d.vectors[:d.n_real], np.float32)
                  * np.asarray(d.scale)[None, :])
    oracle = DenseIndex.build(jnp.asarray(np.concatenate(dq)))
    Q = _queries(32)
    so, io = oracle.search(Q, k=10)
    s1, i1 = seg.search(Q, k=10)
    np.testing.assert_array_equal(np.asarray(io), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(so), np.asarray(s1),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# growth: rollover, widening, no clipping, no recompiles
# ---------------------------------------------------------------------------


def test_append_rollover_and_global_ids():
    D = _corpus(500, 24)
    seg = SegmentedIndex.from_index(DenseIndex.build(jnp.asarray(D)),
                                    delta_capacity=100)
    extra = _corpus(750, 24, seed=5)[500:]
    seg = seg.append(extra)
    assert seg.n == 750
    assert len(seg.deltas) == 3                 # 100 + 100 + 50
    assert [d.n_real for d in seg.deltas] == [100, 100, 50]
    for gid in (500, 601, 749):
        _, ids = seg.search(jnp.asarray(extra[gid - 500][None, :]), k=5)
        assert gid in np.asarray(ids)[0].tolist()


def test_ood_append_widens_scale_never_clips():
    """The frozen-scale clip problem, killed at the root: a 50x OOD append
    lands with a widened per-delta scale; every stored value round-trips
    within half an LSB of its f32 source — nothing saturates."""
    D = _corpus(400, 24)
    up = IndexUpdater.build(jnp.asarray(D), cutoff=0.5, quantize_int8=True,
                            delta_capacity=256)
    in_dom = _corpus(500, 24, domain_seed=5)[400:480]   # same encoder basis
    up.add_documents(jnp.asarray(in_dom))
    scale0 = np.asarray(up.index.deltas[0].scale)
    up.add_documents(50.0 * jnp.asarray(in_dom[:40]))
    d = up.index.deltas[0]
    scale1 = np.asarray(d.scale)
    assert (scale1 >= scale0).all() and (scale1 > scale0).any()
    stored = np.asarray(d.vectors[:d.n_real], np.float32)
    err = np.abs(stored * scale1[None, :] - d.raw)
    assert (err <= scale1[None, :] / 2 + 1e-7).all(), \
        "a stored value clipped instead of the scale widening"
    assert up.clip_fraction == 0.0
    assert up.scale_divergence() > 4.0
    assert up.needs_refit(jnp.asarray(in_dom))    # scale policy trips
    # drift alone would not have caught it (energy ratio is scale-invariant)
    assert up.drift_score(50.0 * jnp.asarray(in_dom[:40])) > 0.8


def test_steady_state_appends_do_not_recompile():
    """Fixed-capacity dispatch contract: once the segment shapes are warm,
    appends (any live count) add ZERO jit cache entries."""
    D = _corpus(300, 24)
    pruner = StaticPruner(cutoff=0.5).fit(jnp.asarray(D))
    seg = SegmentedIndex.from_index(
        pruner.build_index(jnp.asarray(D), quantize_int8=True),
        delta_capacity=512)
    W, mean = pruner.projection()
    # warm: open the delta, then extend once at the steady-state block
    # size with rows that provably cannot widen the scale (0.5x rows
    # already in the delta) — both extend paths (widen = plain host
    # requant+upload, non-widen = the update-slice jit) are then warm
    warm = np.asarray(pruner.prune_index(
        jnp.asarray(_corpus(20, 24, seed=7))), np.float32)
    seg = seg.append(warm)
    seg = seg.append(0.5 * warm[:15])
    jax.block_until_ready(
        seg.search_projected(jnp.asarray(_queries(24, 4)), W, k=5,
                             mean=mean))
    j0 = segment_jit_cache_sizes()
    for i in range(6):
        seg = seg.append(np.asarray(pruner.prune_index(
            jnp.asarray(_corpus(15, 24, seed=20 + i))), np.float32))
        jax.block_until_ready(
            seg.search_projected(jnp.asarray(_queries(24, 4)), W, k=5,
                                 mean=mean))
    assert segment_jit_cache_sizes() == j0, \
        "an append recompiled the steady-state search path"


# ---------------------------------------------------------------------------
# store layout: backward compat + segmented round trip
# ---------------------------------------------------------------------------


def test_pre_segment_artifact_opens_as_single_base(tmp_path):
    """Backward compat: an artifact written before segments exist reads as
    one base segment, and SegmentedIndex.load serves it bit-identically to
    the flat loader."""
    D = _corpus(500, 32)
    pruner = StaticPruner(cutoff=0.5).fit(jnp.asarray(D))
    idx = pruner.build_index(jnp.asarray(D), quantize_int8=True)
    store = save_index(str(tmp_path / "st"), idx, pruner=pruner)
    assert not store.is_segmented
    views = store.segments()
    assert len(views) == 1 and views[0].kind == "base"
    assert views[0].n == store.n and views[0].offset == 0
    seg = SegmentedIndex.load(store)
    flat = DenseIndex.load(IndexStore.open(store.path))
    Q = _queries(32)
    qh = pruner.transform_queries(Q)
    s0, i0 = flat.search(qh, k=10)
    s1, i1 = seg.search(qh, k=10)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


@pytest.mark.parametrize("quantize", [False, True])
def test_segmented_store_round_trip(tmp_path, quantize):
    """save -> open -> load: per-segment scales, capacities, and search
    results survive; the manifest's global view stays validation-clean."""
    D = _corpus(600, 32)
    pruner = StaticPruner(cutoff=0.5).fit(jnp.asarray(D))
    seg = SegmentedIndex.from_index(
        pruner.build_index(jnp.asarray(D), quantize_int8=quantize),
        delta_capacity=128)
    extra = _corpus(800, 32, seed=5)[600:]
    seg = seg.append(np.asarray(pruner.prune_index(jnp.asarray(extra)),
                                np.float32))
    store = save_index(str(tmp_path / "st"), seg, pruner=pruner)
    re = IndexStore.open(store.path)            # fresh open: full validation
    assert re.is_segmented and re.n == 800
    assert [v.kind for v in re.segments()] == ["base", "delta", "delta"]
    assert re.segments()[1].capacity == 128
    loaded = SegmentedIndex.load(re)
    assert loaded.n == seg.n
    Q = _queries(32)
    qh = pruner.transform_queries(Q)
    s0, i0 = seg.search(qh, k=10)
    s1, i1 = loaded.search(qh, k=10)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_mixed_scale_store_refuses_flat_load(tmp_path):
    D = _corpus(300, 24)
    up = IndexUpdater.build(jnp.asarray(D), cutoff=0.5, quantize_int8=True,
                            store_path=str(tmp_path / "st"),
                            delta_capacity=128)
    up.add_documents(9.0 * jnp.asarray(_corpus(40, 24, seed=5)))
    st = IndexStore.open(str(tmp_path / "st"))
    assert not st.flat_loadable
    with pytest.raises(IndexStoreError, match="SegmentedIndex.load"):
        DenseIndex.load(st)
    mesh = _mesh(1)
    with pytest.raises(IndexStoreError, match="SegmentedIndex.load"):
        ShardedDenseIndex.load(st, mesh)


def test_updater_store_mirror_is_bit_identical(tmp_path):
    """Disk and memory never diverge: after appends (including a widening
    rewrite), the stored delta bytes equal the served delta bytes."""
    D = _corpus(400, 24)
    up = IndexUpdater.build(jnp.asarray(D), cutoff=0.5, quantize_int8=True,
                            store_path=str(tmp_path / "st"),
                            delta_capacity=256)
    up.add_documents(jnp.asarray(_corpus(60, 24, seed=5)))
    up.add_documents(30.0 * jnp.asarray(_corpus(30, 24, seed=6)))  # widen
    up.add_documents(jnp.asarray(_corpus(20, 24, seed=7)))
    st = IndexStore.open(str(tmp_path / "st"))
    views = st.segments()
    assert len(views) == 1 + len(up.index.deltas)
    for v, d in zip(views[1:], up.index.deltas):
        np.testing.assert_array_equal(v.read_rows(0, v.n),
                                      np.asarray(d.vectors[:d.n_real]))
        np.testing.assert_array_equal(v.scale(), np.asarray(d.scale))
    # and a cold start reproduces the exact same search results
    up2 = IndexUpdater.from_store(str(tmp_path / "st"))
    Q = _queries(24)
    s0, i0 = up.search(Q, k=10)
    s1, i1 = up2.search(Q, k=10)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))


def test_store_append_migrating_widen(tmp_path):
    """Store-level scale migration (no f32 staging available): an append
    that would clip widens the segment scale and requantises its chunks —
    bounded by the segment, within half an old LSB of exact."""
    D = _corpus(300, 16)
    pruner = StaticPruner(cutoff=0.5).fit(jnp.asarray(D))
    idx = pruner.build_index(jnp.asarray(D), quantize_int8=True)
    st = save_index(str(tmp_path / "st"), idx, pruner=pruner)
    name = st.add_delta(scale=np.full((8,), 0.01, np.float32), capacity=4096)
    st.append_migrating(np.full((4, 8), 0.5, np.float32))       # fits
    before = IndexStore.open(st.path).segments()[1].scale()
    widened = st.append_migrating(np.full((3, 8), 7.0, np.float32))
    assert widened
    re = IndexStore.open(st.path)
    v = re.segments()[1]
    assert v.n == 7
    after = v.scale()
    assert (after >= before).all() and (after > before).any()
    vals = v.read_rows(0, 7).astype(np.float32) * after[None, :]
    np.testing.assert_allclose(vals[:4], 0.5, atol=float(after.max()))
    np.testing.assert_allclose(vals[4:], 7.0, atol=float(after.max()) / 2)
    # base untouched by the delta migration
    np.testing.assert_array_equal(re.segments()[0].scale(),
                                  np.asarray(idx.scale))


def test_store_append_migrating_base_segment(tmp_path):
    """Regression: widening the BASE segment's scale (pre-segment store,
    the unbounded-rewrite case segmenting exists to avoid) must keep the
    top-level manifest's scale_file in sync with the base entry — the old
    blob is deleted by the rewrite, and a stale pointer would make the
    store permanently unopenable."""
    D = _corpus(300, 16)
    pruner = StaticPruner(cutoff=0.5).fit(jnp.asarray(D))
    idx = pruner.build_index(jnp.asarray(D), quantize_int8=True)
    st = save_index(str(tmp_path / "st"), idx, pruner=pruner)
    scale0 = np.asarray(idx.scale)
    widened = st.append_migrating(
        50.0 * np.asarray(pruner.prune_index(jnp.asarray(D[:5])), np.float32))
    assert widened
    re = IndexStore.open(st.path)          # must validate cleanly
    assert re.n == 305
    base = re.segments()[0]
    assert (base.scale() >= scale0).all() and (base.scale() > scale0).any()
    np.testing.assert_array_equal(np.load(
        os.path.join(re.path, re.manifest["scale_file"])), base.scale())
    # still servable end to end
    loaded = SegmentedIndex.load(re)
    _, ids = loaded.search(pruner.transform_queries(_queries(16)), k=5)
    assert np.asarray(ids).max() < 305


def test_replace_segment_crash_orphans_ignored(tmp_path):
    D = _corpus(200, 16)
    st = save_index(str(tmp_path / "st"), DenseIndex.build(jnp.asarray(D)))
    name = st.add_delta(capacity=64)
    st.append(np.ones((4, 16), np.float32), segment=name)
    # orphan blobs from a crashed replace (blob written, manifest not
    # swapped) must not invalidate the store
    np.save(os.path.join(st.path, "vectors_999998.npy"),
            np.zeros((2, 16), np.float32))
    re = IndexStore.open(st.path)
    assert re.n == 204
    st.replace_segment(name, [np.full((6, 16), 2.0, np.float32)])
    re = IndexStore.open(st.path)
    assert re.n == 206
    np.testing.assert_array_equal(re.segments()[1].read_rows(0, 6),
                                  np.full((6, 16), 2.0, np.float32))


# ---------------------------------------------------------------------------
# compaction
# ---------------------------------------------------------------------------


def test_compact_merges_to_single_fresh_base(tmp_path):
    D = _corpus(500, 24)
    up = IndexUpdater.build(jnp.asarray(D), cutoff=0.5, quantize_int8=True,
                            store_path=str(tmp_path / "st"),
                            delta_capacity=128)
    extra = _corpus(700, 24, seed=5)[500:]
    up.add_documents(jnp.asarray(extra))
    assert up.delta_fraction > 0
    up.compact()
    assert len(up.index.deltas) == 0 and up.index.n == 700
    assert up.compactions == 1 and up.delta_fraction == 0.0
    st = IndexStore.open(str(tmp_path / "st"))
    assert len(st.segments()) == 1 and st.n == 700
    assert not os.path.exists(str(tmp_path / "st") + ".tmp")
    # every doc still retrievable under the fresh corpus-wide scale
    _, ids = up.search(jnp.asarray(D[123][None, :]), k=3)
    assert 123 in np.asarray(ids)[0].tolist()
    # further appends land on the compacted base's store
    up.add_documents(jnp.asarray(_corpus(30, 24, seed=9)))
    assert IndexStore.open(str(tmp_path / "st")).n == 730


def test_refit_preserves_sharded_base():
    """A drift-triggered refit on a sharded deployment must rebuild the
    base on the SAME mesh, not collapse it onto one device."""
    mesh = _mesh(4)
    D = _corpus(400, 32)
    pruner = StaticPruner(cutoff=0.5).fit(jnp.asarray(D))
    base = pruner.build_index(jnp.asarray(D), mesh=mesh, quantize_int8=True)
    up = IndexUpdater(pruner=pruner, index=base, delta_capacity=128)
    shifted = _corpus(500, 32, seed=9)
    up.refit(jnp.asarray(shifted))
    assert isinstance(up.index.base, ShardedDenseIndex)
    assert up.index.base.mesh is mesh
    assert up.index.base.vectors.dtype == jnp.int8
    assert up.index.n == 500
    _, ids = up.search(jnp.asarray(shifted[:3]), k=5)
    assert np.asarray(ids).max() < 500


def test_compact_reconciles_racing_appends():
    """Appends that land while a compaction streams must survive the swap:
    the tail rows re-append onto the fresh base."""
    import time
    D = _corpus(400, 24)
    up = IndexUpdater.build(jnp.asarray(D), cutoff=0.5, delta_capacity=256)
    up.add_documents(jnp.asarray(_corpus(50, 24, seed=5)))
    racing = _corpus(30, 24, seed=6)

    orig_iter = up._iter_dequant_rows
    started = threading.Event()

    def slow_iter(index, block_rows, store):
        for blk in orig_iter(index, block_rows, store):
            started.set()
            time.sleep(0.02)                 # hold the stream open
            yield blk

    up._iter_dequant_rows = slow_iter
    try:
        th = up.compact_async(block_rows=40)
        assert started.wait(30.0)
        up.add_documents(jnp.asarray(racing))   # lands mid-stream
        th.join(timeout=60.0)
        assert not th.is_alive()
    finally:
        up._iter_dequant_rows = orig_iter
    assert up.index.n == 480
    assert up.compactions == 1
    _, ids = up.search(jnp.asarray(racing[7][None, :]), k=5)
    assert (450 + 7) in np.asarray(ids)[0].tolist()


# ---------------------------------------------------------------------------
# serving: atomic swap under live traffic
# ---------------------------------------------------------------------------


def _unit_corpus(n, d=64, seed=77):
    rng = np.random.default_rng(seed)
    D = rng.standard_normal((n, d)).astype(np.float32)
    D /= np.linalg.norm(D, axis=1, keepdims=True)
    return D


def test_swap_under_load_soak():
    """Live appends + swaps while concurrent clients hammer the server:
    every reply must answer its own query (self-retrieval) — a dropped
    reply would hang its client's timeout, a half-swapped segment set
    would misroute ids — and the steady-state jit cache must not grow
    (appends never stall serving on a compile)."""
    from repro.launch.serve import RetrievalServer
    D = _unit_corpus(96)
    extra = _unit_corpus(200, seed=78)
    pruner = StaticPruner(cutoff=0.25).fit(jnp.asarray(D))
    base = DenseIndex.build(pruner.prune_index(jnp.asarray(D)))
    seg = SegmentedIndex.from_index(base, delta_capacity=4096)
    server = RetrievalServer(seg, pruner, k=1, max_batch=8, pipeline_depth=3)
    up = IndexUpdater(pruner=pruner, index=seg, server=server,
                      delta_capacity=4096)
    try:
        # warm every steady-state shape: open the delta, then extend once
        # at the soak's block size with rows that provably cannot widen
        # the scale (0.5x rows already present — their per-dim absmax is
        # strictly covered), so the non-widen update-slice jit compiles
        # HERE, not mid-soak. Those 8 scaled rows get ids 104..111; the
        # clients below never query them.
        up.add_documents(jnp.asarray(extra[:8]))
        up.add_documents(jnp.asarray(0.5 * extra[:8]))
        server.query(D[0])
        j0 = segment_jit_cache_sizes()
        swaps0 = server.swap_count
        n_known = 96 + 8                     # rows safe to self-retrieve

        stop = threading.Event()
        failures: list = []

        def appender():
            i = 16
            while not stop.is_set() and i + 8 <= len(extra):
                up.add_documents(jnp.asarray(extra[i:i + 8]))
                i += 8
                stop.wait(0.002)

        def client(cid):
            rng = np.random.default_rng(cid)
            try:
                for _ in range(40):
                    doc = int(rng.integers(0, n_known))
                    q = D[doc] if doc < 96 else extra[doc - 96]
                    _, ids = server.query(q, timeout=30.0)
                    if int(ids[0]) != doc:
                        failures.append((cid, doc, int(ids[0])))
            except BaseException as e:       # noqa: BLE001
                failures.append((cid, "exception", repr(e)))

        app = threading.Thread(target=appender, daemon=True)
        clients = [threading.Thread(target=client, args=(c,), daemon=True)
                   for c in range(8)]
        app.start()
        for t in clients:
            t.start()
        for t in clients:
            t.join(timeout=120.0)
        stop.set()
        app.join(timeout=30.0)
        assert not failures, f"misrouted/dropped replies: {failures[:5]}"
        assert server.swap_count > swaps0, "appends never swapped the index"
        assert segment_jit_cache_sizes() == j0, \
            "live appends recompiled the serving path"
        # every appended doc is now retrievable through the server
        n_final = up.index.n
        for gid in (100, n_final - 1):
            _, ids = server.query(extra[gid - 96])
            assert int(ids[0]) == gid
        # close() drains — zero dropped replies at shutdown too
        replies = [server.submit(D[i % 96]) for i in range(50)]
        server.close()
        for i, r in enumerate(replies):
            _, ids = r.get(timeout=5.0)
            assert int(ids[0]) == i % 96
    finally:
        server.close()


def test_swap_during_compaction_under_traffic():
    """Background compaction finishes and swaps mid-serve; queries before,
    during, and after must all self-retrieve."""
    from repro.launch.serve import RetrievalServer
    D = _unit_corpus(96)
    extra = _unit_corpus(64, seed=79)
    pruner = StaticPruner(cutoff=0.25).fit(jnp.asarray(D))
    base = DenseIndex.build(pruner.prune_index(jnp.asarray(D)),
                            quantize_int8=True)
    seg = SegmentedIndex.from_index(base, delta_capacity=1024)
    server = RetrievalServer(seg, pruner, k=1, max_batch=8, pipeline_depth=3)
    up = IndexUpdater(pruner=pruner, index=seg, server=server,
                      delta_capacity=1024)
    try:
        up.add_documents(jnp.asarray(extra))
        swaps_before = server.swap_count
        th = up.compact_async()
        ok = 0
        while th.is_alive():
            doc = int(RNG.integers(0, 160))
            q = D[doc] if doc < 96 else extra[doc - 96]
            _, ids = server.query(q, timeout=30.0)
            assert int(ids[0]) == doc
            ok += 1
        th.join(timeout=60.0)
        assert server.swap_count == swaps_before + 1
        assert len(up.index.deltas) == 0
        for doc in (0, 95, 96, 159):
            q = D[doc] if doc < 96 else extra[doc - 96]
            _, ids = server.query(q, timeout=30.0)
            assert int(ids[0]) == doc
    finally:
        server.close()


def test_reply_carries_completion_timestamp():
    from repro.launch.serve import RetrievalServer
    D = _unit_corpus(32)
    pruner = StaticPruner(cutoff=0.25).fit(jnp.asarray(D))
    index = DenseIndex.build(pruner.prune_index(jnp.asarray(D)))
    server = RetrievalServer(index, pruner, k=1, max_batch=8)
    try:
        import time
        t0 = time.perf_counter()
        reply = server.submit(D[3])
        _, ids = reply.get(timeout=10.0)
        assert reply.completed_at is not None
        assert t0 < reply.completed_at <= time.perf_counter()
    finally:
        server.close()


# ---------------------------------------------------------------------------
# config layer: retrieval_cand live-delta cell
# ---------------------------------------------------------------------------


def test_retrieval_cand_delta_rows_bundle():
    """The serving-config cell wires the same cross-segment merge: base
    sharded over the mesh + one replicated delta with its own scale and a
    traced live count."""
    import dataclasses
    from repro.configs.registry import get_arch
    from repro.configs.steps import BUNDLE_BUILDERS
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    spec = get_arch("two-tower-retrieval")
    cell = spec.cell("retrieval_cand")
    cell = dataclasses.replace(cell, dims={**cell.dims,
                                           "n_candidates": 2048,
                                           "index_dim": 32, "int8": 1,
                                           "delta_rows": 256})
    mesh = jax.make_mesh((2, 2), ("dp", "model"))
    bundle = BUNDLE_BUILDERS[spec.family](spec, cell, mesh)
    assert bundle.meta["delta_rows"] == 256
    out_s, out_i = jax.eval_shape(bundle.fn, *bundle.args)
    assert out_s.shape == out_i.shape == (1, 100)
