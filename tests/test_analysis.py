"""Self-tests for the static-analysis suite (``repro.analysis``).

Two directions: every known-bad fixture must trip EXACTLY its expected
finding (the analyzers detect what they claim to), and the live repo code
must produce zero unsuppressed findings (the gate is green at head, so any
future red is a real regression).
"""
import json
from pathlib import Path

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.analysis import Finding, concurrency, jaxpr_lints, pallas_budget
from repro.analysis.fixtures import BAD_TOPK_CONFIG, bad_jaxpr
from repro.analysis.report import (apply_baseline, format_text,
                                   load_baseline, write_report)

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "src" / "repro" / "analysis" / "fixtures"

RNG = np.random.default_rng(5)


def _int8_corpus(n=256, m=32):
    D = (RNG.integers(-127, 128, size=(n, m))).astype(np.int8)
    scale = np.full((m,), 0.05, np.float32)
    q = RNG.standard_normal((3, m)).astype(np.float32)
    return jnp.asarray(D), jnp.asarray(scale), jnp.asarray(q)


# ---------------------------------------------------------------------------
# jaxpr lints: bad fixtures
# ---------------------------------------------------------------------------


def test_upcasting_fixture_flagged():
    D, scale, q = _int8_corpus()
    entry = lambda x: bad_jaxpr.upcasting_search(D, scale, x)  # noqa: E731
    fs = jaxpr_lints.check_storage_dtype_stream(
        "fixture.upcast", entry, (q,), tuple(D.shape), "int8",
        strip_rows=64)
    assert [f.check for f in fs] == ["jaxpr.upcast"]
    assert "convert_element_type" in fs[0].message


def test_strip_sized_dequant_not_flagged():
    """The per-strip in-register dequant is the DESIGN — a convert no
    larger than one strip must pass."""
    D, scale, q = _int8_corpus(n=64)             # corpus == one strip
    entry = lambda x: bad_jaxpr.upcasting_search(D, scale, x)  # noqa: E731
    fs = jaxpr_lints.check_storage_dtype_stream(
        "fixture.strip", entry, (q,), tuple(D.shape), "int8",
        strip_rows=64)
    assert fs == []


def test_two_dispatch_fixture_flagged():
    D, _, q = _int8_corpus()
    Df = D.astype(jnp.float32)
    entry = lambda x: bad_jaxpr.two_dispatch_search(Df, x)  # noqa: E731
    fs = jaxpr_lints.check_dispatch_count("fixture.2disp", entry, (q,),
                                          expected=1)
    assert [f.check for f in fs] == ["jaxpr.extra-dispatch"]
    assert "2 compute dispatches" in fs[0].message


def test_callback_fixture_flagged():
    D, _, q = _int8_corpus()
    Df = D.astype(jnp.float32)
    entry = lambda x: bad_jaxpr.chatty_search(Df, x)  # noqa: E731
    fs = jaxpr_lints.check_no_callbacks("fixture.callback", entry, (q,))
    assert len(fs) == 1 and fs[0].check == "jaxpr.host-callback"


def test_recompile_fixture_flagged():
    D, _, q = _int8_corpus(n=64)
    s = bad_jaxpr.RecompilingSearcher(D.astype(jnp.float32))
    fs = jaxpr_lints.check_recompile_stability(
        lambda live, _off: s.search(q, n_valid=live),
        s.cache_sizes, [(4, 0), (5, 0), (6, 0)], "fixture.recompile")
    assert [f.check for f in fs] == ["jaxpr.recompile"]
    assert "grew" in fs[0].message


def test_fused_entry_is_single_dispatch():
    """The repo's own dense fused path is the known-good control."""
    from repro.core import DenseIndex, StaticPruner
    D = jnp.asarray(RNG.standard_normal((200, 32)).astype(np.float32))
    pruner = StaticPruner(cutoff=0.5).fit(D)
    idx = DenseIndex.build(pruner.prune_index(D), quantize_int8=True)
    W, mean = pruner.projection()
    q = jnp.asarray(RNG.standard_normal((2, 32)).astype(np.float32))
    entry = lambda x: idx.search_projected(x, W, k=5, mean=mean)  # noqa: E731
    assert jaxpr_lints.check_dispatch_count("good", entry, (q,), 1) == []
    assert jaxpr_lints.check_no_callbacks("good", entry, (q,)) == []


# ---------------------------------------------------------------------------
# pallas budget
# ---------------------------------------------------------------------------


def test_over_budget_config_rejected():
    fs = pallas_budget.check_topk_config(**BAD_TOPK_CONFIG)
    errors = [f for f in fs if f.severity == "error"]
    assert [f.check for f in errors] == ["pallas.vmem-budget"]
    assert "exceeds" in errors[0].message


def test_budget_scales_with_block_and_dtype():
    small = pallas_budget.estimate_topk_vmem(
        pallas_budget.topk_geometry(10**6, 128, 64, 10, block_n=512), "int8")
    big = pallas_budget.estimate_topk_vmem(
        pallas_budget.topk_geometry(10**6, 128, 64, 10, block_n=4096),
        "float32")
    assert big["total"] > small["total"]
    assert big["d_strip"] == 4 * 8 * small["d_strip"]  # 8x rows, 4x width


def test_geometry_invariants_hold_on_awkward_shapes():
    for n, m, B, k, bn, bb in ((601, 48, 3, 7, 256, 64),
                               (8, 128, 1, 10, 1024, 128),
                               (4096, 64, 129, 100, 1000, 8)):
        assert pallas_budget.check_topk_config(
            n, m, B, k, block_n=bn, block_b=bb, dtype="int8",
            budget=2**40) == [f for f in pallas_budget.check_topk_config(
                n, m, B, k, block_n=bn, block_b=bb, dtype="int8",
                budget=2**40) if f.check == "pallas.alignment"]


def test_traced_index_maps_accept_good_kernel():
    import functools
    from repro.kernels.topk_score import topk_score_pallas
    D = RNG.standard_normal((300, 128)).astype(np.float32)
    Q = RNG.standard_normal((4, 128)).astype(np.float32)
    fs = pallas_budget.check_traced_index_maps(
        "good", functools.partial(topk_score_pallas, k=5, block_n=128,
                                  block_b=8), (D, Q))
    assert fs == []


def test_traced_index_maps_catch_out_of_bounds():
    from jax.experimental import pallas as pl

    def bad(x):
        return pl.pallas_call(
            lambda x_ref, o_ref: o_ref.__setitem__(Ellipsis, x_ref[...]),
            grid=(4,),
            in_specs=[pl.BlockSpec((8, 16), lambda i: (i + 1, 0))],  # skew
            out_specs=pl.BlockSpec((8, 16), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((32, 16), jnp.float32),
            interpret=True)(x)

    x = np.zeros((32, 16), np.float32)
    fs = pallas_budget.check_traced_index_maps("fixture.oob", bad, (x,))
    assert any(f.check == "pallas.index-map" and f.severity == "error"
               for f in fs)


# ---------------------------------------------------------------------------
# concurrency lint
# ---------------------------------------------------------------------------


def test_bad_locks_fixture_findings_exact():
    fs = concurrency.analyze([("fx", FIXTURES / "bad_locks.py")])
    keys = sorted(f.key for f in fs)
    assert "conc.unguarded-field:fx:UnguardedCounter.peek:count" in keys
    assert "conc.unlocked-shared-mutable:fx:NeverLockedLog:log" in keys
    assert "conc.blocking-under-lock:fx:SleepyWriter.publish:np.asarray" \
        in keys
    assert "conc.blocking-under-lock:fx:SleepyWriter.publish:time.sleep" \
        in keys
    cycles = [f for f in fs if f.check == "conc.lock-order"]
    assert len(cycles) == 1
    assert "Left._lock" in cycles[0].message
    assert "Right._lock" in cycles[0].message
    assert len(fs) == 5                       # nothing beyond the five sins


def test_lock_propagation_suppresses_false_positive():
    """A private helper whose every call site holds the lock is analysed
    as locked — the _mirror_ops pattern."""
    src = '''
import threading

class C:
    def __init__(self):
        self._lock = threading.Lock()
        self.state = []

    def mutate(self, x):
        with self._lock:
            self._apply(x)

    def replace(self, xs):
        with self._lock:
            self.state.clear()
            for x in xs:
                self._apply(x)

    def _apply(self, x):
        self.state.append(x)
'''
    infos = concurrency.analyze_classes(src, "fx")
    assert concurrency.field_findings(infos[0]) == []


def test_real_serving_code_clean_modulo_baseline():
    fs = concurrency.run()
    report = apply_baseline(fs, load_baseline(REPO
                                              / "analysis_baseline.json"))
    assert report.gating == ()
    assert report.stale == ()


# ---------------------------------------------------------------------------
# report / baseline / CLI
# ---------------------------------------------------------------------------


def _f(check="c.x", where="w", sev="error"):
    return Finding(check=check, where=where, message="m", severity=sev)


def test_baseline_roundtrip(tmp_path):
    findings = [_f(where="a"), _f(where="b"), _f(where="w2", sev="warn")]
    base = tmp_path / "b.json"
    base.write_text(json.dumps({"suppressions": [
        {"key": "c.x:a", "reason": "reviewed"},
        {"key": "c.x:gone", "reason": "paid off"}]}))
    report = apply_baseline(findings, load_baseline(base))
    assert [f.where for f in report.findings] == ["b", "w2"]
    assert report.gating == (findings[1],)       # warn does not gate
    assert report.stale == ("c.x:gone",)
    out = tmp_path / "r.json"
    write_report(report, out)
    doc = json.loads(out.read_text())
    assert doc["schema"] == "repro.analysis/v1"
    assert doc["counts"] == {"findings": 2, "gating": 1, "suppressed": 1,
                             "stale_suppressions": 1}
    txt = format_text(report)
    assert "stale-suppression" in txt and "c.x:b" in txt


def test_missing_baseline_is_empty():
    assert load_baseline(None) == {}
    assert load_baseline("/nonexistent/x.json") == {}


def test_duplicate_baseline_key_rejected(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"suppressions": [
        {"key": "k", "reason": "r1"}, {"key": "k", "reason": "r2"}]}))
    with pytest.raises(ValueError, match="duplicate"):
        load_baseline(p)


def test_cli_conc_gate_green_and_red(tmp_path):
    from repro.analysis.__main__ import main
    out = tmp_path / "rep.json"
    rc = main(["--only", "conc", "--json", str(out),
               "--baseline", str(REPO / "analysis_baseline.json"),
               "--fail-on-findings"])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["counts"]["gating"] == 0
    assert doc["counts"]["suppressed"] == 2
    # without the baseline the same findings gate
    rc = main(["--only", "conc", "--json", "",
               "--baseline", str(tmp_path / "missing.json"),
               "--fail-on-findings"])
    assert rc == 1
