"""Self-tests for the static-analysis suite (``repro.analysis``).

Two directions: every known-bad fixture must trip EXACTLY its expected
finding (the analyzers detect what they claim to), and the live repo code
must produce zero unsuppressed findings (the gate is green at head, so any
future red is a real regression).
"""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import Finding, concurrency, jaxpr_lints, pallas_budget
from repro.analysis.fixtures import BAD_TOPK_CONFIG, bad_jaxpr
from repro.analysis.report import apply_baseline, format_text, load_baseline, write_report

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "src" / "repro" / "analysis" / "fixtures"

RNG = np.random.default_rng(5)


def _int8_corpus(n=256, m=32):
    D = (RNG.integers(-127, 128, size=(n, m))).astype(np.int8)
    scale = np.full((m,), 0.05, np.float32)
    q = RNG.standard_normal((3, m)).astype(np.float32)
    return jnp.asarray(D), jnp.asarray(scale), jnp.asarray(q)


# ---------------------------------------------------------------------------
# jaxpr lints: bad fixtures
# ---------------------------------------------------------------------------


def test_upcasting_fixture_flagged():
    D, scale, q = _int8_corpus()
    entry = lambda x: bad_jaxpr.upcasting_search(D, scale, x)  # noqa: E731
    fs = jaxpr_lints.check_storage_dtype_stream(
        "fixture.upcast", entry, (q,), tuple(D.shape), "int8",
        strip_rows=64)
    assert [f.check for f in fs] == ["jaxpr.upcast"]
    assert "convert_element_type" in fs[0].message


def test_strip_sized_dequant_not_flagged():
    """The per-strip in-register dequant is the DESIGN — a convert no
    larger than one strip must pass."""
    D, scale, q = _int8_corpus(n=64)             # corpus == one strip
    entry = lambda x: bad_jaxpr.upcasting_search(D, scale, x)  # noqa: E731
    fs = jaxpr_lints.check_storage_dtype_stream(
        "fixture.strip", entry, (q,), tuple(D.shape), "int8",
        strip_rows=64)
    assert fs == []


def test_two_dispatch_fixture_flagged():
    D, _, q = _int8_corpus()
    Df = D.astype(jnp.float32)
    entry = lambda x: bad_jaxpr.two_dispatch_search(Df, x)  # noqa: E731
    fs = jaxpr_lints.check_dispatch_count("fixture.2disp", entry, (q,),
                                          expected=1)
    assert [f.check for f in fs] == ["jaxpr.extra-dispatch"]
    assert "2 compute dispatches" in fs[0].message


def test_callback_fixture_flagged():
    D, _, q = _int8_corpus()
    Df = D.astype(jnp.float32)
    entry = lambda x: bad_jaxpr.chatty_search(Df, x)  # noqa: E731
    fs = jaxpr_lints.check_no_callbacks("fixture.callback", entry, (q,))
    assert len(fs) == 1 and fs[0].check == "jaxpr.host-callback"


def test_recompile_fixture_flagged():
    D, _, q = _int8_corpus(n=64)
    s = bad_jaxpr.RecompilingSearcher(D.astype(jnp.float32))
    fs = jaxpr_lints.check_recompile_stability(
        lambda live, _off: s.search(q, n_valid=live),
        s.cache_sizes, [(4, 0), (5, 0), (6, 0)], "fixture.recompile")
    assert [f.check for f in fs] == ["jaxpr.recompile"]
    assert "grew" in fs[0].message


def test_fused_entry_is_single_dispatch():
    """The repo's own dense fused path is the known-good control."""
    from repro.core import DenseIndex, StaticPruner
    D = jnp.asarray(RNG.standard_normal((200, 32)).astype(np.float32))
    pruner = StaticPruner(cutoff=0.5).fit(D)
    idx = DenseIndex.build(pruner.prune_index(D), quantize_int8=True)
    W, mean = pruner.projection()
    q = jnp.asarray(RNG.standard_normal((2, 32)).astype(np.float32))
    entry = lambda x: idx.search_projected(x, W, k=5, mean=mean)  # noqa: E731
    assert jaxpr_lints.check_dispatch_count("good", entry, (q,), 1) == []
    assert jaxpr_lints.check_no_callbacks("good", entry, (q,)) == []


# ---------------------------------------------------------------------------
# pallas budget
# ---------------------------------------------------------------------------


def test_over_budget_config_rejected():
    fs = pallas_budget.check_topk_config(**BAD_TOPK_CONFIG)
    errors = [f for f in fs if f.severity == "error"]
    assert [f.check for f in errors] == ["pallas.vmem-budget"]
    assert "exceeds" in errors[0].message


def test_budget_scales_with_block_and_dtype():
    small = pallas_budget.estimate_topk_vmem(
        pallas_budget.topk_geometry(10**6, 128, 64, 10, block_n=512), "int8")
    big = pallas_budget.estimate_topk_vmem(
        pallas_budget.topk_geometry(10**6, 128, 64, 10, block_n=4096),
        "float32")
    assert big["total"] > small["total"]
    assert big["d_strip"] == 4 * 8 * small["d_strip"]  # 8x rows, 4x width


def test_geometry_invariants_hold_on_awkward_shapes():
    for n, m, B, k, bn, bb in ((601, 48, 3, 7, 256, 64),
                               (8, 128, 1, 10, 1024, 128),
                               (4096, 64, 129, 100, 1000, 8)):
        assert pallas_budget.check_topk_config(
            n, m, B, k, block_n=bn, block_b=bb, dtype="int8",
            budget=2**40) == [f for f in pallas_budget.check_topk_config(
                n, m, B, k, block_n=bn, block_b=bb, dtype="int8",
                budget=2**40) if f.check == "pallas.alignment"]


def test_traced_index_maps_accept_good_kernel():
    import functools
    from repro.kernels.topk_score import topk_score_pallas
    D = RNG.standard_normal((300, 128)).astype(np.float32)
    Q = RNG.standard_normal((4, 128)).astype(np.float32)
    fs = pallas_budget.check_traced_index_maps(
        "good", functools.partial(topk_score_pallas, k=5, block_n=128,
                                  block_b=8), (D, Q))
    assert fs == []


def test_traced_index_maps_catch_out_of_bounds():
    from jax.experimental import pallas as pl

    def bad(x):
        return pl.pallas_call(
            lambda x_ref, o_ref: o_ref.__setitem__(Ellipsis, x_ref[...]),
            grid=(4,),
            in_specs=[pl.BlockSpec((8, 16), lambda i: (i + 1, 0))],  # skew
            out_specs=pl.BlockSpec((8, 16), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((32, 16), jnp.float32),
            interpret=True)(x)

    x = np.zeros((32, 16), np.float32)
    fs = pallas_budget.check_traced_index_maps("fixture.oob", bad, (x,))
    assert any(f.check == "pallas.index-map" and f.severity == "error"
               for f in fs)


# ---------------------------------------------------------------------------
# concurrency lint
# ---------------------------------------------------------------------------


def test_bad_locks_fixture_findings_exact():
    fs = concurrency.analyze([("fx", FIXTURES / "bad_locks.py")])
    keys = sorted(f.key for f in fs)
    assert "conc.unguarded-field:fx:UnguardedCounter.peek:count" in keys
    assert "conc.unlocked-shared-mutable:fx:NeverLockedLog:log" in keys
    assert "conc.blocking-under-lock:fx:SleepyWriter.publish:np.asarray" \
        in keys
    assert "conc.blocking-under-lock:fx:SleepyWriter.publish:time.sleep" \
        in keys
    cycles = [f for f in fs if f.check == "conc.lock-order"]
    assert len(cycles) == 1
    assert "Left._lock" in cycles[0].message
    assert "Right._lock" in cycles[0].message
    assert len(fs) == 5                       # nothing beyond the five sins


def test_lock_propagation_suppresses_false_positive():
    """A private helper whose every call site holds the lock is analysed
    as locked — the _mirror_ops pattern."""
    src = '''
import threading

class C:
    def __init__(self):
        self._lock = threading.Lock()
        self.state = []

    def mutate(self, x):
        with self._lock:
            self._apply(x)

    def replace(self, xs):
        with self._lock:
            self.state.clear()
            for x in xs:
                self._apply(x)

    def _apply(self, x):
        self.state.append(x)
'''
    infos = concurrency.analyze_classes(src, "fx")
    assert concurrency.field_findings(infos[0]) == []


def test_real_serving_code_clean_modulo_baseline():
    fs = concurrency.run()
    report = apply_baseline(fs, load_baseline(REPO
                                              / "analysis_baseline.json"),
                            active_analyzers=["conc"])
    assert report.gating == ()
    assert report.stale == ()


# ---------------------------------------------------------------------------
# report / baseline / CLI
# ---------------------------------------------------------------------------


def _f(check="c.x", where="w", sev="error"):
    return Finding(check=check, where=where, message="m", severity=sev)


def test_baseline_roundtrip(tmp_path):
    findings = [_f(where="a"), _f(where="b"), _f(where="w2", sev="warn")]
    base = tmp_path / "b.json"
    base.write_text(json.dumps({"suppressions": [
        {"key": "c.x:a", "reason": "reviewed"},
        {"key": "c.x:gone", "reason": "paid off"}]}))
    report = apply_baseline(findings, load_baseline(base))
    assert [f.where for f in report.findings] == ["b", "w2"]
    assert report.gating == (findings[1],)       # warn does not gate
    assert report.stale == ("c.x:gone",)
    out = tmp_path / "r.json"
    write_report(report, out)
    doc = json.loads(out.read_text())
    assert doc["schema"] == "repro.analysis/v1"
    assert doc["counts"] == {"findings": 2, "gating": 1, "suppressed": 1,
                             "stale_suppressions": 1}
    txt = format_text(report)
    assert "stale-suppression" in txt and "c.x:b" in txt


def test_missing_baseline_is_empty():
    assert load_baseline(None) == {}
    assert load_baseline("/nonexistent/x.json") == {}


def test_duplicate_baseline_key_rejected(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"suppressions": [
        {"key": "k", "reason": "r1"}, {"key": "k", "reason": "r2"}]}))
    with pytest.raises(ValueError, match="duplicate"):
        load_baseline(p)


def test_cli_conc_gate_green_and_red(tmp_path):
    from repro.analysis.__main__ import main
    out = tmp_path / "rep.json"
    rc = main(["--only", "conc", "--json", str(out),
               "--baseline", str(REPO / "analysis_baseline.json"),
               "--fail-on-findings"])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["counts"]["gating"] == 0
    assert doc["counts"]["suppressed"] == 2
    # without the baseline the same findings gate
    rc = main(["--only", "conc", "--json", "",
               "--baseline", str(tmp_path / "missing.json"),
               "--fail-on-findings"])
    assert rc == 1


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


def _costs_doc():
    return json.loads((REPO / "analysis_costs.json").read_text())


def test_cost_baseline_schema_valid():
    from repro.analysis import cost_model
    cost_model.check_costs_schema(_costs_doc())     # must not raise


def test_cost_schema_rejects_missing_metric():
    from repro.analysis import cost_model
    doc = _costs_doc()
    label = next(iter(doc["entries"]))
    del doc["entries"][label]["flops_per_query"]
    with pytest.raises(SystemExit, match="flops_per_query"):
        cost_model.check_costs_schema(doc)


def test_cost_shadow_copy_fixture_fails_gate():
    """An f32 shadow copy of the int8 index must blow the per-query HBM
    byte budget of the entry it impersonates."""
    from repro.analysis import cost_model
    from repro.analysis.fixtures import bad_costs
    ep = bad_costs.shadow_copy_entry()
    doc = _costs_doc()
    sub = {"schema": doc["schema"],
           "entries": {ep.label: doc["entries"][ep.label]}}
    fs = cost_model.compare_costs({ep.label: cost_model.measure_entry(ep)},
                                  sub)
    regressed = {f.where.rsplit(":", 1)[-1] for f in fs
                 if f.check == "cost.regression"}
    assert "hbm_read_bytes_per_query" in regressed
    assert "dispatches" not in regressed         # same dispatch count


def test_cost_extra_dispatch_fixture_fails_gate():
    from repro.analysis import cost_model
    from repro.analysis.fixtures import bad_costs
    ep = bad_costs.extra_dispatch_entry()
    doc = _costs_doc()
    sub = {"schema": doc["schema"],
           "entries": {ep.label: doc["entries"][ep.label]}}
    fs = cost_model.compare_costs({ep.label: cost_model.measure_entry(ep)},
                                  sub)
    assert any(f.check == "cost.regression"
               and f.where.endswith(":dispatches") for f in fs)


def test_cost_bench_crosscheck_flags_inverted_ordering():
    from repro.analysis import cost_model
    entries = {
        "A": {"family": "dense", "bench_key": "ka",
              "hbm_read_bytes_per_query": 100.0,
              "hbm_write_bytes_per_query": 0.0},
        "B": {"family": "dense", "bench_key": "kb",
              "hbm_read_bytes_per_query": 900.0,
              "hbm_write_bytes_per_query": 0.0},
    }
    bench = {"serve_pipeline": {"configs": {
        "ka": {"pipelined": {"worker_qps": 10.0}},
        "kb": {"pipelined": {"worker_qps": 50.0}},
    }}}
    fs = cost_model.bench_crosscheck(entries, bench)
    assert [f.check for f in fs] == ["cost.bench-mismatch"]
    assert fs[0].severity == "warn"
    bench["serve_pipeline"]["configs"]["kb"]["pipelined"]["worker_qps"] = 5.0
    assert cost_model.bench_crosscheck(entries, bench) == []


def test_cli_cost_gate_green_and_red(tmp_path):
    from repro.analysis.__main__ import main
    out = tmp_path / "rep.json"
    rc = main(["--only", "cost", "--json", str(out),
               "--baseline", str(REPO / "analysis_baseline.json"),
               "--costs", str(REPO / "analysis_costs.json"),
               "--bench", str(REPO / "BENCH_perf.json"),
               "--fail-on-findings"])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["counts"]["gating"] == 0
    # doctor one entry's byte budget far below what the code spends: the
    # same gate must go red
    costs = _costs_doc()
    label = "DenseIndex.search_projected[jnp]"
    costs["entries"][label]["hbm_read_bytes_per_query"] /= 4.0
    doctored = tmp_path / "costs.json"
    doctored.write_text(json.dumps(costs))
    rc = main(["--only", "cost", "--json", "",
               "--baseline", str(REPO / "analysis_baseline.json"),
               "--costs", str(doctored),
               "--bench", str(REPO / "BENCH_perf.json"),
               "--fail-on-findings"])
    assert rc == 1


def test_cost_write_baseline_roundtrips(tmp_path):
    from repro.analysis import cost_model
    from repro.analysis.jaxpr_lints import serving_entry_points
    eps = [ep for ep in serving_entry_points() if ep.family == "dense"]
    measured = cost_model.measure_all(eps)
    path = tmp_path / "costs.json"
    cost_model.write_baseline(path, measured)
    doc = json.loads(path.read_text())
    assert cost_model.compare_costs(measured, doc) == []


# ---------------------------------------------------------------------------
# dataflow invariants
# ---------------------------------------------------------------------------


def test_invariants_clean_on_live_entry_points():
    from repro.analysis import invariants
    assert invariants.run() == []


def _inv_fixture_args():
    D = jnp.asarray(RNG.standard_normal((64, 16)).astype(np.float32))
    q = jnp.asarray(RNG.standard_normal((2, 16)).astype(np.float32))
    cids = jnp.asarray(RNG.integers(0, 64, size=(2, 12)).astype(np.int32))
    return D, q, cids


@pytest.mark.parametrize("fn_name,expect", [
    ("unsorted_rescore", "inv.rowids-order"),
    ("swapped_dedup_rescore", "inv.dedup-tiebreak"),
    ("unmasked_rescore_jnp", "inv.sentinel-mask"),
])
def test_invariant_fixtures_trip_exactly_their_finding(fn_name, expect):
    from repro.analysis import invariants
    from repro.analysis.fixtures import bad_invariants
    fs = invariants.check_entry(f"fixture.{fn_name}",
                                getattr(bad_invariants, fn_name),
                                _inv_fixture_args())
    assert [f.check for f in fs] == [expect]
    assert all(f.severity == "error" for f in fs)


def test_segment_offset_fixture_flagged():
    from repro.analysis import invariants
    from repro.analysis.fixtures import bad_invariants
    D8a = jnp.asarray(RNG.integers(-127, 127, (64, 16)).astype(np.int8))
    D8b = jnp.asarray(RNG.integers(-127, 127, (64, 16)).astype(np.int8))
    sc = jnp.full((16,), 0.05, jnp.float32)
    q = jnp.asarray(RNG.standard_normal((2, 16)).astype(np.float32))
    fs = invariants.check_entry("fixture.overlap",
                                bad_invariants.overlapping_segments,
                                (D8a, D8b, sc, q))
    assert [f.check for f in fs] == ["inv.segment-offsets"]
    assert "100" in fs[0].message and "132" in fs[0].message


# ---------------------------------------------------------------------------
# lock sanitizer
# ---------------------------------------------------------------------------


def test_handoff_fixture_flagged_exactly():
    from repro.analysis import lock_sanitizer
    infos = concurrency.analyze_classes(
        (FIXTURES / "bad_handoff.py").read_text(), "fx")
    fs = lock_sanitizer.handoff_findings(infos)
    assert [f.key for f in fs] == \
        ["locks.handoff-deadlock:fx:StalledPipeline.consume:_q"]
    # and the lock-order pass sees nothing: no cycle exists
    assert concurrency.lock_order_findings(infos) == []


def test_handoff_clean_on_live_tree():
    from repro.analysis import lock_sanitizer
    assert lock_sanitizer.run() == []


def test_static_lock_graph_contents():
    from repro.analysis import lock_sanitizer
    g = lock_sanitizer.static_lock_graph()
    assert g["schema"] == lock_sanitizer.LOCKGRAPH_SCHEMA
    assert {"BatchingQueue._cv", "IndexUpdater._lock",
            "RetrievalServer._index_lock",
            "RetrievalServer._inflight_lock",
            "RetrievalServer._log_lock"} <= set(g["nodes"])
    assert ["IndexUpdater._lock", "RetrievalServer._index_lock"] \
        in g["edges"]
    assert g["handoffs"] == []


def test_crosscheck_divergence_and_unknown_lock():
    from repro.analysis import lock_sanitizer
    static = {"schema": lock_sanitizer.LOCKGRAPH_SCHEMA,
              "nodes": ["A.x", "B.y", "C.z"],
              "edges": [["A.x", "B.y"], ["B.y", "C.z"]]}
    ok = {"schema": lock_sanitizer.LOCKGRAPH_SCHEMA,
          "nodes": ["A.x", "C.z"],
          "edges": [["A.x", "C.z"]]}       # in the transitive closure
    assert lock_sanitizer.crosscheck(ok, static) == []
    bad = {"schema": lock_sanitizer.LOCKGRAPH_SCHEMA,
           "nodes": ["A.x", "B.y", "D.w"],
           "edges": [["B.y", "A.x"]]}      # reversed + unknown node
    fs = lock_sanitizer.crosscheck(bad, static)
    keys = sorted(f.key for f in fs)
    assert keys == ["locks.graph-divergence:B.y->A.x",
                    "locks.unknown-lock:D.w"]
    sev = {f.key: f.severity for f in fs}
    assert sev["locks.unknown-lock:D.w"] == "warn"
    assert sev["locks.graph-divergence:B.y->A.x"] == "error"


def test_lock_graph_schema_rejected(tmp_path):
    from repro.analysis import lock_sanitizer
    p = tmp_path / "g.json"
    p.write_text(json.dumps({"schema": "nope", "nodes": [], "edges": []}))
    with pytest.raises(SystemExit, match="lockgraph"):
        lock_sanitizer.run(lock_graph_path=str(p))


def test_runtime_lock_graph_embeds_in_static():
    """Drive a real updater+server through query/append/swap under a
    fresh monitor: every runtime acquisition order must embed in the
    static graph (the CI cross-check, in miniature)."""
    from repro.analysis import lock_sanitizer
    mon = lock_sanitizer.LockMonitor()
    originals = lock_sanitizer.instrument(mon)
    try:
        from repro.core.maintenance import IndexUpdater
        from repro.launch.serve import RetrievalServer
        corpus = jnp.asarray(RNG.standard_normal((96, 32))
                             .astype(np.float32))
        upd = IndexUpdater.build(corpus, cutoff=0.5, quantize_int8=True,
                                 delta_capacity=16)
        assert type(upd._lock).__name__ == "_TrackedLock"  # late-bound
        srv = RetrievalServer(upd.index, upd.pruner, max_batch=4)
        upd.server = srv
        try:
            srv.query(np.asarray(corpus[0]))
            upd.add_documents(jnp.asarray(
                RNG.standard_normal((8, 32)).astype(np.float32)))
            srv.query(np.asarray(corpus[0]))
        finally:
            srv.close()
    finally:
        lock_sanitizer.uninstrument(originals)
    observed = mon.to_doc()
    # the append path's cross-class order was actually exercised
    assert ["IndexUpdater._lock", "RetrievalServer._index_lock"] \
        in observed["edges"]
    assert lock_sanitizer.crosscheck(
        observed, lock_sanitizer.static_lock_graph()) == []


def test_stale_suppressions_scoped_to_ran_analyzers():
    findings = [_f(check="conc.x", where="a")]
    baseline = {"conc.x:a": "reviewed", "cost.regression:gone": "reviewed",
                "mystery.key:z": "reviewed"}
    # cost analyzer did not run: its unmatched key is NOT stale; an
    # unrecognised prefix always is
    rep = apply_baseline(findings, baseline, active_analyzers=["conc"])
    assert rep.stale == ("mystery.key:z",)
    # with every analyzer active (None) the cost key is genuinely stale
    rep = apply_baseline(findings, baseline, active_analyzers=None)
    assert sorted(rep.stale) == ["cost.regression:gone", "mystery.key:z"]
