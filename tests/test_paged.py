"""Paged index memory: kernel, parity, lifecycle, store, serving.

The acceptance surface of the paged architecture:
  * ``topk_score_paged_pallas`` walks a scrambled two-tier page table
    (pool + tail) bit-identically to the contiguous fused kernel — f32
    and int8 (per-page scales folded into the query), partial last page
    masked by ``n_valid``, run-split carry chaining, ``ids_pool`` rescore
    mode, any pipeline depth;
  * ``PagedIndex`` search is BIT-IDENTICAL to ``SegmentedIndex`` at equal
    contents — dense x {f32, int8} x {jnp, pallas}, through appends,
    promotion, compaction, eviction (host-tier streaming), and the
    cascade rescore path;
  * promotion / compaction / eviction are page-pointer swaps: results
    never change, and a full lifecycle never grows the jit cache once
    every variant is warm;
  * paged artifacts round-trip through ``IndexStore`` page-granularly
    (chunk boundaries page-aligned, host-tier pages included, bytes
    identical from either residency), reject corruption/truncation and a
    paged block that LEADS the segments, and accept a lagging block (the
    crash window);
  * ``RetrievalServer`` under live append+promote+compact traffic and
    under eviction/readmission swaps drops no reply and misroutes none;
  * ``IndexUpdater`` telemetry is page-based on a paged index:
    ``delta_fraction`` counts pages and ``last_compaction`` reports pages
    moved/freed/host — not rows copied.
"""
import dataclasses
import json
import os
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DenseIndex, SegmentedIndex, StaticPruner
from repro.core.index import segment_jit_cache_size
from repro.core.maintenance import IndexUpdater
from repro.core.paged import PagedIndex, PagedIndexStorage
from repro.core.store import (
    IndexStore,
    IndexStoreError,
    save_index,
    save_paged_index,
)
from repro.kernels.topk_score import topk_score_paged_pallas, topk_score_pallas

RNG = np.random.default_rng(170)


def _assert_same(a, b, msg=""):
    assert jnp.array_equal(a[0], b[0]), f"scores diverged {msg}"
    assert jnp.array_equal(a[1], b[1]), f"ids diverged {msg}"


# ---------------------------------------------------------------------------
# kernel: two-tier paged walk vs the contiguous fused kernel
# ---------------------------------------------------------------------------


def _two_tier_fixture(dtype=np.float32, seed=0):
    """Corpus scattered over a scrambled pool+tail page layout: logical
    slot j lives at physical page perm[j], the last page is partial."""
    rng = np.random.default_rng(seed)
    R, m, B, k = 8, 32, 5, 7
    npages, n_last = 11, 3
    n = (npages - 1) * R + n_last
    D = rng.standard_normal((n, m)).astype(np.float32)
    Q = rng.standard_normal((B, m)).astype(np.float32)
    pool_pages, tail_pages, table_cap = 7, 6, 16
    perm = rng.permutation(npages)
    pt = np.full(table_cap, -1, np.int32)
    pt[:npages] = perm
    nv = np.zeros(table_cap, np.int32)
    nv[:npages] = R
    nv[npages - 1] = n_last
    off = np.zeros(table_cap, np.int32)
    off[:npages] = np.arange(npages) * R
    pool = np.zeros((pool_pages, R, m), dtype)
    tail = np.zeros((tail_pages, R, m), dtype)
    return (R, m, B, k, npages, n, D, Q, pool_pages, table_cap, pt, nv, off,
            pool, tail)


@pytest.mark.parametrize("depth", [1, 2, 4])
def test_paged_kernel_two_tier_partial_last_page(depth):
    (R, m, B, k, npages, n, D, Q, pool_pages, _tc, pt, nv, off, pool,
     tail) = _two_tier_fixture()
    for j in range(npages):
        phys = pt[j]
        buf, idx = (pool, phys) if phys < pool_pages \
            else (tail, phys - pool_pages)
        buf[idx, :nv[j]] = D[j * R: j * R + nv[j]]
    ref = topk_score_pallas(jnp.asarray(D), jnp.asarray(Q), k=k,
                            block_n=R * npages, interpret=True)
    got = topk_score_paged_pallas(
        jnp.asarray(pool), jnp.asarray(pt), jnp.asarray(nv),
        jnp.asarray(off), jnp.int32(0), jnp.int32(npages), jnp.asarray(Q),
        k=k, tail=jnp.asarray(tail), depth=depth, interpret=True)
    _assert_same(got, ref, f"depth={depth}")


def test_paged_kernel_run_split_carry_matches_single_pass():
    (R, m, B, k, npages, n, D, Q, pool_pages, _tc, pt, nv, off, pool,
     tail) = _two_tier_fixture(seed=1)
    for j in range(npages):
        phys = pt[j]
        buf, idx = (pool, phys) if phys < pool_pages \
            else (tail, phys - pool_pages)
        buf[idx, :nv[j]] = D[j * R: j * R + nv[j]]
    args = (jnp.asarray(pool), jnp.asarray(pt), jnp.asarray(nv),
            jnp.asarray(off))
    ref = topk_score_paged_pallas(*args, jnp.int32(0), jnp.int32(npages),
                                  jnp.asarray(Q), k=k,
                                  tail=jnp.asarray(tail), depth=2,
                                  interpret=True)
    part = topk_score_paged_pallas(*args, jnp.int32(0), jnp.int32(4),
                                   jnp.asarray(Q), k=k,
                                   tail=jnp.asarray(tail), depth=2,
                                   finalize=False, interpret=True)
    got = topk_score_paged_pallas(*args, jnp.int32(4), jnp.int32(npages),
                                  jnp.asarray(Q), k=k,
                                  tail=jnp.asarray(tail), depth=2,
                                  carry=part, interpret=True)
    _assert_same(got, ref, "run-split carry")


def test_paged_kernel_int8_per_page_scale():
    (R, m, B, k, npages, n, D, Q, pool_pages, table_cap, pt, nv, off, _p,
     _t) = _two_tier_fixture(seed=2)
    scale = np.stack([
        np.abs(D[j * R:(j + 1) * R]).max(axis=0).clip(1e-12) / 127.0
        for j in range(npages)]).astype(np.float32)
    pool8 = np.zeros((pool_pages, R, m), np.int8)
    tail8 = np.zeros((6, R, m), np.int8)
    D8 = np.zeros_like(D, np.int8)
    for j in range(npages):
        rows = D[j * R: j * R + nv[j]]
        q8 = np.clip(np.round(rows / scale[j][None, :]), -127,
                     127).astype(np.int8)
        D8[j * R: j * R + nv[j]] = q8
        phys = pt[j]
        buf, idx = (pool8, phys) if phys < pool_pages \
            else (tail8, phys - pool_pages)
        buf[idx, :nv[j]] = q8
    ps = np.zeros((table_cap, m), np.float32)
    ps[:npages] = scale
    # reference: per-page scale folded into the query, jnp dot per page
    parts_s, parts_i = [], []
    for j in range(npages):
        qf = jnp.asarray(Q) * jnp.asarray(scale[j])[None, :]
        sj = jax.lax.dot_general(
            qf, jnp.asarray(D8[j * R: j * R + nv[j]]).astype(jnp.float32),
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        parts_s.append(sj)
        parts_i.append(jnp.asarray(np.arange(
            j * R, j * R + nv[j], dtype=np.int32)[None, :].repeat(B, 0)))
    cat_s = jnp.concatenate(parts_s, axis=1)
    cat_i = jnp.concatenate(parts_i, axis=1)
    rs, ridx = jax.lax.top_k(cat_s, k)
    ref = (rs, jnp.take_along_axis(cat_i, ridx, axis=1))
    got = topk_score_paged_pallas(
        jnp.asarray(pool8), jnp.asarray(pt), jnp.asarray(nv),
        jnp.asarray(off), jnp.int32(0), jnp.int32(npages), jnp.asarray(Q),
        k=k, tail=jnp.asarray(tail8), page_scale=jnp.asarray(ps), depth=2,
        interpret=True)
    _assert_same(got, ref, "int8 per-page scale")


def test_paged_kernel_ids_pool_rescore_mode():
    (R, m, B, k, npages, n, D, Q, pool_pages, table_cap, pt, nv, off, pool,
     tail) = _two_tier_fixture(seed=3)
    for j in range(npages):
        phys = pt[j]
        buf, idx = (pool, phys) if phys < pool_pages \
            else (tail, phys - pool_pages)
        buf[idx, :nv[j]] = D[j * R: j * R + nv[j]]
    ids_pool = np.full((table_cap, R), -1, np.int32)
    for j in range(npages):
        ids_pool[j, :nv[j]] = np.arange(j * R, j * R + nv[j], dtype=np.int32)
    ref = topk_score_pallas(
        jnp.asarray(D), jnp.asarray(Q), k=k, block_n=R * npages,
        row_ids=jnp.asarray(np.arange(n, dtype=np.int32)), interpret=True)
    got = topk_score_paged_pallas(
        jnp.asarray(pool), jnp.asarray(pt), jnp.asarray(nv),
        jnp.asarray(off), jnp.int32(0), jnp.int32(npages), jnp.asarray(Q),
        k=k, tail=jnp.asarray(tail), ids_pool=jnp.asarray(ids_pool),
        depth=2, interpret=True)
    _assert_same(got, ref, "ids_pool rescore")


# ---------------------------------------------------------------------------
# PagedIndex vs SegmentedIndex: bit parity through the full lifecycle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quant", [False, True], ids=["f32", "int8"])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_paged_parity_lifecycle(quant, backend):
    rng = np.random.default_rng(1)
    n, d, m, B, k = 500, 48, 24, 6, 9
    X = rng.standard_normal((n, m)).astype(np.float32)
    W = jnp.asarray(rng.standard_normal((d, m)).astype(np.float32) * 0.2)
    mean = jnp.asarray(rng.standard_normal(d).astype(np.float32) * 0.1)
    Qraw = jnp.asarray(rng.standard_normal((B, d)).astype(np.float32))
    Qm = jnp.asarray(rng.standard_normal((B, m)).astype(np.float32))
    base = DenseIndex.build(jnp.asarray(X), quantize_int8=quant,
                            backend=backend)
    seg = SegmentedIndex.from_index(base, delta_capacity=96)
    pg = PagedIndex.from_index(base, page_rows=32, seal_rows=96,
                               backend=backend)
    _assert_same(pg.search(Qm, k), seg.search(Qm, k), "base")
    _assert_same(pg.search_projected(Qraw, W, k, mean=mean),
                 seg.search_projected(Qraw, W, k, mean=mean),
                 "base projected")
    # appends, including a big-magnitude block that widens the int8 scale
    blocks = [rng.standard_normal((37, m)).astype(np.float32),
              (rng.standard_normal((20, m)) * 9.0).astype(np.float32),
              rng.standard_normal((150, m)).astype(np.float32)]
    for bl in blocks:
        seg = seg.append(bl)
        pg = pg.append(bl)
    _assert_same(pg.search(Qm, k), seg.search(Qm, k), "after appends")
    _assert_same(pg.search_projected(Qraw, W, k, mean=mean),
                 seg.search_projected(Qraw, W, k, mean=mean),
                 "appends projected")
    # promotion and compaction are pointer swaps: results must not move
    ref = pg.search(Qm, k)
    pg, _ = pg.promote()
    _assert_same(pg.search(Qm, k), ref, "after promote")
    pg, stats = pg.compact_pages()
    _assert_same(pg.search(Qm, k), ref, "after compact")
    assert pg.delta_pages == 0
    # eviction: same contents, host-tier streaming, same bits
    pg, nev = pg.evict(7)
    assert pg.storage.n_host_pages >= 7, nev
    _assert_same(pg.search(Qm, k), ref, "oversubscribed")
    _assert_same(pg.search_projected(Qraw, W, k, mean=mean),
                 seg.search_projected(Qraw, W, k, mean=mean),
                 "oversubscribed projected")
    # append while oversubscribed. Compaction SEALED the open delta, so a
    # post-compact int8 append opens a fresh extent with a fresh scale —
    # compare against the OTHER backend (cross-backend self-parity), not
    # the never-compacted segmented index.
    pg = pg.append(blocks[0])
    if quant:
        other = dataclasses.replace(
            pg, backend="pallas" if backend == "jnp" else "jnp")
        _assert_same(pg.search(Qm, k), other.search(Qm, k),
                     "oversub append xbackend")
    else:
        _assert_same(pg.search(Qm, k), seg.append(blocks[0]).search(Qm, k),
                     "oversub append")


def test_paged_construction_oversubscription_parity():
    rng = np.random.default_rng(2)
    X = rng.standard_normal((500, 24)).astype(np.float32)
    Qm = jnp.asarray(rng.standard_normal((5, 24)).astype(np.float32))
    base = DenseIndex.build(jnp.asarray(X), quantize_int8=True,
                            backend="pallas")
    seg = SegmentedIndex.from_index(base, delta_capacity=96)
    pg = PagedIndex.from_index(base, page_rows=32, pool_pages=6,
                               seal_rows=96, backend="pallas")
    assert pg.storage.n_host_pages > 0
    _assert_same(pg.search(Qm, 8), seg.search(Qm, 8), "construction oversub")


def test_paged_from_segmented_adopts_bytes():
    rng = np.random.default_rng(3)
    X = rng.standard_normal((400, 24)).astype(np.float32)
    Qm = jnp.asarray(rng.standard_normal((5, 24)).astype(np.float32))
    seg = SegmentedIndex.from_index(
        DenseIndex.build(jnp.asarray(X), quantize_int8=True),
        delta_capacity=96)
    seg = seg.append(rng.standard_normal((130, 24)).astype(np.float32))
    pg = PagedIndex.from_segmented(seg, page_rows=32)
    _assert_same(pg.search(Qm, 8), seg.search(Qm, 8), "from_segmented")
    # continued appends stay in lockstep, including a widening block
    bl = (rng.standard_normal((25, 24)) * 8.0).astype(np.float32)
    _assert_same(pg.append(bl).search(Qm, 8), seg.append(bl).search(Qm, 8),
                 "continued append + widen")


def test_paged_cascade_rescore_parity():
    from repro.core.cascade import _cascade_select, _segment_rescore
    rng = np.random.default_rng(4)
    X = rng.standard_normal((400, 24)).astype(np.float32)
    qf = jnp.asarray(rng.standard_normal((5, 24)).astype(np.float32))
    seg = SegmentedIndex.from_index(
        DenseIndex.build(jnp.asarray(X), quantize_int8=True),
        delta_capacity=96)
    seg = seg.append(rng.standard_normal((130, 24)).astype(np.float32))
    pg = PagedIndex.from_segmented(seg, page_rows=32)
    uids = jnp.sort(jnp.asarray(
        rng.choice(seg.n, size=40, replace=False).astype(np.int32)))
    parts, off = [], seg.base.n
    segs = [(seg.base.vectors, seg.base.scale, 0, seg.base.n)]
    for dd in seg.deltas:
        segs.append((dd.vectors, dd.scale, off, dd.n_real))
        off += dd.n_real
    for D, sc, o, nvalid in segs:
        parts.append(_segment_rescore(D, sc, qf, uids, jnp.int32(o),
                                      jnp.int32(nvalid)))
    ref = _cascade_select(tuple(parts), uids, 8)
    _assert_same(_cascade_select((pg.rescore(qf, uids),), uids, 8), ref,
                 "paged rescore")
    # rescore with host-tier pages streams waves, same bits
    pgo, _ = pg.evict(9)
    _assert_same(_cascade_select((pgo.rescore(qf, uids),), uids, 8), ref,
                 "paged rescore oversubscribed")


def test_paged_k_exceeding_n_clamps_like_segmented():
    rng = np.random.default_rng(5)
    X = rng.standard_normal((5, 24)).astype(np.float32)
    Qm = jnp.asarray(rng.standard_normal((3, 24)).astype(np.float32))
    small = DenseIndex.build(jnp.asarray(X))
    _assert_same(PagedIndex.from_index(small, page_rows=32).search(Qm, 50),
                 SegmentedIndex.from_index(small).search(Qm, 50), "k>n")


def test_paged_lifecycle_zero_recompiles_across_page_counts():
    """Append -> search -> promote -> compact -> search at growing page
    counts: the page count is data ([lo,hi) is traced), so once every
    variant is warm the jit cache must not move."""
    rng = np.random.default_rng(6)
    m = 24
    X = rng.standard_normal((256, m)).astype(np.float32)
    Qm = jnp.asarray(rng.standard_normal((4, m)).astype(np.float32))
    pg = PagedIndex.from_index(
        DenseIndex.build(jnp.asarray(X), quantize_int8=True),
        page_rows=32, seal_rows=64)

    def lifecycle(pg, rows):
        pg = pg.append(rng.standard_normal((rows, m)).astype(np.float32))
        pg.search(Qm, 6)
        pg, _ = pg.promote()
        pg, _ = pg.compact_pages()
        jax.block_until_ready(pg.search(Qm, 6)[0])
        return pg

    pg = lifecycle(pg, 48)           # warmup: compile every resident path
    j0 = segment_jit_cache_size()
    counts = set()
    for rows in (32, 48, 80, 96):
        pg = lifecycle(pg, rows)
        counts.add(pg.total_pages)
    assert len(counts) > 1, "page count never changed — sweep is vacuous"
    assert segment_jit_cache_size() == j0, \
        "page-count growth leaked into a static jit key"


# ---------------------------------------------------------------------------
# store: page-granular round-trip, corruption, crash-window manifests
# ---------------------------------------------------------------------------


def _grown_paged(quant, seed=30):
    rng = np.random.default_rng(seed)
    X = rng.standard_normal((400, 24)).astype(np.float32)
    pg = PagedIndex.from_index(
        DenseIndex.build(jnp.asarray(X), quantize_int8=quant),
        page_rows=32, seal_rows=96)
    pg = pg.append(rng.standard_normal((50, 24)).astype(np.float32))
    pg = pg.append((rng.standard_normal((60, 24)) * 6).astype(np.float32))
    return pg


@pytest.mark.parametrize("quant", [False, True], ids=["f32", "int8"])
def test_paged_store_roundtrip_page_granular(tmp_path, quant):
    rng = np.random.default_rng(31)
    Qm = jnp.asarray(rng.standard_normal((5, 24)).astype(np.float32))
    pg = _grown_paged(quant)
    p = str(tmp_path / "idx")
    st = save_index(p, pg)                       # isinstance dispatch branch
    assert "paged" in st.manifest
    pg2 = PagedIndex.load(IndexStore.open(p))
    _assert_same(pg2.search(Qm, 8), pg.search(Qm, 8), "roundtrip")
    # geometry and lifecycle state survive the round-trip
    assert pg2.storage.page_rows == 32 and pg2.storage.seal_rows == 96
    assert ([(e.kind, e.sealed) for e in pg2.storage.extents]
            == [(e.kind, e.sealed) for e in pg.storage.extents])
    # every non-final chunk boundary is page-aligned
    for s in st.manifest["segments"]:
        for c in s["chunks"][:-1]:
            assert c["rows"] % 32 == 0, c


def test_paged_store_host_tier_pages_roundtrip(tmp_path):
    """Saving from an oversubscribed (host-tier) storage writes the same
    bytes as saving the fully resident equivalent."""
    rng = np.random.default_rng(32)
    Qm = jnp.asarray(rng.standard_normal((5, 24)).astype(np.float32))
    pg = _grown_paged(True)
    pr, po = str(tmp_path / "resident"), str(tmp_path / "oversub")
    save_paged_index(pr, pg)
    pg4 = PagedIndex.load(IndexStore.open(pr), pool_pages=5)
    assert pg4.storage.n_host_pages > 0
    _assert_same(pg4.search(Qm, 8), pg.search(Qm, 8), "oversubscribed load")
    save_paged_index(po, pg4)
    pg5 = PagedIndex.load(IndexStore.open(po))
    _assert_same(pg5.search(Qm, 8), pg.search(Qm, 8), "host-tier roundtrip")
    a = sorted(f for f in os.listdir(pr) if f.startswith("vectors"))
    b = sorted(f for f in os.listdir(po) if f.startswith("vectors"))
    assert a == b
    for x in a:
        assert np.array_equal(np.load(os.path.join(pr, x)),
                              np.load(os.path.join(po, x))), x


def test_paged_store_rejects_truncated_blob(tmp_path):
    pg = _grown_paged(True)
    p = str(tmp_path / "idx")
    save_paged_index(p, pg)
    blob = sorted(f for f in os.listdir(p) if f.startswith("vectors"))[0]
    path = os.path.join(p, blob)
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    with pytest.raises(IndexStoreError):
        IndexStore.open(p)


def test_paged_store_rejects_leading_manifest_block(tmp_path):
    """A paged block claiming MORE rows than the segments hold means the
    metadata committed ahead of the data — never recoverable, reject."""
    pg = _grown_paged(True)
    p = str(tmp_path / "idx")
    save_paged_index(p, pg)
    mpath = os.path.join(p, "manifest.json")
    man = json.load(open(mpath))
    man["paged"]["extents"][0]["n"] += 1
    json.dump(man, open(mpath, "w"))
    with pytest.raises(IndexStoreError):
        IndexStore.open(p)


def test_paged_store_accepts_lagging_manifest_block(tmp_path):
    """A paged block missing the newest extent is the crash window
    (data committed, metadata not yet): reload reconstructs it."""
    rng = np.random.default_rng(33)
    Qm = jnp.asarray(rng.standard_normal((5, 24)).astype(np.float32))
    pg = _grown_paged(True)
    p = str(tmp_path / "idx")
    save_paged_index(p, pg)
    mpath = os.path.join(p, "manifest.json")
    man = json.load(open(mpath))
    man["paged"]["extents"] = man["paged"]["extents"][:-1]
    json.dump(man, open(mpath, "w"))
    pgl = PagedIndex.load(IndexStore.open(p))
    _assert_same(pgl.search(Qm, 8), pg.search(Qm, 8), "lagging block")


def test_paged_store_append_reload_bit_parity(tmp_path):
    """Page-granular append -> save -> reload -> append: the reloaded
    index continues bit-for-bit (cross-backend self-parity — the reload
    must not perturb quantised bytes or extent scales)."""
    rng = np.random.default_rng(34)
    Qm = jnp.asarray(rng.standard_normal((5, 24)).astype(np.float32))
    pg = _grown_paged(True)
    p = str(tmp_path / "idx")
    save_paged_index(p, pg)
    pg2 = PagedIndex.load(IndexStore.open(p))
    bl = rng.standard_normal((30, 24)).astype(np.float32)
    a, b = pg.append(bl), pg2.append(bl)
    _assert_same(a.search(Qm, 8), b.search(Qm, 8), "append after reload")
    other = dataclasses.replace(b, backend="pallas")
    _assert_same(b.search(Qm, 8), other.search(Qm, 8), "xbackend")


def test_paged_store_empty_grown_index_roundtrip(tmp_path):
    """An index grown purely from appends (0-row base) round-trips with
    its open delta intact and keeps accepting appends."""
    import types
    rng = np.random.default_rng(35)
    m = 24
    Qm = jnp.asarray(rng.standard_normal((5, m)).astype(np.float32))
    st0 = PagedIndexStorage.from_index(
        types.SimpleNamespace(vectors=np.zeros((0, m), np.int8),
                              scale=np.ones(m, np.float32)),
        page_rows=32, seal_rows=96)
    pg = PagedIndex(storage=st0)
    pg = pg.append(rng.standard_normal((40, m)).astype(np.float32))
    p = str(tmp_path / "idx")
    save_paged_index(p, pg)
    pgr = PagedIndex.load(IndexStore.open(p))
    _assert_same(pgr.search(Qm, 8), pg.search(Qm, 8), "empty-grown")
    assert pgr.storage.extents[0].kind == "delta"
    assert not pgr.storage.extents[0].sealed
    pgr.append(rng.standard_normal((20, m)).astype(np.float32))


# ---------------------------------------------------------------------------
# maintenance: page-based telemetry, durable mirror, refit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("quant", [False, True], ids=["f32", "int8"])
def test_updater_paged_telemetry_and_mirror(tmp_path, quant):
    rng = np.random.default_rng(40)
    n, d = 600, 48
    corpus = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    Qd = jnp.asarray(rng.standard_normal((4, d)).astype(np.float32))
    sp = str(tmp_path / "store")
    u = IndexUpdater.build(corpus, cutoff=0.5, quantize_int8=quant,
                           store_path=sp, delta_capacity=96,
                           paged=True, page_rows=32)
    assert isinstance(u.index, PagedIndex)
    assert u.delta_fraction == 0.0

    def srch(upd):
        W, mean = upd.pruner.projection()
        return upd.index.search_projected(
            Qd, jnp.asarray(W), k=6,
            mean=None if mean is None else jnp.asarray(mean))

    u.add_documents(jnp.asarray(
        rng.standard_normal((50, d)).astype(np.float32)))
    u.add_documents(jnp.asarray(
        (rng.standard_normal((70, d)) * 5).astype(np.float32)))  # widens
    # delta_fraction counts PAGES on a paged index, not rows
    st = u.index.storage
    assert u.delta_fraction == pytest.approx(st.delta_pages / st.n_slots)
    # durable mirror auto-detects paged and reloads to the same bits
    u2 = IndexUpdater.from_store(sp)
    assert isinstance(u2.index, PagedIndex)
    _assert_same(srch(u2), srch(u), "mirror reload")
    # compaction telemetry reports pages moved/freed/host — not rows
    assert u.health()["last_compaction"] is None
    u.compact()
    assert set(u.last_compaction) == {"pages_moved", "pages_freed",
                                      "pages_host"}
    assert u.compactions == 1 and u.delta_fraction == 0.0
    assert all(e.kind == "base" for e in u.index.storage.extents)
    # post-compact appends keep mirroring page-granularly
    u.add_documents(jnp.asarray(
        rng.standard_normal((40, d)).astype(np.float32)))
    _assert_same(srch(IndexUpdater.from_store(sp)), srch(u),
                 "post-compact append reload")
    # refit rebuilds in place and stays paged
    u.refit(corpus)
    assert isinstance(u.index, PagedIndex)


# ---------------------------------------------------------------------------
# serving: promotion/compaction and eviction swaps under live traffic
# ---------------------------------------------------------------------------


def _unit_corpus(n, d=64, seed=77):
    rng = np.random.default_rng(seed)
    D = rng.standard_normal((n, d)).astype(np.float32)
    D /= np.linalg.norm(D, axis=1, keepdims=True)
    return D


def test_paged_swap_soak_append_promote_compact():
    """Live appends (sealing + promoting pages) plus a mid-soak compaction
    while concurrent clients self-retrieve: every reply must answer its
    own query — a dropped reply hangs its client, a half-swapped page
    table misroutes ids."""
    from repro.launch.serve import RetrievalServer
    D = _unit_corpus(96)
    extra = _unit_corpus(200, seed=78)
    pruner = StaticPruner(cutoff=0.25).fit(jnp.asarray(D))
    base = DenseIndex.build(pruner.prune_index(jnp.asarray(D)))
    pg = PagedIndex.from_index(base, page_rows=32, seal_rows=64)
    server = RetrievalServer(pg, pruner, k=1, max_batch=8, pipeline_depth=3)
    up = IndexUpdater(pruner=pruner, index=pg, server=server)
    try:
        assert isinstance(up.index, PagedIndex)   # no segmented rewrap
        up.add_documents(jnp.asarray(extra[:8]))
        up.add_documents(jnp.asarray(0.5 * extra[:8]))
        server.query(D[0])
        swaps0 = server.swap_count
        n_known = 96 + 8

        stop = threading.Event()
        failures: list = []

        def appender():
            i = 16
            while not stop.is_set() and i + 8 <= len(extra):
                up.add_documents(jnp.asarray(extra[i:i + 8]))
                if i == 96:               # pointer-swap compaction mid-soak
                    up.compact()
                i += 8
                stop.wait(0.002)

        def client(cid):
            rng = np.random.default_rng(cid)
            try:
                for _ in range(30):
                    doc = int(rng.integers(0, n_known))
                    q = D[doc] if doc < 96 else extra[doc - 96]
                    _, ids = server.query(q, timeout=30.0)
                    if int(ids[0]) != doc:
                        failures.append((cid, doc, int(ids[0])))
            except BaseException as e:    # noqa: BLE001
                failures.append((cid, "exception", repr(e)))

        app = threading.Thread(target=appender, daemon=True)
        clients = [threading.Thread(target=client, args=(c,), daemon=True)
                   for c in range(6)]
        app.start()
        for t in clients:
            t.start()
        for t in clients:
            t.join(timeout=120.0)
        stop.set()
        app.join(timeout=60.0)
        assert not failures, f"misrouted/dropped replies: {failures[:5]}"
        assert server.swap_count > swaps0, "appends never swapped the index"
        assert up.compactions >= 1
        # every appended doc is retrievable through the server afterwards
        n_final = up.index.n
        for gid in (100, n_final - 1):
            _, ids = server.query(extra[gid - 96])
            assert int(ids[0]) == gid
    finally:
        server.close()


def test_paged_eviction_swaps_under_live_traffic():
    """Residency changes (evict to host tier / readmit) swapped into a
    live server must never change results: clients self-retrieve while a
    maintenance thread flips the same contents between fully resident and
    oversubscribed."""
    from repro.launch.serve import RetrievalServer
    D = _unit_corpus(192)
    pruner = StaticPruner(cutoff=0.25).fit(jnp.asarray(D))
    base = DenseIndex.build(pruner.prune_index(jnp.asarray(D)))
    resident = PagedIndex.from_index(base, page_rows=32, seal_rows=64)
    evicted, nev = resident.evict(3)
    assert nev == 3 and evicted.storage.n_host_pages == 3
    server = RetrievalServer(resident, pruner, k=1, max_batch=8,
                             pipeline_depth=3)
    try:
        server.query(D[0])
        stop = threading.Event()
        failures: list = []

        def flipper():
            flip = 0
            while not stop.is_set():
                server.swap_index((evicted, resident)[flip % 2])
                flip += 1
                stop.wait(0.001)

        def client(cid):
            rng = np.random.default_rng(1000 + cid)
            try:
                for _ in range(40):
                    doc = int(rng.integers(0, len(D)))
                    _, ids = server.query(D[doc], timeout=30.0)
                    if int(ids[0]) != doc:
                        failures.append((cid, doc, int(ids[0])))
            except BaseException as e:    # noqa: BLE001
                failures.append((cid, "exception", repr(e)))

        fl = threading.Thread(target=flipper, daemon=True)
        clients = [threading.Thread(target=client, args=(c,), daemon=True)
                   for c in range(6)]
        fl.start()
        for t in clients:
            t.start()
        for t in clients:
            t.join(timeout=120.0)
        stop.set()
        fl.join(timeout=30.0)
        assert not failures, f"misrouted/dropped replies: {failures[:5]}"
    finally:
        server.close()
