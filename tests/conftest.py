"""Force a 4-way host platform so the sharded parity tests exercise real
multi-device meshes on CPU-only CI.

conftest is imported before any test module, i.e. before the JAX backend
initialises — the only window in which XLA_FLAGS still takes effect. An
operator-set XLA_FLAGS with an explicit device count wins.

(Deliberately inlined rather than importing repro.util — conftest must not
depend on sys.path being configured yet; keep in sync with
``repro.util.force_host_device_count``.)
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"{_flags} --xla_force_host_platform_device_count=4".strip())
