"""Force a 4-way host platform so the sharded parity tests exercise real
multi-device meshes on CPU-only CI.

conftest is imported before any test module, i.e. before the JAX backend
initialises — the only window in which XLA_FLAGS still takes effect. An
operator-set XLA_FLAGS with an explicit device count wins.

(Deliberately inlined rather than importing repro.util — conftest must not
depend on sys.path being configured yet; keep in sync with
``repro.util.force_host_device_count``.)
"""
import os

_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        f"{_flags} --xla_force_host_platform_device_count=4".strip())

import pytest  # noqa: E402  (must come after the XLA_FLAGS block)


@pytest.fixture(scope="session", autouse=True)
def _lock_monitor():
    """Record every lock acquisition order the serving tests exercise.

    ``threading.Lock/RLock/Condition`` are wrapped for the whole session
    (scoped to locks created by ``repro`` code), and the observed
    held->acquired graph lands in ``LOCK_graph.json`` at session end. CI
    feeds it back through ``python -m repro.analysis --lock-graph`` so a
    runtime order the static deadlock lint cannot see fails the gate.
    """
    from repro.analysis import lock_sanitizer
    mon = lock_sanitizer.LockMonitor()
    originals = lock_sanitizer.instrument(mon)
    try:
        yield mon
    finally:
        lock_sanitizer.uninstrument(originals)
        mon.write(os.path.join(os.path.dirname(__file__), os.pardir,
                               "LOCK_graph.json"))
