"""End-to-end behaviour of the paper's system.

Full pipeline on CPU: train a tiny bi-encoder with contrastive loss ->
encode a synthetic corpus -> fit PCA offline -> prune index + queries ->
serve top-k -> score with IR metrics -> verify the paper's qualitative
claims hold on the *learned* (not just synthetic-gaussian) embeddings.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DenseIndex, StaticPruner
from repro.core.metrics import evaluate_run, mean_metrics
from repro.data.tokens import pair_batch
from repro.models.biencoder import BiEncoderConfig, contrastive_loss, encode, init_biencoder
from repro.optim import adamw_init, adamw_update

CFG = BiEncoderConfig(n_layers=2, d_model=64, n_heads=4, d_ff=128, vocab=256,
                      embed_dim=64, max_len=32, compute_dtype="float32",
                      remat=False, temperature=0.1)


@pytest.fixture(scope="module")
def trained_encoder():
    params = init_biencoder(jax.random.PRNGKey(0), CFG)
    opt = adamw_init(params)
    step = jax.jit(lambda p, o, b: _step(p, o, b))

    def _step(p, o, b):
        loss, g = jax.value_and_grad(contrastive_loss)(p, b, CFG)
        p, o = adamw_update(g, o, p, jnp.float32(3e-4))
        return p, o, loss

    losses = []
    for t in range(30):
        b = {k: jnp.asarray(v) for k, v in
             pair_batch(0, t, batch=32, seq_len=16, vocab=256).items()}
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    assert losses[-1] < losses[0], "contrastive training must descend"
    return params


def _encode_corpus(params, n_docs=600, seq_len=16):
    """Corpus = topic-structured docs; queries = noisy same-topic variants."""
    docs, queries, qrels = [], [], {}
    for i in range(0, n_docs, 64):
        b = pair_batch(99, i, batch=min(64, n_docs - i), seq_len=seq_len,
                       vocab=256)
        docs.append(b["d_tokens"])
        queries.append(b["q_tokens"])
    d_tokens = np.concatenate(docs)[:n_docs]
    q_tokens = np.concatenate(queries)[:n_docs]
    ones = jnp.ones((n_docs, seq_len), jnp.int32)
    D = np.asarray(encode(params, jnp.asarray(d_tokens), ones, CFG))
    # 40 queries; each query's relevant doc is its paired doc
    Q = np.asarray(encode(params, jnp.asarray(q_tokens[:40]),
                          ones[:40], CFG))
    qrels = {i: {i: 1} for i in range(40)}
    return jnp.asarray(D), jnp.asarray(Q), qrels


def _run_metrics(D, Q, qrels, pruner=None):
    if pruner is not None:
        D = pruner.prune_index(D)
        Q = pruner.transform_queries(Q)
    _, ids = DenseIndex.build(D).search(Q, k=20)
    run = {i: list(map(int, np.asarray(ids)[i])) for i in range(Q.shape[0])}
    return mean_metrics(evaluate_run(run, qrels, metrics=("MRR@10",)))["MRR@10"]


def test_end_to_end_train_encode_prune_serve(trained_encoder):
    D, Q, qrels = _encode_corpus(trained_encoder)
    base = _run_metrics(D, Q, qrels)
    assert base > 0.2, f"trained encoder must retrieve paired docs, got {base}"

    pruner = StaticPruner(cutoff=0.5).fit(D)
    pruned = _run_metrics(D, Q, qrels, pruner)
    # paper claim on learned embeddings: 50% pruning keeps most quality
    assert pruned > base * 0.75, (base, pruned)


def test_end_to_end_index_size_halves(trained_encoder):
    D, _, _ = _encode_corpus(trained_encoder, n_docs=200)
    pruner = StaticPruner(cutoff=0.5).fit(D)
    full = DenseIndex.build(D)
    pruned = DenseIndex.build(pruner.prune_index(D))
    assert pruned.nbytes == full.nbytes // 2


def test_end_to_end_pallas_kernel_serving_path(trained_encoder):
    D, Q, qrels = _encode_corpus(trained_encoder, n_docs=300)
    pruner = StaticPruner(cutoff=0.5).fit(D)
    Dh = pruner.prune_index(D)
    qh = pruner.transform_queries(Q)
    a = DenseIndex.build(Dh, backend="jnp").search(qh, k=10)
    b = DenseIndex.build(Dh, backend="pallas").search(qh, k=10)
    for i in range(qh.shape[0]):
        assert set(np.asarray(a[1])[i].tolist()) == set(np.asarray(b[1])[i].tolist())


def test_serving_driver_roundtrip():
    """RetrievalServer: batched async queries return correct neighbours."""
    from repro.launch.serve import RetrievalServer
    rng = np.random.default_rng(0)
    D = jnp.asarray(rng.standard_normal((500, 32)), jnp.float32)
    pruner = StaticPruner(cutoff=0.25).fit(D)
    index = DenseIndex.build(pruner.prune_index(D))
    server = RetrievalServer(index, pruner, k=5, max_batch=8)
    try:
        q = np.asarray(D[42])
        scores, ids = server.query(q)
        assert 42 in ids.tolist()   # self-retrieval through the pruned space
    finally:
        server.close()
