"""Sharding rules engine: divisibility fallback, logical axes, family rules."""
import jax
from jax.sharding import PartitionSpec as P

from repro.par.compat import abstract_mesh
from repro.par.sharding import (
    ShardingRules,
    gnn_rules,
    lm_rules,
    logical_to_physical,
    recsys_rules,
    spec_for,
)

# rules resolve against mesh *shape* only — an abstract mesh needs no
# devices; compat.abstract_mesh handles both AbstractMesh signatures
MESH2 = abstract_mesh((1, 2), ("data", "model"))


def test_logical_axes():
    assert logical_to_physical("dp", MESH2) == ("data",)
    assert logical_to_physical("tp", MESH2) == ("model",)
    assert logical_to_physical("fsdp", MESH2) == ("data", "model")
    m3 = abstract_mesh((1, 1, 2), ("pod", "data", "model"))
    assert logical_to_physical("dp", m3) == ("pod", "data")


def test_divisibility_fallback():
    rules = ShardingRules([(r"w$", [((0, "tp"),)]), (r".*", [()])])
    # 4 % 2 == 0 -> sharded; 3 % 2 != 0 -> replicated
    assert rules.spec("a/w", (4, 8), MESH2) == P("model", None)
    assert rules.spec("a/w", (3, 8), MESH2) == P()


def test_clause_group_ordering():
    # first group that FULLY fits wins; others ignored
    rules = ShardingRules([
        (r"moe$", [((0, "ep"), (1, "dp")), ((1, "tp"),)]),
        (r".*", [()]),
    ])
    # group 1 fits (E=4 % 2, ff=2 % 1(data))
    assert rules.spec("moe", (4, 2), MESH2) == P("model", "data")
    # E=3 doesn't divide: falls to group 2 on dim 1
    assert rules.spec("moe", (3, 8), MESH2) == P(None, "model")


def test_lm_rules_2d_fsdp_tp():
    rules = lm_rules()
    # (L, d, out): out over model + d over data(=1 here, divides)
    spec = rules.spec("layers/attn/wq/w", (4, 64, 128), MESH2)
    assert spec == P(None, "data", "model")
    # embed: vocab over model, d over data
    assert rules.spec("embed", (1000, 64), MESH2) == P("model", "data")


def test_lm_rules_smollm_fallbacks():
    # 16-wide model axis vs 9-head smollm: fused proj (576) shards,
    # per-head reshape never sees a 9-way constraint
    mesh16 = abstract_mesh((1, 16), ("data", "model"))
    rules = lm_rules()
    spec = rules.spec("layers/attn/wq/w", (30, 576, 576), mesh16)
    assert spec == P(None, "data", "model")


def test_moe_rules_ep_vs_tp():
    mesh16 = abstract_mesh((1, 16), ("data", "model"))
    rules = lm_rules(moe=True)
    # arctic: 128 experts % 16 == 0 -> EP (+ ff over dp)
    assert rules.spec("layers/moe/w1", (35, 128, 7168, 4864), mesh16) \
        == P(None, "model", None, "data")
    # mixtral: 8 experts % 16 != 0 -> falls to TP-inside-expert
    spec = rules.spec("layers/moe/w1", (32, 8, 4096, 14336), mesh16)
    assert spec == P(None, None, "data", "model")


def test_recsys_rules_fsdp_tables():
    rules = recsys_rules()
    assert rules.spec("tables/0", (1024, 128), MESH2) == P(("data", "model"), None)
    assert rules.spec("user_embed", (2048, 64), MESH2) == P(("data", "model"), None)


def test_gnn_rules_replicate():
    rules = gnn_rules()
    assert rules.spec("layers/edge_mlp/0/w", (16, 48, 16), MESH2) == P()


def test_spec_for_tree():
    tree = {"embed": jax.ShapeDtypeStruct((100, 4), "float32"),
            "norm": {"scale": jax.ShapeDtypeStruct((7,), "float32")}}
    specs = spec_for(tree, MESH2, lm_rules())
    assert specs["embed"] == P("model", "data")
    assert specs["norm"]["scale"] == P()
