"""Sharded/serial parity: every distributed path against its single-device
oracle, on 1- and 4-device CPU meshes (conftest forces 4 host devices).

Oracles: ``_scan_topk`` / ``DenseIndex.search`` for ``ShardedDenseIndex``,
``fit_pca`` for ``fit_pca_distributed`` / ``StaticPruner.fit_distributed``.
Covers int8 quantisation and row counts not divisible by the device count
(device-padding rows must never surface in results).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DenseIndex, ShardedDenseIndex, StaticPruner, fit_pca, fit_pca_distributed
from repro.par import compat

RNG = np.random.default_rng(42)


def _mesh(ndev):
    if jax.device_count() < ndev:
        pytest.skip(f"needs {ndev} devices, have {jax.device_count()}")
    return jax.make_mesh((ndev,), ("data",))


def _data(n, d, nq=6):
    D = jnp.asarray(RNG.standard_normal((n, d)), jnp.float32)
    Q = jnp.asarray(RNG.standard_normal((nq, d)), jnp.float32)
    return D, Q


@pytest.mark.parametrize("ndev", [1, 4])
def test_sharded_search_matches_dense(ndev):
    mesh = _mesh(ndev)
    D, Q = _data(2048, 32)
    s, ids = ShardedDenseIndex.build(D, mesh).search(Q, k=10)
    ws, wids = DenseIndex.build(D).search(Q, k=10)
    assert (np.asarray(ids) == np.asarray(wids)).all()
    np.testing.assert_allclose(np.asarray(s), np.asarray(ws),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("ndev", [1, 4])
def test_sharded_search_uneven_rows(ndev):
    """1003 % 4 != 0: device-padding rows score exactly 0.0, so with every
    real score forced negative an unmasked pad row would always win the
    local top-k — ids must stay < n and match the unpadded oracle."""
    mesh = _mesh(ndev)
    D, Q = _data(1003, 16)
    D, Q = jnp.abs(D), -jnp.abs(Q)        # all real scores < 0
    sidx = ShardedDenseIndex.build(D, mesh)
    assert sidx.n == 1003
    s, ids = sidx.search(Q, k=10)
    _, wids = DenseIndex.build(D).search(Q, k=10)
    assert int(ids.max()) < 1003
    assert float(s.max()) < 0.0
    assert (np.asarray(ids) == np.asarray(wids)).all()


@pytest.mark.parametrize("ndev", [1, 4])
def test_sharded_search_int8_matches_dense_int8(ndev):
    mesh = _mesh(ndev)
    D, Q = _data(1000, 32)
    s, ids = ShardedDenseIndex.build(D, mesh, quantize_int8=True).search(Q, k=10)
    ws, wids = DenseIndex.build(D, quantize_int8=True).search(Q, k=10)
    assert (np.asarray(ids) == np.asarray(wids)).all()
    np.testing.assert_allclose(np.asarray(s), np.asarray(ws),
                               rtol=1e-4, atol=1e-4)


def test_sharded_search_int8_uneven_rows_4dev():
    mesh = _mesh(4)
    D, Q = _data(1001, 16)
    D, Q = jnp.abs(D), -jnp.abs(Q)        # all real scores < 0 (see above)
    sidx = ShardedDenseIndex.build(D, mesh, quantize_int8=True)
    s, ids = sidx.search(Q, k=7)
    _, wids = DenseIndex.build(D, quantize_int8=True).search(Q, k=7)
    assert int(ids.max()) < 1001
    assert float(s.max()) < 0.0
    assert (np.asarray(ids) == np.asarray(wids)).all()


def test_sharded_pallas_backend_matches_jnp_4dev():
    mesh = _mesh(4)
    D, Q = _data(512, 32)
    _, a = ShardedDenseIndex.build(D, mesh, backend="pallas").search(Q, k=10)
    _, b = ShardedDenseIndex.build(D, mesh, backend="jnp").search(Q, k=10)
    for row in range(Q.shape[0]):
        assert set(np.asarray(a)[row].tolist()) == set(np.asarray(b)[row].tolist())


@pytest.mark.parametrize("ndev", [1, 4])
def test_sharded_hierarchical_matches_flat_1d(ndev):
    """merge='hierarchical' on a 1-axis mesh degenerates to the flat single
    stage — results must be bit-identical, and match the dense oracle."""
    mesh = _mesh(ndev)
    D, Q = _data(2048, 32)
    idx = ShardedDenseIndex.build(D, mesh)
    sf, if_ = idx.search(Q, k=10, merge="flat")
    sh, ih = idx.search(Q, k=10, merge="hierarchical")
    assert (np.asarray(sf) == np.asarray(sh)).all()
    assert (np.asarray(if_) == np.asarray(ih)).all()
    _, wids = DenseIndex.build(D).search(Q, k=10)
    assert (np.asarray(ih) == np.asarray(wids)).all()


def test_sharded_hierarchical_matches_flat_2d_mesh():
    """2x2 mesh: the hierarchical merge really runs two all-gather stages
    (within 'col', then across 'row') — bit-identical to the flat merge,
    tied scores included."""
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    mesh = jax.make_mesh((2, 2), ("row", "col"))
    D, Q = _data(1003, 16)          # uneven rows: device padding in play
    # duplicate a row across shards so the merges must tie-break identically
    D = D.at[900].set(D[5])
    idx = ShardedDenseIndex.build(D, mesh, merge="hierarchical")
    sh, ih = idx.search(Q, k=10)    # build-time default: hierarchical
    sf, if_ = idx.search(Q, k=10, merge="flat")
    assert (np.asarray(sf) == np.asarray(sh)).all()
    assert (np.asarray(if_) == np.asarray(ih)).all()
    _, wids = DenseIndex.build(D).search(Q, k=10)
    assert (np.asarray(ih) == np.asarray(wids)).all()


def test_sharded_hierarchical_int8_2d_mesh():
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    mesh = jax.make_mesh((2, 2), ("row", "col"))
    D, Q = _data(1001, 16)
    D, Q = jnp.abs(D), -jnp.abs(Q)  # all real scores < 0 (pad-row trap)
    idx = ShardedDenseIndex.build(D, mesh, quantize_int8=True,
                                  merge="hierarchical")
    s, ids = idx.search(Q, k=7)
    _, wids = DenseIndex.build(D, quantize_int8=True).search(Q, k=7)
    assert int(ids.max()) < 1001
    assert float(s.max()) < 0.0
    assert (np.asarray(ids) == np.asarray(wids)).all()


def test_sharded_pad_rows_cannot_displace_real_candidates():
    """Device-padding rows score 0.0 — above every real score here — and
    would win the padded shard's local top-k before any post-hoc mask. The
    shard-local select must over-fetch (k+pad) so the shard's true top-k
    real rows survive. Regression: the global top-k is concentrated in the
    padded (last) shard."""
    mesh = _mesh(4)
    n, k = 29, 4                     # 29 % 4 = 1 -> 3 pad rows, last shard
    D = np.abs(RNG.standard_normal((n, 8))).astype(np.float32)
    D[-k:] *= 0.01                   # last shard holds the least-negative rows
    D, Q = jnp.asarray(D), -jnp.abs(
        jnp.asarray(RNG.standard_normal((3, 8)), jnp.float32))
    for merge in ("flat", "hierarchical"):
        s, ids = ShardedDenseIndex.build(D, mesh).search(Q, k=k, merge=merge)
        ws, wids = DenseIndex.build(D).search(Q, k=k)
        assert (np.asarray(ids) == np.asarray(wids)).all()
        np.testing.assert_allclose(np.asarray(s), np.asarray(ws),
                                   rtol=1e-5, atol=1e-5)


def test_sharded_k_exceeds_shard_rows():
    """k larger than any single shard's row count: the per-shard scan pads
    with sentinels and the global merge must still match the dense oracle."""
    mesh = _mesh(4)
    D, Q = _data(20, 8)             # 5 rows per shard < k=10
    s, ids = ShardedDenseIndex.build(D, mesh).search(Q, k=10)
    ws, wids = DenseIndex.build(D).search(Q, k=10)
    assert (np.asarray(ids) == np.asarray(wids)).all()
    np.testing.assert_allclose(np.asarray(s), np.asarray(ws),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("ndev", [1, 4])
def test_fit_pca_distributed_matches_serial(ndev):
    mesh = _mesh(ndev)
    D, _ = _data(1003, 24)   # uneven rows: gram_distributed zero-pads
    s1 = fit_pca(D)
    s2 = fit_pca_distributed(D, mesh)
    np.testing.assert_allclose(np.asarray(s1.eigenvalues),
                               np.asarray(s2.eigenvalues),
                               rtol=1e-3, atol=1e-3)
    assert int(s2.n_samples) == 1003
    # eigenvectors match up to sign on the well-separated top components
    dots = np.abs(np.sum(np.asarray(s1.components) * np.asarray(s2.components),
                         axis=0))
    assert (dots[:8] > 0.99).all()


def test_static_pruner_fit_distributed_end_to_end():
    """Paper pipeline on a 4-device mesh: distributed fit -> sharded pruned
    index -> search matches the all-serial pipeline."""
    mesh = _mesh(4)
    D, Q = _data(1200, 32)
    serial = StaticPruner(cutoff=0.5).fit(D)
    dist = StaticPruner(cutoff=0.5).fit_distributed(D, mesh)
    assert dist.kept_dims == serial.kept_dims

    sidx = dist.build_index(D, mesh=mesh)
    assert isinstance(sidx, ShardedDenseIndex)
    _, ids = sidx.search(dist.transform_queries(Q), k=10)
    _, wids = serial.build_index(D).search(serial.transform_queries(Q), k=10)
    # same rotation up to column sign; scores in the rotated space agree
    assert (np.asarray(ids) == np.asarray(wids)).all()


# ---------------------------------------------------------------------------
# fused projection parity: search_projected(raw q) must be bit-identical to
# transform_queries(q) -> search on every layout x backend x dtype
# ---------------------------------------------------------------------------


def _fused_vs_two_step(idx, pruner, Qraw, k=10):
    W, mean = pruner.projection()
    qh = pruner.transform_queries(Qraw)
    s0, i0 = idx.search(qh, k=k)
    s1, i1 = idx.search_projected(Qraw, W, k=k, mean=mean)
    assert (np.asarray(i0) == np.asarray(i1)).all()
    assert (np.asarray(s0) == np.asarray(s1)).all()   # bit-identical


@pytest.mark.parametrize("dtype", ["f32", "bf16", "int8"])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_search_projected_matches_two_step_dense(backend, dtype):
    D, Q = _data(700, 32)
    pruner = StaticPruner(cutoff=0.5).fit(D)
    Dh = pruner.prune_index(D)
    if dtype == "int8":
        idx = DenseIndex.build(Dh, quantize_int8=True, backend=backend)
    else:
        idx = DenseIndex.build(
            Dh.astype(jnp.bfloat16) if dtype == "bf16" else Dh,
            backend=backend)
    _fused_vs_two_step(idx, pruner, Q)


@pytest.mark.parametrize("dtype", ["f32", "bf16", "int8"])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("ndev", [1, 4])
def test_search_projected_matches_two_step_sharded(ndev, backend, dtype):
    """Uneven shard rows on purpose: 1003 % 4 != 0, so the fused path must
    agree under device padding too."""
    mesh = _mesh(ndev)
    D, Q = _data(1003, 32)
    pruner = StaticPruner(cutoff=0.5).fit(D)
    Dh = pruner.prune_index(D)
    if dtype == "int8":
        idx = ShardedDenseIndex.build(Dh, mesh, quantize_int8=True,
                                      backend=backend)
    else:
        idx = ShardedDenseIndex.build(
            Dh.astype(jnp.bfloat16) if dtype == "bf16" else Dh,
            mesh, backend=backend)
    _fused_vs_two_step(idx, pruner, Q)


def test_search_projected_centered_pruner_dense_and_sharded():
    """center=True exercises the mean-subtraction branch of the fused jit."""
    mesh = _mesh(4)
    D, Q = _data(900, 24)
    pruner = StaticPruner(cutoff=0.5, center=True).fit(D)
    Dh = pruner.prune_index(D)
    _fused_vs_two_step(DenseIndex.build(Dh), pruner, Q)
    _fused_vs_two_step(ShardedDenseIndex.build(Dh, mesh), pruner, Q)


def test_search_projected_hierarchical_2d_mesh_int8():
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    mesh = jax.make_mesh((2, 2), ("row", "col"))
    D, Q = _data(1001, 16)
    pruner = StaticPruner(cutoff=0.5).fit(D)
    Dh = pruner.prune_index(D)
    idx = ShardedDenseIndex.build(Dh, mesh, quantize_int8=True,
                                  merge="hierarchical")
    _fused_vs_two_step(idx, pruner, Q, k=7)


def test_search_projected_is_single_dispatch_dense():
    """The fused path must stay ONE compiled computation: the d->m
    projection matmul traces into the same jit as the top-k scan instead
    of running as its own dispatch on the hot path."""
    import repro.core.index as index_mod
    D, Q = _data(600, 32)
    pruner = StaticPruner(cutoff=0.5).fit(D)
    Dh = pruner.prune_index(D)
    W, _ = pruner.projection()
    jaxpr = jax.make_jaxpr(
        lambda d, w, q: index_mod._dense_search_projected(
            d, None, w, None, q, 10, None, "jnp"))(Dh, W, Q)
    prims = {e.primitive.name for e in jaxpr.jaxpr.eqns}
    # the (B, d) @ (d, m) projection is a dot_general at this level; the
    # scan carries the streamed top-k — both inside one traced computation
    assert "dot_general" in prims or "pjit" in prims
    flat = jaxpr.pretty_print(use_color=False)
    assert "dot_general" in flat and ("scan" in flat or "top_k" in flat)


def test_compat_abstract_mesh_roundtrip():
    am = compat.abstract_mesh((2, 4), ("data", "model"))
    assert tuple(am.axis_names) == ("data", "model")
    assert dict(am.shape) == {"data": 2, "model": 4}
    with pytest.raises(ValueError):
        compat.abstract_mesh((2, 4), ("data",))


def test_compat_axis_size_inside_shard_map():
    from jax.sharding import PartitionSpec as P
    mesh = _mesh(4)
    out = compat.shard_map(lambda: jnp.asarray(compat.axis_size("data")),
                           mesh=mesh, in_specs=(), out_specs=P(),
                           check_vma=False)()
    assert int(out) == 4
