"""Benchmark orchestrator — one section per paper table/figure + perf.

Prints ``name,us_per_call,derived`` CSV rows (perf benches) and the
markdown tables reproducing the paper's Tables 1-2 / Figures 1-2. The perf
section additionally writes ``BENCH_perf.json`` at the repo root — the
per-PR perf trajectory (us/call, qps, index bytes, recall@10 per serving
config) that CI uploads as an artifact.

  PYTHONPATH=src python -m benchmarks.run [--only table1,fig2] [--fast]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH_PERF_PATH = REPO_ROOT / "BENCH_perf.json"

# every serve_pipeline config row must carry both modes with these keys —
# a refactor that silently drops a bench section must fail CI, not ship a
# BENCH_perf.json that quietly stopped tracking the serving trajectory
_SERVE_MODE_KEYS = ("qps", "p50_ms", "p95_ms", "p99_ms", "worker_qps")
# live_index rows per config: the static baseline + the segmented server at
# each append rate; segmented rows must additionally report append/swap
# telemetry and a ZERO steady-state recompile count
_LIVE_ROWS = ("static", "append_0", "append_low", "append_high")
_LIVE_APPEND_KEYS = ("appended_rows", "swaps", "recompiles_steady")
# cascade Pareto rows: every operating point (baselines included) must
# report its oracle recall and saturated worker qps, at least one
# single-resolution baseline row must anchor the comparison, and the
# steady-state recompile count must be zero (fixed-nk cascade contract)
_CASCADE_ROW_KEYS = ("recall_at_10", "worker_qps", "recompiles_steady")
# fleet chaos rows: every drive reports its latency tier plus the
# droplessness/misroute accounting; the kill and rollout drives carry hard
# robustness invariants (zero lost accepted replies, zero misrouted
# replies, rollback on regression, p99 held vs the healthy baseline)
_FLEET_ROWS = ("healthy", "kill_restart", "bad_rollout")
_FLEET_ROW_KEYS = ("n", "n_ok", "p50_ms", "p99_ms", "lost_accepted",
                   "misrouted", "health_ok")
# paged rows per config: both layouts at both append rates, every row with
# full serve telemetry and a ZERO steady-state recompile count (paged
# appends are page-pointer swaps at fixed dispatch shapes); the depth sweep
# must cover {1,2,4} with a residency bit-identity check per depth, and
# the oversubscription headline row must hold >=80% of the fully-resident
# qps at pipeline depth >=2
_PAGED_ROWS = ("segmented_append_0", "segmented_append_high",
               "paged_append_0", "paged_append_high")
_PAGED_DEPTHS = ("depth_1", "depth_2", "depth_4")
_PAGED_OVERSUB_FLOOR = 0.80


def check_perf_schema(results: dict) -> None:
    """Validate the perf dict before it becomes ``BENCH_perf.json``."""
    sp = results.get("serve_pipeline")
    if not isinstance(sp, dict) or not isinstance(sp.get("configs"), dict) \
            or not sp["configs"]:
        raise SystemExit("BENCH_perf.json schema: missing or empty "
                         "'serve_pipeline.configs' section")
    for name, row in sp["configs"].items():
        for mode in ("sync", "pipelined"):
            if mode not in row:
                raise SystemExit(f"serve_pipeline.{name}: missing '{mode}' row")
            missing = [k for k in _SERVE_MODE_KEYS if k not in row[mode]]
            if missing:
                raise SystemExit(f"serve_pipeline.{name}.{mode}: missing "
                                 f"keys {missing}")
        if "match" not in row:
            raise SystemExit(f"serve_pipeline.{name}: missing sync-vs-"
                             f"pipelined 'match' flag")
        if not row["match"]:
            raise SystemExit(f"serve_pipeline.{name}: pipelined results "
                             f"diverged from the sync path (match=False)")
    li = results.get("live_index")
    if not isinstance(li, dict) or not isinstance(li.get("configs"), dict) \
            or not li["configs"]:
        raise SystemExit("BENCH_perf.json schema: missing or empty "
                         "'live_index.configs' section")
    for name, cfg in li["configs"].items():
        for rowname in _LIVE_ROWS:
            if rowname not in cfg:
                raise SystemExit(f"live_index.{name}: missing "
                                 f"'{rowname}' row")
            missing = [k for k in _SERVE_MODE_KEYS if k not in cfg[rowname]]
            if missing:
                raise SystemExit(f"live_index.{name}.{rowname}: missing "
                                 f"keys {missing}")
            if rowname.startswith("append"):
                missing = [k for k in _LIVE_APPEND_KEYS
                           if k not in cfg[rowname]]
                if missing:
                    raise SystemExit(f"live_index.{name}.{rowname}: missing "
                                     f"keys {missing}")
                if cfg[rowname]["recompiles_steady"] != 0:
                    raise SystemExit(
                        f"live_index.{name}.{rowname}: "
                        f"{cfg[rowname]['recompiles_steady']} steady-state "
                        f"recompiles — appends must never stall serving on "
                        f"a jit compile (fixed-capacity delta contract)")
    ca = results.get("cascade")
    if not isinstance(ca, dict) or not isinstance(ca.get("rows"), dict) \
            or not ca["rows"]:
        raise SystemExit("BENCH_perf.json schema: missing or empty "
                         "'cascade.rows' section")
    if not any(row.get("baseline") for row in ca["rows"].values()):
        raise SystemExit("cascade: no single-resolution baseline row — "
                         "the Pareto sweep has lost its reference point")
    for name, row in ca["rows"].items():
        missing = [k for k in _CASCADE_ROW_KEYS if k not in row]
        if missing:
            raise SystemExit(f"cascade.{name}: missing keys {missing}")
        if row["recompiles_steady"] != 0:
            raise SystemExit(
                f"cascade.{name}: {row['recompiles_steady']} steady-state "
                f"recompiles — with nk fixed, every cascade dispatch must "
                f"reuse its compiled shape")
    pg = results.get("paged")
    if not isinstance(pg, dict) or not isinstance(pg.get("configs"), dict) \
            or not pg["configs"]:
        raise SystemExit("BENCH_perf.json schema: missing or empty "
                         "'paged.configs' section")
    for name, cfg in pg["configs"].items():
        for rowname in _PAGED_ROWS:
            if rowname not in cfg:
                raise SystemExit(f"paged.{name}: missing '{rowname}' row")
            row = cfg[rowname]
            missing = [k for k in _SERVE_MODE_KEYS + _LIVE_APPEND_KEYS
                       if k not in row]
            if missing:
                raise SystemExit(f"paged.{name}.{rowname}: missing keys "
                                 f"{missing}")
            if row["recompiles_steady"] != 0:
                raise SystemExit(
                    f"paged.{name}.{rowname}: "
                    f"{row['recompiles_steady']} steady-state recompiles — "
                    f"page-pointer appends must never stall serving on a "
                    f"jit compile (paged fixed-shape dispatch contract)")
    ds = pg.get("depth_sweep")
    if not isinstance(ds, dict):
        raise SystemExit("paged: missing 'depth_sweep' section")
    for dname in _PAGED_DEPTHS:
        drow = ds.get(dname)
        if not isinstance(drow, dict) or "resident" not in drow \
                or "oversubscribed" not in drow:
            raise SystemExit(f"paged.depth_sweep.{dname}: missing "
                             f"resident/oversubscribed rows")
        if not drow.get("match"):
            raise SystemExit(
                f"paged.depth_sweep.{dname}: oversubscribed results "
                f"diverged from fully resident (match=False) — host-tier "
                f"streaming must change throughput, never results")
        if drow["oversubscribed"]["host_pages"] == 0:
            raise SystemExit(f"paged.depth_sweep.{dname}: oversubscribed "
                             f"row has no host-tier pages — the pool cap "
                             f"did not oversubscribe")
    ov = pg.get("oversubscription")
    if not isinstance(ov, dict) or "ratio" not in ov:
        raise SystemExit("paged: missing 'oversubscription' row")
    if ov.get("depth", 0) < 2:
        raise SystemExit("paged.oversubscription: headline row must come "
                         "from pipeline depth >= 2")
    if ov["ratio"] < _PAGED_OVERSUB_FLOOR:
        raise SystemExit(
            f"paged.oversubscription: {ov['ratio']:.2f} of fully-resident "
            f"qps with {ov.get('host_pages')} host pages — below the "
            f"{_PAGED_OVERSUB_FLOOR:.2f} floor; host-tier staging is not "
            f"hiding behind compute at depth {ov.get('depth')}")
    sw = pg.get("page_count_sweep")
    if not isinstance(sw, dict) or "recompiles_steady" not in sw:
        raise SystemExit("paged: missing 'page_count_sweep' section")
    if len(set(sw.get("page_counts", []))) < 2:
        raise SystemExit("paged.page_count_sweep: page count never "
                         "changed — the sweep is not sweeping")
    if sw["recompiles_steady"] != 0:
        raise SystemExit(
            f"paged.page_count_sweep: {sw['recompiles_steady']} "
            f"steady-state recompiles across page counts "
            f"{sw.get('page_counts')} — [lo,hi) is traced, page count is "
            f"data; growth must never leak into a static jit key")
    ga = pg.get("guard_ab")
    if not isinstance(ga, dict) or not ga.get("bitwise_identical"):
        raise SystemExit("paged.guard_ab: per-row guard results are not "
                         "bit-identical to the whole-batch guard — the "
                         "guard is an optimisation, never a result change")
    fl = results.get("fleet")
    if not isinstance(fl, dict):
        raise SystemExit("BENCH_perf.json schema: missing 'fleet' section")
    for rowname in _FLEET_ROWS:
        row = fl.get(rowname)
        if not isinstance(row, dict):
            raise SystemExit(f"fleet: missing '{rowname}' drive row")
        missing = [k for k in _FLEET_ROW_KEYS if k not in row]
        if missing:
            raise SystemExit(f"fleet.{rowname}: missing keys {missing}")
        if row["lost_accepted"] != 0:
            raise SystemExit(
                f"fleet.{rowname}: {row['lost_accepted']} accepted replies "
                f"never got a terminal payload — the router dropped "
                f"accepted work (droplessness invariant)")
        if row["misrouted"] != 0:
            raise SystemExit(
                f"fleet.{rowname}: {row['misrouted']} replies answered with "
                f"wrong ids — a reply was served by an unvalidated or "
                f"stale index (misroute invariant)")
        if not row["health_ok"]:
            raise SystemExit(f"fleet.{rowname}: fleet unhealthy after the "
                             f"drive (a replica never rejoined, or a "
                             f"background maintenance thread died)")
    if not fl["bad_rollout"].get("rolled_back"):
        raise SystemExit("fleet.bad_rollout: the recall-regressing rollout "
                         "was NOT rolled back — the health gate is dead")
    p99_healthy = fl["healthy"]["p99_ms"]
    p99_kill = fl["kill_restart"]["p99_ms"]
    if p99_kill > 2.0 * max(p99_healthy, 1.0):
        raise SystemExit(
            f"fleet.kill_restart: p99 {p99_kill:.1f}ms vs healthy "
            f"{p99_healthy:.1f}ms — a single replica kill/restart must not "
            f"double the latency tier (failover is supposed to contain it)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,fig1,fig2,perf,size")
    ap.add_argument("--fast", action="store_true",
                    help="smaller corpora (CI-sized)")
    ap.add_argument("--host-devices", type=int, default=4,
                    help="CPU device count for the sharded perf configs")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def need(name):
        return only is None or name in only

    if need("perf"):
        # multi-device CPU mesh for the sharded sweep configs; must happen
        # before the bench imports below pull in jax and touch a device
        from repro.util import force_host_device_count
        force_host_device_count(args.host_devices)

    import benchmarks.common as common
    import benchmarks.perf_qps as perf_qps
    if args.fast:
        common.N_DOCS = 4000
        common.DIM = 256
        perf_qps.N_DOCS = 4000
        perf_qps.DIM = 256

    t0 = time.time()
    datasets = None

    if need("table1") or need("table2") or need("fig1") or need("fig2"):
        print(f"# building {3} corpora (n={common.N_DOCS}, d={common.DIM})",
              flush=True)
        datasets = common.load_all_datasets(common.N_DOCS, common.DIM)

    if need("table1"):
        from benchmarks.table1_indomain import run as t1
        t1(datasets)
    if need("table2"):
        from benchmarks.table2_ood import run as t2
        t2(datasets)
    if need("fig1"):
        from benchmarks.fig1_cutoff import run as f1
        f1(datasets)
    if need("fig2"):
        from benchmarks.fig2_nembed import run as f2
        f2(datasets)
    if need("perf"):
        print("\n### Perf — name,us_per_call,derived")
        results = perf_qps.run()
        check_perf_schema(results)
        BENCH_PERF_PATH.write_text(json.dumps(results, indent=2,
                                              sort_keys=True) + "\n")
        print(f"# wrote {BENCH_PERF_PATH}")
    if need("size"):
        print("\n### Index size — name,us_per_call,derived")
        from benchmarks.index_size import run as isz
        isz()

    print(f"\n# benchmarks done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
