"""Shared benchmark plumbing: datasets, retrieval, significance marking."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import DenseIndex
from repro.core.metrics import evaluate_run
from repro.data.synthetic import make_dataset

ENCODERS = ("tasb", "contriever", "ance")
QUERY_SETS = ("dl19", "dl20", "dlhard", "devsmall", "covid")
CUTOFFS = (0.25, 0.50, 0.75)
METRICS = ("AP", "MRR@10", "nDCG@10")

# benchmark scale (paper: 8.8M docs, d=768; container: CPU-sized but the
# same d and protocol)
N_DOCS = 20000
DIM = 768


def retrieve(D, Q, k=1000):
    _, ids = DenseIndex.build(D).search(jnp.asarray(Q), k=min(k, D.shape[0]))
    ids = np.asarray(ids)
    return {i: ids[i].tolist() for i in range(ids.shape[0])}


def eval_system(D, queries, qrels, pruner=None):
    """Per-query metric vectors for one system over all query sets."""
    out = {}
    Dx = pruner.prune_index(D) if pruner else D
    for qs, Q in queries.items():
        Qx = pruner.transform_queries(jnp.asarray(Q)) if pruner else jnp.asarray(Q)
        run = retrieve(Dx, Qx)
        out[qs] = evaluate_run(run, qrels[qs], metrics=METRICS)
    return out


def fmt_cell(val: float, sig: bool) -> str:
    return f"{val:.4f}{'†' if sig else ' '}"


def load_all_datasets(n_docs=N_DOCS, d=DIM, seed=0):
    return {enc: make_dataset(enc, n_docs=n_docs, d=d, seed=seed,
                              query_sets=QUERY_SETS)
            for enc in ENCODERS}


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.s = time.time() - self.t0
