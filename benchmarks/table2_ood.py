"""Table 2 — Out-of-domain PCA: W_m fit on a different corpus (paper RQ2)."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import (
    CUTOFFS,
    DIM,
    METRICS,
    N_DOCS,
    QUERY_SETS,
    eval_system,
    fmt_cell,
    load_all_datasets,
)
from repro.core import StaticPruner
from repro.core.metrics import wilcoxon_significant
from repro.data.synthetic import make_ood_corpus


def run(datasets=None, emit=print) -> dict:
    datasets = datasets or load_all_datasets()
    results = {}
    for enc, ds in datasets.items():
        D = jnp.asarray(ds.docs)
        ood = jnp.asarray(make_ood_corpus(enc, n_docs=N_DOCS, d=DIM))
        base = eval_system(D, ds.queries, ds.qrels)
        rows = {"baseline": base}
        for c in CUTOFFS:
            pruner = StaticPruner(cutoff=c).fit(ood)   # fit OUT of domain
            rows[c] = eval_system(D, ds.queries, ds.qrels, pruner)
        results[enc] = rows

        emit(f"\n### Table 2 — {enc} (out-of-domain PCA)")
        hdr = "| c (%) | " + " | ".join(
            f"{qs}:{m}" for qs in QUERY_SETS for m in METRICS) + " |"
        emit(hdr)
        emit("|" + "---|" * (len(QUERY_SETS) * len(METRICS) + 1))
        for label, row in rows.items():
            cells = []
            for qs in QUERY_SETS:
                for m in METRICS:
                    v = float(row[qs][m].mean())
                    if label == "baseline":
                        cells.append(f"{v:.4f} ")
                    else:
                        sig, _ = wilcoxon_significant(base[qs][m], row[qs][m])
                        cells.append(fmt_cell(v, sig))
            name = "-" if label == "baseline" else f"{int(label*100)}"
            emit(f"| {name} | " + " | ".join(cells) + " |")
    return results


def main():
    run()


if __name__ == "__main__":
    main()
