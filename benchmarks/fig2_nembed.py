"""Fig 2 — nDCG@10 on DL19 vs number of embeddings used to fit PCA.

Paper RQ3: decompositions from 10^3 / 10^4 / 10^5 documents are
near-indistinguishable. Scaled to the container corpus: {10^3, 10^4, all}.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import eval_system, load_all_datasets
from repro.core import StaticPruner
from repro.core.metrics import wilcoxon_significant

FIT_SIZES = (1_000, 10_000, None)     # None = full corpus
CUTOFFS = (0.25, 0.5, 0.75)


def run(datasets=None, emit=print) -> dict:
    datasets = datasets or load_all_datasets()
    results = {}
    emit("\n### Fig 2 — nDCG@10 (DL19) vs #embeddings used for PCA fit")
    emit("| encoder | fit size | " +
         " | ".join(f"c={int(c*100)}%" for c in CUTOFFS) + " |")
    emit("|" + "---|" * (len(CUTOFFS) + 2))
    for enc, ds in datasets.items():
        D = jnp.asarray(ds.docs)
        queries = {"dl19": ds.queries["dl19"]}
        qrels = {"dl19": ds.qrels["dl19"]}
        base = eval_system(D, queries, qrels)
        per_enc = {}
        for n_fit in FIT_SIZES:
            Dfit = D if n_fit is None else D[:n_fit]
            row = {}
            cells = []
            for c in CUTOFFS:
                pruner = StaticPruner(cutoff=c).fit(Dfit)
                r = eval_system(D, queries, qrels, pruner)
                row[c] = r
                v = float(r["dl19"]["nDCG@10"].mean())
                sig, _ = wilcoxon_significant(base["dl19"]["nDCG@10"],
                                              r["dl19"]["nDCG@10"])
                cells.append(f"{v:.4f}{'*' if sig else ' '}")
            label = "all" if n_fit is None else f"{n_fit}"
            emit(f"| {enc} | {label} | " + " | ".join(cells) + " |")
            per_enc[label] = row
        results[enc] = per_enc
    return results


def main():
    run()


if __name__ == "__main__":
    main()
