"""Fig 1 — effectiveness on DL19 vs pruning cutoff (fine sweep, 3 encoders)."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import METRICS, eval_system, load_all_datasets
from repro.core import StaticPruner
from repro.core.metrics import wilcoxon_significant

CUTOFF_SWEEP = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9)


def run(datasets=None, emit=print) -> dict:
    datasets = datasets or load_all_datasets()
    results = {}
    emit("\n### Fig 1 — DL19 effectiveness vs cutoff "
         "(* = significant vs baseline)")
    emit("| encoder | metric | base | " +
         " | ".join(f"c={int(c*100)}%" for c in CUTOFF_SWEEP) + " |")
    emit("|" + "---|" * (len(CUTOFF_SWEEP) + 3))
    for enc, ds in datasets.items():
        D = jnp.asarray(ds.docs)
        queries = {"dl19": ds.queries["dl19"]}
        qrels = {"dl19": ds.qrels["dl19"]}
        base = eval_system(D, queries, qrels)
        curve = {}
        for c in CUTOFF_SWEEP:
            pruner = StaticPruner(cutoff=c).fit(D)
            curve[c] = eval_system(D, queries, qrels, pruner)
        results[enc] = {"base": base, "curve": curve}
        for m in METRICS:
            cells = []
            for c in CUTOFF_SWEEP:
                v = float(curve[c]["dl19"][m].mean())
                sig, _ = wilcoxon_significant(base["dl19"][m], curve[c]["dl19"][m])
                cells.append(f"{v:.4f}{'*' if sig else ' '}")
            emit(f"| {enc} | {m} | {float(base['dl19'][m].mean()):.4f} | "
                 + " | ".join(cells) + " |")
    return results


def main():
    run()


if __name__ == "__main__":
    main()
