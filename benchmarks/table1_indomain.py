"""Table 1 — In-domain PCA pruning at cutoffs {25, 50, 75}%.

Three encoder spectra × five query sets × {AP, MRR@10, nDCG@10}, with a
two-tailed paired Wilcoxon signed-rank test vs the unpruned baseline
(† = significant at α=0.05), exactly the paper's protocol. PCA is fit on
min(10^5, corpus) in-domain embeddings.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import CUTOFFS, METRICS, QUERY_SETS, eval_system, fmt_cell, load_all_datasets
from repro.core import StaticPruner
from repro.core.metrics import wilcoxon_significant


def run(datasets=None, emit=print) -> dict:
    datasets = datasets or load_all_datasets()
    results = {}
    for enc, ds in datasets.items():
        D = jnp.asarray(ds.docs)
        base = eval_system(D, ds.queries, ds.qrels)
        rows = {"baseline": base}
        for c in CUTOFFS:
            pruner = StaticPruner(cutoff=c).fit(D)
            rows[c] = eval_system(D, ds.queries, ds.qrels, pruner)
        results[enc] = rows

        emit(f"\n### Table 1 — {enc} (in-domain PCA)")
        hdr = "| c (%) | " + " | ".join(
            f"{qs}:{m}" for qs in QUERY_SETS for m in METRICS) + " |"
        emit(hdr)
        emit("|" + "---|" * (len(QUERY_SETS) * len(METRICS) + 1))
        for label, row in rows.items():
            cells = []
            for qs in QUERY_SETS:
                for m in METRICS:
                    v = float(row[qs][m].mean())
                    if label == "baseline":
                        cells.append(f"{v:.4f} ")
                    else:
                        sig, _ = wilcoxon_significant(base[qs][m], row[qs][m])
                        cells.append(fmt_cell(v, sig))
            name = "-" if label == "baseline" else f"{int(label*100)}"
            emit(f"| {name} | " + " | ".join(cells) + " |")
    return results


def main():
    run()


if __name__ == "__main__":
    main()
