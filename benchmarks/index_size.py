"""Index space-occupancy table: O(mn + md) vs O(dn) (paper §2)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import DenseIndex, StaticPruner

N_DOCS = 50_000
DIM = 768


def run(emit=print) -> dict:
    rng = np.random.default_rng(0)
    D = jnp.asarray(rng.standard_normal((N_DOCS, DIM)), jnp.float32)
    full = DenseIndex.build(D)
    emit(f"index_full,0,bytes={full.nbytes} dims={DIM}")
    out = {"full": full.nbytes}
    for c in (0.25, 0.5, 0.75):
        pr = StaticPruner(cutoff=c).fit(D)
        m = pr.kept_dims
        idx = DenseIndex.build(pr.prune_index(D))
        w_bytes = m * DIM * 4     # W_m transform matrix (O(md))
        total = idx.nbytes + w_bytes
        emit(f"index_pca_c{int(c*100)},0,bytes={total} "
             f"ratio={total/full.nbytes:.3f} predicted={m/DIM:.3f}")
        out[c] = total
        idx8 = pr.build_index(D, quantize_int8=True)
        emit(f"index_pca_c{int(c*100)}_int8,0,bytes={idx8.nbytes + w_bytes} "
             f"ratio={(idx8.nbytes + w_bytes)/full.nbytes:.3f}")
        out[f"{c}_int8"] = idx8.nbytes + w_bytes
    return out


def main():
    run()


if __name__ == "__main__":
    main()
