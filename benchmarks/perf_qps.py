"""Perf — the paper's §2 complexity claims, measured, plus the serving sweep.

  * query scoring time O(dn) -> O(dm + mn): wall-clock speedup vs d/m
  * index bytes O(dn) -> O(mn) (+ md for W_m)
  * serving sweep {backend x dtype x layout x merge}: us/call, qps, bytes,
    recall@10 per config — the trajectory ``BENCH_perf.json`` tracks PR
    over PR (written by ``benchmarks.run``)
  * select-path A/B: the two-stage + block-skip ``_scan_topk`` against the
    legacy concat-and-full-top_k select on the same corpus
  * paged: paged-vs-segmented serve qps under live appends, the Pallas
    DMA pipeline depth sweep (resident vs oversubscribed, host-tier
    streaming), the zero-recompile page-count lifecycle, and the per-row
    block-skip guard A/B
  * serve_pipeline: sync vs pipelined RetrievalServer under open-loop
    (Poisson) load — worker qps, p50/p95/p99 latency, occupancy, and a
    bit-identity check between the two workers per config
  * beyond-paper: int8 index on top of PCA (bytes /4, recall preserved)

Emits ``name,us_per_call,derived`` CSV rows like every other bench and
returns a JSON-ready dict.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import threading
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DenseIndex, ShardedDenseIndex, StaticPruner
from repro.core.index import _scan_topk, _topk_merge
from repro.core.store import IndexStore, save_index

N_DOCS = 100_000
DIM = 768
N_QUERIES = 16
K = 10
ITERS = 3
# interpret-mode Pallas pays a huge per-op interpreter tax off-TPU; cap its
# corpus so the sweep stays tractable (the config records its own n)
PALLAS_MAX_DOCS = 20_000
# serve_pipeline section: open-loop queries per drive, in-flight window
N_SERVE = 192
SERVE_DEPTH = 3
SERVE_BATCH = 32
# live_index section: queries per drive, append block, delta capacity (one
# open delta must absorb the whole drive's appends — steady state means a
# CONSTANT segment count, which is what makes zero recompiles assertable)
N_LIVE = 160
LIVE_APPEND_BLOCK = 128
LIVE_DELTA_CAP = 16384
LIVE_APPEND_RATES = {"append_0": 0.0, "append_low": 256.0,
                     "append_high": 2048.0}   # rows/s
# paged section: page geometry, DMA pipeline depths, and the append blocks
# (in pruned m-dim rows) that walk the page count up during the
# zero-recompile sweep
PAGED_PAGE_ROWS = 256
PAGED_DEPTHS = (1, 2, 4)
PAGED_SWEEP_ROWS = (64, 128, 192, 256, 320)
PAGED_SWEEP_PAGE_ROWS = 64
PAGED_SWEEP_SEAL_ROWS = 128
# cascade section: coarse widths x shortlist depths (N*k candidates per
# query) x full-resolution dtypes; the coarse pass is always int8 and the
# rows serve through the jnp backend (interpret-mode pallas pays an
# intractable per-candidate tax at these shortlist depths off-TPU)
CASCADE_M_COARSE = (32, 64, 128, 192)
CASCADE_N_FACTORS = (4, 8, 16, 32, 64)
# fleet section: replicas, offered rate, and drive lengths. The corpus is
# unit-norm with self-retrieval queries (query i IS row i) so every
# successful reply's top-1 id is exactly checkable — "misrouted" is a
# measured count, not an inference. The kill drive is long enough that the
# handful of failover-delayed replies around the kill cannot dominate p99.
FLEET_REPLICAS = 3
FLEET_RATE = 150.0
FLEET_N_DOCS = 4096
FLEET_DIM = 64
N_FLEET_HEALTHY = 512
N_FLEET_KILL = 1536
N_FLEET_ROLLOUT = 512


def _bench(fn, *args, iters: int = ITERS) -> float:
    """Median us/call. Blocks on the result inside the timed region each
    iteration — with JAX's async dispatch, timing a loop of un-blocked
    calls measures enqueue rate, not latency."""
    jax.block_until_ready(fn(*args))   # compile + warmup
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def _recall(ids_ref: np.ndarray, ids: np.ndarray, k: int) -> float:
    return float(np.mean([
        len(set(ids_ref[i].tolist()) & set(ids[i].tolist())) / k
        for i in range(ids_ref.shape[0])]))


# ---------------------------------------------------------------------------
# legacy select path (pre two-stage/block-skip) — kept only for the A/B row
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("k", "block"))
def _scan_topk_concat(D, Q, k, block=65536):
    """The old select: concat running + full strip, one big top_k per strip."""
    n, d = D.shape
    B = Q.shape[0]
    block = min(block, n)
    nblocks = -(-n // block)
    pad = nblocks * block - n
    Dp = jnp.pad(D, ((0, pad), (0, 0))) if pad else D
    blocks = Dp.reshape(nblocks, block, d)
    Qf = Q.astype(jnp.float32)

    def body(carry, inp):
        bs, bi = carry
        blk, start = inp
        s = Qf @ blk.T.astype(jnp.float32)
        ids = start + jnp.arange(block, dtype=jnp.int32)[None, :]
        s = jnp.where(ids < n, s, -jnp.inf)
        cs = jnp.concatenate([bs, s], axis=1)
        ci = jnp.concatenate([bi, jnp.broadcast_to(ids, (B, block))], axis=1)
        return _topk_merge(cs, ci, k), None

    init = (jnp.full((B, k), -jnp.inf, jnp.float32),
            jnp.full((B, k), -1, jnp.int32))
    starts = jnp.arange(nblocks, dtype=jnp.int32) * block
    (scores, ids), _ = jax.lax.scan(body, init, (blocks, starts))
    return scores, ids


# ---------------------------------------------------------------------------
# serving sweep
# ---------------------------------------------------------------------------


def _build_index(D, dtype: str, backend: str, layout: str, mesh):
    if layout == "dense":
        if dtype == "int8":
            return DenseIndex.build(D, quantize_int8=True, backend=backend)
        v = D.astype(jnp.bfloat16) if dtype == "bf16" else D
        return DenseIndex.build(v, backend=backend)
    merge = "hierarchical" if layout == "sharded-hier" else "flat"
    if dtype == "int8":
        return ShardedDenseIndex.build(D, mesh, quantize_int8=True,
                                       backend=backend, merge=merge)
    v = D.astype(jnp.bfloat16) if dtype == "bf16" else D
    return ShardedDenseIndex.build(v, mesh, backend=backend, merge=merge)


def _sweep(D, Q, ids_ref, emit) -> dict:
    """{backend x dtype x layout(+merge)} serving grid on the pruned index."""
    from repro.launch.serve import _serve_mesh
    ndev = jax.device_count()
    layouts = ["dense"]
    meshes = {}
    if ndev > 1:
        # flat merges over a 1-D mesh; hierarchical needs the factored 2-D
        # mesh (on 1-D it degenerates to the same single stage — measuring
        # that would just duplicate the flat row)
        meshes["sharded-flat"] = _serve_mesh(ndev, "flat")
        meshes["sharded-hier"] = _serve_mesh(ndev, "hierarchical")
        layouts += ["sharded-flat", "sharded-hier"]
    else:
        emit("# sweep: single device — sharded configs skipped")
    out = {}
    B = Q.shape[0]
    for backend in ("jnp", "pallas"):
        n_cap = min(D.shape[0], PALLAS_MAX_DOCS) if backend == "pallas" \
            else D.shape[0]
        Dc = D[:n_cap]
        if n_cap == D.shape[0]:
            ref_c = ids_ref
        else:   # exact f32 ranking on the capped corpus
            _, rid = DenseIndex.build(Dc).search(Q, k=K)
            ref_c = np.asarray(rid)
        for dtype in ("f32", "bf16", "int8"):
            for layout in layouts:
                name = f"{backend}_{dtype}_{layout}"
                mesh = meshes.get(layout)
                idx = _build_index(Dc, dtype, backend, layout, mesh)
                us = _bench(lambda q: idx.search(q, k=K), Q)
                _, ids = idx.search(Q, k=K)
                rec = _recall(ref_c, np.asarray(ids), K)
                qps = B / (us / 1e6)
                out[name] = dict(us=us, qps=qps, nbytes=int(idx.nbytes),
                                 recall=rec, n=n_cap, dim=int(D.shape[1]),
                                 mesh=(list(mesh.devices.shape)
                                       if mesh is not None else None))
                emit(f"sweep_{name},{us:.0f},qps={qps:.1f} "
                     f"bytes={idx.nbytes} recall@10={rec:.3f} n={n_cap}")
    return out


class _LegacySyncServer:
    """The pre-PR synchronous serving loop, faithfully reproduced for the
    sync row of the serve_pipeline bench (the ``_scan_topk_concat`` of the
    serving layer).

    One worker thread that (a) sleep-polls the request queue while
    assembling a batch, (b) dispatches projection (``transform_queries``)
    and search as separate computations, (c) blocks on ``np.asarray`` for
    the full D2H round-trip before assembling the next batch, and (d)
    dispatches whatever batch size arrived — so under ragged open-loop
    load every novel size jit-compiles a fresh full-index scan mid-serve.
    The pipelined server exists to delete exactly these four behaviours.
    """

    def __init__(self, index, pruner, k=10, max_batch=32):
        import queue as _q
        self.index, self.pruner, self.k = index, pruner, k
        self.max_batch = max_batch
        self.q: "queue.Queue" = _q.Queue()
        self.batch_log: list = []   # (size, t0, t1) — same shape as the new log
        self._log_lock = threading.Lock()   # worker_stats is borrowed from
        self._stop = threading.Event()      # RetrievalServer and locks it
        self._worker = threading.Thread(target=self._loop, daemon=True)
        self._worker.start()

    def reset_stats(self):
        # single worker thread appends; same drive-side contract as
        # RetrievalServer.reset_stats
        self.batch_log.clear()

    def _next_batch(self):
        import queue as _q
        try:
            first = self.q.get(timeout=0.5)
        except _q.Empty:
            return None
        items = [first]
        t0 = time.time()
        while len(items) < self.max_batch and (time.time() - t0) < 0.002:
            try:
                items.append(self.q.get_nowait())
            except _q.Empty:
                time.sleep(0.0002)
        return np.stack([x[0] for x in items]), [x[1] for x in items]

    def _loop(self):
        while not self._stop.is_set():
            item = self._next_batch()
            if item is None:
                continue
            vecs, replies = item
            t0 = time.perf_counter()
            q = jnp.asarray(vecs)
            if self.pruner is not None:
                q = self.pruner.transform_queries(q)      # separate dispatch
            scores, ids = self.index.search(q, k=self.k)  # second dispatch
            scores = np.asarray(scores)                   # full D2H block
            ids = np.asarray(ids)
            self.batch_log.append((len(replies), t0, time.perf_counter()))
            for i, r in enumerate(replies):
                r.put((scores[i], ids[i]))

    def submit(self, qvec):
        import queue as _q
        reply: "queue.Queue" = _q.Queue(maxsize=1)
        self.q.put((qvec, reply))
        return reply

    def query(self, qvec, timeout: float = 10.0):
        return self.submit(qvec).get(timeout=timeout)

    def worker_stats(self):
        from repro.launch.serve import RetrievalServer
        return RetrievalServer.worker_stats(self)

    def close(self):
        self._stop.set()
        self._worker.join(timeout=60.0)


def _serve_mode_row(res: dict, stats: dict) -> dict:
    return dict(qps=res["achieved_qps"], p50_ms=res["p50_ms"],
                p95_ms=res["p95_ms"], p99_ms=res["p99_ms"],
                worker_qps=stats["worker_qps"],
                service_qps=stats["service_qps"],
                occupancy=stats["occupancy"], batches=stats["batches"])


def _serve_pipeline(Dh, pruner, Q_raw, emit) -> dict:
    """Sync vs pipelined serving under open-loop (Poisson) load.

    Per config {dense, sharded} x {f32, int8}, three servers run the same
    Poisson arrival tape at 1.5x the fused batched capacity:

      * ``sync``       — the pre-PR synchronous loop (``_LegacySyncServer``:
                         separate projection dispatch, ragged batch shapes
                         that recompile mid-serve, D2H-blocking before the
                         next batch is even assembled);
      * ``sync_fused`` — the new worker at pipeline_depth=1: fused
                         ``search_projected`` + fixed-shape padded batches,
                         but still one batch in flight (attribution row —
                         how much of the win is fusion vs pipelining);
      * ``pipelined``  — the new stager/completer worker at depth 3.

    Every query's (scores, ids) is collected from all three; the two
    new-architecture workers (same compiled fn, same padded shape) are
    compared bit-exactly — the pipeline must change throughput, never
    results. The legacy worker's ids agreement is reported alongside (its
    ragged batch shapes hit different matmul kernels, ~1e-7 score jitter).
    """
    from repro.launch.serve import RetrievalServer, _drive_open, _serve_mesh
    ndev = jax.device_count()
    layouts = [("dense", None)]
    if ndev > 1:
        layouts.append(("sharded", _serve_mesh(ndev, "flat")))
    else:
        emit("# serve_pipeline: single device — sharded configs skipped")
    Q = np.asarray(Q_raw)
    Qs = np.tile(Q, (N_SERVE // len(Q) + 1, 1))[:N_SERVE]
    W, mean = pruner.projection()
    configs = {}
    for layout, mesh in layouts:
        for dtype in ("f32", "int8"):
            quant = dtype == "int8"
            if mesh is None:
                idx = DenseIndex.build(Dh, quantize_int8=quant)
            else:
                idx = ShardedDenseIndex.build(Dh, mesh, quantize_int8=quant)
            name = f"{layout}_{dtype}"

            # offered rate: 1.5x the fused full-batch capacity, so every
            # worker saturates and worker-side qps is the comparison
            tb = _bench(lambda q: idx.search_projected(q, W, k=K, mean=mean),
                        jnp.asarray(Qs[:SERVE_BATCH])) / 1e6
            rate = 1.5 * SERVE_BATCH / tb

            rows, outs = {}, {}
            servers = (
                ("sync", lambda: _LegacySyncServer(
                    idx, pruner, k=K, max_batch=SERVE_BATCH)),
                ("sync_fused", lambda: RetrievalServer(
                    idx, pruner, k=K, max_batch=SERVE_BATCH,
                    pipeline_depth=1)),
                ("pipelined", lambda: RetrievalServer(
                    idx, pruner, k=K, max_batch=SERVE_BATCH,
                    pipeline_depth=SERVE_DEPTH)),
            )
            for mode, make in servers:
                srv = make()
                res = _drive_open(srv, Qs, rate=rate, collect=True)
                stats = srv.worker_stats()
                srv.close()
                outs[mode] = res.pop("results")
                rows[mode] = _serve_mode_row(res, stats)
            # scheduling must never change results: depth-1 and depth-3 run
            # the same compiled fn on the same padded shape, so (scores,
            # ids) are required bit-identical. The legacy worker dispatches
            # ragged unpadded shapes whose small-B matmul kernels round
            # differently at ~1e-7 — its ids agreement is reported, not
            # asserted bitwise.
            match = all(
                (np.asarray(a[0]) == np.asarray(b[0])).all()
                and (np.asarray(a[1]) == np.asarray(b[1])).all()
                for a, b in zip(outs["sync_fused"], outs["pipelined"]))
            legacy_ids = float(np.mean([
                (np.asarray(a[1]) == np.asarray(b[1])).all()
                for a, b in zip(outs["sync"], outs["pipelined"])]))
            configs[name] = dict(
                n=int(Dh.shape[0]), dim=int(Dh.shape[1]),
                nbytes=int(idx.nbytes), rate_qps=float(rate),
                match=bool(match), legacy_ids_equal=legacy_ids, **rows)
            emit(f"serve_pipeline_{name},{rows['pipelined']['p50_ms']*1e3:.0f},"
                 f"sync={rows['sync']['worker_qps']:.1f}qps "
                 f"fused={rows['sync_fused']['worker_qps']:.1f}qps "
                 f"piped={rows['pipelined']['worker_qps']:.1f}qps "
                 f"(offered {rate:.1f}) "
                 f"p99 {rows['sync']['p99_ms']:.0f}->"
                 f"{rows['pipelined']['p99_ms']:.0f}ms match={match}")
    return dict(meta=dict(depth=int(SERVE_DEPTH), max_batch=int(SERVE_BATCH),
                          n_queries=int(N_SERVE),
                          rate_policy="1.5x fused batched capacity",
                          sync_row="pre-PR synchronous worker "
                                   "(_LegacySyncServer)"),
                configs=configs)


def _live_index(Dh, pruner, Q_raw, emit) -> dict:
    """Serve QPS under concurrent live appends vs the static baseline.

    Per dtype {f32, int8}, the same Poisson tape drives four servers at
    ~0.8x the fused batched capacity:

      * ``static``      — monolithic ``DenseIndex`` (the pre-segment
                          architecture: appends would require a rebuild);
      * ``append_0``    — ``SegmentedIndex`` server, no appends (the cost
                          of the segmented read path itself);
      * ``append_low`` / ``append_high`` — a background ``IndexUpdater``
                          appends raw documents at that rate while the
                          drive runs; every append swaps a fresh segment
                          set into the server atomically.

    Each segmented row also records the number of search-path jit
    compilations during the timed drive (``recompiles_steady``) — the
    acceptance bar is ZERO: deltas dispatch at fixed padded capacity with
    traced live counts, so corpus growth never stalls serving on a
    compile. ``benchmarks/run.py`` schema-checks all of this before
    BENCH_perf.json is written.
    """
    from repro.core.index import SegmentedIndex, segment_jit_cache_size
    from repro.core.maintenance import IndexUpdater
    from repro.launch.serve import RetrievalServer, _drive_open
    d_raw = int(pruner.state.d)
    Q = np.asarray(Q_raw)
    Qs = np.tile(Q, (N_LIVE // len(Q) + 1, 1))[:N_LIVE]
    W, mean = pruner.projection()
    rng = np.random.default_rng(42)
    configs = {}
    for dtype in ("f32", "int8"):
        quant = dtype == "int8"
        idx = DenseIndex.build(Dh, quantize_int8=quant)
        tb = _bench(lambda q: idx.search_projected(q, W, k=K, mean=mean),
                    jnp.asarray(Qs[:SERVE_BATCH])) / 1e6
        rate = 0.8 * SERVE_BATCH / tb

        rows = {}
        srv = RetrievalServer(idx, pruner, k=K, max_batch=SERVE_BATCH,
                              pipeline_depth=SERVE_DEPTH)
        res = _drive_open(srv, Qs, rate=rate)
        rows["static"] = _serve_mode_row(res, srv.worker_stats())
        srv.close()

        for name, arate in LIVE_APPEND_RATES.items():
            seg = SegmentedIndex.from_index(idx,
                                            delta_capacity=LIVE_DELTA_CAP)
            srv = RetrievalServer(seg, pruner, k=K, max_batch=SERVE_BATCH,
                                  pipeline_depth=SERVE_DEPTH)
            up = IndexUpdater(pruner=pruner, index=seg, server=srv,
                              delta_capacity=LIVE_DELTA_CAP)
            # warm appends (open + a provably NON-widening extend at the
            # live block size: 0.5x rows already present) + query: compile
            # the delta scan, the 2-segment merge, the append-side
            # projection and the extend's update-slice BEFORE the timed
            # drive — everything after this is steady state (widening
            # extends do a plain host requant + upload, no jit)
            warm = rng.standard_normal(
                (LIVE_APPEND_BLOCK, d_raw)).astype(np.float32)
            up.add_documents(jnp.asarray(warm))
            up.add_documents(jnp.asarray(0.5 * warm))
            srv.query(Qs[0])
            jit0 = segment_jit_cache_size()
            n0 = up.index.n
            stop = threading.Event()

            def appender(arate=arate):
                while not stop.is_set():
                    t0 = time.perf_counter()
                    up.add_documents(jnp.asarray(
                        rng.standard_normal((LIVE_APPEND_BLOCK, d_raw))
                        .astype(np.float32)))
                    lag = (LIVE_APPEND_BLOCK / arate
                           - (time.perf_counter() - t0))
                    if lag > 0:
                        stop.wait(lag)

            th = None
            if arate > 0:
                th = threading.Thread(target=appender, daemon=True)
                th.start()
            res = _drive_open(srv, Qs, rate=rate)
            if th is not None:
                stop.set()
                th.join(timeout=30.0)
            recompiles = segment_jit_cache_size() - jit0
            rows[name] = dict(_serve_mode_row(res, srv.worker_stats()),
                              appended_rows=int(up.index.n - n0),
                              swaps=int(srv.swap_count),
                              recompiles_steady=int(recompiles))
            srv.close()
        configs[f"dense_{dtype}"] = dict(
            n=int(Dh.shape[0]), dim=int(Dh.shape[1]), rate_qps=float(rate),
            **rows)
        emit(f"live_index_dense_{dtype},{rows['append_high']['p50_ms']*1e3:.0f},"
             f"static={rows['static']['worker_qps']:.1f}qps "
             f"seg={rows['append_0']['worker_qps']:.1f}qps "
             f"low={rows['append_low']['worker_qps']:.1f}qps"
             f"(+{rows['append_low']['appended_rows']}) "
             f"high={rows['append_high']['worker_qps']:.1f}qps"
             f"(+{rows['append_high']['appended_rows']}r/"
             f"{rows['append_high']['swaps']}sw) "
             f"recompiles={rows['append_high']['recompiles_steady']}")
    return dict(meta=dict(n_queries=int(N_LIVE),
                          append_block=int(LIVE_APPEND_BLOCK),
                          delta_capacity=int(LIVE_DELTA_CAP),
                          append_rates_rows_per_s={
                              k: float(v)
                              for k, v in LIVE_APPEND_RATES.items()},
                          rate_policy="0.8x fused batched capacity"),
                configs=configs)


def _paged(Dh, pruner, Q_raw, emit) -> dict:
    """Paged index memory: the four tracked claims, one subsection each.

      * ``configs`` — paged vs segmented serve qps at append rates
        {0, high} on the live-append harness: per dtype, the same Poisson
        tape at the same offered rate (0.8x the dense fused capacity)
        drives four servers — ``{segmented, paged} x {append_0,
        append_high}`` — with a background ``IndexUpdater`` supplying the
        appends.  Every row reports the steady-state jit-compile count;
        the schema gate pins it to ZERO (paged appends are page-pointer
        swaps at fixed dispatch shapes — growth must never stall serving
        on a compile).
      * ``depth_sweep`` — DMA/compute overlap through the interpreted
        Pallas paged kernel at pipeline depth {1, 2, 4}, fully resident
        vs oversubscribed (pool capped at half the index, overflow on the
        host tier), with a bit-identity check between the two residencies
        at each depth (streaming must change throughput, never results).
      * ``oversubscription`` — the headline row: best depth>=2
        oversubscribed qps as a fraction of fully resident.  The schema
        floor is 0.80 — host-tier staging has to hide behind compute once
        the pipeline is at least double-buffered.
      * ``page_count_sweep`` — full lifecycle (append -> search ->
        promote -> compact -> search) at growing page counts, ``jnp``
        backend: the page count is data ([lo, hi) slot bounds are
        traced), so the compiled-variant count must not move.
      * ``guard_ab`` — the per-row block-skip guard (masked merge) vs the
        legacy whole-batch guard on the same blocked scan, asserted
        bit-identical (the guard is an optimisation, never a result).
    """
    from repro.core.index import SegmentedIndex, segment_jit_cache_size
    from repro.core.maintenance import IndexUpdater
    from repro.core.paged import PagedIndex
    from repro.launch.serve import RetrievalServer, _drive_open
    d_raw = int(pruner.state.d)
    Q = np.asarray(Q_raw)
    Qs = np.tile(Q, (N_LIVE // len(Q) + 1, 1))[:N_LIVE]
    W, mean = pruner.projection()
    rng = np.random.default_rng(17)

    # -- paged vs segmented under live appends ------------------------------
    configs = {}
    for dtype in ("f32", "int8"):
        quant = dtype == "int8"
        idx = DenseIndex.build(Dh, quantize_int8=quant)
        tb = _bench(lambda q: idx.search_projected(q, W, k=K, mean=mean),
                    jnp.asarray(Qs[:SERVE_BATCH])) / 1e6
        rate = 0.8 * SERVE_BATCH / tb
        rows = {}
        for layout in ("segmented", "paged"):
            for name, arate in (("append_0", 0.0),
                                ("append_high",
                                 LIVE_APPEND_RATES["append_high"])):
                if layout == "segmented":
                    live = SegmentedIndex.from_index(
                        idx, delta_capacity=LIVE_DELTA_CAP)
                else:
                    live = PagedIndex.from_index(
                        idx, page_rows=PAGED_PAGE_ROWS,
                        seal_rows=LIVE_DELTA_CAP)
                srv = RetrievalServer(live, pruner, k=K,
                                      max_batch=SERVE_BATCH,
                                      pipeline_depth=SERVE_DEPTH)
                up = IndexUpdater(pruner=pruner, index=live, server=srv,
                                  delta_capacity=LIVE_DELTA_CAP)
                # same warmup contract as live_index: open + non-widening
                # extend + query compile every steady-state path up front
                warm = rng.standard_normal(
                    (LIVE_APPEND_BLOCK, d_raw)).astype(np.float32)
                up.add_documents(jnp.asarray(warm))
                up.add_documents(jnp.asarray(0.5 * warm))
                srv.query(Qs[0])
                jit0 = segment_jit_cache_size()
                n0 = up.index.n
                stop = threading.Event()

                def appender(arate=arate):
                    while not stop.is_set():
                        t0 = time.perf_counter()
                        up.add_documents(jnp.asarray(
                            rng.standard_normal((LIVE_APPEND_BLOCK, d_raw))
                            .astype(np.float32)))
                        lag = (LIVE_APPEND_BLOCK / arate
                               - (time.perf_counter() - t0))
                        if lag > 0:
                            stop.wait(lag)

                th = None
                if arate > 0:
                    th = threading.Thread(target=appender, daemon=True)
                    th.start()
                res = _drive_open(srv, Qs, rate=rate)
                if th is not None:
                    stop.set()
                    th.join(timeout=30.0)
                recompiles = segment_jit_cache_size() - jit0
                rows[f"{layout}_{name}"] = dict(
                    _serve_mode_row(res, srv.worker_stats()),
                    appended_rows=int(up.index.n - n0),
                    swaps=int(srv.swap_count),
                    recompiles_steady=int(recompiles))
                srv.close()
        configs[f"dense_{dtype}"] = dict(
            n=int(Dh.shape[0]), dim=int(Dh.shape[1]), rate_qps=float(rate),
            **rows)
        emit(f"paged_live_dense_{dtype},"
             f"{rows['paged_append_high']['p50_ms']*1e3:.0f},"
             f"seg0={rows['segmented_append_0']['worker_qps']:.1f}qps "
             f"pg0={rows['paged_append_0']['worker_qps']:.1f}qps "
             f"segH={rows['segmented_append_high']['worker_qps']:.1f}qps "
             f"pgH={rows['paged_append_high']['worker_qps']:.1f}qps"
             f"(+{rows['paged_append_high']['appended_rows']}r/"
             f"{rows['paged_append_high']['swaps']}sw) "
             f"recompiles={rows['paged_append_high']['recompiles_steady']}")

    # -- DMA/compute overlap: depth sweep, resident vs oversubscribed -------
    n_cap = min(Dh.shape[0], PALLAS_MAX_DOCS)
    Dc = Dh[:n_cap]
    base8 = DenseIndex.build(Dc, quantize_int8=True)
    npages = -(-n_cap // PAGED_PAGE_ROWS)
    pool = max(npages // 2, 1)
    Qb = jnp.asarray(Qs[:SERVE_BATCH])
    depth_rows = {}
    for depth in PAGED_DEPTHS:
        row = {}
        outs = {}
        for mode, pp in (("resident", None), ("oversubscribed", pool)):
            pg = PagedIndex.from_index(base8, page_rows=PAGED_PAGE_ROWS,
                                       pool_pages=pp, backend="pallas",
                                       depth=depth)
            us = _bench(
                lambda q: pg.search_projected(q, W, k=K, mean=mean), Qb)
            outs[mode] = pg.search_projected(Qb, W, k=K, mean=mean)
            row[mode] = dict(us=us, qps=SERVE_BATCH / (us / 1e6),
                             host_pages=int(pg.storage.n_host_pages))
        row["match"] = bool(
            (np.asarray(outs["resident"][0])
             == np.asarray(outs["oversubscribed"][0])).all()
            and (np.asarray(outs["resident"][1])
                 == np.asarray(outs["oversubscribed"][1])).all())
        row["overlap_ratio"] = (row["oversubscribed"]["qps"]
                                / row["resident"]["qps"])
        depth_rows[f"depth_{depth}"] = row
        emit(f"paged_depth_{depth},{row['resident']['us']:.0f},"
             f"resident={row['resident']['qps']:.1f}qps "
             f"oversub={row['oversubscribed']['qps']:.1f}qps "
             f"({row['overlap_ratio']:.2f}x, "
             f"{row['oversubscribed']['host_pages']} host pages) "
             f"match={row['match']}")
    best_depth, best_ratio = max(
        ((d, depth_rows[f"depth_{d}"]["overlap_ratio"])
         for d in PAGED_DEPTHS if d >= 2), key=lambda t: t[1])
    oversub = dict(
        n=int(n_cap), page_rows=int(PAGED_PAGE_ROWS),
        total_pages=int(npages), pool_pages=int(pool),
        host_pages=int(npages - pool), depth=int(best_depth),
        resident_qps=depth_rows[f"depth_{best_depth}"]["resident"]["qps"],
        oversub_qps=depth_rows[f"depth_{best_depth}"]["oversubscribed"]["qps"],
        ratio=float(best_ratio))
    emit(f"paged_oversubscription,{oversub['resident_qps']:.0f},"
         f"ratio={oversub['ratio']:.2f} at depth={best_depth} "
         f"({oversub['host_pages']}/{npages} pages on host)")

    # -- page-count sweep: full lifecycle, zero steady-state recompiles -----
    # deliberately oversubscribed (pool of 18 against a growing index) so
    # the measured sweep crosses NOTHING for the first time: warmup runs
    # the lifecycle until the jit-variant set is a fixed point with the
    # host tier already live, then five more lifecycles grow the page
    # count (and the host tier) with the cache pinned
    rngp = np.random.default_rng(23)
    m = int(Dh.shape[1])
    pg = PagedIndex.from_index(
        DenseIndex.build(Dh[:1024], quantize_int8=True),
        page_rows=PAGED_SWEEP_PAGE_ROWS, pool_pages=18,
        seal_rows=PAGED_SWEEP_SEAL_ROWS, wave_pages=2)

    def lifecycle(pg, rows):
        pg = pg.append(jnp.asarray(
            rngp.standard_normal((rows, m)).astype(np.float32)))
        pg.search_projected(Qb, W, k=K, mean=mean)
        pg, _ = pg.promote()
        pg, _ = pg.compact_pages()
        jax.block_until_ready(pg.search_projected(Qb, W, k=K, mean=mean))
        return pg

    warmups, prev = 0, -1
    while warmups < 10:
        pg = lifecycle(pg, 192)
        warmups += 1
        cur = segment_jit_cache_size()
        if cur == prev and pg.storage.n_host_pages > 0:
            break
        prev = cur
    jit0 = segment_jit_cache_size()
    page_counts = [int(pg.total_pages)]
    host_counts = [int(pg.storage.n_host_pages)]
    for rows in PAGED_SWEEP_ROWS:
        pg = lifecycle(pg, rows)
        page_counts.append(int(pg.total_pages))
        host_counts.append(int(pg.storage.n_host_pages))
    sweep = dict(page_rows=int(PAGED_SWEEP_PAGE_ROWS),
                 seal_rows=int(PAGED_SWEEP_SEAL_ROWS),
                 pool_pages=18, warmup_lifecycles=int(warmups),
                 append_rows=[int(r) for r in PAGED_SWEEP_ROWS],
                 page_counts=page_counts, host_pages=host_counts,
                 recompiles_steady=int(segment_jit_cache_size() - jit0))
    emit(f"paged_page_count_sweep,0,pages={page_counts} "
         f"host={host_counts} recompiles={sweep['recompiles_steady']}")

    # -- guard A/B: per-row masked merge vs legacy whole-batch guard --------
    qh = pruner.transform_queries(jnp.asarray(Q))
    blk = min(512, Dh.shape[0])
    t_row = _bench(lambda q: _scan_topk(Dh, q, K, block=blk), qh)
    t_batch = _bench(
        lambda q: _scan_topk(Dh, q, K, block=blk, guard="batch"), qh)
    out_r = _scan_topk(Dh, qh, K, block=blk)
    out_b = _scan_topk(Dh, qh, K, block=blk, guard="batch")
    identical = bool(
        (np.asarray(out_r[0]) == np.asarray(out_b[0])).all()
        and (np.asarray(out_r[1]) == np.asarray(out_b[1])).all())
    guard_ab = dict(row_us=t_row, batch_us=t_batch,
                    speedup=t_batch / t_row, block=int(blk),
                    bitwise_identical=identical)
    emit(f"paged_guard_ab,{t_row:.0f},row-vs-batch={t_batch/t_row:.2f}x "
         f"identical={identical}")

    return dict(meta=dict(page_rows=int(PAGED_PAGE_ROWS),
                          depths=[int(d) for d in PAGED_DEPTHS],
                          n_queries=int(N_LIVE),
                          append_block=int(LIVE_APPEND_BLOCK),
                          seal_rows=int(LIVE_DELTA_CAP),
                          rate_policy="0.8x fused batched capacity",
                          depth_backend="pallas (interpret off-TPU)"),
                configs=configs, depth_sweep=depth_rows,
                oversubscription=oversub, page_count_sweep=sweep,
                guard_ab=guard_ab)


def _serve_bucketing(Dh, pruner, Q_raw, emit) -> dict:
    """Pad-to-max vs batch-shape bucketing at LOW load (0.2x capacity):
    partial batches dominate there, so padding every one of them to
    ``max_batch`` burns up to 4x the needed scan compute — bucketing pads
    to the next of {8, 16, 32} instead, for a handful of extra compiles
    (absorbed by ``warmup()``, not paid mid-serve)."""
    from repro.launch.serve import RetrievalServer, _drive_open
    Q = np.asarray(Q_raw)
    Qs = np.tile(Q, (N_LIVE // len(Q) + 1, 1))[:N_LIVE]
    W, mean = pruner.projection()
    idx = DenseIndex.build(Dh)
    tb = _bench(lambda q: idx.search_projected(q, W, k=K, mean=mean),
                jnp.asarray(Qs[:SERVE_BATCH])) / 1e6
    rate = 0.2 * SERVE_BATCH / tb
    out = {"rate_qps": float(rate), "n": int(Dh.shape[0])}
    for mode, bucketed in (("pad_to_max", False), ("bucketed", True)):
        srv = RetrievalServer(idx, pruner, k=K, max_batch=SERVE_BATCH,
                              pipeline_depth=SERVE_DEPTH,
                              bucket_batches=bucketed)
        srv.warmup()
        res = _drive_open(srv, Qs, rate=rate)
        out[mode] = _serve_mode_row(res, srv.worker_stats())
        srv.close()
    emit(f"serve_bucketing,{out['bucketed']['p50_ms']*1e3:.0f},"
         f"@{rate:.1f}qps p50 {out['pad_to_max']['p50_ms']:.2f}->"
         f"{out['bucketed']['p50_ms']:.2f}ms p99 "
         f"{out['pad_to_max']['p99_ms']:.2f}->"
         f"{out['bucketed']['p99_ms']:.2f}ms")
    return out


def _cascade(Dh, pruner, Q_raw, emit) -> dict:
    """Cascade Pareto sweep: recall@10 vs saturated worker qps across
    {m_coarse x N x full dtype}, against the single-resolution full-m
    worker on the same open-loop harness.

    Every row (baselines included) drives the same query tape at 1.5x its
    OWN fused batched capacity — each worker saturates, so ``worker_qps``
    is the capacity comparison — and reports recall@10 against the exact
    full-m f32 oracle plus the steady-state jit-compile count (the
    cascade's zero-recompile contract under fixed nk)."""
    from repro.core import CascadeIndex
    from repro.core.index import segment_jit_cache_size
    from repro.launch.serve import RetrievalServer, _drive_open
    Q = np.asarray(Q_raw)
    Qs = np.tile(Q, (N_SERVE // len(Q) + 1, 1))[:N_SERVE]
    W, mean = pruner.projection()
    n, m = int(Dh.shape[0]), int(Dh.shape[1])
    _, ids_o = DenseIndex.build(Dh).search_projected(
        jnp.asarray(Qs), W, k=K, mean=mean)
    ids_o = np.asarray(ids_o)

    def drive(idx):
        tb = _bench(lambda q: idx.search_projected(q, W, k=K, mean=mean),
                    jnp.asarray(Qs[:SERVE_BATCH])) / 1e6
        rate = 1.5 * SERVE_BATCH / tb
        srv = RetrievalServer(idx, pruner, k=K, max_batch=SERVE_BATCH,
                              pipeline_depth=SERVE_DEPTH)
        srv.query(Qs[0])            # compile the padded batch shape
        jit0 = segment_jit_cache_size()
        srv.reset_stats()
        res = _drive_open(srv, Qs, rate=rate, collect=True)
        outs = res.pop("results")
        stats = srv.worker_stats()
        recompiles = segment_jit_cache_size() - jit0
        srv.close()
        ids = np.stack([np.asarray(i) for _, i in outs])
        return dict(_serve_mode_row(res, stats), rate_qps=float(rate),
                    recall_at_10=_recall(ids_o, ids, K),
                    recompiles_steady=int(recompiles))

    rows = {}
    for dtype in ("f32", "int8"):
        base = DenseIndex.build(Dh, quantize_int8=dtype == "int8")
        brow = dict(drive(base), dtype=dtype, m_coarse=None, n_factor=None,
                    baseline=True, nbytes=int(base.nbytes))
        rows[f"baseline_{dtype}"] = brow
        emit(f"cascade_baseline_{dtype},{brow['p50_ms']*1e3:.0f},"
             f"worker={brow['worker_qps']:.1f}qps "
             f"recall@10={brow['recall_at_10']:.3f}")
        for mc in CASCADE_M_COARSE:
            if mc >= base.dim:   # coarse view must strictly nest (fast mode)
                continue
            for nf in CASCADE_N_FACTORS:
                cas = CascadeIndex.from_index(base, m_coarse=mc,
                                              n_factor=nf)
                crow = dict(drive(cas), dtype=dtype, m_coarse=int(mc),
                            n_factor=int(nf), baseline=False,
                            nbytes=int(cas.nbytes))
                crow["speedup_vs_baseline"] = (crow["worker_qps"]
                                               / brow["worker_qps"])
                rows[f"{dtype}_m{mc}_N{nf}"] = crow
                emit(f"cascade_{dtype}_m{mc}_N{nf},"
                     f"{crow['p50_ms']*1e3:.0f},"
                     f"worker={crow['worker_qps']:.1f}qps "
                     f"({crow['speedup_vs_baseline']:.2f}x baseline) "
                     f"recall@10={crow['recall_at_10']:.3f} "
                     f"recompiles={crow['recompiles_steady']}")
    return dict(meta=dict(n=n, m=m, n_queries=int(N_SERVE), k=int(K),
                          max_batch=int(SERVE_BATCH),
                          depth=int(SERVE_DEPTH), backend="jnp",
                          coarse_dtype="int8",
                          rate_policy="1.5x own fused batched capacity",
                          oracle="exact full-m f32 search_projected"),
                rows=rows)


def _fleet(emit) -> dict:
    """Replicated fleet under chaos: the three tracked drives.

      * ``healthy``      — R replicas, open-loop Poisson, no faults: the
                           p99 baseline the fault drives are held against.
      * ``kill_restart`` — replica r1 is crash-injected mid-drive and
                           restarted later; the schema gate requires zero
                           lost accepted replies, zero misrouted replies,
                           and p99 within 2x the healthy baseline (the
                           failover cohort is a fixed handful of requests,
                           so a long enough drive keeps it out of p99).
      * ``bad_rollout``  — a recall-regressing artifact (same corpus,
                           shuffled row ids) is rolled out mid-drive; the
                           health gate must roll the whole fleet back and
                           no live reply may ever have been served by the
                           bad index (top-1 self-retrieval makes every
                           reply checkable).
    """
    import threading as _threading

    from repro.launch.serve import _drive_open
    from repro.serving.fleet import FaultEvent, FaultPlan
    from repro.serving.soak import _unit_corpus, build_fleet

    def drive_row(fleet, D, n, *, seed, plan=None, rollout_to=None):
        rng = np.random.default_rng(seed)
        qids = rng.integers(0, len(D), size=n)
        rollout_result = {}
        threads = []
        if plan is not None:
            threads.append(plan.start(fleet))
        if rollout_to is not None:
            def _roll():
                time.sleep(1.0)
                rollout_result.update(fleet.rollout(rollout_to))
            th = _threading.Thread(target=_roll, daemon=True)
            th.start()
            threads.append(th)
        res = _drive_open(fleet, D[qids], rate=FLEET_RATE, seed=seed,
                          collect=True, tolerate_errors=True, deadline=2.0)
        for th in threads:
            th.join(timeout=60.0)
        misrouted = sum(
            1 for i, out in enumerate(res.pop("results"))
            if isinstance(out, tuple)
            and int(np.asarray(out[1])[0]) != int(qids[i]))
        stats = fleet.stats()
        row = dict(n=res["n"], n_ok=res["n_ok"], errors=res["errors"],
                   achieved_qps=res["achieved_qps"],
                   p50_ms=res["p50_ms"], p95_ms=res["p95_ms"],
                   p99_ms=res["p99_ms"], misrouted=misrouted,
                   accepted=stats["accepted"], shed=stats["shed"],
                   timed_out=stats["timed_out"], failed=stats["failed"],
                   failovers=stats["failovers"],
                   lost_accepted=stats["lost_accepted"],
                   health_ok=bool(fleet.health()["ok"]))
        if rollout_result:
            row["rolled_back"] = bool(rollout_result.get("rolled_back"))
        return row

    tmpdir = tempfile.mkdtemp(prefix="bench_fleet_")
    try:
        fleet, D = build_fleet(os.path.join(tmpdir, "v1"),
                               n_docs=FLEET_N_DOCS, dim=FLEET_DIM,
                               replicas=FLEET_REPLICAS)
        try:
            out = {"replicas": FLEET_REPLICAS, "rate": FLEET_RATE}
            out["healthy"] = drive_row(fleet, D, N_FLEET_HEALTHY, seed=1)
            emit(f"fleet_healthy,{out['healthy']['p99_ms']*1e3:.0f},"
                 f"qps={out['healthy']['achieved_qps']:.1f} "
                 f"ok={out['healthy']['n_ok']}/{out['healthy']['n']}")

            t_kill = 0.3 * (N_FLEET_KILL / FLEET_RATE)
            plan = FaultPlan([FaultEvent(t_kill, "kill", "r1"),
                              FaultEvent(2.0 * t_kill, "restart", "r1")])
            out["kill_restart"] = drive_row(fleet, D, N_FLEET_KILL,
                                            seed=2, plan=plan)
            kr = out["kill_restart"]
            emit(f"fleet_kill_restart,{kr['p99_ms']*1e3:.0f},"
                 f"lost={kr['lost_accepted']} misrouted={kr['misrouted']} "
                 f"failovers={kr['failovers']} ok={kr['n_ok']}/{kr['n']}")

            # recall-regressing artifact: identical rows, shuffled order —
            # every id the bad index would return is wrong
            from repro.core import StaticPruner as _SP
            perm = np.random.default_rng(3).permutation(len(D))
            prb = _SP(cutoff=0.5).fit(jnp.asarray(D[perm]))
            save_index(os.path.join(tmpdir, "v_bad"),
                       prb.build_index(jnp.asarray(D[perm])), pruner=prb)
            out["bad_rollout"] = drive_row(
                fleet, D, N_FLEET_ROLLOUT, seed=4,
                rollout_to=os.path.join(tmpdir, "v_bad"))
            br = out["bad_rollout"]
            emit(f"fleet_bad_rollout,{br['p99_ms']*1e3:.0f},"
                 f"rolled_back={br.get('rolled_back')} "
                 f"misrouted={br['misrouted']} lost={br['lost_accepted']}")
            return out
        finally:
            fleet.close()
    finally:
        shutil.rmtree(tmpdir, ignore_errors=True)


def run(emit=print) -> dict:
    # structured corpus (trained-encoder spectral regime) — recall under
    # pruning is meaningless on isotropic gaussians
    from repro.data.synthetic import make_corpus
    rng = np.random.default_rng(0)
    D_np, _ = make_corpus("tasb", n_docs=N_DOCS, d=DIM, seed=0)
    D = jnp.asarray(D_np)
    q_idx = rng.choice(N_DOCS, N_QUERIES, replace=False)
    Q = jnp.asarray(D_np[q_idx] + 0.05 * rng.standard_normal((N_QUERIES, DIM))
                    .astype(np.float32))

    results = {"meta": dict(n_docs=int(N_DOCS), dim=int(DIM),
                            n_queries=int(N_QUERIES), k=int(K),
                            iters=int(ITERS),
                            device_count=int(jax.device_count()),
                            backend=jax.default_backend(),
                            jax_version=jax.__version__)}
    full = DenseIndex.build(D)
    t_full = _bench(lambda q: full.search(q, k=K), Q)
    emit(f"search_full_d{DIM},{t_full:.0f},bytes={full.nbytes}")
    results["full"] = dict(us=t_full, qps=N_QUERIES / (t_full / 1e6),
                           nbytes=int(full.nbytes), recall=1.0)
    _, ids_full = full.search(Q, k=K)
    ids_full = np.asarray(ids_full)

    for c in (0.25, 0.5, 0.75):
        pruner = StaticPruner(cutoff=c).fit(D)
        m = pruner.kept_dims
        idx = DenseIndex.build(pruner.prune_index(D))
        qh = pruner.transform_queries(Q)
        t = _bench(lambda q: idx.search(q, k=K), qh)
        _, ids_p = idx.search(qh, k=K)
        rec = _recall(ids_full, np.asarray(ids_p), K)
        emit(f"search_pca_m{m},{t:.0f},speedup={t_full/t:.2f}x "
             f"predicted={DIM/m:.2f}x bytes={idx.nbytes} recall@10={rec:.3f}")
        results[f"pca_{c}"] = dict(us=t, qps=N_QUERIES / (t / 1e6), m=int(m),
                                   speedup=t_full / t, predicted=DIM / m,
                                   nbytes=int(idx.nbytes), recall=rec)

    # beyond paper: PCA(50%) + int8
    pruner = StaticPruner(cutoff=0.5).fit(D)
    idx8 = pruner.build_index(D, quantize_int8=True)
    qh = pruner.transform_queries(Q)
    t8 = _bench(lambda q: idx8.search(q, k=K), qh)
    _, ids_8 = idx8.search(qh, k=K)
    rec8 = _recall(ids_full, np.asarray(ids_8), K)
    emit(f"search_pca50_int8,{t8:.0f},bytes={idx8.nbytes} "
         f"compression={full.nbytes/idx8.nbytes:.1f}x recall@10={rec8:.3f}")
    results["pca50_int8"] = dict(us=t8, qps=N_QUERIES / (t8 / 1e6),
                                 nbytes=int(idx8.nbytes), recall=rec8)

    # serving sweep on the pruned index (the paper's serve-time artefact);
    # recall reference = exact f32 ranking on the same pruned space
    Dh = pruner.prune_index(D)
    _, ids_ref_pruned = DenseIndex.build(Dh).search(qh, k=K)
    results["sweep"] = _sweep(Dh, qh, np.asarray(ids_ref_pruned), emit)

    # end-to-end serving: sync vs pipelined workers under open-loop load,
    # raw d-dim queries through the fused search_projected hot path
    results["serve_pipeline"] = _serve_pipeline(Dh, pruner, np.asarray(Q),
                                                emit)

    # live segmented index: serve QPS while a background updater appends
    # (zero steady-state recompiles asserted by the schema check), plus the
    # batch-shape bucketing A/B at low load
    results["live_index"] = _live_index(Dh, pruner, np.asarray(Q), emit)
    results["serve_bucketing"] = _serve_bucketing(Dh, pruner, np.asarray(Q),
                                                  emit)

    # paged index memory: paged-vs-segmented live serve, DMA depth sweep
    # with the oversubscription headline row, zero-recompile page-count
    # lifecycle, and the per-row vs whole-batch guard A/B
    results["paged"] = _paged(Dh, pruner, np.asarray(Q), emit)

    # cascade Pareto: two-stage coarse scan -> exact shortlist rescore vs
    # the single-resolution full-m worker, same open-loop harness
    results["cascade"] = _cascade(Dh, pruner, np.asarray(Q), emit)

    # replicated fleet under chaos: healthy baseline, kill/restart, and a
    # recall-regressing rollout — droplessness/misroute/rollback invariants
    # enforced by benchmarks.run's schema gate before BENCH_perf.json lands
    results["fleet"] = _fleet(emit)

    # cold start: committed on-disk artifact -> first answered query — the
    # restart path ``serve.py --load-index`` takes. One-shot by nature
    # (page cache + jit compile are part of the cost being measured).
    tmpdir = tempfile.mkdtemp(prefix="bench_store_")
    try:
        store_path = os.path.join(tmpdir, "idx")
        save_index(store_path, DenseIndex.build(Dh), pruner=pruner)
        t0 = time.perf_counter()
        st = IndexStore.open(store_path)
        idx_cold = DenseIndex.load(st)
        jax.block_until_ready(
            idx_cold.search(st.load_pruner().transform_queries(Q), k=K))
        cold_dense = (time.perf_counter() - t0) * 1e6
        emit(f"cold_start_dense,{cold_dense:.0f},n={st.n} bytes={st.nbytes}")
        results["cold_start"] = dict(dense_us=cold_dense, n=int(st.n),
                                     nbytes=int(st.nbytes))
        if jax.device_count() > 1:
            mesh = jax.make_mesh((jax.device_count(),), ("data",))
            t0 = time.perf_counter()
            st = IndexStore.open(store_path)
            sidx_cold = ShardedDenseIndex.load(st, mesh)
            jax.block_until_ready(sidx_cold.search(qh, k=K))
            cold_sh = (time.perf_counter() - t0) * 1e6
            emit(f"cold_start_sharded,{cold_sh:.0f},"
                 f"ndev={jax.device_count()}")
            results["cold_start"]["sharded_us"] = cold_sh
            results["cold_start"]["ndev"] = int(jax.device_count())
    finally:
        shutil.rmtree(tmpdir, ignore_errors=True)

    # select-path A/B: two-stage + block-skip scan vs legacy concat select.
    # Same arrays, same block size — isolates the selection machinery.
    blk = min(65536, Dh.shape[0])
    t_new = _bench(lambda q: _scan_topk(Dh, q, K, block=blk), qh)
    t_old = _bench(lambda q: _scan_topk_concat(Dh, q, K, block=blk), qh)
    emit(f"scan_select_new,{t_new:.0f},vs_old={t_old/t_new:.2f}x")
    emit(f"scan_select_old,{t_old:.0f},")
    results["scan_select"] = dict(new_us=t_new, old_us=t_old,
                                  speedup=t_old / t_new)

    # offline build cost: gram + projection
    t_gram = _bench(lambda d: jnp.asarray(np.asarray(d)).T @ d, D, iters=2)
    results["gram_naive_us"] = t_gram
    return results


def main():
    run()


if __name__ == "__main__":
    main()
