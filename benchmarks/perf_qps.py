"""Perf — the paper's §2 complexity claims, measured.

  * query scoring time O(dn) -> O(dm + mn): wall-clock speedup vs d/m
  * index bytes O(dn) -> O(mn) (+ md for W_m)
  * kernel path: fused score+top-k vs unfused matmul+top_k
  * beyond-paper: int8 index on top of PCA (bytes /4, recall preserved)

Emits ``name,us_per_call,derived`` CSV rows like every other bench.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DenseIndex, StaticPruner
from repro.kernels import ops as kops

N_DOCS = 100_000
DIM = 768
N_QUERIES = 16
K = 10


def _bench(fn, *args, iters=5) -> float:
    fn(*args)  # compile + warmup
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6  # us


def run(emit=print) -> dict:
    # structured corpus (trained-encoder spectral regime) — recall under
    # pruning is meaningless on isotropic gaussians
    from repro.data.synthetic import make_corpus
    rng = np.random.default_rng(0)
    D_np, _ = make_corpus("tasb", n_docs=N_DOCS, d=DIM, seed=0)
    D = jnp.asarray(D_np)
    q_idx = rng.choice(N_DOCS, N_QUERIES, replace=False)
    Q = jnp.asarray(D_np[q_idx] + 0.05 * rng.standard_normal((N_QUERIES, DIM))
                    .astype(np.float32))

    results = {}
    full = DenseIndex.build(D)
    t_full = _bench(lambda q: full.search(q, k=K), Q)
    emit(f"search_full_d{DIM},{t_full:.0f},bytes={full.nbytes}")
    results["full"] = dict(us=t_full, nbytes=full.nbytes)

    for c in (0.25, 0.5, 0.75):
        pruner = StaticPruner(cutoff=c).fit(D)
        m = pruner.kept_dims
        idx = DenseIndex.build(pruner.prune_index(D))
        qh = pruner.transform_queries(Q)
        t = _bench(lambda q: idx.search(q, k=K), qh)
        # recall vs full-dim ranking
        _, ids_f = full.search(Q, k=K)
        _, ids_p = idx.search(qh, k=K)
        rec = np.mean([len(set(np.asarray(ids_f)[i]) & set(np.asarray(ids_p)[i])) / K
                       for i in range(N_QUERIES)])
        emit(f"search_pca_m{m},{t:.0f},speedup={t_full/t:.2f}x "
             f"predicted={DIM/m:.2f}x bytes={idx.nbytes} recall@10={rec:.3f}")
        results[f"pca_{c}"] = dict(us=t, m=m, speedup=t_full / t,
                                   predicted=DIM / m, nbytes=idx.nbytes,
                                   recall=float(rec))

    # beyond paper: PCA(50%) + int8
    pruner = StaticPruner(cutoff=0.5).fit(D)
    idx8 = pruner.build_index(D, quantize_int8=True)
    qh = pruner.transform_queries(Q)
    t8 = _bench(lambda q: idx8.search(q, k=K), qh)
    _, ids_f = full.search(Q, k=K)
    _, ids_8 = idx8.search(qh, k=K)
    rec8 = np.mean([len(set(np.asarray(ids_f)[i]) & set(np.asarray(ids_8)[i])) / K
                    for i in range(N_QUERIES)])
    emit(f"search_pca50_int8,{t8:.0f},bytes={idx8.nbytes} "
         f"compression={full.nbytes/idx8.nbytes:.1f}x recall@10={rec8:.3f}")
    results["pca50_int8"] = dict(us=t8, nbytes=idx8.nbytes, recall=float(rec8))

    # kernel path (interpret mode on CPU: correctness + call shape, not TPU perf)
    Dh = pruner.prune_index(D[:20000])
    t_kern = _bench(lambda q: kops.topk_score(Dh, q, k=K, block_n=4096), qh)
    emit(f"kernel_fused_topk_20k,{t_kern:.0f},interpret-mode")
    results["kernel"] = dict(us=t_kern)

    # offline build cost: gram + projection
    t_gram = _bench(lambda d: jnp.asarray(np.asarray(d)).T @ d, D, iters=2)
    results["gram_naive_us"] = t_gram
    return results


def main():
    run()


if __name__ == "__main__":
    main()
