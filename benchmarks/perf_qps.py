"""Perf — the paper's §2 complexity claims, measured, plus the serving sweep.

  * query scoring time O(dn) -> O(dm + mn): wall-clock speedup vs d/m
  * index bytes O(dn) -> O(mn) (+ md for W_m)
  * serving sweep {backend x dtype x layout x merge}: us/call, qps, bytes,
    recall@10 per config — the trajectory ``BENCH_perf.json`` tracks PR
    over PR (written by ``benchmarks.run``)
  * select-path A/B: the two-stage + block-skip ``_scan_topk`` against the
    legacy concat-and-full-top_k select on the same corpus
  * beyond-paper: int8 index on top of PCA (bytes /4, recall preserved)

Emits ``name,us_per_call,derived`` CSV rows like every other bench and
returns a JSON-ready dict.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import DenseIndex, ShardedDenseIndex, StaticPruner
from repro.core.index import _scan_topk, _topk_merge
from repro.core.store import IndexStore, save_index
from repro.kernels import ops as kops

N_DOCS = 100_000
DIM = 768
N_QUERIES = 16
K = 10
ITERS = 3
# interpret-mode Pallas pays a huge per-op interpreter tax off-TPU; cap its
# corpus so the sweep stays tractable (the config records its own n)
PALLAS_MAX_DOCS = 20_000


def _bench(fn, *args, iters: int = ITERS) -> float:
    """Median us/call. Blocks on the result inside the timed region each
    iteration — with JAX's async dispatch, timing a loop of un-blocked
    calls measures enqueue rate, not latency."""
    jax.block_until_ready(fn(*args))   # compile + warmup
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


def _recall(ids_ref: np.ndarray, ids: np.ndarray, k: int) -> float:
    return float(np.mean([
        len(set(ids_ref[i].tolist()) & set(ids[i].tolist())) / k
        for i in range(ids_ref.shape[0])]))


# ---------------------------------------------------------------------------
# legacy select path (pre two-stage/block-skip) — kept only for the A/B row
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("k", "block"))
def _scan_topk_concat(D, Q, k, block=65536):
    """The old select: concat running + full strip, one big top_k per strip."""
    n, d = D.shape
    B = Q.shape[0]
    block = min(block, n)
    nblocks = -(-n // block)
    pad = nblocks * block - n
    Dp = jnp.pad(D, ((0, pad), (0, 0))) if pad else D
    blocks = Dp.reshape(nblocks, block, d)
    Qf = Q.astype(jnp.float32)

    def body(carry, inp):
        bs, bi = carry
        blk, start = inp
        s = Qf @ blk.T.astype(jnp.float32)
        ids = start + jnp.arange(block, dtype=jnp.int32)[None, :]
        s = jnp.where(ids < n, s, -jnp.inf)
        cs = jnp.concatenate([bs, s], axis=1)
        ci = jnp.concatenate([bi, jnp.broadcast_to(ids, (B, block))], axis=1)
        return _topk_merge(cs, ci, k), None

    init = (jnp.full((B, k), -jnp.inf, jnp.float32),
            jnp.full((B, k), -1, jnp.int32))
    starts = jnp.arange(nblocks, dtype=jnp.int32) * block
    (scores, ids), _ = jax.lax.scan(body, init, (blocks, starts))
    return scores, ids


# ---------------------------------------------------------------------------
# serving sweep
# ---------------------------------------------------------------------------


def _build_index(D, dtype: str, backend: str, layout: str, mesh):
    if layout == "dense":
        if dtype == "int8":
            return DenseIndex.build(D, quantize_int8=True, backend=backend)
        v = D.astype(jnp.bfloat16) if dtype == "bf16" else D
        return DenseIndex.build(v, backend=backend)
    merge = "hierarchical" if layout == "sharded-hier" else "flat"
    if dtype == "int8":
        return ShardedDenseIndex.build(D, mesh, quantize_int8=True,
                                       backend=backend, merge=merge)
    v = D.astype(jnp.bfloat16) if dtype == "bf16" else D
    return ShardedDenseIndex.build(v, mesh, backend=backend, merge=merge)


def _sweep(D, Q, ids_ref, emit) -> dict:
    """{backend x dtype x layout(+merge)} serving grid on the pruned index."""
    from repro.launch.serve import _serve_mesh
    ndev = jax.device_count()
    layouts = ["dense"]
    meshes = {}
    if ndev > 1:
        # flat merges over a 1-D mesh; hierarchical needs the factored 2-D
        # mesh (on 1-D it degenerates to the same single stage — measuring
        # that would just duplicate the flat row)
        meshes["sharded-flat"] = _serve_mesh(ndev, "flat")
        meshes["sharded-hier"] = _serve_mesh(ndev, "hierarchical")
        layouts += ["sharded-flat", "sharded-hier"]
    else:
        emit("# sweep: single device — sharded configs skipped")
    out = {}
    B = Q.shape[0]
    for backend in ("jnp", "pallas"):
        n_cap = min(D.shape[0], PALLAS_MAX_DOCS) if backend == "pallas" \
            else D.shape[0]
        Dc = D[:n_cap]
        if n_cap == D.shape[0]:
            ref_c = ids_ref
        else:   # exact f32 ranking on the capped corpus
            _, rid = DenseIndex.build(Dc).search(Q, k=K)
            ref_c = np.asarray(rid)
        for dtype in ("f32", "bf16", "int8"):
            for layout in layouts:
                name = f"{backend}_{dtype}_{layout}"
                mesh = meshes.get(layout)
                idx = _build_index(Dc, dtype, backend, layout, mesh)
                us = _bench(lambda q: idx.search(q, k=K), Q)
                _, ids = idx.search(Q, k=K)
                rec = _recall(ref_c, np.asarray(ids), K)
                qps = B / (us / 1e6)
                out[name] = dict(us=us, qps=qps, nbytes=int(idx.nbytes),
                                 recall=rec, n=n_cap, dim=int(D.shape[1]),
                                 mesh=(list(mesh.devices.shape)
                                       if mesh is not None else None))
                emit(f"sweep_{name},{us:.0f},qps={qps:.1f} "
                     f"bytes={idx.nbytes} recall@10={rec:.3f} n={n_cap}")
    return out


def run(emit=print) -> dict:
    # structured corpus (trained-encoder spectral regime) — recall under
    # pruning is meaningless on isotropic gaussians
    from repro.data.synthetic import make_corpus
    rng = np.random.default_rng(0)
    D_np, _ = make_corpus("tasb", n_docs=N_DOCS, d=DIM, seed=0)
    D = jnp.asarray(D_np)
    q_idx = rng.choice(N_DOCS, N_QUERIES, replace=False)
    Q = jnp.asarray(D_np[q_idx] + 0.05 * rng.standard_normal((N_QUERIES, DIM))
                    .astype(np.float32))

    results = {"meta": dict(n_docs=int(N_DOCS), dim=int(DIM),
                            n_queries=int(N_QUERIES), k=int(K),
                            iters=int(ITERS),
                            device_count=int(jax.device_count()),
                            backend=jax.default_backend(),
                            jax_version=jax.__version__)}
    full = DenseIndex.build(D)
    t_full = _bench(lambda q: full.search(q, k=K), Q)
    emit(f"search_full_d{DIM},{t_full:.0f},bytes={full.nbytes}")
    results["full"] = dict(us=t_full, qps=N_QUERIES / (t_full / 1e6),
                           nbytes=int(full.nbytes), recall=1.0)
    _, ids_full = full.search(Q, k=K)
    ids_full = np.asarray(ids_full)

    for c in (0.25, 0.5, 0.75):
        pruner = StaticPruner(cutoff=c).fit(D)
        m = pruner.kept_dims
        idx = DenseIndex.build(pruner.prune_index(D))
        qh = pruner.transform_queries(Q)
        t = _bench(lambda q: idx.search(q, k=K), qh)
        _, ids_p = idx.search(qh, k=K)
        rec = _recall(ids_full, np.asarray(ids_p), K)
        emit(f"search_pca_m{m},{t:.0f},speedup={t_full/t:.2f}x "
             f"predicted={DIM/m:.2f}x bytes={idx.nbytes} recall@10={rec:.3f}")
        results[f"pca_{c}"] = dict(us=t, qps=N_QUERIES / (t / 1e6), m=int(m),
                                   speedup=t_full / t, predicted=DIM / m,
                                   nbytes=int(idx.nbytes), recall=rec)

    # beyond paper: PCA(50%) + int8
    pruner = StaticPruner(cutoff=0.5).fit(D)
    idx8 = pruner.build_index(D, quantize_int8=True)
    qh = pruner.transform_queries(Q)
    t8 = _bench(lambda q: idx8.search(q, k=K), qh)
    _, ids_8 = idx8.search(qh, k=K)
    rec8 = _recall(ids_full, np.asarray(ids_8), K)
    emit(f"search_pca50_int8,{t8:.0f},bytes={idx8.nbytes} "
         f"compression={full.nbytes/idx8.nbytes:.1f}x recall@10={rec8:.3f}")
    results["pca50_int8"] = dict(us=t8, qps=N_QUERIES / (t8 / 1e6),
                                 nbytes=int(idx8.nbytes), recall=rec8)

    # serving sweep on the pruned index (the paper's serve-time artefact);
    # recall reference = exact f32 ranking on the same pruned space
    Dh = pruner.prune_index(D)
    _, ids_ref_pruned = DenseIndex.build(Dh).search(qh, k=K)
    results["sweep"] = _sweep(Dh, qh, np.asarray(ids_ref_pruned), emit)

    # cold start: committed on-disk artifact -> first answered query — the
    # restart path ``serve.py --load-index`` takes. One-shot by nature
    # (page cache + jit compile are part of the cost being measured).
    tmpdir = tempfile.mkdtemp(prefix="bench_store_")
    try:
        store_path = os.path.join(tmpdir, "idx")
        save_index(store_path, DenseIndex.build(Dh), pruner=pruner)
        t0 = time.perf_counter()
        st = IndexStore.open(store_path)
        idx_cold = DenseIndex.load(st)
        jax.block_until_ready(
            idx_cold.search(st.load_pruner().transform_queries(Q), k=K))
        cold_dense = (time.perf_counter() - t0) * 1e6
        emit(f"cold_start_dense,{cold_dense:.0f},n={st.n} bytes={st.nbytes}")
        results["cold_start"] = dict(dense_us=cold_dense, n=int(st.n),
                                     nbytes=int(st.nbytes))
        if jax.device_count() > 1:
            mesh = jax.make_mesh((jax.device_count(),), ("data",))
            t0 = time.perf_counter()
            st = IndexStore.open(store_path)
            sidx_cold = ShardedDenseIndex.load(st, mesh)
            jax.block_until_ready(sidx_cold.search(qh, k=K))
            cold_sh = (time.perf_counter() - t0) * 1e6
            emit(f"cold_start_sharded,{cold_sh:.0f},"
                 f"ndev={jax.device_count()}")
            results["cold_start"]["sharded_us"] = cold_sh
            results["cold_start"]["ndev"] = int(jax.device_count())
    finally:
        shutil.rmtree(tmpdir, ignore_errors=True)

    # select-path A/B: two-stage + block-skip scan vs legacy concat select.
    # Same arrays, same block size — isolates the selection machinery.
    blk = min(65536, Dh.shape[0])
    t_new = _bench(lambda q: _scan_topk(Dh, q, K, block=blk), qh)
    t_old = _bench(lambda q: _scan_topk_concat(Dh, q, K, block=blk), qh)
    emit(f"scan_select_new,{t_new:.0f},vs_old={t_old/t_new:.2f}x")
    emit(f"scan_select_old,{t_old:.0f},")
    results["scan_select"] = dict(new_us=t_new, old_us=t_old,
                                  speedup=t_old / t_new)

    # offline build cost: gram + projection
    t_gram = _bench(lambda d: jnp.asarray(np.asarray(d)).T @ d, D, iters=2)
    results["gram_naive_us"] = t_gram
    return results


def main():
    run()


if __name__ == "__main__":
    main()
