"""Static VMEM/grid budget checker for the repo's Pallas kernels.

TPU cores have ~16 MiB of VMEM; a ``pallas_call`` whose resident working
set exceeds it fails at *compile* time on hardware — but this repo's CI
runs the kernels in interpret mode, where any geometry "works". This
checker closes that gap statically, with no TPU and no execution:

  * **VMEM estimate** — resident bytes for a ``topk_score_pallas`` /
    ``pca_project`` config, derived from the kernels' own shared geometry
    helpers (``topk_geometry`` / ``project_geometry``), so the checker
    prices exactly the dispatch the wrapper would launch: streamed inputs
    double-buffered, outputs double-buffered, scratch and the kernel's
    in-register intermediates single-buffered.
  * **grid/padding invariants** — the clamp/pad/fold arithmetic must tile
    exactly (no dropped or double-visited rows): ``nblocks·block_n =
    n + pad_rows`` with ``pad_rows < block_n``, batch and fold likewise.
  * **traced index-map bounds** — best-effort introspection of the traced
    ``pallas_call``: every BlockSpec index map is evaluated at the grid
    corners and the resulting block windows must lie inside the (padded)
    operand. Guarded per JAX version; introspection failure degrades to a
    warn, never a crash.
  * **alignment warnings** — lane (128) / sublane (8) misalignment wastes
    VMEM and MXU occupancy without being wrong; reported at warn severity.
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import numpy as np

from repro.analysis import Finding
from repro.analysis.jaxpr_lints import iter_all_eqns
from repro.kernels.pca_project import project_geometry
from repro.kernels.topk_score import (PagedTopKGeometry, TopKGeometry,
                                      paged_topk_geometry, topk_geometry)

#: per-core VMEM on current TPU generations; the checker budget defaults to
#: this minus a safety margin for compiler-managed temporaries.
VMEM_PER_CORE = 16 * 2 ** 20
DEFAULT_BUDGET = int(VMEM_PER_CORE * 0.9)

_WIDTH = {"int8": 1, "bfloat16": 2, "float16": 2, "float32": 4,
          "int32": 4, "float64": 8}

LANE = 128
SUBLANE = 8


def _width(dtype: str) -> int:
    return _WIDTH.get(str(dtype), 4)


def estimate_topk_vmem(g: TopKGeometry, dtype: str,
                       with_ids: bool = False) -> dict[str, int]:
    """Resident-bytes breakdown of one ``topk_score_pallas`` dispatch.

    Inputs/outputs are priced double-buffered (the Pallas pipeline keeps
    the next block in flight while the kernel runs on the current one);
    scratch is persistent single-buffered; the kernel's largest live
    intermediates — the (block_b, block_n) f32 score strip, its int32 id
    strip, the fold buffers and the (k + fold_w) candidate rows — are
    priced once. ``with_ids`` adds the cascade rescore's explicit
    ``row_ids`` strip: a double-buffered (1, block_n) int32 input (the
    broadcast gids buffer replaces the plain mode's iota — same bytes,
    already priced as ``gids``).
    """
    w = _width(dtype)
    q_tile = 2 * g.block_b * g.m * 4                  # f32 query tile
    d_strip = 2 * g.block_n * g.m * w                 # storage-dtype strip
    ids_strip = 2 * g.block_n * 4 if with_ids else 0  # row_ids int32 strip
    outs = 2 * g.block_b * g.k * (4 + 4)              # scores + ids
    scratch = g.block_b * g.k * (4 + 4)               # running top-k
    scores = g.block_b * g.block_n * 4                # S_blk f32
    gids = g.block_b * g.block_n * 4                  # iota/broadcast int32
    dequant = g.block_n * g.m * 4 if w < 4 else 0     # in-register upcast
    fold = g.block_b * g.fold_r * g.fold_w * (4 + 4)  # fs + fi
    cand = g.block_b * (g.k + g.fold_w) * (4 + 4)     # merge buffer
    parts = dict(q_tile=q_tile, d_strip=d_strip, ids_strip=ids_strip,
                 dequant=dequant, scores=scores, gids=gids, fold=fold,
                 cand=cand, scratch=scratch, outputs=outs)
    parts["total"] = sum(parts.values())
    return parts


def estimate_paged_topk_vmem(g: PagedTopKGeometry, dtype: str,
                             with_scale: bool = False,
                             with_ids: bool = False,
                             with_carry: bool = False) -> dict[str, int]:
    """Resident-bytes breakdown of one ``topk_score_paged_pallas`` dispatch.

    The page pool and tail live in HBM (``ANY`` memory space) — only the
    DMA landing window is VMEM-resident, and it is priced ``depth`` times:
    at pipeline depth D, D page buffers (plus their per-page scale and id
    strips in the rescore mode) are in flight at once. That is the whole
    point of the estimate — doubling ``depth`` buys copy/compute overlap
    by doubling exactly these rows. Everything else mirrors the flat
    kernel: the f32 query tile streams per batch tile, the running top-k
    scratch persists, and the per-page score/fold/candidate intermediates
    are priced once (the loop reuses them each page).
    """
    w = _width(dtype)
    R = g.page_rows
    parts = dict(
        q_tile=2 * g.block_b * g.m * 4,               # f32 query tile
        page_window=g.depth * R * g.m * w,            # DMA buffers x depth
        scale_window=g.depth * g.m * 4 if with_scale else 0,
        ids_window=g.depth * R * 4 if with_ids else 0,
        dequant=R * g.m * 4 if w < 4 else 0,          # in-register upcast
        scores=g.block_b * R * 4,                     # per-page strip
        gids=g.block_b * R * 4,
        fold=g.block_b * g.fold_r * g.fold_w * (4 + 4),
        cand=g.block_b * (g.k + g.fold_w) * (4 + 4),
        scratch=g.block_b * g.k * (4 + 4),            # running top-k
        carry=2 * g.block_b * g.k * (4 + 4) if with_carry else 0,
        outputs=2 * g.block_b * g.k * (4 + 4),
    )
    parts["total"] = sum(parts.values())
    return parts


def estimate_paged_hbm_reads(g: PagedTopKGeometry, dtype: str,
                             live_pages: int, with_scale: bool = False,
                             with_ids: bool = False) -> dict[str, int]:
    """HBM read-bytes of one paged dispatch: every live page is DMA'd once
    per batch tile, the int32 page table / n_valid / offset arrays ride
    along (they are small but they are real reads the flat kernel does
    not pay), and the query tiles stream once."""
    w = _width(dtype)
    R = g.page_rows
    parts = dict(
        pages=g.nbt * live_pages * R * g.m * w,
        page_table=3 * g.table_cap * 4 + 8,           # pt/nvalid/offset+lohi
        scales=g.nbt * live_pages * g.m * 4 if with_scale else 0,
        ids=g.nbt * live_pages * R * 4 if with_ids else 0,
        queries=g.b_pad * g.m * 4,
    )
    parts["total"] = sum(parts.values())
    return parts


def check_paged_topk_config(table_cap: int, pool_pages: int, page_rows: int,
                            m: int, B: int, k: int, *, depth: int = 2,
                            block_b: int = 128, dtype: str = "float32",
                            with_scale: bool = False, with_ids: bool = False,
                            budget: int = DEFAULT_BUDGET) -> list[Finding]:
    """Budget + tiling-invariant findings for one paged-scan config."""
    g = paged_topk_geometry(table_cap, pool_pages, page_rows, m, B, k,
                            depth=depth, block_b=block_b)
    label = (f"topk_score_paged[R={page_rows},m={m},k={k},d={depth},"
             f"bb={g.block_b},{dtype}"
             f"{',scale' if with_scale else ''}{',ids' if with_ids else ''}]")
    findings: list[Finding] = []

    est = estimate_paged_topk_vmem(g, dtype, with_scale=with_scale,
                                   with_ids=with_ids)
    if est["total"] > budget:
        top = sorted((v, c) for c, v in est.items() if c != "total")[-2:]
        hot = ", ".join(f"{c}={v // 1024}KiB" for v, c in reversed(top))
        findings.append(Finding(
            check="pallas.vmem-budget", where=label,
            message=(f"{label}: resident VMEM estimate "
                     f"{est['total'] / 2 ** 20:.1f} MiB exceeds the "
                     f"{budget / 2 ** 20:.1f} MiB budget ({hot}) — shrink "
                     f"page_rows or the pipeline depth")))

    bad = []
    if g.nbt * g.block_b != g.b_pad or g.b_pad < g.B:
        bad.append(f"batch tiles: {g.nbt}x{g.block_b} vs B={g.B}"
                   f" pad->{g.b_pad}")
    if g.fold_r * g.fold_w != g.page_rows + g.pad_w or g.pad_w >= g.fold_w:
        bad.append(f"fold: {g.fold_r}x{g.fold_w} vs page_rows="
                   f"{g.page_rows}+pad{g.pad_w}")
    if depth < 1:
        bad.append(f"depth: {depth} < 1 — no DMA buffer in flight")
    for b in bad:
        findings.append(Finding(
            check="pallas.grid", where=f"{label}:{b.split(':')[0]}",
            message=(f"{label}: tiling invariant violated — {b}; rows "
                     f"would be dropped or double-visited")))

    if table_cap < pool_pages:
        findings.append(Finding(
            check="pallas.grid", where=f"{label}:table",
            message=(f"{label}: table_cap={table_cap} < pool_pages="
                     f"{pool_pages} — pool slots exist that no page-table "
                     f"entry can ever address")))
    if g.fold_w % LANE:
        findings.append(Finding(
            check="pallas.alignment", where=f"{label}:fold_w",
            severity="warn",
            message=(f"{label}: fold_w={g.fold_w} is not lane-aligned "
                     f"({LANE}); cross-lane reductions pad internally")))
    if page_rows % SUBLANE:
        findings.append(Finding(
            check="pallas.alignment", where=f"{label}:page_rows",
            severity="warn",
            message=(f"{label}: page_rows={page_rows} is not "
                     f"sublane-aligned ({SUBLANE}); every page DMA pads "
                     f"internally")))
    return findings


def estimate_project_vmem(n: int, d: int, m: int, *, block_rows: int,
                          in_dtype: str = "float32",
                          out_dtype: str = "float32") -> dict[str, int]:
    """Resident-bytes breakdown of one ``pca_project`` dispatch: the
    VMEM-resident ``W``, a double-buffered input strip, the f32 accumulator
    and the double-buffered output strip (+ the broadcast scale row when
    the quant epilogue is fused)."""
    block_rows, _, _ = project_geometry(n, block_rows)
    parts = dict(
        w_resident=d * m * 4,
        x_strip=2 * block_rows * d * _width(in_dtype),
        accum=block_rows * m * 4,
        out_strip=2 * block_rows * m * _width(out_dtype),
        scale=m * 4 if out_dtype == "int8" else 0,
    )
    parts["total"] = sum(parts.values())
    return parts


def check_topk_config(n: int, m: int, B: int, k: int, *,
                      block_n: int = 1024, block_b: int = 128,
                      dtype: str = "float32", with_ids: bool = False,
                      budget: int = DEFAULT_BUDGET) -> list[Finding]:
    """Budget + tiling-invariant findings for one top-k scan config."""
    g = topk_geometry(n, m, B, k, block_n=block_n, block_b=block_b)
    label = (f"topk_score[m={m},k={k},bn={g.block_n},bb={g.block_b},"
             f"{dtype}{',ids' if with_ids else ''}]")
    findings: list[Finding] = []

    est = estimate_topk_vmem(g, dtype, with_ids=with_ids)
    if est["total"] > budget:
        top = sorted((v, c) for c, v in est.items() if c != "total")[-2:]
        hot = ", ".join(f"{c}={v // 1024}KiB" for v, c in reversed(top))
        findings.append(Finding(
            check="pallas.vmem-budget", where=label,
            message=(f"{label}: resident VMEM estimate "
                     f"{est['total'] / 2 ** 20:.1f} MiB exceeds the "
                     f"{budget / 2 ** 20:.1f} MiB budget ({hot}) — this "
                     f"config compiles in interpret mode but cannot "
                     f"launch on a real core")))

    # tiling must cover every row exactly once
    bad = []
    if g.nblocks * g.block_n != g.n + g.pad_rows or g.pad_rows >= g.block_n:
        bad.append(f"index strips: {g.nblocks}x{g.block_n} vs n={g.n}"
                   f"+pad{g.pad_rows}")
    if g.nbt * g.block_b != g.b_pad or g.b_pad < g.B:
        bad.append(f"batch tiles: {g.nbt}x{g.block_b} vs B={g.B}"
                   f" pad->{g.b_pad}")
    if g.fold_r * g.fold_w != g.block_n + g.pad_w or g.pad_w >= g.fold_w:
        bad.append(f"fold: {g.fold_r}x{g.fold_w} vs block_n={g.block_n}"
                   f"+pad{g.pad_w}")
    # (fold_w < k is fine: a strip smaller than k contributes what it has;
    # the running-list merge keeps earlier strips' survivors)
    for b in bad:
        findings.append(Finding(
            check="pallas.grid", where=f"{label}:{b.split(':')[0]}",
            message=(f"{label}: tiling invariant violated — {b}; rows "
                     f"would be dropped or double-visited")))

    if g.fold_w % LANE:
        findings.append(Finding(
            check="pallas.alignment", where=f"{label}:fold_w",
            severity="warn",
            message=(f"{label}: fold_w={g.fold_w} is not lane-aligned "
                     f"({LANE}); cross-lane reductions pad internally")))
    if g.block_b % SUBLANE and g.block_b != g.B:
        findings.append(Finding(
            check="pallas.alignment", where=f"{label}:block_b",
            severity="warn",
            message=(f"{label}: block_b={g.block_b} is not sublane-aligned "
                     f"({SUBLANE}); the query tile pads internally")))
    return findings


def check_project_config(n: int, d: int, m: int, *, block_rows: int = 1024,
                         quant: bool = False,
                         budget: int = DEFAULT_BUDGET) -> list[Finding]:
    label = (f"pca_project[d={d},m={m},rows={block_rows}"
             f"{',int8' if quant else ''}]")
    est = estimate_project_vmem(n, d, m, block_rows=block_rows,
                                out_dtype="int8" if quant else "float32")
    findings: list[Finding] = []
    if est["total"] > budget:
        findings.append(Finding(
            check="pallas.vmem-budget", where=label,
            message=(f"{label}: resident VMEM estimate "
                     f"{est['total'] / 2 ** 20:.1f} MiB exceeds the "
                     f"{budget / 2 ** 20:.1f} MiB budget — shrink "
                     f"block_rows or m")))
    br, nblocks, pad = project_geometry(n, block_rows)
    if nblocks * br != n + pad or pad >= br:
        findings.append(Finding(
            check="pallas.grid", where=f"{label}:rows",
            message=(f"{label}: tiling invariant violated — {nblocks}x{br} "
                     f"vs n={n}+pad{pad}")))
    return findings


# ---------------------------------------------------------------------------
# Traced index-map bounds (best-effort, JAX-version-sensitive)
# ---------------------------------------------------------------------------


def _grid_corners(grid: Sequence[int]):
    """All 2^len(grid) corner index tuples (first/last step per dim)."""
    corners = [()]
    for size in grid:
        ends = (0,) if size <= 1 else (0, size - 1)
        corners = [c + (e,) for c in corners for e in ends]
    return corners


def check_traced_index_maps(label: str, fn: Callable, args: Sequence
                            ) -> list[Finding]:
    """Trace ``fn``, locate its ``pallas_call`` eqns and evaluate every
    BlockSpec index map at the grid corners: each block window must lie
    inside its (padded) operand. Introspection details vary across JAX
    versions, so any failure to introspect degrades to a warn finding
    rather than an error or a crash."""
    findings: list[Finding] = []
    try:
        jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
        calls = [e for e in iter_all_eqns(jaxpr)
                 if e.primitive.name == "pallas_call"]
        if not calls:
            return [Finding(
                check="pallas.index-map", where=f"{label}:no-pallas-call",
                severity="warn",
                message=f"{label}: traced entry contains no pallas_call")]
        for eqn in calls:
            gm = eqn.params["grid_mapping"]
            grid = tuple(int(s) for s in gm.grid)
            operands = list(eqn.invars) + list(eqn.outvars)
            mappings = list(gm.block_mappings)
            # index/scalar-prefetch operands precede the mapped ones
            operands = operands[len(operands) - len(mappings):] \
                if len(operands) >= len(mappings) else operands
            for bm, var in zip(mappings, operands):
                shape = tuple(var.aval.shape)
                block = tuple(bm.block_shape)
                imap = bm.index_map_jaxpr
                for corner in _grid_corners(grid):
                    idx = jax.core.eval_jaxpr(
                        imap.jaxpr, imap.consts,
                        *(np.int32(c) for c in corner))
                    for ax, (bi, bs) in enumerate(zip(idx, block)):
                        if bs is None or not isinstance(bs, int):
                            continue
                        start = int(bi) * bs
                        if start < 0 or start + bs > shape[ax]:
                            findings.append(Finding(
                                check="pallas.index-map",
                                where=f"{label}:axis{ax}",
                                message=(
                                    f"{label}: index map sends grid "
                                    f"{corner} to block start {start} "
                                    f"(+{bs}) outside operand dim "
                                    f"{shape[ax]} on axis {ax} — "
                                    f"out-of-bounds window")))
    except Exception as exc:  # noqa: BLE001 — version-sensitive introspection
        findings.append(Finding(
            check="pallas.index-map", where=f"{label}:introspection",
            severity="warn",
            message=(f"{label}: pallas_call introspection unavailable on "
                     f"this JAX version ({type(exc).__name__}: {exc})")))
    return findings


# ---------------------------------------------------------------------------
# The repo's real kernel configs
# ---------------------------------------------------------------------------

#: (n, m, B, k, block_n, block_b, dtype) — the serving configs BENCH_perf
#: exercises plus the defaults the wrappers ship with.
SERVING_TOPK_CONFIGS = (
    (1_000_000, 128, 128, 10, 1024, 128, "int8"),
    (1_000_000, 128, 128, 10, 1024, 128, "float32"),
    (1_000_000, 256, 64, 100, 1024, 128, "float32"),
    # bn=4096 at k=100 busts the budget (14.9 MiB: fold + dequant strips);
    # 2048 is the largest power-of-two strip that fits with headroom
    (10_000_000, 256, 256, 100, 2048, 128, "int8"),
)

SERVING_PROJECT_CONFIGS = (
    (1_000_000, 1024, 256, 1024, False),
    (1_000_000, 1024, 256, 1024, True),
    (1_000_000, 768, 128, 2048, True),
)

#: cascade geometries — the coarse first pass keeps N·k candidates per
#: query over the narrow int8 view, then the rescore scans the U = B·N·k
#: gathered full-m rows with an explicit ``row_ids`` strip.
CASCADE_COARSE_CONFIGS = (
    # n, m_coarse, B, N*k, block_n, block_b, dtype — deepest shortlist N=64
    (1_000_000, 192, 32, 640, 1024, 32, "int8"),
    (1_000_000, 128, 32, 320, 1024, 32, "int8"),
    (1_000_000, 64, 32, 160, 1024, 32, "int8"),
    (1_000_000, 32, 32, 80, 1024, 32, "int8"),
)
#: paged streaming geometries — the bench's paged serve rows plus the
#: oversubscription and rescore shapes. Layout: (table_cap, pool_pages,
#: page_rows, m, B, k, depth, block_b, dtype, with_scale, with_ids).
#: depth counts DMA page buffers in flight, so the f32 depth-4 row prices
#: the deepest overlap the bench sweeps; the pool_pages<live row is the
#: oversubscribed config (same kernel, tail/host pages DMA through the
#: identical buffer window).
PAGED_TOPK_CONFIGS = (
    (8192, 8192, 512, 128, 128, 10, 2, 128, "int8", True, False),
    (8192, 8192, 512, 128, 128, 10, 2, 128, "float32", False, False),
    (8192, 8192, 512, 128, 128, 10, 4, 128, "float32", False, False),
    (4096, 1024, 1024, 256, 64, 100, 2, 64, "int8", True, False),
    (4096, 4096, 512, 384, 32, 10, 2, 32, "int8", True, True),
)

CASCADE_RESCORE_CONFIGS = (
    # U = B*N*k rows at full m, final k — the BENCH_perf cascade grid
    (1_280, 384, 32, 10, 1024, 32, "float32"),    # N=4
    (2_560, 384, 32, 10, 1024, 32, "int8"),       # N=8
    (5_120, 384, 32, 10, 1024, 32, "int8"),       # N=16
    (10_240, 384, 32, 10, 1024, 32, "float32"),   # N=32
    (20_480, 384, 32, 10, 1024, 32, "float32"),   # N=64
)


def run(budget: int = DEFAULT_BUDGET) -> list[Finding]:
    """Budget-check the repo's shipped kernel configs and bounds-check the
    traced dispatches."""
    from repro.kernels.pca_project import (pca_project_pallas,
                                           pca_project_quant_pallas)
    from repro.kernels.topk_score import topk_score_pallas

    findings: list[Finding] = []
    for n, m, B, k, bn, bb, dt in SERVING_TOPK_CONFIGS:
        findings += check_topk_config(n, m, B, k, block_n=bn, block_b=bb,
                                      dtype=dt, budget=budget)
    for n, m, B, k, bn, bb, dt in CASCADE_COARSE_CONFIGS:
        findings += check_topk_config(n, m, B, k, block_n=bn, block_b=bb,
                                      dtype=dt, budget=budget)
    for n, m, B, k, bn, bb, dt in CASCADE_RESCORE_CONFIGS:
        findings += check_topk_config(n, m, B, k, block_n=bn, block_b=bb,
                                      dtype=dt, with_ids=True, budget=budget)
    for tc, pp, R, m, B, k, dep, bb, dt, sc, ids in PAGED_TOPK_CONFIGS:
        findings += check_paged_topk_config(tc, pp, R, m, B, k, depth=dep,
                                            block_b=bb, dtype=dt,
                                            with_scale=sc, with_ids=ids,
                                            budget=budget)
    for n, d, m, rows, quant in SERVING_PROJECT_CONFIGS:
        findings += check_project_config(n, d, m, block_rows=rows,
                                         quant=quant, budget=budget)

    # traced bounds on representative tiny dispatches (nontrivial padding:
    # 600 % 128 != 0 exercises the pad window at the last grid step)
    rng = np.random.default_rng(0)
    D = rng.standard_normal((600, 128)).astype(np.float32)
    Q = rng.standard_normal((4, 128)).astype(np.float32)
    findings += check_traced_index_maps(
        "topk_score_pallas[600x128]",
        functools.partial(topk_score_pallas, k=10, block_n=128, block_b=8),
        (D, Q))
    # cascade rescore mode: the extra (1, n) row_ids operand gets its own
    # BlockSpec — its windows must stay inside the padded ids row too
    ids = np.arange(600, dtype=np.int32)
    findings += check_traced_index_maps(
        "topk_score_pallas[600x128,ids]",
        functools.partial(topk_score_pallas, k=10, block_n=128, block_b=8,
                          row_ids=ids),
        (D, Q))
    # paged mode: the query/carry tiles are the only windowed operands
    # (tables ride SMEM, pools ride ANY) — their windows must stay inside
    # the padded batch, including the partial last page (nvalid < R)
    from repro.kernels.topk_score import topk_score_paged_pallas
    R, npg, mD = 64, 4, 32
    pool = rng.standard_normal((npg, R, mD)).astype(np.float32)
    nv = np.full(npg, R, np.int32)
    nv[-1] = 40
    findings += check_traced_index_maps(
        "topk_score_paged_pallas[4x64p]",
        functools.partial(topk_score_paged_pallas, k=10, depth=2,
                          block_b=8),
        (pool, np.arange(npg, dtype=np.int32), nv,
         np.arange(npg, dtype=np.int32) * R, np.int32(0), np.int32(npg),
         rng.standard_normal((4, mD)).astype(np.float32)))
    X = rng.standard_normal((600, 64)).astype(np.float32)
    W = rng.standard_normal((64, 32)).astype(np.float32)
    findings += check_traced_index_maps(
        "pca_project_pallas[600x64->32]",
        functools.partial(pca_project_pallas, block_rows=128), (X, W))
    scale = np.full((32,), 0.1, np.float32)
    findings += check_traced_index_maps(
        "pca_project_quant_pallas[600x64->32]",
        functools.partial(pca_project_quant_pallas, block_rows=128),
        (X, W, scale))
    return findings
