"""Dataflow invariant checker: abstract interpretation over serving jaxprs.

The scan/merge/cascade pipeline leans on *value* contracts that no type
system sees: the shortlist ids entering the Pallas rescore kernel are
sorted and duplicate-free, ``-1`` dedup/pad sentinels are masked to
``-inf`` before any top-k, and segment id offsets partition the global id
space. PR 7 shipped those as comments; this pass proves them, per traced
entry point, by tracking value facts through jaxpr equations:

  * ``asc``       — the non-sentinel subsequence is sorted ascending
  * ``distinct``  — the non-sentinel values are pairwise distinct
  * ``sentinels`` — negative values are dedup/pad sentinels by contract

with transfer rules for exactly the patterns the live code lowers to:
``sort`` introduces ``asc``; the ``_shortlist`` adjacent-duplicate mask
(``eq(x[1:], x[:-1])`` concatenated behind a leading ``False``) recognised
as a keep-first dup mask; ``where(dup, -1, x)`` on a sorted ``x`` yields
``{asc, sentinels, distinct}`` (the *swapped*-branch variant keeps only
duplicates and loses ``distinct``); ``where(ids >= 0, s, -inf)`` marks
scores as masked by those ids; reshape/broadcast/convert/pad(-1) preserve
facts when they preserve last-axis order. Facts that reach a **sink** are
checked:

  * **Pallas rescore** (``pallas_call`` with an int32 ``(1, U)`` ids
    operand): ids must be ``asc`` (``inv.rowids-order`` — ROADMAP
    follow-up (a), the block-skip guard contract) and ``distinct``
    (``inv.dedup-tiebreak`` — lowest-id-wins dedup), and the kernel body
    must mask ids-derived negative lanes to ``-inf``
    (``inv.sentinel-mask``), found structurally: a ``select_n`` whose
    predicate derives from the ids ref and whose branch is ``-inf``.
  * **jnp rescore** (``take_along_axis`` reporting sentinel-bearing ids
    selected by a ``top_k``): the top-k's score input must carry the
    ``masked-by-those-ids`` fact (``inv.sentinel-mask``), and the ids must
    be ``distinct`` (``inv.dedup-tiebreak``).
  * **segment offsets** (top-level ``_delta_topk`` / ``_segment_rescore``
    dispatches): each segment's ``[offset, offset+capacity)`` id interval,
    read from the call-site literals and operand shapes, must be pairwise
    disjoint and (for deltas) start at or above the base row count
    (``inv.segment-offsets``) — what makes ``merge_segment_topk``'s
    first-occurrence dedup mean "lowest global id".

Unknown primitives drop facts, so the pass errs toward "cannot prove"
(a finding) rather than wrongly proving; each contract has a known-bad
fixture in ``analysis/fixtures/bad_invariants.py`` tripping exactly its
finding.
"""
from __future__ import annotations

import dataclasses
import math

import jax

from repro.analysis import Finding
from repro.analysis.jaxpr_lints import _eqn_subjaxprs

_NEG_INF = float("-inf")


@dataclasses.dataclass(frozen=True)
class Fact:
    """Abstract value attached to one jaxpr var."""

    flags: frozenset = frozenset()     # subset of {asc, distinct, sentinels}
    const: object = None               # known uniform value (scalar fills)
    kind: str | None = None            # dupmask_dups | dupmask_keepfirst |
    #                                    ge0 | masked | slice
    origin: object = None              # provenance Var (mask/slice subject)
    start: int | None = None           # slice start along the last axis


_EMPTY = Fact()


def _scalar(x) -> object:
    try:
        return x.item() if hasattr(x, "item") and getattr(x, "size", 2) == 1 \
            else x if isinstance(x, (int, float, bool)) else None
    except (TypeError, ValueError):
        return None


class _Interp:
    """One entry point's interpretation; findings accumulate on self."""

    def __init__(self, label: str):
        self.label = label
        self.findings: list[Finding] = []
        self._seen_sinks: set[int] = set()

    # -- facts ------------------------------------------------------------

    def fact(self, env, v) -> Fact:
        if isinstance(v, jax.core.Literal):
            return Fact(const=_scalar(v.val))
        return env.get(v, _EMPTY)

    @staticmethod
    def _ident(env, v):
        """The provenance identity of ``v``: its fact origin, else itself."""
        if isinstance(v, jax.core.Literal):
            return None
        f = env.get(v)
        return f.origin if f is not None and f.origin is not None else v

    # -- interpretation ---------------------------------------------------

    def run_jaxpr(self, jaxpr, env) -> None:
        producer = {}
        for eqn in jaxpr.eqns:
            for v in eqn.outvars:
                producer[v] = eqn
        for eqn in jaxpr.eqns:
            self._eqn(eqn, env, producer)

    def _recurse(self, eqn, env) -> None:
        """Generic call boundary: map facts in, interpret, map facts out."""
        closed = eqn.params.get("jaxpr")
        if closed is None:
            return
        inner = closed.jaxpr
        ienv: dict = {}
        for cv, cval in zip(inner.constvars, closed.consts):
            ienv[cv] = Fact(const=_scalar(cval))
        for iv, ov in zip(inner.invars, eqn.invars):
            f = self.fact(env, ov)
            if f is not _EMPTY or f.const is not None:
                # keep OUTER provenance identity across the boundary so
                # mask origins still match after re-entering the caller
                ienv[iv] = f if f.origin is not None or isinstance(
                    ov, jax.core.Literal) else dataclasses.replace(
                        f, origin=ov)
        self.run_jaxpr(inner, ienv)
        for ov, iv in zip(eqn.outvars, inner.outvars):
            f = ienv.get(iv)
            if f is not None:
                env[ov] = f

    def _eqn(self, eqn, env, producer) -> None:
        name = eqn.primitive.name
        if name == "pjit":
            if eqn.params.get("name") == "take_along_axis":
                self._jnp_rescore_sink(eqn, env, producer)
            self._recurse(eqn, env)
            return
        if name == "pallas_call":
            self._pallas_rescore_sink(eqn, env)
            return
        if name in ("scan", "while", "cond", "shard_map", "custom_jvp_call",
                    "custom_vjp_call", "remat"):
            return                               # facts do not flow through
        handler = getattr(self, f"_p_{name}", None)
        if handler is not None:
            handler(eqn, env)
        # every unhandled primitive drops facts (conservative)

    # -- transfer rules ---------------------------------------------------

    def _p_sort(self, eqn, env):
        dim = eqn.params.get("dimension", -1)
        aval = eqn.invars[0].aval
        if aval.shape and dim in (-1, len(aval.shape) - 1):
            env[eqn.outvars[0]] = Fact(flags=frozenset({"asc"}))

    def _p_slice(self, eqn, env):
        src = eqn.invars[0]
        aval = src.aval
        if not aval.shape:
            return
        starts = tuple(eqn.params["start_indices"])
        limits = tuple(eqn.params["limit_indices"])
        # last-axis-only slice: every leading dim taken whole
        for i, (s, li) in enumerate(zip(starts[:-1], limits[:-1])):
            if s != 0 or li != aval.shape[i]:
                return
        f = self.fact(env, src)
        env[eqn.outvars[0]] = Fact(
            flags=f.flags, kind="slice", origin=self._ident(env, src),
            start=int(starts[-1]))

    def _p_eq(self, eqn, env):
        a, b = (self.fact(env, v) for v in eqn.invars[:2])
        if (a.kind == "slice" and b.kind == "slice"
                and a.origin is b.origin and a.origin is not None
                and {a.start, b.start} == {0, 1}):
            env[eqn.outvars[0]] = Fact(kind="dupmask_dups", origin=a.origin)

    def _p_concatenate(self, eqn, env):
        if len(eqn.invars) != 2:
            return
        head, tail = (self.fact(env, v) for v in eqn.invars)
        head_len = eqn.invars[0].aval.shape[-1] \
            if eqn.invars[0].aval.shape else 0
        if (head.const is False and head_len == 1
                and tail.kind == "dupmask_dups"):
            env[eqn.outvars[0]] = Fact(kind="dupmask_keepfirst",
                                       origin=tail.origin)

    def _p_broadcast_in_dim(self, eqn, env):
        src = eqn.invars[0]
        f = self.fact(env, src)
        in_shape = src.aval.shape if hasattr(src, "aval") else ()
        if not in_shape or math.prod(in_shape) == 1:
            if f.const is not None:
                env[eqn.outvars[0]] = Fact(const=f.const)
            return
        bd = tuple(eqn.params["broadcast_dimensions"])
        out_ndim = len(eqn.outvars[0].aval.shape)
        if bd and bd[-1] == out_ndim - 1:        # last axis preserved
            env[eqn.outvars[0]] = dataclasses.replace(
                f, origin=self._ident(env, src))

    def _p_reshape(self, eqn, env):
        src = eqn.invars[0]
        f = self.fact(env, src)
        if f is _EMPTY:
            return
        a = tuple(d for d in src.aval.shape if d != 1)
        b = tuple(d for d in eqn.outvars[0].aval.shape if d != 1)
        if a == b:                               # only unit dims moved
            env[eqn.outvars[0]] = dataclasses.replace(
                f, origin=self._ident(env, src))

    def _p_convert_element_type(self, eqn, env):
        f = self.fact(env, eqn.invars[0])
        if f is not _EMPTY:
            env[eqn.outvars[0]] = dataclasses.replace(
                f, origin=self._ident(env, eqn.invars[0]))

    def _p_squeeze(self, eqn, env):
        self._p_convert_element_type(eqn, env)

    def _p_pad(self, eqn, env):
        f = self.fact(env, eqn.invars[0])
        padval = self.fact(env, eqn.invars[1]).const
        cfg = eqn.params["padding_config"]
        if (f.flags and padval is not None and padval < 0
                and all(int(interior) == 0 and int(lo) >= 0
                        for lo, _hi, interior in cfg)):
            env[eqn.outvars[0]] = dataclasses.replace(
                f, flags=f.flags | {"sentinels"})

    def _p_ge(self, eqn, env):
        rhs = self.fact(env, eqn.invars[1]).const
        subject = self._ident(env, eqn.invars[0])
        if rhs == 0 and subject is not None:
            env[eqn.outvars[0]] = Fact(kind="ge0", origin=subject)

    def _p_and(self, eqn, env):
        # narrowing a >=0 mask only masks MORE lanes to -inf — the
        # sentinel-masking contract direction survives conjunction
        for v in eqn.invars:
            f = self.fact(env, v)
            if f.kind == "ge0":
                env[eqn.outvars[0]] = f
                return

    def _p_max(self, eqn, env):
        # _cascade_select folds per-segment rescore parts with elementwise
        # max; every part masks the same shortlist's sentinels to -inf, so
        # the fold is still masked by those ids
        a, b = (self.fact(env, v) for v in eqn.invars[:2])
        if (a.kind == "masked" and b.kind == "masked"
                and a.origin is b.origin):
            env[eqn.outvars[0]] = a

    def _p_select_n(self, eqn, env):
        pred, case0, case1 = eqn.invars[:3]
        pf = self.fact(env, pred)
        f0, f1 = self.fact(env, case0), self.fact(env, case1)
        out = eqn.outvars[0]
        if pf.kind == "dupmask_keepfirst":
            neg1 = f1.const is not None and f1.const < 0
            neg0 = f0.const is not None and f0.const < 0
            keeps0 = ("asc" in f0.flags
                      and self._ident(env, case0) is pf.origin)
            keeps1 = ("asc" in f1.flags
                      and self._ident(env, case1) is pf.origin)
            if keeps0 and neg1:
                # where(dup, -1, sorted): first occurrence survives ⇒
                # non-sentinels are strictly increasing
                env[out] = Fact(flags=frozenset({"asc", "sentinels",
                                                 "distinct"}))
            elif keeps1 and neg0:
                # swapped branches: only the DUPLICATES survive — still
                # sorted, but repeated values break the lowest-id dedup
                env[out] = Fact(flags=frozenset({"asc", "sentinels"}))
            return
        if pf.kind == "ge0":
            if f0.const == _NEG_INF:
                env[out] = Fact(kind="masked", origin=pf.origin)
            elif f1.const == _NEG_INF:
                # inverted where(ids >= 0, -inf, s): masks the LIVE lanes
                return
            return

    # -- sinks ------------------------------------------------------------

    def _pallas_rescore_sink(self, eqn, env) -> None:
        ids_pos = None
        for pos, v in enumerate(eqn.invars):
            aval = getattr(v, "aval", None)
            if (aval is not None and str(aval.dtype) == "int32"
                    and len(aval.shape) == 2 and aval.shape[0] == 1
                    and aval.shape[1] > 1):
                ids_pos = pos
                break
        if ids_pos is None:
            return                                # plain mode: no contract
        if id(eqn) in self._seen_sinks:
            return
        self._seen_sinks.add(id(eqn))
        f = self.fact(env, eqn.invars[ids_pos])
        where = f"{self.label}:pallas-rescore"
        if "asc" not in f.flags:
            self.findings.append(Finding(
                check="inv.rowids-order", where=where,
                message=(f"{self.label}: cannot prove the row_ids operand "
                         f"of the rescore pallas_call is sorted ascending "
                         f"— the block-skip guard's strict-improvement "
                         f"skip and the shortlist contract assume a "
                         f"sorted, deduplicated id stream")))
            return
        if "distinct" not in f.flags:
            self.findings.append(Finding(
                check="inv.dedup-tiebreak", where=where,
                message=(f"{self.label}: row_ids reach the rescore kernel "
                         f"sorted but not provably duplicate-free — "
                         f"repeated ids break the lowest-id-wins dedup "
                         f"(_shortlist keep-first contract)")))
            return
        if not self._kernel_masks_ids(eqn, ids_pos):
            self.findings.append(Finding(
                check="inv.sentinel-mask", where=where,
                message=(f"{self.label}: the rescore kernel never masks "
                         f"ids-derived negative lanes to -inf — a -1 "
                         f"dedup/pad sentinel's score could surface as a "
                         f"real result")))

    @staticmethod
    def _kernel_masks_ids(eqn, ids_pos: int) -> bool:
        """Structurally: some ``select_n`` in the kernel body has a
        predicate derived from the ids ref and a ``-inf`` branch."""
        kernel = eqn.params["jaxpr"]
        kj = kernel.jaxpr if hasattr(kernel, "jaxpr") else kernel
        if ids_pos >= len(kj.invars):
            return False
        derived = {kj.invars[ids_pos]}
        neginf = set()

        def scan(jaxpr):
            hit = False
            for e in jaxpr.eqns:
                lit_neg = any(isinstance(v, jax.core.Literal)
                              and _scalar(v.val) == _NEG_INF
                              for v in e.invars)
                if lit_neg or any(v in neginf for v in e.invars
                                  if not isinstance(v, jax.core.Literal)):
                    if e.primitive.name in ("broadcast_in_dim",
                                            "convert_element_type"):
                        neginf.update(e.outvars)
                if e.primitive.name == "select_n":
                    pred = e.invars[0]
                    cases = e.invars[1:]
                    if (not isinstance(pred, jax.core.Literal)
                            and pred in derived
                            and any((not isinstance(c, jax.core.Literal)
                                     and c in neginf)
                                    or (isinstance(c, jax.core.Literal)
                                        and _scalar(c.val) == _NEG_INF)
                                    for c in cases)):
                        hit = True
                if any(not isinstance(v, jax.core.Literal) and v in derived
                       for v in e.invars):
                    derived.update(e.outvars)
                closed = e.params.get("jaxpr")
                if closed is not None and hasattr(closed, "jaxpr"):
                    # jnp.where traces as pjit[_where] even inside kernel
                    # bodies — carry the derived/-inf sets across the call
                    # boundary, then back out to the call's outvars
                    sub = closed.jaxpr
                    for iv, ov in zip(sub.invars, e.invars):
                        if isinstance(ov, jax.core.Literal):
                            if _scalar(ov.val) == _NEG_INF:
                                neginf.add(iv)
                            continue
                        if ov in derived:
                            derived.add(iv)
                        if ov in neginf:
                            neginf.add(iv)
                    inner_hit = scan(sub)
                    hit = inner_hit or hit
                    for ov, iv in zip(e.outvars, sub.outvars):
                        if not isinstance(iv, jax.core.Literal):
                            if iv in derived:
                                derived.add(ov)
                            if iv in neginf:
                                neginf.add(ov)
                else:
                    for sub in _eqn_subjaxprs(e):
                        hit = scan(sub) or hit
            return hit

        return scan(kj)

    def _jnp_rescore_sink(self, eqn, env, producer) -> None:
        ids_f = self.fact(env, eqn.invars[0])
        if "sentinels" not in ids_f.flags:
            return                                # not a rescore select
        idx = eqn.invars[1]
        src = producer.get(idx)
        if src is None or src.primitive.name != "top_k":
            return
        if id(src) in self._seen_sinks:
            return
        self._seen_sinks.add(id(src))
        where = f"{self.label}:jnp-rescore"
        if "distinct" not in ids_f.flags:
            self.findings.append(Finding(
                check="inv.dedup-tiebreak", where=where,
                message=(f"{self.label}: the rescore top-k reports "
                         f"sentinel-bearing ids that are not provably "
                         f"duplicate-free — a document could surface "
                         f"twice in one result list")))
            return
        score_f = self.fact(env, src.invars[0])
        ids_origin = self._ident(env, eqn.invars[0])
        if score_f.kind != "masked" or score_f.origin is not ids_origin:
            self.findings.append(Finding(
                check="inv.sentinel-mask", where=where,
                message=(f"{self.label}: rescore scores reach top_k "
                         f"without the where(ids >= 0, s, -inf) sentinel "
                         f"mask — a -1 dedup/pad slot's score competes as "
                         f"a real document")))


# ---------------------------------------------------------------------------
# segment-offset disjointness (top-level dispatch literals)
# ---------------------------------------------------------------------------

_SEGMENT_DISPATCHES = {
    # dispatch name -> positional role of its two scalar operands
    "_delta_topk": ("n_valid", "offset"),
    "_segment_rescore": ("offset", "n_valid"),
}


def _scalar_operands(eqn, constmap) -> list:
    vals = []
    for v in eqn.invars:
        aval = getattr(v, "aval", None)
        if aval is None or aval.shape:
            continue
        if isinstance(v, jax.core.Literal):
            vals.append(_scalar(v.val))
        elif v in constmap:
            vals.append(_scalar(constmap[v]))
        else:
            vals.append(None)                     # traced: unknowable here
    return vals


def _first_matrix_rows(eqn) -> int | None:
    for v in eqn.invars:
        aval = getattr(v, "aval", None)
        if aval is not None and len(aval.shape) == 2:
            return int(aval.shape[0])
    return None


def check_segment_offsets(label: str, closed_jaxpr) -> list[Finding]:
    """Prove the per-segment global-id intervals partition disjointly."""
    jaxpr = closed_jaxpr.jaxpr
    constmap = dict(zip(jaxpr.constvars, closed_jaxpr.consts))
    groups: dict[str, list[tuple[int, int]]] = {}
    base_n = None
    findings: list[Finding] = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name != "pjit":
            continue
        name = eqn.params.get("name")
        if name == "_scan_topk" and base_n is None:
            base_n = _first_matrix_rows(eqn)
        if name not in _SEGMENT_DISPATCHES:
            continue
        roles = _SEGMENT_DISPATCHES[name]
        scalars = _scalar_operands(eqn, constmap)
        cap = _first_matrix_rows(eqn)
        if len(scalars) < len(roles) or cap is None or any(
                s is None for s in scalars[:len(roles)]):
            findings.append(Finding(
                check="inv.segment-offsets", where=f"{label}:{name}",
                message=(f"{label}: cannot statically read the "
                         f"(offset, n_valid) operands of a {name} "
                         f"dispatch — segment id disjointness is "
                         f"unprovable")))
            continue
        vals = dict(zip(roles, scalars))
        off = int(vals["offset"])
        groups.setdefault(name, []).append((off, off + cap))
    for name, ivs in sorted(groups.items()):
        if name == "_delta_topk":
            if base_n is not None:
                low = min(o for o, _ in ivs)
                if low < base_n:
                    findings.append(Finding(
                        check="inv.segment-offsets",
                        where=f"{label}:{name}:base",
                        message=(f"{label}: delta segment id offset {low} "
                                 f"overlaps the base index rows "
                                 f"[0, {base_n}) — delta global ids must "
                                 f"start past the base")))
        ivs = sorted(ivs)
        for (alo, ahi), (blo, bhi) in zip(ivs, ivs[1:]):
            if blo < ahi:
                findings.append(Finding(
                    check="inv.segment-offsets",
                    where=f"{label}:{name}:{alo}-{blo}",
                    message=(f"{label}: {name} segment id intervals "
                             f"[{alo}, {ahi}) and [{blo}, {bhi}) overlap "
                             f"— two documents share a global id, so the "
                             f"cross-segment merge dedup is wrong")))
    return findings


# ---------------------------------------------------------------------------
# entry
# ---------------------------------------------------------------------------


def check_entry(label: str, fn, args) -> list[Finding]:
    """All invariant checks for one traced entry point."""
    closed = jax.make_jaxpr(fn)(*args)
    findings = check_segment_offsets(label, closed)
    interp = _Interp(label)
    env: dict = {}
    for cv, cval in zip(closed.jaxpr.constvars, closed.consts):
        env[cv] = Fact(const=_scalar(cval))
    interp.run_jaxpr(closed.jaxpr, env)
    return findings + interp.findings


def run() -> list[Finding]:
    """Prove the pipeline contracts on every serving entry point."""
    from repro.analysis.jaxpr_lints import serving_entry_points
    findings: list[Finding] = []
    for ep in serving_entry_points():
        findings += check_entry(ep.label, ep.fn, ep.args)
    return findings
