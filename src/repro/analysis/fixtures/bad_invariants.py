"""Invariant-checker fixtures: each entry breaks exactly one value
contract the abstract interpreter proves on the live pipeline."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.topk_score import topk_score_pallas


def unsorted_rescore(D: jax.Array, q: jax.Array, cids: jax.Array, k: int = 5):
    """Skips ``_shortlist`` entirely: the gathered ids reach the rescore
    kernel in raw coarse-scan order — never sorted, never deduplicated.
    Must trip exactly ``inv.rowids-order``."""
    uids = cids.reshape(-1)
    rows = D[jnp.maximum(uids, 0)]
    return topk_score_pallas(rows, q, k=k, block_n=16, interpret=True,
                             row_ids=uids)


def swapped_dedup_rescore(D: jax.Array, q: jax.Array, cids: jax.Array,
                          k: int = 5):
    """The dedup select with its branches swapped: keeps the *duplicates*
    and sentinels the first occurrences — sorted, but the lowest-id
    keep-first contract is gone. Must trip exactly
    ``inv.dedup-tiebreak``."""
    flat = jnp.sort(cids.reshape(-1))
    dup = jnp.concatenate([jnp.zeros((1,), bool), flat[1:] == flat[:-1]])
    uids = jnp.where(dup, flat, jnp.int32(-1))          # branches swapped
    rows = D[jnp.maximum(uids, 0)]
    return topk_score_pallas(rows, q, k=k, block_n=16, interpret=True,
                             row_ids=uids)


def unmasked_rescore_jnp(D: jax.Array, q: jax.Array, cids: jax.Array,
                         k: int = 5):
    """A correct shortlist whose -1 sentinel slots are never masked to
    -inf before the final top-k: a dedup slot's score competes as a real
    document. Must trip exactly ``inv.sentinel-mask``."""
    flat = jnp.sort(cids.reshape(-1))
    dup = jnp.concatenate([jnp.zeros((1,), bool), flat[1:] == flat[:-1]])
    uids = jnp.where(dup, jnp.int32(-1), flat)          # correct dedup
    rows = D[jnp.maximum(uids, 0)].astype(jnp.float32)
    s = q @ rows.T                                      # missing the mask
    top_s, idx = jax.lax.top_k(s, k)
    ids = jnp.take_along_axis(jnp.broadcast_to(uids[None, :], s.shape),
                              idx, axis=-1)
    return top_s, ids


def overlapping_segments(D1: jax.Array, D2: jax.Array, scale: jax.Array,
                         q: jax.Array, k: int = 5):
    """Two delta dispatches whose [offset, offset+capacity) global-id
    intervals collide — two documents share an id, so the cross-segment
    merge dedup is wrong. Must trip exactly ``inv.segment-offsets``."""
    from repro.core.index import _delta_topk
    a = _delta_topk(D1, scale, q, jnp.int32(D1.shape[0]), jnp.int32(100), k)
    b = _delta_topk(D2, scale, q, jnp.int32(D2.shape[0]), jnp.int32(132), k)
    return a, b
