"""Cost-model fixtures: entry points that impersonate a real serving
entry (same label, same corpus) but spend more than its checked-in
budget — each must turn the cost gate red against ``analysis_costs.json``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis.jaxpr_lints import EntryPoint, _tiny


def shadow_copy_entry() -> EntryPoint:
    """The int8 dense entry with a full-corpus f32 shadow copy inside the
    dispatch: the quantized index is dequantized wholesale before the
    matmul instead of strip-by-strip.  Dispatch count is unchanged, but
    HBM traffic per query balloons — ``cost.regression`` on bytes."""
    from repro.core import DenseIndex, StaticPruner

    D, Q = _tiny()
    pruner = StaticPruner(cutoff=0.5).fit(D)
    Dh = pruner.prune_index(D)
    W, _ = pruner.projection()
    idx = DenseIndex.build(Dh, quantize_int8=True, backend="jnp")
    n, m = Dh.shape

    @jax.jit
    def _bad_search(D8, scale, Wm, q):
        Df = D8.astype(jnp.float32) * scale[None, :]   # corpus shadow copy
        s = (q @ Wm) @ Df.T
        return jax.lax.top_k(s, 10)

    def entry(q):
        return _bad_search(idx.vectors, idx.scale, W, q)

    return EntryPoint(
        label="DenseIndex.search_projected[jnp,int8]", fn=entry, args=(Q,),
        expected_dispatches=1, corpus_shape=(n, m), family="dense",
        backend="jnp", storage_dtype=str(idx.vectors.dtype), strip_rows=128,
        batch=int(Q.shape[0]))


def extra_dispatch_entry() -> EntryPoint:
    """The f32 dense entry split into two compiled dispatches (score,
    then select) instead of one fused computation — ``cost.regression``
    on the exact-gated dispatch count."""
    from repro.core import StaticPruner

    D, Q = _tiny()
    pruner = StaticPruner(cutoff=0.5).fit(D)
    Dh = pruner.prune_index(D)
    W, _ = pruner.projection()
    n, m = Dh.shape

    _score = jax.jit(lambda Dm, Wm, q: (q @ Wm) @ Dm.T)
    _select = jax.jit(lambda s: jax.lax.top_k(s, 10))

    def entry(q):
        return _select(_score(Dh, W, q))

    return EntryPoint(
        label="DenseIndex.search_projected[jnp]", fn=entry, args=(Q,),
        expected_dispatches=1, corpus_shape=(n, m), family="dense",
        backend="jnp", batch=int(Q.shape[0]))
