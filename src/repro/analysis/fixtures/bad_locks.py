"""Concurrency-lint fixtures: each class commits one threading sin on
purpose. Parsed by the analyzer (AST only) — never instantiated."""
from __future__ import annotations

import threading
import time

import numpy as np


class UnguardedCounter:
    """`count` is guarded in `bump` but read bare in `peek`."""

    def __init__(self):
        self._lock = threading.Lock()
        self.count = 0

    def bump(self) -> None:
        with self._lock:
            self.count += 1

    def peek(self) -> int:
        return self.count


class NeverLockedLog:
    """Owns a lock, but `log` is mutated and read with it never held."""

    def __init__(self):
        self._lock = threading.Lock()
        self.log: list = []

    def record(self, x) -> None:
        self.log.append(x)

    def dump(self) -> list:
        return list(self.log)


class Left:
    """Acquires its own lock, then the peer's — while Right does the
    opposite: a classic ABBA deadlock."""

    def __init__(self, peer: "Right"):
        self._lock = threading.Lock()
        self.peer = peer
        self.value = 0

    def poke(self) -> None:
        with self._lock:
            self.value += 1
            self.peer.poke_back()

    def poke_back(self) -> None:
        with self._lock:
            self.value += 1


class Right:
    def __init__(self, peer: Left):
        self._lock = threading.Lock()
        self.peer = peer
        self.value = 0

    def poke(self) -> None:
        with self._lock:
            self.value += 1
            self.peer.poke_back()

    def poke_back(self) -> None:
        with self._lock:
            self.value += 1


class SleepyWriter:
    """Blocks the device/host (asarray + sleep) while holding the lock
    every reader needs."""

    def __init__(self):
        self._lock = threading.Lock()
        self.snapshot = None

    def publish(self, device_array) -> None:
        with self._lock:
            self.snapshot = np.asarray(device_array)   # D2H under lock
            time.sleep(0.01)

    def read(self):
        with self._lock:
            return self.snapshot
