"""Lock-sanitizer fixture: a producer/consumer handoff that deadlocks
with zero lock-order cycles — the consumer parks on the queue holding
the exact lock the producer needs to publish. Must trip exactly
``locks.handoff-deadlock``."""
from __future__ import annotations

import queue
import threading


class StalledPipeline:
    """Consumer blocks on ``_q.get()`` inside ``_lock``; the only
    producer publishes under the same ``_lock``. The acquisition graph
    is a single node (no cycle), yet the first consume wedges forever.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._q: queue.Queue = queue.Queue()
        self.processed = 0

    def produce(self, item) -> None:
        with self._lock:
            self._q.put(item)

    def consume(self):
        with self._lock:
            item = self._q.get()        # unbounded wait, lock held
            self.processed += 1
        return item
