"""Jaxpr-lint fixtures: each function violates one traced-hot-path
invariant on purpose."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def upcasting_search(D_int8: jax.Array, scale: jax.Array, q: jax.Array,
                     k: int = 10):
    """The anti-pattern the storage-dtype lint exists for: dequantise the
    ENTIRE int8 corpus to f32 before scanning — a 4x shadow copy in HBM
    instead of per-strip in-register dequant."""
    Df = D_int8.astype(jnp.float32) * scale[None, :]
    scores = q @ Df.T
    return jax.lax.top_k(scores, k)


def chatty_search(D: jax.Array, q: jax.Array, k: int = 10):
    """Host callback inside the hot path: every dispatch synchronises the
    device behind the host print."""
    scores = q @ D.T
    jax.debug.print("scores ready: {}", scores.shape[0])
    return jax.lax.top_k(scores, k)


def two_dispatch_search(D: jax.Array, q: jax.Array, k: int = 10):
    """Fusion breaker: the scoring and the selection are dispatched as two
    separate jits, so the n-length score vector round-trips through HBM
    between them."""
    score = jax.jit(lambda d, x: x @ d.T)
    select = jax.jit(functools.partial(jax.lax.top_k, k=k))
    return select(score(D, q))


class RecompilingSearcher:
    """Recompile bomb: the live row count is a STATIC jit argument, so
    every distinct count compiles a fresh executable — exactly what the
    recompile-stability lint drives a sweep to catch."""

    def __init__(self, D: jax.Array):
        self.D = D
        self._fn = jax.jit(self._search, static_argnames=("n_valid",))

    @staticmethod
    def _search(D, q, *, n_valid: int):
        scores = q @ D.T
        ids = jnp.arange(scores.shape[-1])
        scores = jnp.where(ids[None, :] < n_valid, scores, -jnp.inf)
        return jax.lax.top_k(scores, 5)

    def search(self, q: jax.Array, n_valid: int):
        return self._fn(self.D, q, n_valid=n_valid)

    def cache_sizes(self) -> dict:
        return {"RecompilingSearcher._search": self._fn._cache_size()}
