"""Known-bad fixtures for the analyzer self-tests.

Each module here violates exactly one invariant the analyzers exist to
catch; ``tests/test_analysis.py`` asserts each produces its expected
finding (and nothing else). These are NEVER imported by production code.

  * ``bad_jaxpr``      — dispatch-contract violations (shadow upcast,
    host callback, extra dispatch, recompile churn).
  * ``bad_locks``      — guarded-field / lock-order / blocking-under-lock
    violations for the concurrency pass.
  * ``bad_costs``      — entry points impersonating real serving entries
    but overspending their ``analysis_costs.json`` budget.
  * ``bad_invariants`` — rescore pipelines breaking exactly one value
    contract each (sortedness, dedup tie-break, sentinel mask, segment
    offsets).
  * ``bad_handoff``    — a cycle-free producer/consumer handoff deadlock
    for the lock sanitizer.
"""

#: a topk_score config whose double-buffered f32 strip alone (~64 MiB)
#: dwarfs a 16 MiB core — must trip pallas.vmem-budget
BAD_TOPK_CONFIG = dict(n=1_000_000, m=1024, B=256, k=100,
                       block_n=8192, block_b=512, dtype="float32")
