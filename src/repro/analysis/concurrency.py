"""AST concurrency lint for the serving tier (no imports, no execution).

The live-serving classes (``RetrievalServer``, ``BatchingQueue``,
``IndexUpdater``) share mutable state across worker/appender/compactor
threads behind ``threading`` locks — a discipline Python cannot check.
This pass parses the source and rebuilds it statically:

  * **guarded-field map** — for every class owning a lock field
    (``threading.Lock/RLock/Condition``, including dataclass
    ``field(default_factory=...)``), every ``self.X`` access in every
    method is recorded with the set of locks *lexically held* at that
    point (``with self.lock:`` nesting, plus one level of call-site
    propagation: a private method whose in-class call sites ALL hold a
    lock is analysed as running under it).
  * **conc.unguarded-field** — a field written outside ``__init__`` that
    has BOTH locked and unlocked accesses: the lock is load-bearing
    somewhere and skipped somewhere else, which is how torn snapshots and
    lost updates happen.
  * **conc.unlocked-shared-mutable** — a mutated field touched from
    several methods of a lock-owning class with NO locked accesses at
    all: nothing even claims to guard it.
  * **conc.lock-order** — directed acquisition edges (lock held →
    lock acquired), including interprocedural edges through calls to
    known methods of the analysed classes (``self.server.swap_index``
    acquires the server's swap lock while the updater's lock is held);
    any cycle is a deadlock waiting for the right interleaving.
  * **conc.blocking-under-lock** — device/host synchronisation
    (``block_until_ready``, ``np.asarray`` on device arrays,
    ``time.sleep``…) while a lock is held stalls every thread parked on
    that lock behind the device.

Self-synchronised stdlib primitives (``queue.Queue``, ``threading.Event``
/``Semaphore``) are exempt; fields only ever written in ``__init__`` /
``__post_init__`` are config, not shared mutable state.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Sequence

from repro.analysis import Finding

_LOCK_TYPES = ("Lock", "RLock", "Condition")
_SELFSYNC_TYPES = ("Event", "Semaphore", "BoundedSemaphore", "Queue",
                   "SimpleQueue", "LifoQueue", "PriorityQueue", "Barrier")
_INIT_METHODS = ("__init__", "__post_init__")
# method calls on a field that mutate it in place
_MUTATORS = frozenset({
    "append", "appendleft", "extend", "insert", "add", "remove", "discard",
    "pop", "popleft", "clear", "update", "put", "put_nowait", "setdefault",
    "sort", "reverse",
})
# calls that synchronise with the device / block the host
_BLOCKING_TAILS = frozenset({"block_until_ready"})
_BLOCKING_DOTTED = frozenset({
    "np.asarray", "numpy.asarray", "jnp.asarray", "jax.numpy.asarray",
    "jax.device_get", "jax.device_put", "jax.block_until_ready",
    "time.sleep",
})


@dataclasses.dataclass(frozen=True)
class Access:
    method: str
    field: str
    kind: str                  # "read" | "write"
    held: frozenset            # lock field names held at the access


@dataclasses.dataclass(frozen=True)
class CallSite:
    method: str
    held: frozenset
    target: str                # bare method name being invoked
    via_self: bool             # self._m() vs self.field._m()
    owner: str | None = None   # field name for self.field._m() calls
    bounded: bool = False      # call passes args/timeout (cannot block forever)


@dataclasses.dataclass
class ClassInfo:
    module: str
    name: str
    locks: set = dataclasses.field(default_factory=set)
    selfsync: set = dataclasses.field(default_factory=set)
    methods: dict = dataclasses.field(default_factory=dict)
    accesses: list = dataclasses.field(default_factory=list)
    calls: list = dataclasses.field(default_factory=list)
    # (method, held_before frozenset, lock acquired)
    acquisitions: list = dataclasses.field(default_factory=list)
    blocking: list = dataclasses.field(default_factory=list)

    def locks_acquired_by(self, method: str) -> set:
        return {l for m, _, l in self.acquisitions if m == method}


def _dotted(node) -> str | None:
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_self_attr(node) -> str | None:
    """``self.X`` -> ``"X"``."""
    if (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"):
        return node.attr
    return None


def _value_typename(value) -> str | None:
    """Tail name of the constructor in ``self.x = threading.Lock()``."""
    if isinstance(value, ast.Call):
        d = _dotted(value.func)
        if d:
            return d.rsplit(".", 1)[-1]
    return None


class _ClassScanner:
    """Two-pass scan of one ClassDef: lock discovery, then lexical
    held-lock tracking through every method body."""

    def __init__(self, module: str, node: ast.ClassDef):
        self.info = ClassInfo(module=module, name=node.name)
        self.node = node
        self._discover()

    def _discover(self) -> None:
        info = self.info
        for stmt in self.node.body:
            # dataclass-style: _lock: RLock = field(default_factory=...)
            if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                              ast.Name):
                names = [stmt.target.id]
                ann = ast.dump(stmt.annotation) if stmt.annotation else ""
                factory = ""
                if isinstance(stmt.value, ast.Call):
                    for kw in stmt.value.keywords:
                        if kw.arg == "default_factory":
                            factory = _dotted(kw.value) or ""
                blob = ann + " " + factory
                if any(t in blob for t in _LOCK_TYPES):
                    info.locks.update(names)
                elif any(t in blob for t in _SELFSYNC_TYPES):
                    info.selfsync.update(names)
            if (isinstance(stmt, ast.FunctionDef)
                    and not any(_dotted(d) in ("staticmethod", "classmethod")
                                for d in stmt.decorator_list)):
                info.methods[stmt.name] = stmt
        for name in _INIT_METHODS:
            fn = info.methods.get(name)
            if fn is None:
                continue
            for sub in ast.walk(fn):
                targets = []
                if isinstance(sub, ast.Assign):
                    targets, value = sub.targets, sub.value
                elif isinstance(sub, ast.AnnAssign) and sub.value is not None:
                    targets, value = [sub.target], sub.value
                else:
                    continue
                tn = _value_typename(value)
                if tn is None:
                    continue
                for t in targets:
                    f = _is_self_attr(t)
                    if f is None:
                        continue
                    if tn in _LOCK_TYPES:
                        self.info.locks.add(f)
                    elif tn in _SELFSYNC_TYPES:
                        self.info.selfsync.add(f)

    # -- pass 2: per-method lexical scan -----------------------------------
    def scan(self, entry_held: dict | None = None) -> None:
        entry_held = entry_held or {}
        info = self.info
        info.accesses, info.calls = [], []
        info.acquisitions, info.blocking = [], []
        for name, fn in info.methods.items():
            if name in _INIT_METHODS:
                continue
            held = frozenset(entry_held.get(name, ()))
            for stmt in fn.body:
                self._scan(stmt, held, name)

    def _lock_of(self, expr) -> str | None:
        f = _is_self_attr(expr)
        return f if f in self.info.locks else None

    def _scan(self, node, held: frozenset, method: str) -> None:
        info = self.info
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return      # nested defs run at unknown times / threads
        if isinstance(node, ast.With):
            acquired = []
            for item in node.items:
                self._scan(item.context_expr, held, method)
                lf = self._lock_of(item.context_expr)
                if lf is not None:
                    info.acquisitions.append((method, held, lf))
                    acquired.append(lf)
            inner = held | frozenset(acquired)
            for s in node.body:
                self._scan(s, inner, method)
            return
        if isinstance(node, ast.Attribute):
            f = _is_self_attr(node)
            if f is not None and f not in info.locks \
                    and f not in info.selfsync:
                kind = ("write" if isinstance(node.ctx,
                                              (ast.Store, ast.Del))
                        else "read")
                info.accesses.append(Access(method, f, kind, held))
        if isinstance(node, ast.Call):
            fn = node.func
            # self.field.mutator(...) counts as a write to the field
            if isinstance(fn, ast.Attribute):
                owner = _is_self_attr(fn.value)
                if (owner is not None and fn.attr in _MUTATORS
                        and owner not in info.locks
                        and owner not in info.selfsync):
                    info.accesses.append(Access(method, owner, "write",
                                                held))
                bounded = bool(node.args) or any(
                    kw.arg in ("timeout", "block") for kw in node.keywords)
                if isinstance(fn.value, ast.Name) and fn.value.id == "self":
                    info.calls.append(CallSite(method, held, fn.attr, True,
                                               None, bounded))
                elif owner is not None:
                    info.calls.append(CallSite(method, held, fn.attr, False,
                                               owner, bounded))
            dotted = _dotted(fn)
            tail = dotted.rsplit(".", 1)[-1] if dotted else (
                fn.attr if isinstance(fn, ast.Attribute) else None)
            if held and (tail in _BLOCKING_TAILS
                         or (dotted and dotted in _BLOCKING_DOTTED)):
                info.blocking.append((method, dotted or tail, sorted(held)))
        for child in ast.iter_child_nodes(node):
            self._scan(child, held, method)


def _propagated_context(info: ClassInfo) -> dict:
    """One level of call-site lock propagation for private methods: if
    every in-class call site of ``self._m()`` holds lock L, ``_m``'s body
    is re-analysed with L held on entry."""
    ctx = {}
    for name in info.methods:
        if not name.startswith("_") or name.startswith("__"):
            continue
        sites = [c.held for c in info.calls
                 if c.via_self and c.target == name]
        if not sites:
            continue
        common = frozenset.intersection(*sites)
        if common:
            ctx[name] = common
    return ctx


def analyze_classes(source: str, module: str) -> list[ClassInfo]:
    tree = ast.parse(source)
    infos = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            sc = _ClassScanner(module, node)
            sc.scan()
            sc.scan(_propagated_context(sc.info))   # second pass, propagated
            infos.append(sc.info)
    return infos


def field_findings(info: ClassInfo) -> list[Finding]:
    findings = []
    fields = sorted({a.field for a in info.accesses})
    for field in fields:
        acc = [a for a in info.accesses if a.field == field]
        writes = [a for a in acc if a.kind == "write"]
        if not writes:
            continue                     # read-only after init: config
        locked = [a for a in acc if a.held]
        unlocked = [a for a in acc if not a.held]
        if locked and unlocked:
            guards = sorted({l for a in locked for l in a.held})
            for method in sorted({a.method for a in unlocked}):
                kinds = sorted({a.kind for a in unlocked
                                if a.method == method})
                findings.append(Finding(
                    check="conc.unguarded-field",
                    where=f"{info.module}:{info.name}.{method}:{field}",
                    message=(f"{info.name}.{field} is guarded by "
                             f"{'/'.join(guards)} elsewhere but "
                             f"{'/'.join(kinds)} without it in "
                             f"{method}() — torn snapshot or lost "
                             f"update under contention")))
        elif not locked and info.locks and len({a.method for a in acc}) > 1:
            methods = sorted({a.method for a in acc})
            findings.append(Finding(
                check="conc.unlocked-shared-mutable",
                where=f"{info.module}:{info.name}:{field}",
                message=(f"{info.name}.{field} is mutated and shared "
                         f"across {', '.join(methods)} with no lock ever "
                         f"held, in a class that owns "
                         f"{'/'.join(sorted(info.locks))}")))
    return findings


def acquisition_edges(infos: Sequence[ClassInfo]) -> dict[str, set]:
    """Directed acquisition graph over qualified locks (``Class.lock``
    held -> acquired), including interprocedural edges through calls to
    known methods of the analysed classes."""
    by_method: dict[str, list[tuple[ClassInfo, set]]] = {}
    for info in infos:
        for m in info.methods:
            locks = info.locks_acquired_by(m)
            if locks:
                by_method.setdefault(m, []).append((info, locks))
    edges: dict[str, set] = {}

    def _edge(a: str, b: str) -> None:
        if a != b:
            edges.setdefault(a, set()).add(b)

    for info in infos:
        for _method, held, lock in info.acquisitions:
            for h in held:
                _edge(f"{info.name}.{h}", f"{info.name}.{lock}")
        for c in info.calls:
            if not c.held:
                continue
            for target_info, locks in by_method.get(c.target, ()):
                if c.via_self and target_info is not info:
                    continue            # self-call: same class only
                for l in locks:
                    for h in c.held:
                        _edge(f"{info.name}.{h}",
                              f"{target_info.name}.{l}")
    return edges


def lock_order_findings(infos: Sequence[ClassInfo]) -> list[Finding]:
    """Cycles in the acquisition graph are potential deadlocks."""
    edges = acquisition_edges(infos)
    findings, seen = [], set()

    def _dfs(n, stack, on_stack):
        for nxt in sorted(edges.get(n, ())):
            if nxt in on_stack:
                cyc = stack[stack.index(nxt):] + [nxt]
                key = tuple(sorted(cyc[:-1]))
                if key not in seen:
                    seen.add(key)
                    findings.append(Finding(
                        check="conc.lock-order",
                        where=":".join(sorted(key)),
                        message=("lock acquisition cycle "
                                 + " -> ".join(cyc)
                                 + " — two threads entering from opposite "
                                   "ends deadlock")))
            elif nxt not in visited:
                visited.add(nxt)
                _dfs(nxt, stack + [nxt], on_stack | {nxt})

    visited: set = set()
    for n in sorted(edges):
        if n not in visited:
            visited.add(n)
            _dfs(n, [n], {n})
    return findings


def blocking_findings(infos: Sequence[ClassInfo]) -> list[Finding]:
    findings = []
    for info in infos:
        for method, call, held in info.blocking:
            findings.append(Finding(
                check="conc.blocking-under-lock",
                where=f"{info.module}:{info.name}.{method}:{call}",
                message=(f"{info.name}.{method}() calls {call} while "
                         f"holding {'/'.join(held)} — every thread parked "
                         f"on that lock now waits on the device/host "
                         f"transfer")))
    return findings


def analyze(paths: Sequence[tuple[str, str | Path]]) -> list[Finding]:
    """(module-label, source-path) pairs -> combined findings."""
    infos: list[ClassInfo] = []
    for module, path in paths:
        infos += analyze_classes(Path(path).read_text(), module)
    findings: list[Finding] = []
    for info in infos:
        findings += field_findings(info)
    findings += lock_order_findings(infos)
    findings += blocking_findings(infos)
    return findings


#: the serving-tier modules under contract
TARGETS = (("repro.launch.serve", "launch/serve.py"),
           ("repro.core.maintenance", "core/maintenance.py"))


def source_targets() -> list[tuple[str, Path]]:
    """(dotted-module, path) for every module in the ``repro`` tree,
    excluding the analysis package itself (its fixtures are deliberately
    broken and its passes are not serving code)."""
    import repro
    root = Path(next(iter(repro.__path__)))   # namespace package
    targets = []
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root)
        if rel.parts[0] == "analysis":
            continue
        dotted = ".".join(("repro",) + rel.parts[:-1]
                          + (() if rel.name == "__init__.py"
                             else (rel.stem,)))
        targets.append((dotted, path))
    return targets


def run() -> list[Finding]:
    return analyze(source_targets())
