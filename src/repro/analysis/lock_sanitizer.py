"""Happens-before lock sanitizer: static handoff analysis + runtime
lock-order recording, cross-checked against the static graph.

The AST pass in ``concurrency`` already proves the *lexical* lock-order
graph acyclic. Two failure modes slip through it:

  * **Handoff deadlocks** — no lock cycle at all: a consumer blocks on a
    channel (``queue.Queue.get``, ``Condition.wait``, a reply future)
    *while holding a lock the producer needs* to ever publish. The
    static half here walks every class's call sites: an unbounded
    receive on a self-owned channel with lock L held is a finding
    (``locks.handoff-deadlock``) when some producer site of the same
    channel holds or acquires L. A condition variable's *own* lock is
    exempt — ``wait`` releases it — as is any receive with a timeout
    (stall, not deadlock).
  * **Dynamic orders the AST cannot see** — locks threaded through
    callbacks, reflection, or data. The runtime half monkeypatches
    ``threading.Lock/RLock/Condition`` with recording wrappers (scoped
    to locks *created by* ``repro`` serving code — stdlib internals and
    the analysis package are left alone). Every acquisition appends
    held-lock -> acquired-lock edges to a :class:`LockMonitor`; labels
    are derived lazily at first acquisition from the acquiring frame
    (``with self._index_lock:`` -> ``RetrievalServer._index_lock``).
    The observed multigraph must embed in the transitive closure of the
    static acquisition graph (``locks.graph-divergence`` otherwise);
    observed locks the static pass never discovered are flagged
    ``locks.unknown-lock``.

CI runs the tier-1 serve/segments/maintenance tests under the monitor,
uploads the observed graph, and feeds it back through
``python -m repro.analysis --lock-graph LOCK_graph.json`` so the two
views can never drift apart silently.
"""
from __future__ import annotations

import json
import linecache
import re
import sys
import threading
from pathlib import Path
from typing import Sequence

from repro.analysis import Finding, concurrency as _conc

LOCKGRAPH_SCHEMA = "repro.analysis/lockgraph-v1"

#: blocking receive method -> the channel kinds it blocks on
_RECV_METHODS = frozenset({"get", "wait", "wait_for", "result", "join"})
#: methods that publish to / wake a channel
_PRODUCE_METHODS = frozenset({"put", "put_nowait", "set", "notify",
                              "notify_all", "set_result"})


# --------------------------------------------------------------------------
# static half: handoff (happens-before) analysis
# --------------------------------------------------------------------------

def _channel_fields(info) -> set:
    """Fields a thread can park on: self-sync primitives (queues, events)
    plus condition variables (wait/notify handoff)."""
    return set(info.selfsync) | set(info.locks)


def handoff_findings(infos: Sequence) -> list[Finding]:
    findings = []
    for info in infos:
        channels = _channel_fields(info)
        produced_under: dict[str, list[frozenset]] = {}
        for c in info.calls:
            if (c.owner in channels and c.target in _PRODUCE_METHODS):
                # locks held at the producing site, plus any the producing
                # method acquires on some path before/around the publish
                need = set(c.held) | info.locks_acquired_by(c.method)
                produced_under.setdefault(c.owner, []).append(
                    frozenset(need))
        for c in info.calls:
            if (c.owner not in channels or c.target not in _RECV_METHODS
                    or c.bounded or not c.held):
                continue
            # a condition's wait releases the condition's own lock
            blocked_holding = set(c.held) - {c.owner}
            if not blocked_holding:
                continue
            sites = produced_under.get(c.owner, [])
            if not sites:
                continue
            # deadlock needs EVERY producer path to require the held lock;
            # one lock-free producer can still complete the handoff
            stuck = blocked_holding & frozenset.intersection(*sites)
            if not stuck:
                continue
            findings.append(Finding(
                check="locks.handoff-deadlock",
                where=f"{info.module}:{info.name}.{c.method}:{c.owner}",
                message=(f"{info.name}.{c.method}() blocks on "
                         f"{c.owner}.{c.target}() holding "
                         f"{'/'.join(sorted(stuck))}, but the producer of "
                         f"{c.owner} needs that lock to publish — the "
                         f"handoff can never complete")))
    return findings


# --------------------------------------------------------------------------
# static lock graph (exported for the runtime cross-check)
# --------------------------------------------------------------------------

def static_lock_graph(infos: Sequence | None = None) -> dict:
    if infos is None:
        infos = []
        for module, path in _conc.source_targets():
            infos += _conc.analyze_classes(Path(path).read_text(), module)
    edges = _conc.acquisition_edges(infos)
    nodes = {f"{i.name}.{l}" for i in infos for l in i.locks}
    nodes |= set(edges) | {b for bs in edges.values() for b in bs}
    return {
        "schema": LOCKGRAPH_SCHEMA,
        "nodes": sorted(nodes),
        "edges": sorted([a, b] for a, bs in edges.items() for b in bs),
        "handoffs": sorted(f.key for f in handoff_findings(infos)),
    }


def _closure(edges: dict[str, set]) -> dict[str, set]:
    out = {a: set(bs) for a, bs in edges.items()}
    changed = True
    while changed:
        changed = False
        for a in list(out):
            for b in list(out[a]):
                for c in out.get(b, ()):
                    if c not in out[a] and c != a:
                        out[a].add(c)
                        changed = True
    return out


def crosscheck(observed: dict, static: dict) -> list[Finding]:
    """Observed (runtime) lock graph must embed in the static one."""
    findings = []
    static_nodes = set(static.get("nodes", ()))
    sedges: dict[str, set] = {}
    for a, b in static.get("edges", ()):
        sedges.setdefault(a, set()).add(b)
    closed = _closure(sedges)
    for node in sorted(set(observed.get("nodes", ())) - static_nodes):
        findings.append(Finding(
            check="locks.unknown-lock", where=node, severity="warn",
            message=(f"runtime observed lock {node} that the static pass "
                     f"never discovered — naming drift or a lock created "
                     f"outside the analysed tree")))
    for a, b in observed.get("edges", ()):
        if a not in static_nodes or b not in static_nodes:
            continue                      # already reported as unknown
        if b not in closed.get(a, set()):
            findings.append(Finding(
                check="locks.graph-divergence", where=f"{a}->{b}",
                message=(f"runtime acquired {b} while holding {a}, an "
                         f"order the static acquisition graph does not "
                         f"contain — the deadlock lint is blind to this "
                         f"path")))
    return findings


# --------------------------------------------------------------------------
# runtime half: recording lock wrappers
# --------------------------------------------------------------------------

_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock
_REAL_CONDITION = threading.Condition

_SELF_ATTR_RE = re.compile(r"self\.(\w+)")


def _repro_scope() -> tuple[str, str]:
    import repro
    root = str(Path(next(iter(repro.__path__))))
    return root, str(Path(root) / "analysis")


class LockMonitor:
    """Thread-safe recorder of per-thread held stacks and the directed
    held->acquired edge set."""

    def __init__(self):
        self._tl = threading.local()
        self._mu = _REAL_LOCK()
        self.nodes: set[str] = set()
        self.edges: set[tuple[str, str]] = set()

    def _stack(self) -> list:
        st = getattr(self._tl, "stack", None)
        if st is None:
            st = self._tl.stack = []
        return st

    def on_acquire(self, label: str) -> None:
        st = self._stack()
        with self._mu:
            self.nodes.add(label)
            for held in st:
                if held != label:
                    self.edges.add((held, label))
        st.append(label)

    def on_release(self, label: str) -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i] == label:
                del st[i]
                break

    def to_doc(self) -> dict:
        with self._mu:
            return {"schema": LOCKGRAPH_SCHEMA,
                    "nodes": sorted(self.nodes),
                    "edges": sorted([a, b] for a, b in self.edges)}

    def write(self, path) -> None:
        Path(path).write_text(json.dumps(self.to_doc(), indent=1) + "\n")


def _derive_label(skip: int = 2) -> str | None:
    """Walk the acquiring stack to the first frame inside the monitored
    tree and name the lock ``ClassName.field`` from its source line."""
    root, analysis = _repro_scope()
    f = sys._getframe(skip)
    for _ in range(12):
        if f is None:
            return None
        fname = f.f_code.co_filename
        if fname.startswith(root) and not fname.startswith(analysis):
            m = _SELF_ATTR_RE.search(
                linecache.getline(fname, f.f_lineno))
            obj = f.f_locals.get("self")
            if m and obj is not None:
                return f"{type(obj).__name__}.{m.group(1)}"
            return None
        f = f.f_back
    return None


class _TrackedLock:
    """Recording proxy over a real Lock/RLock. The label is derived at
    first acquisition from the acquiring frame; unlabelled acquisitions
    (locks only ever touched outside the monitored tree) record nothing.
    """

    def __init__(self, inner, mon: LockMonitor):
        self._inner = inner
        self._mon = mon
        self._label: str | None = None
        self._named = False

    def _name(self) -> str | None:
        if not self._named:
            label = _derive_label(skip=3)
            if label is not None:
                self._label, self._named = label, True
        return self._label

    def acquire(self, *args, **kwargs):
        got = self._inner.acquire(*args, **kwargs)
        if got:
            label = self._name()
            if label is not None:
                self._mon.on_acquire(label)
        return got

    def release(self):
        if self._label is not None:
            self._mon.on_release(self._label)
        self._inner.release()

    def locked(self):
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


class _TrackedCondition:
    """Recording proxy over a real Condition. ``wait``/``wait_for``
    release the underlying lock for their whole park, so the held stack
    drops the label across the call and restores it on wake."""

    def __init__(self, inner, mon: LockMonitor):
        self._inner = inner
        self._mon = mon
        self._label: str | None = None
        self._named = False

    def _name(self) -> str | None:
        if not self._named:
            label = _derive_label(skip=3)
            if label is not None:
                self._label, self._named = label, True
        return self._label

    def __enter__(self):
        self._inner.__enter__()
        label = self._name()
        if label is not None:
            self._mon.on_acquire(label)
        return self

    def __exit__(self, *exc):
        if self._label is not None:
            self._mon.on_release(self._label)
        return self._inner.__exit__(*exc)

    def _parked(self):
        mon, label = self._mon, self._label

        class _Park:
            def __enter__(self):
                if label is not None:
                    mon.on_release(label)

            def __exit__(self, *exc):
                if label is not None:
                    mon.on_acquire(label)
                return False
        return _Park()

    def wait(self, timeout=None):
        with self._parked():
            return self._inner.wait(timeout)

    def wait_for(self, predicate, timeout=None):
        with self._parked():
            return self._inner.wait_for(predicate, timeout)

    def notify(self, n=1):
        self._inner.notify(n)

    def notify_all(self):
        self._inner.notify_all()

    def acquire(self, *a, **k):
        got = self._inner.acquire(*a, **k)
        if got:
            label = self._name()
            if label is not None:
                self._mon.on_acquire(label)
        return got

    def release(self):
        if self._label is not None:
            self._mon.on_release(self._label)
        self._inner.release()


def instrument(mon: LockMonitor):
    """Monkeypatch ``threading.Lock/RLock/Condition`` so locks *created*
    by code under ``repro`` (excluding this analysis package) record into
    ``mon``. Returns the original constructors for :func:`uninstrument`.
    Creations from the stdlib (``queue.Queue``'s internal mutex, ...) and
    from user code outside the tree get real primitives, untouched."""
    root, analysis = _repro_scope()

    def _in_scope() -> bool:
        fname = sys._getframe(2).f_code.co_filename
        return fname.startswith(root) and not fname.startswith(analysis)

    def _lock_factory(real, cls):
        def factory(*args, **kwargs):
            inner = real(*args, **kwargs)
            return cls(inner, mon) if _in_scope() else inner
        return factory

    def _condition_factory(lock=None):
        if isinstance(lock, _TrackedLock):
            lock = lock._inner
        inner = _REAL_CONDITION(lock)
        return _TrackedCondition(inner, mon) if _in_scope() else inner

    originals = (threading.Lock, threading.RLock, threading.Condition)
    threading.Lock = _lock_factory(_REAL_LOCK, _TrackedLock)
    threading.RLock = _lock_factory(_REAL_RLOCK, _TrackedLock)
    threading.Condition = _condition_factory
    return originals


def uninstrument(originals) -> None:
    threading.Lock, threading.RLock, threading.Condition = originals


# --------------------------------------------------------------------------
# analyzer entry point
# --------------------------------------------------------------------------

def run(lock_graph_path: str | None = None) -> list[Finding]:
    """Static handoff findings over the whole tree; with an observed
    runtime graph, also cross-check it against the static one."""
    infos = []
    for module, path in _conc.source_targets():
        infos += _conc.analyze_classes(Path(path).read_text(), module)
    findings = handoff_findings(infos)
    if lock_graph_path is not None:
        observed = json.loads(Path(lock_graph_path).read_text())
        if observed.get("schema") != LOCKGRAPH_SCHEMA:
            raise SystemExit(
                f"{lock_graph_path}: expected schema {LOCKGRAPH_SCHEMA}, "
                f"got {observed.get('schema')!r}")
        findings += crosscheck(observed, static_lock_graph(infos))
    return findings
