"""Finding aggregation: suppression baseline + machine-readable report.

The baseline (``analysis_baseline.json`` at the repo root) is the ONLY
sanctioned way to ship code with a finding: every entry carries the
finding's stable key and a human reason, reviewed like code. Keys contain
no line numbers, so unrelated edits never invalidate them; entries whose
key no longer matches any finding are reported as *stale* so the baseline
shrinks back as debt is paid.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Sequence

from repro.analysis import Finding

SCHEMA = "repro.analysis/v1"


@dataclasses.dataclass(frozen=True)
class Report:
    findings: tuple          # unsuppressed Finding objects
    suppressed: tuple        # (Finding, reason) pairs matched by baseline
    stale: tuple             # baseline keys that matched nothing

    @property
    def gating(self) -> tuple:
        """Unsuppressed error-severity findings — what fails the CI gate."""
        return tuple(f for f in self.findings if f.severity == "error")

    def to_json(self) -> dict:
        return {
            "schema": SCHEMA,
            "counts": {
                "findings": len(self.findings),
                "gating": len(self.gating),
                "suppressed": len(self.suppressed),
                "stale_suppressions": len(self.stale),
            },
            "findings": [f.to_json() for f in self.findings],
            "suppressed": [dict(reason=r, **f.to_json())
                           for f, r in self.suppressed],
            "stale_suppressions": list(self.stale),
        }


def load_baseline(path: str | Path | None) -> dict[str, str]:
    """key -> reason; a missing file is an empty baseline."""
    if path is None:
        return {}
    p = Path(path)
    if not p.exists():
        return {}
    doc = json.loads(p.read_text())
    out = {}
    for entry in doc.get("suppressions", ()):
        key, reason = entry["key"], entry.get("reason", "")
        if key in out:
            raise ValueError(f"duplicate baseline key: {key}")
        out[key] = reason
    return out


#: finding-key prefix -> analyzer that can produce it, for stale scoping
PREFIX_ANALYZERS = {"jaxpr.": "jaxpr", "pallas.": "pallas",
                    "conc.": "conc", "cost.": "cost", "inv.": "inv",
                    "locks.": "locks"}


def apply_baseline(findings: Sequence[Finding],
                   baseline: dict[str, str],
                   active_analyzers: Sequence[str] | None = None) -> Report:
    """``active_analyzers`` scopes staleness: with ``--only conc`` a
    ``cost.*`` suppression matches nothing *because its analyzer never
    ran*, which is not evidence of paid-off debt. ``None`` means every
    analyzer ran. Keys with an unrecognised prefix are always active."""
    kept, suppressed, hit = [], [], set()
    for f in findings:
        if f.key in baseline:
            suppressed.append((f, baseline[f.key]))
            hit.add(f.key)
        else:
            kept.append(f)

    def _active(key: str) -> bool:
        if active_analyzers is None:
            return True
        for prefix, analyzer in PREFIX_ANALYZERS.items():
            if key.startswith(prefix):
                return analyzer in active_analyzers
        return True
    stale = tuple(sorted(k for k in set(baseline) - hit if _active(k)))
    return Report(findings=tuple(kept), suppressed=tuple(suppressed),
                  stale=stale)


def write_report(report: Report, path: str | Path) -> None:
    Path(path).write_text(json.dumps(report.to_json(), indent=2,
                                     sort_keys=True) + "\n")


def format_text(report: Report) -> str:
    lines = []
    for f in report.findings:
        lines.append(f"[{f.severity}] {f.key}\n    {f.message}")
    for f, reason in report.suppressed:
        lines.append(f"[suppressed] {f.key}\n    baseline: {reason}")
    for key in report.stale:
        lines.append(f"[stale-suppression] {key}\n    baseline entry no "
                     f"longer matches any finding — remove it")
    c = report.to_json()["counts"]
    lines.append(f"{c['findings']} finding(s) ({c['gating']} gating), "
                 f"{c['suppressed']} suppressed, "
                 f"{c['stale_suppressions']} stale suppression(s)")
    return "\n".join(lines)
