"""Static analysis for the serving hot path — the repo's efficiency gate.

The paper's value proposition is *offline, query-independent* efficiency.
This repo banks that as three families of invariant that nothing in the
type system enforces, so each gets a dedicated static analyzer:

  * ``jaxpr_lints``   — trace every serving entry point and assert the
    fused-dispatch contract: one compiled computation per dispatch, the
    index operand streams in its storage dtype (no ``convert_element_type``
    shadow-upcasting an int8/bf16 corpus), no host callbacks inside the
    traced hot path, and jit-cache stability across a sweep of segment
    live-counts/offsets (recompile detection without running traffic).
  * ``pallas_budget`` — a VMEM/grid checker for ``topk_score_pallas`` and
    ``pca_project``: resident bytes per (block_b, block_n, k, fold, dtype)
    config from the kernels' own shared geometry, grid divisibility and
    index-map bounds from the *traced* ``pallas_call``, rejected against a
    configurable per-core budget.
  * ``concurrency``   — an AST pass over the whole ``repro`` tree that
    builds the guarded-field map per class, flags fields accessed both
    under and outside their lock, detects lock-acquisition-order cycles,
    and flags blocking device calls while a lock is held.
  * ``cost_model``    — a jaxpr cost walk of every serving entry point:
    per-query FLOPs, HBM bytes (storage-dtype aware), and arithmetic
    intensity, gated against the checked-in ``analysis_costs.json``
    baseline with per-metric tolerances and cross-checked against the
    measured qps ordering in ``BENCH_perf.json``.
  * ``invariants``    — an abstract interpreter over the traced serving
    jaxprs proving the value contracts the kernels rely on: shortlist
    ids sorted into the block-skip guard, ``-1`` padding masked to
    ``-inf`` before final top-k, dedup keeping the lowest id on score
    ties, and disjoint global-id intervals across segment dispatches.
  * ``lock_sanitizer`` — happens-before handoff analysis (consumer
    blocking on a channel while holding the producer's lock) plus a
    runtime lock-order recorder whose observed graph CI cross-checks
    against the static acquisition graph.

``python -m repro.analysis`` runs all six against the live repo code,
emits a machine-readable JSON report, subtracts the checked-in suppression
baseline (``analysis_baseline.json``), and exits nonzero on any
unsuppressed finding — the CI gate for the 2-6x wins in BENCH_perf.json.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer hit.

    ``check`` is the lint id (``"jaxpr.extra-dispatch"``, …); ``where`` is
    a *stable* location key (module:Class.method:field — never a line
    number, so the suppression baseline survives unrelated edits);
    ``severity`` is ``"error"`` (gates CI) or ``"warn"`` (reported only).
    """

    check: str
    where: str
    message: str
    severity: str = "error"

    @property
    def key(self) -> str:
        return f"{self.check}:{self.where}"

    def to_json(self) -> dict:
        return dict(check=self.check, where=self.where,
                    message=self.message, severity=self.severity)


__all__ = ["Finding"]
