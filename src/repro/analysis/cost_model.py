"""Static per-query cost model over the serving entry points.

The paper's trade is *static*: prune dimensions once, serve cheaper
forever — so the quantity worth gating is exactly the one the pruning
changes, bytes and FLOPs per query, and it can be priced without running a
single query. Every serving entry point from
``jaxpr_lints.serving_entry_points()`` is traced (``jax.make_jaxpr``, no
device execution) and its jaxpr is walked into a roofline-style cost:

  * **FLOPs** — ``dot_general`` priced from its dimension numbers
    (2·batch·M·N·K), reductions/argmax by operand size, ``sort`` as
    n·log n, ``top_k`` as n·log k, element-wise by output size; ``scan``
    bodies multiply by trip count, ``pallas_call`` kernels by grid size,
    ``cond`` takes the max branch, ``shard_map`` multiplies by mesh size.
  * **HBM bytes** — each top-level compute dispatch reads its operands and
    writes its outputs at their *storage* width (an int8 index prices at
    1 byte/elem — the whole point), plus materialisation traffic: any
    copy-like eqn (``convert_element_type``/``gather``/``sort``/…) whose
    output is strictly larger than one dequant strip prices a full
    write+read round trip. A f32 shadow copy of an int8 corpus therefore
    shows up as ~8x the bytes even though the jaxpr still "works".
  * **arithmetic intensity** — FLOPs / HBM bytes, the roofline position.

Costs are gated against the checked-in ``analysis_costs.json``: dispatch
counts exactly, FLOPs/bytes within per-metric tolerances (regression =
error, improvement beyond tolerance = warn: re-baseline), intensity drift
warns. Entries traced under a different device topology than they were
baselined with (the sharded family embeds the mesh) are skipped rather
than mis-gated. Finally the model is cross-checked against reality: where
two entries model the same ``BENCH_perf.json`` serve config family, the
predicted bytes/query ordering must agree with the measured worker-qps
ordering (memory-bound ⇒ fewer bytes = more qps), else
``cost.bench-mismatch`` warns.

Re-baseline after an intentional perf change with
``python -m repro.analysis --write-cost-baseline``.
"""
from __future__ import annotations

import json
import math
import pathlib

import jax
import numpy as np

from repro.analysis import Finding
from repro.analysis.jaxpr_lints import _DISPATCH_PRIMS, _contains_compute, _eqn_subjaxprs

COSTS_SCHEMA = "repro.analysis/costs-v1"

# gated metrics: exact for dispatches, relative tolerance otherwise.
# Tolerances absorb cross-JAX-version jaxpr drift (fused vs split
# elementwise chains), NOT real regressions: a shadow copy or an extra
# dispatch moves bytes by integer factors.
METRIC_TOL = {
    "flops_per_query": 0.10,
    "hbm_read_bytes_per_query": 0.10,
    "hbm_write_bytes_per_query": 0.10,
}
INTENSITY_TOL = 0.15
METRIC_KEYS = ("dispatches", "flops_per_query", "hbm_read_bytes_per_query",
               "hbm_write_bytes_per_query", "arithmetic_intensity")

# copy-like primitives whose oversized outputs price a materialisation
# round trip (write + read back) — the shadow-copy detectors
_MATERIALIZE_PRIMS = frozenset({
    "convert_element_type", "gather", "sort", "concatenate", "pad",
    "scatter", "dynamic_update_slice", "copy",
})
# shape plumbing that moves no bytes and does no arithmetic
_FREE_PRIMS = frozenset({
    "reshape", "broadcast_in_dim", "squeeze", "transpose", "slice",
    "dynamic_slice", "iota", "stop_gradient", "convert_element_type",
    "gather", "concatenate", "pad", "scatter", "dynamic_update_slice",
    "copy", "split",
})
_REDUCE_PRIMS = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "argmax",
    "argmin", "reduce_and", "reduce_or", "reduce_precision",
})


def _elems(aval) -> int:
    return int(np.prod(aval.shape)) if aval.shape else 1


def _nbytes(aval) -> int:
    return _elems(aval) * np.dtype(aval.dtype).itemsize


def _dot_general_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs = eqn.invars[0].aval.shape
    rhs = eqn.invars[1].aval.shape
    batch = math.prod(lhs[i] for i in lb)
    contract = math.prod(lhs[i] for i in lc)
    lfree = math.prod(d for i, d in enumerate(lhs)
                      if i not in tuple(lc) + tuple(lb))
    rfree = math.prod(d for i, d in enumerate(rhs)
                      if i not in tuple(rc) + tuple(rb))
    return 2.0 * batch * contract * lfree * rfree


def _prim_flops(eqn) -> float:
    name = eqn.primitive.name
    if name == "dot_general":
        return _dot_general_flops(eqn)
    if name in _REDUCE_PRIMS:
        return float(max((_elems(v.aval) for v in eqn.invars), default=0))
    if name == "sort":
        aval = eqn.invars[0].aval
        axis = aval.shape[eqn.params.get("dimension", -1)] \
            if aval.shape else 1
        return float(_elems(aval)) * max(1, math.ceil(math.log2(max(2,
                                                                    axis))))
    if name == "top_k":
        aval = eqn.invars[0].aval
        k = eqn.params.get("k", 1)
        return float(_elems(aval)) * max(1, math.ceil(math.log2(k + 1)))
    if name in _FREE_PRIMS:
        return 0.0
    # default: element-wise over the (largest) output
    return float(max((_elems(v.aval) for v in eqn.outvars), default=0))


def _grid_prod(eqn) -> int:
    gm = eqn.params.get("grid_mapping")
    grid = getattr(gm, "grid", None) or eqn.params.get("grid") or ()
    try:
        return int(math.prod(int(g) for g in grid)) or 1
    except (TypeError, ValueError):
        return 1


def _walk_cost(jaxpr, threshold_elems: int) -> tuple[float, float]:
    """(flops, materialisation bytes) of one jaxpr, multipliers applied."""
    flops = 0.0
    mat = 0.0
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            mult = int(eqn.params.get("length", 1))
            for j in _eqn_subjaxprs(eqn):
                f, b = _walk_cost(j, threshold_elems)
                flops += mult * f
                mat += mult * b
            continue
        if name == "cond":
            best = (0.0, 0.0)
            for j in _eqn_subjaxprs(eqn):
                c = _walk_cost(j, threshold_elems)
                if c[0] + c[1] > best[0] + best[1]:
                    best = c
            flops += best[0]
            mat += best[1]
            continue
        if name == "pallas_call":
            mult = _grid_prod(eqn)
            for j in _eqn_subjaxprs(eqn):
                f, b = _walk_cost(j, threshold_elems)
                flops += mult * f
                mat += mult * b
            continue
        if name == "shard_map":
            mesh = eqn.params.get("mesh")
            mult = int(getattr(mesh, "size", 1) or 1)
            for j in _eqn_subjaxprs(eqn):
                f, b = _walk_cost(j, threshold_elems)
                flops += mult * f
                mat += mult * b
            continue
        subs = list(_eqn_subjaxprs(eqn))
        if subs:                                 # pjit, custom_*_call, …
            for j in subs:
                f, b = _walk_cost(j, threshold_elems)
                flops += f
                mat += b
            continue
        flops += _prim_flops(eqn)
        if name in _MATERIALIZE_PRIMS:
            out = max((_elems(v.aval) for v in eqn.outvars), default=0)
            if out > threshold_elems:            # strictly larger than a
                big = max(eqn.outvars, key=lambda v: _elems(v.aval))
                mat += 2.0 * _nbytes(big.aval)   # strip: write + read back
    return flops, mat


def measure_entry(ep) -> dict:
    """Price one ``EntryPoint``: trace and walk its jaxpr."""
    jaxpr = jax.make_jaxpr(ep.fn)(*ep.args).jaxpr
    n, m = ep.corpus_shape
    strip = ep.strip_rows if ep.strip_rows else n
    threshold = min(strip, n) * m
    reads = writes = 0.0
    flops = mat = 0.0
    dispatches = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in _DISPATCH_PRIMS and _contains_compute(eqn):
            dispatches += 1
            reads += sum(_nbytes(v.aval) for v in eqn.invars
                         if hasattr(v, "aval"))
            writes += sum(_nbytes(v.aval) for v in eqn.outvars)
            f, b = _walk_cost_eqn(eqn, threshold)
            flops += f
            mat += b
    B = max(1, ep.batch)
    read_q = (reads + mat) / B
    write_q = (writes + mat) / B
    total = read_q + write_q
    return {
        "device_count": (jax.device_count()
                         if ep.family == "sharded" else None),
        "dispatches": dispatches,
        "flops_per_query": flops / B,
        "hbm_read_bytes_per_query": read_q,
        "hbm_write_bytes_per_query": write_q,
        "arithmetic_intensity": (flops / B) / total if total else 0.0,
        "family": ep.family,
        "bench_key": ep.bench_key,
    }


def _walk_cost_eqn(eqn, threshold_elems):
    flops = mat = 0.0
    if eqn.primitive.name == "pallas_call":
        mult = _grid_prod(eqn)
    else:
        mult = 1
    for j in _eqn_subjaxprs(eqn):
        f, b = _walk_cost(j, threshold_elems)
        flops += mult * f
        mat += mult * b
    return flops, mat


def measure_all(entries=None) -> dict[str, dict]:
    if entries is None:
        from repro.analysis.jaxpr_lints import serving_entry_points
        entries = serving_entry_points()
    return {ep.label: measure_entry(ep) for ep in entries}


# ---------------------------------------------------------------------------
# baseline file
# ---------------------------------------------------------------------------


def check_costs_schema(doc: dict) -> None:
    """Validate ``analysis_costs.json`` before it gates anything (or is
    written) — benchmarks/run.py style: SystemExit naming what's missing."""
    if not isinstance(doc, dict) or doc.get("schema") != COSTS_SCHEMA:
        raise SystemExit(f"analysis_costs.json schema: expected "
                         f"'{COSTS_SCHEMA}', got "
                         f"{doc.get('schema') if isinstance(doc, dict) else type(doc).__name__!r}")
    entries = doc.get("entries")
    if not isinstance(entries, dict) or not entries:
        raise SystemExit("analysis_costs.json schema: missing or empty "
                         "'entries' section")
    for label, row in entries.items():
        if not isinstance(row, dict):
            raise SystemExit(f"analysis_costs.json: entry '{label}' is not "
                             f"an object")
        missing = [k for k in METRIC_KEYS if k not in row]
        if missing:
            raise SystemExit(f"analysis_costs.json: entry '{label}' missing "
                             f"keys {missing}")
        if "device_count" not in row:
            raise SystemExit(f"analysis_costs.json: entry '{label}' missing "
                             f"'device_count' (null = device-independent)")
        for key in ("family", "bench_key"):
            if key not in row:
                raise SystemExit(f"analysis_costs.json: entry '{label}' "
                                 f"missing '{key}'")
        bad = [k for k in METRIC_KEYS
               if not isinstance(row[k], (int, float))]
        if bad:
            raise SystemExit(f"analysis_costs.json: entry '{label}' has "
                             f"non-numeric metrics {bad}")


def write_baseline(path, measured: dict[str, dict]) -> None:
    doc = {
        "schema": COSTS_SCHEMA,
        "_comment": ("Per-query static cost baseline over the serving "
                     "entry points (see repro/analysis/cost_model.py). "
                     "Regenerate after an INTENTIONAL perf change with: "
                     "python -m repro.analysis --write-cost-baseline"),
        "entries": {
            label: {k: v for k, v in row.items()
                    if not k.startswith("_")}
            for label, row in sorted(measured.items())
        },
    }
    check_costs_schema(doc)
    pathlib.Path(path).write_text(json.dumps(doc, indent=2, sort_keys=True)
                                  + "\n")


def compare_costs(measured: dict[str, dict], baseline_doc: dict | None,
                  costs_path="analysis_costs.json") -> list[Finding]:
    findings: list[Finding] = []
    if not baseline_doc:
        return [Finding(
            check="cost.no-baseline", where=str(costs_path),
            message=(f"no cost baseline at {costs_path} — run "
                     f"'python -m repro.analysis --write-cost-baseline' "
                     f"and commit the file"))]
    check_costs_schema(baseline_doc)
    base = baseline_doc["entries"]
    dc = jax.device_count()
    for label in sorted(set(base) - set(measured)):
        findings.append(Finding(
            check="cost.stale-entry", where=label,
            message=(f"cost baseline entry '{label}' matches no traced "
                     f"entry point — it was removed or renamed; "
                     f"re-baseline to drop it")))
    for label, row in sorted(measured.items()):
        if label not in base:
            findings.append(Finding(
                check="cost.unbaselined", where=label,
                message=(f"{label}: no cost baseline entry — a new serving "
                         f"entry point must be priced and committed "
                         f"(--write-cost-baseline)")))
            continue
        want = base[label]
        if want.get("device_count") is not None \
                and want["device_count"] != dc:
            continue        # sharded entries embed the mesh; wrong topology
        if row["dispatches"] != want["dispatches"]:
            findings.append(Finding(
                check="cost.regression", where=f"{label}:dispatches",
                message=(f"{label}: {row['dispatches']} compute dispatches "
                         f"vs baseline {want['dispatches']} — dispatch "
                         f"count is gated exactly")))
        for metric, tol in METRIC_TOL.items():
            got, ref = float(row[metric]), float(want[metric])
            if ref <= 0:
                continue
            rel = (got - ref) / ref
            if rel > tol:
                findings.append(Finding(
                    check="cost.regression", where=f"{label}:{metric}",
                    message=(f"{label}: {metric} {got:,.0f} is "
                             f"{rel * 100:.1f}% above baseline {ref:,.0f} "
                             f"(tolerance {tol * 100:.0f}%) — the static "
                             f"pruning win is being spent")))
            elif rel < -tol:
                findings.append(Finding(
                    check="cost.improved", where=f"{label}:{metric}",
                    message=(f"{label}: {metric} {got:,.0f} is "
                             f"{-rel * 100:.1f}% below baseline {ref:,.0f} "
                             f"— nice; re-baseline to lock it in"),
                    severity="warn"))
        got_i, ref_i = (float(row["arithmetic_intensity"]),
                        float(want["arithmetic_intensity"]))
        if ref_i > 0 and abs(got_i - ref_i) / ref_i > INTENSITY_TOL:
            findings.append(Finding(
                check="cost.intensity-drift",
                where=f"{label}:arithmetic_intensity",
                message=(f"{label}: arithmetic intensity {got_i:.2f} "
                         f"drifted >{INTENSITY_TOL * 100:.0f}% from "
                         f"baseline {ref_i:.2f} — roofline position "
                         f"moved; check flops/bytes deltas"),
                severity="warn"))
    return findings


# ---------------------------------------------------------------------------
# bench cross-check
# ---------------------------------------------------------------------------


def bench_crosscheck(entries: dict[str, dict],
                     bench_doc: dict | None) -> list[Finding]:
    """Predicted bytes/query ordering vs measured worker-qps ordering.

    Within one serve_pipeline config family (dense, sharded) the serving
    path is memory-bound, so the entry the model says moves FEWER bytes
    per query must be the one the bench measured as FASTER. Disagreement
    warns: either the model mis-prices something, or (as with the
    interpreted-CPU int8 dequant overhead) the bench environment is not
    bandwidth-dominated — either way a human should look.

    ``entries`` should be the CHECKED-IN cost baseline (artifact vs
    artifact — deterministic regardless of the device count this process
    happens to see); measured rows work too and have the same shape.
    """
    if not bench_doc:
        return []
    configs = (bench_doc.get("serve_pipeline") or {}).get("configs") or {}

    def qps(key):
        row = configs.get(key) or {}
        return ((row.get("pipelined") or {}).get("worker_qps"))

    by_key = {row["bench_key"]: (label, row)
              for label, row in entries.items() if row.get("bench_key")}
    findings: list[Finding] = []
    fams: dict[str, list[str]] = {}
    for key, (_label, row) in by_key.items():
        fams.setdefault(row["family"], []).append(key)
    for _fam, keys in sorted(fams.items()):
        keys = sorted(keys)
        for i, a in enumerate(keys):
            for b in keys[i + 1:]:
                qa, qb = qps(a), qps(b)
                if qa is None or qb is None or qa == qb:
                    continue
                la, ra = by_key[a]
                lb, rb = by_key[b]
                bytes_a = (ra["hbm_read_bytes_per_query"]
                           + ra["hbm_write_bytes_per_query"])
                bytes_b = (rb["hbm_read_bytes_per_query"]
                           + rb["hbm_write_bytes_per_query"])
                if bytes_a == bytes_b:
                    continue
                model_faster = a if bytes_a < bytes_b else b
                bench_faster = a if qa > qb else b
                if model_faster != bench_faster:
                    findings.append(Finding(
                        check="cost.bench-mismatch", where=f"{a}-vs-{b}",
                        message=(f"cost model predicts {model_faster} "
                                 f"faster ({min(bytes_a, bytes_b):,.0f} vs "
                                 f"{max(bytes_a, bytes_b):,.0f} bytes/q) "
                                 f"but BENCH_perf.json measured "
                                 f"{bench_faster} faster ({qa:.1f} vs "
                                 f"{qb:.1f} qps) — model or bench "
                                 f"environment is off the roofline"),
                        severity="warn"))
    return findings


# ---------------------------------------------------------------------------
# CLI entry
# ---------------------------------------------------------------------------


def run(costs_path="analysis_costs.json",
        bench_path="BENCH_perf.json") -> list[Finding]:
    measured = measure_all()
    baseline_doc = None
    p = pathlib.Path(costs_path)
    if p.exists():
        baseline_doc = json.loads(p.read_text())
    findings = compare_costs(measured, baseline_doc, costs_path=costs_path)
    bench_doc = None
    bp = pathlib.Path(bench_path)
    if bp.exists():
        bench_doc = json.loads(bp.read_text())
    findings += bench_crosscheck(
        baseline_doc["entries"] if baseline_doc else measured, bench_doc)
    return findings
