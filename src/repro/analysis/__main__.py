"""CLI gate: ``python -m repro.analysis [--fail-on-findings]``.

Runs the analyzers against the live repo code, subtracts the checked-in
suppression baseline, writes the machine-readable report, and (with
``--fail-on-findings``) exits 1 on any unsuppressed error-severity
finding or stale suppression. This is the CI entry point.
"""
from __future__ import annotations

import argparse
import sys

ANALYZERS = ("jaxpr", "pallas", "conc", "cost", "inv", "locks")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--only", default=",".join(ANALYZERS),
                    help="comma list of analyzers to run "
                         f"(default: {','.join(ANALYZERS)})")
    ap.add_argument("--baseline", default="analysis_baseline.json",
                    help="suppression baseline (missing file = empty)")
    ap.add_argument("--json", default="ANALYSIS_report.json",
                    help="report output path ('' disables)")
    ap.add_argument("--vmem-budget", type=int, default=None, metavar="BYTES",
                    help="override the Pallas per-core VMEM budget")
    ap.add_argument("--costs", default="analysis_costs.json",
                    help="checked-in cost baseline for the cost analyzer")
    ap.add_argument("--bench", default="BENCH_perf.json",
                    help="benchmark results for the cost cross-check "
                         "(missing file = cross-check skipped)")
    ap.add_argument("--write-cost-baseline", action="store_true",
                    help="re-measure every entry point and rewrite --costs "
                         "instead of gating against it")
    ap.add_argument("--lock-graph", default=None, metavar="PATH",
                    help="observed runtime lock graph (LOCK_graph.json) to "
                         "cross-check against the static acquisition graph")
    ap.add_argument("--lock-graph-out", default=None, metavar="PATH",
                    help="write the STATIC lock graph to PATH (artifact)")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 on unsuppressed error findings or stale "
                         "suppressions")
    args = ap.parse_args(argv)

    chosen = [s.strip() for s in args.only.split(",") if s.strip()]
    unknown = set(chosen) - set(ANALYZERS)
    if unknown:
        ap.error(f"unknown analyzer(s): {sorted(unknown)}")

    if args.write_cost_baseline:
        from repro.analysis import cost_model
        cost_model.write_baseline(args.costs, cost_model.measure_all())
        print(f"[analysis] cost baseline -> {args.costs}")
        return 0

    findings = []
    if "jaxpr" in chosen:
        from repro.analysis import jaxpr_lints
        findings += jaxpr_lints.run()
    if "pallas" in chosen:
        from repro.analysis import pallas_budget
        budget = (args.vmem_budget if args.vmem_budget is not None
                  else pallas_budget.DEFAULT_BUDGET)
        findings += pallas_budget.run(budget=budget)
    if "conc" in chosen:
        from repro.analysis import concurrency
        findings += concurrency.run()
    if "cost" in chosen:
        from repro.analysis import cost_model
        findings += cost_model.run(costs_path=args.costs,
                                   bench_path=args.bench)
    if "inv" in chosen:
        from repro.analysis import invariants
        findings += invariants.run()
    if "locks" in chosen:
        from repro.analysis import lock_sanitizer
        findings += lock_sanitizer.run(lock_graph_path=args.lock_graph)
        if args.lock_graph_out:
            import json
            from pathlib import Path
            Path(args.lock_graph_out).write_text(
                json.dumps(lock_sanitizer.static_lock_graph(),
                           indent=1, sort_keys=True) + "\n")
            print(f"[analysis] static lock graph -> {args.lock_graph_out}")

    from repro.analysis.report import (apply_baseline, format_text,
                                       load_baseline, write_report)
    report = apply_baseline(findings, load_baseline(args.baseline),
                            active_analyzers=chosen)
    if args.json:
        write_report(report, args.json)
        print(f"[analysis] report -> {args.json}")
    print(format_text(report))

    if args.fail_on_findings and (report.gating or report.stale):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
