"""CLI gate: ``python -m repro.analysis [--fail-on-findings]``.

Runs the three analyzers against the live repo code, subtracts the
checked-in suppression baseline, writes the machine-readable report, and
(with ``--fail-on-findings``) exits 1 on any unsuppressed error-severity
finding or stale suppression. This is the CI entry point.
"""
from __future__ import annotations

import argparse
import sys

ANALYZERS = ("jaxpr", "pallas", "conc")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--only", default=",".join(ANALYZERS),
                    help="comma list of analyzers to run "
                         f"(default: {','.join(ANALYZERS)})")
    ap.add_argument("--baseline", default="analysis_baseline.json",
                    help="suppression baseline (missing file = empty)")
    ap.add_argument("--json", default="ANALYSIS_report.json",
                    help="report output path ('' disables)")
    ap.add_argument("--vmem-budget", type=int, default=None, metavar="BYTES",
                    help="override the Pallas per-core VMEM budget")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 on unsuppressed error findings or stale "
                         "suppressions")
    args = ap.parse_args(argv)

    chosen = [s.strip() for s in args.only.split(",") if s.strip()]
    unknown = set(chosen) - set(ANALYZERS)
    if unknown:
        ap.error(f"unknown analyzer(s): {sorted(unknown)}")

    findings = []
    if "jaxpr" in chosen:
        from repro.analysis import jaxpr_lints
        findings += jaxpr_lints.run()
    if "pallas" in chosen:
        from repro.analysis import pallas_budget
        budget = (args.vmem_budget if args.vmem_budget is not None
                  else pallas_budget.DEFAULT_BUDGET)
        findings += pallas_budget.run(budget=budget)
    if "conc" in chosen:
        from repro.analysis import concurrency
        findings += concurrency.run()

    from repro.analysis.report import (apply_baseline, format_text,
                                       load_baseline, write_report)
    report = apply_baseline(findings, load_baseline(args.baseline))
    if args.json:
        write_report(report, args.json)
        print(f"[analysis] report -> {args.json}")
    print(format_text(report))

    if args.fail_on_findings and (report.gating or report.stale):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
