"""Jaxpr invariant lints over the serving entry points.

Each serving dispatch path is traced (``jax.make_jaxpr`` — no device
execution, no traffic) on a tiny synthetic corpus shaped to exercise the
invariant, and the resulting jaxpr is walked statically:

  * **fused-dispatch** — the top level of a traced entry point must contain
    exactly the expected number of *compute dispatches* (pjit eqns whose
    inner jaxpr does real work: dot_general / scan / top_k / pallas_call /
    collectives). ``DenseIndex.search_projected`` and
    ``ShardedDenseIndex.search_projected`` must be ONE; a
    ``SegmentedIndex`` is one projection + one per segment + one merge by
    design. A stray extra dispatch (a projection that escaped the jit, a
    device round-trip) is the regression this lint exists to catch.
  * **storage-dtype streaming** — with an int8/bf16 index, no
    ``convert_element_type`` may upcast an operand larger than one scan
    strip (the in-register dequant unit), and the array handed to
    ``pallas_call`` must keep the storage dtype: the whole bandwidth win
    is streaming n·m·1 bytes, not a 4x fp32 shadow copy.
  * **no host callbacks** — ``pure_callback``/``io_callback``/debug
    prints/infeed inside the traced hot path serialise the device behind
    the host; none may appear anywhere in the trace.
  * **jit-cache stability** — dispatching the segmented search across a
    sweep of delta live-counts and id offsets must not grow any jit cache
    (``segment_jit_cache_sizes``): live-count and offset are traced
    operands by contract, so an append never recompiles.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import Finding

# primitives that mark a pjit eqn as a real compute dispatch (vs a trivial
# jnp wrapper like atleast_2d, which also traces as a named pjit)
_COMPUTE_PRIMS = frozenset({
    "dot_general", "scan", "while", "pallas_call", "top_k", "sort",
    "all_gather", "all_reduce", "psum", "reduce_sum", "reduce_max",
    "argmax", "shard_map",
})
_DISPATCH_PRIMS = frozenset({"pjit", "xla_call", "pallas_call"})
# host round-trips that must never appear inside a traced hot path
_CALLBACK_PRIMS = frozenset({
    "pure_callback", "io_callback", "debug_callback", "debug_print",
    "host_callback", "outside_call", "infeed", "outfeed",
})
_WIDTH = {"int8": 1, "bfloat16": 2, "float16": 2, "float32": 4,
          "float64": 8}


def iter_all_eqns(jaxpr) -> Iterable:
    """Every eqn of ``jaxpr`` and (recursively) of every sub-jaxpr in eqn
    params — scan bodies, cond branches, pjit/pallas inner jaxprs."""
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        yield from j.eqns
        stack.extend(jax.core.subjaxprs(j))


def _contains_compute(eqn) -> bool:
    if eqn.primitive.name == "pallas_call":
        return True
    for j in _eqn_subjaxprs(eqn):
        for sub in _walk(j):
            for e in sub.eqns:
                if e.primitive.name in _COMPUTE_PRIMS:
                    return True
    return False


def _eqn_subjaxprs(eqn):
    for v in eqn.params.values():
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, jax.core.ClosedJaxpr):
                    yield x.jaxpr
                elif isinstance(x, jax.core.Jaxpr):
                    yield x


def _walk(jaxpr):
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        yield j
        stack.extend(jax.core.subjaxprs(j))


def compute_dispatches(fn: Callable, *args) -> list:
    """Top-level compute-dispatch eqns of ``fn`` traced on ``args``."""
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    out = []
    for eqn in jaxpr.eqns:
        if eqn.primitive.name not in _DISPATCH_PRIMS:
            continue
        if _contains_compute(eqn):
            out.append(eqn)
    return out


def dispatch_name(eqn) -> str:
    name = eqn.params.get("name")
    return str(name) if name else eqn.primitive.name


def check_dispatch_count(label: str, fn: Callable, args: Sequence,
                         expected: int) -> list[Finding]:
    got = compute_dispatches(fn, *args)
    if len(got) == expected:
        return []
    names = [dispatch_name(e) for e in got]
    return [Finding(
        check="jaxpr.extra-dispatch", where=label,
        message=(f"{label}: {len(got)} compute dispatches on the hot path "
                 f"({names}), contract says exactly {expected} — a "
                 f"projection or merge escaped the fused jit"))]


def check_storage_dtype_stream(label: str, fn: Callable, args: Sequence,
                               corpus_shape: tuple[int, int],
                               storage_dtype: str,
                               strip_rows: int) -> list[Finding]:
    """No upcast larger than ONE scan strip anywhere in the trace; pallas
    operands keep the storage dtype.

    Per-strip upcasts (the in-register dequant, ``strip_rows`` × m) are the
    design; anything strictly larger is a shadow copy of multiple strips —
    in the limit the whole corpus — and defeats the storage-dtype
    streaming win. Callers must trace a config whose strip is smaller than
    the corpus, or the check is vacuous by construction."""
    findings: list[Finding] = []
    width = _WIDTH.get(storage_dtype)
    if width is None or width >= 4:
        return findings          # f32 storage: nothing to shadow-copy
    n, m = corpus_shape
    corpus_elems = n * m
    strip_elems = min(strip_rows, n) * m
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    for eqn in iter_all_eqns(jaxpr):
        name = eqn.primitive.name
        if name == "convert_element_type":
            (src,), (dst,) = eqn.invars, eqn.outvars
            src_elems = int(np.prod(src.aval.shape)) if src.aval.shape else 1
            if (str(src.aval.dtype) == storage_dtype
                    and src_elems > strip_elems
                    and _WIDTH.get(str(dst.aval.dtype), 8) > width):
                findings.append(Finding(
                    check="jaxpr.upcast", where=f"{label}:{src.aval.shape}",
                    message=(f"{label}: convert_element_type upcasts a "
                             f"{storage_dtype} operand "
                             f"{tuple(src.aval.shape)} (> one "
                             f"{strip_rows}-row strip) to "
                             f"{dst.aval.dtype} — a multi-strip shadow "
                             f"copy defeats storage-dtype streaming")))
        elif name == "pallas_call":
            dtypes = {str(v.aval.dtype) for v in eqn.invars}
            if storage_dtype not in dtypes:
                findings.append(Finding(
                    check="jaxpr.upcast", where=f"{label}:pallas_call",
                    message=(f"{label}: no {storage_dtype} operand reaches "
                             f"pallas_call (got {sorted(dtypes)}) — the "
                             f"index was upcast before the kernel instead "
                             f"of dequantising in-register")))
            for v in eqn.invars:
                if (str(v.aval.dtype) not in (storage_dtype,)
                        and int(np.prod(v.aval.shape or (1,)))
                        >= corpus_elems):
                    findings.append(Finding(
                        check="jaxpr.upcast",
                        where=f"{label}:pallas_call:{v.aval.shape}",
                        message=(f"{label}: corpus-sized "
                                 f"{v.aval.dtype} operand "
                                 f"{tuple(v.aval.shape)} handed to "
                                 f"pallas_call alongside the "
                                 f"{storage_dtype} index")))
    return findings


def check_no_callbacks(label: str, fn: Callable, args: Sequence
                       ) -> list[Finding]:
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    hits = sorted({e.primitive.name for e in iter_all_eqns(jaxpr)
                   if e.primitive.name in _CALLBACK_PRIMS})
    return [Finding(
        check="jaxpr.host-callback", where=f"{label}:{h}",
        message=(f"{label}: host callback primitive '{h}' inside the "
                 f"traced hot path — every dispatch would synchronise the "
                 f"device behind the host")) for h in hits]


def check_recompile_stability(dispatch: Callable[[int, int], None],
                              cache_sizes: Callable[[], dict],
                              sweep: Sequence[tuple[int, int]],
                              label: str) -> list[Finding]:
    """Drive ``dispatch(live_count, offset)`` across ``sweep`` after one
    warmup call; any jit-cache growth means a cache key depends on a value
    that must stay a traced operand."""
    lo, off = sweep[0]
    dispatch(lo, off)                       # warmup compiles once
    before = cache_sizes()
    for live, offset in sweep[1:]:
        dispatch(live, offset)
    after = cache_sizes()
    grew = {name: (before.get(name, 0), n) for name, n in after.items()
            if n > before.get(name, 0)}
    return [Finding(
        check="jaxpr.recompile", where=f"{label}:{name}",
        message=(f"{label}: jit cache of '{name}' grew {b} -> {a} across a "
                 f"live-count/offset sweep — a segment quantity leaked "
                 f"into a static cache key, so appends recompile under "
                 f"live traffic")) for name, (b, a) in sorted(grew.items())]


# ---------------------------------------------------------------------------
# The repo's real entry points, on tiny traced corpora
# ---------------------------------------------------------------------------


def _tiny(n=600, d=32, B=4, seed=0):
    rng = np.random.default_rng(seed)
    D = jnp.asarray(rng.standard_normal((n, d)).astype(np.float32))
    Q = jnp.asarray(rng.standard_normal((B, d)).astype(np.float32))
    return D, Q


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    """One traced serving entry point — the shared registry row consumed by
    the jaxpr lints, the cost model, and the invariant checker.

    ``fn(*args)`` is trace-ready (``jax.make_jaxpr``-safe, tiny corpus, no
    device traffic implied). ``storage_dtype``/``strip_rows`` are None when
    the storage-dtype streaming check does not apply (f32 storage, or the
    deltas' whole-capacity dequant-by-design). ``bench_key`` names the
    ``BENCH_perf.json`` serve_pipeline config this entry models, when one
    exists. ``family`` ∈ dense/cascade/sharded/segmented/cascade-seg."""

    label: str
    fn: Callable
    args: tuple
    expected_dispatches: int
    corpus_shape: tuple[int, int]
    family: str
    backend: str
    storage_dtype: str | None = None
    strip_rows: int | None = None
    bench_key: str | None = None
    batch: int = 4


def serving_entry_points() -> tuple[EntryPoint, ...]:
    """Build every serving entry point on the tiny synthetic corpus."""
    from repro.core import (CascadeIndex, DenseIndex, ShardedDenseIndex,
                            StaticPruner)
    from repro.core.index import SegmentedIndex

    D, Q = _tiny()
    pruner = StaticPruner(cutoff=0.5).fit(D)
    Dh = pruner.prune_index(D)
    W, mean = pruner.projection()
    n, m = Dh.shape
    B = int(Q.shape[0])
    entries: list[EntryPoint] = []

    # -- dense: fused path is ONE dispatch, streams storage dtype ----------
    for quant, backend, block in ((False, "jnp", None), (True, "jnp", 128),
                                  (True, "pallas", 128)):
        idx = DenseIndex.build(Dh, quantize_int8=quant, backend=backend)
        label = f"DenseIndex.search_projected[{backend}" \
                f"{',int8' if quant else ''}]"
        entry = (lambda i, blk: lambda q: i.search_projected(
            q, W, k=10, mean=mean, block=blk))(idx, block)
        bench = None
        if backend == "jnp":          # serve_pipeline rows run jnp backend
            bench = "dense_int8" if quant else "dense_f32"
        entries.append(EntryPoint(
            label=label, fn=entry, args=(Q,), expected_dispatches=1,
            corpus_shape=(n, m), family="dense", backend=backend,
            storage_dtype=str(idx.vectors.dtype) if quant else None,
            strip_rows=block if quant else None, bench_key=bench, batch=B))

    # -- cascade (dense x dense): coarse scan + shortlist + gather +
    # exact rescore all trace into the SAME single fused dispatch ----------
    for quant, backend, block in ((False, "jnp", None), (True, "jnp", 128),
                                  (True, "pallas", 128)):
        cas = CascadeIndex.build(Dh, m_coarse=max(2, m // 2), n_factor=2,
                                 quantize_int8=quant, backend=backend)
        label = f"CascadeIndex.search_projected[{backend}" \
                f"{',int8' if quant else ''}]"
        entry = (lambda c, blk: lambda q: c.search_projected(
            q, W, k=10, mean=mean, block=blk))(cas, block)
        # the (U, m) = (B*nk, m) int8->f32 upcast of the gathered
        # shortlist IS the rescore stage's dequant unit (one matmul
        # operand, not a corpus shadow copy) — price the strip as the
        # larger of the coarse scan strip and the whole shortlist
        nk = min(cas.n_factor * 10, cas.n)
        entries.append(EntryPoint(
            label=label, fn=entry, args=(Q,), expected_dispatches=1,
            corpus_shape=(n, m), family="cascade", backend=backend,
            storage_dtype=(str(cas.full.vectors.dtype) if quant else None),
            strip_rows=max(block, B * nk) if quant else None, batch=B))

    # -- sharded: one dispatch wrapping shard_map + merge ------------------
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    for quant in (False, True):
        sidx = ShardedDenseIndex.build(Dh, mesh, quantize_int8=quant)
        label = f"ShardedDenseIndex.search_projected" \
                f"[{'int8' if quant else 'f32'}]"
        entry = (lambda i: lambda q: i.search_projected(
            q, W, k=10, mean=mean, block=128))(sidx)
        entries.append(EntryPoint(
            label=label, fn=entry, args=(Q,), expected_dispatches=1,
            corpus_shape=(n, m), family="sharded", backend="jnp",
            storage_dtype=str(sidx.vectors.dtype) if quant else None,
            strip_rows=128 if quant else None,
            bench_key="sharded_int8" if quant else "sharded_f32", batch=B))

    # -- segmented: projection + base + one per delta + merge --------------
    # (storage-dtype streaming of the base is covered by the dense/sharded
    # checks above; deltas upcast their whole small capacity by design)
    rng = np.random.default_rng(3)
    seg = SegmentedIndex.from_index(DenseIndex.build(Dh, quantize_int8=True),
                                    delta_capacity=64)
    seg = seg.append(rng.standard_normal((70, m)).astype(np.float32))
    nd = len(seg.deltas)
    entries.append(EntryPoint(
        label=f"SegmentedIndex.search_projected[int8,{nd}d]",
        fn=(lambda s: lambda q: s.search_projected(q, W, k=10,
                                                   mean=mean))(seg),
        args=(Q,), expected_dispatches=nd + 3, corpus_shape=(n, m),
        family="segmented", backend="jnp", batch=B))

    # -- paged: a device-resident paged index is ONE fused dispatch — the
    # projection, page-table walk, per-page in-register dequant and top-k
    # all trace into ``_paged_search_projected``. Appends land in the tail
    # tier at contiguous logical slots, so a grown index stays single-run
    # (the [lo, hi) bounds are traced operands, never static shapes) ------
    from repro.core.paged import PagedIndex
    rng_p = np.random.default_rng(11)
    for quant, backend in ((False, "jnp"), (True, "jnp"), (True, "pallas")):
        pidx = PagedIndex.from_index(
            DenseIndex.build(Dh, quantize_int8=quant), page_rows=64,
            seal_rows=128, backend=backend)
        pidx = pidx.append(rng_p.standard_normal((70, m))
                           .astype(np.float32))
        label = f"PagedIndex.search_projected[{backend}" \
                f"{',int8' if quant else ''}]"
        entries.append(EntryPoint(
            label=label,
            fn=(lambda i: lambda q: i.search_projected(q, W, k=10,
                                                       mean=mean))(pidx),
            args=(Q,), expected_dispatches=1, corpus_shape=(n, m),
            family="paged", backend=backend,
            storage_dtype="int8" if quant else None,
            strip_rows=64 if quant else None, batch=B))

    # -- paged cascade: projection + paged coarse walk + shortlist +
    # paged rescore + select = 5 dispatches, independent of page or
    # extent count (the segmented cascade pays 2 more per delta) -----------
    rng_pc = np.random.default_rng(13)
    pcas = CascadeIndex.build(Dh, m_coarse=max(2, m // 2), n_factor=2,
                              quantize_int8=True
                              ).paged(page_rows=64, seal_rows=128)
    pcas = pcas.append(rng_pc.standard_normal((70, m)).astype(np.float32))
    entries.append(EntryPoint(
        label="CascadeIndex.search_projected[paged,int8]",
        fn=(lambda c: lambda q: c.search_projected(q, W, k=10,
                                                   mean=mean))(pcas),
        args=(Q,), expected_dispatches=5, corpus_shape=(n, m),
        family="cascade-paged", backend="jnp", batch=B))

    # -- segmented cascade: projection + per-segment coarse scans + coarse
    # merge + shortlist + per-segment rescores + select = 2*nd + 6 ---------
    rng_c = np.random.default_rng(7)
    cseg = CascadeIndex.build(Dh, m_coarse=max(2, m // 2), n_factor=2,
                              quantize_int8=True
                              ).segmented(delta_capacity=64)
    cseg = cseg.append(rng_c.standard_normal((70, m)).astype(np.float32))
    cnd = len(cseg.full.deltas)
    entries.append(EntryPoint(
        label=f"CascadeIndex.search_projected[seg,int8,{cnd}d]",
        fn=(lambda c: lambda q: c.search_projected(q, W, k=10,
                                                   mean=mean))(cseg),
        args=(Q,), expected_dispatches=2 * cnd + 6, corpus_shape=(n, m),
        family="cascade-seg", backend="jnp", batch=B))
    return tuple(entries)


def run() -> list[Finding]:
    """Lint every serving entry point; returns the combined findings."""
    from repro.core import DenseIndex, StaticPruner
    from repro.core.index import SegmentedIndex, segment_jit_cache_sizes
    from repro.core.pca import transform

    findings: list[Finding] = []
    for ep in serving_entry_points():
        findings += check_dispatch_count(ep.label, ep.fn, ep.args,
                                         expected=ep.expected_dispatches)
        findings += check_no_callbacks(ep.label, ep.fn, ep.args)
        if ep.storage_dtype is not None:
            findings += check_storage_dtype_stream(
                ep.label, ep.fn, ep.args, ep.corpus_shape, ep.storage_dtype,
                strip_rows=ep.strip_rows)

    D, Q = _tiny()
    pruner = StaticPruner(cutoff=0.5).fit(D)
    Dh = pruner.prune_index(D)
    W, mean = pruner.projection()
    m = Dh.shape[1]

    # -- compaction streaming: the per-block projection is one dispatch ----
    rng = np.random.default_rng(3)
    label = "pca.transform[compaction-block]"
    block = jnp.asarray(rng.standard_normal((64, D.shape[1]))
                        .astype(np.float32))
    entry = lambda b: transform(b, pruner.state, pruner.kept_dims)  # noqa: E731
    findings += check_no_callbacks(label, entry, (block,))

    # -- recompile stability across live-counts/offsets --------------------
    from repro.core import CascadeIndex
    seg = SegmentedIndex.from_index(DenseIndex.build(Dh, quantize_int8=True),
                                    delta_capacity=64)
    seg = seg.append(rng.standard_normal((70, m)).astype(np.float32))
    rng_c = np.random.default_rng(7)
    cseg = CascadeIndex.build(Dh, m_coarse=max(2, m // 2), n_factor=2,
                              quantize_int8=True
                              ).segmented(delta_capacity=64)
    cseg = cseg.append(rng_c.standard_normal((70, m)).astype(np.float32))
    state = {"seg": seg}

    def dispatch(live_rows: int, _offset: int) -> None:
        state["seg"] = state["seg"].append(
            rng.standard_normal((live_rows, m)).astype(np.float32))
        state["seg"].search_projected(Q, W, k=5, mean=mean)

    # stays within the open delta's capacity: every step changes the live
    # count and the next segment's id offset but must reuse every jit.
    # One compile per distinct append-block SHAPE is the documented
    # ``_delta_update`` contract, and whether a given append exercises it
    # (vs the scale-widening requant path) depends on the data — so every
    # sweep shape is warmed deterministically for both the full and the
    # cascade's coarse width before anything is measured.
    from repro.core.index import _delta_update
    sweep = [(1, 0), (2, 0), (3, 0), (5, 0), (1, 0)]
    store_dt = seg.deltas[-1].vectors.dtype
    for r in sorted({lr for lr, _ in sweep}):
        for mm in (m, max(2, m // 2)):
            _delta_update(jnp.zeros((64, mm), store_dt),
                          jnp.zeros((r, mm), store_dt), jnp.int32(0))
    findings += check_recompile_stability(
        dispatch, segment_jit_cache_sizes, sweep,
        "SegmentedIndex.append+search_projected")

    # -- cascade recompile stability: appends grow BOTH resolutions; every
    # per-segment rescore takes live count/offset as traced operands and
    # nk = n_factor*k stays fixed, so no cascade jit may recompile. The
    # sweep stays inside the open delta's capacity (the part count — a
    # legitimate static shape — is unchanged throughout).
    cstate = {"cas": cseg}

    def cdispatch(live_rows: int, _offset: int) -> None:
        cstate["cas"] = cstate["cas"].append(
            rng_c.standard_normal((live_rows, m)).astype(np.float32))
        cstate["cas"].search_projected(Q, W, k=5, mean=mean)

    findings += check_recompile_stability(
        cdispatch, segment_jit_cache_sizes, sweep,
        "CascadeIndex.append+search_projected")

    # -- paged lifecycle recompile stability: the FULL page lifecycle —
    # append -> search -> promote -> compact -> search — at varying live
    # counts must reuse every jit. All page metadata (table, nvalid,
    # offsets, scales) is host-authoritative and re-pushed at fixed
    # shapes; [lo, hi) slot bounds are traced operands; compaction is the
    # one fused ``_pool_drain`` gather. Any cache growth here means a page
    # count or extent boundary leaked into a static key.
    from repro.core.paged import PagedIndex
    rng_p = np.random.default_rng(11)
    pstate = {"pg": PagedIndex.from_index(
        DenseIndex.build(Dh, quantize_int8=True), page_rows=64,
        seal_rows=128)}

    def pdispatch(live_rows: int, _offset: int) -> None:
        pg = pstate["pg"].append(
            rng_p.standard_normal((live_rows, m)).astype(np.float32))
        pg.search_projected(Q, W, k=5, mean=mean)
        pg, _ = pg.promote()
        pg, _ = pg.compact_pages()
        pg.search_projected(Q, W, k=5, mean=mean)
        pstate["pg"] = pg

    findings += check_recompile_stability(
        pdispatch, segment_jit_cache_sizes, sweep,
        "PagedIndex.lifecycle")
    return findings
