"""Small dependency-free utilities shared across entry points.

Nothing here may import jax (directly or transitively): the helpers run
before the JAX backend initialises, and some callers rely on that window.
"""
from __future__ import annotations

import os


def force_host_device_count(n: int) -> bool:
    """Append ``--xla_force_host_platform_device_count=n`` to ``XLA_FLAGS``.

    Gives a CPU-only process an n-way device mesh (the code path a TPU pod
    takes, minus the speed). Only effective before the JAX *backend*
    initialises — importing jax is fine, touching a device is not — so
    call it before the first array op. A no-op if ``n <= 1`` or the flag
    is already set (an operator-provided count wins). Returns whether the
    flag was applied. ``tests/conftest.py`` intentionally inlines the same
    three lines — it must run before any import graph.
    """
    if n <= 1:
        return False
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in flags:
        return False
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip())
    return True
