"""Phi-3-medium 14B — dense decoder, GQA 40/10, RoPE + SwiGLU.

[arXiv:2404.14219]
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

CFG = TransformerConfig(
    name="phi3-medium-14b",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10, d_ff=17920,
    vocab=100352, rope_theta=10000.0, tie_embeddings=False,
    norm="rmsnorm", act="silu",
    param_dtype="float32", compute_dtype="bfloat16", remat=True,
    microbatch=8,
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="phi3-medium-14b", family="lm", cfg=CFG,
        shapes=lm_shapes(sub_quadratic=False),
        source="arXiv:2404.14219",
        optimizer="adamw")


def smoke_cfg() -> TransformerConfig:
    return TransformerConfig(
        name="phi3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab=512, compute_dtype="float32", remat=False)
