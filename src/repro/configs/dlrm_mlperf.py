"""DLRM — MLPerf benchmark config (Criteo 1TB). [arXiv:1906.00091]

13 dense + 26 sparse features, embed_dim 128, bottom MLP 13-512-256-128,
top MLP 1024-1024-512-256-1, dot interaction. Vocab sizes are the Criteo
Terabyte cardinalities used by the MLPerf reference, rounded up to multiples
of 512 so table rows shard evenly on both production meshes (256/512 chips).
"""
from repro.configs.base import RECSYS_SHAPES, ArchSpec, round_up
from repro.models.recsys import RecsysConfig

_CRITEO_TB_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)

VOCABS = tuple(round_up(v, 512) for v in _CRITEO_TB_VOCABS)

CFG = RecsysConfig(
    name="dlrm-mlperf", kind="dlrm",
    vocab_sizes=VOCABS, embed_dim=128, n_dense=13,
    bot_mlp=(512, 256, 128), top_mlp=(1024, 1024, 512, 256, 1),
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="dlrm-mlperf", family="recsys", cfg=CFG,
        shapes=RECSYS_SHAPES,
        source="arXiv:1906.00091 (MLPerf reference)",
        optimizer="rowwise",   # §Perf: sparse rowwise-AdaGrad tables (96x memory term)
        notes="~188M embedding rows; tables FSDP-sharded over every mesh axis.")


def smoke_cfg() -> RecsysConfig:
    return RecsysConfig(
        name="dlrm-smoke", kind="dlrm",
        vocab_sizes=(512, 256, 128, 64), embed_dim=16, n_dense=13,
        bot_mlp=(32, 16), top_mlp=(64, 32, 1))
