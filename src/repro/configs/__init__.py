"""Architecture configs (exact public hyperparameters) + registry."""
from repro.configs.registry import (ARCHS, get_arch, list_archs, input_specs,
                                    make_step_bundle, cells)

__all__ = ["ARCHS", "get_arch", "list_archs", "input_specs",
           "make_step_bundle", "cells"]
