"""Two-tower retrieval — sampled-softmax dual encoder. [Yi et al., RecSys'19]

embed_dim 256, tower MLP 1024-512-256, dot scoring. The ``retrieval_cand``
shape (1 query vs 10^6 candidates) is the paper's exact dense-retrieval
setting: the candidate index is built offline from the item tower and is
PCA-prunable via ``repro.core.StaticPruner`` (256 → m dims).
"""
from repro.configs.base import RECSYS_SHAPES, ArchSpec
from repro.models.recsys import RecsysConfig

CFG = RecsysConfig(
    name="two-tower-retrieval", kind="two_tower",
    embed_dim=256, tower_mlp=(1024, 512, 256),
    user_vocab=2_097_152, item_vocab=1_048_576,   # 2^21 / 2^20 (shard-even)
    temperature=0.05,
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="two-tower-retrieval", family="recsys", cfg=CFG,
        shapes=RECSYS_SHAPES,
        source="RecSys'19 (YouTube two-tower)",
        optimizer="adamw",
        notes="train_batch uses the sharded in-batch sampled softmax "
              "(65k x 65k logits never replicated); retrieval_cand is the "
              "paper's flagship PCA cell.")


def smoke_cfg() -> RecsysConfig:
    return RecsysConfig(
        name="two-tower-smoke", kind="two_tower",
        embed_dim=32, tower_mlp=(64, 32), user_vocab=2048, item_vocab=1024)
