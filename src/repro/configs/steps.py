"""Step bundles: (fn, abstract args, shardings) per (arch × shape × mesh).

A ``StepBundle`` is everything the dry-run / launcher needs to AOT-compile
one cell: the step function, ``ShapeDtypeStruct`` stand-ins for every input
(no allocation), and NamedSharding trees resolved from the arch's sharding
rules against the given mesh. ``bundle.lower()`` is the single entry point
``launch/dryrun.py`` drives.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, ShapeCell, round_up
from repro.models import biencoder as BE, gnn as G, recsys as R, transformer as T
from repro.optim import adafactor, adamw
from repro.par import compat, sharding as SH

TOPK_SERVE = 100  # retrieval top-k


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), jnp.dtype(dtype))


@dataclasses.dataclass
class StepBundle:
    name: str
    fn: Callable
    args: tuple
    in_specs: tuple
    out_specs: Any
    mesh: Mesh
    donate: tuple = ()
    meta: dict = dataclasses.field(default_factory=dict)

    def _ns(self, tree):
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), tree,
            is_leaf=lambda x: isinstance(x, P))

    def jitted(self):
        kw = {}
        if self.in_specs is not None:
            kw["in_shardings"] = tuple(self._ns(s) for s in self.in_specs)
        if self.out_specs is not None:
            kw["out_shardings"] = self._ns(self.out_specs)
        if self.donate:
            kw["donate_argnums"] = self.donate
        return jax.jit(self.fn, **kw)

    def lower(self):
        return self.jitted().lower(*self.args)


# ---------------------------------------------------------------------------
# shared optimizer plumbing
# ---------------------------------------------------------------------------


def rowwise_opt_init(params):
    """Rowwise-AdaGrad tables + AdamW rest (see repro.optim.rowwise)."""
    rest = {k: v for k, v in params.items() if k != "tables"}
    return {"adamw": adamw.adamw_init(rest),
            "acc": [jnp.zeros((t.shape[0],), jnp.float32)
                    for t in params["tables"]]}


def _opt_pack(optimizer: str):
    if optimizer == "adafactor":
        return adafactor.adafactor_init, adafactor.adafactor_update
    if optimizer == "rowwise":
        return rowwise_opt_init, None   # update lives in the rowwise bundle
    return adamw.adamw_init, adamw.adamw_update


def _zero1_like(opt_sds: Any, base_specs: Any, params_sds: Any, mesh: Mesh,
                optimizer: str) -> Any:
    if optimizer == "adamw":
        return adamw.opt_state_specs(base_specs, params_sds, mesh, zero1=True)
    # adafactor: factored leaves don't mirror param structure — dp-shard the
    # first divisible dim of each state leaf (ZeRO-1 flavoured)
    dp = SH.logical_to_physical("dp", mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))

    def leaf_spec(leaf):
        for d, n in enumerate(leaf.shape):
            if n % dp_size == 0 and n > 1:
                parts = [None] * len(leaf.shape)
                parts[d] = dp if len(dp) > 1 else dp[0]
                return P(*parts)
        return P()

    return {"v": jax.tree.map(leaf_spec, opt_sds["v"]), "step": P()}


def _make_train_step(loss_fn: Callable, optimizer: str, lr: float = 1e-4,
                     microbatch: int = 1, accum_dtype=jnp.float32,
                     mb_shardings=None):
    """Train step with gradient-accumulation microbatching.

    ``microbatch`` K splits the global batch into K sequential microbatches
    inside a lax.scan: activation memory drops by K (the difference between
    a 480B model fitting a pod or not); grads accumulate in ``accum_dtype``
    (bf16 for the largest models — halves grad-buffer HBM at ~1e-3 relative
    accumulation error over K<=32 microbatches).

    ``mb_shardings``: NamedSharding tree pinning the reshaped (K, B/K, ...)
    batch to keep B/K on the dp axes — without the constraint GSPMD is free
    to shard the K dim instead, silently un-sharding every activation.
    """
    opt_init, opt_update = _opt_pack(optimizer)

    def step(params, opt_state, batch):
        if microbatch <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape(microbatch, x.shape[0] // microbatch,
                                    *x.shape[1:]), batch)
            if mb_shardings is not None:
                mbs = jax.tree.map(jax.lax.with_sharding_constraint, mbs,
                                   mb_shardings)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                              params)

            def mb_step(carry, mb):
                acc_l, acc_g = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc_g = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), acc_g, g)
                return (acc_l + l, acc_g), None

            (loss, gsum), _ = jax.lax.scan(
                mb_step, (jnp.float32(0.0), g0), mbs)
            loss = loss / microbatch
            grads = jax.tree.map(lambda g: (g / microbatch), gsum)
        new_params, new_opt = opt_update(grads, opt_state, params,
                                         jnp.float32(lr))
        return new_params, new_opt, {"loss": loss}

    return step, opt_init


def _microbatch_of(cfg) -> tuple[int, Any]:
    mb = getattr(cfg, "microbatch", 1) or 1
    dt = jnp.dtype(getattr(cfg, "grad_accum_dtype", "float32"))
    return mb, dt


def _train_bundle(name, mesh, params_sds, param_spec, batch_sds, batch_spec,
                  loss_fn, optimizer, meta, microbatch: int = 1,
                  accum_dtype=jnp.float32) -> StepBundle:
    mb_ns = None
    if microbatch > 1:
        mb_ns = jax.tree.map(
            lambda s: NamedSharding(mesh, P(None, *s)), batch_spec,
            is_leaf=lambda x: isinstance(x, P))
    step, opt_init = _make_train_step(loss_fn, optimizer,
                                      microbatch=microbatch,
                                      accum_dtype=accum_dtype,
                                      mb_shardings=mb_ns)
    opt_sds = jax.eval_shape(opt_init, params_sds)
    opt_spec = _zero1_like(opt_sds, param_spec, params_sds, mesh, optimizer)
    return StepBundle(
        name=name, fn=step, mesh=mesh,
        args=(params_sds, opt_sds, batch_sds),
        in_specs=(param_spec, opt_spec, batch_spec),
        out_specs=(param_spec, opt_spec, {"loss": P()}),
        donate=(0, 1),
        meta=meta)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def _lm_param_sds(cfg: T.TransformerConfig, serve: bool):
    c = dataclasses.replace(cfg, param_dtype="bfloat16") if serve else cfg
    return jax.eval_shape(lambda: T.init_lm(jax.random.PRNGKey(0), c)), c


def _dp(mesh: Mesh):
    dp = SH.logical_to_physical("dp", mesh)
    return dp if len(dp) > 1 else dp[0]


def _lm_mem_bytes(cfg: T.TransformerConfig, kind: str, B: int, S: int) -> int:
    """Analytic global HBM traffic per step (napkin model, documented in
    EXPERIMENTS.md §Roofline). Attention interiors are assumed VMEM-resident
    (flash kernel on the TPU target)."""
    P = cfg.param_count()
    Pa = cfg.active_param_count()
    L, d, V = cfg.n_layers, cfg.d_model, cfg.vocab
    kv = cfg.n_kv_heads * cfg.hd
    tokens = B * S
    if kind == "train":
        params = 3 * P * 2 + 2 * P * 4 + 4 * P * 4 + P * 4  # casts+grads+adam
        acts = L * tokens * d * 2 * 20          # fwd+bwd+remat tensor passes
        logits = 2 * 2 * tokens * V * 4 / max(1, S // 2048)  # chunked, fwd+bwd
        return int(params + acts + logits)
    if kind == "prefill":
        return int(P * 2 + L * tokens * d * 2 * 6 + 2 * L * tokens * kv * 2)
    if kind == "decode":
        cache = 2 * L * B * S * kv * 2
        return int(Pa * 2 + cache + B * V * 4)
    # decode_long: rolling window cache
    W = cfg.sliding_window or S
    return int(Pa * 2 + 2 * L * B * W * kv * 2 + B * V * 4)


def lm_bundle(spec_: ArchSpec, cell: ShapeCell, mesh: Mesh) -> StepBundle:
    cfg: T.TransformerConfig = spec_.cfg
    rules = (SH.lm_rules_dp_only() if cfg.parallelism == "dp_only"
             else SH.lm_rules(moe=cfg.n_experts > 0, moe_dp_dim=cfg.moe_dp_dim))
    S, B = cell.dims["seq_len"], cell.dims["global_batch"]
    dp = _dp(mesh)
    tokens_B = B
    meta = dict(family="lm", arch=spec_.arch_id, shape=cell.name,
                params=cfg.param_count(), active_params=cfg.active_param_count(),
                dims=dict(cell.dims), n_layers=cfg.n_layers, d_model=cfg.d_model,
                vocab=cfg.vocab,
                analytic_bytes=_lm_mem_bytes(cfg, cell.kind, B, S))

    act_ns = NamedSharding(mesh, P(dp, None, None)) if B > 1 else None

    if cell.kind == "train":
        params_sds, cfg_t = _lm_param_sds(cfg, serve=False)
        cfg_t = dataclasses.replace(cfg_t, act_sharding=act_ns)
        pspec = SH.param_specs(params_sds, mesh, rules)
        batch_sds = {"tokens": sds((B, S), jnp.int32),
                     "labels": sds((B, S), jnp.int32)}
        bspec = {"tokens": P(dp, None), "labels": P(dp, None)}
        logit_ns = NamedSharding(mesh, P(dp, None, "model"))
        loss = partial(_lm_loss, cfg=cfg_t, logit_sharding=logit_ns)
        meta["model_flops"] = 6 * cfg.active_param_count() * B * S
        meta["tokens"] = B * S
        mb, adt = _microbatch_of(cfg)
        meta["microbatch"] = mb
        return _train_bundle(f"{spec_.arch_id}:{cell.name}", mesh, params_sds,
                             pspec, batch_sds, bspec, loss, spec_.optimizer,
                             meta, microbatch=mb, accum_dtype=adt)

    params_sds, cfg_s = _lm_param_sds(cfg, serve=True)
    cfg_s = dataclasses.replace(cfg_s, act_sharding=act_ns)
    pspec = SH.param_specs(params_sds, mesh, rules)
    hd = cfg.hd
    meta["model_flops"] = 2 * cfg.active_param_count() * B * (
        S if cell.kind == "prefill" else 1)

    if cell.kind == "prefill":
        def fn(params, tokens):
            return T.prefill(params, tokens, cfg_s)
        cache_spec = P(None, dp, "model", None, None)  # seq-sharded KV
        return StepBundle(
            name=f"{spec_.arch_id}:{cell.name}", fn=fn, mesh=mesh,
            args=(params_sds, sds((B, S), jnp.int32)),
            in_specs=(pspec, P(dp, None)),
            out_specs=(P(dp, None), (cache_spec, cache_spec)),
            meta=meta)

    if cell.kind == "decode":
        cache_sds = sds((cfg.n_layers, B, S, cfg.n_kv_heads, hd), jnp.bfloat16)
        cache_spec = P(None, dp, "model", None, None)

        def fn(params, kv_cache, token, pos):
            return T.decode_step(params, kv_cache, token, pos, cfg_s)

        return StepBundle(
            name=f"{spec_.arch_id}:{cell.name}", fn=fn, mesh=mesh,
            args=(params_sds, (cache_sds, cache_sds),
                  sds((B,), jnp.int32), sds((), jnp.int32)),
            in_specs=(pspec, (cache_spec, cache_spec), P(dp), P()),
            out_specs=(P(dp, None), (cache_spec, cache_spec)),
            donate=(1,),
            meta=meta)

    if cell.kind == "decode_long":
        # sliding-window rolling buffer: live cache = window, not seq_len
        W = cfg.sliding_window
        assert W is not None, "long_500k requires a sub-quadratic arch"
        cache_sds = sds((cfg.n_layers, B, W, cfg.n_kv_heads, hd), jnp.bfloat16)
        cache_spec = P(None, None, "model", None, None)  # B=1: shard window

        def fn(params, kv_cache, token, pos):
            return T.decode_step_sliding(params, kv_cache, token, pos, cfg_s)

        meta["window"] = W
        return StepBundle(
            name=f"{spec_.arch_id}:{cell.name}", fn=fn, mesh=mesh,
            args=(params_sds, (cache_sds, cache_sds),
                  sds((B,), jnp.int32), sds((), jnp.int32)),
            in_specs=(pspec, (cache_spec, cache_spec), P(), P()),
            out_specs=(P(None, None), (cache_spec, cache_spec)),
            donate=(1,),
            meta=meta)

    raise ValueError(f"unknown LM cell kind {cell.kind}")


def _lm_loss(params, batch, cfg, logit_sharding=None):
    return T.forward_train(params, batch["tokens"], batch["labels"], cfg,
                           logit_sharding=logit_sharding)


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


def gnn_bundle(spec_: ArchSpec, cell: ShapeCell, mesh: Mesh) -> StepBundle:
    cfg: G.GNNConfig = spec_.cfg
    d = cell.dims
    ndev = int(np.prod(mesh.devices.shape))
    all_axes = tuple(mesh.axis_names)

    if cell.kind == "train_sampled":
        # static padded subgraph from the CSR fanout sampler
        bn, f0, f1 = d["batch_nodes"], d["fanout0"], d["fanout1"]
        N = bn + bn * f0 + bn * f0 * f1
        E = bn * f0 + bn * f0 * f1
        d_feat = d["d_feat"]
    elif cell.name == "molecule":
        N = d["batch"] * d["n_nodes"]
        E = d["batch"] * d["n_edges"]
        d_feat = d["d_feat"]
    else:
        N, E, d_feat = d["n_nodes"], d["n_edges"], d["d_feat"]

    big = E >= 1_000_000
    E_pad = round_up(E, 512) if big else E
    cfg_r = dataclasses.replace(cfg, d_in=d_feat)

    params_sds = jax.eval_shape(lambda: G.init_gnn(jax.random.PRNGKey(0), cfg_r))
    pspec = SH.param_specs(params_sds, mesh, SH.gnn_rules())

    batch_sds = {
        "nodes": sds((N, d_feat), jnp.float32),
        "edges": sds((E_pad, cfg.d_edge_in), jnp.float32),
        "edge_index": sds((2, E_pad), jnp.int32),
        "edge_mask": sds((E_pad,), jnp.float32),
        "targets": sds((N, cfg.d_out), jnp.float32),
        "node_mask": sds((N,), jnp.float32),
    }
    # big graphs: edges shard over every axis (pure data); node tables
    # replicate. Small graphs (< 1M edges, not shard-even) replicate fully —
    # there is no data to parallelise and the dry-run records that honestly.
    if big:
        bspec = {"nodes": P(), "edges": P(all_axes, None),
                 "edge_index": P(None, all_axes), "edge_mask": P(all_axes),
                 "targets": P(), "node_mask": P()}
    else:
        bspec = {k: P() if v.ndim == 1 else P(*([None] * v.ndim))
                 for k, v in batch_sds.items()}

    loss = partial(_gnn_loss, cfg=cfg_r)
    h = cfg.d_hidden
    fwd_flops = 2 * (E * (4 * h * h) + N * (3 * h * h)) * cfg.n_layers \
        + 2 * N * (d_feat * h + h * h) + 2 * E_pad * (cfg.d_edge_in * h + h * h) \
        + 2 * N * (h * h + h * cfg.d_out)
    # traffic: per layer, gather 2 endpoint features + write messages +
    # scatter-add, fwd+bwd+remat (~3x); params negligible
    mem = 3 * cfg.n_layers * (3 * E * h * 4 + 4 * N * h * 4) \
        + 3 * N * (d_feat + cfg.d_out) * 4
    meta = dict(family="gnn", arch=spec_.arch_id, shape=cell.name,
                params=cfg_r.param_count(), active_params=cfg_r.param_count(),
                model_flops=3 * fwd_flops,  # fwd + bwd(2x)
                n_nodes=N, n_edges=E_pad, d_hidden=h,
                dims=dict(cell.dims), analytic_bytes=int(mem))
    return _train_bundle(f"{spec_.arch_id}:{cell.name}", mesh, params_sds,
                         pspec, batch_sds, bspec, loss, spec_.optimizer, meta)


def _gnn_loss(params, batch, cfg):
    return G.mse_loss(params, batch, cfg)


# ---------------------------------------------------------------------------
# RecSys family
# ---------------------------------------------------------------------------


def _recsys_mem_bytes(cfg: R.RecsysConfig, kind: str, B: int, C: int = 0) -> int:
    """Analytic global HBM traffic. NOTE the dense-optimizer reality: AdamW
    moments for the full embedding tables are read+written every step —
    the dominant term for DLRM-scale tables (a designed-in hillclimb
    target: rowwise/sparse optimizers)."""
    e = cfg.embed_dim
    if cfg.kind == "two_tower":
        table_p = (cfg.user_vocab + cfg.item_vocab) * e
        dims = (e,) + cfg.tower_mlp
        mlp_p = 2 * sum(dims[i] * dims[i + 1] for i in range(len(dims) - 1))
        if kind == "train":
            return int(3 * 2 * B * e * 4 + 6 * table_p * 4 + 7 * mlp_p * 4
                       + 3 * B * B * 4)
        if kind == "serve":
            return int(2 * B * e * 4 + mlp_p * 4 + 3 * B * sum(dims) * 4)
        return int(C * cfg.tower_mlp[-1] * 4 + mlp_p * 4 + e * 4)
    F = cfg.n_sparse
    table_p = sum(cfg.vocab_sizes) * e
    mlp_p = cfg.param_count() - table_p
    act_w = F * e + (sum(cfg.bot_mlp) + sum(cfg.top_mlp)
                     + sum(cfg.deep_mlp) + cfg.n_attn_layers
                     * cfg.n_heads * cfg.d_attn * F)
    if kind == "train":
        return int(3 * B * F * e * 4 + 6 * table_p * 4 + 7 * mlp_p * 4
                   + 3 * B * act_w * 4)
    if kind == "serve":
        return int(B * F * e * 4 + mlp_p * 4 + B * act_w * 4)
    f_item = F - F // 2
    return int(C * f_item * e * 4 + mlp_p * 4 + C * act_w * 4)


def recsys_bundle(spec_: ArchSpec, cell: ShapeCell, mesh: Mesh) -> StepBundle:
    cfg: R.RecsysConfig = spec_.cfg
    rules = SH.recsys_rules()
    dp = _dp(mesh)
    all_axes = tuple(mesh.axis_names)
    params_sds = jax.eval_shape(lambda: R.init_recsys(jax.random.PRNGKey(0), cfg))
    pspec = SH.param_specs(params_sds, mesh, rules)
    B = cell.dims["batch"]
    C0 = round_up(cell.dims.get("n_candidates", 0), 512)
    meta = dict(family="recsys", arch=spec_.arch_id, shape=cell.name,
                params=cfg.param_count(), active_params=_recsys_active(cfg),
                model_flops=None, dims=dict(cell.dims),
                analytic_bytes=_recsys_mem_bytes(cfg, cell.kind, B, C0))

    if cfg.kind == "two_tower":
        return _two_tower_bundle(spec_, cell, mesh, cfg, params_sds, pspec, meta)

    F = cfg.n_sparse
    batch_sds = {"sparse": sds((B, F), jnp.int32),
                 "label": sds((B,), jnp.float32)}
    bspec = {"sparse": P(dp, None), "label": P(dp)}
    if cfg.kind == "dlrm":
        batch_sds["dense"] = sds((B, cfg.n_dense), jnp.float32)
        bspec["dense"] = P(dp, None)

    per_sample = _ctr_flops_per_sample(cfg)
    if cell.kind == "train":
        meta["model_flops"] = 3 * per_sample * B
        if spec_.optimizer == "rowwise":
            # sparse-grad table path: optimizer traffic O(batch·dim), see
            # repro.optim.rowwise. Analytic bytes shrink accordingly.
            e = cfg.embed_dim
            meta["analytic_bytes"] = int(
                6 * B * cfg.n_sparse * e * 4      # gather + grad + scatter
                + 7 * (meta["params"] - sum(cfg.vocab_sizes) * e) * 4
                + 3 * B * 4096)
            return _recsys_rowwise_bundle(spec_, cell, mesh, cfg, params_sds,
                                          pspec, batch_sds, bspec, meta)
        loss = partial(_ctr_loss, cfg=cfg)
        return _train_bundle(f"{spec_.arch_id}:{cell.name}", mesh, params_sds,
                             pspec, batch_sds, bspec, loss, spec_.optimizer, meta)

    if cell.kind == "serve":
        meta["model_flops"] = per_sample * B

        def fn(params, batch):
            return R.forward_ctr(params, batch, cfg)

        return StepBundle(
            name=f"{spec_.arch_id}:{cell.name}", fn=fn, mesh=mesh,
            args=(params_sds, batch_sds),
            in_specs=(pspec, bspec), out_specs=P(dp),
            meta=meta)

    # retrieval: 1 user context vs C candidate items
    C = round_up(cell.dims["n_candidates"], 512)
    f_user, f_item = R.ctr_user_item_split(cfg)
    user_sds = {"sparse": sds((1, f_user), jnp.int32)}
    uspec = {"sparse": P()}
    if cfg.kind == "dlrm":
        user_sds["dense"] = sds((1, cfg.n_dense), jnp.float32)
        uspec["dense"] = P()
    cand_sds = sds((C, f_item), jnp.int32)
    meta["model_flops"] = per_sample * C
    meta["n_candidates"] = C

    def fn(params, user_batch, cand_sparse):
        scores = R.ctr_retrieval_scores(params, user_batch, cand_sparse, cfg)
        return _sharded_topk_1d(scores, TOPK_SERVE, mesh)

    return StepBundle(
        name=f"{spec_.arch_id}:{cell.name}", fn=fn, mesh=mesh,
        args=(params_sds, user_sds, cand_sds),
        in_specs=(pspec, uspec, P(all_axes, None)),
        out_specs=(P(), P()),
        meta=meta)


def _ctr_loss(params, batch, cfg):
    return R.bce_loss(params, batch, cfg)


def _recsys_rowwise_bundle(spec_, cell, mesh, cfg, params_sds, pspec,
                           batch_sds, bspec, meta) -> StepBundle:
    """CTR train step with rows gathered OUTSIDE autodiff + rowwise AdaGrad.

    Dense table grads never exist; tables are donated so the row updates
    scatter in place. Dense (non-table) params keep AdamW.
    """
    from repro.optim import rowwise as RW

    def bce_from_logit(logit, y):
        return jnp.mean(jnp.maximum(logit, 0) - logit * y
                        + jnp.log1p(jnp.exp(-jnp.abs(logit))))

    opt_init = rowwise_opt_init
    dp = _dp(mesh)
    rows_ns = NamedSharding(mesh, P(dp, None))

    def step(params, opt_state, batch):
        tables = params["tables"]
        rest = {k: v for k, v in params.items() if k != "tables"}
        idx = batch["sparse"]                                   # (B, F)
        # gather OUTSIDE autodiff; pin rows batch-sharded — without the
        # constraint XLA materialises each table's rows at GLOBAL batch
        # (26 x 832 MiB all-gathers on this cell)
        rows = [jax.lax.with_sharding_constraint(
                    jnp.take(t, idx[:, f], axis=0), rows_ns)
                for f, t in enumerate(tables)]

        def loss_fn(rest_, rows_):
            emb = jnp.stack(rows_, axis=1).astype(jnp.float32)
            logit = R.forward_ctr_from_emb(rest_, emb, batch, cfg)
            return bce_from_logit(logit, batch["label"].astype(jnp.float32))

        loss, (g_rest, g_rows) = jax.value_and_grad(
            loss_fn, argnums=(0, 1))(rest, rows)
        lr = jnp.float32(1e-4)
        new_rest, new_adam = adamw.adamw_update(g_rest, opt_state["adamw"],
                                                rest, lr)
        new_tables, new_acc = [], []
        for f, (t, a, gr) in enumerate(zip(tables, opt_state["acc"], g_rows)):
            nt, na = RW.rowwise_adagrad_update(t, a, idx[:, f], gr, lr)
            new_tables.append(nt)
            new_acc.append(na)
        new_params = dict(new_rest, tables=new_tables)
        return new_params, {"adamw": new_adam, "acc": new_acc}, {"loss": loss}

    opt_sds = jax.eval_shape(opt_init, params_sds)
    rest_spec = {k: v for k, v in pspec.items() if k != "tables"}
    acc_spec = [P(s[0]) for s in pspec["tables"]]   # rows spec of each table
    opt_spec = {"adamw": adamw.opt_state_specs(rest_spec,
                                               {k: v for k, v in params_sds.items()
                                                if k != "tables"}, mesh),
                "acc": acc_spec}
    meta["optimizer"] = "rowwise-adagrad"
    return StepBundle(
        name=f"{spec_.arch_id}:{cell.name}", fn=step, mesh=mesh,
        args=(params_sds, opt_sds, batch_sds),
        in_specs=(pspec, opt_spec, bspec),
        out_specs=(pspec, opt_spec, {"loss": P()}),
        donate=(0, 1),
        meta=meta)


def _recsys_active(cfg: R.RecsysConfig) -> int:
    """Params actually touched per sample (few embedding rows, all MLPs)."""
    e = cfg.embed_dim
    emb_rows = (cfg.n_sparse if cfg.kind != "two_tower" else 2) * e
    total = cfg.param_count()
    table_rows = (sum(cfg.vocab_sizes) * e if cfg.kind != "two_tower"
                  else (cfg.user_vocab + cfg.item_vocab) * e)
    return total - table_rows + emb_rows


def _ctr_flops_per_sample(cfg: R.RecsysConfig) -> int:
    e = cfg.embed_dim
    F = cfg.n_sparse
    if cfg.kind == "dlrm":
        dims = (cfg.n_dense,) + cfg.bot_mlp
        bot = sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
        f = F + 1
        inter = 2 * f * f * e
        d_int = f * (f - 1) // 2 + cfg.bot_mlp[-1]
        dims = (d_int,) + cfg.top_mlp
        top = sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
        return bot + inter + top
    if cfg.kind == "deepfm":
        dims = (F * e,) + cfg.deep_mlp + (1,)
        deep = sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))
        return deep + 4 * F * e
    if cfg.kind == "autoint":
        d_l = [e] + [cfg.n_heads * cfg.d_attn] * cfg.n_attn_layers
        fl = 0
        for i in range(cfg.n_attn_layers):
            fl += 2 * F * d_l[i] * (4 * d_l[i + 1]) + 2 * F * F * d_l[i + 1] * 2
        return fl + 2 * F * d_l[-1]
    dims = (e,) + cfg.tower_mlp
    return 2 * sum(2 * dims[i] * dims[i + 1] for i in range(len(dims) - 1))


def _two_tower_bundle(spec_, cell, mesh, cfg, params_sds, pspec, meta):
    dp = _dp(mesh)
    all_axes = tuple(mesh.axis_names)
    B = cell.dims["batch"]
    per_sample = _ctr_flops_per_sample(cfg)

    if cell.kind == "train":
        batch_sds = {"user_ids": sds((B,), jnp.int32),
                     "item_ids": sds((B,), jnp.int32),
                     "item_logq": sds((B,), jnp.float32)}
        bspec = {"user_ids": P(dp), "item_ids": P(dp), "item_logq": P(dp)}
        logit_sharding = NamedSharding(mesh, P(dp, "model"))
        loss = partial(_tt_loss, cfg=cfg, logit_sharding=logit_sharding)
        meta["model_flops"] = 3 * (per_sample * B + 2 * B * B * cfg.tower_mlp[-1])
        return _train_bundle(f"{spec_.arch_id}:{cell.name}", mesh, params_sds,
                             pspec, batch_sds, bspec, loss, spec_.optimizer, meta)

    if cell.kind == "serve":
        batch_sds = {"user_ids": sds((B,), jnp.int32),
                     "item_ids": sds((B,), jnp.int32)}
        bspec = {"user_ids": P(dp), "item_ids": P(dp)}
        meta["model_flops"] = per_sample * B

        def fn(params, batch):
            u = R.user_embedding(params, batch["user_ids"])
            v = R.item_embedding(params, batch["item_ids"])
            return (u * v).sum(-1)

        return StepBundle(
            name=f"{spec_.arch_id}:{cell.name}", fn=fn, mesh=mesh,
            args=(params_sds, batch_sds),
            in_specs=(pspec, bspec), out_specs=P(dp), meta=meta)

    # retrieval_cand: THE paper cell — user query vs precomputed item index.
    # dims overrides (hillclimb variants): index_dim = m after PCA pruning,
    # int8 = quantised index (+ per-dim scale folded into the query).
    C = round_up(cell.dims["n_candidates"], 512)
    d_full = cfg.tower_mlp[-1]
    m = int(cell.dims.get("index_dim", d_full))
    int8 = bool(cell.dims.get("int8", 0))
    index_sds = sds((C, m), jnp.int8 if int8 else jnp.float32)
    meta["model_flops"] = per_sample // 2 + 2 * C * m + 2 * d_full * m
    meta["n_candidates"] = C
    meta["index_dim"] = m
    meta["index_int8"] = int8
    meta["analytic_bytes"] = int(C * m * (1 if int8 else 4)
                                 + 2 * cfg.param_count() // 1000)

    hier = bool(cell.dims.get("hier_merge", 0))
    delta_rows = int(cell.dims.get("delta_rows", 0))
    if delta_rows:
        delta_rows = round_up(delta_rows, 128)
        meta["delta_rows"] = delta_rows
        meta["model_flops"] += 2 * delta_rows * m
        meta["analytic_bytes"] += delta_rows * m * (1 if int8 else 4)
    if m == d_full and not int8 and not delta_rows:
        def fn(params, item_index, user_ids):
            u = R.user_embedding(params, user_ids)           # (1, d)
            return _sharded_index_topk(item_index, u, TOPK_SERVE, mesh,
                                       hierarchical=hier)

        args = (params_sds, index_sds, sds((1,), jnp.int32))
        in_specs = (pspec, P(all_axes, None), P())
    elif delta_rows:
        # live segmented serving (SegmentedIndex at pod scale): sharded
        # immutable base + one replicated open delta at fixed padded
        # capacity with its OWN scale and a traced live-row count — the
        # query projects once unfolded, folds each segment's scale
        # separately, and the two candidate lists merge with global id
        # offsets (delta ids start at C) via the same merge_segment_topk
        # the serving index uses
        W_sds = sds((d_full, m), jnp.float32)
        scale_sds = sds((m,), jnp.float32)
        delta_sds = sds((delta_rows, m), jnp.int8 if int8 else jnp.float32)

        def fn(params, item_index, W_m, scale, delta_seg, delta_scale,
               delta_n, user_ids):
            from repro.core.index import (_delta_topk, merge_segment_topk,
                                          project_queries)
            u = R.user_embedding(params, user_ids)           # (1, d)
            q = project_queries(u, W_m)                      # unfolded
            base = _sharded_index_topk(item_index, q * scale[None, :],
                                       TOPK_SERVE, mesh, hierarchical=hier)
            delta = _delta_topk(delta_seg, delta_scale, q, delta_n,
                                jnp.int32(C), TOPK_SERVE)
            return merge_segment_topk([base, delta], TOPK_SERVE)

        args = (params_sds, index_sds, W_sds, scale_sds, delta_sds,
                sds((m,), jnp.float32), sds((), jnp.int32),
                sds((1,), jnp.int32))
        in_specs = (pspec, P(all_axes, None), P(), P(), P(None, None),
                    P(), P(), P())
    else:
        # PCA-pruned (optionally int8) index: q̂ = (q @ W_m) ⊙ scale — the
        # same fused projection+fold the serving hot path traces
        # (repro.core.index.project_queries, one jit with the scan)
        W_sds = sds((d_full, m), jnp.float32)
        scale_sds = sds((m,), jnp.float32)

        def fn(params, item_index, W_m, scale, user_ids):
            from repro.core.index import project_queries
            u = R.user_embedding(params, user_ids)           # (1, d)
            q = project_queries(u, W_m, scale=scale)         # O(dm) transform
            return _sharded_index_topk(item_index, q, TOPK_SERVE, mesh,
                                       hierarchical=hier)

        args = (params_sds, index_sds, W_sds, scale_sds, sds((1,), jnp.int32))
        in_specs = (pspec, P(all_axes, None), P(), P(), P())

    return StepBundle(
        name=f"{spec_.arch_id}:{cell.name}", fn=fn, mesh=mesh,
        args=args, in_specs=in_specs,
        out_specs=(P(), P()),
        meta=meta)


def _tt_loss(params, batch, cfg, logit_sharding):
    return R.two_tower_loss(params, batch, cfg, logit_sharding=logit_sharding)


# ---------------------------------------------------------------------------
# BiEncoder family (the paper's own model — examples/launcher, not a cell)
# ---------------------------------------------------------------------------


def biencoder_bundle(spec_: ArchSpec, cell: ShapeCell, mesh: Mesh) -> StepBundle:
    cfg: BE.BiEncoderConfig = spec_.cfg
    rules = SH.biencoder_rules()
    dp = _dp(mesh)
    S, B = cell.dims["seq_len"], cell.dims["global_batch"]
    params_sds = jax.eval_shape(lambda: BE.init_biencoder(jax.random.PRNGKey(0), cfg))
    pspec = SH.param_specs(params_sds, mesh, rules)
    P = cfg.param_count()
    tok = 2 * B * S
    mem = (3 * P * 2 + 11 * P * 4 + cfg.n_layers * tok * cfg.d_model * 2 * 20
           if cell.kind == "train" else
           P * 2 + cfg.n_layers * B * S * cfg.d_model * 2 * 6)
    meta = dict(family="biencoder", arch=spec_.arch_id, shape=cell.name,
                params=P, active_params=P, dims=dict(cell.dims),
                analytic_bytes=int(mem))

    if cell.kind == "train":
        batch_sds = {k: sds((B, S), jnp.int32)
                     for k in ("q_tokens", "q_mask", "d_tokens", "d_mask")}
        bspec = {k: P(dp, None) for k in batch_sds}
        loss = partial(_be_loss, cfg=cfg)
        meta["model_flops"] = 6 * cfg.param_count() * 2 * B * S
        return _train_bundle(f"{spec_.arch_id}:{cell.name}", mesh, params_sds,
                             pspec, batch_sds, bspec, loss, spec_.optimizer, meta)

    def fn(params, tokens, mask):
        return BE.encode(params, tokens, mask, cfg)

    meta["model_flops"] = 2 * cfg.param_count() * B * S
    return StepBundle(
        name=f"{spec_.arch_id}:{cell.name}", fn=fn, mesh=mesh,
        args=(params_sds, sds((B, S), jnp.int32), sds((B, S), jnp.int32)),
        in_specs=(pspec, P(dp, None), P(dp, None)),
        out_specs=P(dp, None), meta=meta)


def _be_loss(params, batch, cfg):
    return BE.contrastive_loss(params, batch, cfg)


# ---------------------------------------------------------------------------
# Sharded top-k helpers (retrieval serving across the whole mesh)
# ---------------------------------------------------------------------------


def _sharded_index_topk(index: jax.Array, q: jax.Array, k: int, mesh: Mesh,
                        hierarchical: bool = False):
    """Exact top-k of q @ index^T with index rows sharded over every axis.

    ``hierarchical=True`` merges in two stages (within 'model', then across
    the dp axes): per-device gather volume drops from |devices|·k to
    (|model| + |dp|)·k — 8x on a 16x16 pod. Exactness and tie-breaks are
    preserved (see ``repro.core.index._staged_topk_merge``, which is the
    same machinery ``ShardedDenseIndex.search(merge=...)`` serves through).
    """
    from repro.core.index import _scan_topk, _staged_topk_merge
    axes = tuple(mesh.axis_names)
    ndev = int(np.prod(mesh.devices.shape))
    rows_per = index.shape[0] // ndev
    if hierarchical and len(axes) > 1:
        inner = ("model",) if "model" in axes else (axes[-1],)
        stages = (inner, tuple(a for a in axes if a not in inner))
    else:
        stages = (axes,)

    def shard_fn(idx_local, q_rep):
        pos = compat.axis_index(axes)
        s, ids = _scan_topk(idx_local, q_rep, k, vma_axes=axes)
        ids = jnp.where(ids >= 0, ids + pos * rows_per, -1)
        return _staged_topk_merge(s, ids, k, stages)

    # the merged top-k is replicated by construction (all_gather + same
    # reduction everywhere) but that can't be statically proven: check_vma off
    return compat.shard_map(shard_fn, mesh=mesh,
                            in_specs=(P(axes, None), P(None, None)),
                            out_specs=(P(None, None), P(None, None)),
                            check_vma=False)(index, q)


def _sharded_topk_1d(scores: jax.Array, k: int, mesh: Mesh):
    """Top-k over a 1-D score vector sharded over every mesh axis."""
    from repro.core.index import _topk_merge
    axes = tuple(mesh.axis_names)
    ndev = int(np.prod(mesh.devices.shape))
    rows_per = scores.shape[0] // ndev

    def shard_fn(s_local):
        pos = jax.lax.axis_index(axes)
        kk = min(k, s_local.shape[0])
        s, ids = jax.lax.top_k(s_local, kk)
        ids = ids + pos * rows_per
        s_all = jax.lax.all_gather(s[None], axes, axis=1, tiled=True)
        i_all = jax.lax.all_gather(ids[None], axes, axis=1, tiled=True)
        ms, mi = _topk_merge(s_all, i_all, k)
        return ms[0], mi[0]

    return compat.shard_map(shard_fn, mesh=mesh, in_specs=(P(axes),),
                            out_specs=(P(None), P(None)),
                            check_vma=False)(scores)


BUNDLE_BUILDERS = {
    "lm": lm_bundle,
    "gnn": gnn_bundle,
    "recsys": recsys_bundle,
    "biencoder": biencoder_bundle,
}
