"""Mixtral 8x7B — MoE decoder, 8 experts top-2, GQA 32/8, SWA 4096.

[arXiv:2401.04088; hf:mistralai/Mixtral-8x7B-v0.1]
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

CFG = TransformerConfig(
    name="mixtral-8x7b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=32000, n_experts=8, top_k=2, sliding_window=4096,
    rope_theta=1e6, tie_embeddings=False, norm="rmsnorm", act="silu",
    param_dtype="float32", compute_dtype="bfloat16", remat=True,
    moe_group_size=512, microbatch=8,
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="mixtral-8x7b", family="lm", cfg=CFG,
        shapes=lm_shapes(sub_quadratic=True),   # SWA rolling cache => 500k OK
        source="arXiv:2401.04088; hf",
        optimizer="adamw",
        notes="8 experts < 16 model shards: rules fall back to TP-inside-expert.")


def smoke_cfg() -> TransformerConfig:
    return TransformerConfig(
        name="mixtral-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=512, n_experts=4, top_k=2, sliding_window=32,
        rope_theta=1e6, compute_dtype="float32", remat=False, moe_group_size=64)
