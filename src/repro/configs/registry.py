"""Architecture registry: ``--arch`` lookup, input specs, step bundles."""
from __future__ import annotations

from typing import Iterator

from jax.sharding import Mesh

from repro.configs import (
    arctic_480b,
    autoint,
    biencoder_msmarco,
    deepfm,
    dlrm_mlperf,
    graphcast,
    mixtral_8x7b,
    phi3_medium_14b,
    qwen2_1_5b,
    smollm_135m,
    two_tower_retrieval,
)
from repro.configs.base import ArchSpec, ShapeCell
from repro.configs.steps import BUNDLE_BUILDERS, StepBundle

_MODULES = {
    "mixtral-8x7b": mixtral_8x7b,
    "arctic-480b": arctic_480b,
    "qwen2-1.5b": qwen2_1_5b,
    "phi3-medium-14b": phi3_medium_14b,
    "smollm-135m": smollm_135m,
    "graphcast": graphcast,
    "dlrm-mlperf": dlrm_mlperf,
    "autoint": autoint,
    "deepfm": deepfm,
    "two-tower-retrieval": two_tower_retrieval,
    # the paper's own encoder (examples/launcher; not a graded cell)
    "biencoder-msmarco": biencoder_msmarco,
}

ARCHS = tuple(k for k in _MODULES if k != "biencoder-msmarco")


def list_archs(include_extra: bool = False) -> tuple[str, ...]:
    return tuple(_MODULES) if include_extra else ARCHS


def get_arch(arch_id: str) -> ArchSpec:
    try:
        return _MODULES[arch_id].spec()
    except KeyError:
        raise KeyError(f"unknown arch {arch_id!r}; "
                       f"known: {sorted(_MODULES)}") from None


def get_smoke_cfg(arch_id: str):
    return _MODULES[arch_id].smoke_cfg()


def cells(include_skipped: bool = True) -> Iterator[tuple[ArchSpec, ShapeCell]]:
    """Every (arch × shape) dry-run cell, in registry order."""
    for arch_id in ARCHS:
        spec = get_arch(arch_id)
        for cell in spec.shapes:
            if cell.skip_reason and not include_skipped:
                continue
            yield spec, cell


def make_step_bundle(arch_id: str, shape: str, mesh: Mesh) -> StepBundle:
    spec = get_arch(arch_id)
    cell = spec.cell(shape)
    if cell.skip_reason:
        raise ValueError(f"{arch_id}:{shape} is skipped: {cell.skip_reason}")
    return BUNDLE_BUILDERS[spec.family](spec, cell, mesh)


def input_specs(arch_id: str, shape: str, mesh: Mesh) -> tuple:
    """ShapeDtypeStruct stand-ins for every input of the cell's step fn."""
    return make_step_bundle(arch_id, shape, mesh).args
