"""DeepFM — FM + deep MLP with shared embeddings. [arXiv:1703.04247]

39 sparse fields, embed 10, deep MLP 400-400-400, FM interaction.
"""
from repro.configs.autoint import _BUCKETISED_DENSE, _CRITEO_KAGGLE_CAT
from repro.configs.base import RECSYS_SHAPES, ArchSpec, round_up
from repro.models.recsys import RecsysConfig

VOCABS = tuple(round_up(v, 512) for v in _BUCKETISED_DENSE + _CRITEO_KAGGLE_CAT)

CFG = RecsysConfig(
    name="deepfm", kind="deepfm",
    vocab_sizes=VOCABS, embed_dim=10,
    deep_mlp=(400, 400, 400),
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="deepfm", family="recsys", cfg=CFG,
        shapes=RECSYS_SHAPES,
        source="arXiv:1703.04247",
        optimizer="rowwise",
        notes="embed_dim 10 doesn't tile the MXU; lookups stay "
              "gather-bound (recorded in roofline).")


def smoke_cfg() -> RecsysConfig:
    return RecsysConfig(
        name="deepfm-smoke", kind="deepfm",
        vocab_sizes=(512, 256, 128, 64, 64), embed_dim=10,
        deep_mlp=(32, 32))
