"""Snowflake Arctic — dense-MoE hybrid: 128 experts top-2 + parallel dense
residual FFN. [hf:Snowflake/snowflake-arctic-base]

Trains with Adafactor + bf16 params: AdamW fp32 state for ~480B params
(7.7 TB) exceeds a 256-chip v5e pod's 4 TB HBM; factored states fit
(see EXPERIMENTS.md §Dry-run memory table).
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

CFG = TransformerConfig(
    name="arctic-480b",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=4864,
    vocab=32000, n_experts=128, top_k=2, dense_residual=True,
    residual_d_ff=4864, sliding_window=None, rope_theta=1e6,
    tie_embeddings=False, norm="rmsnorm", act="silu",
    param_dtype="bfloat16", compute_dtype="bfloat16", remat=True,
    moe_group_size=512, microbatch=16, grad_accum_dtype="bfloat16",
    capacity_factor=1.0,  # §Perf: -7% collective vs 1.25, zero quality loss budgeted
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="arctic-480b", family="lm", cfg=CFG,
        shapes=lm_shapes(sub_quadratic=False),
        source="hf:Snowflake/snowflake-arctic-base",
        optimizer="adafactor",
        notes="128 experts = 8/chip on the 16-wide model axis (EP); "
              "dense residual FFN runs TP in parallel.")


def smoke_cfg() -> TransformerConfig:
    return TransformerConfig(
        name="arctic-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=512, n_experts=8, top_k=2, dense_residual=True,
        residual_d_ff=96, compute_dtype="float32", remat=False,
        moe_group_size=64)
