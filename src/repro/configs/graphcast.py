"""GraphCast — encoder-processor-decoder mesh GNN, 16 MP layers, d=512,
sum aggregation, 227 output variables. [arXiv:2212.12794]

The architecture (layer structure, width, aggregator) is GraphCast's; the
four assigned shapes exercise it across graph-size regimes (full-batch
small, sampled minibatch, full-batch 2.4M-node, batched molecules). Input
feature width comes from each shape; output stays n_vars=227 (regression),
matching the arch definition — see DESIGN.md §5.
"""
from repro.configs.base import GNN_SHAPES, ArchSpec
from repro.models.gnn import GNNConfig

N_VARS = 227

CFG = GNNConfig(
    name="graphcast",
    n_layers=16, d_hidden=512, d_in=N_VARS, d_edge_in=4, d_out=N_VARS,
    aggregator="sum", mesh_refinement=6,
    param_dtype="float32", compute_dtype="bfloat16", remat=True,
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="graphcast", family="gnn", cfg=CFG,
        shapes=GNN_SHAPES,
        source="arXiv:2212.12794",
        optimizer="adamw",
        notes="d_in is overridden per shape (1433/602/100/32); d_out=227.")


def smoke_cfg() -> GNNConfig:
    return GNNConfig(name="graphcast-smoke", n_layers=3, d_hidden=32, d_in=16,
                     d_edge_in=4, d_out=8, aggregator="sum",
                     compute_dtype="float32", remat=False)
