"""SmolLM-135M — llama-architecture small model, GQA 9/3.

[hf:HuggingFaceTB/SmolLM-135M]
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

CFG = TransformerConfig(
    name="smollm-135m",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536,
    vocab=49152, rope_theta=10000.0, tie_embeddings=True,
    norm="rmsnorm", act="silu",
    param_dtype="float32", compute_dtype="bfloat16", remat=True,
    microbatch=4,
    parallelism="dp_only",  # §Perf cell 4: 21x step vs TP16 (compute-bound at ~31% peak)
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="smollm-135m", family="lm", cfg=CFG,
        shapes=lm_shapes(sub_quadratic=False),
        source="hf:HuggingFaceTB/SmolLM-135M",
        optimizer="adamw",
        notes="9 heads / 576 head-proj (=36·16) — head dim shards only via "
              "the fused projection; vocab and d_ff shard cleanly.")


def smoke_cfg() -> TransformerConfig:
    return TransformerConfig(
        name="smollm-smoke", n_layers=3, d_model=48, n_heads=3, n_kv_heads=3,
        d_ff=128, vocab=512, tie_embeddings=True,
        compute_dtype="float32", remat=False)
