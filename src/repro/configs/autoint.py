"""AutoInt — self-attentive feature interaction. [arXiv:1810.11921]

39 sparse fields (Criteo: 13 bucketised dense + 26 categorical), embed 16,
3 attention layers, 2 heads, d_attn 32.
"""
from repro.configs.base import RECSYS_SHAPES, ArchSpec, round_up
from repro.models.recsys import RecsysConfig

_CRITEO_KAGGLE_CAT = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18, 15,
    286181, 105, 142572,
)
_BUCKETISED_DENSE = (128,) * 13

VOCABS = tuple(round_up(v, 512) for v in _BUCKETISED_DENSE + _CRITEO_KAGGLE_CAT)

CFG = RecsysConfig(
    name="autoint", kind="autoint",
    vocab_sizes=VOCABS, embed_dim=16,
    n_attn_layers=3, n_heads=2, d_attn=32,
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="autoint", family="recsys", cfg=CFG,
        shapes=RECSYS_SHAPES,
        source="arXiv:1810.11921",
        optimizer="rowwise")


def smoke_cfg() -> RecsysConfig:
    return RecsysConfig(
        name="autoint-smoke", kind="autoint",
        vocab_sizes=(512, 256, 128, 64, 64, 64), embed_dim=8,
        n_attn_layers=2, n_heads=2, d_attn=8)
