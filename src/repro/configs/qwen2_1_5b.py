"""Qwen2-1.5B — dense decoder, GQA 12/2, QKV bias, tied embeddings.

[arXiv:2407.10671; hf:Qwen/Qwen2-1.5B]
"""
from repro.configs.base import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

CFG = TransformerConfig(
    name="qwen2-1.5b",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960,
    vocab=151936, qkv_bias=True, rope_theta=1e6, tie_embeddings=True,
    norm="rmsnorm", act="silu",
    param_dtype="float32", compute_dtype="bfloat16", remat=True,
    microbatch=4,
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="qwen2-1.5b", family="lm", cfg=CFG,
        shapes=lm_shapes(sub_quadratic=False),
        source="arXiv:2407.10671; hf",
        optimizer="adamw",
        notes="12 heads don't divide the 16-wide model axis; fused-QKV dim "
              "(1536) does — rules shard the projection, not the head dim.")


def smoke_cfg() -> TransformerConfig:
    return TransformerConfig(
        name="qwen2-smoke", n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=512, qkv_bias=True, tie_embeddings=True,
        compute_dtype="float32", remat=False)
