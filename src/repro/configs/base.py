"""ArchSpec: one architecture + its assigned input-shape set."""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture × input shape) dry-run cell."""

    name: str
    kind: str                   # train | prefill | decode | decode_long |
                                # serve | retrieval | train_sampled
    dims: dict[str, int]
    skip_reason: str | None = None


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                 # lm | gnn | recsys | biencoder
    cfg: Any
    shapes: tuple[ShapeCell, ...]
    source: str = ""            # provenance: paper/hf reference
    optimizer: str = "adamw"    # adamw | adafactor
    notes: str = ""

    def cell(self, name: str) -> ShapeCell:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name}")


# -- canonical shape sets ----------------------------------------------------

LM_SHAPES = (
    ShapeCell("train_4k", "train", dict(seq_len=4096, global_batch=256)),
    ShapeCell("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
    ShapeCell("decode_32k", "decode", dict(seq_len=32768, global_batch=128)),
    ShapeCell("long_500k", "decode_long", dict(seq_len=524288, global_batch=1)),
)


def lm_shapes(sub_quadratic: bool) -> tuple[ShapeCell, ...]:
    """long_500k runs only for sub-quadratic-attention archs (SWA etc.)."""
    out = []
    for s in LM_SHAPES:
        if s.name == "long_500k" and not sub_quadratic:
            out.append(dataclasses.replace(
                s, skip_reason="pure full-attention arch: 500k-token decode "
                "requires sub-quadratic attention (see DESIGN.md §5)"))
        else:
            out.append(s)
    return tuple(out)


GNN_SHAPES = (
    ShapeCell("full_graph_sm", "train",
              dict(n_nodes=2708, n_edges=10556, d_feat=1433)),
    ShapeCell("minibatch_lg", "train_sampled",
              dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                   fanout0=15, fanout1=10, d_feat=602)),
    ShapeCell("ogb_products", "train",
              dict(n_nodes=2449029, n_edges=61859140, d_feat=100)),
    ShapeCell("molecule", "train",
              dict(n_nodes=30, n_edges=64, batch=128, d_feat=32)),
)

RECSYS_SHAPES = (
    ShapeCell("train_batch", "train", dict(batch=65536)),
    ShapeCell("serve_p99", "serve", dict(batch=512)),
    ShapeCell("serve_bulk", "serve", dict(batch=262144)),
    ShapeCell("retrieval_cand", "retrieval", dict(batch=1, n_candidates=1_000_000)),
)


def round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult
