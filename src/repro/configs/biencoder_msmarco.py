"""The paper's own encoder family: a BERT-base-scale bi-encoder (~110M).

TAS-B / Contriever / ANCE are all 6-12-layer BERT-family bi-encoders with
d=768 embeddings; this config is the trainable stand-in used by the
end-to-end example (train -> encode -> PCA-prune -> serve). Not one of the
10 graded dry-run architectures, but it IS wired into the registry so the
same launcher drives it.
"""
from repro.configs.base import ArchSpec, ShapeCell
from repro.models.biencoder import BiEncoderConfig

CFG = BiEncoderConfig(
    name="biencoder-msmarco",
    n_layers=12, d_model=768, n_heads=12, d_ff=3072, vocab=30522,
    embed_dim=768, max_len=256, pooling="mean", temperature=0.05,
)

SHAPES = (
    ShapeCell("train_pairs", "train", dict(seq_len=128, global_batch=4096)),
    ShapeCell("encode_corpus", "serve", dict(seq_len=256, global_batch=8192)),
)


def spec() -> ArchSpec:
    return ArchSpec(
        arch_id="biencoder-msmarco", family="biencoder", cfg=CFG,
        shapes=SHAPES,
        source="paper (ANCE/TAS-B/Contriever stand-in)",
        optimizer="adamw")


def smoke_cfg() -> BiEncoderConfig:
    return BiEncoderConfig(
        name="biencoder-smoke", n_layers=2, d_model=64, n_heads=4, d_ff=128,
        vocab=512, embed_dim=64, max_len=32, compute_dtype="float32",
        remat=False)
