"""Checkpointing for multi-pod training: async, atomic, elastic.

Design (mirrors Orbax semantics without the dependency):

  * **Layout** — one ``.npy`` blob per pytree leaf under
    ``<dir>/step_<N>.tmp/``; a ``manifest.json`` stores the flattened tree
    paths, shapes, dtypes and *logical* PartitionSpecs. The directory is
    atomically renamed to ``step_<N>/`` only after every blob and the
    manifest are fsynced — a crashed save can never be mistaken for a valid
    checkpoint (restore scans for complete dirs only).
  * **Async** — ``save()`` snapshots device arrays to host (blocking only on
    the device->host copy) and hands serialisation to a background thread;
    training resumes immediately. ``wait()`` joins outstanding saves.
  * **Elastic restore** — specs are stored logically ('dp'/'tp'/'ep'), so a
    restarted job *re-resolves* them against whatever mesh it now has and
    ``jax.device_put``s each leaf with the new NamedSharding: the same
    checkpoint restores onto 8, 256, or 512 devices (tested in
    ``tests/test_checkpoint.py``).
  * **Retention** — keep the last ``keep_n`` checkpoints (GC after commit).

On a real multi-host pod each process writes only the shards it owns
(addressable_shards); in this single-process container that is the whole
array — the layout and commit protocol are identical.
"""
from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _flatten_with_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        keys = []
        for k in path:
            keys.append(str(getattr(k, "key", getattr(k, "idx", k))))
        out.append(("/".join(keys), leaf))
    return out


def _spec_to_json(spec) -> list:
    if spec is None:
        return []
    out = []
    for part in spec:
        if part is None:
            out.append(None)
        elif isinstance(part, (tuple, list)):
            out.append(list(part))
        else:
            out.append(part)
    return out


def _spec_from_json(parts: list) -> P:
    return P(*[tuple(p) if isinstance(p, list) else p for p in parts])


def fsync_file(path: str) -> None:
    """fsync an already-written file so it survives a crash after rename."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_dir(path: str) -> None:
    """fsync a directory so the entries (incl. a rename) are durable.

    Best-effort on platforms where directories can't be opened/fsynced.
    """
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def write_json_fsync(path: str, obj: Any) -> None:
    """Write JSON and fsync the file before returning."""
    with open(path, "w") as f:
        json.dump(obj, f)
        f.flush()
        os.fsync(f.fileno())


def commit_dir(tmp: str, path: str) -> None:
    """Atomically publish ``tmp`` as ``path`` (rename + parent-dir fsync).

    Callers must have fsynced every file inside ``tmp`` first — the rename
    is the commit point, so anything not durable before it can be lost
    while the directory still looks committed.

    Replacing an existing committed ``path`` renames it aside first and
    deletes it only after the new directory is in place — at no instant is
    there no committed artifact on disk (a crash leaves either the old or
    the new one, never a bare ``.tmp``).
    """
    old = path + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(path):
        os.rename(path, old)
    os.rename(tmp, path)
    fsync_dir(os.path.dirname(os.path.abspath(path)))
    if os.path.exists(old):
        shutil.rmtree(old)


def save_pytree(path: str, tree: Any, spec_tree: Any | None = None,
                extra: dict | None = None) -> None:
    """Synchronous atomic save of a pytree (+ optional PartitionSpec tree)."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    specs = dict(_flatten_with_paths(spec_tree)) if spec_tree is not None else {}
    manifest = {"leaves": [], "extra": extra or {}}
    for name, leaf in leaves:
        arr = np.asarray(leaf)
        fname = name.replace("/", "__") + ".npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        fsync_file(fpath)
        manifest["leaves"].append({
            "path": name, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "spec": _spec_to_json(specs.get(name)),
        })
    write_json_fsync(os.path.join(tmp, "manifest.json"), manifest)
    commit_dir(tmp, path)


def load_pytree(path: str, target: Any, mesh: Mesh | None = None,
                spec_resolver: Callable[[str, tuple], P] | None = None) -> Any:
    """Restore into the structure of ``target`` (a pytree of arrays or
    ShapeDtypeStructs). With ``mesh``, each leaf is placed with the manifest
    spec (elastic: the spec re-resolves against *this* mesh's axis sizes —
    falling back to replication if a stored axis no longer divides)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    flat = _flatten_with_paths(target)
    treedef = jax.tree.structure(target)
    leaves = []
    for name, _tgt in flat:
        e = by_path[name]
        arr = np.load(os.path.join(path, e["file"]))
        if mesh is not None:
            spec = (spec_resolver(name, arr.shape) if spec_resolver
                    else _spec_from_json(e["spec"]))
            spec = _fit_spec(spec, arr.shape, mesh)
            leaves.append(jax.device_put(arr, NamedSharding(mesh, spec)))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, leaves)


def _fit_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop spec entries that no longer divide on this mesh (elastic)."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for d, part in enumerate(parts[:len(shape)]):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        size = 1
        ok = True
        for a in axes:
            if a not in mesh.shape:
                ok = False
                break
            size *= mesh.shape[a]
        out.append(part if ok and shape[d] % size == 0 else None)
    return P(*out)


@dataclasses.dataclass
class CheckpointManager:
    """Step-indexed checkpoint directory with async save + auto-resume."""

    directory: str
    keep_n: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._pending: list[threading.Thread] = []

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def save(self, step: int, tree: Any, spec_tree: Any | None = None,
             extra: dict | None = None, *, async_: bool = True) -> None:
        # snapshot to host while devices are quiescent
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        extra = dict(extra or {}, step=step)

        def work():
            save_pytree(self._step_dir(step), host_tree, spec_tree, extra)
            self._gc()

        if async_:
            t = threading.Thread(target=work, daemon=True)
            t.start()
            self._pending.append(t)
        else:
            work()

    def wait(self) -> None:
        for t in self._pending:
            t.join()
        self._pending.clear()

    def restore(self, target: Any, step: int | None = None,
                mesh: Mesh | None = None,
                spec_resolver: Callable | None = None) -> tuple[Any, int]:
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.directory}")
        tree = load_pytree(self._step_dir(step), target, mesh, spec_resolver)
        return tree, step

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep_n] if self.keep_n else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
