"""Fault-tolerance substrate: async sharded checkpoints, elastic restore."""
from repro.checkpoint.manager import (
    CheckpointManager, save_pytree, load_pytree,
    commit_dir, fsync_dir, fsync_file, write_json_fsync,
)

__all__ = ["CheckpointManager", "save_pytree", "load_pytree",
           "commit_dir", "fsync_dir", "fsync_file", "write_json_fsync"]
