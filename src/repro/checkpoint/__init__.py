"""Fault-tolerance substrate: async sharded checkpoints, elastic restore."""
from repro.checkpoint.manager import CheckpointManager, save_pytree, load_pytree

__all__ = ["CheckpointManager", "save_pytree", "load_pytree"]
