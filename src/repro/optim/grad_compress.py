"""Gradient compression for bandwidth-constrained (inter-pod / DCN) reduction.

int8 symmetric quantisation with a *shared* scale + error feedback:

  1. scalar psum of per-device |g|_max  → shared scale (tiny collective)
  2. quantise (g + residual) to int8, accumulate into int32 psum
  3. dequantise; residual_{t+1} = (g + residual_t) − dequant(q)

The big all-reduce moves 1/4 of the fp32 bytes (int8 payload accumulated in
int32 lanes ⇒ exact integer summation, no overflow for ≤ 2^23 devices).
Error feedback keeps the compression *unbiased over time* (Seide et al.;
1-bit Adam lineage) so convergence matches uncompressed SGD/Adam closely.

Use inside shard_map over the dp axes, e.g.::

    def step(params, batch, residual):
        grads = jax.grad(loss)(params, batch)          # local microbatch grads
        grads, residual = error_feedback_step(grads, residual, axis="data")
        ...
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.par import compat


def compress_int8(g: jax.Array, scale: jax.Array) -> jax.Array:
    q = jnp.clip(jnp.round(g / jnp.maximum(scale, 1e-20)), -127, 127)
    return q.astype(jnp.int8)


def decompress_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, axis) -> jax.Array:
    """All-reduce one tensor at int8 precision with a shared scale."""
    absmax = jax.lax.pmax(jnp.max(jnp.abs(g)), axis)
    scale = jnp.maximum(absmax, 1e-20) / 127.0
    q = compress_int8(g, scale)
    total = jax.lax.psum(q.astype(jnp.int32), axis)
    return decompress_int8(total, scale)  # sum semantics (not mean)


def error_feedback_step(grads: Any, residual: Any, axis) -> tuple[Any, Any]:
    """Compressed all-reduce of a grad pytree with error-feedback residuals.

    Returns (mean-reduced grads, new residuals). Residuals have param shape,
    fp32, and must persist across steps (they are part of training state).
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        absmax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis)
        scale = jnp.maximum(absmax, 1e-20) / 127.0
        q = compress_int8(gf, scale)
        sent = decompress_int8(q, scale)
        new_r = gf - sent
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        mean = decompress_int8(total, scale) / compat.axis_size(axis)
        return mean.astype(g.dtype), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
