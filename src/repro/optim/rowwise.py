"""Rowwise-AdaGrad embedding updates + AdamW for dense params.

The §Perf fix for DLRM-scale training. Two compounding problems with naive
autodiff + AdamW on 188M-row tables:

  1. the gather's VJP materialises a DENSE vocab×dim gradient (zeros init +
     scatter-add): O(vocab) HBM traffic for a batch touching <0.1 % of rows;
  2. AdamW reads+writes two fp32 moments per PARAMETER: ~386 GB/step of
     optimizer traffic.

The industry answer (FBGEMM/TorchRec/TPU embedding API), expressed in JAX:

  * embedding rows are gathered OUTSIDE ``value_and_grad``; the loss is
    differentiated w.r.t. the gathered rows, so table grads never exist in
    dense form — per-step grad traffic is O(batch · dim);
  * one AdaGrad accumulator scalar per ROW; updates scatter-add into the
    donated table buffer in place (duplicate ids combined exactly via a
    sort + segment-sum);
  * everything that isn't a table keeps AdamW.

See ``configs/steps.py::_recsys_rowwise_bundle`` for the step wiring.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RowwiseConfig:
    lr_scale: float = 10.0     # AdaGrad wants a larger lr than Adam
    eps: float = 1e-8


def rowwise_init_table(table: jax.Array) -> jax.Array:
    """Per-row accumulator."""
    return jnp.zeros((table.shape[0],), jnp.float32)


def combine_duplicate_rows(idx: jax.Array, g_rows: jax.Array
                           ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Exactly combine gradient rows with equal ids.

    idx: (n,) int32 (may repeat); g_rows: (n, E).
    Returns (ids (n,), g_combined (n, E), valid (n,)) where only ``valid``
    entries carry a (unique) id + summed gradient; the rest are padding.
    """
    order = jnp.argsort(idx)
    sid = idx[order]
    g = g_rows[order]
    first = jnp.concatenate([jnp.ones((1,), bool), sid[1:] != sid[:-1]])
    seg = jnp.cumsum(first) - 1
    n = idx.shape[0]
    g_comb = jax.ops.segment_sum(g, seg, num_segments=n)
    ids = jax.ops.segment_max(sid, seg, num_segments=n)
    valid = jnp.arange(n) < seg[-1] + 1
    return jnp.where(valid, ids, 0), g_comb, valid


def rowwise_adagrad_update(table: jax.Array, acc: jax.Array, idx: jax.Array,
                           g_rows: jax.Array, lr: jax.Array,
                           cfg: RowwiseConfig = RowwiseConfig()
                           ) -> tuple[jax.Array, jax.Array]:
    """Sparse rowwise-AdaGrad: touch only the rows in ``idx``.

    table: (V, E) (donated => in-place scatter); acc: (V,) rowwise state;
    idx: (n,) touched rows; g_rows: (n, E) grads w.r.t. gathered rows.
    """
    ids, g, valid = combine_duplicate_rows(idx, g_rows.astype(jnp.float32))
    row_g2 = (g ** 2).mean(axis=-1) * valid
    acc_new_rows = acc[ids] + row_g2
    acc = acc.at[ids].add(row_g2)
    scale = (lr * cfg.lr_scale) * jax.lax.rsqrt(acc_new_rows + cfg.eps)
    delta = (scale[:, None] * g) * valid[:, None]
    return table.at[ids].add(-delta.astype(table.dtype)), acc
