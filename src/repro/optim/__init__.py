"""Optimisation substrate: AdamW (+ZeRO-1), Adafactor, schedules, grad compression."""
from repro.optim.adamw import adamw_init, adamw_update, AdamWConfig
from repro.optim.adafactor import adafactor_init, adafactor_update, AdafactorConfig
from repro.optim.schedule import warmup_cosine, constant_lr
from repro.optim.grad_compress import (compress_int8, decompress_int8,
                                       compressed_psum, error_feedback_step)

__all__ = ["adamw_init", "adamw_update", "AdamWConfig",
           "adafactor_init", "adafactor_update", "AdafactorConfig",
           "warmup_cosine", "constant_lr",
           "compress_int8", "decompress_int8", "compressed_psum",
           "error_feedback_step"]
