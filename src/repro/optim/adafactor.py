"""Adafactor (factored second moments) — the 480B-scale optimizer.

For a (..., r, c) param the second moment is stored as row/col means
(O(r+c) memory instead of O(r·c)); vectors fall back to full moments.
No first moment (beta1=0 variant), matching the memory budget that makes
arctic-480b trainable on a 256-chip v5e pod (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdafactorConfig:
    decay: float = 0.8          # beta2 exponent schedule: 1 - step^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0


def _factored(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def adafactor_init(params: Any) -> dict:
    def init(p):
        if _factored(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

    return {"v": jax.tree.map(init, params,
                              is_leaf=lambda x: isinstance(x, jax.Array)),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads: Any, state: dict, params: Any, lr: jax.Array,
                     cfg: AdafactorConfig = AdafactorConfig()) -> tuple[Any, dict]:
    step = state["step"] + 1
    beta2 = 1.0 - jnp.power(step.astype(jnp.float32), -cfg.decay)

    def upd(p, g, v):
        g = g.astype(jnp.float32)
        g2 = g * g + cfg.eps
        if _factored(p):
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
            # denom broadcasts against vr[..., None]: add the trailing axis
            denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), cfg.eps)[..., None]
            u = g * jax.lax.rsqrt(vr[..., None] / denom) * jax.lax.rsqrt(vc[..., None, :])
            new_v = {"vr": vr, "vc": vc}
        else:
            nv = beta2 * v["v"] + (1 - beta2) * g2
            u = g * jax.lax.rsqrt(nv)
            new_v = {"v": nv}
        # update clipping (RMS)
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms / cfg.clip_threshold)
        if cfg.weight_decay and p.ndim >= 2:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    return new_p, {"v": new_v, "step": step}
