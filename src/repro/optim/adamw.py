"""AdamW with optional ZeRO-1 sharding of optimizer moments.

Pure-pytree implementation. ZeRO-1 is expressed through sharding specs:
``zero1_specs`` extends each param's PartitionSpec by sharding the first
still-unsharded, evenly-divisible dimension over the data axes. Because the
update math is elementwise, XLA's SPMD partitioner materialises exactly the
ZeRO schedule: grads arrive param-sharded (already summed over dp by the
backward), moments live dp-sharded, the param delta is all-gathered — i.e.
optimizer state memory drops by |dp| with one extra all-gather per step.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.par.sharding import logical_to_physical


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads: Any, state: dict, params: Any, lr: jax.Array,
                 cfg: AdamWConfig = AdamWConfig()) -> tuple[Any, dict]:
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        mu_hat = mu / (1 - cfg.b1 ** step)
        nu_hat = nu / (1 - cfg.b2 ** step)
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (llama convention)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}


# ---------------------------------------------------------------------------
# ZeRO-1 sharding of the moments
# ---------------------------------------------------------------------------


def zero1_specs(param_spec_tree: Any, params_shape: Any, mesh: Mesh) -> Any:
    """Extend each param spec by sharding one more dim over the dp axes."""
    dp = logical_to_physical("dp", mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]

    def extend(spec: P, leaf) -> P:
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used: set = set()
        for part in parts:
            if part is None:
                continue
            used.update(part if isinstance(part, tuple) else (part,))
        if used.intersection(dp):   # dp axes already consumed (e.g. FSDP rows)
            return P(*parts)
        for d, cur in enumerate(parts):
            if cur is None and leaf.shape[d] % dp_size == 0 and leaf.shape[d] > 1:
                parts[d] = dp if len(dp) > 1 else dp[0]
                return P(*parts)
        return P(*parts)

    return jax.tree.map(extend, param_spec_tree, params_shape,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(param_spec_tree: Any, params_shape: Any, mesh: Mesh,
                    *, zero1: bool = True) -> dict:
    mom = (zero1_specs(param_spec_tree, params_shape, mesh)
           if zero1 else param_spec_tree)
    return {"mu": mom, "nu": mom, "step": P()}
