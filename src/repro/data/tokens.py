"""Deterministic synthetic token pipeline with background prefetch.

Determinism contract (fault tolerance): batch ``t`` is a pure function of
``(seed, t)`` — a restarted or re-scaled job replays the identical global
batch sequence from any step, so checkpoint-resume is bit-reproducible and
stragglers can be re-issued idempotently.

Prefetch: a daemon thread keeps a bounded queue of host batches ahead of
the training loop (straggler mitigation at the input layer — device steps
never wait on host-side generation).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np


def token_batch(seed: int, step: int, *, batch: int, seq_len: int,
                vocab: int) -> dict[str, np.ndarray]:
    """Markov-ish synthetic LM batch: tokens + next-token labels."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # mixture of a few 'topics' so the LM has learnable structure
    n_topics = 16
    topic = rng.integers(0, n_topics, size=(batch, 1))
    base = (topic * (vocab // n_topics)) % vocab
    drift = rng.integers(0, max(vocab // n_topics, 2), size=(batch, seq_len))
    tokens = ((base + drift) % vocab).astype(np.int32)
    labels = np.concatenate([tokens[:, 1:], np.full((batch, 1), -1, np.int32)],
                            axis=1)
    return {"tokens": tokens, "labels": labels}


def pair_batch(seed: int, step: int, *, batch: int, seq_len: int,
               vocab: int) -> dict[str, np.ndarray]:
    """Query/positive-document pairs for contrastive bi-encoder training.

    A pair shares a topic prefix; negatives are implicit (in-batch)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 7]))
    n_topics = 64
    topic = rng.integers(0, n_topics, size=(batch, 1))
    span = max(vocab // n_topics, 2)

    def draw(noise):
        drift = rng.integers(0, span, size=(batch, seq_len))
        flip = rng.random((batch, seq_len)) < noise
        rand = rng.integers(0, vocab, size=(batch, seq_len))
        toks = (topic * span + drift) % vocab
        return np.where(flip, rand, toks).astype(np.int32)

    q_tokens = draw(0.3)
    d_tokens = draw(0.1)
    ones = np.ones((batch, seq_len), np.int32)
    return {"q_tokens": q_tokens, "q_mask": ones,
            "d_tokens": d_tokens, "d_mask": ones}


class Prefetcher:
    """Bounded background prefetch over a step-indexed batch function."""

    def __init__(self, make_batch, start_step: int = 0, depth: int = 4):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        # drain so the worker unblocks
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
