"""Data substrate: synthetic corpora, token pipelines, graph sampling, recsys batches."""
