"""Synthetic retrieval corpora with controlled spectral structure.

Stand-in for MS MARCO + {ANCE, TAS-B, Contriever} embeddings (unavailable
offline). Generative model:

    latent semantics  z_i ~ N(0, diag(lambda)),  lambda_j ∝ j^(-alpha)
    doc embedding     d_i = normalize(F z_i + sigma * eps_i),  F orthonormal
    query             z_q = z_seed + tau * (lambda^(1/2) ⊙ xi);
                      q   = normalize(F z_q + sigma_q * eps_q)
    true relevance    s*(q, i) = <z_q, z_i> / (|z_q||z_i|)   (clean, latent)

Graded qrels are banded from s* — *not* from the noisy embeddings the
retriever sees — so the baseline is imperfect and dimension pruning has the
paper's real trade-off: trailing principal dimensions carry mostly the eps
noise, leading ones carry the latent semantics.

Encoder profiles set the spectral decay ``alpha`` (and noise floor), chosen
to match each bi-encoder's empirically observed pruning robustness:

  * ``ance``        — steep decay, low effective rank: the paper finds ANCE
                      statistically unchanged even at 75 % pruning.
  * ``tasb``        — intermediate: robust at 50 %, degrades at 75 %.
  * ``contriever``  — flat spectrum: most pruning-sensitive.

Five query sets per corpus mimic the paper's DL19 / DL20 / DL-HARD /
DEV-SMALL / COVID surface: DL-HARD uses higher query noise, DEV-SMALL sparse
binary qrels, COVID a domain-shifted factor basis (for RQ2/out-of-domain).
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

ENCODER_PROFILES: dict[str, dict] = {
    # alpha: latent spectrum decay; sigma: embedding noise floor.
    "ance":       dict(alpha=2.0, sigma=0.35),  # steep: low effective rank
    "tasb":       dict(alpha=0.78, sigma=0.42), # medium
    "contriever": dict(alpha=0.66, sigma=0.38), # flat: pruning-sensitive
}

QUERY_SET_PROFILES: dict[str, dict] = {
    "dl19":      dict(n_queries=43,  tau=0.45, graded=True,  pool_depth=64),
    "dl20":      dict(n_queries=54,  tau=0.45, graded=True,  pool_depth=64),
    "dlhard":    dict(n_queries=50,  tau=0.95, graded=True,  pool_depth=64),
    "devsmall":  dict(n_queries=200, tau=0.40, graded=False, pool_depth=2),
    "covid":     dict(n_queries=50,  tau=0.60, graded=True,  pool_depth=96,
                      domain_shift=0.5),
}


@dataclasses.dataclass
class RetrievalDataset:
    """A synthetic corpus + query sets + qrels, in embedding space."""

    docs: np.ndarray                              # (n, d) float32
    queries: dict[str, np.ndarray]                # set -> (nq, d)
    qrels: dict[str, dict[int, dict[int, int]]]   # set -> qid -> {docid: grade}
    encoder: str
    d: int


def _orthonormal(d: int, r: int, rng: np.random.Generator) -> np.ndarray:
    A = rng.standard_normal((d, r))
    Q, _ = np.linalg.qr(A)
    return Q[:, :r]


def _normalize(X: np.ndarray) -> np.ndarray:
    return X / np.linalg.norm(X, axis=1, keepdims=True).clip(1e-9)


def make_corpus(encoder: str = "tasb", *, n_docs: int = 20000, d: int = 768,
                seed: int = 0, domain_seed: int | None = None
                ) -> tuple[np.ndarray, dict]:
    """Generate a corpus embedding matrix + latent ground truth.

    The factor basis ``F`` and spectrum belong to the *encoder* (keyed by
    ``encoder`` + ``seed``); ``domain_seed`` varies the *corpus* drawn
    through that encoder — a different domain re-weights which latent
    directions carry mass (as a real domain shift does) but lives in the
    same embedding space, which is what makes the paper's out-of-domain
    PCA transfer (RQ2) meaningful.
    """
    prof = ENCODER_PROFILES[encoder]
    enc_rng = np.random.default_rng(seed * 1_000_003 + abs(hash(encoder)) % (2**31))
    lam = np.arange(1, d + 1, dtype=np.float64) ** (-prof["alpha"])
    lam /= lam.sum()
    F = _orthonormal(d, d, enc_rng)
    if domain_seed is None:
        data_rng = enc_rng
        lam_dom = lam
    else:
        data_rng = np.random.default_rng(domain_seed * 9_000_011 + 5)
        # domain tilt: re-weight latent directions by a smooth random factor
        tilt = np.exp(0.5 * data_rng.standard_normal(d))
        lam_dom = lam * tilt
        lam_dom /= lam_dom.sum()
    Z = data_rng.standard_normal((n_docs, d)) * np.sqrt(lam_dom)[None, :]
    noise = prof["sigma"] * data_rng.standard_normal((n_docs, d)) / np.sqrt(d)
    D = _normalize(Z @ F.T + noise)
    aux = dict(F=F, lam=lam_dom, Z=Z, sigma=prof["sigma"], seed=seed,
               encoder=encoder)
    return D.astype(np.float32), aux


def _make_query_set(aux: Mapping, name: str, *, seed: int,
                    ) -> tuple[np.ndarray, dict[int, dict[int, int]]]:
    prof = QUERY_SET_PROFILES[name]
    rng = np.random.default_rng(seed * 7_000_003 + abs(hash(name)) % (2**31))
    F, lam, Z, sigma = aux["F"], aux["lam"], aux["Z"], aux["sigma"]
    n, d = Z.shape
    nq = prof["n_queries"]

    seed_docs = rng.choice(n, size=nq, replace=False)
    dz = prof["tau"] * rng.standard_normal((nq, d)) * np.sqrt(lam)[None, :]
    Zq = Z[seed_docs] + dz

    Fq = F
    if prof.get("domain_shift"):
        # COVID-like: query basis partially rotated off the corpus basis.
        shift = prof["domain_shift"]
        G = _orthonormal(d, d, rng)
        Fq = (1 - shift) * F + shift * G
        Fq, _ = np.linalg.qr(Fq)

    q_noise = sigma * rng.standard_normal((nq, d)) / np.sqrt(d)
    Q = _normalize(Zq @ Fq.T + q_noise)

    # True relevance from clean latent similarity (cosine).
    Zn = Z / np.linalg.norm(Z, axis=1, keepdims=True).clip(1e-12)
    Zqn = Zq / np.linalg.norm(Zq, axis=1, keepdims=True).clip(1e-12)
    s_true = Zqn @ Zn.T                               # (nq, n)

    qrels: dict[int, dict[int, int]] = {}
    depth = prof["pool_depth"]
    for qi in range(nq):
        order = np.argsort(-s_true[qi])[:depth]
        grades: dict[int, int] = {}
        if prof["graded"]:
            b1, b2 = max(1, depth // 16), max(2, depth // 4)
            for rank, doc in enumerate(order):
                if rank < b1:
                    grades[int(doc)] = 3
                elif rank < b2:
                    grades[int(doc)] = 2
                elif rng.random() < 0.5:
                    grades[int(doc)] = 1
                else:
                    grades[int(doc)] = 0
        else:
            for doc in order[:depth]:
                grades[int(doc)] = 1
        qrels[qi] = grades
    return Q.astype(np.float32), qrels


def make_dataset(encoder: str = "tasb", *, n_docs: int = 20000, d: int = 768,
                 seed: int = 0,
                 query_sets: tuple[str, ...] = ("dl19", "dl20", "dlhard",
                                                "devsmall", "covid"),
                 ) -> RetrievalDataset:
    D, aux = make_corpus(encoder, n_docs=n_docs, d=d, seed=seed)
    queries, qrels = {}, {}
    for name in query_sets:
        Q, R = _make_query_set(aux, name, seed=seed)
        queries[name] = Q
        qrels[name] = R
    return RetrievalDataset(docs=D, queries=queries, qrels=qrels,
                            encoder=encoder, d=d)


def make_ood_corpus(base_encoder: str, *, n_docs: int = 20000, d: int = 768,
                    seed: int = 0, domain_seed: int = 1234) -> np.ndarray:
    """A different-domain corpus from the SAME encoder (paper RQ2 setting):
    same embedding space, different document distribution."""
    D, _ = make_corpus(base_encoder, n_docs=n_docs, d=d, seed=seed,
                       domain_seed=domain_seed)
    return D
