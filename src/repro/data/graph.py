"""Graph data: synthetic generators + a real CSR fanout neighbour sampler.

``NeighborSampler`` implements GraphSAGE-style layered fanout sampling over
a CSR adjacency (the ``minibatch_lg`` training regime): seed nodes →
fanout[0] neighbours each → fanout[1] neighbours of those → …, emitted as a
*padded, fixed-shape* subgraph so every training step compiles once.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class CSRGraph:
    indptr: np.ndarray     # (N+1,)
    indices: np.ndarray    # (E,)
    n_nodes: int

    @classmethod
    def from_edge_index(cls, edge_index: np.ndarray, n_nodes: int) -> "CSRGraph":
        src, dst = edge_index
        order = np.argsort(src, kind="stable")
        src_s, dst_s = src[order], dst[order]
        counts = np.bincount(src_s, minlength=n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return cls(indptr=indptr, indices=dst_s.astype(np.int32), n_nodes=n_nodes)


def random_graph(n_nodes: int, avg_degree: int, seed: int = 0,
                 power_law: bool = True) -> np.ndarray:
    """Random (power-law-ish) edge_index (2, E)."""
    rng = np.random.default_rng(seed)
    n_edges = n_nodes * avg_degree
    if power_law:
        # preferential-attachment flavour via zipf-weighted endpoints
        w = 1.0 / np.arange(1, n_nodes + 1) ** 0.8
        w /= w.sum()
        src = rng.choice(n_nodes, size=n_edges, p=w)
        dst = rng.choice(n_nodes, size=n_edges, p=w)
    else:
        src = rng.integers(0, n_nodes, n_edges)
        dst = rng.integers(0, n_nodes, n_edges)
    return np.stack([src, dst]).astype(np.int32)


def batched_molecules(batch: int, n_nodes: int, n_edges: int, d_feat: int,
                      d_edge: int, seed: int = 0) -> dict[str, np.ndarray]:
    """Batch of small graphs as one block-diagonal graph (offset edge ids)."""
    rng = np.random.default_rng(seed)
    nodes = rng.standard_normal((batch * n_nodes, d_feat)).astype(np.float32)
    edges = rng.standard_normal((batch * n_edges, d_edge)).astype(np.float32)
    ei = rng.integers(0, n_nodes, (batch, 2, n_edges)).astype(np.int32)
    offset = (np.arange(batch) * n_nodes)[:, None, None].astype(np.int32)
    edge_index = np.concatenate(list(ei + offset), axis=-1) if batch > 1 else ei[0]
    edge_index = (ei + offset).transpose(1, 0, 2).reshape(2, -1)
    targets = rng.standard_normal((batch * n_nodes, d_feat)).astype(np.float32)
    return {"nodes": nodes, "edges": edges, "edge_index": edge_index,
            "targets": targets}


class NeighborSampler:
    """Layered fanout sampler producing fixed-shape padded subgraphs."""

    def __init__(self, graph: CSRGraph, fanouts: tuple[int, ...],
                 batch_nodes: int, seed: int = 0):
        self.g = graph
        self.fanouts = fanouts
        self.batch_nodes = batch_nodes
        self.rng = np.random.default_rng(seed)
        # static output sizes
        sizes = [batch_nodes]
        for f in fanouts:
            sizes.append(sizes[-1] * f)
        self.max_nodes = sum(sizes)
        self.max_edges = sum(sizes[i + 1] for i in range(len(fanouts)))

    def sample(self, seeds: np.ndarray | None = None) -> dict[str, np.ndarray]:
        g = self.g
        if seeds is None:
            seeds = self.rng.choice(g.n_nodes, size=self.batch_nodes,
                                    replace=False)
        frontier = seeds.astype(np.int32)
        all_nodes = [frontier]
        src_l, dst_l = [], []
        for f in self.fanouts:
            deg = g.indptr[frontier + 1] - g.indptr[frontier]
            # sample f neighbours per frontier node (with replacement; deg-0
            # nodes emit self-loops — standard GraphSAGE practice)
            offs = self.rng.integers(0, np.maximum(deg, 1)[:, None],
                                     size=(len(frontier), f))
            nbr = g.indices[np.minimum(g.indptr[frontier, None] + offs,
                                       g.indptr[frontier + 1, None] - 1)]
            nbr = np.where(deg[:, None] > 0, nbr, frontier[:, None])
            src_l.append(nbr.reshape(-1))
            dst_l.append(np.repeat(frontier, f))
            frontier = nbr.reshape(-1).astype(np.int32)
            all_nodes.append(frontier)

        nodes = np.concatenate(all_nodes)
        uniq, inv = np.unique(nodes, return_inverse=True)
        # remap edges into local ids
        n_seen = 0
        local = {}
        src = np.concatenate(src_l)
        dst = np.concatenate(dst_l)
        lookup = {int(v): i for i, v in enumerate(uniq)}
        src_loc = np.fromiter((lookup[int(s)] for s in src), np.int32, len(src))
        dst_loc = np.fromiter((lookup[int(d)] for d in dst), np.int32, len(dst))

        # pad to static shapes
        n_pad = self.max_nodes - len(uniq)
        e_pad = self.max_edges - len(src_loc)
        node_ids = np.pad(uniq.astype(np.int32), (0, max(n_pad, 0)))
        node_mask = np.pad(np.ones(len(uniq), np.float32), (0, max(n_pad, 0)))
        edge_index = np.stack([
            np.pad(src_loc, (0, max(e_pad, 0))),
            np.pad(dst_loc, (0, max(e_pad, 0))),
        ])
        seed_mask = np.zeros(self.max_nodes, np.float32)
        seed_mask[np.fromiter((lookup[int(s)] for s in seeds), np.int64,
                              len(seeds))] = 1.0
        return {"node_ids": node_ids[:self.max_nodes],
                "node_mask": node_mask[:self.max_nodes],
                "edge_index": edge_index[:, :self.max_edges],
                "seed_mask": seed_mask}
