"""Synthetic recsys batches (Criteo-shaped), deterministic per (seed, step)."""
from __future__ import annotations

import numpy as np


def ctr_batch(seed: int, step: int, *, batch: int, vocab_sizes, n_dense: int = 0
              ) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 11]))
    F = len(vocab_sizes)
    # zipf-ish skew: real CTR ids are heavy-tailed
    sparse = np.stack([
        np.minimum((rng.pareto(1.2, size=batch) * (v / 50)).astype(np.int64), v - 1)
        for v in vocab_sizes], axis=1).astype(np.int32)
    out = {"sparse": sparse,
           "label": (rng.random(batch) < 0.25).astype(np.float32)}
    if n_dense:
        out["dense"] = rng.standard_normal((batch, n_dense)).astype(np.float32)
    return out


def two_tower_batch(seed: int, step: int, *, batch: int, user_vocab: int,
                    item_vocab: int) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 13]))
    item_ids = np.minimum((rng.pareto(1.1, size=batch) * (item_vocab / 50)
                           ).astype(np.int64), item_vocab - 1).astype(np.int32)
    # logQ correction: popularity-proportional sampling probability
    freq = 1.0 / (1.0 + item_ids.astype(np.float64))
    logq = np.log(freq / freq.sum() * batch).astype(np.float32)
    return {"user_ids": rng.integers(0, user_vocab, batch).astype(np.int32),
            "item_ids": item_ids,
            "item_logq": logq}
