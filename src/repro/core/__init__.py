"""Paper core: PCA static pruning for dense retrieval (Siciliano et al. 2024)."""
from repro.core.pca import (
    PCAState, fit_pca, fit_pca_streaming, fit_pca_distributed,
    gram, gram_streaming, gram_distributed,
    transform, transform_query, inverse_transform,
    m_from_cutoff, cutoff_from_m, m_for_variance, explained_variance_ratio,
    save_pca, load_pca,
)
from repro.core.pruning import StaticPruner
from repro.core.index import (DeltaSegment, DenseIndex, SegmentedIndex,
                              ShardedDenseIndex, merge_segment_topk)
from repro.core.cascade import CascadeIndex
from repro.core.store import IndexStore, IndexStoreError, save_index
from repro.core import metrics
from repro.core import quantization
from repro.core import table_compress

__all__ = [
    "PCAState", "fit_pca", "fit_pca_streaming", "fit_pca_distributed",
    "gram", "gram_streaming", "gram_distributed",
    "transform", "transform_query", "inverse_transform",
    "m_from_cutoff", "cutoff_from_m", "m_for_variance", "explained_variance_ratio",
    "save_pca", "load_pca", "StaticPruner", "DenseIndex", "ShardedDenseIndex",
    "SegmentedIndex", "DeltaSegment", "CascadeIndex", "merge_segment_topk",
    "IndexStore", "IndexStoreError", "save_index",
    "metrics", "quantization",
]
