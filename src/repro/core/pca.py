"""PCA decomposition of dense-retrieval embedding indexes.

Implements the paper's core machinery (Siciliano et al., 2024):

    D^T D = W Λ W^T              (uncentered Gram eigendecomposition)
    T     = D W                  (rotated index, variance-sorted columns)
    D̂    = T_m = D W_m           (pruned index at cutoff c = (d-m)/d)
    q̂    = W_m^T q               (query transform, applied online)

The paper eigendecomposes the *uncentered* Gram matrix D^T D (not the
mean-centred covariance); we default to that for faithfulness and expose
``center=True`` as an option (classical PCA).

Three Gram paths, one math:
  * ``gram(D)``                — single-device blocked jnp (reference).
  * ``gram_streaming(batches)``— host-side accumulation over an iterator of
                                 row blocks; the index never needs to be
                                 resident (production offline path).
  * ``gram_distributed(D, mesh)`` — rows sharded over every mesh device,
                                 local Gram + psum (multi-pod offline path).
A Pallas kernel path (``repro.kernels.gram_ops``) is selected automatically
for large blocks when available.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.par import compat


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PCAState:
    """Result of fitting PCA on an embedding matrix.

    Attributes:
      components: ``W`` — (d, d) orthonormal eigenvector matrix, columns
        sorted by decreasing eigenvalue.
      eigenvalues: (d,) eigenvalues of the (un)centered Gram/covariance,
        descending, clipped at >= 0.
      mean: (d,) mean row of the fitted corpus (zeros when ``center=False``
        — kept so transform code is branch-free).
      n_samples: number of embedding rows used for the fit.
      centered: static flag — whether ``mean`` was subtracted before the
        eigendecomposition.
    """

    components: jax.Array
    eigenvalues: jax.Array
    mean: jax.Array
    n_samples: jax.Array
    centered: bool = dataclasses.field(metadata=dict(static=True), default=False)

    @property
    def d(self) -> int:
        return self.components.shape[0]


# ---------------------------------------------------------------------------
# Gram computation
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("block_rows",))
def gram(D: jax.Array, block_rows: int = 8192) -> jax.Array:
    """Blocked ``D^T D`` in fp32, streaming row blocks through a scan.

    Blocking bounds the transient working set to ``block_rows × d`` while the
    (d, d) accumulator stays live — the structure the Pallas kernel mirrors
    on TPU (strip streams HBM→VMEM, accumulator is VMEM-resident).
    """
    n, d = D.shape
    nblocks = max(1, -(-n // block_rows))
    pad = nblocks * block_rows - n
    Dp = jnp.pad(D, ((0, pad), (0, 0))) if pad else D
    blocks = Dp.reshape(nblocks, block_rows, d)

    def body(acc, blk):
        blk = blk.astype(jnp.float32)
        return acc + blk.T @ blk, None

    acc0 = jnp.zeros((d, d), jnp.float32)
    out, _ = jax.lax.scan(body, acc0, blocks)
    return out


def gram_streaming(batches: Iterable[np.ndarray | jax.Array]) -> tuple[jax.Array, jax.Array, int]:
    """Accumulate Gram + column sums over an iterator of row blocks.

    Returns ``(G, colsum, n)`` so the caller can optionally centre:
    ``cov = G/n − mean meanᵀ``. The corpus never needs to fit in memory.
    """
    G = None
    colsum = None
    n = 0
    step = jax.jit(lambda g, s, b: (g + b.T.astype(jnp.float32) @ b.astype(jnp.float32),
                                    s + b.sum(0, dtype=jnp.float32)))
    for b in batches:
        b = jnp.asarray(b)
        if G is None:
            d = b.shape[1]
            G = jnp.zeros((d, d), jnp.float32)
            colsum = jnp.zeros((d,), jnp.float32)
        G, colsum = step(G, colsum, b)
        n += int(b.shape[0])
    if G is None:
        raise ValueError("gram_streaming received an empty iterator")
    return G, colsum, n


def gram_distributed(D: jax.Array, mesh: Mesh) -> jax.Array:
    """Gram of a row-sharded index: local strip Gram + psum over all axes.

    ``D`` is (n, d) sharded ``P(mesh.axis_names, None)`` (rows over every
    device). Each device computes its strip's Gram and a single all-reduce
    of (d, d) fp32 — d ≤ 4096 ⇒ ≤ 64 MiB, negligible next to streaming D.
    Row counts not divisible by the device count are zero-padded: zero rows
    are Gram-neutral, so the result is exact.
    """
    axes = tuple(mesh.axis_names)
    spec = P(axes, None)
    ndev = int(np.prod(mesh.devices.shape))
    pad = (-D.shape[0]) % ndev
    if pad:
        D = jnp.pad(D, ((0, pad), (0, 0)))

    def local_gram(strip):
        strip = strip.astype(jnp.float32)
        return jax.lax.psum(strip.T @ strip, axes)

    fn = compat.shard_map(local_gram, mesh=mesh, in_specs=(spec,),
                          out_specs=P(None, None))
    return jax.jit(fn)(D)


# ---------------------------------------------------------------------------
# Fit
# ---------------------------------------------------------------------------


def _eig_from_gram(G: jax.Array, colsum: jax.Array, n: int, center: bool) -> PCAState:
    d = G.shape[0]
    mean = colsum / jnp.maximum(n, 1)
    if center:
        M = G / jnp.maximum(n, 1) - jnp.outer(mean, mean)
    else:
        M = G
        mean = jnp.zeros((d,), jnp.float32)
    # eigh returns ascending eigenvalues; the paper wants descending.
    evals, evecs = jnp.linalg.eigh(M.astype(jnp.float64) if jax.config.jax_enable_x64 else M)
    order = jnp.argsort(evals)[::-1]
    evals = jnp.clip(evals[order], 0.0, None).astype(jnp.float32)
    evecs = evecs[:, order].astype(jnp.float32)
    return PCAState(components=evecs, eigenvalues=evals, mean=mean,
                    n_samples=jnp.asarray(n, jnp.int32), centered=center)


def fit_pca(D: jax.Array, *, center: bool = False, block_rows: int = 8192) -> PCAState:
    """Fit PCA on an in-memory embedding matrix (paper default: uncentered)."""
    D = jnp.asarray(D)
    n, d = D.shape
    G = gram(D, block_rows=min(block_rows, max(1, n)))
    colsum = D.sum(0, dtype=jnp.float32)
    return _eig_from_gram(G, colsum, n, center)


def fit_pca_streaming(batches: Iterable[np.ndarray | jax.Array], *, center: bool = False) -> PCAState:
    """Fit PCA over an iterator of row blocks (out-of-core offline path)."""
    G, colsum, n = gram_streaming(batches)
    return _eig_from_gram(G, colsum, n, center)


def fit_pca_distributed(D: jax.Array, mesh: Mesh, *, center: bool = False) -> PCAState:
    """Fit PCA on a row-sharded index across a mesh (multi-pod offline path)."""
    G = gram_distributed(D, mesh)
    colsum = D.sum(0, dtype=jnp.float32)
    n = D.shape[0]
    return _eig_from_gram(G, colsum, n, center)


# ---------------------------------------------------------------------------
# Transforms
# ---------------------------------------------------------------------------


def m_from_cutoff(d: int, cutoff: float) -> int:
    """Paper's cutoff c = (d - m)/d ⇒ m = round(d · (1 - c)). c in [0, 1)."""
    if not 0.0 <= cutoff < 1.0:
        raise ValueError(f"cutoff must be in [0, 1), got {cutoff}")
    return max(1, int(round(d * (1.0 - cutoff))))


def cutoff_from_m(d: int, m: int) -> float:
    return (d - m) / d


def transform(X: jax.Array, state: PCAState, m: int | None = None) -> jax.Array:
    """Project rows of X onto the first m principal components: X @ W_m."""
    W = state.components
    if m is not None:
        W = W[:, :m]
    Xc = X - state.mean if state.centered else X
    return (Xc @ W).astype(X.dtype)


def transform_query(q: jax.Array, state: PCAState, m: int | None = None) -> jax.Array:
    """q̂ = W_m^T q for a single query (d,) or a batch (B, d)."""
    return transform(jnp.atleast_2d(q), state, m).reshape(
        (*q.shape[:-1], m if m is not None else state.d))


def projection_operands(state: PCAState, m: int | None = None
                        ) -> tuple[jax.Array, jax.Array | None]:
    """``(W_m, mean-or-None)`` — the operands a fused search needs.

    ``DenseIndex.search_projected`` / ``ShardedDenseIndex.search_projected``
    trace ``transform_query`` inline (projection + int8 scale fold + top-k
    in one jit); they take these raw arrays instead of a ``PCAState`` so
    the hot path carries no pytree and the compiled cache keys stay flat.
    ``mean`` is ``None`` for the paper's uncentered fit — the fused path
    then skips the subtract entirely rather than adding zeros.
    """
    W = state.components if m is None else state.components[:, :m]
    return W, (state.mean if state.centered else None)


def inverse_transform(T: jax.Array, state: PCAState) -> jax.Array:
    """Reconstruct from an m-dim projection (lossy for m < d): T @ W_m^T."""
    m = T.shape[-1]
    X = T @ state.components[:, :m].T
    return X + state.mean if state.centered else X


def explained_variance_ratio(state: PCAState) -> jax.Array:
    tot = jnp.maximum(state.eigenvalues.sum(), 1e-30)
    return state.eigenvalues / tot


def m_for_variance(state: PCAState, target: float) -> int:
    """Smallest m whose leading eigenvalues explain >= target of total.

    Clamped to [1, d]: with ``target=1.0`` fp32 rounding can leave
    ``cumsum.max() < target``, where searchsorted would point past the
    last component.
    """
    csum = jnp.cumsum(explained_variance_ratio(state))
    m = int(jnp.searchsorted(csum, jnp.float32(target)) + 1)
    return max(1, min(m, state.d))


# ---------------------------------------------------------------------------
# Serialization (offline artefact: W, Λ, mean)
# ---------------------------------------------------------------------------


def save_pca(path: str, state: PCAState) -> None:
    np.savez(path,
             components=np.asarray(state.components),
             eigenvalues=np.asarray(state.eigenvalues),
             mean=np.asarray(state.mean),
             n_samples=np.asarray(state.n_samples),
             centered=np.asarray(state.centered))


def load_pca(path: str) -> PCAState:
    z = np.load(path)
    return PCAState(components=jnp.asarray(z["components"]),
                    eigenvalues=jnp.asarray(z["eigenvalues"]),
                    mean=jnp.asarray(z["mean"]),
                    n_samples=jnp.asarray(z["n_samples"]),
                    centered=bool(z["centered"]))
