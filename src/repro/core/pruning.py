"""StaticPruner — the paper's offline pipeline as one high-level object.

Usage (offline):
    pruner = StaticPruner(cutoff=0.5)              # keep m = d/2 dims
    pruner.fit(corpus_embeddings)                  # or .fit_streaming(...)
    pruned_index = pruner.prune_index(corpus_embeddings)   # D̂ = D W_m
    pruner.save("msmarco_pca.npz")

Usage (online / query processing):
    q_hat = pruner.transform_queries(q)            # q̂ = W_mᵀ q,  O(dm)
    scores = pruned_index @ q_hat                  # O(mn)  — via DenseIndex

Out-of-domain (paper RQ2): the same fitted pruner prunes a *different*
corpus: ``pruner.prune_index(other_corpus)``.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import pca as _pca
from repro.core.index import DenseIndex, ShardedDenseIndex


@dataclasses.dataclass
class StaticPruner:
    """PCA-based static dimension pruning (query-independent, offline).

    Exactly one of ``cutoff`` / ``m`` / ``variance_target`` picks the kept
    dimensionality; ``center=False`` reproduces the paper's uncentered
    Gram eigendecomposition.
    """

    cutoff: float | None = None
    m: int | None = None
    variance_target: float | None = None
    center: bool = False
    state: _pca.PCAState | None = None

    def __post_init__(self):
        picked = sum(x is not None for x in (self.cutoff, self.m, self.variance_target))
        if picked != 1:
            raise ValueError("specify exactly one of cutoff / m / variance_target")

    # -- fit ---------------------------------------------------------------
    def fit(self, D: jax.Array) -> "StaticPruner":
        self.state = _pca.fit_pca(D, center=self.center)
        return self

    def fit_streaming(self, batches: Iterable[np.ndarray | jax.Array]) -> "StaticPruner":
        self.state = _pca.fit_pca_streaming(batches, center=self.center)
        return self

    def fit_distributed(self, D: jax.Array, mesh: Mesh) -> "StaticPruner":
        self.state = _pca.fit_pca_distributed(D, mesh, center=self.center)
        return self

    # -- dimensionality ------------------------------------------------------
    @property
    def kept_dims(self) -> int:
        if self.state is None:
            raise RuntimeError("fit() before querying kept_dims")
        d = self.state.d
        if self.m is not None:
            return min(self.m, d)
        if self.cutoff is not None:
            return _pca.m_from_cutoff(d, self.cutoff)
        return _pca.m_for_variance(self.state, self.variance_target)

    @property
    def effective_cutoff(self) -> float:
        return _pca.cutoff_from_m(self.state.d, self.kept_dims)

    # -- offline application -------------------------------------------------
    def prune_index(self, D: jax.Array, *, block_rows: int = 262144) -> jax.Array:
        """D̂ = D·W_m, computed in row blocks (out-of-core friendly)."""
        self._check_fit()
        m = self.kept_dims
        n = D.shape[0]
        if n <= block_rows:
            return _pca.transform(D, self.state, m)
        outs = [
            _pca.transform(D[i:i + block_rows], self.state, m)
            for i in range(0, n, block_rows)
        ]
        return jnp.concatenate(outs, axis=0)

    def build_index(self, D: jax.Array, *, mesh: Mesh | None = None,
                    quantize_int8: bool = False, backend: str = "jnp"):
        """One-stop offline artefact: pruned (optionally int8) search index."""
        pruned = self.prune_index(D)
        if mesh is not None:
            return ShardedDenseIndex.build(pruned, mesh, quantize_int8=quantize_int8)
        return DenseIndex.build(pruned, quantize_int8=quantize_int8, backend=backend)

    def build_index_to(self, path: str, corpus_batches, *,
                       quantize_int8: bool = False,
                       dtype: jnp.dtype | None = None,
                       meta: dict | None = None,
                       already_projected: bool = False):
        """Streaming offline build: fit + prune + (quantize) straight to disk.

        ``corpus_batches`` is the corpus as row blocks — either a sequence
        of arrays or a zero-argument callable returning a fresh iterator
        (the build makes up to two passes: Gram fit if not yet fitted,
        then one combined project/absmax/write pass). A one-shot generator
        is rejected loudly rather than silently yielding an empty second
        pass.

        ``already_projected=True`` declares the blocks are ALREADY in the
        pruned m-dim space (f32) — the fit and projection are skipped and
        only the absmax/quantise/write machinery runs. This is the segment
        compaction path: ``IndexUpdater.compact`` streams dequantised rows
        of base+deltas through here to mint a fresh base with one fresh
        corpus-wide scale.

        ``quantize_int8`` costs no third corpus pass, and the spill is now
        int8, not f32: each projected block is quantised under the
        *provisional running* per-dim scale (its own absmax included, so
        the spill never clips) and the scale it was spilled under is
        recorded. Blocks spilled after the scale stabilised are already
        bit-exact under the final corpus-wide scale and append as-is;
        blocks spilled before a later block widened the scale are
        re-projected in ONE bounded re-read pass (only the stale blocks are
        projected — the rest of the generator is just advanced past). The
        committed artifact is bit-identical to quantising exact f32
        projections under the final scale, while spill bytes drop 4x
        (``meta['spill_bytes']``, ``meta['requant_blocks']`` record both).

        Peak host memory is O(block_rows × d): the full (n, d) corpus and
        the full (n, m) pruned index never materialise. Returns the
        committed ``IndexStore``.
        """
        import os
        import shutil
        import tempfile

        from repro.core.store import IndexStore

        def passes():
            if callable(corpus_batches):
                return iter(corpus_batches())
            if isinstance(corpus_batches, (list, tuple)):
                return iter(corpus_batches)
            raise TypeError(
                "corpus_batches must be a list/tuple of row blocks or a "
                "zero-arg callable returning a fresh iterator: the streaming "
                "build reads the corpus in multiple passes")

        if self.state is None:
            if already_projected:
                raise RuntimeError("already_projected=True requires a "
                                   "fitted pruner (the blocks carry no "
                                   "d-dim information to fit from)")
            self.fit_streaming(passes())
        m = self.kept_dims

        def project(b) -> np.ndarray:
            if already_projected:
                b = np.asarray(b, np.float32)
                if b.ndim != 2 or b.shape[1] != m:
                    raise ValueError(f"already_projected blocks must be "
                                     f"(rows, {m}), got {tuple(b.shape)}")
                return b
            return np.asarray(_pca.transform(jnp.asarray(b), self.state, m),
                              np.float32)

        spill_stats = {}
        writer = IndexStore.create(path)
        with writer:
            writer.put_pca(self.state)
            if quantize_int8:
                # int8 spill under the provisional running scale. The spill
                # lives NEXT TO the target store, not in the system temp
                # dir: /tmp is often RAM-backed tmpfs, which would silently
                # turn the O(n·m) spill back into host memory.
                from repro.core.quantization import quantize_with_scale
                spill = tempfile.mkdtemp(
                    prefix="idxbuild_spill_",
                    dir=os.path.dirname(os.path.abspath(path)) or ".")
                try:
                    absmax = np.zeros((m,), np.float32)
                    files: list[str] = []
                    scales: list[np.ndarray] = []
                    spill_bytes = 0
                    for b in passes():
                        p = project(b)
                        absmax = np.maximum(absmax, np.abs(p).max(axis=0))
                        s_prov = np.maximum(absmax, 1e-12) / 127.0
                        q = quantize_with_scale(p, s_prov)
                        f = os.path.join(spill, f"{len(files):06d}.npy")
                        np.save(f, q)
                        spill_bytes += q.nbytes
                        files.append(f)
                        scales.append(s_prov)
                    scale = np.maximum(absmax, 1e-12) / 127.0
                    writer.set_scale(scale)
                    stale = {i for i, s in enumerate(scales)
                             if not np.array_equal(s, scale)}
                    if stale:
                        # bounded re-read: advance the generator block by
                        # block, re-projecting ONLY the stale ones and
                        # overwriting their spill with the exact final-scale
                        # quantisation (still O(block) memory)
                        seen = 0
                        for i, b in enumerate(passes()):
                            if i in stale:
                                p = project(b)
                                np.save(files[i],
                                        quantize_with_scale(p, scale))
                                seen += 1
                                if seen == len(stale):
                                    break
                        if seen != len(stale):
                            raise RuntimeError(
                                f"corpus iterator yielded fewer blocks on "
                                f"the re-read pass ({seen}/{len(stale)} "
                                f"stale blocks revisited)")
                    for f in files:
                        writer.append(np.load(f, mmap_mode="r"))
                        os.remove(f)
                    spill_stats = dict(spill_bytes=int(spill_bytes),
                                       spill_dtype="int8",
                                       requant_blocks=int(len(stale)))
                finally:
                    shutil.rmtree(spill, ignore_errors=True)
            else:
                for b in passes():
                    p = project(b)
                    if dtype is not None:
                        p = np.asarray(jnp.asarray(p).astype(dtype))
                    writer.append(p)
            info = dict(kept_dims=int(m), source_dim=int(self.state.d),
                        cutoff=float(self.effective_cutoff),
                        centered=bool(self.state.centered),
                        quantize_int8=bool(quantize_int8), **spill_stats)
            info.update(meta or {})
            return writer.commit(meta=info)

    # -- online application ----------------------------------------------------
    def transform_queries(self, q: jax.Array) -> jax.Array:
        """q̂ = W_mᵀq — the only per-query cost the method adds: O(dm)."""
        self._check_fit()
        return _pca.transform_query(q, self.state, self.kept_dims)

    def projection(self) -> tuple[jax.Array, jax.Array | None]:
        """``(W_m, mean-or-None)`` for the fused ``search_projected`` path:
        the serving loop passes raw d-dim queries plus these operands and
        the index applies projection + scale fold + top-k in one dispatch."""
        self._check_fit()
        return _pca.projection_operands(self.state, self.kept_dims)

    # -- persistence ------------------------------------------------------------
    def save(self, path: str) -> None:
        self._check_fit()
        _pca.save_pca(path, self.state)

    @classmethod
    def load(cls, path: str, *, cutoff: float | None = None, m: int | None = None,
             variance_target: float | None = None) -> "StaticPruner":
        if cutoff is None and m is None and variance_target is None:
            cutoff = 0.5
        state = _pca.load_pca(path)
        obj = cls(cutoff=cutoff, m=m, variance_target=variance_target,
                  center=state.centered)
        obj.state = state
        return obj

    def _check_fit(self):
        if self.state is None:
            raise RuntimeError("StaticPruner is not fitted; call fit() first")
