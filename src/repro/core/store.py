"""On-disk index artifact store: the paper's offline object, made durable.

The whole point of static pruning is that it is *query independent and
executed offline* — the deliverable is a reusable artifact, not a warm
process. This module gives that artifact a versioned on-disk layout:

    <dir>/
      manifest.json          # version, n, dim, logical dtype, chunk list,
                             # pca/scale file names, free-form meta
      pca.npz                # PCAState (W, Λ, mean) — save_pca format
      scale.npy              # per-dim int8 dequant scale (int8 stores only)
      vectors_000000.npy     # row chunk 0
      vectors_000001.npy     # row chunk 1 ...

Durability reuses the checkpoint module's commit protocol: everything is
written into ``<dir>.tmp`` with every blob fsynced, then the directory is
atomically renamed into place and the parent fsynced — a crashed build can
never be mistaken for a committed artifact, and ``IndexStore.open``
validates the manifest against the blobs it names (version, chunk
presence, per-chunk shape, row-count sum) so a tampered or partially
copied directory is rejected loudly.

Appends to a *committed* store (incremental corpus growth through
``IndexUpdater``) use a blob-then-manifest protocol: the new chunk is
written and fsynced first, then the manifest is atomically replaced
(``os.replace`` + dir fsync). A crash between the two leaves an orphan
blob the manifest never names — still a valid store.

Reads are host-streamed: chunks are memory-mapped (``np.load(mmap_mode=
'r')``), so assembling a device-resident index never needs a second full
host copy — ``DenseIndex.load`` copies one chunk at a time to device, and
``ShardedDenseIndex.load`` materialises one *shard* at a time on its
target device and assembles the global array with
``jax.make_array_from_single_device_arrays``.

bfloat16 has no native ``.npy`` encoding; bf16 chunks are stored as raw
``uint16`` views and re-viewed on load (the manifest keeps the logical
dtype).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Iterator

import numpy as np

from repro.checkpoint.manager import (commit_dir, fsync_dir, fsync_file,
                                      write_json_fsync)

FORMAT_VERSION = 1
MANIFEST = "manifest.json"
PCA_FILE = "pca.npz"
SCALE_FILE = "scale.npy"

# logical dtypes with no native .npy encoding -> raw storage view
_STORAGE_VIEW = {"bfloat16": np.uint16}


class IndexStoreError(RuntimeError):
    """A store directory is missing, corrupted, or inconsistent."""


def save_index(path: str, index, *, pruner=None, meta: dict | None = None,
               chunk_rows: int = 262144) -> "IndexStore":
    """Persist an already-built ``DenseIndex``/``ShardedDenseIndex``.

    Rows are copied device→host one ``chunk_rows`` slice at a time, so the
    host transient is O(chunk); only the logical ``index.n`` rows are
    written (a sharded index's device-padding rows are dropped — the load
    path re-synthesises them for whatever mesh it targets). Pass the fitted
    ``pruner`` to persist the PCA state alongside (required for
    ``IndexStore.load_pruner`` / ``serve --load-index`` to transform
    queries).
    """
    import numpy as _np
    writer = IndexStoreWriter(path)
    with writer:
        if pruner is not None:
            writer.put_pca(pruner.state)
        if index.scale is not None:
            writer.set_scale(_np.asarray(index.scale))
        v = index.vectors
        n = index.n   # logical rows: excludes sharded device padding
        for start in range(0, n, chunk_rows):
            writer.append(_np.asarray(v[start:min(start + chunk_rows, n)]))
        info = {} if pruner is None else dict(
            kept_dims=int(pruner.kept_dims),
            source_dim=int(pruner.state.d),
            cutoff=float(pruner.effective_cutoff),
            centered=bool(pruner.state.centered))
        info["quantize_int8"] = index.scale is not None
        info.update(meta or {})
        return writer.commit(meta=info)


def _as_numpy_dtype(logical: str):
    if logical in _STORAGE_VIEW:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, logical))
    return np.dtype(logical)


def _logical_dtype_name(arr: np.ndarray) -> str:
    return arr.dtype.name


def _write_chunk(path: str, arr: np.ndarray) -> None:
    view = _STORAGE_VIEW.get(arr.dtype.name)
    np.save(path, arr.view(view) if view is not None else arr)
    fsync_file(path)


def _read_chunk(path: str, logical: str, mmap: bool = True) -> np.ndarray:
    arr = np.load(path, mmap_mode="r" if mmap else None)
    view = _STORAGE_VIEW.get(logical)
    return arr.view(_as_numpy_dtype(logical)) if view is not None else arr


class IndexStoreWriter:
    """Streaming writer: append row chunks, then commit atomically.

    Peak host memory is one chunk — nothing is buffered across ``append``
    calls. ``dim``/``dtype`` are inferred from the first chunk and enforced
    thereafter. Usable as a context manager (aborts on exception).
    """

    def __init__(self, path: str):
        self.path = str(path)
        self.tmp = self.path + ".tmp"
        if os.path.exists(self.tmp):
            shutil.rmtree(self.tmp)
        os.makedirs(self.tmp)
        self._chunks: list[dict] = []
        self._n = 0
        self._dim: int | None = None
        self._dtype: str | None = None
        self._has_pca = False
        self._has_scale = False
        self._committed = False

    # -- content -----------------------------------------------------------
    def put_pca(self, state) -> None:
        """Persist the fitted PCAState alongside the vectors."""
        from repro.core import pca as _pca
        _pca.save_pca(os.path.join(self.tmp, PCA_FILE), state)
        fsync_file(os.path.join(self.tmp, PCA_FILE))
        self._has_pca = True

    def set_scale(self, scale: np.ndarray) -> None:
        """Per-dim dequant scale for int8 stores."""
        scale = np.asarray(scale, np.float32)
        path = os.path.join(self.tmp, SCALE_FILE)
        np.save(path, scale)
        fsync_file(path)
        self._has_scale = True

    def append(self, block: np.ndarray) -> None:
        block = np.asarray(block)
        if block.ndim != 2 or block.shape[0] == 0:
            raise ValueError(f"append expects a non-empty (rows, dim) block, "
                             f"got shape {block.shape}")
        if self._dim is None:
            self._dim = int(block.shape[1])
            self._dtype = _logical_dtype_name(block)
        if block.shape[1] != self._dim or block.dtype.name != self._dtype:
            raise ValueError(
                f"chunk mismatch: got ({block.shape[1]}, {block.dtype.name}), "
                f"store is ({self._dim}, {self._dtype})")
        fname = f"vectors_{len(self._chunks):06d}.npy"
        _write_chunk(os.path.join(self.tmp, fname), block)
        self._chunks.append({"file": fname, "rows": int(block.shape[0])})
        self._n += int(block.shape[0])

    # -- commit ------------------------------------------------------------
    def commit(self, meta: dict | None = None) -> "IndexStore":
        if self._committed:
            raise IndexStoreError("writer already committed")
        if not self._chunks:
            raise IndexStoreError("commit on an empty store (no chunks)")
        manifest = {
            "format_version": FORMAT_VERSION,
            "kind": "dense_index",
            "n": self._n,
            "dim": self._dim,
            "dtype": self._dtype,
            "chunks": self._chunks,
            "pca_file": PCA_FILE if self._has_pca else None,
            "scale_file": SCALE_FILE if self._has_scale else None,
            "meta": meta or {},
        }
        write_json_fsync(os.path.join(self.tmp, MANIFEST), manifest)
        commit_dir(self.tmp, self.path)
        self._committed = True
        return IndexStore.open(self.path)

    def abort(self) -> None:
        if not self._committed and os.path.exists(self.tmp):
            shutil.rmtree(self.tmp)

    def __enter__(self) -> "IndexStoreWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.abort()


@dataclasses.dataclass
class IndexStore:
    """Read/append handle on a committed artifact directory."""

    path: str
    manifest: dict

    # -- open / validate ---------------------------------------------------
    @classmethod
    def create(cls, path: str) -> IndexStoreWriter:
        return IndexStoreWriter(path)

    @classmethod
    def open(cls, path: str) -> "IndexStore":
        path = str(path)
        mpath = os.path.join(path, MANIFEST)
        if not os.path.isfile(mpath):
            raise IndexStoreError(
                f"{path}: not a committed index store (no {MANIFEST} — "
                f"a crashed build leaves only a .tmp directory)")
        try:
            with open(mpath) as f:
                manifest = json.load(f)
        except json.JSONDecodeError as e:
            raise IndexStoreError(f"{path}: unreadable manifest: {e}") from e
        store = cls(path=path, manifest=manifest)
        store.validate()
        return store

    def validate(self) -> None:
        m = self.manifest
        if m.get("format_version") != FORMAT_VERSION:
            raise IndexStoreError(
                f"{self.path}: format_version {m.get('format_version')!r} "
                f"!= supported {FORMAT_VERSION}")
        for key in ("n", "dim", "dtype", "chunks"):
            if key not in m:
                raise IndexStoreError(f"{self.path}: manifest missing {key!r}")
        rows = 0
        for c in m["chunks"]:
            fpath = os.path.join(self.path, c["file"])
            if not os.path.isfile(fpath):
                raise IndexStoreError(f"{self.path}: missing chunk {c['file']}")
            arr = _read_chunk(fpath, m["dtype"])
            if arr.ndim != 2 or arr.shape != (c["rows"], m["dim"]):
                raise IndexStoreError(
                    f"{self.path}: chunk {c['file']} has shape "
                    f"{tuple(arr.shape)}, manifest says ({c['rows']}, {m['dim']})")
            rows += c["rows"]
        if rows != m["n"]:
            raise IndexStoreError(
                f"{self.path}: chunk rows sum to {rows}, manifest n={m['n']}")
        for key in ("pca_file", "scale_file"):
            f = m.get(key)
            if f is not None and not os.path.isfile(os.path.join(self.path, f)):
                raise IndexStoreError(f"{self.path}: missing {key} blob {f}")

    # -- shape -------------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.manifest["n"])

    @property
    def dim(self) -> int:
        return int(self.manifest["dim"])

    @property
    def dtype(self) -> np.dtype:
        return _as_numpy_dtype(self.manifest["dtype"])

    @property
    def meta(self) -> dict:
        return self.manifest.get("meta", {})

    @property
    def nbytes(self) -> int:
        b = self.n * self.dim * self.dtype.itemsize
        if self.manifest.get("scale_file"):
            b += self.dim * 4
        return b

    # -- reads (host-streamed) --------------------------------------------
    def iter_chunks(self, mmap: bool = True) -> Iterator[np.ndarray]:
        """Yield row chunks in order, memory-mapped by default."""
        for c in self.manifest["chunks"]:
            yield _read_chunk(os.path.join(self.path, c["file"]),
                              self.manifest["dtype"], mmap=mmap)

    def read_rows(self, start: int, stop: int) -> np.ndarray:
        """Materialise rows [start, stop) — host memory O(stop - start).

        Chunks outside the range are never touched (mmap slicing), which is
        what lets a sharded load pull one device's rows at a time.
        """
        if not 0 <= start <= stop <= self.n:
            raise ValueError(f"row range [{start}, {stop}) outside [0, {self.n})")
        out = np.empty((stop - start, self.dim), self.dtype)
        pos = 0          # global row index at the current chunk's head
        filled = 0
        for c in self.manifest["chunks"]:
            rows = c["rows"]
            lo, hi = max(start, pos), min(stop, pos + rows)
            if lo < hi:
                chunk = _read_chunk(os.path.join(self.path, c["file"]),
                                    self.manifest["dtype"])
                out[filled:filled + (hi - lo)] = chunk[lo - pos:hi - pos]
                filled += hi - lo
            pos += rows
            if pos >= stop:
                break
        return out

    def scale(self) -> np.ndarray | None:
        f = self.manifest.get("scale_file")
        if f is None:
            return None
        return np.load(os.path.join(self.path, f))

    def load_pca(self):
        """PCAState persisted at build time (None file -> error)."""
        f = self.manifest.get("pca_file")
        if f is None:
            raise IndexStoreError(f"{self.path}: store has no PCA state")
        from repro.core import pca as _pca
        return _pca.load_pca(os.path.join(self.path, f))

    def load_pruner(self):
        """Rebuild the StaticPruner this store was pruned with."""
        from repro.core.pruning import StaticPruner
        state = self.load_pca()
        m = self.meta.get("kept_dims", self.dim)
        pruner = StaticPruner(m=int(m), center=state.centered)
        pruner.state = state
        return pruner

    # -- append (incremental growth) --------------------------------------
    def append(self, block: np.ndarray) -> None:
        """Durably append a row chunk to a committed store.

        Protocol: chunk blob fsynced first, then the manifest atomically
        replaced (``os.replace``) and the directory fsynced — the manifest
        swap is the commit point.
        """
        block = np.asarray(block)
        if block.ndim != 2 or block.shape[1] != self.dim:
            raise ValueError(f"append expects (rows, {self.dim}), got "
                             f"{tuple(block.shape)}")
        if block.dtype.name != self.manifest["dtype"]:
            raise ValueError(f"append dtype {block.dtype.name} != store dtype "
                             f"{self.manifest['dtype']}")
        fname = f"vectors_{len(self.manifest['chunks']):06d}.npy"
        _write_chunk(os.path.join(self.path, fname), block)
        manifest = dict(self.manifest)
        manifest["chunks"] = self.manifest["chunks"] + [
            {"file": fname, "rows": int(block.shape[0])}]
        manifest["n"] = self.n + int(block.shape[0])
        tmp_manifest = os.path.join(self.path, MANIFEST + ".tmp")
        write_json_fsync(tmp_manifest, manifest)
        os.replace(tmp_manifest, os.path.join(self.path, MANIFEST))
        fsync_dir(self.path)
        self.manifest = manifest
