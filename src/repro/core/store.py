"""On-disk index artifact store: the paper's offline object, made durable.

The whole point of static pruning is that it is *query independent and
executed offline* — the deliverable is a reusable artifact, not a warm
process. This module gives that artifact a versioned on-disk layout:

    <dir>/
      manifest.json          # version, n, dim, logical dtype, chunk list,
                             # pca/scale file names, free-form meta
      pca.npz                # PCAState (W, Λ, mean) — save_pca format
      scale.npy              # per-dim int8 dequant scale (int8 stores only)
      vectors_000000.npy     # row chunk 0
      vectors_000001.npy     # row chunk 1 ...

Durability reuses the checkpoint module's commit protocol: everything is
written into ``<dir>.tmp`` with every blob fsynced, then the directory is
atomically renamed into place and the parent fsynced — a crashed build can
never be mistaken for a committed artifact, and ``IndexStore.open``
validates the manifest against the blobs it names (version, chunk
presence, per-chunk shape, row-count sum) so a tampered or partially
copied directory is rejected loudly.

Appends to a *committed* store (incremental corpus growth through
``IndexUpdater``) use a blob-then-manifest protocol: the new chunk is
written and fsynced first, then the manifest is atomically replaced
(``os.replace`` + dir fsync). A crash between the two leaves an orphan
blob the manifest never names — still a valid store.

**Segments.** A live store may carry a ``segments`` list in its manifest:
segment 0 is the immutable base (the offline PCA-pruned artifact), later
entries are growable *delta* segments, each with the chunked-blob format
above plus its OWN ``scale_file`` (per-segment int8 scale — the fix for
the frozen-scale clip problem) and a ``capacity`` (the fixed padded shape
deltas dispatch at). The top-level ``n``/``chunks``/``scale_file`` fields
are always the derived global view (total rows, all chunks in id order,
the base's scale), so a pre-segment manifest IS a valid single-base
segmented store — ``IndexStore.open`` on an old artifact exposes exactly
one base segment, and old artifacts round-trip untouched. Segment
mutations (``add_delta`` / ``append`` / ``replace_segment``) all follow
the blob-then-manifest-swap protocol; whole-store replacement (compaction
building a fresh base) reuses ``checkpoint.manager.commit_dir``.

**Resolutions.** A manifest may also carry a ``resolutions`` list: extra
*coarse* views of the SAME rows at a smaller width — the leading
``m < dim`` PCA columns (dims nest, so no second projection state exists),
usually re-quantised int8 with their own scale. Each entry reuses the
chunked-blob layout (``chunks`` + optional ``scale_file`` + its own
``dtype``) and covers exactly the immutable BASE segment's rows. A
segmented cascade's coarse DELTA segments may ride along in the entry's
``deltas`` list (same per-delta layout as the main segments: exact
quantised rows + own scale + capacity), so a reload serves the very bytes
that were serving before instead of requantising from the full deltas;
a store whose main deltas outgrow the persisted coarse view falls back to
re-derivation at load. ``open`` refuses a resolution whose row count
disagrees with the base or whose m does not nest strictly inside ``dim``
— a mismatched pair would silently rescore the wrong rows.

Reads are host-streamed: chunks are memory-mapped (``np.load(mmap_mode=
'r')``), so assembling a device-resident index never needs a second full
host copy — ``DenseIndex.load`` copies one chunk at a time to device, and
``ShardedDenseIndex.load`` materialises one *shard* at a time on its
target device and assembles the global array with
``jax.make_array_from_single_device_arrays``.

bfloat16 has no native ``.npy`` encoding; bf16 chunks are stored as raw
``uint16`` views and re-viewed on load (the manifest keeps the logical
dtype).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Iterator, Sequence

import numpy as np

from repro.checkpoint.manager import commit_dir, fsync_dir, fsync_file, write_json_fsync

FORMAT_VERSION = 1
MANIFEST = "manifest.json"
PCA_FILE = "pca.npz"
SCALE_FILE = "scale.npy"

# logical dtypes with no native .npy encoding -> raw storage view
_STORAGE_VIEW = {"bfloat16": np.uint16}


class IndexStoreError(RuntimeError):
    """A store directory is missing, corrupted, or inconsistent."""


def save_index(path: str, index, *, pruner=None, meta: dict | None = None,
               chunk_rows: int = 262144) -> "IndexStore":
    """Persist an already-built ``DenseIndex``/``ShardedDenseIndex``.

    Rows are copied device→host one ``chunk_rows`` slice at a time, so the
    host transient is O(chunk); only the logical ``index.n`` rows are
    written (a sharded index's device-padding rows are dropped — the load
    path re-synthesises them for whatever mesh it targets). Pass the fitted
    ``pruner`` to persist the PCA state alongside (required for
    ``IndexStore.load_pruner`` / ``serve --load-index`` to transform
    queries).
    """
    import numpy as _np
    from repro.core.cascade import CascadeIndex
    from repro.core.index import SegmentedIndex
    from repro.core.paged import PagedIndex
    if isinstance(index, PagedIndex):
        return save_paged_index(path, index, pruner=pruner, meta=meta,
                                chunk_rows=chunk_rows)
    if isinstance(index, CascadeIndex):
        # full resolution commits through the normal (possibly segmented)
        # path; the coarse base rides along as a `resolutions` entry, so
        # one artifact round-trips the whole cascade via CascadeIndex.load.
        # A segmented coarse side persists its delta segments too (exact
        # quantised bytes + per-delta scales), so a segmented load
        # rehydrates them bit-for-bit instead of requantising from the
        # full deltas.
        store = save_index(path, index.full, pruner=pruner, meta=meta,
                           chunk_rows=chunk_rows)
        coarse = index.coarse
        if hasattr(coarse, "storage"):
            # paged coarse side: extent 0 is the resolution base, later
            # extents persist as resolution deltas — bytes gathered
            # straight off the page tiers
            cst = coarse.storage
            exts = cst.extents
            base_rows = (cst.extent_rows(0) if exts
                         else _np.zeros((0, cst.dim), cst.np_dtype))
            base_scale = exts[0].scale if exts else None
            coarse_deltas = [
                {"rows": cst.extent_rows(i),
                 "scale": None if e.scale is None else _np.asarray(e.scale),
                 "capacity": cst.seal_rows}
                for i, e in enumerate(exts) if i > 0]
        else:
            coarse_base = getattr(coarse, "base", coarse)
            base_rows = _np.asarray(coarse_base.vectors[:coarse_base.n])
            base_scale = coarse_base.scale
            coarse_deltas = [
                {"rows": _np.asarray(d.vectors[:d.n_real]),
                 "scale": None if d.scale is None else _np.asarray(d.scale),
                 "capacity": d.capacity}
                for d in getattr(coarse, "deltas", ())]
        store.add_resolution(
            base_rows,
            scale=None if base_scale is None else _np.asarray(base_scale),
            chunk_rows=chunk_rows, deltas=coarse_deltas)
        return store
    if isinstance(index, SegmentedIndex):
        # base commits through the normal path, then each delta is replayed
        # as a durable segment mutation — the artifact round-trips through
        # SegmentedIndex.load with every per-segment scale intact
        store = save_index(path, index.base, pruner=pruner, meta=meta,
                           chunk_rows=chunk_rows)
        for d in index.deltas:
            name = store.add_delta(
                scale=None if d.scale is None else _np.asarray(d.scale),
                capacity=d.capacity)
            if d.n_real:
                store.append(_np.asarray(d.vectors[:d.n_real]), segment=name)
        return store
    writer = IndexStoreWriter(path)
    with writer:
        if pruner is not None:
            writer.put_pca(pruner.state)
        if index.scale is not None:
            writer.set_scale(_np.asarray(index.scale))
        v = index.vectors
        n = index.n   # logical rows: excludes sharded device padding
        for start in range(0, n, chunk_rows):
            writer.append(_np.asarray(v[start:min(start + chunk_rows, n)]))
        info = {} if pruner is None else dict(
            kept_dims=int(pruner.kept_dims),
            source_dim=int(pruner.state.d),
            cutoff=float(pruner.effective_cutoff),
            centered=bool(pruner.state.centered))
        info["quantize_int8"] = index.scale is not None
        info.update(meta or {})
        return writer.commit(meta=info)


def paged_manifest_block(storage) -> dict:
    """The ``paged`` manifest entry for a ``PagedIndexStorage``: page
    geometry plus per-extent lifecycle state (kind/sealed). The page map
    itself is positional — extent i's rows are store segment i's rows,
    paged into ``page_rows``-row pages ascending — so the block stays tiny
    and every byte is validated through the existing segment machinery."""
    return {"page_rows": int(storage.page_rows),
            "seal_rows": int(storage.seal_rows),
            "extents": [{"kind": e.kind, "sealed": bool(e.sealed),
                         "n": int(e.n_rows)} for e in storage.extents]}


def save_paged_index(path: str, index, *, pruner=None,
                     meta: dict | None = None,
                     chunk_rows: int = 262144) -> "IndexStore":
    """Persist a ``PagedIndex``: one store segment per extent (page-granular
    chunks — every blob boundary is page-aligned) plus the ``paged``
    manifest block. Bytes are gathered straight off the page tiers
    (pool/tail/host alike), so the artifact is bit-identical to what was
    serving; the final ``set_paged_state`` manifest swap is the commit
    point for the lifecycle metadata."""
    import numpy as _np
    st = index.storage
    R = st.page_rows
    # page-align the chunking: whole pages per blob, never a split page
    chunk_rows = max(chunk_rows // R, 1) * R
    exts = st.extents
    writer = IndexStoreWriter(path)
    with writer:
        if pruner is not None:
            writer.put_pca(pruner.state)
        base_scale = exts[0].scale if exts else None
        if base_scale is not None:
            writer.set_scale(_np.asarray(base_scale))
        if exts:
            rows = st.extent_rows(0)
            for s in range(0, rows.shape[0], chunk_rows):
                writer.append(rows[s:s + chunk_rows])
        info = {} if pruner is None else dict(
            kept_dims=int(pruner.kept_dims),
            source_dim=int(pruner.state.d),
            cutoff=float(pruner.effective_cutoff),
            centered=bool(pruner.state.centered))
        info["quantize_int8"] = st.quantized
        info.update(meta or {})
        store = writer.commit(meta=info)
    for ei in range(1, len(exts)):
        e = exts[ei]
        name = store.add_delta(
            scale=None if e.scale is None else _np.asarray(e.scale),
            capacity=st.seal_rows)
        rows = st.extent_rows(ei)
        for s in range(0, rows.shape[0], chunk_rows):
            store.append(rows[s:s + chunk_rows], segment=name)
    store.set_paged_state(paged_manifest_block(st))
    return store


def _as_numpy_dtype(logical: str):
    if logical in _STORAGE_VIEW:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, logical))
    return np.dtype(logical)


def _logical_dtype_name(arr: np.ndarray) -> str:
    return arr.dtype.name


def _write_chunk(path: str, arr: np.ndarray) -> None:
    view = _STORAGE_VIEW.get(arr.dtype.name)
    np.save(path, arr.view(view) if view is not None else arr)
    fsync_file(path)


def _read_chunk(path: str, logical: str, mmap: bool = True) -> np.ndarray:
    arr = np.load(path, mmap_mode="r" if mmap else None)
    view = _STORAGE_VIEW.get(logical)
    return arr.view(_as_numpy_dtype(logical)) if view is not None else arr


def _read_chunk_validated(store_path: str, fpath: str,
                          logical: str) -> np.ndarray:
    """``_read_chunk`` for validate(): a blob whose payload is shorter
    than its npy header promises (a torn write — crash mid-rollout or
    mid-copy) must surface as an IndexStoreError diagnosis, not a raw
    mmap/np.load failure."""
    try:
        return _read_chunk(fpath, logical)
    except IndexStoreError:
        raise
    except Exception as e:
        raise IndexStoreError(
            f"{store_path}: chunk {os.path.basename(fpath)} is truncated "
            f"or unreadable ({e}) — partial artifact rejected") from e


def _read_rows_from_chunks(path: str, chunks: list, logical: str, dim: int,
                           total: int, start: int, stop: int) -> np.ndarray:
    """Materialise rows [start, stop) of a chunk list — host O(stop-start)."""
    if not 0 <= start <= stop <= total:
        raise ValueError(f"row range [{start}, {stop}) outside [0, {total})")
    out = np.empty((stop - start, dim), _as_numpy_dtype(logical))
    pos = 0          # global row index at the current chunk's head
    filled = 0
    for c in chunks:
        rows = c["rows"]
        lo, hi = max(start, pos), min(stop, pos + rows)
        if lo < hi:
            chunk = _read_chunk(os.path.join(path, c["file"]), logical)
            out[filled:filled + (hi - lo)] = chunk[lo - pos:hi - pos]
            filled += hi - lo
        pos += rows
        if pos >= stop:
            break
    return out


@dataclasses.dataclass
class SegmentView:
    """Read handle on one segment of a (possibly pre-segment) store.

    Duck-types the slice of the ``IndexStore`` read API the index loaders
    use (``n``/``dim``/``dtype``/``iter_chunks``/``read_rows``/``scale``),
    so ``DenseIndex.load`` / ``ShardedDenseIndex.load`` work unchanged on a
    single segment — that is how ``SegmentedIndex.load`` assembles its
    base. Row indices are segment-local; ``offset`` is the segment's
    global doc-id base.
    """

    store_path: str
    name: str
    kind: str                      # "base" | "delta"
    entry: dict                    # manifest segment entry (shared ref)
    offset: int                    # global row offset of this segment
    dim: int
    dtype_name: str

    @property
    def n(self) -> int:
        return int(self.entry["n"])

    @property
    def dtype(self) -> np.dtype:
        return _as_numpy_dtype(self.dtype_name)

    @property
    def capacity(self) -> int | None:
        c = self.entry.get("capacity")
        return None if c is None else int(c)

    def iter_chunks(self, mmap: bool = True) -> Iterator[np.ndarray]:
        for c in self.entry["chunks"]:
            yield _read_chunk(os.path.join(self.store_path, c["file"]),
                              self.dtype_name, mmap=mmap)

    def read_rows(self, start: int, stop: int) -> np.ndarray:
        return _read_rows_from_chunks(self.store_path, self.entry["chunks"],
                                      self.dtype_name, self.dim, self.n,
                                      start, stop)

    def scale(self) -> np.ndarray | None:
        f = self.entry.get("scale_file")
        if f is None:
            return None
        return np.load(os.path.join(self.store_path, f))


class IndexStoreWriter:
    """Streaming writer: append row chunks, then commit atomically.

    Peak host memory is one chunk — nothing is buffered across ``append``
    calls. ``dim``/``dtype`` are inferred from the first chunk and enforced
    thereafter. Usable as a context manager (aborts on exception).
    """

    def __init__(self, path: str):
        self.path = str(path)
        self.tmp = self.path + ".tmp"
        if os.path.exists(self.tmp):
            shutil.rmtree(self.tmp)
        os.makedirs(self.tmp)
        self._chunks: list[dict] = []
        self._n = 0
        self._dim: int | None = None
        self._dtype: str | None = None
        self._has_pca = False
        self._has_scale = False
        self._committed = False

    # -- content -----------------------------------------------------------
    def put_pca(self, state) -> None:
        """Persist the fitted PCAState alongside the vectors."""
        from repro.core import pca as _pca
        _pca.save_pca(os.path.join(self.tmp, PCA_FILE), state)
        fsync_file(os.path.join(self.tmp, PCA_FILE))
        self._has_pca = True

    def set_scale(self, scale: np.ndarray) -> None:
        """Per-dim dequant scale for int8 stores."""
        scale = np.asarray(scale, np.float32)
        path = os.path.join(self.tmp, SCALE_FILE)
        np.save(path, scale)
        fsync_file(path)
        self._has_scale = True

    def append(self, block: np.ndarray) -> None:
        block = np.asarray(block)
        if block.ndim != 2 or block.shape[0] == 0:
            raise ValueError(f"append expects a non-empty (rows, dim) block, "
                             f"got shape {block.shape}")
        if self._dim is None:
            self._dim = int(block.shape[1])
            self._dtype = _logical_dtype_name(block)
        if block.shape[1] != self._dim or block.dtype.name != self._dtype:
            raise ValueError(
                f"chunk mismatch: got ({block.shape[1]}, {block.dtype.name}), "
                f"store is ({self._dim}, {self._dtype})")
        fname = f"vectors_{len(self._chunks):06d}.npy"
        _write_chunk(os.path.join(self.tmp, fname), block)
        self._chunks.append({"file": fname, "rows": int(block.shape[0])})
        self._n += int(block.shape[0])

    # -- commit ------------------------------------------------------------
    def commit(self, meta: dict | None = None) -> "IndexStore":
        if self._committed:
            raise IndexStoreError("writer already committed")
        if not self._chunks:
            raise IndexStoreError("commit on an empty store (no chunks)")
        manifest = {
            "format_version": FORMAT_VERSION,
            "kind": "dense_index",
            "n": self._n,
            "dim": self._dim,
            "dtype": self._dtype,
            "chunks": self._chunks,
            "pca_file": PCA_FILE if self._has_pca else None,
            "scale_file": SCALE_FILE if self._has_scale else None,
            "meta": meta or {},
        }
        write_json_fsync(os.path.join(self.tmp, MANIFEST), manifest)
        commit_dir(self.tmp, self.path)
        self._committed = True
        return IndexStore.open(self.path)

    def abort(self) -> None:
        if not self._committed and os.path.exists(self.tmp):
            shutil.rmtree(self.tmp)

    def __enter__(self) -> "IndexStoreWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is not None:
            self.abort()


@dataclasses.dataclass
class IndexStore:
    """Read/append handle on a committed artifact directory."""

    path: str
    manifest: dict

    # -- open / validate ---------------------------------------------------
    @classmethod
    def create(cls, path: str) -> IndexStoreWriter:
        return IndexStoreWriter(path)

    @classmethod
    def open(cls, path: str) -> "IndexStore":
        path = str(path)
        mpath = os.path.join(path, MANIFEST)
        if not os.path.isfile(mpath):
            raise IndexStoreError(
                f"{path}: not a committed index store (no {MANIFEST} — "
                f"a crashed build leaves only a .tmp directory)")
        try:
            with open(mpath) as f:
                manifest = json.load(f)
        except json.JSONDecodeError as e:
            raise IndexStoreError(f"{path}: unreadable manifest: {e}") from e
        store = cls(path=path, manifest=manifest)
        store.validate()
        return store

    def validate(self) -> None:
        m = self.manifest
        if m.get("format_version") != FORMAT_VERSION:
            raise IndexStoreError(
                f"{self.path}: format_version {m.get('format_version')!r} "
                f"!= supported {FORMAT_VERSION}")
        for key in ("n", "dim", "dtype", "chunks"):
            if key not in m:
                raise IndexStoreError(f"{self.path}: manifest missing {key!r}")
        rows = 0
        for c in m["chunks"]:
            fpath = os.path.join(self.path, c["file"])
            if not os.path.isfile(fpath):
                raise IndexStoreError(f"{self.path}: missing chunk {c['file']}")
            arr = _read_chunk_validated(self.path, fpath, m["dtype"])
            if arr.ndim != 2 or arr.shape != (c["rows"], m["dim"]):
                raise IndexStoreError(
                    f"{self.path}: chunk {c['file']} has shape "
                    f"{tuple(arr.shape)}, manifest says ({c['rows']}, {m['dim']})")
            rows += c["rows"]
        if rows != m["n"]:
            raise IndexStoreError(
                f"{self.path}: chunk rows sum to {rows}, manifest n={m['n']}")
        for key in ("pca_file", "scale_file"):
            f = m.get(key)
            if f is not None and not os.path.isfile(os.path.join(self.path, f)):
                raise IndexStoreError(f"{self.path}: missing {key} blob {f}")
        segs = m.get("segments")
        if segs is not None:
            if not segs or segs[0].get("kind") != "base":
                raise IndexStoreError(
                    f"{self.path}: segments must start with a base segment")
            if sum(int(s["n"]) for s in segs) != m["n"]:
                raise IndexStoreError(
                    f"{self.path}: segment rows sum "
                    f"{sum(int(s['n']) for s in segs)} != manifest n={m['n']}")
            seg_files = [c["file"] for s in segs for c in s["chunks"]]
            if seg_files != [c["file"] for c in m["chunks"]]:
                raise IndexStoreError(
                    f"{self.path}: top-level chunks are not the "
                    f"concatenation of the segment chunk lists")
            for s in segs:
                f = s.get("scale_file")
                if f is not None and not os.path.isfile(
                        os.path.join(self.path, f)):
                    raise IndexStoreError(
                        f"{self.path}: segment {s['name']} missing scale "
                        f"blob {f}")
                cap = s.get("capacity")
                if cap is not None and int(s["n"]) > int(cap):
                    raise IndexStoreError(
                        f"{self.path}: segment {s['name']} holds {s['n']} "
                        f"rows over its capacity {cap}")
        self._validate_resolutions()
        self._validate_paged()

    def _validate_resolutions(self) -> None:
        """A coarse resolution must be a nested, row-aligned view of the
        base: same rows in the same order at a strictly smaller m. A
        mismatch would make cascade shortlist ids address the wrong
        rescore rows, so open() refuses loudly."""
        m = self.manifest
        base_n = int(self._segment_entries()[0]["n"])
        seen_m: set[int] = set()
        for r in m.get("resolutions", ()):
            for key in ("name", "m", "dtype", "chunks"):
                if key not in r:
                    raise IndexStoreError(
                        f"{self.path}: resolution entry missing {key!r}")
            rm = int(r["m"])
            if not 0 < rm < m["dim"]:
                raise IndexStoreError(
                    f"{self.path}: resolution {r['name']} has m={rm}, which "
                    f"does not nest inside the store's dim={m['dim']} "
                    f"(need 0 < m < dim — PCA leading columns)")
            if rm in seen_m:
                raise IndexStoreError(
                    f"{self.path}: duplicate resolution m={rm}")
            seen_m.add(rm)
            rows = 0
            for c in r["chunks"]:
                fpath = os.path.join(self.path, c["file"])
                if not os.path.isfile(fpath):
                    raise IndexStoreError(
                        f"{self.path}: resolution {r['name']} missing chunk "
                        f"{c['file']}")
                arr = _read_chunk_validated(self.path, fpath, r["dtype"])
                if arr.ndim != 2 or arr.shape != (c["rows"], rm):
                    raise IndexStoreError(
                        f"{self.path}: resolution chunk {c['file']} has "
                        f"shape {tuple(arr.shape)}, manifest says "
                        f"({c['rows']}, {rm})")
                rows += c["rows"]
            if rows != base_n:
                raise IndexStoreError(
                    f"{self.path}: resolution {r['name']} holds {rows} "
                    f"rows, base segment has {base_n} — the views no "
                    f"longer describe the same corpus")
            f = r.get("scale_file")
            if f is not None and not os.path.isfile(
                    os.path.join(self.path, f)):
                raise IndexStoreError(
                    f"{self.path}: resolution {r['name']} missing scale "
                    f"blob {f}")
            for d in r.get("deltas", ()):
                for key in ("name", "n", "capacity", "dtype", "chunks"):
                    if key not in d:
                        raise IndexStoreError(
                            f"{self.path}: resolution delta entry missing "
                            f"{key!r}")
                if int(d["n"]) > int(d["capacity"]):
                    raise IndexStoreError(
                        f"{self.path}: resolution delta {d['name']} holds "
                        f"{d['n']} rows over its capacity {d['capacity']}")
                drows = 0
                for c in d["chunks"]:
                    fpath = os.path.join(self.path, c["file"])
                    if not os.path.isfile(fpath):
                        raise IndexStoreError(
                            f"{self.path}: resolution delta {d['name']} "
                            f"missing chunk {c['file']}")
                    arr = _read_chunk_validated(self.path, fpath, d["dtype"])
                    if arr.ndim != 2 or arr.shape != (c["rows"], rm):
                        raise IndexStoreError(
                            f"{self.path}: resolution delta chunk "
                            f"{c['file']} has shape {tuple(arr.shape)}, "
                            f"manifest says ({c['rows']}, {rm})")
                    drows += c["rows"]
                if drows != int(d["n"]):
                    raise IndexStoreError(
                        f"{self.path}: resolution delta {d['name']} chunk "
                        f"rows sum to {drows}, manifest n={d['n']}")
                sf = d.get("scale_file")
                if sf is not None and not os.path.isfile(
                        os.path.join(self.path, sf)):
                    raise IndexStoreError(
                        f"{self.path}: resolution delta {d['name']} "
                        f"missing scale blob {sf}")

    def _validate_paged(self) -> None:
        """The ``paged`` block must describe the segment list it rides on.

        Append mirroring is two swaps (segment op, then lifecycle block),
        so the block may LAG the segments after a crash between them —
        fewer extents than segments, or a stale smaller row count — and
        the loader reconstructs the missing state conservatively. It must
        never LEAD: an extent claiming rows (or a whole extent) the
        segments don't hold is a torn artifact and is rejected."""
        pb = self.manifest.get("paged")
        if pb is None:
            return
        for key in ("page_rows", "seal_rows", "extents"):
            if key not in pb:
                raise IndexStoreError(
                    f"{self.path}: paged block missing {key!r}")
        if int(pb["page_rows"]) <= 0 or int(pb["seal_rows"]) <= 0:
            raise IndexStoreError(
                f"{self.path}: paged block needs positive page_rows/"
                f"seal_rows, got {pb['page_rows']}/{pb['seal_rows']}")
        exts = pb["extents"]
        entries = self._segment_entries() if int(self.manifest["n"]) else []
        if len(exts) > len(entries):
            raise IndexStoreError(
                f"{self.path}: paged block lists {len(exts)} extents but "
                f"the store holds {len(entries)} segments")
        for i, e in enumerate(exts):
            if e.get("kind") not in ("base", "delta"):
                raise IndexStoreError(
                    f"{self.path}: paged extent {i} has kind "
                    f"{e.get('kind')!r} (need base|delta)")
            if int(e["n"]) > int(entries[i]["n"]):
                raise IndexStoreError(
                    f"{self.path}: paged extent {i} claims {e['n']} rows, "
                    f"segment {entries[i]['name']} holds {entries[i]['n']}")
            if not e.get("sealed", True) and (i != len(exts) - 1
                                              or e["kind"] != "delta"):
                raise IndexStoreError(
                    f"{self.path}: paged extent {i} is unsealed but only "
                    f"the last delta extent may be open")

    # -- shape -------------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.manifest["n"])

    @property
    def dim(self) -> int:
        return int(self.manifest["dim"])

    @property
    def dtype(self) -> np.dtype:
        return _as_numpy_dtype(self.manifest["dtype"])

    @property
    def meta(self) -> dict:
        return self.manifest.get("meta", {})

    @property
    def nbytes(self) -> int:
        b = self.n * self.dim * self.dtype.itemsize
        if self.manifest.get("scale_file"):
            b += self.dim * 4
        return b

    # -- reads (host-streamed) --------------------------------------------
    def iter_chunks(self, mmap: bool = True) -> Iterator[np.ndarray]:
        """Yield row chunks in order, memory-mapped by default."""
        for c in self.manifest["chunks"]:
            yield _read_chunk(os.path.join(self.path, c["file"]),
                              self.manifest["dtype"], mmap=mmap)

    def read_rows(self, start: int, stop: int) -> np.ndarray:
        """Materialise rows [start, stop) — host memory O(stop - start).

        Chunks outside the range are never touched (mmap slicing), which is
        what lets a sharded load pull one device's rows at a time.
        """
        return _read_rows_from_chunks(self.path, self.manifest["chunks"],
                                      self.manifest["dtype"], self.dim,
                                      self.n, start, stop)

    def scale(self) -> np.ndarray | None:
        f = self.manifest.get("scale_file")
        if f is None:
            return None
        return np.load(os.path.join(self.path, f))

    def load_pca(self):
        """PCAState persisted at build time (None file -> error)."""
        f = self.manifest.get("pca_file")
        if f is None:
            raise IndexStoreError(f"{self.path}: store has no PCA state")
        from repro.core import pca as _pca
        return _pca.load_pca(os.path.join(self.path, f))

    def load_pruner(self):
        """Rebuild the StaticPruner this store was pruned with."""
        from repro.core.pruning import StaticPruner
        state = self.load_pca()
        m = self.meta.get("kept_dims", self.dim)
        pruner = StaticPruner(m=int(m), center=state.centered)
        pruner.state = state
        return pruner

    # -- segments ----------------------------------------------------------
    @property
    def is_segmented(self) -> bool:
        return "segments" in self.manifest

    def _segment_entries(self) -> list[dict]:
        """Manifest segment list, synthesising the single-base view for a
        pre-segment artifact (the backward-compat normalisation)."""
        segs = self.manifest.get("segments")
        if segs is not None:
            return segs
        return [{"name": "base", "kind": "base", "n": self.manifest["n"],
                 "chunks": self.manifest["chunks"],
                 "scale_file": self.manifest.get("scale_file")}]

    def segments(self) -> list[SegmentView]:
        """Read handles on every segment, base first, with global offsets."""
        views, offset = [], 0
        for s in self._segment_entries():
            views.append(SegmentView(store_path=self.path, name=s["name"],
                                     kind=s["kind"], entry=s, offset=offset,
                                     dim=self.dim,
                                     dtype_name=self.manifest["dtype"]))
            offset += int(s["n"])
        return views

    # -- resolutions (multi-resolution cascade artifact) -------------------
    def resolutions(self) -> list[SegmentView]:
        """Read handles on every coarse resolution (row-aligned with the
        base segment; ``dim`` is the resolution's m, ``dtype`` its own
        storage dtype). ``DenseIndex.load`` works on a view unchanged."""
        return [SegmentView(store_path=self.path, name=r["name"],
                            kind="resolution", entry=r, offset=0,
                            dim=int(r["m"]), dtype_name=r["dtype"])
                for r in self.manifest.get("resolutions", ())]

    def add_resolution(self, vectors: np.ndarray, *,
                       scale: np.ndarray | None = None,
                       chunk_rows: int = 262144,
                       deltas: "Sequence[dict]" = ()) -> str:
        """Durably attach a coarse resolution: the (base_n, m) leading-
        column view of the base rows in its storage dtype (int8 rows with
        their own per-dim ``scale``, or f32). Blob-then-manifest-swap like
        every other segment mutation; refuses a duplicate m, a non-nested
        m, or a row count that disagrees with the base segment.

        ``deltas`` persists a segmented cascade's COARSE delta segments so
        a segmented load rehydrates them bit-for-bit instead of re-deriving
        (requantising) from the full deltas: each dict carries ``rows``
        (the n_real live rows in storage dtype — exactly the bytes served),
        ``scale`` (per-dim dequant scale or None) and ``capacity`` (the
        fixed padded dispatch shape). Their row counts must mirror the main
        delta segments one-for-one — the two views describe the same docs.
        """
        vectors = np.asarray(vectors)
        if vectors.ndim != 2:
            raise ValueError(f"add_resolution expects (rows, m), got shape "
                             f"{tuple(vectors.shape)}")
        seg_entries = self._segment_entries()
        base_n = int(seg_entries[0]["n"])
        n, m = vectors.shape
        if n != base_n:
            raise IndexStoreError(
                f"{self.path}: resolution has {n} rows, base segment has "
                f"{base_n}")
        if not 0 < m < self.dim:
            raise IndexStoreError(
                f"{self.path}: resolution m={m} does not nest inside "
                f"dim={self.dim}")
        deltas = list(deltas)
        main_delta_n = [int(s["n"]) for s in seg_entries[1:]]
        if deltas and [int(np.asarray(d["rows"]).shape[0])
                       for d in deltas] != main_delta_n:
            raise IndexStoreError(
                f"{self.path}: resolution delta rows "
                f"{[int(np.asarray(d['rows']).shape[0]) for d in deltas]} "
                f"do not mirror the main delta segments {main_delta_n} — "
                f"the views would describe different docs")
        manifest = json.loads(json.dumps(self.manifest))   # deep copy
        if any(int(r["m"]) == m for r in manifest.get("resolutions", ())):
            raise IndexStoreError(
                f"{self.path}: resolution m={m} already present")
        name = f"m{m}"
        entry = {"name": name, "m": m,
                 "dtype": _logical_dtype_name(vectors), "chunks": [],
                 "scale_file": None}
        for start in range(0, n, chunk_rows):
            fname, seq = self._next_blob(f"res_{name}")
            manifest["blob_seq"] = seq
            self.manifest["blob_seq"] = seq    # keep the counter monotonic
            block = vectors[start:min(start + chunk_rows, n)]
            _write_chunk(os.path.join(self.path, fname), block)
            entry["chunks"].append({"file": fname,
                                    "rows": int(block.shape[0])})
        if scale is not None:
            fname, seq = self._next_blob(f"scale_{name}")
            manifest["blob_seq"] = seq
            self.manifest["blob_seq"] = seq
            np.save(os.path.join(self.path, fname),
                    np.asarray(scale, np.float32))
            fsync_file(os.path.join(self.path, fname))
            entry["scale_file"] = fname
        if deltas:
            entry["deltas"] = []
            for di, d in enumerate(deltas):
                rows = np.asarray(d["rows"])
                if rows.ndim != 2 or rows.shape[1] != m:
                    raise ValueError(
                        f"resolution delta {di} expects (rows, {m}), got "
                        f"{tuple(rows.shape)}")
                dname = f"{name}-delta-{di:03d}"
                dent = {"name": dname, "n": int(rows.shape[0]),
                        "capacity": int(d["capacity"]),
                        "dtype": _logical_dtype_name(rows), "chunks": [],
                        "scale_file": None}
                if dent["n"] > dent["capacity"]:
                    raise IndexStoreError(
                        f"{self.path}: resolution delta {dname} holds "
                        f"{dent['n']} rows over its capacity "
                        f"{dent['capacity']}")
                if rows.shape[0]:
                    fname, seq = self._next_blob(f"res_{dname}")
                    manifest["blob_seq"] = seq
                    self.manifest["blob_seq"] = seq
                    _write_chunk(os.path.join(self.path, fname), rows)
                    dent["chunks"].append({"file": fname,
                                           "rows": int(rows.shape[0])})
                ds = d.get("scale")
                if ds is not None:
                    fname, seq = self._next_blob(f"scale_{dname}")
                    manifest["blob_seq"] = seq
                    self.manifest["blob_seq"] = seq
                    np.save(os.path.join(self.path, fname),
                            np.asarray(ds, np.float32))
                    fsync_file(os.path.join(self.path, fname))
                    dent["scale_file"] = fname
                entry["deltas"].append(dent)
        manifest.setdefault("resolutions", []).append(entry)
        self._swap_manifest(manifest)
        return name

    def resolution_deltas(self, name: str) -> list[SegmentView]:
        """Read handles on a resolution's persisted coarse delta segments
        (empty for a base-only resolution). ``dim`` is the resolution's m;
        offsets continue from the base rows in delta order, mirroring the
        main segment layout."""
        for r in self.manifest.get("resolutions", ()):
            if r["name"] == name:
                views, offset = [], int(self._segment_entries()[0]["n"])
                for d in r.get("deltas", ()):
                    views.append(SegmentView(
                        store_path=self.path, name=d["name"],
                        kind="resolution-delta", entry=d, offset=offset,
                        dim=int(r["m"]), dtype_name=d["dtype"]))
                    offset += int(d["n"])
                return views
        raise IndexStoreError(f"{self.path}: no resolution {name!r}")

    @property
    def flat_loadable(self) -> bool:
        """Whether the global chunk list is a coherent single index: one
        segment, no scales at all, or every segment sharing one scale —
        mixed per-segment scales need ``SegmentedIndex.load``."""
        segs = self._segment_entries()
        if len(segs) == 1:
            return True
        scales = [SegmentView(self.path, s["name"], s["kind"], s, 0,
                              self.dim, self.manifest["dtype"]).scale()
                  for s in segs]
        if all(s is None for s in scales):
            return True
        if any(s is None for s in scales):
            return False
        return all(np.array_equal(scales[0], s) for s in scales[1:])

    # -- append / segment mutation (incremental growth) --------------------
    def _next_blob(self, prefix: str = "vectors") -> str:
        """Unique blob name: a monotonically increasing sequence survives
        segment rewrites that delete earlier blobs (names never reused)."""
        seq = int(self.manifest.get("blob_seq",
                                    len(self.manifest["chunks"])))
        return f"{prefix}_{seq:06d}.npy", seq + 1

    def _swap_manifest(self, manifest: dict) -> None:
        """Atomic manifest replacement — the commit point of every segment
        mutation (all blobs must already be fsynced)."""
        tmp_manifest = os.path.join(self.path, MANIFEST + ".tmp")
        write_json_fsync(tmp_manifest, manifest)
        os.replace(tmp_manifest, os.path.join(self.path, MANIFEST))
        fsync_dir(self.path)
        self.manifest = manifest

    def _rebuild_global(self, manifest: dict) -> dict:
        """Re-derive the top-level n/chunks/scale_file from the segment
        list, keeping pre-segment readers (and validation) working on the
        global view. The top-level scale_file must track the BASE
        segment's: a base rewrite (``append_migrating`` widening the base)
        replaces and deletes the old scale blob, and a stale top-level
        pointer would fail validation forever after."""
        segs = manifest["segments"]
        manifest["chunks"] = [c for s in segs for c in s["chunks"]]
        manifest["n"] = sum(int(s["n"]) for s in segs)
        manifest["scale_file"] = segs[0].get("scale_file")
        return manifest

    def set_paged_state(self, block: dict) -> None:
        """Install/replace the ``paged`` lifecycle block in one manifest
        swap. Page bytes never move: promote and compact are pointer swaps
        in memory and exactly this metadata swap on disk."""
        manifest = json.loads(json.dumps(self.manifest))   # deep copy
        manifest["paged"] = block
        self._swap_manifest(manifest)

    def add_delta(self, scale: np.ndarray | None = None,
                  capacity: int | None = None) -> str:
        """Open a new (empty) delta segment with its own scale; returns its
        name. Converts a pre-segment manifest to the segmented layout (the
        existing vectors become the base segment, bit-untouched)."""
        manifest = json.loads(json.dumps(self.manifest))   # deep copy
        segs = manifest.setdefault("segments", self._segment_entries())
        name = f"delta-{len(segs):03d}"
        entry = {"name": name, "kind": "delta", "n": 0, "chunks": [],
                 "scale_file": None}
        if capacity is not None:
            entry["capacity"] = int(capacity)
        if scale is not None:
            fname, seq = self._next_blob(f"scale_{name}")
            np.save(os.path.join(self.path, fname), np.asarray(scale,
                                                               np.float32))
            fsync_file(os.path.join(self.path, fname))
            entry["scale_file"] = fname
            manifest["blob_seq"] = seq
        segs.append(entry)
        self._swap_manifest(self._rebuild_global(manifest))
        return name

    def _find_segment(self, manifest: dict, segment: str | None) -> dict:
        segs = manifest.get("segments")
        if segs is None:
            if segment not in (None, "base"):
                raise IndexStoreError(
                    f"{self.path}: no segment {segment!r} (pre-segment store)")
            return manifest                     # legacy: top-level IS the base
        if segment is None:
            return segs[-1]                     # the open (last) segment
        for s in segs:
            if s["name"] == segment:
                return s
        raise IndexStoreError(f"{self.path}: no segment {segment!r}")

    def append(self, block: np.ndarray, *, segment: str | None = None) -> None:
        """Durably append a row chunk (storage dtype) to a segment.

        ``segment=None`` targets the open (last) segment — the base on a
        pre-segment store, the newest delta on a segmented one. Protocol:
        chunk blob fsynced first, then the manifest atomically replaced
        (``os.replace``) and the directory fsynced — the manifest swap is
        the commit point.
        """
        block = np.asarray(block)
        if block.ndim != 2 or block.shape[1] != self.dim:
            raise ValueError(f"append expects (rows, {self.dim}), got "
                             f"{tuple(block.shape)}")
        if block.dtype.name != self.manifest["dtype"]:
            raise ValueError(f"append dtype {block.dtype.name} != store dtype "
                             f"{self.manifest['dtype']}")
        fname, seq = self._next_blob()
        _write_chunk(os.path.join(self.path, fname), block)
        manifest = json.loads(json.dumps(self.manifest))
        target = self._find_segment(manifest, segment)
        target["chunks"] = target["chunks"] + [
            {"file": fname, "rows": int(block.shape[0])}]
        target["n"] = int(target["n"]) + int(block.shape[0])
        manifest["blob_seq"] = seq
        if "segments" in manifest:
            manifest = self._rebuild_global(manifest)
        self._swap_manifest(manifest)

    def replace_segment(self, segment: str, blocks, *,
                        scale: np.ndarray | None = None) -> None:
        """Atomically rewrite one segment's contents (and scale).

        Used when a delta's int8 scale widens: the requantised rows replace
        the old chunks in one manifest swap. New blobs are written and
        fsynced first; the old blobs are deleted only after the swap, so a
        crash leaves either the old or the new segment — orphan blobs from
        the crash window are ignored by ``open`` (never named by the
        manifest). The rewrite cost is bounded by the segment's size.
        """
        manifest = json.loads(json.dumps(self.manifest))
        if "segments" not in manifest:
            manifest["segments"] = self._segment_entries()
        target = self._find_segment(manifest, segment)
        old_files = [c["file"] for c in target["chunks"]]
        old_scale = target.get("scale_file")
        chunks, total = [], 0
        for block in blocks:
            block = np.asarray(block)
            if block.dtype.name != self.manifest["dtype"]:
                raise ValueError(
                    f"replace dtype {block.dtype.name} != store dtype "
                    f"{self.manifest['dtype']}")
            fname, seq = self._next_blob()
            manifest["blob_seq"] = seq
            self.manifest["blob_seq"] = seq    # keep the counter monotonic
            _write_chunk(os.path.join(self.path, fname), block)
            chunks.append({"file": fname, "rows": int(block.shape[0])})
            total += int(block.shape[0])
        if scale is not None:
            fname, seq = self._next_blob(f"scale_{segment}")
            manifest["blob_seq"] = seq
            self.manifest["blob_seq"] = seq
            np.save(os.path.join(self.path, fname),
                    np.asarray(scale, np.float32))
            fsync_file(os.path.join(self.path, fname))
            target["scale_file"] = fname
        target["chunks"] = chunks
        target["n"] = total
        self._swap_manifest(self._rebuild_global(manifest))
        for f in old_files + ([old_scale] if scale is not None and old_scale
                              else []):
            try:
                os.remove(os.path.join(self.path, f))
            except OSError:
                pass

    def append_migrating(self, block: np.ndarray, *,
                         segment: str | None = None) -> bool:
        """Append f32 rows to an int8 segment, widening its scale instead
        of clipping (the scale-migration path, scoped per segment).

        If any value of ``block`` falls outside ±127 under the segment's
        current scale, the scale widens per-dim to fit and the segment's
        existing chunks requantise under it (dequantise with the old scale,
        requantise with the new — within half an old LSB of exact; callers
        holding the exact f32 rows should use ``replace_segment``
        directly). Returns True when the scale widened. On float stores
        this is a plain cast-and-append.
        """
        block = np.atleast_2d(np.asarray(block, np.float32))
        views = {v.name: v for v in self.segments()}
        target = self._find_segment(self.manifest, segment)
        name = target.get("name", "base")
        view = views.get(name, self.segments()[0])
        if self.dtype != np.int8:
            self.append(block.astype(self.dtype), segment=segment)
            return False
        from repro.core.quantization import quantize_with_scale, scale_for
        old = view.scale()
        if old is None:
            raise IndexStoreError(
                f"{self.path}: segment {name} is int8 but has no scale")
        need = scale_for(block)
        widened = bool((need > old).any())
        if not widened:
            self.append(quantize_with_scale(block, old), segment=segment)
            return False
        new_scale = np.maximum(old, need).astype(np.float32)
        requant = [
            quantize_with_scale(c.astype(np.float32) * old[None, :],
                                new_scale)
            for c in view.iter_chunks()]
        requant.append(quantize_with_scale(block, new_scale))
        if "segments" not in self.manifest:
            # pre-segment store: the rewrite touches the whole (base)
            # artifact — exactly the unbounded cost segmenting avoids
            self.manifest["segments"] = self._segment_entries()
        self.replace_segment(name, requant, scale=new_scale)
        return True
