"""PCA compression of recsys embedding-table columns (beyond-paper).

The paper prunes document-embedding dimensions. The same offline rotation
applies to the *item side* of recommender models: an embedding table
``T ∈ R^{V×E}`` is itself an embedding index, so ``T̂ = T·W_m`` shrinks
serving memory by m/E while any dot-product consumer transforms its other
operand once (`q̂ = W_mᵀq`). For two-tower retrieval this is exactly the
candidate index path; for CTR models the interaction layer consumes pruned
dims directly (with the small accuracy trade measured in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pruning import StaticPruner


def compress_tables(tables: list[jax.Array], *, cutoff: float = 0.5,
                    fit_rows: int = 100_000
                    ) -> tuple[list[jax.Array], StaticPruner]:
    """Fit one shared PCA over all tables' rows, prune every table.

    Tables share an embedding dim E; a single rotation keeps downstream
    dot products consistent across fields. Returns (pruned tables, pruner).
    """
    sample = jnp.concatenate(
        [t[: max(1, min(fit_rows // len(tables), t.shape[0]))] for t in tables],
        axis=0)
    pruner = StaticPruner(cutoff=cutoff).fit(sample)
    return [pruner.prune_index(t) for t in tables], pruner


def compressed_table_bytes(tables: list[jax.Array], cutoff: float = 0.5) -> dict:
    full = sum(t.size * t.dtype.itemsize for t in tables)
    pruned, pruner = compress_tables(tables, cutoff=cutoff)
    comp = sum(t.size * t.dtype.itemsize for t in pruned)
    return {"full_bytes": full, "pruned_bytes": comp,
            "ratio": comp / full, "kept_dims": pruner.kept_dims}
