"""Index maintenance: incremental updates + PCA drift monitoring.

Beyond-paper production plumbing for the pruned index. The paper shows the
transform is robust out-of-domain (RQ2) and to small fit samples (RQ3) —
this module turns those findings into operational policy:

  * ``IndexUpdater.add_documents`` — new documents are rotated with the
    EXISTING ``W_m`` and appended (no refit, no reindex of old docs): the
    offline artefact stays valid as the corpus grows. With a ``store``
    attached, every append also lands durably on disk, so incremental
    growth survives a restart.
  * ``drift_score`` — fraction of new-batch embedding energy captured by
    the kept subspace, ``||X W_m||² / ||X||²``, compared to the energy the
    subspace captured at fit time. A ratio near 1 ⇒ the rotation still
    fits (paper RQ2 regime); a falling ratio quantifies when the corpus
    distribution has moved enough to warrant an offline refit.
  * ``clip_fraction`` — int8 appends quantise with the *frozen* per-dim
    scale; values outside ±127·scale silently clip, degrading scores with
    no signal in the drift metric (clipping is per-value, drift is
    per-subspace). The updater tracks the fraction of clipped values over
    everything appended so far and folds it into ``needs_refit``.
  * ``needs_refit`` — thresholded policy hook for the serving controller.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import DenseIndex
from repro.core.pruning import StaticPruner


def captured_energy(X: jax.Array, pruner: StaticPruner) -> float:
    """||X W_m||^2 / ||X||^2 — energy the kept subspace explains on X."""
    W = pruner.state.components[:, :pruner.kept_dims]
    Xf = X.astype(jnp.float32)
    num = jnp.sum((Xf @ W) ** 2)
    den = jnp.maximum(jnp.sum(Xf ** 2), 1e-30)
    return float(num / den)


@dataclasses.dataclass
class IndexUpdater:
    """Pruned index + transform with incremental growth and drift tracking.

    ``fit_energy`` may be left unset (a directly-constructed updater): the
    reference energy is then derived lazily from the fitted state — for an
    uncentered fit ``||D W_m||²/||D||² = Σ_{i≤m} λ_i / Σ λ_i``, and a
    centered fit adds the mean's energy on both sides (see
    ``_reference_energy``) — exact either way, so no fit-corpus pass is
    needed.

    ``store``: an optional ``IndexStore`` (or path) the updater appends
    through — each ``add_documents`` block is durably appended so the
    on-disk artifact tracks the in-memory index.
    """

    pruner: StaticPruner
    index: DenseIndex
    fit_energy: float | None = None  # energy on the fit corpus (reference)
    store: object | None = None      # IndexStore | str | None
    # int8 clip telemetry over everything appended so far
    clipped_values: int = 0
    appended_values: int = 0

    def __post_init__(self):
        from repro.core.store import IndexStore
        if isinstance(self.store, (str, bytes)) or hasattr(self.store, "__fspath__"):
            self.store = IndexStore.open(self.store)

    @classmethod
    def build(cls, corpus: jax.Array, *, cutoff: float = 0.5,
              quantize_int8: bool = False,
              store_path: str | None = None) -> "IndexUpdater":
        """Fit + build in memory; with ``store_path``, also persist the
        artifact and attach the committed store for durable appends."""
        pruner = StaticPruner(cutoff=cutoff).fit(corpus)
        index = pruner.build_index(corpus, quantize_int8=quantize_int8)
        store = None
        if store_path is not None:
            from repro.core.store import save_index
            store = save_index(store_path, index, pruner=pruner)
        return cls(pruner=pruner, index=index,
                   fit_energy=captured_energy(corpus, pruner), store=store)

    @classmethod
    def from_store(cls, store, *, backend: str = "jnp") -> "IndexUpdater":
        """Rehydrate updater state from a committed artifact (cold start).

        ``fit_energy`` stays lazy — the fit corpus is not in the store, and
        the eigenvalue identity gives the same reference.
        """
        from repro.core.store import IndexStore
        if not isinstance(store, IndexStore):
            store = IndexStore.open(store)
        return cls(pruner=store.load_pruner(),
                   index=DenseIndex.load(store, backend=backend),
                   store=store)

    # -- incremental growth ------------------------------------------------
    def add_documents(self, new_embs: jax.Array) -> float:
        """Rotate with the existing W_m and append (no refit).

        Returns this batch's int8 clip fraction (0.0 on float indexes):
        the fraction of quantised values that fell outside ±127 under the
        frozen per-dim scale and were clipped.
        """
        pruned = self.pruner.prune_index(new_embs)
        batch_clip = 0.0
        if self.index.scale is not None:
            raw = jnp.round(pruned / self.index.scale[None, :])
            clipped = jnp.sum(jnp.abs(raw) > 127)
            batch_clip = float(clipped) / max(raw.size, 1)
            self.clipped_values += int(clipped)
            self.appended_values += int(raw.size)
            new = jnp.clip(raw, -127, 127).astype(jnp.int8)
        else:
            new = pruned.astype(self.index.vectors.dtype)
        self.index = DenseIndex(
            vectors=jnp.concatenate([self.index.vectors, new], axis=0),
            scale=self.index.scale, backend=self.index.backend)
        if self.store is not None:
            self.store.append(np.asarray(new))
        return batch_clip

    @property
    def clip_fraction(self) -> float:
        """Fraction of clipped values over every int8 append so far."""
        if self.appended_values == 0:
            return 0.0
        return self.clipped_values / self.appended_values

    # -- drift policy ------------------------------------------------------
    def _reference_energy(self) -> float:
        if self.fit_energy is None:
            state = self.pruner.state
            m = self.pruner.kept_dims
            lam = np.asarray(state.eigenvalues, np.float64)
            # captured_energy is an *uncentered* ratio. Uncentered fit:
            # ||D W_m||²/||D||² = Σ_{i≤m} λ_i / Σ λ_i (mean is zeros, the
            # correction terms vanish). Centered fit: the Gram is
            # n·(C + μμᵀ), so the same ratio gains the mean's energy —
            # (Σ_{i≤m} λ_i + ||W_mᵀμ||²) / (Σ λ_i + ||μ||²). Both exact.
            mu = np.asarray(state.mean, np.float64)
            W = np.asarray(state.components, np.float64)[:, :m]
            num = float(lam[:m].sum()) + float(np.sum((W.T @ mu) ** 2))
            den = float(lam.sum()) + float(np.sum(mu ** 2))
            self.fit_energy = num / max(den, 1e-30)
        return self.fit_energy

    def drift_score(self, new_embs: jax.Array) -> float:
        """1.0 = no drift; < 1.0 = kept subspace explains less energy on the
        new batch than it did on the fit corpus."""
        return captured_energy(new_embs, self.pruner) / max(
            self._reference_energy(), 1e-12)

    def needs_refit(self, new_embs: jax.Array, threshold: float = 0.9,
                    clip_threshold: float = 0.01) -> bool:
        """Refit when the subspace drifted *or* the frozen int8 scale is
        clipping more than ``clip_threshold`` of appended values — clipping
        degrades scores even when the subspace still fits."""
        if self.clip_fraction > clip_threshold:
            return True
        return self.drift_score(new_embs) < threshold

    def refit(self, corpus: jax.Array) -> None:
        """Offline refit on the current corpus distribution."""
        cutoff = self.pruner.effective_cutoff
        quant = self.index.scale is not None
        fresh = IndexUpdater.build(corpus, cutoff=cutoff,
                                   quantize_int8=quant)
        self.pruner, self.index, self.fit_energy = (fresh.pruner, fresh.index,
                                                    fresh.fit_energy)
        self.clipped_values = self.appended_values = 0
        if self.store is not None:
            # the old artifact is invalid under the new rotation — replace
            # it atomically at the same path
            from repro.core.store import save_index
            self.store = save_index(self.store.path, self.index,
                                    pruner=self.pruner)

    def search(self, queries: jax.Array, k: int = 10):
        return self.index.search(self.pruner.transform_queries(queries), k=k)
