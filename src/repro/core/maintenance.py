"""Index maintenance: segmented live growth + drift-triggered compaction.

Beyond-paper production plumbing for the pruned index. The paper shows the
transform is robust out-of-domain (RQ2) and to small fit samples (RQ3) —
this module turns those findings into operational policy over a
**segmented** index (``repro.core.index.SegmentedIndex``):

  * ``IndexUpdater.add_documents`` — new documents are rotated with the
    EXISTING ``W_m`` and appended to the open *delta segment* (no refit, no
    reindex of old docs). Each delta carries its OWN int8 scale, widened
    per append block when needed, so nothing ever clips against the base's
    frozen scale — the clip problem the monolithic updater could only
    *measure* is killed at the root. With a ``store`` attached, every
    append mirrors durably to disk (the quantised bytes on disk are the
    bytes being served); with a ``server`` attached, every append installs
    the new segment set atomically between in-flight batches
    (``RetrievalServer.swap_index``).
  * ``drift_score`` — fraction of new-batch embedding energy captured by
    the kept subspace, ``||X W_m||² / ||X||²``, compared to the energy the
    subspace captured at fit time. A ratio near 1 ⇒ the rotation still
    fits (paper RQ2 regime); a falling ratio quantifies when the corpus
    distribution has moved enough to warrant an offline refit.
  * ``scale_divergence`` / ``delta_fraction`` — how far the delta scales
    have widened past the base's, and how much of the corpus lives outside
    the base. Either climbing is the compaction signal.
  * ``needs_refit`` — thresholded policy over all three signals.
  * ``compact()`` — streaming re-build of base+deltas into ONE fresh base
    segment (same rotation, fresh corpus-wide scale) through
    ``StaticPruner.build_index_to(already_projected=True)``; commits
    atomically at the store path, swaps into the server, retires the old
    segments. ``compact_async()`` runs it off-thread — appends that land
    mid-compaction are reconciled onto the new base before the swap.
"""
from __future__ import annotations

import dataclasses
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import DenseIndex, SegmentedIndex, ShardedDenseIndex
from repro.core.pruning import StaticPruner


def _new_rlock():
    """Call-time ``threading.RLock`` lookup for the dataclass default.

    ``default_factory=threading.RLock`` freezes the lock class at module
    import; an instrumented ``threading.RLock`` (see
    ``repro.analysis.lock_sanitizer``) installed later would be ignored
    for every new updater. Resolving at call time keeps construction
    late-bound.
    """
    return threading.RLock()


def _eigval_energy(pruner: StaticPruner) -> float:
    """Reference captured energy from the fitted state alone.

    ``captured_energy`` is an *uncentered* ratio. Uncentered fit:
    ``||D W_m||²/||D||² = Σ_{i≤m} λ_i / Σ λ_i`` (mean is zeros, the
    correction terms vanish). Centered fit: the Gram is ``n·(C + μμᵀ)``,
    so the same ratio gains the mean's energy —
    ``(Σ_{i≤m} λ_i + ||W_mᵀμ||²) / (Σ λ_i + ||μ||²)``. Both exact.
    """
    state = pruner.state
    m = pruner.kept_dims
    lam = np.asarray(state.eigenvalues, np.float64)
    mu = np.asarray(state.mean, np.float64)
    W = np.asarray(state.components, np.float64)[:, :m]
    num = float(lam[:m].sum()) + float(np.sum((W.T @ mu) ** 2))
    den = float(lam.sum()) + float(np.sum(mu ** 2))
    return num / max(den, 1e-30)


def captured_energy(X: jax.Array, pruner: StaticPruner) -> float:
    """||X W_m||^2 / ||X||^2 — energy the kept subspace explains on X."""
    W = pruner.state.components[:, :pruner.kept_dims]
    Xf = X.astype(jnp.float32)
    num = jnp.sum((Xf @ W) ** 2)
    den = jnp.maximum(jnp.sum(Xf ** 2), 1e-30)
    return float(num / den)


@dataclasses.dataclass
class IndexUpdater:
    """Segmented pruned index + transform with live growth and compaction.

    ``index`` may be handed in as a bare ``DenseIndex``/``ShardedDenseIndex``
    (it is wrapped as a single-base ``SegmentedIndex``) or as a segment set.

    ``fit_energy`` may be left unset (a directly-constructed updater): the
    reference energy is then derived lazily from the fitted state — for an
    uncentered fit ``||D W_m||²/||D||² = Σ_{i≤m} λ_i / Σ λ_i``, and a
    centered fit adds the mean's energy on both sides (see
    ``_reference_energy``) — exact either way, so no fit-corpus pass is
    needed.

    ``store``: an optional ``IndexStore`` (or path) the updater appends
    through — every delta mutation lands durably so the on-disk artifact
    tracks the in-memory segments bit-for-bit. ``server``: an optional
    ``RetrievalServer`` that receives the new segment set via
    ``swap_index`` after every mutation.
    """

    pruner: StaticPruner
    index: SegmentedIndex
    fit_energy: float | None = None  # energy on the fit corpus (reference)
    store: object | None = None      # IndexStore | str | None
    server: object | None = None     # RetrievalServer | None
    delta_capacity: int = 4096
    # telemetry
    appended_rows: int = 0
    compactions: int = 0
    # last compaction's cost receipt. Paged: {"pages_moved", "pages_freed",
    # "pages_host"} — pointer swaps, the true cost unit (a rows-copied
    # number would claim O(corpus) work the paged path never does).
    # Segmented streaming rebuild: {"rows_rebuilt"}.
    last_compaction: dict | None = None
    # background-thread failures (compact_async and any future maintenance
    # thread): a swallowed exception is an operational lie — the fleet
    # health check reads this list, so a dead compaction surfaces instead
    # of silently leaving the deltas to grow forever
    background_errors: list = dataclasses.field(default_factory=list)
    _lock: threading.RLock = dataclasses.field(default_factory=_new_rlock,
                                               repr=False, compare=False)

    def __post_init__(self):
        from repro.core.store import IndexStore
        if isinstance(self.store, (str, bytes)) or hasattr(self.store,
                                                           "__fspath__"):
            self.store = IndexStore.open(self.store)
        if isinstance(self.index, (DenseIndex, ShardedDenseIndex)):
            self.index = SegmentedIndex.from_index(
                self.index, delta_capacity=self.delta_capacity)

    @classmethod
    def build(cls, corpus: jax.Array, *, cutoff: float = 0.5,
              quantize_int8: bool = False,
              store_path: str | None = None,
              delta_capacity: int = 4096, paged: bool = False,
              page_rows: int = 256,
              pool_pages: int | None = None) -> "IndexUpdater":
        """Fit + build in memory; with ``store_path``, also persist the
        artifact and attach the committed store for durable appends.
        ``paged=True`` serves through ``PagedIndex`` (pointer-swap
        lifecycle; ``pool_pages`` below the corpus page count
        oversubscribes device memory)."""
        from repro.core.paged import PagedIndex
        pruner = StaticPruner(cutoff=cutoff).fit(corpus)
        base = pruner.build_index(corpus, quantize_int8=quantize_int8)
        if paged:
            index = PagedIndex.from_index(base, page_rows=page_rows,
                                          pool_pages=pool_pages,
                                          seal_rows=delta_capacity)
        else:
            index = SegmentedIndex.from_index(base,
                                              delta_capacity=delta_capacity)
        store = None
        if store_path is not None:
            from repro.core.store import save_index
            store = save_index(store_path, index if paged else base,
                               pruner=pruner)
        return cls(pruner=pruner, index=index,
                   fit_energy=captured_energy(corpus, pruner), store=store,
                   delta_capacity=delta_capacity)

    @classmethod
    def from_store(cls, store, *, backend: str = "jnp",
                   mesh=None, delta_capacity: int = 4096,
                   paged: bool | None = None,
                   pool_pages: int | None = None) -> "IndexUpdater":
        """Rehydrate updater state from a committed artifact (cold start) —
        base AND delta segments, each with its own scale. ``paged=None``
        auto-detects: a store carrying the ``paged`` manifest block reloads
        as a ``PagedIndex``.

        ``fit_energy`` stays lazy — the fit corpus is not in the store, and
        the eigenvalue identity gives the same reference.
        """
        from repro.core.store import IndexStore
        if not isinstance(store, IndexStore):
            store = IndexStore.open(store)
        if paged is None:
            paged = "paged" in store.manifest
        if paged:
            from repro.core.paged import PagedIndex
            index = PagedIndex.load(store, backend=backend,
                                    pool_pages=pool_pages)
        else:
            index = SegmentedIndex.load(store, mesh=mesh, backend=backend,
                                        delta_capacity=delta_capacity)
        return cls(pruner=store.load_pruner(), index=index,
                   store=store, delta_capacity=delta_capacity)

    # -- incremental growth ------------------------------------------------
    def add_documents(self, new_embs: jax.Array) -> int:
        """Rotate with the existing W_m and append to the open delta.

        Copy-on-write: a NEW segment set is built, mirrored to the store
        (open/extend/widen ops with the exact quantised bytes), then
        installed into the attached server atomically. Nothing ever clips:
        an int8 delta's scale widens per-dim to fit every appended block
        (requantised from the exact f32 staging — the rewrite is bounded by
        the open delta's capacity). Returns the number of rows appended.
        """
        with self._lock:
            pruner = self.pruner
        # the rotation runs OUTSIDE the lock (device work must not block
        # concurrent telemetry); the append below re-takes it
        pruned = np.asarray(pruner.prune_index(new_embs), np.float32)
        with self._lock:
            new_index, ops = self.index.append_with_ops(pruned)
            self._mirror_ops(ops, new_index)
            self.index = new_index
            self.appended_rows += pruned.shape[0]
            # swap INSIDE the lock: a preempted thread must not install a
            # segment set an already-completed append/compaction superseded
            if self.server is not None:
                self.server.swap_index(new_index)
        return int(pruned.shape[0])

    def _mirror_ops(self, ops, new_index) -> None:
        """Replay append ops durably. The op stream is identical for
        segmented and paged indexes; only the delta-ordinal -> store-segment
        mapping differs (paged: extents are segments positionally, with
        base extents a prefix — delta ordinal di is extent/segment
        ``n_base + di``). A paged mirror finishes with the lifecycle-block
        swap, which may lag the segment ops across a crash (the loader
        reconstructs; ``IndexStore._validate_paged``)."""
        if self.store is None:
            return
        paged = hasattr(new_index, "storage")
        if paged:
            base_idx = sum(1 for e in new_index.storage.extents
                           if e.kind == "base")
            capacity = new_index.storage.seal_rows
        else:
            base_idx = 1
        names = [v.name for v in self.store.segments()]
        for op in ops:
            kind, di = op[0], op[1]
            seg_idx = base_idx + di                # store segment position
            if kind == "open":
                _, _, stored, scale = op
                cap = capacity if paged else new_index.deltas[di].capacity
                name = self.store.add_delta(scale=scale, capacity=cap)
                names.append(name)
                if stored.shape[0]:
                    self.store.append(stored, segment=name)
            elif kind == "extend":
                _, _, stored = op
                self.store.append(stored, segment=names[seg_idx])
            else:                                   # widen: bounded rewrite
                _, _, stored, scale = op
                self.store.replace_segment(names[seg_idx], [stored],
                                           scale=scale)
        if paged:
            from repro.core.store import paged_manifest_block
            self.store.set_paged_state(
                paged_manifest_block(new_index.storage))

    # -- telemetry ---------------------------------------------------------
    @property
    def clip_fraction(self) -> float:
        """Always 0.0: per-delta scales widen instead of clipping. Kept as
        an explicit invariant (and for dashboards that tracked it when the
        monolithic updater could only report the damage)."""
        return 0.0

    @property
    def delta_fraction(self) -> float:
        """Fraction of the corpus living outside the compacted base.

        Paged index: counted in PAGES (``delta_pages / total_pages``), the
        unit compaction actually pays in — pointer swaps per page. The old
        rows-over-corpus ratio undercounted a delta of many part-filled
        pages (the fleet auto-compaction controller then waited too long),
        and a capacity-based ratio would overcount sealed-but-short
        extents that cost nothing to promote."""
        with self._lock:
            index = self.index
        pages = getattr(index, "total_pages", None)
        if pages is not None:
            return index.delta_pages / pages if pages else 0.0
        n = index.n
        return index.delta_rows / n if n else 0.0

    def scale_divergence(self) -> float:
        """max over deltas of max-dim ratio (delta scale / base scale) —
        how far live data has outgrown the base's quantisation regime.
        1.0 when unquantised or no deltas have widened past the base."""
        with self._lock:
            index = self.index
        if hasattr(index, "storage"):             # paged: extents carry it
            exts = index.storage.extents
            base_scale = exts[0].scale if exts else None
            dscales = [e.scale for e in exts if e.kind == "delta"]
        else:
            base_scale = index.base.scale
            dscales = [d.scale for d in index.deltas]
        if base_scale is None or not dscales:
            return 1.0
        b = np.asarray(base_scale, np.float64)
        worst = 1.0
        for s in dscales:
            if s is not None:
                worst = max(worst, float(np.max(np.asarray(s,
                                                           np.float64) / b)))
        return worst

    # -- drift policy ------------------------------------------------------
    def _reference_energy(self) -> float:
        with self._lock:
            if self.fit_energy is not None:
                return self.fit_energy
            pruner = self.pruner
        # the device->host transfers inside the eigenvalue identity run
        # UNLOCKED; only the cache fill re-takes the lock (and discards
        # the result if a refit swapped the pruner meanwhile)
        ref = _eigval_energy(pruner)
        with self._lock:
            if self.fit_energy is None and self.pruner is pruner:
                self.fit_energy = ref
            return self.fit_energy if self.fit_energy is not None else ref

    def drift_score(self, new_embs: jax.Array) -> float:
        """1.0 = no drift; < 1.0 = kept subspace explains less energy on the
        new batch than it did on the fit corpus."""
        with self._lock:
            pruner = self.pruner
        ref = self._reference_energy()
        return captured_energy(new_embs, pruner) / max(ref, 1e-12)

    def needs_refit(self, new_embs: jax.Array, threshold: float = 0.9,
                    delta_threshold: float = 0.5,
                    scale_threshold: float = 4.0) -> bool:
        """Compact/refit when the subspace drifted, the deltas hold more
        than ``delta_threshold`` of the corpus, *or* a delta scale has
        widened more than ``scale_threshold``x past the base's — widened
        scales never clip, but they do coarsen the quantisation grid for
        everything in that delta."""
        if self.delta_fraction > delta_threshold:
            return True
        if self.scale_divergence() > scale_threshold:
            return True
        return self.drift_score(new_embs) < threshold

    # -- compaction --------------------------------------------------------
    def _iter_dequant_rows(self, index: SegmentedIndex, block_rows: int,
                           store) -> "object":
        """Stream base+delta rows as f32 blocks in global id order.

        ``store`` is the caller's locked snapshot of ``self.store`` (or
        None): the generator runs unlocked while appends mirror to the
        live store, so it must never re-read the field mid-stream. With a
        store the base streams from DISK (host O(block)); otherwise from
        the device copy. Deltas stream from their exact f32 staging either
        way.
        """
        if store is not None:
            base_view = store.segments()[0]
            scale = base_view.scale()
            for lo in range(0, base_view.n, block_rows):
                rows = base_view.read_rows(lo, min(lo + block_rows,
                                                   base_view.n))
                rows = rows.astype(np.float32)
                if scale is not None:
                    rows = rows * scale[None, :].astype(np.float32)
                yield rows
        else:
            base = index.base
            scale = (None if base.scale is None
                     else np.asarray(base.scale, np.float32))
            v = np.asarray(base.vectors[:base.n])
            for lo in range(0, base.n, block_rows):
                rows = v[lo:lo + block_rows].astype(np.float32)
                if scale is not None:
                    rows = rows * scale[None, :]
                yield rows
        for d in index.deltas:
            for lo in range(0, d.n_real, block_rows):
                yield d.raw[lo:lo + block_rows]

    def _compact_paged(self) -> None:
        """Paged compaction: seal + promote every delta extent and drain
        tail pages into free pool slots — pointer swaps plus ONE fused
        gather dispatch, never a corpus rebuild. Cheap enough to run
        entirely under the lock (no racing-append reconcile needed); on
        disk it is a single lifecycle-block manifest swap (the page bytes
        already mirrored at append time)."""
        with self._lock:
            new_index, stats = self.index.compact_pages()
            if self.store is not None:
                from repro.core.store import paged_manifest_block
                self.store.set_paged_state(
                    paged_manifest_block(new_index.storage))
            self.index = new_index
            self.compactions += 1
            self.last_compaction = dict(stats)
            if self.server is not None:
                self.server.swap_index(new_index)

    def compact(self, *, block_rows: int = 65536) -> None:
        """Merge base + deltas into ONE fresh base segment and swap it in.

        The rotation (``W_m``) is unchanged — compaction re-homogenises the
        quantisation: a single fresh corpus-wide scale replaces the base's
        frozen scale and every widened delta scale. Rows stream through
        ``StaticPruner.build_index_to(already_projected=True)`` (O(block)
        host memory, int8 spill). With a store attached the new artifact
        builds UNLOCKED at a sidecar path (``<path>.compact`` — appends
        keep mirroring to the live store meanwhile) and only the final
        directory swap into the live path (``commit_dir`` rename-aside — a
        crash leaves the old or the new artifact, never neither) happens
        under the updater lock, so no append mirror can interleave with the
        replacement and scribble a stale manifest over the fresh artifact.
        The attached server receives the new segment set between batches.
        Appends racing a background compaction are reconciled: rows landed
        after the snapshot re-append onto the fresh base before the swap.
        """
        with self._lock:
            snapshot, pruner = self.index, self.pruner
            store, n_compactions = self.store, self.compactions
        if hasattr(snapshot, "compact_pages"):
            self._compact_paged()
            return
        quant = snapshot.quantized
        mesh = getattr(snapshot.base, "mesh", None)
        backend = snapshot.base.backend
        if store is not None:
            from repro.checkpoint.manager import commit_dir
            from repro.core.store import IndexStore
            side_path = store.path + ".compact"
            side = pruner.build_index_to(
                side_path,
                lambda: self._iter_dequant_rows(snapshot, block_rows, store),
                quantize_int8=quant, already_projected=True,
                meta={"compactions": n_compactions + 1})
            # the base's device arrays materialise from the sidecar BEFORE
            # the lock: the expensive load never blocks appends
            if mesh is not None:
                base = ShardedDenseIndex.load(side, mesh, backend=backend,
                                              merge=snapshot.base.merge)
            else:
                base = DenseIndex.load(side, backend=backend)
        else:
            side_path = None
            rows = np.concatenate(
                list(self._iter_dequant_rows(snapshot, block_rows, None)))
            if mesh is not None:
                base = ShardedDenseIndex.build(jnp.asarray(rows), mesh,
                                               quantize_int8=quant,
                                               backend=backend,
                                               merge=snapshot.base.merge)
            else:
                base = DenseIndex.build(jnp.asarray(rows),
                                        quantize_int8=quant, backend=backend)
        fresh = SegmentedIndex.from_index(base,
                                          delta_capacity=self.delta_capacity)
        with self._lock:
            if side_path is not None:
                commit_dir(side_path, self.store.path)   # atomic retire
                self.store = IndexStore.open(self.store.path)
            # reconcile rows appended while the compaction streamed: the
            # current segment set extends the snapshot row-for-row, so the
            # tail [snapshot.n:) is exactly the racing appends
            tail = []
            for d in self.index.deltas:
                tail.append(d.raw)
            tail_rows = (np.concatenate(tail)[snapshot.delta_rows:]
                         if tail else np.zeros((0, snapshot.dim), np.float32))
            if tail_rows.shape[0]:
                fresh, ops = fresh.append_with_ops(tail_rows)
                self._mirror_ops(ops, fresh)
            self.index = fresh
            self.compactions += 1
            self.last_compaction = {"rows_rebuilt": int(fresh.n)}
            if self.server is not None:
                self.server.swap_index(fresh)

    def compact_async(self, **kw) -> threading.Thread:
        """Run ``compact`` off-thread: the serving path keeps dispatching
        against the old segment set until the finished base swaps in.

        A crash in the background thread is RECORDED, not swallowed: the
        exception lands in ``background_errors`` (read by ``health()`` and
        the fleet's rollout/auto-compaction health checks), so a dead
        compaction can fail a health probe instead of leaving the deltas
        to grow unboundedly with nobody the wiser."""
        def _run():
            import time as _time
            try:
                self.compact(**kw)
            except BaseException as e:   # noqa: BLE001 — recorded, re-raised
                with self._lock:
                    self.background_errors.append(
                        {"op": "compact", "error": repr(e),
                         "time": _time.time()})
                raise
        th = threading.Thread(target=_run, daemon=True)
        th.start()
        return th

    def health(self) -> dict:
        """Maintenance health snapshot: ok iff no background thread has
        died. ``background_errors`` is a copy — callers can't tear it."""
        with self._lock:
            errs = list(self.background_errors)
            compactions = self.compactions
            appended = self.appended_rows
            last = (None if self.last_compaction is None
                    else dict(self.last_compaction))
        return {"ok": not errs, "background_errors": errs,
                "compactions": compactions, "appended_rows": appended,
                "last_compaction": last}

    def refit(self, corpus: jax.Array) -> None:
        """Full offline refit (new rotation) on the current corpus
        distribution — unlike ``compact``, this re-fits ``W_m`` itself.
        The base keeps its layout: a sharded base refits onto the same
        mesh/merge/backend instead of collapsing onto one device."""
        with self._lock:
            old_index, old_pruner = self.index, self.pruner
        cutoff = old_pruner.effective_cutoff
        quant = old_index.quantized
        paged = hasattr(old_index, "storage")
        old_base = getattr(old_index, "base", None)
        mesh = getattr(old_base, "mesh", None)
        backend = old_index.backend if paged else old_base.backend
        pruner = StaticPruner(cutoff=cutoff).fit(corpus)
        if mesh is not None:
            base = ShardedDenseIndex.build(
                pruner.prune_index(corpus), mesh, quantize_int8=quant,
                backend=backend, merge=old_base.merge)
        else:
            base = pruner.build_index(corpus, quantize_int8=quant,
                                      backend=backend)
        if paged:
            from repro.core.paged import PagedIndex
            new_index = PagedIndex.from_index(
                base, page_rows=old_index.storage.page_rows,
                seal_rows=old_index.storage.seal_rows,
                backend=backend, depth=old_index.depth,
                wave_pages=old_index.wave_pages)
        else:
            new_index = SegmentedIndex.from_index(
                base, delta_capacity=self.delta_capacity)
        energy = captured_energy(corpus, pruner)
        with self._lock:
            self.pruner, self.index, self.fit_energy = (pruner, new_index,
                                                        energy)
            self.appended_rows = 0
            if self.store is not None:
                # the old artifact is invalid under the new rotation —
                # replace it atomically at the same path
                from repro.core.store import save_index
                self.store = save_index(
                    self.store.path,
                    self.index if paged else self.index.base,
                    pruner=self.pruner)
            if self.server is not None:
                self.server.swap_index(self.index, pruner=self.pruner)

    def search(self, queries: jax.Array, k: int = 10):
        with self._lock:
            index, pruner = self.index, self.pruner
        return index.search(pruner.transform_queries(queries), k=k)
