"""Index maintenance: incremental updates + PCA drift monitoring.

Beyond-paper production plumbing for the pruned index. The paper shows the
transform is robust out-of-domain (RQ2) and to small fit samples (RQ3) —
this module turns those findings into operational policy:

  * ``IndexUpdater.add_documents`` — new documents are rotated with the
    EXISTING ``W_m`` and appended (no refit, no reindex of old docs): the
    offline artefact stays valid as the corpus grows.
  * ``drift_score`` — fraction of new-batch embedding energy captured by
    the kept subspace, ``||X W_m||² / ||X||²``, compared to the energy the
    subspace captured at fit time. A ratio near 1 ⇒ the rotation still
    fits (paper RQ2 regime); a falling ratio quantifies when the corpus
    distribution has moved enough to warrant an offline refit.
  * ``needs_refit`` — thresholded policy hook for the serving controller.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.index import DenseIndex
from repro.core.pruning import StaticPruner


def captured_energy(X: jax.Array, pruner: StaticPruner) -> float:
    """||X W_m||^2 / ||X||^2 — energy the kept subspace explains on X."""
    W = pruner.state.components[:, :pruner.kept_dims]
    Xf = X.astype(jnp.float32)
    num = jnp.sum((Xf @ W) ** 2)
    den = jnp.maximum(jnp.sum(Xf ** 2), 1e-30)
    return float(num / den)


@dataclasses.dataclass
class IndexUpdater:
    """Pruned index + transform with incremental growth and drift tracking."""

    pruner: StaticPruner
    index: DenseIndex
    fit_energy: float = None  # energy on the fit corpus (reference point)

    @classmethod
    def build(cls, corpus: jax.Array, *, cutoff: float = 0.5,
              quantize_int8: bool = False) -> "IndexUpdater":
        pruner = StaticPruner(cutoff=cutoff).fit(corpus)
        index = pruner.build_index(corpus, quantize_int8=quantize_int8)
        return cls(pruner=pruner, index=index,
                   fit_energy=captured_energy(corpus, pruner))

    def add_documents(self, new_embs: jax.Array) -> None:
        """Rotate with the existing W_m and append (no refit)."""
        pruned = self.pruner.prune_index(new_embs)
        if self.index.scale is not None:
            q = jnp.clip(jnp.round(pruned / self.index.scale[None, :]),
                         -127, 127).astype(jnp.int8)
            vectors = jnp.concatenate([self.index.vectors, q], axis=0)
        else:
            vectors = jnp.concatenate(
                [self.index.vectors, pruned.astype(self.index.vectors.dtype)],
                axis=0)
        self.index = DenseIndex(vectors=vectors, scale=self.index.scale,
                                backend=self.index.backend)

    def drift_score(self, new_embs: jax.Array) -> float:
        """1.0 = no drift; < 1.0 = kept subspace explains less energy on the
        new batch than it did on the fit corpus."""
        return captured_energy(new_embs, self.pruner) / max(self.fit_energy,
                                                            1e-12)

    def needs_refit(self, new_embs: jax.Array, threshold: float = 0.9) -> bool:
        return self.drift_score(new_embs) < threshold

    def refit(self, corpus: jax.Array) -> None:
        """Offline refit on the current corpus distribution."""
        cutoff = self.pruner.effective_cutoff
        quant = self.index.scale is not None
        fresh = IndexUpdater.build(corpus, cutoff=cutoff,
                                   quantize_int8=quant)
        self.pruner, self.index, self.fit_energy = (fresh.pruner, fresh.index,
                                                    fresh.fit_energy)

    def search(self, queries: jax.Array, k: int = 10):
        return self.index.search(self.pruner.transform_queries(queries), k=k)
