"""Dense embedding index with exact (brute-force) top-k search.

This is the FAISS-flat role in the paper's pipeline, built TPU-native:

  * ``DenseIndex``        — single-logical-array index, matmul + top-k.
                            Backend 'jnp' (XLA) or 'pallas' (fused
                            score-and-select scan; see repro.kernels).
  * ``ShardedDenseIndex`` — rows sharded over every mesh device; each shard
                            scans locally, then a tiny global merge over the
                            per-shard top-k (k·chips candidates).
  * int8 symmetric quantisation (beyond-paper) composes with PCA pruning:
    index bytes drop by 4x on top of the m/d PCA reduction.

Scores are always accumulated in fp32 regardless of index dtype.
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.par import compat


Backend = Literal["jnp", "pallas"]
Merge = Literal["flat", "hierarchical"]


def project_queries(q: jax.Array, W: jax.Array,
                    scale: jax.Array | None = None,
                    mean: jax.Array | None = None) -> jax.Array:
    """q̂ = ((q − mean) @ W_m) ⊙ scale — the full raw-query-to-search-query
    transform (PCA projection + int8 dequant fold), written to be traced
    inline inside the fused ``search_projected`` jits.

    Operation order deliberately mirrors the two-step path
    (``transform_query`` then ``_dequeries``) — cast to f32, center,
    project, then fold the scale — so for f32 raw queries (the serving
    input) the fused dispatch is bit-identical to the separate-dispatch
    path (pinned by tests/test_sharded_parity.py). Lower-precision raw
    queries upcast here, whereas ``transform`` casts its result back to
    the input dtype — feed f32 when exact parity matters.
    """
    q = jnp.atleast_2d(q).astype(jnp.float32)
    if mean is not None:
        q = q - mean[None, :]
    q = q @ W
    if scale is not None:
        q = q * scale[None, :]
    return q


@partial(jax.jit, static_argnames=("k", "block", "backend"))
def _dense_search_projected(D, scale, W, mean, Q, k: int,
                            block: int | None, backend: Backend):
    """One compiled dispatch: projection + scale fold + fused top-k scan."""
    q = project_queries(Q, W, scale=scale, mean=mean)
    if backend == "pallas":
        from repro.kernels import ops as kops
        if block is None:
            return kops.topk_score(D, q, k=k)
        return kops.topk_score(D, q, k=k, block_n=block)
    return _scan_topk(D, q, k, block=65536 if block is None else block)


def _topk_merge(scores: jax.Array, ids: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Top-k of (B, C) candidate scores, returning (B, k) scores + gathered ids."""
    s, idx = jax.lax.top_k(scores, k)
    return s, jnp.take_along_axis(ids, idx, axis=-1)


def _staged_topk_merge(s: jax.Array, ids: jax.Array, k: int,
                       stages) -> tuple[jax.Array, jax.Array]:
    """Merge per-shard (B, k) top-k across the mesh in all-gather stages.

    ``stages`` is a sequence of axis-name tuples; each stage all-gathers the
    surviving candidates over its axes and re-selects top-k. One stage over
    every axis is the flat merge (k·ndev candidates per device); splitting
    into two stages shrinks the per-device gather volume to
    k·(|stage1| + |stage2|) — k·2√ndev on a square mesh. Exactness is
    preserved: a global top-k entry is a top-k entry of every intermediate
    device group it belongs to, so it survives each stage. Gather order is
    row-major by mesh position in both layouts, so tie-breaks (and thus the
    selected ids) are bit-identical between flat and staged merges.
    """
    for stage in stages:
        stage = tuple(stage)
        if not stage:
            continue
        s_all = jax.lax.all_gather(s, stage, axis=1, tiled=True)
        i_all = jax.lax.all_gather(ids, stage, axis=1, tiled=True)
        s, ids = _topk_merge(s_all, i_all, k)
    return s, ids


@partial(jax.jit, static_argnames=("k", "block", "vma_axes"))
def _scan_topk(D: jax.Array, Q: jax.Array, k: int, block: int = 65536,
               vma_axes: tuple[str, ...] | None = None
               ) -> tuple[jax.Array, jax.Array]:
    """Blocked exact search: stream row blocks of D, keep a running top-k.

    Never materialises the full (B, n) score matrix — the jnp analogue of
    the Pallas fused kernel, and the oracle it is tested against. Mirrors
    the kernel's structure:

      * the index blocks keep their storage dtype (int8 stays int8 in the
        scan carry's xs); each block upcasts to f32 only for its matmul —
        no full-index fp32 shadow copy;
      * two-stage select: ``top_k`` over the (B, block) strip alone, then a
        tiny (B, 2k) merge with the running list — never a sort over the
        (B, k + block) concat;
      * block-skip guard: a strip whose max cannot beat the current k-th
        best (across the whole batch) skips selection entirely under
        ``lax.cond``. Skipping on equality is exact — strips are visited
        in ascending id order, so later ties lose the first-occurrence
        tie-break anyway.

    ``vma_axes``: when called inside shard_map over those axes, the scan
    carry must be marked varying (compat.mark_varying) to typecheck on
    JAX versions with VMA tracking.
    """
    n, d = D.shape
    B = Q.shape[0]
    block = min(block, n)
    nblocks = -(-n // block)
    pad = nblocks * block - n
    Dp = jnp.pad(D, ((0, pad), (0, 0))) if pad else D
    blocks = Dp.reshape(nblocks, block, d)
    Qf = Q.astype(jnp.float32)
    kk = min(k, block)   # strip-local candidate count

    if nblocks == 1:
        # single strip (block == n): the running list is empty, a guard can
        # never fire, and the two-stage detour just adds a second sort —
        # select directly
        s = Qf @ Dp.T.astype(jnp.float32)
        ids = jnp.broadcast_to(
            jnp.arange(block, dtype=jnp.int32)[None, :], (B, block))
        if k > block:
            # fewer rows than k: sentinels first so they win -inf ties,
            # matching the scan init and the Pallas kernel's -1 pads
            s = jnp.concatenate(
                [jnp.full((B, k), -jnp.inf, jnp.float32), s], axis=1)
            ids = jnp.concatenate(
                [jnp.full((B, k), -1, jnp.int32), ids], axis=1)
        return _topk_merge(s, ids, k)

    def body(carry, inp):
        bs, bi = carry
        blk, start = inp
        s = Qf @ blk.T.astype(jnp.float32)                       # (B, block)
        ids = start + jnp.arange(block, dtype=jnp.int32)[None, :]
        s = jnp.where(ids < n, s, -jnp.inf)

        def merge(carry_in):
            bs0, bi0 = carry_in
            ss, si = jax.lax.top_k(s, kk)                        # (B, kk)
            gi = start + si.astype(jnp.int32)
            # running list first: at -inf ties its (-1) pads win the
            # first-occurrence tie-break, matching the kernel's pads
            cs = jnp.concatenate([bs0, ss], axis=1)              # (B, k+kk)
            ci = jnp.concatenate([bi0, gi], axis=1)
            return _topk_merge(cs, ci, k)

        can_improve = jnp.max(s) > jnp.min(bs)
        return jax.lax.cond(can_improve, merge, lambda c: c, (bs, bi)), None

    init = (jnp.full((B, k), -jnp.inf, jnp.float32), jnp.full((B, k), -1, jnp.int32))
    if vma_axes:
        init = compat.mark_varying(init, vma_axes)
    starts = jnp.arange(nblocks, dtype=jnp.int32) * block
    (scores, ids), _ = jax.lax.scan(body, init, (blocks, starts))
    return scores, ids


@dataclasses.dataclass
class DenseIndex:
    """Flat exact-search index over document embeddings.

    ``vectors``: (n, m) document matrix (possibly PCA-pruned and/or int8).
    ``scale``:   per-dim dequant scale when vectors are int8, else None.
    """

    vectors: jax.Array
    scale: jax.Array | None = None
    backend: Backend = "jnp"

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def nbytes(self) -> int:
        b = self.vectors.size * self.vectors.dtype.itemsize
        if self.scale is not None:
            b += self.scale.size * self.scale.dtype.itemsize
        return b

    @classmethod
    def build(cls, vectors: jax.Array, *, dtype: jnp.dtype | None = None,
              quantize_int8: bool = False, backend: Backend = "jnp") -> "DenseIndex":
        v = jnp.asarray(vectors)
        if quantize_int8:
            from repro.core.quantization import quantize_int8_per_dim
            q, scale = quantize_int8_per_dim(v)
            return cls(vectors=q, scale=scale, backend=backend)
        if dtype is not None:
            v = v.astype(dtype)
        return cls(vectors=v, scale=None, backend=backend)

    @classmethod
    def load(cls, store, *, backend: Backend = "jnp") -> "DenseIndex":
        """Load from an on-disk ``IndexStore`` (path or open handle).

        Chunks are memory-mapped and copied to device one at a time — the
        host never holds a full-index copy beyond the OS page cache.
        """
        from repro.core.store import IndexStore
        if isinstance(store, (str, os.PathLike)):
            store = IndexStore.open(store)
        parts = [jnp.asarray(np.ascontiguousarray(c))
                 for c in store.iter_chunks()]
        vectors = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
        s = store.scale()
        return cls(vectors=vectors,
                   scale=None if s is None else jnp.asarray(s),
                   backend=backend)

    def _dequeries(self, queries: jax.Array) -> jax.Array:
        """Fold the int8 scale into the query side: (Dq) = (D_int8)(s ⊙ q)."""
        q = jnp.atleast_2d(queries)
        if self.scale is not None:
            q = q * self.scale[None, :]
        return q

    def search(self, queries: jax.Array, k: int = 10,
               block: int | None = None) -> tuple[jax.Array, jax.Array]:
        """Exact top-k. Returns (scores (B,k) fp32, ids (B,k) int32).

        ``block`` is the scan strip size. ``None`` picks the backend
        default (65536 rows for the jnp scan, the kernel's ``block_n`` for
        pallas); an explicit value is honoured on *both* backends — it used
        to be silently dropped on pallas, so serve-time tuning did nothing.
        """
        q = self._dequeries(queries)
        k = min(k, self.n)
        if self.backend == "pallas":
            from repro.kernels import ops as kops
            if block is None:
                return kops.topk_score(self.vectors, q, k=k)
            return kops.topk_score(self.vectors, q, k=k, block_n=block)
        return _scan_topk(self.vectors, q, k,
                          block=65536 if block is None else block)

    def search_projected(self, queries: jax.Array, components: jax.Array,
                         k: int = 10, *, mean: jax.Array | None = None,
                         block: int | None = None
                         ) -> tuple[jax.Array, jax.Array]:
        """Fused raw-query search: one dispatch from d-dim query to top-k.

        ``queries`` are raw (B, d) vectors; ``components`` is the (d, m)
        PCA projection ``W_m`` (``StaticPruner.projection()`` /
        ``pca.projection_operands``); ``mean`` the optional centering row.
        Projection, the int8 scale fold, and the top-k scan all trace into
        a single jit — no separate projection dispatch, no intermediate
        q̂ round-trip. For f32 raw queries (the serving input) results are
        bit-identical to ``transform_queries`` → ``search``.
        """
        k = min(k, self.n)
        return _dense_search_projected(self.vectors, self.scale,
                                       jnp.asarray(components), mean,
                                       jnp.atleast_2d(queries), k, block,
                                       self.backend)


@dataclasses.dataclass
class ShardedDenseIndex:
    """Index with rows sharded across every device of a mesh.

    Serve-time layout of the paper's index at pod scale: each chip owns
    n/num_devices contiguous rows. Search = local blocked scan per shard
    followed by a global merge of per-shard top-k — the only collective is
    an all-gather of (B, k) scores + ids per shard (k·chips ≪ n).

    ``backend`` selects the per-shard scan: 'jnp' (blocked XLA scan) or
    'pallas' (fused score-and-select kernel — interpreted off-TPU).
    ``merge`` selects the global candidate merge: 'flat' (one all-gather
    over every axis, k·ndev candidates per query) or 'hierarchical' (one
    stage per mesh dimension — within the minor axis, then across the
    rest — shrinking the collective to k·(minor + rest) candidates; on a
    1-axis mesh the two are the same single stage).
    """

    vectors: jax.Array          # (n_padded, m) sharded P(axes, None)
    mesh: Mesh
    scale: jax.Array | None = None
    backend: Backend = "jnp"
    merge: Merge = "flat"
    n_real: int | None = None   # logical row count before device padding
    # compiled search per (B, k, merge) — rebuilding the shard_map closure
    # per call would recompile per batch and cap serving at trace speed
    _jit_cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                         compare=False)

    @classmethod
    def build(cls, vectors: jax.Array, mesh: Mesh, *,
              quantize_int8: bool = False,
              backend: Backend = "jnp",
              merge: Merge = "flat") -> "ShardedDenseIndex":
        axes = tuple(mesh.axis_names)
        scale = None
        v = jnp.asarray(vectors)
        if quantize_int8:
            from repro.core.quantization import quantize_int8_per_dim
            v, scale = quantize_int8_per_dim(v)
        sharding = NamedSharding(mesh, P(axes, None))
        n = v.shape[0]
        ndev = int(np.prod(mesh.devices.shape))
        pad = (-n) % ndev
        if pad:
            v = jnp.pad(v, ((0, pad), (0, 0)))
        v = jax.device_put(v, sharding)
        return cls(vectors=v, mesh=mesh, scale=scale, backend=backend,
                   merge=merge, n_real=n)

    @classmethod
    def load(cls, store, mesh: Mesh, *,
             backend: Backend = "jnp",
             merge: Merge = "flat") -> "ShardedDenseIndex":
        """Host-streamed sharded load from an on-disk ``IndexStore``.

        Each device's row range is sliced out of the memory-mapped chunks
        (host memory O(shard), one shard live at a time), placed on that
        device, and the global array assembled with
        ``jax.make_array_from_single_device_arrays`` — no full-index host
        copy and no single-device ``device_put`` ever materialises, so the
        index may exceed one host's RAM. Device-padding rows for n not
        divisible by the device count are synthesised at load.
        """
        from repro.core.store import IndexStore
        if isinstance(store, (str, os.PathLike)):
            store = IndexStore.open(store)
        axes = tuple(mesh.axis_names)
        n, m = store.n, store.dim
        ndev = int(np.prod(mesh.devices.shape))
        pad = (-n) % ndev
        n_padded = n + pad
        sharding = NamedSharding(mesh, P(axes, None))
        shape = (n_padded, m)
        shards = []
        for device, index in sharding.addressable_devices_indices_map(shape).items():
            rows = index[0]
            start, stop = rows.start or 0, rows.stop if rows.stop is not None else n_padded
            # clamp to the real rows: a shard may be partly — or, when
            # n < (ndev-1)·rows_per, entirely — device padding
            lo, hi = min(start, n), min(stop, n)
            local = store.read_rows(lo, hi)
            if stop - start > hi - lo:   # synthesise this shard's padding rows
                local = np.concatenate(
                    [local, np.zeros(((stop - start) - (hi - lo), m),
                                     store.dtype)], axis=0)
            shards.append(jax.device_put(local, device))
            del local
        vectors = jax.make_array_from_single_device_arrays(shape, sharding, shards)
        s = store.scale()
        return cls(vectors=vectors, mesh=mesh,
                   scale=None if s is None else jnp.asarray(s),
                   backend=backend, merge=merge, n_real=n)

    @property
    def n(self) -> int:
        """Logical (unpadded) row count."""
        return self.n_real if self.n_real is not None else self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def nbytes(self) -> int:
        b = self.vectors.size * self.vectors.dtype.itemsize
        if self.scale is not None:
            b += self.scale.size * self.scale.dtype.itemsize
        return b

    def search(self, queries: jax.Array, k: int = 10,
               merge: Merge | None = None) -> tuple[jax.Array, jax.Array]:
        q = jnp.atleast_2d(queries).astype(jnp.float32)
        if self.scale is not None:
            q = q * self.scale[None, :]
        k = min(k, self.n)
        merge = self.merge if merge is None else merge
        key = (q.shape[0], k, merge)
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = self._jit_cache[key] = jax.jit(self._search_fn(k, merge))
        return fn(self.vectors, q)

    def search_projected(self, queries: jax.Array, components: jax.Array,
                         k: int = 10, *, mean: jax.Array | None = None,
                         merge: Merge | None = None
                         ) -> tuple[jax.Array, jax.Array]:
        """Fused raw-query search over the sharded index (one dispatch).

        The PCA projection + int8 scale fold run on the replicated query
        inside the same jit as the shard_map'd scan+merge, so the serving
        hot path issues exactly one compiled computation per batch. For
        f32 raw queries, bit-identical to ``transform_queries`` →
        ``search`` (parity-tested).
        """
        q = jnp.atleast_2d(queries)
        k = min(k, self.n)
        merge = self.merge if merge is None else merge
        key = ("projected", q.shape[0], q.shape[1], k, merge,
               self.scale is not None, mean is not None)
        fn = self._jit_cache.get(key)
        if fn is None:
            search = self._search_fn(k, merge)

            def projected(vectors, W, scale, mean_, q_):
                return search(vectors,
                              project_queries(q_, W, scale=scale, mean=mean_))

            fn = self._jit_cache[key] = jax.jit(projected)
        return fn(self.vectors, jnp.asarray(components), self.scale, mean, q)

    def _search_fn(self, k: int, merge: Merge):
        axes = tuple(self.mesh.axis_names)
        n_real = self.n
        ndev = int(np.prod(self.mesh.devices.shape))
        rows_per = self.vectors.shape[0] // ndev
        backend = self.backend
        # Device-padding rows score like real zero vectors and can *win* the
        # shard-local top-k (every real score may be negative), displacing
        # real candidates before any post-hoc mask runs. All ``pad`` padding
        # rows live in the last shard, so a local top-(k+pad) provably
        # retains the shard's true top-k real rows; the pad entries are then
        # masked and cut back to k before the gather.
        pad = self.vectors.shape[0] - n_real
        kp = k + pad
        if merge == "hierarchical" and len(axes) > 1:
            stages = ((axes[-1],), tuple(axes[:-1]))   # minor axis first
        else:
            stages = (axes,)

        def shard_fn(D_local, q_rep):
            # Which shard am I? Flat linear index over mesh axes.
            idx = compat.axis_index(axes)
            base = idx * rows_per
            if backend == "pallas":
                from repro.kernels import ops as kops
                s, ids = kops.topk_score(D_local, q_rep, k=kp)
            else:
                s, ids = _scan_topk(D_local, q_rep, kp, vma_axes=axes)
            ids = jnp.where(ids >= 0, ids + base, -1)
            padded = ids >= n_real
            s = jnp.where(padded, -jnp.inf, s)
            ids = jnp.where(padded, -1, ids)
            if pad:
                s, ids = _topk_merge(s, ids, k)
            # Gather every shard's candidates and merge (1 or 2 stages).
            return _staged_topk_merge(s, ids, k, stages)

        # merged result is replicated by construction; not statically provable
        return compat.shard_map(shard_fn, mesh=self.mesh,
                                in_specs=(P(axes, None), P(None, None)),
                                out_specs=(P(None, None), P(None, None)),
                                check_vma=False)
