"""Dense embedding index with exact (brute-force) top-k search.

This is the FAISS-flat role in the paper's pipeline, built TPU-native:

  * ``DenseIndex``        — single-logical-array index, matmul + top-k.
                            Backend 'jnp' (XLA) or 'pallas' (fused
                            score-and-select scan; see repro.kernels).
  * ``ShardedDenseIndex`` — rows sharded over every mesh device; each shard
                            scans locally, then a tiny global merge over the
                            per-shard top-k (k·chips candidates).
  * ``SegmentedIndex``    — an immutable base segment (dense or sharded)
                            plus growable fixed-capacity ``DeltaSegment``s,
                            each delta with its OWN int8 scale; searched by
                            a cross-segment top-k merge with global doc-id
                            offsets. Appends are copy-on-write and dispatch
                            at the delta's fixed padded capacity (live row
                            count and id offset are traced operands), so a
                            growing index never recompiles in steady state.
  * int8 symmetric quantisation (beyond-paper) composes with PCA pruning:
    index bytes drop by 4x on top of the m/d PCA reduction.

Scores are always accumulated in fp32 regardless of index dtype.
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.par import compat


Backend = Literal["jnp", "pallas"]
Merge = Literal["flat", "hierarchical"]


def project_queries(q: jax.Array, W: jax.Array,
                    scale: jax.Array | None = None,
                    mean: jax.Array | None = None) -> jax.Array:
    """q̂ = ((q − mean) @ W_m) ⊙ scale — the full raw-query-to-search-query
    transform (PCA projection + int8 dequant fold), written to be traced
    inline inside the fused ``search_projected`` jits.

    Operation order deliberately mirrors the two-step path
    (``transform_query`` then ``_dequeries``) — cast to f32, center,
    project, then fold the scale — so for f32 raw queries (the serving
    input) the fused dispatch is bit-identical to the separate-dispatch
    path (pinned by tests/test_sharded_parity.py). Lower-precision raw
    queries upcast here, whereas ``transform`` casts its result back to
    the input dtype — feed f32 when exact parity matters.
    """
    q = jnp.atleast_2d(q).astype(jnp.float32)
    if mean is not None:
        q = q - mean[None, :]
    q = q @ W
    if scale is not None:
        q = q * scale[None, :]
    return q


@partial(jax.jit, static_argnames=("k", "block", "backend"))
def _dense_search_projected(D, scale, W, mean, Q, k: int,
                            block: int | None, backend: Backend):
    """One compiled dispatch: projection + scale fold + fused top-k scan."""
    q = project_queries(Q, W, scale=scale, mean=mean)
    if backend == "pallas":
        from repro.kernels import ops as kops
        if block is None:
            return kops.topk_score(D, q, k=k)
        return kops.topk_score(D, q, k=k, block_n=block)
    return _scan_topk(D, q, k, block=65536 if block is None else block)


def _check_flat_loadable(store) -> None:
    """Refuse to flatten a segmented store whose segments disagree on the
    int8 scale — a flat load would dequantise delta rows with the base's
    scale. ``SegmentView``s (single segment by construction) pass."""
    if getattr(store, "flat_loadable", True):
        return
    from repro.core.store import IndexStoreError
    raise IndexStoreError(
        f"{store.path}: store has delta segments with per-segment scales — "
        f"load it with SegmentedIndex.load, not a flat index loader")


def _topk_merge(scores: jax.Array, ids: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Top-k of (B, C) candidate scores, returning (B, k) scores + gathered ids."""
    s, idx = jax.lax.top_k(scores, k)
    return s, jnp.take_along_axis(ids, idx, axis=-1)


def _staged_topk_merge(s: jax.Array, ids: jax.Array, k: int,
                       stages) -> tuple[jax.Array, jax.Array]:
    """Merge per-shard (B, k) top-k across the mesh in all-gather stages.

    ``stages`` is a sequence of axis-name tuples; each stage all-gathers the
    surviving candidates over its axes and re-selects top-k. One stage over
    every axis is the flat merge (k·ndev candidates per device); splitting
    into two stages shrinks the per-device gather volume to
    k·(|stage1| + |stage2|) — k·2√ndev on a square mesh. Exactness is
    preserved: a global top-k entry is a top-k entry of every intermediate
    device group it belongs to, so it survives each stage. Gather order is
    row-major by mesh position in both layouts, so tie-breaks (and thus the
    selected ids) are bit-identical between flat and staged merges.
    """
    for stage in stages:
        stage = tuple(stage)
        if not stage:
            continue
        s_all = jax.lax.all_gather(s, stage, axis=1, tiled=True)
        i_all = jax.lax.all_gather(ids, stage, axis=1, tiled=True)
        s, ids = _topk_merge(s_all, i_all, k)
    return s, ids


@partial(jax.jit, static_argnames=("k", "block", "vma_axes", "guard"))
def _scan_topk(D: jax.Array, Q: jax.Array, k: int, block: int = 65536,
               vma_axes: tuple[str, ...] | None = None, guard: str = "row"
               ) -> tuple[jax.Array, jax.Array]:
    """Blocked exact search: stream row blocks of D, keep a running top-k.

    Never materialises the full (B, n) score matrix — the jnp analogue of
    the Pallas fused kernel, and the oracle it is tested against. Mirrors
    the kernel's structure:

      * the index blocks keep their storage dtype (int8 stays int8 in the
        scan carry's xs); each block upcasts to f32 only for its matmul —
        no full-index fp32 shadow copy;
      * two-stage select: ``top_k`` over the (B, block) strip alone, then a
        tiny (B, 2k) merge with the running list — never a sort over the
        (B, k + block) concat;
      * block-skip guard: a strip that cannot improve the running top-k
        skips selection entirely under ``lax.cond``. ``guard="row"``
        (default): row b improves iff ``max(s[b]) > min(run_s[b])``; the
        strip is skipped iff *no* row improves (a strictly weaker skip
        condition than the legacy ``guard="batch"`` global compare, so
        mixed batches skip more often, never less) and the merge writes
        back only improving rows. Results are bit-identical either way:
        for a non-improving row the merge is already a no-op — strict
        guard, and ascending-id strips lose first-occurrence ties.
        Skipping on equality is exact for the same ascending-id reason.

    ``vma_axes``: when called inside shard_map over those axes, the scan
    carry must be marked varying (compat.mark_varying) to typecheck on
    JAX versions with VMA tracking.
    """
    n, d = D.shape
    B = Q.shape[0]
    block = min(block, n)
    nblocks = -(-n // block)
    pad = nblocks * block - n
    Dp = jnp.pad(D, ((0, pad), (0, 0))) if pad else D
    blocks = Dp.reshape(nblocks, block, d)
    Qf = Q.astype(jnp.float32)
    kk = min(k, block)   # strip-local candidate count

    if nblocks == 1:
        # single strip (block == n): the running list is empty, a guard can
        # never fire, and the two-stage detour just adds a second sort —
        # select directly
        s = Qf @ Dp.T.astype(jnp.float32)
        ids = jnp.broadcast_to(
            jnp.arange(block, dtype=jnp.int32)[None, :], (B, block))
        if k > block:
            # fewer rows than k: sentinels first so they win -inf ties,
            # matching the scan init and the Pallas kernel's -1 pads
            s = jnp.concatenate(
                [jnp.full((B, k), -jnp.inf, jnp.float32), s], axis=1)
            ids = jnp.concatenate(
                [jnp.full((B, k), -1, jnp.int32), ids], axis=1)
        return _topk_merge(s, ids, k)

    def body(carry, inp):
        bs, bi = carry
        blk, start = inp
        s = Qf @ blk.T.astype(jnp.float32)                       # (B, block)
        ids = start + jnp.arange(block, dtype=jnp.int32)[None, :]
        s = jnp.where(ids < n, s, -jnp.inf)

        imp = jnp.max(s, axis=1) > jnp.min(bs, axis=1)           # (B,)

        def merge(carry_in):
            bs0, bi0 = carry_in
            ss, si = jax.lax.top_k(s, kk)                        # (B, kk)
            gi = start + si.astype(jnp.int32)
            # running list first: at -inf ties its (-1) pads win the
            # first-occurrence tie-break, matching the kernel's pads
            cs = jnp.concatenate([bs0, ss], axis=1)              # (B, k+kk)
            ci = jnp.concatenate([bi0, gi], axis=1)
            ms, mi = _topk_merge(cs, ci, k)
            if guard == "row":
                # masked merge: non-improving rows keep their list bitwise
                ms = jnp.where(imp[:, None], ms, bs0)
                mi = jnp.where(imp[:, None], mi, bi0)
            return ms, mi

        if guard == "row":
            can_improve = jnp.any(imp)
        else:
            can_improve = jnp.max(s) > jnp.min(bs)
        return jax.lax.cond(can_improve, merge, lambda c: c, (bs, bi)), None

    init = (jnp.full((B, k), -jnp.inf, jnp.float32), jnp.full((B, k), -1, jnp.int32))
    if vma_axes:
        init = compat.mark_varying(init, vma_axes)
    starts = jnp.arange(nblocks, dtype=jnp.int32) * block
    (scores, ids), _ = jax.lax.scan(body, init, (blocks, starts))
    return scores, ids


@dataclasses.dataclass
class DenseIndex:
    """Flat exact-search index over document embeddings.

    ``vectors``: (n, m) document matrix (possibly PCA-pruned and/or int8).
    ``scale``:   per-dim dequant scale when vectors are int8, else None.
    """

    vectors: jax.Array
    scale: jax.Array | None = None
    backend: Backend = "jnp"

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def nbytes(self) -> int:
        b = self.vectors.size * self.vectors.dtype.itemsize
        if self.scale is not None:
            b += self.scale.size * self.scale.dtype.itemsize
        return b

    @classmethod
    def build(cls, vectors: jax.Array, *, dtype: jnp.dtype | None = None,
              quantize_int8: bool = False, backend: Backend = "jnp") -> "DenseIndex":
        v = jnp.asarray(vectors)
        if quantize_int8:
            from repro.core.quantization import quantize_int8_per_dim
            q, scale = quantize_int8_per_dim(v)
            return cls(vectors=q, scale=scale, backend=backend)
        if dtype is not None:
            v = v.astype(dtype)
        return cls(vectors=v, scale=None, backend=backend)

    @classmethod
    def load(cls, store, *, backend: Backend = "jnp") -> "DenseIndex":
        """Load from an on-disk ``IndexStore`` (path or open handle).

        Chunks are memory-mapped and copied to device one at a time — the
        host never holds a full-index copy beyond the OS page cache.
        """
        from repro.core.store import IndexStore
        if isinstance(store, (str, os.PathLike)):
            store = IndexStore.open(store)
        _check_flat_loadable(store)
        parts = [jnp.asarray(np.ascontiguousarray(c))
                 for c in store.iter_chunks()]
        vectors = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
        s = store.scale()
        return cls(vectors=vectors,
                   scale=None if s is None else jnp.asarray(s),
                   backend=backend)

    def _dequeries(self, queries: jax.Array) -> jax.Array:
        """Fold the int8 scale into the query side: (Dq) = (D_int8)(s ⊙ q)."""
        q = jnp.atleast_2d(queries)
        if self.scale is not None:
            q = q * self.scale[None, :]
        return q

    def search(self, queries: jax.Array, k: int = 10,
               block: int | None = None) -> tuple[jax.Array, jax.Array]:
        """Exact top-k. Returns (scores (B,k) fp32, ids (B,k) int32).

        ``block`` is the scan strip size. ``None`` picks the backend
        default (65536 rows for the jnp scan, the kernel's ``block_n`` for
        pallas); an explicit value is honoured on *both* backends — it used
        to be silently dropped on pallas, so serve-time tuning did nothing.
        """
        q = self._dequeries(queries)
        k = min(k, self.n)
        if self.backend == "pallas":
            from repro.kernels import ops as kops
            if block is None:
                return kops.topk_score(self.vectors, q, k=k)
            return kops.topk_score(self.vectors, q, k=k, block_n=block)
        return _scan_topk(self.vectors, q, k,
                          block=65536 if block is None else block)

    def search_projected(self, queries: jax.Array, components: jax.Array,
                         k: int = 10, *, mean: jax.Array | None = None,
                         block: int | None = None
                         ) -> tuple[jax.Array, jax.Array]:
        """Fused raw-query search: one dispatch from d-dim query to top-k.

        ``queries`` are raw (B, d) vectors; ``components`` is the (d, m)
        PCA projection ``W_m`` (``StaticPruner.projection()`` /
        ``pca.projection_operands``); ``mean`` the optional centering row.
        Projection, the int8 scale fold, and the top-k scan all trace into
        a single jit — no separate projection dispatch, no intermediate
        q̂ round-trip. For f32 raw queries (the serving input) results are
        bit-identical to ``transform_queries`` → ``search``.
        """
        k = min(k, self.n)
        return _dense_search_projected(self.vectors, self.scale,
                                       jnp.asarray(components), mean,
                                       jnp.atleast_2d(queries), k, block,
                                       self.backend)


def _addressable_shard_ranges(sharding, shape: tuple[int, int], n: int
                              ) -> list[tuple]:
    """Row ranges of the shards THIS PROCESS must materialise.

    One ``(device, start, stop, lo, hi)`` tuple per shard in
    ``sharding.addressable_devices_indices_map`` — i.e. per local device
    only, so a multi-host load reads 1/num_hosts of the store and never
    touches rows another process owns. ``[start, stop)`` is the shard's
    padded-global row window; ``[lo, hi)`` is its clamp to the ``n`` real
    rows (a shard may be partly — or, when ``n < (ndev-1)·rows_per``,
    entirely — device padding the caller synthesises as zeros).
    """
    n_padded = shape[0]
    out = []
    for device, index in sharding.addressable_devices_indices_map(
            shape).items():
        rows = index[0]
        start = rows.start or 0
        stop = rows.stop if rows.stop is not None else n_padded
        out.append((device, start, stop, min(start, n), min(stop, n)))
    return out


@dataclasses.dataclass
class ShardedDenseIndex:
    """Index with rows sharded across every device of a mesh.

    Serve-time layout of the paper's index at pod scale: each chip owns
    n/num_devices contiguous rows. Search = local blocked scan per shard
    followed by a global merge of per-shard top-k — the only collective is
    an all-gather of (B, k) scores + ids per shard (k·chips ≪ n).

    ``backend`` selects the per-shard scan: 'jnp' (blocked XLA scan) or
    'pallas' (fused score-and-select kernel — interpreted off-TPU).
    ``merge`` selects the global candidate merge: 'flat' (one all-gather
    over every axis, k·ndev candidates per query) or 'hierarchical' (one
    stage per mesh dimension — within the minor axis, then across the
    rest — shrinking the collective to k·(minor + rest) candidates; on a
    1-axis mesh the two are the same single stage).
    """

    vectors: jax.Array          # (n_padded, m) sharded P(axes, None)
    mesh: Mesh
    scale: jax.Array | None = None
    backend: Backend = "jnp"
    merge: Merge = "flat"
    n_real: int | None = None   # logical row count before device padding
    # compiled search per (B, k, merge) — rebuilding the shard_map closure
    # per call would recompile per batch and cap serving at trace speed
    _jit_cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                         compare=False)

    @classmethod
    def build(cls, vectors: jax.Array, mesh: Mesh, *,
              quantize_int8: bool = False,
              backend: Backend = "jnp",
              merge: Merge = "flat") -> "ShardedDenseIndex":
        axes = tuple(mesh.axis_names)
        scale = None
        v = jnp.asarray(vectors)
        if quantize_int8:
            from repro.core.quantization import quantize_int8_per_dim
            v, scale = quantize_int8_per_dim(v)
        sharding = NamedSharding(mesh, P(axes, None))
        n = v.shape[0]
        ndev = int(np.prod(mesh.devices.shape))
        pad = (-n) % ndev
        if pad:
            v = jnp.pad(v, ((0, pad), (0, 0)))
        v = jax.device_put(v, sharding)
        return cls(vectors=v, mesh=mesh, scale=scale, backend=backend,
                   merge=merge, n_real=n)

    @classmethod
    def load(cls, store, mesh: Mesh, *,
             backend: Backend = "jnp",
             merge: Merge = "flat") -> "ShardedDenseIndex":
        """Host-streamed sharded load from an on-disk ``IndexStore``.

        Each device's row range is sliced out of the memory-mapped chunks
        (host memory O(shard), one shard live at a time), placed on that
        device, and the global array assembled with
        ``jax.make_array_from_single_device_arrays`` — no full-index host
        copy and no single-device ``device_put`` ever materialises, so the
        index may exceed one host's RAM. Device-padding rows for n not
        divisible by the device count are synthesised at load.
        """
        from repro.core.store import IndexStore
        if isinstance(store, (str, os.PathLike)):
            store = IndexStore.open(store)
        _check_flat_loadable(store)
        axes = tuple(mesh.axis_names)
        n, m = store.n, store.dim
        ndev = int(np.prod(mesh.devices.shape))
        pad = (-n) % ndev
        n_padded = n + pad
        sharding = NamedSharding(mesh, P(axes, None))
        shape = (n_padded, m)
        shards = []
        for device, start, stop, lo, hi in _addressable_shard_ranges(
                sharding, shape, n):
            local = store.read_rows(lo, hi)
            if stop - start > hi - lo:   # synthesise this shard's padding rows
                local = np.concatenate(
                    [local, np.zeros(((stop - start) - (hi - lo), m),
                                     store.dtype)], axis=0)
            shards.append(jax.device_put(local, device))
            del local
        vectors = jax.make_array_from_single_device_arrays(shape, sharding, shards)
        s = store.scale()
        return cls(vectors=vectors, mesh=mesh,
                   scale=None if s is None else jnp.asarray(s),
                   backend=backend, merge=merge, n_real=n)

    @property
    def n(self) -> int:
        """Logical (unpadded) row count."""
        return self.n_real if self.n_real is not None else self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def nbytes(self) -> int:
        b = self.vectors.size * self.vectors.dtype.itemsize
        if self.scale is not None:
            b += self.scale.size * self.scale.dtype.itemsize
        return b

    def search(self, queries: jax.Array, k: int = 10,
               merge: Merge | None = None,
               block: int | None = None) -> tuple[jax.Array, jax.Array]:
        q = jnp.atleast_2d(queries).astype(jnp.float32)
        if self.scale is not None:
            q = q * self.scale[None, :]
        k = min(k, self.n)
        merge = self.merge if merge is None else merge
        key = (q.shape[0], k, merge, block)
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = self._jit_cache[key] = jax.jit(
                self._search_fn(k, merge, block))
        return fn(self.vectors, q)

    def search_projected(self, queries: jax.Array, components: jax.Array,
                         k: int = 10, *, mean: jax.Array | None = None,
                         merge: Merge | None = None,
                         block: int | None = None
                         ) -> tuple[jax.Array, jax.Array]:
        """Fused raw-query search over the sharded index (one dispatch).

        The PCA projection + int8 scale fold run on the replicated query
        inside the same jit as the shard_map'd scan+merge, so the serving
        hot path issues exactly one compiled computation per batch. For
        f32 raw queries, bit-identical to ``transform_queries`` →
        ``search`` (parity-tested).
        """
        q = jnp.atleast_2d(queries)
        k = min(k, self.n)
        merge = self.merge if merge is None else merge
        key = ("projected", q.shape[0], q.shape[1], k, merge, block,
               self.scale is not None, mean is not None)
        fn = self._jit_cache.get(key)
        if fn is None:
            search = self._search_fn(k, merge, block)

            def projected(vectors, W, scale, mean_, q_):
                return search(vectors,
                              project_queries(q_, W, scale=scale, mean=mean_))

            fn = self._jit_cache[key] = jax.jit(projected)
        return fn(self.vectors, jnp.asarray(components), self.scale, mean, q)

    def _search_fn(self, k: int, merge: Merge, block: int | None = None):
        axes = tuple(self.mesh.axis_names)
        n_real = self.n
        ndev = int(np.prod(self.mesh.devices.shape))
        rows_per = self.vectors.shape[0] // ndev
        backend = self.backend
        # Device-padding rows score like real zero vectors and can *win* the
        # shard-local top-k (every real score may be negative), displacing
        # real candidates before any post-hoc mask runs. All ``pad`` padding
        # rows live in the last shard, so a local top-(k+pad) provably
        # retains the shard's true top-k real rows; the pad entries are then
        # masked and cut back to k before the gather.
        pad = self.vectors.shape[0] - n_real
        kp = k + pad
        if merge == "hierarchical" and len(axes) > 1:
            stages = ((axes[-1],), tuple(axes[:-1]))   # minor axis first
        else:
            stages = (axes,)

        def shard_fn(D_local, q_rep):
            # Which shard am I? Flat linear index over mesh axes.
            idx = compat.axis_index(axes)
            base = idx * rows_per
            if backend == "pallas":
                from repro.kernels import ops as kops
                if block is None:
                    s, ids = kops.topk_score(D_local, q_rep, k=kp)
                else:
                    s, ids = kops.topk_score(D_local, q_rep, k=kp,
                                             block_n=block)
            else:
                s, ids = _scan_topk(D_local, q_rep, kp,
                                    block=65536 if block is None else block,
                                    vma_axes=axes)
            ids = jnp.where(ids >= 0, ids + base, -1)
            padded = ids >= n_real
            s = jnp.where(padded, -jnp.inf, s)
            ids = jnp.where(padded, -1, ids)
            if pad:
                s, ids = _topk_merge(s, ids, k)
            # Gather every shard's candidates and merge (1 or 2 stages).
            return _staged_topk_merge(s, ids, k, stages)

        # merged result is replicated by construction; not statically provable
        return compat.shard_map(shard_fn, mesh=self.mesh,
                                in_specs=(P(axes, None), P(None, None)),
                                out_specs=(P(None, None), P(None, None)),
                                check_vma=False)


# ---------------------------------------------------------------------------
# Segmented live index: immutable base + growable delta segments
# ---------------------------------------------------------------------------


@jax.jit
def _project_nofold(Q, W, mean):
    """Shared raw-query projection for segmented search: center + project,
    WITHOUT any scale fold — per-segment scales fold inside each segment's
    own dispatch (the segments no longer agree on one scale)."""
    return project_queries(Q, W, scale=None, mean=mean)


@partial(jax.jit, static_argnames=("k",))
def _delta_topk(D, scale, Q, n_valid, offset, k: int):
    """Top-k over one fixed-capacity delta segment, in one compiled shape.

    ``D`` is the (capacity, m) segment in its storage dtype — rows at and
    beyond the live count are zero padding. ``n_valid`` (live rows) and
    ``offset`` (this segment's global doc-id base) are *traced* operands,
    so appends that grow the live count never trigger a recompile: the
    serving hot path dispatches the same compiled computation whether the
    delta holds 1 row or its full capacity. Padding rows are masked to
    (-inf, -1) before selection, exactly like the scan's init sentinels.
    """
    q = jnp.atleast_2d(Q).astype(jnp.float32)
    if scale is not None:
        q = q * scale[None, :]
    cap = D.shape[0]
    s = q @ D.T.astype(jnp.float32)                          # (B, cap) f32
    ids = jnp.arange(cap, dtype=jnp.int32)[None, :]
    live = ids < n_valid
    s = jnp.where(live, s, -jnp.inf)
    gids = jnp.broadcast_to(jnp.where(live, ids + offset, -1), s.shape)
    ss, si = jax.lax.top_k(s, min(k, cap))
    return ss, jnp.take_along_axis(gids, si, axis=-1)


@jax.jit
def _delta_update(D, block, start):
    """Patch appended rows into a delta's fixed-capacity buffer — O(rows)
    per append instead of re-uploading the whole capacity. ``start`` is
    traced, so steady-state appends of one block size compile once."""
    return jax.lax.dynamic_update_slice(D, block, (start, 0))


@partial(jax.jit, static_argnames=("k",))
def _concat_topk(parts_s, parts_i, k: int):
    s = jnp.concatenate(parts_s, axis=1)
    ids = jnp.concatenate(parts_i, axis=1)
    return _topk_merge(s, ids, k)


def merge_segment_topk(candidates, k: int):
    """Merge per-segment (B, k_i) top-k candidate lists (global ids already
    applied) into the global (B, k) top-k.

    Segments must be passed in ascending id-offset order (base first, then
    deltas): ``lax.top_k`` keeps the *first* occurrence among equal scores,
    so concatenation order reproduces the monolithic index's lowest-id
    tie-break — the same invariant ``_staged_topk_merge`` relies on for its
    row-major shard gather, which makes the segmented search bit-identical
    to a monolithic scan over the concatenated corpus.
    """
    parts_s = tuple(s for s, _ in candidates)
    parts_i = tuple(i for _, i in candidates)
    if len(parts_s) == 1:
        return parts_s[0], parts_i[0]
    return _concat_topk(parts_s, parts_i, k)


def segment_jit_cache_sizes() -> dict:
    """Per-jit compiled-variant counts for every jit the segmented search
    path can touch — the diagnosable form of ``segment_jit_cache_size``
    (a failure names the function that recompiled)."""
    from repro.core import cascade, paged  # lazy: both import this module
    sizes = {fn.__wrapped__.__name__: fn._cache_size()
             for fn in (_delta_topk, _concat_topk, _project_nofold,
                        _scan_topk, _dense_search_projected, _delta_update)}
    sizes.update(cascade._jit_cache_sizes())
    sizes.update(paged._jit_cache_sizes())
    return sizes


def segment_jit_cache_size() -> int:
    """Total compiled-variant count across every jit the segmented search
    path can touch — the soak tests pin this to ZERO growth during
    steady-state appends (the whole point of fixed-capacity deltas)."""
    return sum(segment_jit_cache_sizes().values())


@dataclasses.dataclass(frozen=True)
class DeltaSegment:
    """One growable segment: fixed-capacity storage + its own scale.

    ``vectors`` always has ``capacity`` rows (zeros beyond ``n_real``) so
    every search dispatches one compiled shape. ``raw`` keeps the exact f32
    rows appended so far — the requantisation source when an append widens
    the scale (re-quantising from f32 is exact; from int8 it would drift by
    up to half an old LSB). After a cold start from disk ``raw`` is the
    dequantised reconstruction — the best source that survives a restart.
    """

    vectors: jax.Array                 # (capacity, m), storage dtype
    n_real: int
    scale: jax.Array | None            # per-dim dequant scale (int8 deltas)
    raw: np.ndarray                    # (n_real, m) f32 requant source

    @property
    def capacity(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]

    @property
    def nbytes(self) -> int:
        b = self.vectors.size * self.vectors.dtype.itemsize
        if self.scale is not None:
            b += self.scale.size * self.scale.dtype.itemsize
        return b

    @staticmethod
    def quantise(raw: np.ndarray, scale: np.ndarray) -> np.ndarray:
        from repro.core.quantization import quantize_with_scale
        return quantize_with_scale(raw, scale)

    @classmethod
    def build(cls, rows: np.ndarray, capacity: int, *, quantize: bool,
              dtype) -> "DeltaSegment":
        """Open a delta from its first f32 rows; int8 deltas get a FRESH
        per-dim scale fitted to these rows — never the base's frozen one."""
        from repro.core.quantization import scale_for
        raw = np.ascontiguousarray(np.asarray(rows, np.float32))
        if raw.shape[0] > capacity:
            raise ValueError(f"{raw.shape[0]} rows exceed delta capacity "
                             f"{capacity}")
        if quantize:
            scale = scale_for(raw)
            stored = cls.quantise(raw, scale)
        else:
            scale = None
            stored = raw.astype(np.dtype(dtype))
        pad = capacity - stored.shape[0]
        if pad:
            stored = np.concatenate(
                [stored, np.zeros((pad, stored.shape[1]), stored.dtype)])
        return cls(vectors=jnp.asarray(stored), n_real=raw.shape[0],
                   scale=None if scale is None else jnp.asarray(scale),
                   raw=raw)

    def extend(self, rows: np.ndarray
               ) -> tuple["DeltaSegment", bool, np.ndarray]:
        """Copy-on-write append of f32 rows.

        Returns ``(new segment, widened, stored)`` where ``stored`` is the
        host copy of what changed in storage dtype — just the new rows in
        the common case, the whole requantised segment when the scale
        widened (the durable mirror appends/rewrites exactly those bytes).

        int8 deltas widen their per-dim scale whenever a new row's absmax
        exceeds the representable range — the whole segment requantises
        from its exact f32 staging, so nothing ever clips. That rewrite is
        bounded by the segment's capacity (the reason the scale problem is
        tractable per segment and was not on the monolithic index). The
        common non-widened append touches only O(rows): the new rows
        quantise under the unchanged scale and patch into the existing
        device buffer with a ``dynamic_update_slice`` (a new immutable
        array — in-flight searches keep the old one).
        """
        rows = np.ascontiguousarray(np.asarray(rows, np.float32))
        if self.n_real + rows.shape[0] > self.capacity:
            raise ValueError("extend beyond delta capacity — seal and open "
                             "a new delta instead")
        from repro.core.quantization import scale_for
        raw = np.concatenate([self.raw, rows])
        if self.scale is not None:
            old = np.asarray(self.scale)
            need = scale_for(rows)
            scale = np.maximum(old, need).astype(np.float32)
            if bool((scale > old).any()):          # widen: bounded rewrite
                stored = self.quantise(raw, scale)
                full = np.concatenate(
                    [stored, np.zeros((self.capacity - stored.shape[0],
                                       stored.shape[1]), stored.dtype)]) \
                    if stored.shape[0] < self.capacity else stored
                return dataclasses.replace(
                    self, vectors=jnp.asarray(full), n_real=raw.shape[0],
                    scale=jnp.asarray(scale), raw=raw), True, stored
            new_rows = self.quantise(rows, old)
        else:
            new_rows = rows.astype(self.vectors.dtype)
        vectors = _delta_update(self.vectors, jnp.asarray(new_rows),
                                jnp.int32(self.n_real))
        return dataclasses.replace(
            self, vectors=vectors, n_real=raw.shape[0],
            raw=raw), False, new_rows


def rehydrate_delta(view, delta_capacity: int) -> DeltaSegment:
    """Rebuild one ``DeltaSegment`` from a store view (a main delta OR a
    persisted coarse-resolution delta): the stored quantised bytes become
    the served bytes bit-for-bit, padded to the stored capacity; ``raw``
    is the dequantised reconstruction — the best requant source that
    survives a restart."""
    rows = view.read_rows(0, view.n)
    s = view.scale()
    if s is not None:
        raw = rows.astype(np.float32) * s[None, :].astype(np.float32)
    else:
        raw = rows.astype(np.float32)
    cap = int(view.capacity) if view.capacity else max(delta_capacity,
                                                       view.n)
    stored = np.zeros((cap, view.dim), rows.dtype)
    stored[:view.n] = rows
    return DeltaSegment(vectors=jnp.asarray(stored), n_real=view.n,
                        scale=None if s is None else jnp.asarray(s),
                        raw=np.ascontiguousarray(raw))


@dataclasses.dataclass(frozen=True)
class SegmentedIndex:
    """Immutable segment set: [base] + deltas, searched as one index.

    The base is a committed ``DenseIndex`` or ``ShardedDenseIndex`` (the
    offline PCA-pruned artifact); deltas absorb live corpus growth. Every
    mutation (``append``) returns a NEW ``SegmentedIndex`` sharing the
    untouched segments — the running ``RetrievalServer`` swaps whole
    segment sets atomically between batches, and in-flight batches keep
    the old set alive until their replies post.

    Search = per-segment top-k (each segment folds its OWN scale) merged by
    ``merge_segment_topk`` with global id offsets (base rows first, deltas
    in open order). When every segment shares one scale the result is
    bit-identical to a monolithic index over the concatenated corpus; with
    mixed scales, ids/ordering are exactly the top-k of the per-segment
    dequantised scores.
    """

    base: DenseIndex | ShardedDenseIndex
    deltas: tuple[DeltaSegment, ...] = ()
    delta_capacity: int = 4096

    # -- construction -------------------------------------------------------
    @classmethod
    def from_index(cls, base, *, delta_capacity: int = 4096
                   ) -> "SegmentedIndex":
        return cls(base=base, deltas=(), delta_capacity=delta_capacity)

    @classmethod
    def load(cls, store, *, mesh: Mesh | None = None,
             backend: Backend = "jnp", merge: Merge = "flat",
             delta_capacity: int = 4096) -> "SegmentedIndex":
        """Load a (possibly segmented) artifact: segment 0 becomes the base
        (sharded over ``mesh`` when given), every delta segment is
        rehydrated at its stored capacity with its own scale. A pre-segment
        artifact loads as a single base — full backward compatibility."""
        from repro.core.store import IndexStore
        if isinstance(store, (str, os.PathLike)):
            store = IndexStore.open(store)
        views = store.segments()
        base_view = views[0]
        if mesh is not None:
            base = ShardedDenseIndex.load(base_view, mesh, backend=backend,
                                          merge=merge)
        else:
            base = DenseIndex.load(base_view, backend=backend)
        deltas = [rehydrate_delta(v, delta_capacity) for v in views[1:]]
        return cls(base=base, deltas=tuple(deltas),
                   delta_capacity=delta_capacity)

    # -- shape --------------------------------------------------------------
    @property
    def n(self) -> int:
        return self.base.n + sum(d.n_real for d in self.deltas)

    @property
    def dim(self) -> int:
        return self.base.dim

    @property
    def nbytes(self) -> int:
        return self.base.nbytes + sum(d.nbytes for d in self.deltas)

    @property
    def quantized(self) -> bool:
        return self.base.scale is not None

    @property
    def delta_rows(self) -> int:
        return sum(d.n_real for d in self.deltas)

    @property
    def storage_dtype(self):
        return self.base.vectors.dtype

    # -- growth (copy-on-write) --------------------------------------------
    def append(self, rows) -> "SegmentedIndex":
        new, _ = self.append_with_ops(rows)
        return new

    def append_with_ops(self, rows) -> tuple["SegmentedIndex", list]:
        """Append f32 rows (already PCA-pruned to this index's dim).

        Returns ``(new_index, ops)`` where ``ops`` records what changed for
        a durable mirror (``IndexStore``), in order:
          ("open",   di, stored_rows, scale)  — new delta with first rows
          ("extend", di, stored_rows)         — rows appended, scale kept
          ("widen",  di, stored_all,  scale)  — scale widened: the delta's
                                                full requantised contents
        ``stored_*`` are in storage dtype (int8 already quantised), exactly
        the bytes the in-memory index serves — disk and memory stay
        bit-identical.
        """
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        if rows.shape[1] != self.dim:
            raise ValueError(f"append expects (rows, {self.dim}), got "
                             f"{tuple(rows.shape)}")
        quantize = self.quantized
        deltas = list(self.deltas)
        ops: list = []
        pos = 0
        while pos < rows.shape[0]:
            if deltas and deltas[-1].n_real < deltas[-1].capacity:
                di = len(deltas) - 1
                seg = deltas[di]
                take = min(rows.shape[0] - pos, seg.capacity - seg.n_real)
                block = rows[pos:pos + take]
                seg, widened, stored = seg.extend(block)
                deltas[di] = seg
                if widened:
                    ops.append(("widen", di, stored, np.asarray(seg.scale)))
                else:
                    ops.append(("extend", di, stored))
            else:
                di = len(deltas)
                take = min(rows.shape[0] - pos, self.delta_capacity)
                block = rows[pos:pos + take]
                seg = DeltaSegment.build(block, self.delta_capacity,
                                         quantize=quantize,
                                         dtype=self.storage_dtype)
                deltas.append(seg)
                ops.append(("open", di, np.asarray(seg.vectors[:seg.n_real]),
                            None if seg.scale is None
                            else np.asarray(seg.scale)))
            pos += take
        return dataclasses.replace(self, deltas=tuple(deltas)), ops

    # -- search -------------------------------------------------------------
    def _merged_topk(self, q: jax.Array, k: int):
        k = min(k, max(self.n, 1))
        parts = [self.base.search(q, k=k)]
        off = self.base.n
        for d in self.deltas:
            parts.append(_delta_topk(d.vectors, d.scale, q,
                                     jnp.int32(d.n_real), jnp.int32(off), k))
            off += d.n_real
        return merge_segment_topk(parts, k)

    def search(self, queries: jax.Array, k: int = 10
               ) -> tuple[jax.Array, jax.Array]:
        q = jnp.atleast_2d(queries).astype(jnp.float32)
        return self._merged_topk(q, k)

    def search_projected(self, queries: jax.Array, components: jax.Array,
                         k: int = 10, *, mean: jax.Array | None = None
                         ) -> tuple[jax.Array, jax.Array]:
        """Raw-query search: one shared projection dispatch (no scale fold —
        the segments don't share one), then per-segment fold+scan+merge."""
        q = _project_nofold(jnp.atleast_2d(queries),
                            jnp.asarray(components), mean)
        return self._merged_topk(q, k)
