"""IR effectiveness metrics + significance testing.

Mirrors the paper's evaluation: AP, nDCG@10, MRR@10 (``ir_measures``
conventions) and a two-tailed paired Wilcoxon signed-rank test at α=0.05.

Run format: for each query, a ranked array of doc ids (descending score).
Qrels format: ``dict[qid] -> dict[docid] -> int grade`` (TREC-style), or the
dense array helpers below for synthetic benchmarks.
"""
from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np
from scipy import stats


# ---------------------------------------------------------------------------
# Per-query metrics (numpy — evaluation is host-side, tiny)
# ---------------------------------------------------------------------------


def dcg(grades: np.ndarray) -> float:
    """DCG with the standard (2^g - 1)/log2(rank+1) gain used by TREC DL."""
    if grades.size == 0:
        return 0.0
    ranks = np.arange(1, grades.size + 1)
    return float(np.sum((np.exp2(grades) - 1.0) / np.log2(ranks + 1.0)))


def ndcg_at_k(ranked_ids: Sequence[int], qrel: Mapping[int, int], k: int = 10) -> float:
    grades = np.array([qrel.get(int(d), 0) for d in ranked_ids[:k]], dtype=np.float64)
    ideal = np.sort(np.array(list(qrel.values()), dtype=np.float64))[::-1][:k]
    idcg = dcg(ideal)
    return dcg(grades) / idcg if idcg > 0 else 0.0


def average_precision(ranked_ids: Sequence[int], qrel: Mapping[int, int],
                      rel_threshold: int = 1, k: int | None = None) -> float:
    """AP over the full ranking (ir_measures AP; binarised at rel>=threshold)."""
    rel_total = sum(1 for g in qrel.values() if g >= rel_threshold)
    if rel_total == 0:
        return 0.0
    ids = ranked_ids if k is None else ranked_ids[:k]
    hits = 0
    score = 0.0
    for rank, d in enumerate(ids, start=1):
        if qrel.get(int(d), 0) >= rel_threshold:
            hits += 1
            score += hits / rank
    return score / rel_total


def mrr_at_k(ranked_ids: Sequence[int], qrel: Mapping[int, int],
             k: int = 10, rel_threshold: int = 1) -> float:
    for rank, d in enumerate(ranked_ids[:k], start=1):
        if qrel.get(int(d), 0) >= rel_threshold:
            return 1.0 / rank
    return 0.0


def recall_at_k(ranked_ids: Sequence[int], qrel: Mapping[int, int],
                k: int = 100, rel_threshold: int = 1) -> float:
    rel = {d for d, g in qrel.items() if g >= rel_threshold}
    if not rel:
        return 0.0
    return len(rel.intersection(int(d) for d in ranked_ids[:k])) / len(rel)


# ---------------------------------------------------------------------------
# Corpus-level evaluation
# ---------------------------------------------------------------------------

METRICS = {
    "AP": lambda r, q: average_precision(r, q),
    "MRR@10": lambda r, q: mrr_at_k(r, q, 10),
    "nDCG@10": lambda r, q: ndcg_at_k(r, q, 10),
}


def evaluate_run(run: Mapping[int, Sequence[int]],
                 qrels: Mapping[int, Mapping[int, int]],
                 metrics: Sequence[str] = ("AP", "MRR@10", "nDCG@10"),
                 ) -> dict[str, np.ndarray]:
    """Per-query metric vectors for every query present in ``qrels``.

    Queries missing from the run score 0 (TREC convention). Returns
    ``{metric: vector aligned with sorted(qrels)}`` so paired significance
    tests line up across systems.
    """
    qids = sorted(qrels)
    out: dict[str, np.ndarray] = {}
    for name in metrics:
        fn = METRICS[name]
        out[name] = np.array([fn(run.get(q, ()), qrels[q]) for q in qids], dtype=np.float64)
    return out


def mean_metrics(per_query: Mapping[str, np.ndarray]) -> dict[str, float]:
    return {k: float(v.mean()) if v.size else 0.0 for k, v in per_query.items()}


# ---------------------------------------------------------------------------
# Significance (paper: two-tailed paired Wilcoxon signed-rank, α = 0.05)
# ---------------------------------------------------------------------------


def wilcoxon_significant(baseline: np.ndarray, system: np.ndarray,
                         alpha: float = 0.05) -> tuple[bool, float]:
    """Paired two-tailed Wilcoxon signed-rank test.

    Returns ``(significant, p_value)``. All-zero differences ⇒ not
    significant (p=1.0), matching the paper's ANCE@25% "identical run" rows.
    """
    diff = np.asarray(system, dtype=np.float64) - np.asarray(baseline, dtype=np.float64)
    if np.allclose(diff, 0.0):
        return False, 1.0
    try:
        res = stats.wilcoxon(system, baseline, zero_method="wilcox",
                             alternative="two-sided", method="auto")
        p = float(res.pvalue)
    except ValueError:  # degenerate (e.g. < 1 nonzero pair)
        return False, 1.0
    return bool(p < alpha), p
