"""Cascade retrieval: coarse scan at m_coarse → exact shortlist rescore.

The paper's static pruning picks ONE operating point on the m-vs-quality
curve. Related work (query embedding pruning, arXiv 2108.10341; conditional
dimension reduction, arXiv 2205.03284) shows adaptive per-query pruning
beats any single static cutoff — at a per-query decision cost. The cascade
captures that win query-independently, offline:

  1. **coarse scan** — the first pass scans an aggressively pruned index
     (the first ``m_coarse`` PCA dims, int8) for the top-(N·k) per query.
     PCA dims *nest*: the coarse matrix is literally the full pruned
     matrix's leading columns re-quantised, and the coarse query is a
     column slice of the one shared projected query. At m=64 int8 vs
     m=384 f32 the first pass streams ~24x fewer bytes than a full scan.
  2. **shortlist rescore** — the per-query shortlists are flattened into
     one batch-shared shortlist (sorted ascending, duplicates marked -1),
     the U = B·N·k full-resolution rows are gathered in storage dtype, and
     a single small (B, m)×(m, U) matmul rescores them EXACTLY at full m
     before the final top-k. Sharing the shortlist across the batch cuts
     the gather B-fold and keeps the rescore in the same
     batch-by-contraction dot shape family as the full scan — which is
     what makes the cascade *bit-identical* to the full-m search whenever
     the shortlist covers the corpus (N·k ≥ n), the oracle-parity anchor
     the tests pin.

Both stages trace into ONE jit for a dense×dense cascade (projection +
coarse scan + shortlist + gather + rescore + select — the serving hot path
stays one dispatch per batch). A segmented cascade mirrors the segmented
search contract instead: one shared projection, one dispatch per segment
per stage with live counts and id offsets as *traced* operands, so
steady-state appends never recompile.

Tie-breaks: the shortlist is sorted ascending, so ``lax.top_k``'s
first-occurrence rule reproduces the monolithic scan's lowest-doc-id
tie-break; the Pallas rescore kernel's min-id-among-ties extract gives the
same result independent of gather order.
"""
from __future__ import annotations

import dataclasses
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import (
    Backend,
    DenseIndex,
    SegmentedIndex,
    _project_nofold,
    _scan_topk,
    _topk_merge,
    project_queries,
)


def _shortlist(cids: jax.Array) -> jax.Array:
    """Batch-shared shortlist from per-query coarse ids.

    Flattens (B, nk) coarse top-ids to one (B·nk,) candidate row, sorts
    ascending (so -1 pads lead and ``top_k`` ties resolve to the lowest
    doc id) and marks duplicates as -1 — each surviving slot holds a
    distinct doc id scored once for the whole batch.
    """
    flat = jnp.sort(cids.reshape(-1))
    dup = jnp.concatenate(
        [jnp.zeros((1,), bool), flat[1:] == flat[:-1]])
    return jnp.where(dup, jnp.int32(-1), flat)


_cascade_shortlist = jax.jit(_shortlist)


@partial(jax.jit, static_argnames=("k", "nk", "block", "backend"))
def _cascade_dense_projected(Dc, scale_c, Df, scale_f, W, mean, Q, k: int,
                             nk: int, block: int | None, backend: Backend):
    """One compiled dispatch: projection + coarse scan + shortlist +
    gather + exact rescore + final top-k (dense×dense cascade).

    The U = B·nk shortlist rows gather from ``Df`` in storage dtype — the
    (U, m) upcast inside the rescore matmul/kernel IS the second stage's
    dequant unit (mirroring the scan's per-strip in-register dequant).
    """
    qf = project_queries(Q, W, scale=None, mean=mean)
    mc = Dc.shape[1]
    qc = qf[:, :mc]
    if scale_c is not None:
        qc = qc * scale_c[None, :]
    if backend == "pallas":
        from repro.kernels import ops as kops
        kw = {} if block is None else {"block_n": block}
        _, cids = kops.topk_score(Dc, qc, k=nk, **kw)
        uids = _shortlist(cids)
        q = qf if scale_f is None else qf * scale_f[None, :]
        rows = jnp.take(Df, jnp.maximum(uids, 0), axis=0)
        return kops.topk_score(rows, q, k=k, row_ids=uids, **kw)
    _, cids = _scan_topk(Dc, qc, nk, block=65536 if block is None else block)
    uids = _shortlist(cids)
    q = qf if scale_f is None else qf * scale_f[None, :]
    rows = jnp.take(Df, jnp.maximum(uids, 0), axis=0)
    s = q @ rows.T.astype(jnp.float32)
    s = jnp.where(uids[None, :] >= 0, s, -jnp.inf)
    return _topk_merge(s, jnp.broadcast_to(uids[None, :], s.shape), k)


@jax.jit
def _segment_rescore(D, scale, qf, uids, offset, n_valid):
    """(B, U) exact scores ONE full-resolution segment contributes to the
    shared shortlist; slots outside this segment's live id range are -inf.

    ``offset`` (the segment's global doc-id base) and ``n_valid`` (live
    rows) are traced operands — appends reuse the compiled shape, the same
    zero-recompile contract as ``_delta_topk``.
    """
    q = qf if scale is None else qf * scale[None, :]
    local = uids - offset
    valid = (uids >= 0) & (local >= 0) & (local < n_valid)
    rows = jnp.take(D, jnp.clip(local, 0, D.shape[0] - 1), axis=0)
    s = q @ rows.T.astype(jnp.float32)
    return jnp.where(valid[None, :], s, -jnp.inf)


@partial(jax.jit, static_argnames=("k",))
def _cascade_select(parts_s, uids, k: int):
    """Combine per-segment rescore parts (each uid live in exactly one
    segment, so elementwise max is exact) and select the final top-k."""
    s = parts_s[0]
    for p in parts_s[1:]:
        s = jnp.maximum(s, p)
    return _topk_merge(s, jnp.broadcast_to(uids[None, :], s.shape), k)


def _jit_cache_sizes() -> dict:
    """Compiled-variant counts of every cascade jit, merged into
    ``repro.core.index.segment_jit_cache_sizes`` for recompile soaks."""
    return {fn.__wrapped__.__name__: fn._cache_size()
            for fn in (_cascade_dense_projected, _cascade_shortlist,
                       _segment_rescore, _cascade_select)}


def _coarse_rows(full) -> np.ndarray:
    """Dequantised f32 leading-column source rows of an existing index."""
    v = np.asarray(full.vectors[:full.n], np.float32)
    if full.scale is not None:
        v = v * np.asarray(full.scale, np.float32)[None, :]
    return v


@dataclasses.dataclass(frozen=True)
class CascadeIndex:
    """Two-resolution cascade over one corpus: coarse scan → exact rescore.

    ``coarse`` holds the first ``m_coarse`` PCA dims (int8 by default),
    ``full`` the complete pruned representation; both views index the SAME
    rows in the same order (validated), so a shortlist id from the first
    pass addresses the rescore row directly. ``n_factor`` sets the
    shortlist depth: the coarse pass keeps N·k candidates per query.

    Mutations are copy-on-write like the underlying indexes: ``append``
    returns a new ``CascadeIndex`` with BOTH resolutions grown, so a
    serving swap installs a consistent pair atomically.
    """

    coarse: DenseIndex | SegmentedIndex
    full: DenseIndex | SegmentedIndex
    n_factor: int = 8

    def __post_init__(self):
        if self.coarse.n != self.full.n:
            raise ValueError(
                f"cascade resolutions disagree on row count: coarse has "
                f"{self.coarse.n} rows, full has {self.full.n}")
        if not 0 < self.coarse.dim < self.full.dim:
            raise ValueError(
                f"coarse m={self.coarse.dim} does not nest inside full "
                f"m={self.full.dim} (need 0 < m_coarse < m)")
        if self.n_factor < 1:
            raise ValueError(f"n_factor must be >= 1, got {self.n_factor}")

    # -- construction -------------------------------------------------------
    @classmethod
    def build(cls, pruned, *, m_coarse: int, n_factor: int = 8,
              quantize_int8: bool = False, coarse_int8: bool = True,
              backend: Backend = "jnp") -> "CascadeIndex":
        """Build both resolutions from one (n, m) pruned f32 matrix.

        The coarse index is the leading ``m_coarse`` columns — PCA dims
        nest, so no second projection exists — re-quantised with its OWN
        per-dim scale (``coarse_int8``); ``quantize_int8`` controls the
        full-resolution storage as usual.
        """
        v = jnp.asarray(pruned)
        full = DenseIndex.build(v, quantize_int8=quantize_int8,
                                backend=backend)
        coarse = DenseIndex.build(v[:, :m_coarse],
                                  quantize_int8=coarse_int8, backend=backend)
        return cls(coarse=coarse, full=full, n_factor=n_factor)

    @classmethod
    def from_index(cls, full: DenseIndex, *, m_coarse: int,
                   n_factor: int = 8, coarse_int8: bool = True
                   ) -> "CascadeIndex":
        """Derive the coarse resolution from an existing full index (via
        its dequantised rows — exact for f32 storage, reconstruction for
        int8)."""
        coarse = DenseIndex.build(
            jnp.asarray(_coarse_rows(full)[:, :m_coarse]),
            quantize_int8=coarse_int8, backend=full.backend)
        return cls(coarse=coarse, full=full, n_factor=n_factor)

    @classmethod
    def load(cls, store, *, m_coarse: int | None = None, n_factor: int = 8,
             backend: Backend = "jnp", segmented: bool = False,
             paged: bool = False, page_rows: int | None = None,
             pool_pages: int | None = None,
             delta_capacity: int = 4096) -> "CascadeIndex":
        """Load a multi-resolution artifact: the main segments become the
        full resolution, the ``m_coarse`` resolution entry the coarse one
        (``m_coarse=None`` picks the widest stored resolution).

        On a segmented load the coarse deltas rehydrate from the
        resolution's PERSISTED delta segments when the store carries them
        (``save_index`` on a segmented cascade writes the exact quantised
        bytes + per-delta scales, so the reload is bit-identical to what
        was serving). A store without them — or one whose main deltas have
        grown past the persisted coarse view — falls back to re-deriving
        (requantising) the coarse deltas from the full deltas' dequantised
        rows, so the pair stays row-aligned however far the store has
        grown.
        """
        from repro.core.store import IndexStore, IndexStoreError
        if isinstance(store, (str, os.PathLike)):
            store = IndexStore.open(store)
        if paged:
            # the segmented load path rehydrates both resolutions
            # byte-for-byte; the page tables then adopt those bytes —
            # the paged block (when present) supplies the geometry
            pb = store.manifest.get("paged") or {}
            inner = cls.load(store, m_coarse=m_coarse, n_factor=n_factor,
                             backend=backend, segmented=True,
                             delta_capacity=int(pb.get("seal_rows",
                                                       delta_capacity)))
            return inner.paged(
                page_rows=int(pb.get("page_rows", 256))
                if page_rows is None else page_rows,
                pool_pages=pool_pages,
                seal_rows=int(pb.get("seal_rows", delta_capacity)))
        views = store.resolutions()
        if not views:
            raise IndexStoreError(
                f"{store.path}: no coarse resolutions in manifest — write "
                f"one with IndexStore.add_resolution before loading a "
                f"cascade")
        if m_coarse is None:
            view = max(views, key=lambda v: v.dim)
        else:
            by_m = {v.dim: v for v in views}
            if m_coarse not in by_m:
                raise IndexStoreError(
                    f"{store.path}: no m={m_coarse} resolution (stored: "
                    f"{sorted(by_m)})")
            view = by_m[m_coarse]
        coarse = DenseIndex.load(view, backend=backend)
        if segmented:
            from repro.core.index import rehydrate_delta
            full = SegmentedIndex.load(store, backend=backend,
                                       delta_capacity=delta_capacity)
            coarse = SegmentedIndex.from_index(
                coarse, delta_capacity=delta_capacity)
            dviews = store.resolution_deltas(view.name)
            if dviews and ([v.n for v in dviews]
                           == [d.n_real for d in full.deltas]):
                # persisted coarse deltas mirror the main ones row-for-row:
                # rehydrate the exact quantised bytes (no requantisation)
                coarse = dataclasses.replace(
                    coarse, deltas=tuple(rehydrate_delta(v, delta_capacity)
                                         for v in dviews))
            else:
                # legacy artifact (or the main store grew past the persisted
                # view): re-derive coarse deltas from the full deltas
                for d in full.deltas:
                    if d.n_real:
                        coarse = coarse.append(d.raw[:, :coarse.dim])
        else:
            full = DenseIndex.load(store, backend=backend)
        return cls(coarse=coarse, full=full, n_factor=n_factor)

    def segmented(self, *, delta_capacity: int = 4096) -> "CascadeIndex":
        """Wrap both resolutions as single-base segmented indexes (the
        live-append serving form; appends grow the pair in lockstep)."""
        return dataclasses.replace(
            self,
            coarse=SegmentedIndex.from_index(self.coarse,
                                             delta_capacity=delta_capacity),
            full=SegmentedIndex.from_index(self.full,
                                           delta_capacity=delta_capacity))

    def paged(self, *, page_rows: int = 256, pool_pages: int | None = None,
              coarse_pool_pages: int | None = None, seal_rows: int = 4096,
              depth: int = 2, wave_pages: int = 8) -> "CascadeIndex":
        """Re-home both resolutions on paged storage (byte-for-byte): the
        coarse scan and the exact rescore then both stream through the
        page tables — appends, promotion, compaction and eviction become
        pointer swaps on BOTH sides of the cascade, and either side may
        oversubscribe device memory independently (``pool_pages`` /
        ``coarse_pool_pages``)."""
        from repro.core.paged import PagedIndex

        def conv(ix, pool):
            if isinstance(ix, SegmentedIndex):
                return PagedIndex.from_segmented(
                    ix, page_rows=page_rows, pool_pages=pool, depth=depth,
                    wave_pages=wave_pages)
            return PagedIndex.from_index(
                ix, page_rows=page_rows, pool_pages=pool,
                seal_rows=seal_rows, depth=depth, wave_pages=wave_pages)

        return dataclasses.replace(self, full=conv(self.full, pool_pages),
                                   coarse=conv(self.coarse,
                                               coarse_pool_pages))

    # -- shape --------------------------------------------------------------
    @property
    def n(self) -> int:
        return self.full.n

    @property
    def dim(self) -> int:
        """Search dim = the FULL resolution's width (the projection's m)."""
        return self.full.dim

    @property
    def m_coarse(self) -> int:
        return self.coarse.dim

    @property
    def nbytes(self) -> int:
        return self.coarse.nbytes + self.full.nbytes

    # -- growth (copy-on-write) --------------------------------------------
    def append(self, rows) -> "CascadeIndex":
        """Append pruned f32 rows (full m) to BOTH resolutions — the coarse
        side takes the leading columns. Requires segmented resolutions."""
        if not (isinstance(self.full, SegmentedIndex)
                or hasattr(self.full, "storage")):
            raise TypeError("append needs segmented or paged resolutions — "
                            "wrap with CascadeIndex.segmented()/.paged() "
                            "first")
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        return dataclasses.replace(
            self, full=self.full.append(rows),
            coarse=self.coarse.append(rows[:, :self.m_coarse]))

    # -- search -------------------------------------------------------------
    def search_projected(self, queries: jax.Array, components: jax.Array,
                         k: int = 10, *, mean: jax.Array | None = None,
                         block: int | None = None
                         ) -> tuple[jax.Array, jax.Array]:
        """Cascaded raw-query search. Same signature and same contract as
        the single-resolution ``search_projected``: with N·k >= n the
        result is bit-identical to the full-m exact search."""
        k = min(k, max(self.n, 1))
        nk = min(self.n_factor * k, max(self.n, 1))
        Q = jnp.atleast_2d(queries)
        W = jnp.asarray(components)
        if isinstance(self.full, SegmentedIndex) or hasattr(self.full,
                                                            "storage"):
            return self._segmented_search(Q, W, mean, k, nk)
        return _cascade_dense_projected(
            self.coarse.vectors, self.coarse.scale, self.full.vectors,
            self.full.scale, W, mean, Q, k, nk, block, self.full.backend)

    def _segmented_search(self, Q, W, mean, k: int, nk: int):
        """Segmented/paged cascade: shared projection, coarse scan over
        live segments or the coarse page table, then an exact rescore of
        the shared shortlist — per-segment dispatches combined by max, or
        the paged page-table walk (``PagedIndex.rescore``, bitwise the
        same parts-combine). Live counts/offsets (or page-table slot
        bounds) are traced operands throughout — zero recompiles."""
        qf = _project_nofold(Q, W, mean)
        qc = qf[:, :self.m_coarse]
        if hasattr(self.coarse, "storage"):       # paged coarse scan
            _, cids = self.coarse._search_qf(qc, nk)
        else:
            _, cids = self.coarse._merged_topk(qc, nk)
        uids = _cascade_shortlist(cids)
        if hasattr(self.full, "storage"):         # paged exact rescore
            acc = self.full.rescore(qf, uids)
            return _cascade_select((acc,), uids, k)
        base = self.full.base
        if not isinstance(base, DenseIndex):
            raise TypeError("segmented cascade rescore supports a dense "
                            "base only (sharded bases: see ROADMAP)")
        parts = [_segment_rescore(base.vectors, base.scale, qf, uids,
                                  jnp.int32(0), jnp.int32(base.n))]
        off = base.n
        for d in self.full.deltas:
            parts.append(_segment_rescore(d.vectors, d.scale, qf, uids,
                                          jnp.int32(off),
                                          jnp.int32(d.n_real)))
            off += d.n_real
        return _cascade_select(tuple(parts), uids, k)
