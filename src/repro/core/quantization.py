"""Symmetric int8 quantisation for embedding indexes (beyond-paper).

Per-dimension symmetric scaling composes cleanly with PCA pruning: after the
rotation D̂ = D W_m each column has a well-defined dynamic range (variance =
eigenvalue), so per-dim scales capture it tightly. Scoring folds the scale
into the query side: (D_int8 · diag(s)) q = D_int8 · (s ⊙ q), so the index
stays int8 end-to-end and dot products run int8×fp32→fp32 (TPU-friendly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def quantize_with_scale(X: np.ndarray, scale: np.ndarray) -> np.ndarray:
    """Host-side symmetric int8 under a GIVEN per-dim scale — the single
    definition of the round/clip/cast every store, segment, and spill path
    must share: bit-identity between disk, memory, and spill hinges on all
    of them quantising identically."""
    return np.clip(np.round(np.asarray(X, np.float32) / scale[None, :]),
                   -127, 127).astype(np.int8)


def scale_for(X: np.ndarray) -> np.ndarray:
    """Per-dim symmetric scale covering X's absmax (host-side)."""
    return (np.maximum(np.abs(np.asarray(X, np.float32)).max(axis=0), 1e-12)
            / 127.0).astype(np.float32)


def quantize_int8_per_dim(X: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-column int8. Returns (q (n,m) int8, scale (m,) fp32)."""
    Xf = X.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(Xf), axis=0)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(Xf / scale[None, :]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[None, :]


def quantization_error(X: jax.Array) -> jax.Array:
    """Relative Frobenius reconstruction error of int8 round-trip."""
    q, s = quantize_int8_per_dim(X)
    err = dequantize_int8(q, s) - X.astype(jnp.float32)
    return jnp.linalg.norm(err) / jnp.maximum(jnp.linalg.norm(X), 1e-12)
