"""Paged index memory: fixed-size pages behind an int32 indirection table.

The segmented live index (``core/index.py``) rebuilds whole arrays on delta
promotion/compaction and dispatches one computation per segment, so the
segment COUNT leaks into compiled shapes. ``PagedIndexStorage`` stores the
same rows as fixed ``page_rows``-row pages addressed through a page table:

  * ``pool``  — the stable device tier (promoted/compacted pages). Written
                only by the one-dispatch compaction drain; searches stream
                straight out of it.
  * ``tail``  — a small device write arena absorbing live appends (the
                delta role). O(tail) copy-on-write per append, like a
                delta's ``dynamic_update_slice`` — never O(index).
  * host tier — pages whose table entry is -1 live as host ``np`` arrays
                and stream on demand in bounded waves, so the index may
                exceed device memory (oversubscription).

Logical slots are contiguous per extent (base extents first, then deltas,
ascending global-id order). Every lifecycle step is a page-pointer swap:

  append   — write rows into tail pages, grow the open delta extent;
  seal     — the open extent freezes at ``seal_rows`` rows (metadata);
  promote  — sealed delta extents become base extents (metadata only);
  compact  — promoted tail pages drain into free pool slots in ONE fused
             gather dispatch (``_pool_drain``) + a pointer swap — no
             requantisation, no index rebuild;
  evict    — device pages move to the host tier (pointer swap + host copy).

Search walks slots ``[lo, hi)`` with *traced* bounds over fixed-shape
arrays, so appends/seals/promotions/compactions/evictions never recompile.
An oversubscribed index splits into device/host runs chained through a
top-k carry; visit order stays ascending-slot, preserving the exact
lowest-id tie-break (and the skip-on-equality guard) of the segmented
path. Backends: 'jnp' (``lax.scan`` page walk) or 'pallas'
(``topk_score_paged_pallas`` — double-buffered ``make_async_copy`` DMA
pipeline prefetching page i+depth-1 while scoring page i).

Bit-parity: quantised bytes, per-extent scale evolution (fresh scale per
delta, widen = requantise from exact f32 staging), projection and fold
order, merge structure, and tie-breaks all mirror ``SegmentedIndex`` —
searches over equal contents are bit-identical across dense × f32/int8 ×
jnp/pallas, including the cascade rescore (pinned by tests/test_paged.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import (Backend, DenseIndex, SegmentedIndex,
                              _project_nofold, _topk_merge, project_queries)


class PageExtent(NamedTuple):
    """One logical row range: ``n_rows`` rows in ``n_pages`` contiguous
    slots starting at ``start_slot``; global ids ``[row_offset,
    row_offset + n_rows)``. ``scale`` is the extent's int8 dequant scale
    (replicated into the per-page scale rows); ``raw`` is the exact f32
    staging kept only while a delta extent is open (the requant source
    when an append widens the scale — same contract as ``DeltaSegment``).
    """

    kind: str                    # "base" | "delta"
    sealed: bool
    start_slot: int
    n_pages: int
    n_rows: int
    row_offset: int
    scale: np.ndarray | None
    raw: np.ndarray | None


@jax.jit
def _pool_drain(pool, tail, sel):
    """One fused compaction dispatch: pool slot p takes tail page
    ``sel[p]`` when ``sel[p] >= 0``, else keeps its page. A gather + a
    select — one O(pool) pass, deterministic, no scatter aliasing."""
    take = jnp.clip(sel, 0, tail.shape[0] - 1)
    return jnp.where((sel >= 0)[:, None, None], tail[take], pool)


def _paged_core(pool, tail, pt, scale, nv, off, lo, hi, Qf, k: int,
                guard: str, carry, finalize: bool):
    """Traced jnp page walk: running top-k over slots [lo, hi).

    Mirrors ``_scan_topk``'s merge structure (strip ``top_k``, running
    list first in the concat, per-row guard with masked merge) page by
    page, and the Pallas kernel's pad semantics (unique negative init ids,
    clamped to -1 at ``finalize``) so device/host runs chain through the
    carry bitwise-consistently on both backends.
    """
    pool_pages, R, m = pool.shape
    table_cap = pt.shape[0]
    B = Qf.shape[0]
    kk = min(k, R)
    if carry is None:
        bs = jnp.full((B, k), -jnp.inf, jnp.float32)
        bi = -(jnp.broadcast_to(
            jnp.arange(k, dtype=jnp.int32)[None, :], (B, k)) + 2)
    else:
        bs = carry[0].astype(jnp.float32)
        bi = carry[1].astype(jnp.int32)

    def body(c, t):
        bs, bi = c
        live = (t >= lo) & (t < hi)
        phys = pt[t]
        pg = pool[jnp.clip(phys, 0, pool_pages - 1)]
        if tail is not None:
            pgt = tail[jnp.clip(phys - pool_pages, 0, tail.shape[0] - 1)]
            pg = jnp.where(phys >= pool_pages, pgt, pg)
        q = Qf if scale is None else Qf * scale[t][None, :]
        s = jax.lax.dot_general(q, pg.astype(jnp.float32),
                                dimension_numbers=(((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (B, R)
        iota = jnp.arange(R, dtype=jnp.int32)[None, :]
        gids = jnp.broadcast_to(off[t] + iota, s.shape)
        s = jnp.where((iota < nv[t]) & live, s, -jnp.inf)
        imp = jnp.max(s, axis=1) > jnp.min(bs, axis=1)           # (B,)

        def merge(cin):
            bs0, bi0 = cin
            ss, si = jax.lax.top_k(s, kk)
            gi = jnp.take_along_axis(gids, si, axis=1)
            cs = jnp.concatenate([bs0, ss], axis=1)
            ci = jnp.concatenate([bi0, gi], axis=1)
            ms, mi = _topk_merge(cs, ci, k)
            if guard == "row":
                ms = jnp.where(imp[:, None], ms, bs0)
                mi = jnp.where(imp[:, None], mi, bi0)
            return ms, mi

        if guard == "row":
            can = jnp.any(imp)
        else:
            can = jnp.max(s) > jnp.min(bs)
        return jax.lax.cond(can, merge, lambda x: x, (bs, bi)), None

    (bs, bi), _ = jax.lax.scan(body, (bs, bi),
                               jnp.arange(table_cap, dtype=jnp.int32))
    if finalize:
        bi = jnp.maximum(bi, -1)
    return bs, bi


def _dispatch_topk(pool, tail, pt, scale, nv, off, lo, hi, q, k, backend,
                   depth, guard, carry, finalize):
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.topk_score_paged(pool, pt, nv, off, lo, hi, q, k=k,
                                     tail=tail, page_scale=scale, carry=carry,
                                     depth=depth, guard=guard,
                                     finalize=finalize)
    return _paged_core(pool, tail, pt, scale, nv, off, lo, hi, q, k,
                       guard, carry, finalize)


@partial(jax.jit, static_argnames=("k", "backend", "depth", "guard",
                                   "finalize"))
def _paged_topk(pool, tail, pt, scale, nv, off, lo, hi, Q, *, k: int,
                backend: Backend, depth: int, guard: str = "row",
                carry=None, finalize: bool = True):
    """One compiled paged-search dispatch over slots [lo, hi) (traced) —
    pre-projected queries; every lifecycle mutation reuses this shape."""
    q = jnp.atleast_2d(Q).astype(jnp.float32)
    return _dispatch_topk(pool, tail, pt, scale, nv, off, lo, hi, q, k,
                          backend, depth, guard, carry, finalize)


@partial(jax.jit, static_argnames=("k", "backend", "depth", "guard"))
def _paged_search_projected(pool, tail, pt, scale, nv, off, lo, hi, W, mean,
                            Q, *, k: int, backend: Backend, depth: int,
                            guard: str = "row"):
    """The serving hot path: projection + page walk in ONE dispatch (the
    paged analogue of ``_dense_search_projected``). No scale fold at
    projection — per-page scales fold inside the walk, exactly like the
    segmented per-segment fold, so results stay bit-identical."""
    q = project_queries(jnp.atleast_2d(Q), W, scale=None, mean=mean)
    return _dispatch_topk(pool, tail, pt, scale, nv, off, lo, hi, q, k,
                          backend, depth, guard, None, True)


@jax.jit
def _paged_rescore(pool, tail, pt, scale, nv, off, lo, hi, qf, uids, acc):
    """Cascade rescore over pages: max-combine each page's contribution to
    the shared shortlist into ``acc`` (B, U).

    Per page this is exactly ``_segment_rescore`` — fold the extent scale
    into q, gather shortlist rows in storage dtype, one (B,m)×(m,U)
    matmul, -inf outside the page — and each live uid falls in exactly one
    page, so the elementwise max equals the segmented parts-combine
    bitwise (``_cascade_select`` invariant).
    """
    pool_pages, R, m = pool.shape
    table_cap = pt.shape[0]

    def body(acc, t):
        live = (t >= lo) & (t < hi)
        phys = pt[t]
        pg = pool[jnp.clip(phys, 0, pool_pages - 1)]
        if tail is not None:
            pgt = tail[jnp.clip(phys - pool_pages, 0, tail.shape[0] - 1)]
            pg = jnp.where(phys >= pool_pages, pgt, pg)
        q = qf if scale is None else qf * scale[t][None, :]
        local = uids - off[t]
        valid = (uids >= 0) & (local >= 0) & (local < nv[t]) & live
        rows = jnp.take(pg, jnp.clip(local, 0, R - 1), axis=0)   # (U, m)
        s = q @ rows.T.astype(jnp.float32)                       # (B, U)
        return jnp.maximum(acc, jnp.where(valid[None, :], s, -jnp.inf)), None

    acc, _ = jax.lax.scan(body, acc, jnp.arange(table_cap, dtype=jnp.int32))
    # re-assert the shortlist sentinel OUTSIDE the scan: every dead uid is
    # already -inf from each page's in-scan mask, but the carry hides that
    # from the invariant interpreter — the contract (-1 slots never compete
    # in the final top-k) must be provable at the jaxpr top level
    return jnp.where(uids[None, :] >= 0, acc, -jnp.inf)


def _jit_cache_sizes() -> dict:
    """Compiled-variant counts of every paged-search jit, merged into
    ``repro.core.index.segment_jit_cache_sizes`` for recompile soaks."""
    from repro.kernels.topk_score import topk_score_paged_pallas
    sizes = {fn.__wrapped__.__name__: fn._cache_size()
             for fn in (_paged_topk, _paged_search_projected, _paged_rescore,
                        _pool_drain)}
    sizes["topk_score_paged_pallas"] = topk_score_paged_pallas._cache_size()
    return sizes


class _Mut:
    """Scratch copy-on-write view of a storage's host-side state: every
    mutation edits a private copy, then ``freeze`` pushes the metadata to
    device in one ``asarray`` batch (fixed shapes — no recompiles)."""

    def __init__(self, st: "PagedIndexStorage"):
        self.st = st
        self.pt = st.pt_host.copy()
        self.nv = st.nvalid_host.copy()
        self.off = st.offset_host.copy()
        self.sc = None if st.scale_host is None else st.scale_host.copy()
        self.tail_host = st.tail_host
        self._tail_copied = False
        self.host_pages = dict(st.host_pages)
        self.extents = list(st.extents)
        self.free_pool = list(st.free_pool)
        self.free_tail = list(st.free_tail)
        self.table_grows = st.table_grows
        self.pool = st.pool

    @property
    def R(self) -> int:
        return self.st.page_rows

    def _tail(self) -> np.ndarray:
        if not self._tail_copied:
            self.tail_host = self.tail_host.copy()
            self._tail_copied = True
        return self.tail_host

    def ensure_slots(self, n_needed: int) -> None:
        cap = self.pt.shape[0]
        if n_needed <= cap:
            return
        new_cap = cap
        while new_cap < n_needed:
            new_cap *= 2
        grow = new_cap - cap
        self.pt = np.concatenate([self.pt, np.full(grow, -1, np.int32)])
        self.nv = np.concatenate([self.nv, np.zeros(grow, np.int32)])
        self.off = np.concatenate([self.off, np.zeros(grow, np.int32)])
        if self.sc is not None:
            self.sc = np.concatenate(
                [self.sc, np.zeros((grow, self.sc.shape[1]), np.float32)])
        self.table_grows += 1          # shape change: a counted recompile

    def alloc_page(self, slot: int, offset: int) -> None:
        """Back a fresh logical slot: tail tier while arena slots remain,
        host tier once the arena is full (append never fails)."""
        self.ensure_slots(slot + 1)
        if self.free_tail:
            local = self.free_tail.pop(0)
            self.pt[slot] = self.st.pool_pages + local
        else:
            self.pt[slot] = -1
            self.host_pages[slot] = np.zeros(
                (self.R, self.st.dim), self.st.np_dtype)
        self.nv[slot] = 0
        self.off[slot] = offset

    def write_rows(self, slot: int, row0: int, rows: np.ndarray) -> None:
        """Write ``rows`` (storage dtype) into a page at in-page ``row0``."""
        phys = int(self.pt[slot])
        if phys >= 0:
            local = phys - self.st.pool_pages
            if local < 0:
                raise AssertionError("writes only target tail/host pages")
            t = self._tail()
            t[local, row0:row0 + rows.shape[0]] = rows
        else:
            page = self.host_pages[slot].copy()   # COW: readers keep theirs
            page[row0:row0 + rows.shape[0]] = rows
            self.host_pages[slot] = page
        self.nv[slot] = max(int(self.nv[slot]), row0 + rows.shape[0])

    def set_scale(self, slot: int, scale: np.ndarray) -> None:
        if self.sc is not None:
            self.sc[slot] = scale

    def freeze(self, pool=None) -> "PagedIndexStorage":
        tail_dev = (jnp.asarray(self.tail_host) if self._tail_copied
                    else self.st.tail)
        return dataclasses.replace(
            self.st,
            pool=self.st.pool if pool is None else pool,
            tail=tail_dev,
            page_table=jnp.asarray(self.pt),
            page_scale=None if self.sc is None else jnp.asarray(self.sc),
            page_nvalid=jnp.asarray(self.nv),
            page_offset=jnp.asarray(self.off),
            pt_host=self.pt, nvalid_host=self.nv, offset_host=self.off,
            scale_host=self.sc, tail_host=self.tail_host,
            host_pages=self.host_pages, extents=tuple(self.extents),
            free_pool=tuple(self.free_pool), free_tail=tuple(self.free_tail),
            table_grows=self.table_grows)


@dataclasses.dataclass(frozen=True, eq=False)
class PagedIndexStorage:
    """Two device page tiers + a host tier behind one indirection table.

    Immutable: every mutation returns a NEW storage sharing untouched
    arrays (the ``RetrievalServer`` swap discipline — in-flight searches
    keep the old table/pools alive until their replies post). The host
    ``*_host`` mirrors are authoritative; the device copies are re-pushed
    whole per mutation (fixed shapes, tiny for metadata, O(tail) for the
    write arena — the same cost class as a delta's update slice).
    """

    pool: jax.Array                    # (pool_pages, R, m) stable tier
    tail: jax.Array                    # (tail_pages, R, m) write arena
    page_table: jax.Array              # (table_cap,) int32; -1 = host tier
    page_scale: jax.Array | None       # (table_cap, m) f32 (int8 pools)
    page_nvalid: jax.Array             # (table_cap,) int32 live rows/page
    page_offset: jax.Array             # (table_cap,) int32 first global id
    pt_host: np.ndarray
    nvalid_host: np.ndarray
    offset_host: np.ndarray
    scale_host: np.ndarray | None
    tail_host: np.ndarray              # host staging of the write arena
    host_pages: dict                   # slot -> (R, m) np page (host tier)
    extents: tuple
    free_pool: tuple
    free_tail: tuple
    page_rows: int
    seal_rows: int
    table_grows: int = 0

    # -- shape ---------------------------------------------------------------
    @property
    def pool_pages(self) -> int:
        return self.pool.shape[0]

    @property
    def tail_pages(self) -> int:
        return self.tail.shape[0]

    @property
    def table_cap(self) -> int:
        return self.pt_host.shape[0]

    @property
    def dim(self) -> int:
        return self.pool.shape[2]

    @property
    def np_dtype(self):
        return np.dtype(self.pool.dtype)

    @property
    def quantized(self) -> bool:
        return self.scale_host is not None

    @property
    def n_slots(self) -> int:
        return sum(e.n_pages for e in self.extents)

    @property
    def n_rows(self) -> int:
        return sum(e.n_rows for e in self.extents)

    @property
    def delta_pages(self) -> int:
        return sum(e.n_pages for e in self.extents if e.kind == "delta")

    @property
    def delta_rows(self) -> int:
        return sum(e.n_rows for e in self.extents if e.kind == "delta")

    @property
    def n_host_pages(self) -> int:
        return len(self.host_pages)

    @property
    def nbytes(self) -> int:
        b = self.pool.size * self.pool.dtype.itemsize
        b += self.tail.size * self.tail.dtype.itemsize
        b += self.page_table.size * 4 + self.page_nvalid.size * 4
        b += self.page_offset.size * 4
        if self.page_scale is not None:
            b += self.page_scale.size * 4
        return b

    # -- construction --------------------------------------------------------
    @classmethod
    def from_index(cls, base: DenseIndex, *, page_rows: int = 256,
                   pool_pages: int | None = None,
                   tail_pages: int | None = None,
                   table_cap: int | None = None,
                   seal_rows: int = 4096) -> "PagedIndexStorage":
        """Page an immutable base index. ``pool_pages`` below the base's
        page count oversubscribes at construction: the overflow suffix
        lives on the host tier and streams at search time."""
        R = page_rows
        vec = np.asarray(base.vectors)
        scale = (None if base.scale is None
                 else np.asarray(base.scale, np.float32))
        n, m = vec.shape
        npages = -(-n // R) if n else 0
        if tail_pages is None:
            tail_pages = max(2 * (-(-seal_rows // R)), 2)
        if pool_pages is None:
            pool_pages = npages + max(tail_pages, 8)
        pool_pages = max(pool_pages, 1)
        if table_cap is None:
            table_cap = max(2 * (npages + tail_pages) + 8, 16)
        table_cap = max(table_cap, npages + 1)

        pt = np.full(table_cap, -1, np.int32)
        nv = np.zeros(table_cap, np.int32)
        off = np.zeros(table_cap, np.int32)
        sc = (np.zeros((table_cap, m), np.float32)
              if scale is not None else None)
        pool_np = np.zeros((pool_pages, R, m), vec.dtype)
        host_pages: dict = {}
        for j in range(npages):
            rows = vec[j * R:(j + 1) * R]
            nv[j] = rows.shape[0]
            off[j] = j * R
            if sc is not None:
                sc[j] = scale
            if j < pool_pages:
                pool_np[j, :rows.shape[0]] = rows
                pt[j] = j
            else:
                page = np.zeros((R, m), vec.dtype)
                page[:rows.shape[0]] = rows
                host_pages[j] = page
        extents = ((PageExtent("base", True, 0, npages, n, 0, scale, None),)
                   if n else ())
        return cls(
            pool=jnp.asarray(pool_np), tail=jnp.asarray(
                np.zeros((tail_pages, R, m), vec.dtype)),
            page_table=jnp.asarray(pt),
            page_scale=None if sc is None else jnp.asarray(sc),
            page_nvalid=jnp.asarray(nv), page_offset=jnp.asarray(off),
            pt_host=pt, nvalid_host=nv, offset_host=off, scale_host=sc,
            tail_host=np.zeros((tail_pages, R, m), vec.dtype),
            host_pages=host_pages, extents=extents,
            free_pool=tuple(range(min(npages, pool_pages), pool_pages)),
            free_tail=tuple(range(tail_pages)), page_rows=R,
            seal_rows=seal_rows)

    @classmethod
    def from_segmented(cls, seg: SegmentedIndex, *, page_rows: int = 256,
                       pool_pages: int | None = None,
                       tail_pages: int | None = None,
                       table_cap: int | None = None) -> "PagedIndexStorage":
        """Convert a live segmented index byte-for-byte: the base pages
        into the pool, each delta becomes a delta extent in the tail with
        its own scale (and its exact f32 staging when still open), and
        ``seal_rows`` adopts the delta capacity — continued appends evolve
        scales exactly like the segmented path would have."""
        if not isinstance(seg.base, DenseIndex):
            raise TypeError("PagedIndexStorage.from_segmented needs a "
                            "DenseIndex base — page the sharded artifact "
                            "per shard instead")
        R = page_rows
        need_tail = sum(-(-d.capacity // R) for d in seg.deltas)
        if tail_pages is None:
            tail_pages = max(2 * (-(-seg.delta_capacity // R)),
                             need_tail + (-(-seg.delta_capacity // R)), 2)
        st = cls.from_index(seg.base, page_rows=R, pool_pages=pool_pages,
                            tail_pages=tail_pages, table_cap=table_cap,
                            seal_rows=seg.delta_capacity)
        for di, d in enumerate(seg.deltas):
            stored = np.asarray(d.vectors[:d.n_real])
            dscale = None if d.scale is None else np.asarray(d.scale,
                                                             np.float32)
            sealed = d.n_real >= d.capacity
            st = st._adopt_extent(stored, dscale,
                                  raw=None if sealed else d.raw,
                                  sealed=sealed)
        return st

    def _adopt_extent(self, stored: np.ndarray, scale: np.ndarray | None,
                      *, raw: np.ndarray | None,
                      sealed: bool) -> "PagedIndexStorage":
        """Append a whole pre-quantised extent (segmented-delta adoption)."""
        mut = _Mut(self)
        R = self.page_rows
        start_slot = self.n_slots
        row_offset = self.n_rows
        n = stored.shape[0]
        npages = -(-n // R) if n else 0
        for pi in range(npages):
            slot = start_slot + pi
            mut.alloc_page(slot, row_offset + pi * R)
            mut.write_rows(slot, 0, stored[pi * R:(pi + 1) * R])
            if scale is not None:
                mut.set_scale(slot, scale)
        mut.extents.append(PageExtent("delta", sealed, start_slot, npages,
                                      n, row_offset, scale, raw))
        return mut.freeze()

    def extent_rows(self, ei: int) -> np.ndarray:
        """One extent's stored bytes in global-id order, gathered off
        whatever tier each page lives on (pool/tail/host) — the
        persistence source (``save_paged_index``) and the requant-staging
        rehydration source on load."""
        e = self.extents[ei]
        R = self.page_rows
        out = np.empty((e.n_rows, self.dim), self.np_dtype)
        pool_np = None
        for pi in range(e.n_pages):
            slot = e.start_slot + pi
            phys = int(self.pt_host[slot])
            if phys < 0:
                page = self.host_pages[slot]
            elif phys >= self.pool_pages:
                page = self.tail_host[phys - self.pool_pages]
            else:
                if pool_np is None:       # one device pull, not per page
                    pool_np = np.asarray(self.pool)
                page = pool_np[phys]
            lo = pi * R
            take = min(R, e.n_rows - lo)
            out[lo:lo + take] = page[:take]
        return out

    # -- growth (copy-on-write) ---------------------------------------------
    def append_with_ops(self, rows) -> tuple["PagedIndexStorage", list]:
        """Append f32 rows; page-pointer swaps only — no array rebuilds.

        Rows land in the open delta extent (tail-tier pages; host-tier
        once the arena is full) which seals at ``seal_rows``. Scale
        evolution is ``DeltaSegment``'s exactly: a fresh per-dim scale per
        extent, widen = ``max(old, need)`` + requantise the extent from
        its exact f32 staging. Emits the same op stream as
        ``SegmentedIndex.append_with_ops`` (("open"|"extend"|"widen"),
        delta-ordinal, stored bytes[, scale]) so durable mirrors carry
        over unchanged — disk and memory stay bit-identical.
        """
        from repro.core.quantization import quantize_with_scale, scale_for
        rows = np.atleast_2d(np.asarray(rows, np.float32))
        if rows.shape[1] != self.dim:
            raise ValueError(f"append expects (rows, {self.dim}), got "
                             f"{tuple(rows.shape)}")
        st = self
        ops: list = []
        pos = 0
        while pos < rows.shape[0]:
            mut = _Mut(st)
            open_ei = None
            if (mut.extents and mut.extents[-1].kind == "delta"
                    and not mut.extents[-1].sealed):
                open_ei = len(mut.extents) - 1
            ordinal = sum(1 for e in mut.extents if e.kind == "delta")
            if open_ei is not None:
                ext = mut.extents[open_ei]
                ordinal -= 1                      # this delta's own ordinal
                take = min(rows.shape[0] - pos, st.seal_rows - ext.n_rows)
                block = rows[pos:pos + take]
                raw = np.concatenate([ext.raw, block])
                if ext.scale is not None:
                    need = scale_for(block)
                    scale = np.maximum(ext.scale, need).astype(np.float32)
                    if bool((scale > ext.scale).any()):
                        stored_all = quantize_with_scale(raw, scale)
                        st = st._widen_extent(mut, open_ei, raw, stored_all,
                                              scale)
                        ops.append(("widen", ordinal, stored_all, scale))
                        pos += take
                        continue
                    stored = quantize_with_scale(block, ext.scale)
                else:
                    stored = block.astype(st.np_dtype)
                st = st._extend_extent(mut, open_ei, raw, stored)
                ops.append(("extend", ordinal, stored))
            else:
                take = min(rows.shape[0] - pos, st.seal_rows)
                block = rows[pos:pos + take]
                if st.quantized:
                    scale = scale_for(block)
                    stored = quantize_with_scale(block, scale)
                else:
                    scale = None
                    stored = block.astype(st.np_dtype)
                st = st._open_extent(mut, stored, scale, block)
                ops.append(("open", ordinal, stored, scale))
            pos += take
        return st, ops

    def append(self, rows) -> "PagedIndexStorage":
        return self.append_with_ops(rows)[0]

    def _open_extent(self, mut: "_Mut", stored: np.ndarray,
                     scale: np.ndarray | None,
                     raw: np.ndarray) -> "PagedIndexStorage":
        R = self.page_rows
        start_slot = self.n_slots
        row_offset = self.n_rows
        n = stored.shape[0]
        npages = -(-n // R)
        for pi in range(npages):
            slot = start_slot + pi
            mut.alloc_page(slot, row_offset + pi * R)
            mut.write_rows(slot, 0, stored[pi * R:(pi + 1) * R])
            if scale is not None:
                mut.set_scale(slot, scale)
        sealed = n >= self.seal_rows
        mut.extents.append(PageExtent(
            "delta", sealed, start_slot, npages, n, row_offset, scale,
            None if sealed else np.ascontiguousarray(raw)))
        return mut.freeze()

    def _extend_extent(self, mut: "_Mut", ei: int, raw: np.ndarray,
                       stored: np.ndarray) -> "PagedIndexStorage":
        R = self.page_rows
        ext = mut.extents[ei]
        r = ext.n_rows                     # extent-local first new row
        pos = 0
        n_pages = ext.n_pages
        while pos < stored.shape[0]:
            pi = r // R
            slot = ext.start_slot + pi
            if pi >= n_pages:              # grow the (last) open extent
                mut.alloc_page(slot, ext.row_offset + pi * R)
                if ext.scale is not None:
                    mut.set_scale(slot, ext.scale)
                n_pages = pi + 1
            in_page = r - pi * R
            chunk = min(stored.shape[0] - pos, R - in_page)
            mut.write_rows(slot, in_page, stored[pos:pos + chunk])
            pos += chunk
            r += chunk
        n = ext.n_rows + stored.shape[0]
        sealed = n >= self.seal_rows
        mut.extents[ei] = ext._replace(
            n_pages=n_pages, n_rows=n, sealed=sealed,
            raw=None if sealed else raw)
        return mut.freeze()

    def _widen_extent(self, mut: "_Mut", ei: int, raw: np.ndarray,
                      stored_all: np.ndarray,
                      scale: np.ndarray) -> "PagedIndexStorage":
        """Scale widened: requantise the whole extent from exact f32
        staging and rewrite its pages in place — bounded by ``seal_rows``
        (the tractability argument for per-extent scales)."""
        R = self.page_rows
        ext = mut.extents[ei]
        n = stored_all.shape[0]
        npages = -(-n // R)
        for pi in range(npages):
            slot = ext.start_slot + pi
            if pi >= ext.n_pages:
                mut.alloc_page(slot, ext.row_offset + pi * R)
            mut.write_rows(slot, 0, stored_all[pi * R:(pi + 1) * R])
            mut.set_scale(slot, scale)
        sealed = n >= self.seal_rows
        mut.extents[ei] = ext._replace(
            n_pages=npages, n_rows=n, sealed=sealed, scale=scale,
            raw=None if sealed else raw)
        return mut.freeze()

    # -- lifecycle: pointer swaps -------------------------------------------
    def promote(self) -> tuple["PagedIndexStorage", int]:
        """Sealed delta extents become base extents — metadata only, zero
        page bytes move. Returns (new storage, extents promoted)."""
        promoted = 0
        extents = []
        for e in self.extents:
            if e.kind == "delta" and e.sealed:
                extents.append(e._replace(kind="base", scale=e.scale))
                promoted += 1
            else:
                extents.append(e)
        if not promoted:
            return self, 0
        return dataclasses.replace(self, extents=tuple(extents)), promoted

    def compact(self) -> tuple["PagedIndexStorage", dict]:
        """Seal + promote every delta extent, then drain its tail-tier
        pages into free pool slots with ONE fused gather dispatch — the
        pointer-swap compaction. No requantisation, no rebuild; telemetry
        counts pages, not rows (stale-signal fix for the fleet's
        auto-compaction controller)."""
        mut = _Mut(self)
        for ei, e in enumerate(mut.extents):
            if e.kind == "delta":
                mut.extents[ei] = e._replace(kind="base", sealed=True,
                                             raw=None)
        sel = np.full(self.pool_pages, -1, np.int32)
        moved = 0
        for e in mut.extents:
            for pi in range(e.n_pages):
                slot = e.start_slot + pi
                phys = int(mut.pt[slot])
                if phys >= self.pool_pages and mut.free_pool:
                    dst = mut.free_pool.pop(0)
                    sel[dst] = phys - self.pool_pages
                    mut.pt[slot] = dst
                    mut.free_tail.append(phys - self.pool_pages)
                    moved += 1
        pool = _pool_drain(self.pool, self.tail, jnp.asarray(sel)) \
            if moved else None
        stats = {"pages_moved": moved, "pages_freed": moved,
                 "pages_host": len(mut.host_pages)}
        return mut.freeze(pool=pool), stats

    def evict(self, n_pages: int) -> tuple["PagedIndexStorage", int]:
        """Move the highest-slot pool-tier pages to the host tier (pointer
        swap + one host copy per page). Suffix-of-the-pool policy keeps
        the slot visit order ascending, so the skip-on-equality guard and
        lowest-id tie-breaks stay exact under oversubscription."""
        mut = _Mut(self)
        ns = self.n_slots
        cands = [s for s in range(ns)
                 if 0 <= mut.pt[s] < self.pool_pages][::-1][:n_pages]
        for slot in cands:
            phys = int(mut.pt[slot])
            mut.host_pages[slot] = np.asarray(self.pool[phys])
            mut.free_pool.append(phys)
            mut.pt[slot] = -1
        return mut.freeze(), len(cands)


@dataclasses.dataclass(frozen=True, eq=False)
class PagedIndex:
    """Search facade over ``PagedIndexStorage`` — the drop-in paged
    replacement for ``SegmentedIndex`` in serving (same ``search`` /
    ``search_projected`` / ``append`` surface, same copy-on-write swap
    discipline, bit-identical results at equal contents).

    ``depth`` is the DMA pipeline depth (pallas: page i+depth-1 prefetches
    while page i scores; jnp: host-wave staging lookahead). ``wave_pages``
    bounds the host-tier staging buffer — oversubscribed searches stream
    host pages in fixed-shape waves chained through the top-k carry.
    """

    storage: PagedIndexStorage
    backend: Backend = "jnp"
    depth: int = 2
    guard: str = "row"
    wave_pages: int = 8

    # -- shape ---------------------------------------------------------------
    @property
    def n(self) -> int:
        return self.storage.n_rows

    @property
    def dim(self) -> int:
        return self.storage.dim

    @property
    def nbytes(self) -> int:
        return self.storage.nbytes

    @property
    def quantized(self) -> bool:
        return self.storage.quantized

    @property
    def storage_dtype(self):
        return self.storage.pool.dtype

    @property
    def delta_rows(self) -> int:
        return self.storage.delta_rows

    @property
    def delta_pages(self) -> int:
        return self.storage.delta_pages

    @property
    def total_pages(self) -> int:
        return self.storage.n_slots

    # -- construction --------------------------------------------------------
    @classmethod
    def from_index(cls, base: DenseIndex, *, page_rows: int = 256,
                   pool_pages: int | None = None,
                   tail_pages: int | None = None,
                   table_cap: int | None = None, seal_rows: int = 4096,
                   backend: Backend | None = None, depth: int = 2,
                   wave_pages: int = 8) -> "PagedIndex":
        st = PagedIndexStorage.from_index(
            base, page_rows=page_rows, pool_pages=pool_pages,
            tail_pages=tail_pages, table_cap=table_cap, seal_rows=seal_rows)
        return cls(storage=st,
                   backend=base.backend if backend is None else backend,
                   depth=depth, wave_pages=wave_pages)

    @classmethod
    def from_segmented(cls, seg: SegmentedIndex, *, page_rows: int = 256,
                       pool_pages: int | None = None,
                       tail_pages: int | None = None,
                       table_cap: int | None = None,
                       backend: Backend | None = None, depth: int = 2,
                       wave_pages: int = 8) -> "PagedIndex":
        st = PagedIndexStorage.from_segmented(
            seg, page_rows=page_rows, pool_pages=pool_pages,
            tail_pages=tail_pages, table_cap=table_cap)
        if backend is None:
            backend = getattr(seg.base, "backend", "jnp")
        return cls(storage=st, backend=backend, depth=depth,
                   wave_pages=wave_pages)

    # -- persistence ---------------------------------------------------------
    @classmethod
    def load(cls, store, *, page_rows: int | None = None,
             pool_pages: int | None = None, tail_pages: int | None = None,
             table_cap: int | None = None, seal_rows: int | None = None,
             backend: Backend = "jnp", depth: int = 2,
             wave_pages: int = 8) -> "PagedIndex":
        """Rehydrate from an on-disk artifact bit-for-bit.

        A store written by ``save_paged_index`` carries the ``paged``
        manifest block (page geometry + extent lifecycle); extent i's
        bytes are segment i's bytes, so the load reuses the segmented
        rehydration, then re-applies the recorded extent kinds. The block
        may LAG the segments (crash between the append mirror's two
        manifest swaps): missing trailing extents reload as deltas, and
        sealed-ness is reconstructed conservatively (every non-last extent
        is sealed; the last one by row count or the fresh block entry).
        A plain segmented store (no block) pages directly — the migration
        path. ``pool_pages`` below the resident page count oversubscribes:
        the overflow streams from the host tier at search time.
        """
        import os
        from repro.core.store import IndexStore
        if isinstance(store, (str, os.PathLike)):
            store = IndexStore.open(store)
        pb = store.manifest.get("paged")
        if pb is not None:
            R = int(pb["page_rows"]) if page_rows is None else page_rows
            S = int(pb["seal_rows"]) if seal_rows is None else seal_rows
        else:
            R = 256 if page_rows is None else page_rows
            S = 4096 if seal_rows is None else seal_rows
        if pb is not None and pb["extents"] \
                and pb["extents"][0]["kind"] == "delta":
            # extent 0 is itself a delta (an index grown from empty): adopt
            # every segment through the writable tiers (tail/host) over a
            # zero-row base — pool pages reject writes, so an open extent
            # must never land there
            import types
            views = store.segments()
            shim = types.SimpleNamespace(
                vectors=np.zeros((0, store.dim), store.dtype),
                scale=views[0].scale())
            st = PagedIndexStorage.from_index(
                shim, page_rows=R, pool_pages=pool_pages,
                tail_pages=tail_pages, table_cap=table_cap, seal_rows=S)
            for v in views:
                st = st._adopt_extent(v.read_rows(0, v.n), v.scale(),
                                      raw=None, sealed=True)
        else:
            seg = SegmentedIndex.load(store, backend=backend,
                                      delta_capacity=S)
            st = PagedIndexStorage.from_segmented(
                seg, page_rows=R, pool_pages=pool_pages,
                tail_pages=tail_pages, table_cap=table_cap)
            st = dataclasses.replace(st, seal_rows=S)
        if pb is not None and st.extents:
            pbe = pb["extents"]
            exts = list(st.extents)
            for i, ext in enumerate(exts):
                kind = pbe[i]["kind"] if i < len(pbe) else "delta"
                fresh = (i < len(pbe)
                         and int(pbe[i]["n"]) == ext.n_rows)
                sealed = (i < len(exts) - 1 or ext.n_rows >= S
                          or (fresh and bool(pbe[i]["sealed"])))
                raw = ext.raw
                if not sealed and raw is None:
                    stored = st.extent_rows(i).astype(np.float32)
                    raw = (stored if ext.scale is None else
                           stored * ext.scale[None, :].astype(np.float32))
                exts[i] = ext._replace(kind=kind, sealed=sealed,
                                       raw=None if sealed else raw)
            st = dataclasses.replace(st, extents=tuple(exts))
        return cls(storage=st, backend=backend, depth=depth,
                   wave_pages=wave_pages)

    def save(self, path: str, *, pruner=None, meta: dict | None = None
             ) -> "object":
        """Persist page-granularly (see ``save_paged_index``)."""
        from repro.core.store import save_paged_index
        return save_paged_index(path, self, pruner=pruner, meta=meta)

    # -- growth --------------------------------------------------------------
    def append_with_ops(self, rows) -> tuple["PagedIndex", list]:
        st, ops = self.storage.append_with_ops(rows)
        return dataclasses.replace(self, storage=st), ops

    def append(self, rows) -> "PagedIndex":
        return self.append_with_ops(rows)[0]

    def promote(self) -> tuple["PagedIndex", int]:
        st, n = self.storage.promote()
        return dataclasses.replace(self, storage=st), n

    def compact_pages(self) -> tuple["PagedIndex", dict]:
        st, stats = self.storage.compact()
        return dataclasses.replace(self, storage=st), stats

    def evict(self, n_pages: int) -> tuple["PagedIndex", int]:
        st, n = self.storage.evict(n_pages)
        return dataclasses.replace(self, storage=st), n

    # -- search --------------------------------------------------------------
    def _runs(self) -> list:
        """Maximal contiguous slot ranges per tier, ascending — device
        runs dispatch straight off the pools, host runs stream waves."""
        pt = self.storage.pt_host
        ns = self.storage.n_slots
        runs = []
        i = 0
        while i < ns:
            dev = pt[i] >= 0
            j = i
            while j < ns and (pt[j] >= 0) == dev:
                j += 1
            runs.append((i, j, bool(dev)))
            i = j
        return runs

    def _device_args(self):
        st = self.storage
        return (st.pool, st.tail, st.page_table, st.page_scale,
                st.page_nvalid, st.page_offset)

    def _search_qf(self, qf: jax.Array, k: int):
        runs = self._runs()
        B = qf.shape[0]
        if not runs:
            return (jnp.full((B, k), -jnp.inf, jnp.float32),
                    jnp.full((B, k), -1, jnp.int32))
        out = None
        for idx, (lo, hi, dev) in enumerate(runs):
            last = idx == len(runs) - 1
            if dev:
                out = _paged_topk(*self._device_args(), jnp.int32(lo),
                                  jnp.int32(hi), qf, k=k,
                                  backend=self.backend, depth=self.depth,
                                  guard=self.guard, carry=out, finalize=last)
            else:
                out = self._host_run_topk(qf, k, lo, hi, out, finalize=last)
        return out

    def _stage_wave(self, slots: list):
        """Host pages -> one fixed-shape device wave (pool-of-its-own)."""
        st = self.storage
        W, R, m = self.wave_pages, st.page_rows, st.dim
        buf = np.zeros((W, R, m), st.np_dtype)
        nv = np.zeros(W, np.int32)
        off = np.zeros(W, np.int32)
        sc = (np.zeros((W, m), np.float32) if st.scale_host is not None
              else None)
        for i, s in enumerate(slots):
            buf[i] = st.host_pages[s]
            nv[i] = st.nvalid_host[s]
            off[i] = st.offset_host[s]
            if sc is not None:
                sc[i] = st.scale_host[s]
        pt = np.full(W, -1, np.int32)
        pt[:len(slots)] = np.arange(len(slots), dtype=np.int32)
        return (jnp.asarray(buf), jnp.asarray(pt),
                None if sc is None else jnp.asarray(sc), jnp.asarray(nv),
                jnp.asarray(off), len(slots))

    def _waves(self, lo: int, hi: int) -> list:
        slots = list(range(lo, hi))
        W = self.wave_pages
        return [slots[i:i + W] for i in range(0, len(slots), W)]

    def _host_run_topk(self, qf, k, lo, hi, carry, *, finalize):
        """Stream a host run in waves; ``depth-1`` waves stage ahead of
        the one being scored, so host->device transfer overlaps compute
        (async dispatch) just as page DMA overlaps inside the kernel."""
        waves = self._waves(lo, hi)
        staged: deque = deque()
        nxt = 0
        out = carry
        for wi, _ in enumerate(waves):
            while nxt < len(waves) and nxt <= wi + max(self.depth - 1, 0):
                staged.append(self._stage_wave(waves[nxt]))
                nxt += 1
            buf, pt, sc, nv, off, cnt = staged.popleft()
            out = _paged_topk(buf, None, pt, sc, nv, off, jnp.int32(0),
                              jnp.int32(cnt), qf, k=k, backend=self.backend,
                              depth=self.depth, guard=self.guard, carry=out,
                              finalize=finalize and wi == len(waves) - 1)
        return out

    def search(self, queries: jax.Array, k: int = 10
               ) -> tuple[jax.Array, jax.Array]:
        q = jnp.atleast_2d(queries).astype(jnp.float32)
        k = min(k, max(self.n, 1))
        return self._search_qf(q, k)

    def search_projected(self, queries: jax.Array, components: jax.Array,
                         k: int = 10, *, mean: jax.Array | None = None
                         ) -> tuple[jax.Array, jax.Array]:
        """Raw-query search. Fully device-resident index: ONE dispatch
        (projection + page walk fused). Oversubscribed: one shared
        projection dispatch, then device/host runs chained by carry."""
        k = min(k, max(self.n, 1))
        runs = self._runs()
        if len(runs) == 1 and runs[0][2]:
            lo, hi, _ = runs[0]
            return _paged_search_projected(
                *self._device_args(), jnp.int32(lo), jnp.int32(hi),
                jnp.asarray(components), mean, jnp.atleast_2d(queries),
                k=k, backend=self.backend, depth=self.depth,
                guard=self.guard)
        q = _project_nofold(jnp.atleast_2d(queries),
                            jnp.asarray(components), mean)
        return self._search_qf(q, k)

    # -- cascade rescore -----------------------------------------------------
    def rescore(self, qf: jax.Array, uids: jax.Array) -> jax.Array:
        """(B, U) exact shortlist scores (cascade second stage): device
        runs rescore off the pools, host runs stream waves; max-combined
        per page — bitwise the segmented parts-combine at equal bytes."""
        acc = jnp.full((qf.shape[0], uids.shape[0]), -jnp.inf, jnp.float32)
        for lo, hi, dev in self._runs():
            if dev:
                acc = _paged_rescore(*self._device_args(), jnp.int32(lo),
                                     jnp.int32(hi), qf, uids, acc)
            else:
                for slots in self._waves(lo, hi):
                    buf, pt, sc, nv, off, cnt = self._stage_wave(slots)
                    acc = _paged_rescore(buf, None, pt, sc, nv, off,
                                         jnp.int32(0), jnp.int32(cnt), qf,
                                         uids, acc)
        return acc
