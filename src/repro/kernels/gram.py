"""Pallas TPU kernel: blocked Gram matrix D^T D accumulation.

The offline hot loop of PCA fitting. TPU adaptation (vs a GPU cuBLAS syrk):
a ``(block_n, d)`` strip of ``D`` streams HBM→VMEM once per grid step and is
contracted on the MXU; the ``(d, d)`` fp32 accumulator stays VMEM-resident
across the whole grid (d ≤ 1024 for every bi-encoder we target ⇒ ≤ 4 MiB,
well inside v5e's ~128 MiB VMEM). Arithmetic intensity per strip is
``2·block_n·d² / (block_n·d·bytes)`` = ``2d/bytes`` — with d = 768 and bf16
input that is ~768 FLOP/byte, far above the v5e ridge (~240), i.e. the
kernel is compute-bound and MXU-saturating by construction.

Grid: 1-D over row strips. Accumulation pattern: the output BlockSpec maps
every grid step to the same (d, d) block; the accumulator is zeroed at step
0 and revisited thereafter (standard Pallas reduction idiom).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_kernel(d_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    blk = d_ref[...]
    acc_ref[...] += jax.lax.dot_general(
        blk, blk,
        dimension_numbers=(((0,), (0,)), ((), ())),   # contract rows: blk^T @ blk
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def gram_pallas(D: jax.Array, *, block_rows: int = 1024,
                interpret: bool = True) -> jax.Array:
    """``D^T D`` in fp32 via the blocked Pallas kernel.

    ``D``: (n, d), any float dtype. Rows are zero-padded to a multiple of
    ``block_rows`` (zero rows contribute nothing to the Gram).
    """
    n, d = D.shape
    block_rows = min(block_rows, max(8, n))
    nblocks = -(-n // block_rows)
    pad = nblocks * block_rows - n
    if pad:
        D = jnp.pad(D, ((0, pad), (0, 0)))

    return pl.pallas_call(
        _gram_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((d, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d, d), jnp.float32),
        interpret=interpret,
    )(D)
