"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the container is CPU-only; TPU is
the compile target). On a real TPU backend the wrappers run the compiled
Mosaic kernels.
"""
from __future__ import annotations

import jax

from repro.kernels.gram import gram_pallas
from repro.kernels.pca_project import pca_project_pallas, pca_project_quant_pallas
from repro.kernels.topk_score import topk_score_paged_pallas, topk_score_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def gram(D: jax.Array, *, block_rows: int = 1024,
         interpret: bool | None = None) -> jax.Array:
    """Blocked ``D^T D`` (fp32)."""
    if interpret is None:
        interpret = _interpret_default()
    return gram_pallas(D, block_rows=block_rows, interpret=interpret)


def topk_score(D: jax.Array, Q: jax.Array, *, k: int, block_n: int = 1024,
               block_b: int = 128, n_valid: int | None = None,
               interpret: bool | None = None,
               row_ids: jax.Array | None = None, guard: str = "row"
               ) -> tuple[jax.Array, jax.Array]:
    """Fused score + top-k over a document index shard.

    The index streams in its storage dtype (int8 stays int8 — the dequant
    scale must be folded into ``Q``); ``block_b`` tiles the query batch;
    ``n_valid`` masks trailing padding rows out of the results.
    ``row_ids`` switches to shortlist-rescore mode: each row reports its
    gathered true doc id (any order; negative sentinels masked out).
    ``guard`` selects the per-row (default) vs batch-global block-skip.
    """
    if interpret is None:
        interpret = _interpret_default()
    return topk_score_pallas(D, Q, k=k, block_n=block_n, block_b=block_b,
                             n_valid=n_valid, interpret=interpret,
                             row_ids=row_ids, guard=guard)


def topk_score_paged(pool: jax.Array, page_table: jax.Array,
                     page_nvalid: jax.Array, page_offset: jax.Array,
                     lo, hi, Q: jax.Array, *, k: int,
                     tail: jax.Array | None = None,
                     page_scale: jax.Array | None = None,
                     ids_pool: jax.Array | None = None,
                     carry: tuple[jax.Array, jax.Array] | None = None,
                     depth: int = 2, block_b: int = 128, guard: str = "row",
                     finalize: bool = True, interpret: bool | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Fused score + top-k over a paged index (DMA-pipelined page walk).

    Pages stream from the two-tier pool (stable ``pool`` + append ``tail``)
    in their storage dtype through ``depth`` double-buffered async copies;
    the slot bounds ``[lo, hi)`` are traced scalars, so appends /
    promotions / compactions / evictions (all page-pointer swaps) never
    recompile. ``page_scale`` folds per-page int8 dequant scales into the
    query; ``ids_pool`` enables the rescore mode; ``carry`` /
    ``finalize=False`` chain runs and host-tier waves for indexes larger
    than the device pools.
    """
    if interpret is None:
        interpret = _interpret_default()
    return topk_score_paged_pallas(pool, page_table, page_nvalid, page_offset,
                                   lo, hi, Q, k=k, tail=tail,
                                   page_scale=page_scale,
                                   ids_pool=ids_pool, carry=carry, depth=depth,
                                   block_b=block_b, guard=guard,
                                   finalize=finalize, interpret=interpret)


def pca_project(D: jax.Array, W: jax.Array, *, block_rows: int = 1024,
                interpret: bool | None = None) -> jax.Array:
    """Blocked ``D @ W_m`` index build."""
    if interpret is None:
        interpret = _interpret_default()
    return pca_project_pallas(D, W, block_rows=block_rows, interpret=interpret)


def pca_project_quant(D: jax.Array, W: jax.Array, scale: jax.Array, *,
                      block_rows: int = 1024, interpret: bool | None = None
                      ) -> jax.Array:
    """Blocked ``D @ W_m`` with fused int8 quantisation epilogue."""
    if interpret is None:
        interpret = _interpret_default()
    return pca_project_quant_pallas(D, W, scale, block_rows=block_rows,
                                    interpret=interpret)
