"""Pallas TPU kernel: fused dense-retrieval scan — score + running top-k.

The paper's query-time hot path is ``s = D̂ q̂`` followed by top-k selection.
A naive implementation materialises the n-length score vector in HBM (write
n·4 bytes, re-read for selection). FAISS-GPU fuses selection into the scan
using warp-shuffle k-heaps — a mechanism with no TPU analogue. TPU-native
adaptation:

  * the (B, m) query block stays VMEM-resident; (block_n, m) strips of the
    index stream HBM→VMEM and hit the MXU: ``S_blk = Q · D_blkᵀ``;
  * a running top-k candidate list (scores + global ids) lives in VMEM
    scratch across grid steps;
  * selection uses an **iterative max-extract** (k unrolled passes of
    max / tie-break-by-min-id / mask), which lowers to pure VPU
    max-reductions — no sort network, no warp primitives needed;
  * a **block-skip guard** (FAISS's "thermometer" trick, TPU-flavoured):
    if a strip's max score does not beat the current k-th best, the merge
    is skipped entirely under ``pl.when`` — for well-shuffled indexes the
    merge runs O(few) times instead of O(n/block_n).

HBM traffic ≈ bytes(D̂) streamed exactly once ⇒ the kernel is memory-bound
at the index-read roofline, which is the paper's O(mn) term made optimal:
pruning d→m cuts exactly the streamed bytes.

Outputs are sorted descending; ties break toward the smaller doc id
(matching ``jax.lax.top_k`` first-occurrence semantics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = float("-inf")


def _extract_topk(scores: jax.Array, ids: jax.Array, k: int
                  ) -> tuple[jax.Array, jax.Array]:
    """Top-k by k unrolled max-extract passes. scores/ids: (B, C)."""
    B = scores.shape[0]
    out_s, out_i = [], []
    s = scores
    for _ in range(k):
        m = jnp.max(s, axis=-1)                                   # (B,)
        tie = s >= m[:, None]                                     # max positions
        big = jnp.iinfo(jnp.int32).max
        sel = jnp.min(jnp.where(tie, ids, big), axis=-1)          # min id among ties
        out_s.append(m)
        out_i.append(sel)
        s = jnp.where(ids == sel[:, None], _NEG, s)
    return jnp.stack(out_s, axis=-1), jnp.stack(out_i, axis=-1)   # (B, k)


def _make_kernel(k: int, n_valid: int, block_n: int, nblocks: int):
    def kernel(q_ref, d_ref, out_s_ref, out_i_ref, run_s_ref, run_i_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            run_s_ref[...] = jnp.full_like(run_s_ref, _NEG)
            # unique negative ids so id-keyed masking never collides
            B = run_i_ref.shape[0]
            neg = -(jax.lax.broadcasted_iota(jnp.int32, (B, k), 1) + 1)
            run_i_ref[...] = neg

        q = q_ref[...]
        blk = d_ref[...]
        s = jax.lax.dot_general(
            q, blk, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)                   # (B, block_n)
        gids = i * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(gids < n_valid, s, _NEG)

        # Block-skip guard: merge only if this strip can improve the top-k.
        blk_max = jnp.max(s)
        kth_best = jnp.min(run_s_ref[...])

        @pl.when(blk_max > kth_best)
        def _merge():
            bs, bi = _extract_topk(s, gids, k)
            cs = jnp.concatenate([run_s_ref[...], bs], axis=-1)   # (B, 2k)
            ci = jnp.concatenate([run_i_ref[...], bi], axis=-1)
            ms, mi = _extract_topk(cs, ci, k)
            run_s_ref[...] = ms
            run_i_ref[...] = mi

        @pl.when(i == nblocks - 1)
        def _finish():
            out_s_ref[...] = run_s_ref[...]
            out_i_ref[...] = jnp.maximum(run_i_ref[...], -1)      # pad ids -> -1

    return kernel


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def topk_score_pallas(D: jax.Array, Q: jax.Array, *, k: int,
                      block_n: int = 1024, interpret: bool = True
                      ) -> tuple[jax.Array, jax.Array]:
    """Fused exact search: top-k of ``Q @ D^T`` per query row.

    D: (n, m) index (f32/bf16/int8 — int8 scale must be pre-folded into Q).
    Q: (B, m) queries. Returns (scores (B, k) f32, ids (B, k) int32).
    """
    n, m = D.shape
    B = Q.shape[0]
    block_n = min(block_n, max(8, n))
    nblocks = -(-n // block_n)
    pad = nblocks * block_n - n
    if pad:
        D = jnp.pad(D, ((0, pad), (0, 0)))
    Qf = Q.astype(jnp.float32)
    Df = D.astype(jnp.float32) if D.dtype == jnp.int8 else D

    kernel = _make_kernel(k, n, block_n, nblocks)
    out_s, out_i = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((B, m), lambda i: (0, 0)),          # Q resident
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),    # D strip streams
        ],
        out_specs=[
            pl.BlockSpec((B, k), lambda i: (0, 0)),
            pl.BlockSpec((B, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, k), jnp.float32),
            jax.ShapeDtypeStruct((B, k), jnp.int32),
        ],
        scratch_shapes=[
            _scratch((B, k), jnp.float32),
            _scratch((B, k), jnp.int32),
        ],
        interpret=interpret,
    )(Qf, Df)
    return out_s, out_i


def _scratch(shape, dtype):
    """VMEM scratch allocation (TPU memory space; plain SMEM-free buffer)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
