"""Pallas TPU kernel: fused dense-retrieval scan — score + running top-k.

The paper's query-time hot path is ``s = D̂ q̂`` followed by top-k selection.
A naive implementation materialises the n-length score vector in HBM (write
n·4 bytes, re-read for selection). FAISS-GPU fuses selection into the scan
using warp-shuffle k-heaps — a mechanism with no TPU analogue. TPU-native
adaptation:

  * the index streams HBM→VMEM in **its storage dtype** (f32/bf16/int8) —
    int8 is dequantised in-register (the per-dim scale is folded into the
    query before the kernel), so a pruned+quantised index really moves
    n·m·1 bytes, not a 4x-inflated fp32 shadow copy;
  * a (block_b, m) query tile stays VMEM-resident while (block_n, m) strips
    of the index hit the MXU: ``S_blk = Q · D_blkᵀ``. The grid is
    (batch tiles, index strips) with strips minor, so arbitrarily large B
    works — each batch tile re-streams the index once;
  * a running top-k candidate list (scores + global ids) lives in VMEM
    scratch across grid steps;
  * selection is a **two-stage select**: the strip is partial-reduced by a
    lane fold — (block_b, block_n) reshaped to (block_b, R, W) and maxed
    over the R sub-strips — into a W-wide candidate buffer (W ≈ 2k), which
    is then merged with the running top-k by k unrolled max-extract passes.
    Per pass, only the masking of the extracted id and the lane-fold repair
    touch the full strip, and those are element-wise / sublane reductions;
    every cross-lane (last-axis) reduction is W+k wide instead of block_n
    wide. The merge with the running list is fused into the same k passes
    (no separate 2k extraction stage);
  * a **block-skip guard** (FAISS's "thermometer" trick, TPU-flavoured):
    if a strip cannot improve the running top-k, the merge is skipped
    entirely under ``pl.when`` — for well-shuffled indexes the merge runs
    O(few) times instead of O(n/block_n). The guard is **per-row** by
    default (``guard="row"``): row b improves iff ``max(s[b]) >
    min(run_s[b])``, the strip is skipped iff *no* row improves, and the
    merge writes back only the improving rows (masked merge) — a mixed
    batch where one hot query keeps finding candidates no longer drags
    every other query's merge along. ``guard="batch"`` restores the
    batch-global compare (``max(s) > min(run_s)``) for A/B measurement;
    both produce bit-identical results (for a non-improving row the merge
    is a no-op by construction, since its strict guard plus ascending-id
    tie-breaks would preserve the running list anyway). In plain mode the
    skip fires on equality too, which is exact because strips are visited
    in ascending id order (a later tied score loses the min-id tie-break
    anyway); rescore mode merges on equality — see below.

HBM traffic ≈ bytes(D̂) streamed exactly once per batch tile ⇒ the kernel
is memory-bound at the index-read roofline, which is the paper's O(mn)
term made optimal: pruning d→m (and int8) cuts exactly the streamed bytes.

Outputs are sorted descending; ties break toward the smaller doc id
(matching ``jax.lax.top_k`` first-occurrence semantics).

**Shortlist rescore mode** (``row_ids``): the cascade's second stage scans
a *gathered* shortlist — rows plucked from the full-resolution index — so
row position no longer equals doc id. ``row_ids`` streams a (1, U) int32
id row alongside the strips: the kernel scores position ``j`` but reports
``row_ids[j]``, and masks ``row_ids[j] < 0`` (dedup/pad sentinels) to
-inf instead of the ``n_valid`` iota mask. The min-id-among-ties extract
makes the result independent of gather order, and the block-skip guard
merges (rather than skips) on score equality in this mode, so exactness
holds for *arbitrary* ``row_ids`` order: a tied candidate in a later
strip may carry a smaller id and must get its shot at the tie-break.
(The cascade's ``_shortlist`` still emits ascending ids, which maximises
how often the strict-improvement skip fires; correctness no longer
depends on it.)

**Paged mode** (``topk_score_paged_pallas``): the index lives in a fixed
page pool ``(pool_pages, page_rows, m)`` addressed through an int32 page
table — the layout `PagedIndexStorage` maintains so appends, promotions,
compaction and eviction are pointer swaps. The kernel walks the table's
live slots with a **multi-buffered DMA pipeline**: ``depth`` VMEM page
buffers + DMA semaphores, ``make_async_copy`` of page ``i+depth-1``
started before page ``i`` is scored, so the HBM (or host-tier) stream
overlaps the MXU. The pool stays in its storage dtype end-to-end (int8
pages dequantise in-register); each page's per-page dequant scale row is
DMA'd alongside and folded into the *query* (``q * scale``, the same
fold order as the segmented path, so scores are bit-identical). Dead
table slots (``slot >= n_slots``) are masked, never DMA'd. The page
count is a *traced* scalar — growing or shrinking the index never
recompiles. A ``(table_cap, page_rows)`` ``ids_pool`` switches to the
rescore mode (report gathered ids, mask negatives, merge-on-equality
guard), and an optional ``carry`` seeds the running top-k so an
oversubscribed index can stream through a small pool in waves.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = float("-inf")
_BIG = jnp.iinfo(jnp.int32).max


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


class TopKGeometry(NamedTuple):
    """Grid/padding/fold geometry of one ``topk_score_pallas`` dispatch.

    Single source of truth shared by the kernel wrapper below and the
    static VMEM/grid budget checker (``repro.analysis.pallas_budget``) —
    the checker must reject exactly the configs the kernel would launch,
    so both derive every derived quantity from here.
    """

    n: int            # corpus rows (pre-padding)
    m: int            # index width
    B: int            # query batch (pre-padding)
    k: int
    block_n: int      # index strip rows (clamped)
    block_b: int      # query tile rows (clamped)
    nblocks: int      # index strips in the grid
    pad_rows: int     # corpus padding rows appended
    b_pad: int        # padded batch
    nbt: int          # batch tiles in the grid
    fold_w: int       # stage-1 candidate-lane width (~2k, lane-aligned)
    fold_r: int       # sub-strips folded per lane
    pad_w: int        # strip padding for the (fold_r, fold_w) reshape

    @property
    def grid(self) -> tuple[int, int]:
        return (self.nbt, self.nblocks)


def topk_geometry(n: int, m: int, B: int, k: int, *, block_n: int = 1024,
                  block_b: int = 128) -> TopKGeometry:
    """Clamp/derive the full dispatch geometry for a (n, m) × (B,) call."""
    block_n = min(block_n, max(8, n))
    nblocks = -(-n // block_n)
    pad_rows = nblocks * block_n - n
    block_b = max(1, min(block_b, _round_up(B, 8)))
    b_pad = _round_up(B, block_b)
    nbt = b_pad // block_b
    # two-stage select geometry: W-wide candidate lanes (~2k, lane-aligned),
    # R sub-strips folded per lane
    fold_w = min(block_n, _round_up(2 * k, 128))
    fold_r = -(-block_n // fold_w)
    pad_w = fold_r * fold_w - block_n
    return TopKGeometry(n=n, m=m, B=B, k=k, block_n=block_n, block_b=block_b,
                        nblocks=nblocks, pad_rows=pad_rows, b_pad=b_pad,
                        nbt=nbt, fold_w=fold_w, fold_r=fold_r, pad_w=pad_w)


def _select_merge(s, gids, rs, ri, k: int, fold_w: int, fold_r: int,
                  pad_w: int):
    """Two-stage select over (running list ∪ strip), as plain values.

    ``s``/``gids``: (bb, strip) scores and global ids; ``rs``/``ri``:
    (bb, k) running list. Returns the merged (bb, k) list, sorted
    descending, ties toward the smaller id. Shared by the flat and paged
    kernels so their tie-break semantics cannot drift apart.
    """
    bb = s.shape[0]
    if pad_w:
        s = jnp.concatenate(
            [s, jnp.full((bb, pad_w), _NEG, jnp.float32)], axis=-1)
        gids = jnp.concatenate(
            [gids, jnp.full((bb, pad_w), _BIG, jnp.int32)], axis=-1)
    fs = s.reshape(bb, fold_r, fold_w)
    fi = gids.reshape(bb, fold_r, fold_w)
    out_s, out_i = [], []
    for _ in range(k):
        # stage 1 — partial reduce: lane fold over the R sub-strips
        # (sublane-axis max; min id among in-lane ties)
        lane_s = jnp.max(fs, axis=1)                     # (bb, W)
        lane_i = jnp.min(
            jnp.where(fs >= lane_s[:, None, :], fi, _BIG), axis=1)
        # stage 2 — merge: extract the global max of the (bb, k+W)
        # candidate buffer = running list ∪ lane maxes. Each lane
        # max is the max of its unextracted elements, so the buffer
        # max is the true max of (running ∪ strip remainder).
        cs = jnp.concatenate([rs, lane_s], axis=-1)
        ci = jnp.concatenate([ri, lane_i], axis=-1)
        m = jnp.max(cs, axis=-1)                         # (bb,)
        sel = jnp.min(
            jnp.where(cs >= m[:, None], ci, _BIG), axis=-1)
        out_s.append(m)
        out_i.append(sel)
        # id-keyed removal (element-wise); next pass's lane fold
        # repairs the affected lane's max
        fs = jnp.where(fi == sel[:, None, None], _NEG, fs)
        rs = jnp.where(ri == sel[:, None], _NEG, rs)
    return jnp.stack(out_s, axis=-1), jnp.stack(out_i, axis=-1)


def _guard_and_merge(s, gids, run_s_ref, run_i_ref, k: int, fold_w: int,
                     fold_r: int, pad_w: int, *, guard: str,
                     merge_on_eq: bool):
    """Block-skip guard + (masked) merge into the running-list refs.

    ``guard="row"``: row b improves iff its strip max beats its own k-th
    best; skip the whole strip iff no row improves (a strictly weaker skip
    condition than the batch-global compare, so it never merges less) and
    write back only improving rows. ``guard="batch"``: the legacy
    batch-global compare. ``merge_on_eq`` selects >= (rescore mode —
    arbitrary id order means a later tie may win the min-id tie-break)
    vs > (ascending-id strips, where a later tie always loses).
    """
    rs0 = run_s_ref[...]
    ri0 = run_i_ref[...]
    row_max = jnp.max(s, axis=-1)                        # (bb,)
    row_kth = jnp.min(rs0, axis=-1)                      # (bb,)
    imp = row_max >= row_kth if merge_on_eq else row_max > row_kth
    if guard == "row":
        can_improve = jnp.any(imp)
    else:
        blk_max = jnp.max(s)
        kth_best = jnp.min(rs0)
        can_improve = blk_max >= kth_best if merge_on_eq else blk_max > kth_best

    @pl.when(can_improve)
    def _merge():
        new_s, new_i = _select_merge(s, gids, rs0, ri0, k, fold_w, fold_r,
                                     pad_w)
        if guard == "row":
            run_s_ref[...] = jnp.where(imp[:, None], new_s, rs0)
            run_i_ref[...] = jnp.where(imp[:, None], new_i, ri0)
        else:
            run_s_ref[...] = new_s
            run_i_ref[...] = new_i


def _make_kernel(k: int, n_valid: int, block_n: int, nblocks: int,
                 fold_w: int, fold_r: int, with_ids: bool = False,
                 guard: str = "row"):
    pad_w = fold_r * fold_w - block_n

    def kernel(q_ref, d_ref, *refs):
        if with_ids:
            ids_ref, out_s_ref, out_i_ref, run_s_ref, run_i_ref = refs
        else:
            out_s_ref, out_i_ref, run_s_ref, run_i_ref = refs
        i = pl.program_id(1)   # index strip (minor); program_id(0) = batch tile

        @pl.when(i == 0)
        def _init():
            run_s_ref[...] = jnp.full_like(run_s_ref, _NEG)
            # unique negative ids so id-keyed masking never collides (more
            # negative than the -1 shortlist sentinels, which DO collide —
            # but only among themselves, at -inf, where it cannot matter)
            bb = run_i_ref.shape[0]
            neg = -(jax.lax.broadcasted_iota(jnp.int32, (bb, k), 1) + 2)
            run_i_ref[...] = neg

        q = q_ref[...]
        blk = d_ref[...].astype(jnp.float32)      # dequant/upcast in-register
        s = jax.lax.dot_general(
            q, blk, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bb, block_n)
        if with_ids:
            # rescore mode: report the gathered rows' true doc ids; negative
            # ids mark dedup/pad slots and never surface
            gids = jnp.broadcast_to(ids_ref[...], s.shape)
            s = jnp.where(gids >= 0, s, _NEG)
        else:
            gids = i * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape,
                                                          1)
            s = jnp.where(gids < n_valid, s, _NEG)

        # Block-skip guard + masked merge (see _guard_and_merge). Plain mode
        # skips on equality: strips are visited in ascending id order (iota
        # ids), so a later tied score loses the min-id tie-break anyway.
        # Rescore mode must MERGE on equality: row_ids carry arbitrary
        # gathered order, so a tied candidate in a later strip may hold a
        # smaller id and win the tie-break.
        _guard_and_merge(s, gids, run_s_ref, run_i_ref, k, fold_w, fold_r,
                         pad_w, guard=guard, merge_on_eq=with_ids)

        @pl.when(i == nblocks - 1)
        def _finish():
            out_s_ref[...] = run_s_ref[...]
            out_i_ref[...] = jnp.maximum(run_i_ref[...], -1)  # pad ids -> -1

    return kernel


@functools.partial(jax.jit, static_argnames=("k", "block_n", "block_b",
                                             "n_valid", "interpret", "guard"))
def topk_score_pallas(D: jax.Array, Q: jax.Array, *, k: int,
                      block_n: int = 1024, block_b: int = 128,
                      n_valid: int | None = None, interpret: bool = True,
                      row_ids: jax.Array | None = None, guard: str = "row"
                      ) -> tuple[jax.Array, jax.Array]:
    """Fused exact search: top-k of ``Q @ D^T`` per query row.

    D: (n, m) index, streamed in its own dtype (f32/bf16/int8 — int8 scale
       must be pre-folded into Q; the strip is dequantised in-register).
    Q: (B, m) queries. B is tiled into ``block_b``-row grid steps, so B may
       exceed what fits VMEM-resident alongside an index strip.
    ``n_valid``: logical row count; rows with id >= n_valid (e.g. device
       padding in a sharded index) never surface in results.
    ``row_ids``: optional (n,) int32 true doc id per row — rescore mode for
       a gathered shortlist, in any order. Rows with a negative id
       (dedup/pad sentinels) are masked out and ``n_valid`` is ignored.
    ``guard``: "row" (default) per-row block-skip guard with masked merges;
       "batch" the legacy batch-global compare. Bit-identical results.
    Returns (scores (B, k) f32 sorted desc, ids (B, k) int32; -1 pads).
    """
    n, m = D.shape
    B = Q.shape[0]
    nv = n if n_valid is None else min(n_valid, n)
    g = topk_geometry(n, m, B, k, block_n=block_n, block_b=block_b)
    if g.pad_rows:
        D = jnp.pad(D, ((0, g.pad_rows), (0, 0)))   # dtype-preserving
    Qf = Q.astype(jnp.float32)
    if g.b_pad != B:
        Qf = jnp.pad(Qf, ((0, g.b_pad - B), (0, 0)))

    kernel = _make_kernel(k, nv, g.block_n, g.nblocks, g.fold_w, g.fold_r,
                          with_ids=row_ids is not None, guard=guard)
    in_specs = [
        pl.BlockSpec((g.block_b, m), lambda b, i: (b, 0)),  # Q resident
        pl.BlockSpec((g.block_n, m), lambda b, i: (i, 0)),  # D streams
    ]
    operands = [Qf, D]
    if row_ids is not None:
        ids = row_ids.astype(jnp.int32).reshape(1, n)
        if g.pad_rows:
            ids = jnp.pad(ids, ((0, 0), (0, g.pad_rows)),
                          constant_values=-1)
        in_specs.append(
            pl.BlockSpec((1, g.block_n), lambda b, i: (0, i)))  # ids stream
        operands.append(ids)
    out_s, out_i = pl.pallas_call(
        kernel,
        grid=g.grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((g.block_b, k), lambda b, i: (b, 0)),
            pl.BlockSpec((g.block_b, k), lambda b, i: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g.b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((g.b_pad, k), jnp.int32),
        ],
        scratch_shapes=[
            _scratch((g.block_b, k), jnp.float32),
            _scratch((g.block_b, k), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return out_s[:B], out_i[:B]


def _scratch(shape, dtype):
    """VMEM scratch allocation (TPU memory space; plain SMEM-free buffer)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


class PagedTopKGeometry(NamedTuple):
    """Grid/fold/buffer geometry of one ``topk_score_paged_pallas`` dispatch.

    Single source of truth shared with ``repro.analysis.pallas_budget``:
    the budget checker prices exactly the buffers this geometry allocates
    (``depth`` DMA page buffers count ``depth`` times in VMEM residency;
    the page table's bytes join the HBM read estimate).
    """

    table_cap: int    # page-table capacity (live slots are traced, <= cap)
    pool_pages: int   # physical page-pool slots
    page_rows: int    # rows per page (R)
    m: int            # index width
    B: int            # query batch (pre-padding)
    k: int
    depth: int        # DMA pipeline depth (page buffers in flight)
    block_b: int      # query tile rows
    b_pad: int
    nbt: int          # batch tiles in the grid
    fold_w: int       # stage-1 candidate-lane width
    fold_r: int       # sub-strips folded per lane
    pad_w: int

    @property
    def grid(self) -> tuple[int]:
        return (self.nbt,)


def paged_topk_geometry(table_cap: int, pool_pages: int, page_rows: int,
                        m: int, B: int, k: int, *, depth: int = 2,
                        block_b: int = 128) -> PagedTopKGeometry:
    block_b = max(1, min(block_b, _round_up(B, 8)))
    b_pad = _round_up(B, block_b)
    nbt = b_pad // block_b
    fold_w = min(page_rows, _round_up(2 * k, 128))
    fold_r = -(-page_rows // fold_w)
    pad_w = fold_r * fold_w - page_rows
    return PagedTopKGeometry(table_cap=table_cap, pool_pages=pool_pages,
                             page_rows=page_rows, m=m, B=B, k=k, depth=depth,
                             block_b=block_b, b_pad=b_pad, nbt=nbt,
                             fold_w=fold_w, fold_r=fold_r, pad_w=pad_w)


def _make_paged_kernel(k: int, table_cap: int, page_rows: int,
                       pool_pages: int, depth: int, fold_w: int, fold_r: int,
                       pad_w: int, *, guard: str, with_tail: bool,
                       with_scale: bool, with_ids: bool, with_carry: bool,
                       finalize: bool):
    from jax.experimental.pallas import tpu as pltpu

    # prefetch distance: page i+dist is started while page i is scored, so
    # depth buffers hold the in-flight window. depth=1 is the serial
    # baseline (start, wait, compute — no overlap).
    dist = depth - 1

    def kernel(*refs):
        bounds_ref, pt_ref, nv_ref, off_ref, q_ref = refs[:5]
        pos = 5
        if with_carry:
            cs_ref, ci_ref = refs[pos:pos + 2]
            pos += 2
        pool_ref = refs[pos]
        pos += 1
        if with_tail:
            tail_ref = refs[pos]
            pos += 1
        if with_scale:
            scale_ref = refs[pos]
            pos += 1
        if with_ids:
            idsp_ref = refs[pos]
            pos += 1
        out_s_ref, out_i_ref, run_s_ref, run_i_ref = refs[pos:pos + 4]

        lo = bounds_ref[0]
        hi = bounds_ref[1]
        bb = q_ref.shape[0]
        if with_carry:
            # wave mode: seed from the previous wave's (un-clamped) list so
            # the unique-negative pad ids survive across waves
            run_s_ref[...] = cs_ref[...]
            run_i_ref[...] = ci_ref[...]
        else:
            run_s_ref[...] = jnp.full((bb, k), _NEG, jnp.float32)
            run_i_ref[...] = -(
                jax.lax.broadcasted_iota(jnp.int32, (bb, k), 1) + 2)

        def body(pbuf, psem, sbuf=None, ssem=None, ibuf=None, isem=None):
            def page_copy(j, slot):
                """DMA descriptor(s) for logical slot j's page: the page
                table picks the physical tier — [0, pool_pages) = stable
                pool, beyond = append tail. Exactly one branch fires."""
                phys = pt_ref[j]
                if with_tail:
                    def run(op):
                        @pl.when(phys < pool_pages)
                        def _pool():
                            op(pltpu.make_async_copy(
                                pool_ref.at[phys], pbuf.at[slot],
                                psem.at[slot]))

                        @pl.when(phys >= pool_pages)
                        def _tail():
                            op(pltpu.make_async_copy(
                                tail_ref.at[phys - pool_pages], pbuf.at[slot],
                                psem.at[slot]))
                else:
                    def run(op):
                        op(pltpu.make_async_copy(pool_ref.at[phys],
                                                 pbuf.at[slot],
                                                 psem.at[slot]))
                return run

            def start(j):
                slot = j % depth
                page_copy(j, slot)(lambda c: c.start())
                if with_scale:
                    pltpu.make_async_copy(scale_ref.at[pl.ds(j, 1)],
                                          sbuf.at[slot], ssem.at[slot]).start()
                if with_ids:
                    pltpu.make_async_copy(idsp_ref.at[pl.ds(j, 1)],
                                          ibuf.at[slot], isem.at[slot]).start()

            def wait(j):
                slot = j % depth
                page_copy(j, slot)(lambda c: c.wait())
                if with_scale:
                    pltpu.make_async_copy(scale_ref.at[pl.ds(j, 1)],
                                          sbuf.at[slot], ssem.at[slot]).wait()
                if with_ids:
                    pltpu.make_async_copy(idsp_ref.at[pl.ds(j, 1)],
                                          ibuf.at[slot], isem.at[slot]).wait()

            # warm-up: fill the prefetch window (dead slots never DMA)
            for j in range(min(dist, table_cap)):
                @pl.when(lo + j < hi)
                def _warm(j=j):
                    start(lo + j)

            def step(i, carry):
                if dist:
                    @pl.when(i + dist < hi)
                    def _prefetch():
                        start(i + dist)
                else:
                    start(i)
                wait(i)

                slot = i % depth
                page = pbuf[slot].astype(jnp.float32)   # in-register dequant
                q = q_ref[...]
                if with_scale:
                    # per-page dequant scale folds into the QUERY — the same
                    # fold order as the segmented path, so bitwise-equal
                    q = q * sbuf[slot]                  # (bb, m) * (1, m)
                s = jax.lax.dot_general(
                    q, page, dimension_numbers=(((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32)      # (bb, R)
                iota = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
                if with_ids:
                    gids = jnp.broadcast_to(ibuf[slot], s.shape)
                    mask = gids >= 0
                else:
                    gids = off_ref[i] + iota
                    mask = iota < nv_ref[i]
                s = jnp.where(mask, s, _NEG)
                _guard_and_merge(s, gids, run_s_ref, run_i_ref, k, fold_w,
                                 fold_r, pad_w, guard=guard,
                                 merge_on_eq=with_ids)
                return carry

            jax.lax.fori_loop(lo, hi, step, 0)

        m = q_ref.shape[1]
        scoped = dict(pbuf=pltpu.VMEM((depth, page_rows, m), pool_ref.dtype),
                      psem=pltpu.SemaphoreType.DMA((depth,)))
        if with_scale:
            scoped.update(sbuf=pltpu.VMEM((depth, 1, m), jnp.float32),
                          ssem=pltpu.SemaphoreType.DMA((depth,)))
        if with_ids:
            scoped.update(ibuf=pltpu.VMEM((depth, 1, page_rows), jnp.int32),
                          isem=pltpu.SemaphoreType.DMA((depth,)))
        pl.run_scoped(body, **scoped)
        out_s_ref[...] = run_s_ref[...]
        if finalize:
            out_i_ref[...] = jnp.maximum(run_i_ref[...], -1)  # pad ids -> -1
        else:
            out_i_ref[...] = run_i_ref[...]

    return kernel


@functools.partial(jax.jit, static_argnames=("k", "depth", "block_b",
                                             "guard", "finalize", "interpret"))
def topk_score_paged_pallas(pool: jax.Array, page_table: jax.Array,
                            page_nvalid: jax.Array, page_offset: jax.Array,
                            lo: jax.Array, hi: jax.Array, Q: jax.Array, *,
                            k: int, tail: jax.Array | None = None,
                            page_scale: jax.Array | None = None,
                            ids_pool: jax.Array | None = None,
                            carry: tuple[jax.Array, jax.Array] | None = None,
                            depth: int = 2, block_b: int = 128,
                            guard: str = "row", finalize: bool = True,
                            interpret: bool = True
                            ) -> tuple[jax.Array, jax.Array]:
    """Fused exact search over a paged index: top-k of ``Q @ pages^T``.

    pool:        (pool_pages, R, m) stable page pool in its storage dtype;
                 pages stream pool→VMEM through ``depth`` DMA buffers.
    tail:        optional (tail_pages, R, m) append-tier pool; page-table
                 entries ``>= pool_pages`` address ``tail[phys-pool_pages]``.
    page_table:  (table_cap,) int32, logical slot -> physical page slot.
    page_nvalid: (table_cap,) int32 live rows per page (partial pages).
    page_offset: (table_cap,) int32 global id of each page's first row.
    lo, hi:      *traced* scalar slot bounds — the kernel walks logical
                 slots [lo, hi), so index growth/shrink never recompiles
                 and an oversubscribed walk splits into device/host runs.
    page_scale:  optional (table_cap, m) f32 per-page dequant scales,
                 folded into Q per page (int8 pools).
    ids_pool:    optional (table_cap, R) int32 true doc ids per page row —
                 rescore mode (negative = masked sentinel).
    carry:       optional (B, k) scores/ids seeding the running list —
                 chain runs/waves. Pass the *un-clamped* ids of a
                 ``finalize=False`` call back in.
    Returns (scores (B, k) f32 sorted desc, ids (B, k) int32; -1 pads
    once ``finalize``) — identical semantics to ``topk_score_pallas``.
    """
    from jax.experimental.pallas import tpu as pltpu
    pool_pages, R, m = pool.shape
    table_cap = page_table.shape[0]
    B = Q.shape[0]
    g = paged_topk_geometry(table_cap, pool_pages, R, m, B, k, depth=depth,
                            block_b=block_b)
    Qf = Q.astype(jnp.float32)
    if g.b_pad != B:
        Qf = jnp.pad(Qf, ((0, g.b_pad - B), (0, 0)))

    kernel = _make_paged_kernel(
        k, table_cap, R, pool_pages, depth, g.fold_w, g.fold_r, g.pad_w,
        guard=guard, with_tail=tail is not None,
        with_scale=page_scale is not None, with_ids=ids_pool is not None,
        with_carry=carry is not None, finalize=finalize)

    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    anyspace = pl.BlockSpec(memory_space=pltpu.ANY)
    in_specs = [smem, smem, smem, smem,
                pl.BlockSpec((g.block_b, m), lambda b: (b, 0))]
    bounds = jnp.stack([jnp.asarray(lo, jnp.int32).reshape(()),
                        jnp.asarray(hi, jnp.int32).reshape(())])
    operands = [bounds,
                page_table.astype(jnp.int32),
                page_nvalid.astype(jnp.int32),
                page_offset.astype(jnp.int32), Qf]
    if carry is not None:
        cs, ci = carry
        cs = cs.astype(jnp.float32)
        ci = ci.astype(jnp.int32)
        if g.b_pad != B:
            cs = jnp.pad(cs, ((0, g.b_pad - B), (0, 0)),
                         constant_values=_NEG)
            ci = jnp.pad(ci, ((0, g.b_pad - B), (0, 0)), constant_values=-1)
        in_specs += [pl.BlockSpec((g.block_b, k), lambda b: (b, 0)),
                     pl.BlockSpec((g.block_b, k), lambda b: (b, 0))]
        operands += [cs, ci]
    in_specs.append(anyspace)
    operands.append(pool)
    if tail is not None:
        in_specs.append(anyspace)
        operands.append(tail)
    if page_scale is not None:
        in_specs.append(anyspace)
        operands.append(page_scale.astype(jnp.float32))
    if ids_pool is not None:
        in_specs.append(anyspace)
        operands.append(ids_pool.astype(jnp.int32))
    out_s, out_i = pl.pallas_call(
        kernel,
        grid=g.grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((g.block_b, k), lambda b: (b, 0)),
            pl.BlockSpec((g.block_b, k), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g.b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((g.b_pad, k), jnp.int32),
        ],
        scratch_shapes=[
            _scratch((g.block_b, k), jnp.float32),
            _scratch((g.block_b, k), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return out_s[:B], out_i[:B]
