"""Pallas TPU kernel: fused dense-retrieval scan — score + running top-k.

The paper's query-time hot path is ``s = D̂ q̂`` followed by top-k selection.
A naive implementation materialises the n-length score vector in HBM (write
n·4 bytes, re-read for selection). FAISS-GPU fuses selection into the scan
using warp-shuffle k-heaps — a mechanism with no TPU analogue. TPU-native
adaptation:

  * the index streams HBM→VMEM in **its storage dtype** (f32/bf16/int8) —
    int8 is dequantised in-register (the per-dim scale is folded into the
    query before the kernel), so a pruned+quantised index really moves
    n·m·1 bytes, not a 4x-inflated fp32 shadow copy;
  * a (block_b, m) query tile stays VMEM-resident while (block_n, m) strips
    of the index hit the MXU: ``S_blk = Q · D_blkᵀ``. The grid is
    (batch tiles, index strips) with strips minor, so arbitrarily large B
    works — each batch tile re-streams the index once;
  * a running top-k candidate list (scores + global ids) lives in VMEM
    scratch across grid steps;
  * selection is a **two-stage select**: the strip is partial-reduced by a
    lane fold — (block_b, block_n) reshaped to (block_b, R, W) and maxed
    over the R sub-strips — into a W-wide candidate buffer (W ≈ 2k), which
    is then merged with the running top-k by k unrolled max-extract passes.
    Per pass, only the masking of the extracted id and the lane-fold repair
    touch the full strip, and those are element-wise / sublane reductions;
    every cross-lane (last-axis) reduction is W+k wide instead of block_n
    wide. The merge with the running list is fused into the same k passes
    (no separate 2k extraction stage);
  * a **block-skip guard** (FAISS's "thermometer" trick, TPU-flavoured):
    if a strip's max score does not beat the current k-th best, the merge
    is skipped entirely under ``pl.when`` — for well-shuffled indexes the
    merge runs O(few) times instead of O(n/block_n). In plain mode the
    skip fires on equality too, which is exact because strips are visited
    in ascending id order (a later tied score loses the min-id tie-break
    anyway); rescore mode merges on equality — see below.

HBM traffic ≈ bytes(D̂) streamed exactly once per batch tile ⇒ the kernel
is memory-bound at the index-read roofline, which is the paper's O(mn)
term made optimal: pruning d→m (and int8) cuts exactly the streamed bytes.

Outputs are sorted descending; ties break toward the smaller doc id
(matching ``jax.lax.top_k`` first-occurrence semantics).

**Shortlist rescore mode** (``row_ids``): the cascade's second stage scans
a *gathered* shortlist — rows plucked from the full-resolution index — so
row position no longer equals doc id. ``row_ids`` streams a (1, U) int32
id row alongside the strips: the kernel scores position ``j`` but reports
``row_ids[j]``, and masks ``row_ids[j] < 0`` (dedup/pad sentinels) to
-inf instead of the ``n_valid`` iota mask. The min-id-among-ties extract
makes the result independent of gather order, and the block-skip guard
merges (rather than skips) on score equality in this mode, so exactness
holds for *arbitrary* ``row_ids`` order: a tied candidate in a later
strip may carry a smaller id and must get its shot at the tie-break.
(The cascade's ``_shortlist`` still emits ascending ids, which maximises
how often the strict-improvement skip fires; correctness no longer
depends on it.)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = float("-inf")
_BIG = jnp.iinfo(jnp.int32).max


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


class TopKGeometry(NamedTuple):
    """Grid/padding/fold geometry of one ``topk_score_pallas`` dispatch.

    Single source of truth shared by the kernel wrapper below and the
    static VMEM/grid budget checker (``repro.analysis.pallas_budget``) —
    the checker must reject exactly the configs the kernel would launch,
    so both derive every derived quantity from here.
    """

    n: int            # corpus rows (pre-padding)
    m: int            # index width
    B: int            # query batch (pre-padding)
    k: int
    block_n: int      # index strip rows (clamped)
    block_b: int      # query tile rows (clamped)
    nblocks: int      # index strips in the grid
    pad_rows: int     # corpus padding rows appended
    b_pad: int        # padded batch
    nbt: int          # batch tiles in the grid
    fold_w: int       # stage-1 candidate-lane width (~2k, lane-aligned)
    fold_r: int       # sub-strips folded per lane
    pad_w: int        # strip padding for the (fold_r, fold_w) reshape

    @property
    def grid(self) -> tuple[int, int]:
        return (self.nbt, self.nblocks)


def topk_geometry(n: int, m: int, B: int, k: int, *, block_n: int = 1024,
                  block_b: int = 128) -> TopKGeometry:
    """Clamp/derive the full dispatch geometry for a (n, m) × (B,) call."""
    block_n = min(block_n, max(8, n))
    nblocks = -(-n // block_n)
    pad_rows = nblocks * block_n - n
    block_b = max(1, min(block_b, _round_up(B, 8)))
    b_pad = _round_up(B, block_b)
    nbt = b_pad // block_b
    # two-stage select geometry: W-wide candidate lanes (~2k, lane-aligned),
    # R sub-strips folded per lane
    fold_w = min(block_n, _round_up(2 * k, 128))
    fold_r = -(-block_n // fold_w)
    pad_w = fold_r * fold_w - block_n
    return TopKGeometry(n=n, m=m, B=B, k=k, block_n=block_n, block_b=block_b,
                        nblocks=nblocks, pad_rows=pad_rows, b_pad=b_pad,
                        nbt=nbt, fold_w=fold_w, fold_r=fold_r, pad_w=pad_w)


def _make_kernel(k: int, n_valid: int, block_n: int, nblocks: int,
                 fold_w: int, fold_r: int, with_ids: bool = False):
    pad_w = fold_r * fold_w - block_n

    def kernel(q_ref, d_ref, *refs):
        if with_ids:
            ids_ref, out_s_ref, out_i_ref, run_s_ref, run_i_ref = refs
        else:
            out_s_ref, out_i_ref, run_s_ref, run_i_ref = refs
        i = pl.program_id(1)   # index strip (minor); program_id(0) = batch tile

        @pl.when(i == 0)
        def _init():
            run_s_ref[...] = jnp.full_like(run_s_ref, _NEG)
            # unique negative ids so id-keyed masking never collides (more
            # negative than the -1 shortlist sentinels, which DO collide —
            # but only among themselves, at -inf, where it cannot matter)
            bb = run_i_ref.shape[0]
            neg = -(jax.lax.broadcasted_iota(jnp.int32, (bb, k), 1) + 2)
            run_i_ref[...] = neg

        q = q_ref[...]
        blk = d_ref[...].astype(jnp.float32)      # dequant/upcast in-register
        s = jax.lax.dot_general(
            q, blk, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)               # (bb, block_n)
        if with_ids:
            # rescore mode: report the gathered rows' true doc ids; negative
            # ids mark dedup/pad slots and never surface
            gids = jnp.broadcast_to(ids_ref[...], s.shape)
            s = jnp.where(gids >= 0, s, _NEG)
        else:
            gids = i * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape,
                                                          1)
            s = jnp.where(gids < n_valid, s, _NEG)

        # Block-skip guard: merge only if this strip can improve the top-k.
        # Plain mode skips on equality: strips are visited in ascending id
        # order (iota ids), so a later tied score loses the min-id tie-break
        # anyway. Rescore mode must MERGE on equality: row_ids carry
        # arbitrary gathered order, so a tied candidate in a later strip may
        # hold a smaller id and win the tie-break.
        blk_max = jnp.max(s)
        kth_best = jnp.min(run_s_ref[...])
        can_improve = blk_max >= kth_best if with_ids else blk_max > kth_best

        @pl.when(can_improve)
        def _merge():
            bb = s.shape[0]
            if pad_w:
                s_p = jnp.concatenate(
                    [s, jnp.full((bb, pad_w), _NEG, jnp.float32)], axis=-1)
                i_p = jnp.concatenate(
                    [gids, jnp.full((bb, pad_w), _BIG, jnp.int32)], axis=-1)
            else:
                s_p, i_p = s, gids
            fs = s_p.reshape(bb, fold_r, fold_w)
            fi = i_p.reshape(bb, fold_r, fold_w)
            rs = run_s_ref[...]
            ri = run_i_ref[...]
            out_s, out_i = [], []
            for _ in range(k):
                # stage 1 — partial reduce: lane fold over the R sub-strips
                # (sublane-axis max; min id among in-lane ties)
                lane_s = jnp.max(fs, axis=1)                     # (bb, W)
                lane_i = jnp.min(
                    jnp.where(fs >= lane_s[:, None, :], fi, _BIG), axis=1)
                # stage 2 — merge: extract the global max of the (bb, k+W)
                # candidate buffer = running list ∪ lane maxes. Each lane
                # max is the max of its unextracted elements, so the buffer
                # max is the true max of (running ∪ strip remainder).
                cs = jnp.concatenate([rs, lane_s], axis=-1)
                ci = jnp.concatenate([ri, lane_i], axis=-1)
                m = jnp.max(cs, axis=-1)                         # (bb,)
                sel = jnp.min(
                    jnp.where(cs >= m[:, None], ci, _BIG), axis=-1)
                out_s.append(m)
                out_i.append(sel)
                # id-keyed removal (element-wise); next pass's lane fold
                # repairs the affected lane's max
                fs = jnp.where(fi == sel[:, None, None], _NEG, fs)
                rs = jnp.where(ri == sel[:, None], _NEG, rs)
            run_s_ref[...] = jnp.stack(out_s, axis=-1)
            run_i_ref[...] = jnp.stack(out_i, axis=-1)

        @pl.when(i == nblocks - 1)
        def _finish():
            out_s_ref[...] = run_s_ref[...]
            out_i_ref[...] = jnp.maximum(run_i_ref[...], -1)  # pad ids -> -1

    return kernel


@functools.partial(jax.jit, static_argnames=("k", "block_n", "block_b",
                                             "n_valid", "interpret"))
def topk_score_pallas(D: jax.Array, Q: jax.Array, *, k: int,
                      block_n: int = 1024, block_b: int = 128,
                      n_valid: int | None = None, interpret: bool = True,
                      row_ids: jax.Array | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Fused exact search: top-k of ``Q @ D^T`` per query row.

    D: (n, m) index, streamed in its own dtype (f32/bf16/int8 — int8 scale
       must be pre-folded into Q; the strip is dequantised in-register).
    Q: (B, m) queries. B is tiled into ``block_b``-row grid steps, so B may
       exceed what fits VMEM-resident alongside an index strip.
    ``n_valid``: logical row count; rows with id >= n_valid (e.g. device
       padding in a sharded index) never surface in results.
    ``row_ids``: optional (n,) int32 true doc id per row — rescore mode for
       a gathered shortlist, in any order. Rows with a negative id
       (dedup/pad sentinels) are masked out and ``n_valid`` is ignored.
    Returns (scores (B, k) f32 sorted desc, ids (B, k) int32; -1 pads).
    """
    n, m = D.shape
    B = Q.shape[0]
    nv = n if n_valid is None else min(n_valid, n)
    g = topk_geometry(n, m, B, k, block_n=block_n, block_b=block_b)
    if g.pad_rows:
        D = jnp.pad(D, ((0, g.pad_rows), (0, 0)))   # dtype-preserving
    Qf = Q.astype(jnp.float32)
    if g.b_pad != B:
        Qf = jnp.pad(Qf, ((0, g.b_pad - B), (0, 0)))

    kernel = _make_kernel(k, nv, g.block_n, g.nblocks, g.fold_w, g.fold_r,
                          with_ids=row_ids is not None)
    in_specs = [
        pl.BlockSpec((g.block_b, m), lambda b, i: (b, 0)),  # Q resident
        pl.BlockSpec((g.block_n, m), lambda b, i: (i, 0)),  # D streams
    ]
    operands = [Qf, D]
    if row_ids is not None:
        ids = row_ids.astype(jnp.int32).reshape(1, n)
        if g.pad_rows:
            ids = jnp.pad(ids, ((0, 0), (0, g.pad_rows)),
                          constant_values=-1)
        in_specs.append(
            pl.BlockSpec((1, g.block_n), lambda b, i: (0, i)))  # ids stream
        operands.append(ids)
    out_s, out_i = pl.pallas_call(
        kernel,
        grid=g.grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((g.block_b, k), lambda b, i: (b, 0)),
            pl.BlockSpec((g.block_b, k), lambda b, i: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g.b_pad, k), jnp.float32),
            jax.ShapeDtypeStruct((g.b_pad, k), jnp.int32),
        ],
        scratch_shapes=[
            _scratch((g.block_b, k), jnp.float32),
            _scratch((g.block_b, k), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return out_s[:B], out_i[:B]


def _scratch(shape, dtype):
    """VMEM scratch allocation (TPU memory space; plain SMEM-free buffer)."""
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
