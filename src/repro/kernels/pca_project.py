"""Pallas TPU kernel: blocked PCA projection D·W_m with fused quant epilogue.

The offline index-build hot loop: a tall-skinny GEMM ``(n, d) @ (d, m)``
where n is millions-to-billions and d, m ≤ 1024. TPU adaptation:

  * ``W_m`` (d·m ≤ 4 MiB fp32) is VMEM-resident for the whole grid;
  * ``(block_n, d)`` strips of ``D`` stream HBM→VMEM once, hit the MXU, and
    the projected strip goes straight back out — optionally **quantised to
    int8 in-register** (fused epilogue) so the expensive fp32 intermediate
    index never exists in HBM at all. PCA⊕int8 composition writes
    ``m/d × 1/4`` of the baseline index bytes.

Per-dimension scales for the epilogue are supplied by the wrapper (derived
from eigenvalues or a calibration strip) because a per-column max over the
full index would need a second pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def project_geometry(n: int, block_rows: int = 1024) -> tuple[int, int, int]:
    """``(block_rows, nblocks, pad)`` for one projection dispatch — the
    clamp/padding math shared by both wrappers below and the static budget
    checker (``repro.analysis.pallas_budget``)."""
    block_rows = min(block_rows, max(8, n))
    nblocks = -(-n // block_rows)
    pad = nblocks * block_rows - n
    return block_rows, nblocks, pad


def _project_kernel(x_ref, w_ref, out_ref):
    out_ref[...] = jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(out_ref.dtype)


def _project_quant_kernel(x_ref, w_ref, scale_ref, out_ref):
    t = jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    inv = 1.0 / jnp.maximum(scale_ref[...], 1e-12)               # (1, m)
    q = jnp.clip(jnp.round(t * inv), -127.0, 127.0)
    out_ref[...] = q.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def pca_project_pallas(D: jax.Array, W: jax.Array, *, block_rows: int = 1024,
                       interpret: bool = True) -> jax.Array:
    """``D @ W`` (fp32 accumulate), blocked over rows."""
    n, d = D.shape
    d2, m = W.shape
    assert d == d2
    block_rows, nblocks, pad = project_geometry(n, block_rows)
    Dp = jnp.pad(D, ((0, pad), (0, 0))) if pad else D
    out = pl.pallas_call(
        _project_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks * block_rows, m), D.dtype),
        interpret=interpret,
    )(D if not pad else Dp, W)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def pca_project_quant_pallas(D: jax.Array, W: jax.Array, scale: jax.Array, *,
                             block_rows: int = 1024, interpret: bool = True
                             ) -> jax.Array:
    """``int8(round((D @ W) / scale))`` with the quantisation fused in VMEM."""
    n, d = D.shape
    m = W.shape[1]
    block_rows, nblocks, pad = project_geometry(n, block_rows)
    Dp = jnp.pad(D, ((0, pad), (0, 0))) if pad else D
    out = pl.pallas_call(
        _project_quant_kernel,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d, m), lambda i: (0, 0)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblocks * block_rows, m), jnp.int8),
        interpret=interpret,
    )(Dp, W, scale.reshape(1, m).astype(jnp.float32))
    return out[:n]
