"""Pallas TPU kernels for the paper's compute hot-spots.

  * ``gram``               — blocked D^T D accumulation (offline PCA fit)
  * ``topk_score``         — fused score + running top-k index scan (serving)
  * ``pca_project[_quant]``— blocked D·W_m index build (+ int8 epilogue)

Validated against ``ref.py`` oracles in interpret mode (CPU container);
compiled via Mosaic on real TPU backends.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
