"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_ref(D: jax.Array) -> jax.Array:
    Df = D.astype(jnp.float32)
    return Df.T @ Df


def topk_score_ref(D: jax.Array, Q: jax.Array, *, k: int
                   ) -> tuple[jax.Array, jax.Array]:
    s = Q.astype(jnp.float32) @ D.astype(jnp.float32).T      # (B, n)
    scores, ids = jax.lax.top_k(s, k)
    return scores, ids.astype(jnp.int32)


def pca_project_ref(D: jax.Array, W: jax.Array) -> jax.Array:
    return (D.astype(jnp.float32) @ W.astype(jnp.float32)).astype(D.dtype)


def pca_project_quant_ref(D: jax.Array, W: jax.Array, scale: jax.Array) -> jax.Array:
    t = D.astype(jnp.float32) @ W.astype(jnp.float32)
    q = jnp.clip(jnp.round(t / jnp.maximum(scale[None, :], 1e-12)), -127, 127)
    return q.astype(jnp.int8)
