"""Chaos soak: open-loop Poisson drive against a replicated fleet while a
fault plan kills and restarts a replica mid-drive.

    python -m repro.serving.soak --seconds 10 --replicas 3 --rate 120

Asserts the robustness invariants the fleet exists for and exits
non-zero on any violation:

  * zero lost accepted replies (every accepted request got exactly one
    terminal payload — ``Router.stats()['lost_accepted'] == 0``);
  * zero misrouted replies (queries are self-retrieval over a unit-norm
    corpus, so every successful reply's top-1 id is checkable);
  * the fleet is healthy again at the end (the killed replica restarted
    and rejoined, no background maintenance errors);
  * a usable success rate under the fault (the kill window may shed or
    time out, visibly — but the fleet must keep answering).
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile

import numpy as np

from repro.core.pruning import StaticPruner
from repro.core.store import save_index
from repro.launch.serve import _drive_open
from repro.serving.fleet import FaultEvent, FaultPlan, ReplicaSet


def _unit_corpus(n: int, d: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    D = rng.standard_normal((n, d)).astype(np.float32)
    return D / np.linalg.norm(D, axis=1, keepdims=True)


def build_fleet(tmp: str, *, n_docs: int, dim: int, replicas: int,
                max_batch: int = 32, max_outstanding: int = 512,
                replica_timeout: float = 5.0) -> tuple[ReplicaSet, np.ndarray]:
    """Unit-norm corpus -> pruned artifact -> fleet. Query i is corpus
    row i, so top-1 correctness is exactly checkable."""
    import jax.numpy as jnp
    D = _unit_corpus(n_docs, dim)
    pruner = StaticPruner(cutoff=0.5).fit(jnp.asarray(D))
    index = pruner.build_index(jnp.asarray(D))
    save_index(tmp, index, pruner=pruner)
    fleet = ReplicaSet(tmp, replicas=replicas, max_batch=max_batch,
                       max_outstanding=max_outstanding,
                       replica_timeout=replica_timeout,
                       probe_queries=D[:16])
    return fleet, D


def run_soak(*, seconds: float = 10.0, rate: float = 120.0,
             replicas: int = 3, n_docs: int = 4096, dim: int = 64,
             kill_at: float | None = None,
             restart_at: float | None = None, seed: int = 0) -> dict:
    if kill_at is None:
        kill_at = 0.3 * seconds
    if restart_at is None:
        restart_at = 0.6 * seconds
    n = max(32, int(rate * seconds))
    with tempfile.TemporaryDirectory() as tmp:
        fleet, D = build_fleet(tmp + "/store", n_docs=n_docs, dim=dim,
                               replicas=replicas)
        try:
            rng = np.random.default_rng(seed)
            qids = rng.integers(0, n_docs, size=n)
            Q = D[qids]
            plan = FaultPlan([FaultEvent(kill_at, "kill", "r1"),
                              FaultEvent(restart_at, "restart", "r1")])
            plan.start(fleet)
            res = _drive_open(fleet, Q, rate=rate, seed=seed, collect=True,
                              tolerate_errors=True, deadline=2.0)
            stats = fleet.stats()
            health = fleet.health()
        finally:
            fleet.close()
    misrouted = 0
    for i, out in enumerate(res.pop("results")):
        if isinstance(out, tuple):
            _, ids = out
            if int(np.asarray(ids)[0]) != int(qids[i]):
                misrouted += 1
    ok_rate = res["n_ok"] / res["n"]
    violations = []
    if stats["lost_accepted"] != 0:
        violations.append(f"lost_accepted={stats['lost_accepted']}")
    if misrouted:
        violations.append(f"misrouted={misrouted}")
    if not health["ok"]:
        violations.append("fleet unhealthy after restart")
    if ok_rate < 0.5:
        violations.append(f"success rate {ok_rate:.2f} < 0.5")
    return {"drive": res, "stats": stats, "health_ok": health["ok"],
            "misrouted": misrouted, "ok_rate": ok_rate,
            "violations": violations}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seconds", type=float, default=10.0)
    ap.add_argument("--rate", type=float, default=120.0)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--n-docs", type=int, default=4096)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = run_soak(seconds=args.seconds, rate=args.rate,
                   replicas=args.replicas, n_docs=args.n_docs,
                   dim=args.dim, seed=args.seed)
    print(json.dumps(out, indent=2, default=str))
    if out["violations"]:
        print(f"[soak] FAIL: {', '.join(out['violations'])}",
              file=sys.stderr)
        sys.exit(1)
    print(f"[soak] ok: {out['drive']['n_ok']}/{out['drive']['n']} replies, "
          f"p99={out['drive']['p99_ms']:.1f}ms, zero lost accepted, "
          f"zero misrouted")


if __name__ == "__main__":
    main()
