"""Replicated serving fleet: R RetrievalServer replicas behind one Router.

Topology::

    client -> Router (admission control, least-in-flight dispatch,
              |        retry-with-failover, per-replica health state)
              +-> Replica r0: RetrievalServer over FaultableIndex ----+
              +-> Replica r1: RetrievalServer over FaultableIndex ----+--> one
              +-> Replica r2: RetrievalServer over FaultableIndex ----+   shared
                                                                          index
    ReplicaSet actor thread (the ONLY mutator): appends, compaction,
    rolling index rollout (health-gated, auto-rollback), restarts, and
    the auto-compaction controller.

Every replica serves the SAME logical index version; the per-replica
``FaultableIndex`` proxy exists so the fault-injection harness can
crash/hang/slow one replica without touching the others.

Request lifecycle: ``Router.submit`` either *sheds* (explicit ``Shed``
payload — never a silent drop) when ``max_outstanding`` accepted
requests are already in flight, or accepts and dispatches to the ready
replica with the fewest in-flight requests. A per-replica waiter thread
collects the server reply with a bounded wait; a crash or timeout marks
the replica down and fails the request over to another replica (up to
``max_retries``), and every accepted request ends in exactly ONE
terminal payload — result, ``TimedOut``, ``Shed`` never (it was not
accepted), or an error — so ``stats()['lost_accepted']`` is an invariant
the chaos soak asserts at zero.

Rolling rollout (``ReplicaSet.rollout``): open + validate the new
artifact (a partial/corrupt artifact aborts with the fleet untouched),
record reference answers from the serving fleet, then replica-by-replica
quiesce -> drain -> swap -> probe (recall vs reference, p99, worker
liveness) -> rejoin. Any probe failure swaps every already-swapped
replica back and reports ``rolled_back`` — live traffic is only ever
routed to a replica AFTER its new index passed the probe, so a
recall-regressing rollout serves zero misrouted replies by construction.

Lock discipline (pinned by ``repro.analysis`` and the runtime lock
sanitizer): ``Router._lock`` is the only lock this module creates, it is
only ever acquired with an empty held-lock stack, and no cross-component
call (``server.submit``, ``reply.resolve``, ``updater.*``) happens while
holding it — state is snapshotted under the lock and acted on outside.
``ReplicaSet`` owns NO locks at all: every mutation is serialised
through its single actor thread via a ``queue.Queue``.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from pathlib import Path
from typing import Sequence

import numpy as np

from repro.core import SegmentedIndex
from repro.core.maintenance import IndexUpdater
from repro.core.store import IndexStore
from repro.launch.serve import Reply, RetrievalServer, TimedOut


class Shed(RuntimeError):
    """Admission control rejected the request: the fleet is at capacity.

    Delivered as an explicit reply payload — load shedding is a visible
    outcome, never a silent drop."""


class ReplicaCrash(RuntimeError):
    """Injected (or real) replica failure surfaced through a reply."""


class NoHealthyReplica(RuntimeError):
    """Dispatch found every replica marked down."""


# --------------------------------------------------------------------------
# fault injection
# --------------------------------------------------------------------------

class FaultState:
    """Mutable fault mode shared between a replica's proxy generations.

    ``mode`` is published by plain reference assignment (single writer —
    the injector; readers see either the old or the new mode, both
    valid). ``clear`` releases a pending hang by setting the resume
    event; each new hang gets a FRESH event so cleared hangs don't leak
    into later ones.
    """

    def __init__(self):
        self.mode = None             # None | "crash" | "hang" | ("slow", s)
        self._resume = threading.Event()

    def inject(self, mode) -> None:
        if mode == "hang":
            self._resume = threading.Event()
        self.mode = mode

    def clear(self) -> None:
        self.mode = None
        self._resume.set()

    def apply(self) -> None:
        """Run inside the replica's search call — which executes OUTSIDE
        every server lock (``_dispatch`` snapshots then searches
        unlocked), so a hang parks only this replica's stager."""
        mode = self.mode
        if mode is None:
            return
        if mode == "crash":
            raise ReplicaCrash("injected replica crash")
        if mode == "hang":
            self._resume.wait()
            return
        if isinstance(mode, tuple) and mode[0] == "slow":
            time.sleep(float(mode[1]))


class FaultableIndex:
    """Delegating index proxy that applies the replica's fault mode on
    every search. ``inner`` is rebound on append/compaction swaps (same
    proxy object, read once per search call); rollouts install a fresh
    proxy via ``swap_index`` so (index, projection) stay paired."""

    def __init__(self, inner, state: FaultState | None = None):
        self.inner = inner
        self.state = state if state is not None else FaultState()

    @property
    def dim(self) -> int:
        return self.inner.dim

    @property
    def n(self) -> int:
        return self.inner.n

    @property
    def nbytes(self) -> int:
        return self.inner.nbytes

    def search(self, queries, k: int = 10, **kw):
        inner = self.inner
        self.state.apply()
        return inner.search(queries, k=k, **kw)

    def search_projected(self, queries, components, k: int = 10, **kw):
        inner = self.inner
        self.state.apply()
        return inner.search_projected(queries, components, k=k, **kw)


def corrupt_artifact(path) -> str:
    """Delete one data blob from an on-disk artifact — simulates a torn
    rollout payload. ``IndexStore.open`` must reject the result."""
    p = Path(path)
    blobs = sorted(p.glob("vectors_*.npy")) or sorted(p.glob("*.npy"))
    if not blobs:
        raise FileNotFoundError(f"no data blobs under {path}")
    blobs[0].unlink()
    return str(blobs[0])


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    at_s: float                 # offset from plan start
    action: str                 # kill | hang | slow | clear | restart | corrupt
    replica: str | None = None
    arg: object = None          # slow: seconds; corrupt: artifact path


@dataclasses.dataclass
class FaultPlan:
    """Timed fault schedule, driven by a daemon injector thread."""

    events: Sequence[FaultEvent]

    def start(self, fleet: "ReplicaSet") -> threading.Thread:
        ordered = sorted(self.events, key=lambda e: e.at_s)

        def _inject():
            t0 = time.perf_counter()
            for ev in ordered:
                delay = ev.at_s - (time.perf_counter() - t0)
                if delay > 0:
                    time.sleep(delay)
                fleet.apply_fault(ev)

        th = threading.Thread(target=_inject, daemon=True,
                              name="fault-injector")
        th.start()
        return th


# --------------------------------------------------------------------------
# replicas and routing
# --------------------------------------------------------------------------

class Replica:
    """Plain holder — no locks. ``server``/``faultable`` are rebound by
    the ReplicaSet actor (restart, rollout); readers see a consistent
    reference either way. ``work`` feeds this replica's Router waiter."""

    def __init__(self, name: str, server: RetrievalServer,
                 faultable: FaultableIndex):
        self.name = name
        self.server = server
        self.faultable = faultable
        self.work: queue.Queue = queue.Queue()


class Router:
    """Load-aware front door over a set of replicas.

    One lock (``_lock``) guards the health/load/counter state; it is
    never held across a call into a replica or a reply — pick under the
    lock, dispatch outside it.
    """

    def __init__(self, replicas: Sequence[Replica], *,
                 max_outstanding: int = 256,
                 replica_timeout: float = 5.0,
                 max_retries: int = 2):
        self.replicas = tuple(replicas)
        self.max_outstanding = max_outstanding
        self.replica_timeout = replica_timeout
        self.max_retries = max_retries
        self._lock = threading.Lock()
        self._loads = {r.name: 0 for r in replicas}
        self._down: set = set()
        self._outstanding = 0
        self._counters = {"accepted": 0, "shed": 0, "completed": 0,
                          "timed_out": 0, "failed": 0, "failovers": 0,
                          "marked_down": 0}
        self._stop = threading.Event()
        self._threads = [threading.Thread(target=self._waiter, args=(r,),
                                          daemon=True,
                                          name=f"waiter-{r.name}")
                         for r in replicas]
        for t in self._threads:
            t.start()

    # -- client API ---------------------------------------------------------
    def submit(self, qvec: np.ndarray, deadline: float | None = None) -> Reply:
        """Admit-or-shed, then dispatch. Always returns a Reply that will
        carry exactly one terminal payload."""
        abs_dl = (None if deadline is None
                  else time.perf_counter() + deadline)
        reply = Reply(deadline=abs_dl)
        with self._lock:
            shed = self._outstanding >= self.max_outstanding
            if shed:
                self._counters["shed"] += 1
            else:
                self._outstanding += 1
                self._counters["accepted"] += 1
        if shed:
            reply.resolve(Shed(
                f"fleet at capacity ({self.max_outstanding} outstanding)"),
                time.perf_counter())
            return reply
        self._dispatch(qvec, reply, attempts=0)
        return reply

    def query(self, qvec: np.ndarray, timeout: float = 30.0,
              deadline: float | None = None):
        out = self.submit(qvec, deadline=deadline).get(timeout=timeout)
        if isinstance(out, BaseException):
            raise out
        return out

    def reset_stats(self) -> None:
        with self._lock:
            self._counters = dict.fromkeys(self._counters, 0)
        for rep in self.replicas:
            if rep.server.error is None:
                rep.server.reset_stats()

    # -- health / introspection --------------------------------------------
    def quiesce(self, name: str) -> None:
        """Stop routing NEW work to ``name`` (maintenance or failure)."""
        with self._lock:
            self._down.add(name)

    def revive(self, name: str) -> None:
        with self._lock:
            self._down.discard(name)

    def loads(self) -> dict:
        with self._lock:
            return dict(self._loads)

    def states(self) -> dict:
        with self._lock:
            return {name: ("down" if name in self._down else "up")
                    for name in self._loads}

    def stats(self) -> dict:
        with self._lock:
            out = dict(self._counters)
            out["outstanding"] = self._outstanding
            out["loads"] = dict(self._loads)
            out["down"] = sorted(self._down)
            # droplessness invariant: every accepted request must end in
            # exactly one terminal payload — once outstanding drains to
            # zero, any residue here is a silently dropped reply
            out["lost_accepted"] = (out["accepted"] - out["completed"]
                                    - out["timed_out"] - out["failed"]
                                    - out["outstanding"])
        return out

    def close(self) -> None:
        self._stop.set()
        for rep in self.replicas:
            rep.work.put(None)
        for t in self._threads:
            t.join(timeout=10.0)

    # -- dispatch internals -------------------------------------------------
    def _pick(self) -> Replica | None:
        with self._lock:
            up = [r for r in self.replicas if r.name not in self._down]
            if not up:
                return None
            rep = min(up, key=lambda r: self._loads[r.name])
            self._loads[rep.name] += 1
        return rep

    def _unload(self, name: str) -> None:
        with self._lock:
            # clamp: items dispatched before a restart may drain after
            # the load counter was rebuilt
            self._loads[name] = max(0, self._loads[name] - 1)

    def _mark_down(self, name: str) -> None:
        with self._lock:
            if name not in self._down:
                self._down.add(name)
                self._counters["marked_down"] += 1

    def _dispatch(self, qvec, reply: Reply, attempts: int) -> None:
        rep = self._pick()
        if rep is None:
            self._finish(reply, NoHealthyReplica("every replica is down"))
            return
        now = time.perf_counter()
        budget = self.replica_timeout
        if reply.deadline is not None:
            budget = min(budget, max(0.01, reply.deadline - now))
        try:
            srv_reply = rep.server.submit(qvec, deadline=budget)
        except Exception as e:   # crashed or invalid replica: fail over
            self._unload(rep.name)
            self._mark_down(rep.name)
            self._retry(qvec, reply, attempts, e)
            return
        rep.work.put((reply, srv_reply, qvec, attempts, now + budget))

    def _retry(self, qvec, reply: Reply, attempts: int,
               cause: BaseException) -> None:
        with self._lock:
            self._counters["failovers"] += 1
        now = time.perf_counter()
        if (attempts + 1 > self.max_retries
                or (reply.deadline is not None and reply.deadline <= now)):
            self._finish(reply, cause)
            return
        self._dispatch(qvec, reply, attempts + 1)

    def _finish(self, reply: Reply, payload, t: float | None = None) -> None:
        """Deliver the terminal payload (outside every lock), then account
        for it. Called exactly once per accepted request."""
        reply.resolve(payload, time.perf_counter() if t is None else t)
        with self._lock:
            self._outstanding -= 1
            if isinstance(payload, TimedOut):
                self._counters["timed_out"] += 1
            elif isinstance(payload, BaseException):
                self._counters["failed"] += 1
            else:
                self._counters["completed"] += 1

    def _waiter(self, rep: Replica) -> None:
        """Collect server replies for one replica with BOUNDED waits; a
        timeout or crash marks the replica down and fails the request
        over. Items carry their own absolute wait limit, so a wedged
        head-of-line item does not serialise the timeouts behind it."""
        while True:
            try:
                item = rep.work.get(timeout=0.2)
            except queue.Empty:
                if self._stop.is_set():
                    return
                continue
            if item is None:
                return
            reply, srv_reply, qvec, attempts, t_limit = item
            try:
                # the server's own deadline sweep normally resolves first
                # (TimedOut payload); the +0.5 grace only catches a fully
                # dead server whose sweep is gone too
                wait = max(0.01, t_limit - time.perf_counter()) + 0.5
                out = srv_reply.get(timeout=wait)
            except queue.Empty:
                out = TimedOut(f"replica {rep.name}: no reply by deadline")
            self._unload(rep.name)
            if isinstance(out, tuple):
                self._finish(reply, out, srv_reply.completed_at)
                continue
            if isinstance(out, TimedOut):
                now = time.perf_counter()
                if reply.deadline is not None and reply.deadline <= now:
                    # the CLIENT deadline expired — not the replica's
                    # fault; report without penalising the replica
                    self._finish(reply, out)
                    continue
            self._mark_down(rep.name)
            self._retry(qvec, reply, attempts, out)


# --------------------------------------------------------------------------
# policies
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HealthPolicy:
    """Rollout gate: probe each swapped replica before it rejoins."""

    probes: int = 8              # probe queries per replica
    k: int = 10
    min_recall: float = 0.9      # mean top-k overlap vs pre-rollout answers
    max_p99_ms: float = 2000.0   # probe latency ceiling (post-warmup)
    timeout_s: float = 10.0      # per-probe reply timeout


@dataclasses.dataclass(frozen=True)
class AutoCompactPolicy:
    """Compaction controller thresholds (closes the PR 5 follow-up): when
    the delta tier outgrows the base or per-segment scales diverge, the
    actor compacts and swaps the fresh base into the least-loaded replica
    first."""

    max_delta_fraction: float = 0.25
    max_scale_divergence: float = 1.5    # scale ratio; floor is 1.0
    interval_s: float = 1.0      # evaluation cadence


# --------------------------------------------------------------------------
# the fleet
# --------------------------------------------------------------------------

class ReplicaSet:
    """R replicas over one IndexStore, one Router, one maintenance actor.

    The actor thread is the only code that mutates the index, the store,
    or replica membership — appends, compaction (including the
    auto-compaction controller), rollouts, and restarts are all
    serialised through ``_tasks``, so no replica-swap race is possible
    and the class needs no locks of its own.
    """

    def __init__(self, store, *, replicas: int = 3, k: int = 10,
                 max_batch: int = 32, pipeline_depth: int = 3,
                 backend: str = "jnp", delta_capacity: int = 4096,
                 max_outstanding: int = 256, replica_timeout: float = 5.0,
                 max_retries: int = 2,
                 health_policy: HealthPolicy | None = None,
                 autocompact: AutoCompactPolicy | None = None,
                 probe_queries: np.ndarray | None = None):
        if not isinstance(store, IndexStore):
            store = IndexStore.open(store)
        self.store = store
        self.k = k
        self.max_batch = max_batch
        self.pipeline_depth = pipeline_depth
        self.backend = backend
        self.delta_capacity = delta_capacity
        self.health_policy = health_policy or HealthPolicy()
        self.autocompact = autocompact
        self.probe_queries = probe_queries
        self.pruner = store.load_pruner()
        self.index = SegmentedIndex.load(store, backend=backend,
                                         delta_capacity=delta_capacity)
        self.version = str(store.path)
        self.events: list = []       # actor-appended; snapshot via health()
        self.replicas = []
        for i in range(replicas):
            f = FaultableIndex(self.index)
            srv = RetrievalServer(f, self.pruner, k=k, max_batch=max_batch,
                                  pipeline_depth=pipeline_depth)
            self.replicas.append(Replica(f"r{i}", srv, f))
        self.router = Router(self.replicas, max_outstanding=max_outstanding,
                             replica_timeout=replica_timeout,
                             max_retries=max_retries)
        self.updater = IndexUpdater(pruner=self.pruner, index=self.index,
                                    store=store, server=None,
                                    delta_capacity=delta_capacity)
        self._tasks: queue.Queue = queue.Queue()
        self._stop = threading.Event()
        self._last_tick = time.monotonic()
        self._actor_thread = threading.Thread(target=self._actor, daemon=True,
                                              name="fleet-actor")
        self._actor_thread.start()

    # -- client passthroughs (duck-types RetrievalServer for the driver) ----
    def submit(self, qvec, deadline: float | None = None) -> Reply:
        return self.router.submit(qvec, deadline=deadline)

    def query(self, qvec, timeout: float = 30.0,
              deadline: float | None = None):
        return self.router.query(qvec, timeout=timeout, deadline=deadline)

    def reset_stats(self) -> None:
        self.router.reset_stats()

    def stats(self) -> dict:
        return self.router.stats()

    def health(self) -> dict:
        maint = self.updater.health()
        states = self.router.states()
        reps = {}
        for rep in self.replicas:
            err = rep.server.error
            reps[rep.name] = {"state": states.get(rep.name, "up"),
                              "error": None if err is None else repr(err)}
        ok = maint["ok"] and all(v["error"] is None and v["state"] == "up"
                                 for v in reps.values())
        return {"ok": ok, "version": self.version, "maintenance": maint,
                "replicas": reps, "events": list(self.events)}

    # -- maintenance API (serialised through the actor) ---------------------
    def append(self, rows, timeout: float = 120.0) -> int:
        return self._call("append", timeout, rows=rows)

    def compact(self, timeout: float = 600.0) -> None:
        return self._call("compact", timeout)

    def rollout(self, path, timeout: float = 600.0) -> dict:
        return self._call("rollout", timeout, path=path)

    def restart(self, name: str, timeout: float = 120.0) -> None:
        return self._call("restart", timeout, name=name)

    def apply_fault(self, ev: FaultEvent) -> None:
        """Fault-plan entry point; mutating actions route via the actor."""
        if ev.action == "corrupt":
            removed = corrupt_artifact(ev.arg)
            self.events.append({"kind": "fault", "action": "corrupt",
                                "blob": removed})
            return
        if ev.action == "restart":
            self.restart(ev.replica)
            return
        state = self._replica(ev.replica).faultable.state
        if ev.action == "kill":
            state.inject("crash")
        elif ev.action == "hang":
            state.inject("hang")
        elif ev.action == "slow":
            state.inject(("slow", float(ev.arg if ev.arg is not None
                                        else 0.05)))
        elif ev.action == "clear":
            state.clear()
        else:
            raise ValueError(f"unknown fault action {ev.action!r}")
        self.events.append({"kind": "fault", "action": ev.action,
                            "replica": ev.replica})

    def close(self) -> None:
        self._stop.set()
        self._tasks.put(None)
        self._actor_thread.join(timeout=30.0)
        for rep in self.replicas:
            rep.faultable.state.clear()   # release any injected hang
        self.router.close()
        for rep in self.replicas:
            rep.server.close()

    # -- actor --------------------------------------------------------------
    def _replica(self, name: str) -> Replica:
        for rep in self.replicas:
            if rep.name == name:
                return rep
        raise KeyError(f"no replica named {name!r}")

    def _call(self, kind: str, timeout: float, **kw):
        box = {"evt": threading.Event(), "out": None, "err": None}
        self._tasks.put((kind, kw, box))
        if not box["evt"].wait(timeout=timeout):
            raise TimeoutError(f"fleet task {kind!r} did not finish "
                               f"within {timeout}s")
        if box["err"] is not None:
            raise box["err"]
        return box["out"]

    def _actor(self) -> None:
        handlers = {"append": self._task_append,
                    "compact": self._task_compact,
                    "rollout": self._task_rollout,
                    "restart": self._task_restart}
        while not self._stop.is_set():
            try:
                task = self._tasks.get(timeout=0.1)
            except queue.Empty:
                self._maybe_autocompact()
                continue
            if task is None:
                return
            kind, kw, box = task
            try:
                box["out"] = handlers[kind](**kw)
            except BaseException as e:   # noqa: BLE001 — relayed to caller
                box["err"] = e
            finally:
                box["evt"].set()

    def _maybe_autocompact(self) -> None:
        pol = self.autocompact
        if pol is None:
            return
        now = time.monotonic()
        if now - self._last_tick < pol.interval_s:
            return
        self._last_tick = now
        df = self.updater.delta_fraction
        sd = self.updater.scale_divergence()
        if df <= pol.max_delta_fraction and sd <= pol.max_scale_divergence:
            return
        loads = self.router.loads()
        target = min(loads, key=loads.get) if loads else None
        self.events.append({"kind": "autocompact", "delta_fraction": df,
                            "scale_divergence": sd, "first_swap": target})
        self.updater.compact()
        self._adopt_updater()
        self._swap_all(order_first=target)

    def _adopt_updater(self) -> None:
        """Pull the updater's post-mutation view into the fleet."""
        self.index = self.updater.index
        if self.updater.store is not None:
            self.store = self.updater.store

    def _swap_all(self, order_first: str | None = None) -> None:
        """Install ``self.index`` on every replica (same projection —
        appends/compaction never change the rotation), least-loaded
        first so the fresh arrays warm where it is cheapest."""
        reps = sorted(self.replicas, key=lambda r: r.name != order_first)
        for rep in reps:
            rep.faultable.inner = self.index
            rep.server.swap_index(rep.faultable)

    def _task_append(self, rows) -> int:
        n = self.updater.add_documents(rows)
        self._adopt_updater()
        self._swap_all()
        return n

    def _task_compact(self) -> None:
        self.updater.compact()
        self._adopt_updater()
        self._swap_all()

    def _task_restart(self, name: str) -> None:
        rep = self._replica(name)
        self.router.quiesce(name)            # no new dispatches mid-restart
        self._await_drain(name)
        rep.faultable.state.clear()          # un-hang before joining threads
        try:
            rep.server.close()
        except Exception:                    # noqa: BLE001 — replacing anyway
            pass
        fresh = FaultableIndex(self.index, rep.faultable.state)
        rep.faultable = fresh
        rep.server = RetrievalServer(fresh, self.pruner, k=self.k,
                                     max_batch=self.max_batch,
                                     pipeline_depth=self.pipeline_depth)
        self.router.revive(name)
        self.events.append({"kind": "restart", "replica": name})

    # -- rolling rollout ----------------------------------------------------
    def _await_drain(self, name: str, timeout: float = 10.0) -> None:
        t0 = time.monotonic()
        while self.router.loads().get(name, 0) > 0:
            if time.monotonic() - t0 > timeout:
                break                        # swap is batch-atomic anyway
            time.sleep(0.005)

    def _probe_set(self) -> np.ndarray:
        pol = self.health_policy
        if self.probe_queries is None:
            raise RuntimeError("rollout needs probe_queries: the health "
                               "gate compares answers before/after swap")
        return np.asarray(self.probe_queries)[:pol.probes]

    def _reference_answers(self, probes: np.ndarray) -> list:
        """Top-k ids from a currently-serving healthy replica (bypasses
        admission so a saturated fleet can still health-check)."""
        states = self.router.states()
        rep = next((r for r in self.replicas
                    if states.get(r.name) == "up" and r.server.error is None),
                   None)
        if rep is None:
            raise NoHealthyReplica("no healthy replica to take rollout "
                                   "reference answers from")
        pol = self.health_policy
        return [np.asarray(rep.server.query(q, timeout=pol.timeout_s)[1])
                for q in probes]

    def _probe(self, rep: Replica, probes: np.ndarray, ref: list) -> dict:
        """Health-check one swapped replica: recall vs the pre-rollout
        reference and probe p99. First probe is untimed warmup (a fresh
        index's first batch may pay a compile)."""
        pol = self.health_policy
        try:
            rep.server.query(probes[0], timeout=pol.timeout_s)
        except Exception as e:
            return {"replica": rep.name, "ok": False,
                    "reason": f"warmup probe failed: {e!r}"}
        recalls, lats = [], []
        for q, ids_ref in zip(probes, ref):
            t0 = time.perf_counter()
            try:
                _, ids = rep.server.query(q, timeout=pol.timeout_s)
            except Exception as e:
                return {"replica": rep.name, "ok": False,
                        "reason": f"probe failed: {e!r}"}
            lats.append(time.perf_counter() - t0)
            got = np.asarray(ids)[:pol.k]
            want = set(np.asarray(ids_ref)[:pol.k].tolist())
            recalls.append(len(want & set(got.tolist())) / max(1, len(want)))
        recall = float(np.mean(recalls))
        p99_ms = float(np.percentile(np.array(lats) * 1e3, 99))
        ok = (recall >= pol.min_recall and p99_ms <= pol.max_p99_ms
              and rep.server.error is None)
        return {"replica": rep.name, "ok": ok, "recall": recall,
                "p99_ms": p99_ms}

    def _swap_replica(self, rep: Replica, index, pruner) -> None:
        """Quiesce -> drain -> install (index, pruner) atomically."""
        self.router.quiesce(rep.name)
        self._await_drain(rep.name)
        fresh = FaultableIndex(index, rep.faultable.state)
        rep.server.swap_index(fresh, pruner=pruner)
        rep.faultable = fresh

    def _task_rollout(self, path) -> dict:
        pol = self.health_policy
        result = {"kind": "rollout", "version": str(path), "ok": False,
                  "rolled_back": False, "per_replica": []}
        try:
            # open + validate BEFORE touching any replica: a torn or
            # corrupt artifact aborts here with the fleet untouched
            store_new = IndexStore.open(path)
            pruner_new = store_new.load_pruner()
            index_new = SegmentedIndex.load(
                store_new, backend=self.backend,
                delta_capacity=self.delta_capacity)
        except Exception as e:
            result["reason"] = f"artifact rejected: {e!r}"
            self.events.append(result)
            return result
        probes = self._probe_set()
        ref = self._reference_answers(probes)
        prev_index, prev_pruner = self.index, self.pruner
        swapped: list[Replica] = []
        for rep in self.replicas:
            self._swap_replica(rep, index_new, pruner_new)
            swapped.append(rep)
            verdict = self._probe(rep, probes, ref)
            result["per_replica"].append(verdict)
            if not verdict["ok"]:
                # regression: swap every touched replica back BEFORE any
                # of them rejoins — live traffic never saw the bad index
                for r in swapped:
                    self._swap_replica(r, prev_index, prev_pruner)
                    self.router.revive(r.name)
                result["rolled_back"] = True
                result["reason"] = verdict.get("reason", "probe regression")
                self.events.append(result)
                return result
            self.router.revive(rep.name)
        self.index, self.pruner, self.store = index_new, pruner_new, store_new
        self.version = str(path)
        self.updater = IndexUpdater(pruner=pruner_new, index=index_new,
                                    store=store_new, server=None,
                                    delta_capacity=self.delta_capacity)
        result["ok"] = True
        self.events.append(result)
        return result
