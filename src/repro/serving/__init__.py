"""Replicated serving: a health-checked fleet of RetrievalServers."""

from repro.serving.fleet import (AutoCompactPolicy, FaultEvent, FaultPlan,
                                 FaultState, FaultableIndex, HealthPolicy,
                                 NoHealthyReplica, Replica, ReplicaCrash,
                                 ReplicaSet, Router, Shed, corrupt_artifact)

__all__ = ["AutoCompactPolicy", "FaultEvent", "FaultPlan", "FaultState",
           "FaultableIndex", "HealthPolicy", "NoHealthyReplica", "Replica",
           "ReplicaCrash", "ReplicaSet", "Router", "Shed",
           "corrupt_artifact"]
