"""Path-based sharding rules with divisibility fallback.

One engine drives every architecture on the same mesh: a rule proposes
logical shardings for a param-tree path; each *clause group* is tried in
order and the first group whose every (dim, axis) divides evenly is used.
That is what lets smollm (9 heads) and arctic (56 heads, 128 experts)
coexist on a 16-wide 'model' axis: smollm's attention falls through its
head-sharded clause to a replicated fallback while its MLP/vocab dims still
shard; arctic takes the expert-parallel clause.

Logical axes:
  * ``dp``  — data parallel: ('pod', 'data') when the mesh has a pod axis
  * ``tp``  — tensor parallel: ('model',)
  * ``ep``  — expert parallel: ('model',)   (same physical axis as tp —
              an expert-sharded layer is *not* additionally TP-sharded)
  * ``sp``  — sequence parallel: ('model',) for long-context KV/activations
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LOGICAL_AXES = ("dp", "tp", "ep", "sp")

# A clause is (dim, logical_axis). A clause group is a tuple of clauses that
# must all fit. A rule maps a path regex to an ordered list of clause groups.
Clause = tuple[int, str]
ClauseGroup = tuple[Clause, ...]


def logical_to_physical(logical: str, mesh: Mesh) -> tuple[str, ...]:
    names = mesh.axis_names
    if logical == "dp":
        return tuple(n for n in ("pod", "data") if n in names) or (names[0],)
    if logical in ("tp", "ep", "sp"):
        return ("model",) if "model" in names else ()
    if logical == "fsdp":   # every mesh axis (huge embedding tables)
        return tuple(names)
    raise ValueError(f"unknown logical axis {logical}")


def _axis_size(mesh: Mesh, phys: Sequence[str]) -> int:
    size = 1
    for p in phys:
        size *= mesh.shape[p]
    return size


@dataclasses.dataclass
class ShardingRules:
    """Ordered (regex, clause-groups) rules applied to '/'-joined tree paths."""

    rules: list[tuple[str, list[ClauseGroup]]]

    def spec(self, path: str, shape: tuple[int, ...], mesh: Mesh) -> P:
        for pattern, groups in self.rules:
            if re.search(pattern, path):
                for group in groups:
                    assign: dict[int, tuple[str, ...]] = {}
                    ok = True
                    for dim, logical in group:
                        d = dim if dim >= 0 else len(shape) + dim
                        phys = logical_to_physical(logical, mesh)
                        if not phys or d >= len(shape) or d in assign:
                            ok = False
                            break
                        if shape[d] % _axis_size(mesh, phys) != 0:
                            ok = False
                            break
                        assign[d] = phys
                    if ok and assign:
                        parts: list[Any] = [None] * len(shape)
                        for d, phys in assign.items():
                            parts[d] = phys if len(phys) > 1 else phys[0]
                        return P(*parts)
                return P()  # matched a rule but nothing fits -> replicate
        return P()


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def spec_for(tree: Any, mesh: Mesh, rules: ShardingRules) -> Any:
    """PartitionSpec tree for a pytree of arrays/ShapeDtypeStructs."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: rules.spec(_path_str(path), leaf.shape, mesh), tree)


def param_specs(params_shape: Any, mesh: Mesh, rules: ShardingRules) -> Any:
    return spec_for(params_shape, mesh, rules)


def named_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def data_spec(mesh: Mesh, ndim: int, *, batch_dim: int = 0,
              extra: dict[int, str] | None = None) -> P:
    """Batch-dim over dp; optional extra {dim: logical} (divisibility NOT
    checked here — callers pass shapes they control)."""
    parts: list[Any] = [None] * ndim
    dp = logical_to_physical("dp", mesh)
    parts[batch_dim] = dp if len(dp) > 1 else dp[0]
    for d, logical in (extra or {}).items():
        phys = logical_to_physical(logical, mesh)
        if phys:
            parts[d] = phys if len(phys) > 1 else phys[0]
    return P(*parts)


def replicated(ndim: int) -> P:
    return P()


# ---------------------------------------------------------------------------
# Stock rule sets per model family
# ---------------------------------------------------------------------------


def lm_rules(moe: bool = False, moe_dp_dim: str = "ff") -> ShardingRules:
    """2-D FSDP×TP (+ EP or per-expert-TP) for decoder LMs — MaxText-style.

    Every weight matrix shards one dim over 'tp' (model axis) and, where it
    divides, a second dim over 'dp' (data [+pod] axes) — ZeRO-3/FSDP
    semantics via GSPMD: weights are all-gathered per layer, param/grad/
    optimizer memory drops by |dp|. A 480B Arctic fits a 256-chip pod this
    way; smollm falls through the same rules to mostly-replicated.

    Stacked layers add a leading L dim, so in-layer dims shift by +1 —
    rules use negative dims to stay layout-agnostic.
    """
    r: list[tuple[str, list[ClauseGroup]]] = [
        # embeddings: vocab over tp, d_model over dp
        (r"(^|/)embed$", [((-2, "tp"), (-1, "dp")), ((-2, "tp"),)]),
        (r"(^|/)unembed$", [((-2, "tp"), (-1, "dp")), ((-2, "tp"),)]),
        (r"pos_embed$", [()]),
        # attention: fused head dim over tp, d_model over dp; wo transposed
        (r"attn/w[qkv]/w$", [((-1, "tp"), (-2, "dp")), ((-1, "tp"),)]),
        (r"attn/w[qkv]/b$", [((-1, "tp"),)]),
        (r"attn/wo/w$", [((-2, "tp"), (-1, "dp")), ((-2, "tp"),)]),
        # dense MLP: ff over tp, d_model over dp
        (r"mlp/w[13]/w$", [((-1, "tp"), (-2, "dp")), ((-1, "tp"),)]),
        (r"mlp/w2/w$", [((-2, "tp"), (-1, "dp")), ((-2, "tp"),)]),
    ]
    if moe:
        if moe_dp_dim == "d_model":
            # EP over tp + d_model over dp: the expert GEMMs contract (w1)
            # or produce (w2) the dp-sharded dim, so the expert_in/out
            # buffers stay group-sharded and only (E_loc,G_loc,C,ff) psums
            # + (…,d) gathers cross dp — ~15x less than gathering the full
            # dispatched activations over the ff-FSDP conflict (see
            # EXPERIMENTS.md §Perf arctic log).
            r += [
                (r"moe/w[13]$", [((-3, "ep"), (-2, "dp")), ((-3, "ep"),),
                                 ((-1, "tp"), (-2, "dp")), ((-1, "tp"),)]),
                (r"moe/w2$", [((-3, "ep"), (-1, "dp")), ((-3, "ep"),),
                              ((-2, "tp"), (-1, "dp")), ((-2, "tp"),)]),
                (r"moe/router", [()]),
            ]
        else:
            r += [
                # experts: EP over tp + ff over dp; fallbacks degrade gracefully
                (r"moe/w[13]$", [((-3, "ep"), (-1, "dp")), ((-3, "ep"),),
                                 ((-1, "tp"), (-2, "dp")), ((-1, "tp"),)]),
                (r"moe/w2$", [((-3, "ep"), (-2, "dp")), ((-3, "ep"),),
                              ((-2, "tp"), (-1, "dp")), ((-2, "tp"),)]),
                (r"moe/router", [()]),
            ]
    r.append((r".*", [()]))
    return ShardingRules(r)


def lm_rules_dp_only() -> ShardingRules:
    """Pure data parallelism: params replicated (ZeRO-1 still dp-shards the
    optimizer moments). The correct layout for models whose per-layer TP
    all-reduces dwarf their compute (e.g. smollm-135m — §Perf cell 4)."""
    return ShardingRules([(r".*", [()])])


def biencoder_rules() -> ShardingRules:
    base = lm_rules(moe=False).rules
    return ShardingRules([(r"(^|/)proj/w$", [((-2, "tp"),)])] + base)


def gnn_rules() -> ShardingRules:
    # GNN params are small MLPs — replicate everything; parallelism lives in
    # the edge/node data sharding.
    return ShardingRules([(r".*", [()])])


def recsys_rules() -> ShardingRules:
    return ShardingRules([
        # big embedding tables: rows FSDP-sharded over every mesh axis
        # (e.g. DLRM's 188M rows x 128 => 375 MB/chip on 256 chips)
        (r"tables/\d+$", [((0, "fsdp"),), ((0, "tp"),)]),
        (r"(user|item)_embed$", [((0, "fsdp"),), ((0, "tp"),)]),
        (r"first_order/\d+$", [((0, "fsdp"),), ((0, "tp"),)]),
        # MLPs: modest — shard the wide hidden dims where divisible
        (r"(bot_mlp|top_mlp|deep_mlp|user_tower|item_tower)/\d+/w$",
         [((-1, "tp"),)]),
        (r".*", [()]),
    ])
